//! Fault tolerance demo (the paper's Experiment 2 setting): 12 clients,
//! n/3 = 4 crash mid-run at staggered rounds; the survivors detect the
//! crashes by timeout, keep aggregating, and still terminate adaptively.
//!
//!     make artifacts && cargo run --release --example fault_tolerance

use anyhow::Result;
use dfl::coordinator::fault::proportional_schedule;
use dfl::coordinator::termination::TerminationCause;
use dfl::runtime::{SharedEngine, Trainer};
use dfl::sim::{self, Partition, SimConfig};
use dfl::util::Rng;

fn main() -> Result<()> {
    let engine = SharedEngine::load(std::path::Path::new("artifacts/tiny"))?;
    let meta = engine.meta().clone();

    let n = 12;
    let mut cfg = SimConfig::for_meta(n, &meta);
    cfg.partition = Partition::Dirichlet(0.6);
    cfg.machines = 3; // spread over the three virtual machines
    cfg.protocol.max_rounds = 70;
    cfg.seed = 99;
    let mut rng = Rng::new(cfg.seed);
    cfg.faults = proportional_schedule(n, cfg.protocol.max_rounds, &mut rng);
    let planned: Vec<usize> =
        cfg.faults.iter().enumerate().filter(|(_, f)| f.crash.is_some()).map(|(i, _)| i).collect();
    println!("12 clients, scheduled mid-run crashes for clients {planned:?}");

    let res = sim::run(&engine, &cfg)?;

    let mut crashed = 0;
    for r in &res.reports {
        match r.cause {
            TerminationCause::Crashed => {
                crashed += 1;
                println!("client {:>2}: CRASHED at round {}", r.id, r.rounds_completed);
            }
            cause => println!(
                "client {:>2}: {:?} rounds={} acc={:.1}% detected_crashes={}",
                r.id,
                cause,
                r.rounds_completed,
                r.final_accuracy.unwrap_or(0.0) * 100.0,
                r.history.iter().map(|h| h.crashes_detected.len()).sum::<usize>(),
            ),
        }
    }
    println!(
        "\n{} crashed / {} survived | survivor mean accuracy {:.1}% | wall {:.1}s",
        crashed,
        n - crashed,
        res.mean_accuracy().unwrap_or(0.0) * 100.0,
        res.wall.as_secs_f64()
    );
    assert_eq!(crashed, 4, "expected exactly n/3 crashes");
    Ok(())
}
