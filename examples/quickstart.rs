//! Quickstart: 4 asynchronous clients, non-IID data, train until the
//! Client-Confident Convergence / Client-Responsive Termination protocol
//! shuts the deployment down.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use dfl::runtime::{SharedEngine, Trainer};
use dfl::sim::{self, Partition, SimConfig};

fn main() -> Result<()> {
    let engine = SharedEngine::load(std::path::Path::new("artifacts/tiny"))?;
    let meta = engine.meta().clone();
    println!("loaded artifact config `{}` ({} params)", meta.config, meta.n_params);

    let mut cfg = SimConfig::for_meta(4, &meta);
    cfg.partition = Partition::Dirichlet(0.6); // the paper's non-IID split
    cfg.protocol.max_rounds = 70;
    cfg.seed = 7;

    println!("running 4 async clients (Phase 2) until adaptive termination…");
    let res = sim::run(&engine, &cfg)?;

    for r in &res.reports {
        println!(
            "client {}: {:?} after {} rounds, final accuracy {}",
            r.id,
            r.cause,
            r.rounds_completed,
            r.final_accuracy.map(|a| format!("{:.1}%", a * 100.0)).unwrap_or("-".into())
        );
    }
    println!(
        "\nmean accuracy {:.1}% in {:.1}s — adaptive termination: {}",
        res.mean_accuracy().unwrap_or(0.0) * 100.0,
        res.wall.as_secs_f64(),
        res.all_terminated_adaptively()
    );
    Ok(())
}
