//! Real multi-process deployment over localhost TCP sockets: this launcher
//! spawns N `dfl client` OS processes (the paper's multi-machine setup,
//! collapsed onto one host — point the peer lists at real hosts to spread
//! it across a LAN exactly like the paper's testbed).
//!
//! One client is told to crash mid-run; the rest must detect it by timeout
//! and still terminate adaptively.
//!
//!     make build && cargo run --release --example tcp_cluster

use std::process::{Command, Stdio};

use anyhow::{Context, Result};

fn main() -> Result<()> {
    let n: usize = 4;
    let base_port = 47310u16;
    let bin = std::env::var("DFL_BIN").unwrap_or_else(|_| "target/release/dfl".into());
    if !std::path::Path::new(&bin).exists() {
        anyhow::bail!("{bin} not built — run `cargo build --release` first");
    }

    let addr = |i: usize| format!("127.0.0.1:{}", base_port + i as u16);
    let mut children = Vec::new();
    for i in 0..n {
        let peers: Vec<String> =
            (0..n).filter(|&j| j != i).map(|j| format!("{j}={}", addr(j))).collect();
        let mut cmd = Command::new(&bin);
        cmd.args([
            "client",
            "--config",
            "tiny",
            "--id",
            &i.to_string(),
            "--listen",
            &addr(i),
            "--peers",
            &peers.join(","),
            "--rounds",
            "12",
            "--timeout-ms",
            "800",
            "--seed",
            "11",
        ]);
        if i == n - 1 {
            cmd.args(["--crash-at-round", "4"]); // inject one real crash
        }
        cmd.stdout(Stdio::piped()).stderr(Stdio::inherit());
        println!("spawning client {i} on {}", addr(i));
        children.push((i, cmd.spawn().with_context(|| format!("spawning client {i}"))?));
    }

    let mut ok = true;
    for (i, child) in children {
        let out = child.wait_with_output()?;
        let stdout = String::from_utf8_lossy(&out.stdout);
        print!("--- client {i} ---\n{stdout}");
        if !out.status.success() {
            ok = false;
            eprintln!("client {i} exited with {:?}", out.status);
        }
    }
    anyhow::ensure!(ok, "some clients failed");
    println!("\ntcp cluster run complete: survivors detected the crash and terminated.");
    Ok(())
}
