//! Termination trace: watch CCC fire on one client and the CRT flag flood
//! the network.  Prints, per client, who initiated (source=None) and who
//! was signalled by whom, plus the convergence-counter trajectory of the
//! initiator.
//!
//!     make artifacts && cargo run --release --example termination_trace

use anyhow::Result;
use dfl::coordinator::termination::TerminationCause;
use dfl::runtime::{SharedEngine, Trainer};
use dfl::sim::{self, Partition, SimConfig};

fn main() -> Result<()> {
    let engine = SharedEngine::load(std::path::Path::new("artifacts/tiny"))?;
    let meta = engine.meta().clone();

    let mut cfg = SimConfig::for_meta(6, &meta);
    cfg.partition = Partition::Iid; // IID converges fastest -> clean trace
    cfg.protocol.max_rounds = 70;
    cfg.protocol.count_threshold = 3;
    cfg.seed = 4;

    let res = sim::run(&engine, &cfg)?;

    println!("=== termination provenance ===");
    for r in &res.reports {
        match (r.cause, r.signal_source) {
            (TerminationCause::Converged, _) => println!(
                "client {} INITIATED termination (CCC) at round {}",
                r.id, r.rounds_completed
            ),
            (TerminationCause::Signaled, Some(src)) => println!(
                "client {} terminated via CRT flag first heard from client {} (round {})",
                r.id, src, r.rounds_completed
            ),
            (cause, _) => println!("client {} ended with {:?}", r.id, cause),
        }
    }

    if let Some(initiator) =
        res.reports.iter().find(|r| r.cause == TerminationCause::Converged)
    {
        println!("\n=== initiator (client {}) convergence trajectory ===", initiator.id);
        println!("round | delta_rel | counter | alive_peers");
        for h in &initiator.history {
            println!(
                "{:>5} | {:>9.5} | {:>7} | {}",
                h.round,
                if h.delta_rel.is_finite() { h.delta_rel } else { 9.9 },
                h.conv_counter,
                h.alive_peers
            );
        }
    }
    println!(
        "\nall clients terminated adaptively: {}",
        res.all_terminated_adaptively()
    );
    Ok(())
}
