//! End-to-end driver (EXPERIMENTS.md §E2E): a full federated training run
//! with the `fast` artifact set — 6 asynchronous clients, Dirichlet(0.6)
//! non-IID split, crash injection, loss/accuracy curves logged per round to
//! CSV, final models cross-validated against each other.
//!
//! Every training step, evaluation and aggregation on this path executes
//! AOT-compiled HLO through PJRT; python is not involved.
//!
//!     make artifacts && cargo run --release --example e2e_train [config]

use anyhow::Result;
use dfl::coordinator::fault::FaultPlan;
use dfl::model::ParamVector;
use dfl::runtime::{SharedEngine, Trainer};
use dfl::sim::{self, Partition, SimConfig};

fn main() -> Result<()> {
    let config = std::env::args().nth(1).unwrap_or_else(|| "fast".into());
    let dir = std::path::Path::new("artifacts").join(&config);
    let engine = SharedEngine::load(&dir)?;
    let meta = engine.meta().clone();
    println!(
        "e2e: config `{}` — {} params, {}x{}x{} images, {} minibatches/round",
        meta.config, meta.n_params, meta.img, meta.img, meta.channels, meta.nb_train
    );

    let n = 6;
    let mut cfg = SimConfig::for_meta(n, &meta);
    cfg.partition = Partition::Dirichlet(0.6);
    cfg.machines = 3;
    cfg.train_n = 600 * n;
    cfg.protocol.max_rounds = 30;
    cfg.protocol.min_rounds = 8;
    cfg.protocol.timeout = std::time::Duration::from_secs(3);
    cfg.seed = 2025;
    // one mid-run crash to exercise the fault path end-to-end
    cfg.faults = vec![FaultPlan::none(); n];
    cfg.faults[n - 1] = FaultPlan::at_round(10);

    let t0 = std::time::Instant::now();
    let res = sim::run(&engine, &cfg)?;
    println!("run finished in {:.1}s", t0.elapsed().as_secs_f64());

    // --- loss curve to CSV ---------------------------------------------------
    std::fs::create_dir_all("results")?;
    for r in &res.reports {
        let path = format!("results/e2e_{}_client{}.csv", meta.config, r.id);
        r.write_csv(std::path::Path::new(&path))?;
    }
    println!("per-round curves written to results/e2e_{}_client*.csv", meta.config);

    // --- summary --------------------------------------------------------------
    println!("\nround | mean train loss | mean probe acc");
    let max_r = res.reports.iter().map(|r| r.history.len()).max().unwrap_or(0);
    for round in 0..max_r {
        let losses: Vec<f32> = res
            .reports
            .iter()
            .filter_map(|r| r.history.get(round).map(|h| h.train_loss))
            .collect();
        let accs: Vec<f32> = res
            .reports
            .iter()
            .filter_map(|r| r.history.get(round).map(|h| h.probe_acc))
            .collect();
        let n = losses.len().max(1) as f32;
        println!(
            "{:>5} | {:>15.4} | {:>13.1}%",
            round,
            losses.iter().sum::<f32>() / n,
            accs.iter().sum::<f32>() / accs.len().max(1) as f32 * 100.0
        );
    }

    for r in &res.reports {
        println!(
            "client {}: {:?} rounds={} final acc={}",
            r.id,
            r.cause,
            r.rounds_completed,
            r.final_accuracy.map(|a| format!("{:.1}%", a * 100.0)).unwrap_or("-".into())
        );
    }

    // --- model agreement: survivors' final models should be near-identical ---
    let finals: Vec<ParamVector> = res
        .reports
        .iter()
        .filter_map(|r| r.final_params.clone().map(ParamVector))
        .collect();
    if finals.len() >= 2 {
        let mut max_rel = 0.0f32;
        for i in 1..finals.len() {
            let d = finals[0].l2_distance(&finals[i]) / finals[0].l2_norm().max(1.0);
            max_rel = max_rel.max(d);
        }
        println!("max relative L2 distance between survivor models: {max_rel:.4}");
    }
    println!(
        "\nmean final accuracy {:.1}% | adaptive termination {}",
        res.mean_accuracy().unwrap_or(0.0) * 100.0,
        res.all_terminated_adaptively()
    );
    Ok(())
}
