#!/usr/bin/env python3
"""dfl-lint entrypoint — tier-1's first gate (no cargo, no third-party deps).

    python3 scripts/dfllint.py rust/src            # lint the crate
    python3 scripts/dfllint.py --list-rules        # what is enforced
    python3 scripts/dfllint.py rust/src --json     # machine-readable report

See scripts/dfllint/ for the implementation and DESIGN.md §15 for the
invariant catalog this enforces.
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

from dfllint.cli import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
