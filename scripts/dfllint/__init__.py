"""dfl-lint — toolchain-free determinism & invariant linter for the dfl repo.

A dependency-free Python 3 static analyzer over the Rust sources.  It does
not parse Rust; it *lexes the surface* (strings, chars, raw strings,
comments, attributes) so that rules only ever fire on real code, then runs
a small catalog of deny-by-default rules transcribing the DESIGN.md
invariants (wall-clock bans, seeded RNG, iteration-order hygiene,
panic-free hot paths, feature-gate consistency, wire-tag uniqueness,
CLI/doc parity, module layering).

Entry point: ``scripts/dfllint.py`` (or ``python3 -m dfllint`` with
``scripts/`` on the path).  See ``dfllint.cli`` for flags and exit codes,
``dfllint.rules`` for the catalog, and DESIGN.md §15 for the invariant ↔
rule mapping and the suppression-pragma syntax.
"""

__version__ = "1.0.0"
