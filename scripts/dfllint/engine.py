"""Rule engine: file discovery, pragma suppression, report assembly.

The engine walks the target paths, lexes every ``.rs`` file once
(:mod:`dfllint.lexer`), hands the lexed files plus project context
(Cargo manifest, README) to each enabled rule, then applies the
suppression pragmas and the pragma-hygiene meta-rules.

Pragma syntax (DESIGN.md §15)::

    // dfl-lint: allow(rule-a, rule-b) — justification text
    // dfl-lint: allow-file(rule-a) — justification text

``allow(...)`` suppresses matching findings on its own line, or — when
the comment stands alone on a line — on the next non-blank line.
``allow-file(...)`` suppresses the rule for the whole file.  A pragma
**must** carry a justification (any non-empty text after the closing
paren, conventionally set off with an em-dash) and **must** name known
rules, else it is itself a deny finding (``bad-pragma``).  A pragma that
no longer suppresses anything has *expired* and is reported
(``unused-pragma``) so stale exemptions cannot outlive their reason.
"""

from __future__ import annotations

import os
import re
from dataclasses import dataclass, field

from .lexer import Lexed, lex

_PRAGMA = re.compile(r"dfl-lint\s*:\s*(allow(?:-file)?)\s*\(([^)]*)\)(.*)")

# Meta-rules owned by the engine itself (not suppressible, not listable
# as catalog rules but documented alongside them).
BAD_PRAGMA = "bad-pragma"
UNUSED_PRAGMA = "unused-pragma"


@dataclass(frozen=True, order=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str
    severity: str = "deny"

    def render(self) -> str:
        sev = "" if self.severity == "deny" else f" [{self.severity}]"
        return f"{self.path}:{self.line} {self.rule}{sev} {self.message}"

    def as_dict(self) -> dict:
        return {
            "path": self.path,
            "line": self.line,
            "rule": self.rule,
            "severity": self.severity,
            "message": self.message,
        }


@dataclass
class Pragma:
    path: str
    line: int
    rules: tuple[str, ...]
    file_wide: bool
    justification: str
    target_line: int  # line the pragma covers (== line for trailing form)
    used: bool = False


@dataclass
class SourceFile:
    """One lexed file plus its repo-relative module path.

    ``module_rel`` is the path below the crate's ``src/`` directory
    (``net/tcp.rs``); rules scope themselves with it.  Files outside any
    ``src/`` directory fall back to the path relative to the scan root.
    """

    lexed: Lexed
    rel: str  # path as reported in findings
    module_rel: str
    pragmas: list[Pragma] = field(default_factory=list)

    @property
    def top_module(self) -> str | None:
        """First directory under src/ (``net``), None for src-root files."""
        parts = self.module_rel.split("/")
        return parts[0] if len(parts) > 1 else None


@dataclass
class Project:
    """Everything rules may look at beyond the file in hand."""

    files: list[SourceFile]
    manifest_path: str | None = None
    manifest_features: list[str] = field(default_factory=list)
    readme_path: str | None = None
    readme_text: str = ""
    notes: list[str] = field(default_factory=list)  # stderr-bound context notes


def _module_rel(abspath: str, root: str) -> str:
    rel = os.path.relpath(abspath, root).replace(os.sep, "/")
    parts = rel.split("/")
    if "src" in parts:
        idx = len(parts) - 1 - parts[::-1].index("src")  # last 'src' wins
        below = parts[idx + 1 :]
        if below:
            return "/".join(below)
    return rel


def discover(paths: list[str]) -> list[tuple[str, str]]:
    """Expand targets into (abspath, display-path) pairs for ``.rs`` files."""
    out: list[tuple[str, str]] = []
    for target in paths:
        if os.path.isfile(target):
            out.append((os.path.abspath(target), target))
            continue
        for dirpath, dirnames, filenames in os.walk(target):
            dirnames.sort()
            for name in sorted(filenames):
                if name.endswith(".rs"):
                    full = os.path.join(dirpath, name)
                    out.append((os.path.abspath(full), os.path.normpath(full)))
    seen: set[str] = set()
    uniq = []
    for ab, rel in out:
        if ab not in seen:
            seen.add(ab)
            uniq.append((ab, rel))
    return uniq


def _find_upward(start_dir: str, name: str) -> str | None:
    cur = os.path.abspath(start_dir)
    while True:
        cand = os.path.join(cur, name)
        if os.path.isfile(cand):
            return cand
        parent = os.path.dirname(cur)
        if parent == cur:
            return None
        cur = parent


def parse_manifest_features(text: str) -> list[str]:
    """Feature names from a Cargo.toml ``[features]`` table (no TOML dep)."""
    features: list[str] = []
    in_features = False
    for raw in text.splitlines():
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        if line.startswith("["):
            in_features = line == "[features]"
            continue
        if in_features and "=" in line:
            name = line.split("=", 1)[0].strip().strip('"')
            if name:
                features.append(name)
    return features


def load_project(
    paths: list[str],
    manifest: str | None = None,
    readme: str | None = None,
) -> Project:
    pairs = discover(paths)
    scan_root = os.path.abspath(paths[0]) if paths else os.getcwd()
    if os.path.isfile(scan_root):
        scan_root = os.path.dirname(scan_root)

    files = []
    for ab, rel in pairs:
        with open(ab, encoding="utf-8", errors="replace") as f:
            src = f.read()
        lexed = lex(rel, src)
        sf = SourceFile(lexed=lexed, rel=rel, module_rel=_module_rel(ab, scan_root))
        sf.pragmas = _parse_pragmas(sf)
        files.append(sf)

    project = Project(files=files)

    manifest = manifest or _find_upward(scan_root, "Cargo.toml")
    if manifest and os.path.isfile(manifest):
        project.manifest_path = manifest
        with open(manifest, encoding="utf-8") as f:
            project.manifest_features = parse_manifest_features(f.read())
    else:
        project.notes.append(
            "no Cargo.toml found above the scan root; feature-gate checks skipped"
        )

    readme = readme or _find_upward(scan_root, "README.md")
    if readme and os.path.isfile(readme):
        project.readme_path = readme
        with open(readme, encoding="utf-8", errors="replace") as f:
            project.readme_text = f.read()
    else:
        project.notes.append(
            "no README.md found above the scan root; cli-doc-parity checks skipped"
        )
    return project


def _parse_pragmas(sf: SourceFile) -> list[Pragma]:
    pragmas = []
    lx = sf.lexed
    for ln in range(1, lx.n_lines() + 1):
        comment = lx.comments[ln - 1]
        m = _PRAGMA.search(comment)
        if not m:
            continue
        kind, rule_csv, tail = m.groups()
        rules = tuple(r.strip() for r in rule_csv.split(",") if r.strip())
        justification = tail.strip().lstrip("—–:- ").strip()
        standalone = lx.sig[ln - 1].strip() == ""
        target = ln
        if standalone and not kind.endswith("file"):
            for nxt in range(ln + 1, lx.n_lines() + 1):
                if lx.sig[nxt - 1].strip():
                    target = nxt
                    break
        pragmas.append(
            Pragma(
                path=sf.rel,
                line=ln,
                rules=rules,
                file_wide=kind.endswith("file"),
                justification=justification,
                target_line=target,
            )
        )
    return pragmas


def run(
    project: Project,
    rules: list,
    disabled: set[str] | None = None,
) -> list[Finding]:
    """Run ``rules`` over ``project``; returns sorted post-pragma findings."""
    disabled = disabled or set()
    active = [r for r in rules if r.id not in disabled]
    known_ids = {r.id for r in rules}

    raw: list[Finding] = []
    for rule in active:
        raw.extend(rule.check(project))

    kept: list[Finding] = []
    for f in sorted(set(raw)):
        suppressed = False
        sf = next((s for s in project.files if s.rel == f.path), None)
        if sf is not None:
            for p in sf.pragmas:
                if f.rule not in p.rules:
                    continue
                if p.file_wide or p.target_line == f.line:
                    p.used = True
                    suppressed = True
        if not suppressed:
            kept.append(f)

    # Pragma hygiene: malformed or expired pragmas are findings themselves,
    # and deliberately cannot be suppressed by other pragmas.
    for sf in project.files:
        for p in sf.pragmas:
            unknown = [r for r in p.rules if r not in known_ids]
            if not p.rules:
                kept.append(
                    Finding(p.path, p.line, BAD_PRAGMA, "pragma names no rules")
                )
            elif unknown:
                kept.append(
                    Finding(
                        p.path,
                        p.line,
                        BAD_PRAGMA,
                        f"pragma names unknown rule(s): {', '.join(unknown)}",
                    )
                )
            elif not p.justification:
                kept.append(
                    Finding(
                        p.path,
                        p.line,
                        BAD_PRAGMA,
                        "pragma carries no justification "
                        "(write `// dfl-lint: allow(rule) — why`)",
                    )
                )
            elif not p.used and not all(r in disabled for r in p.rules):
                kept.append(
                    Finding(
                        p.path,
                        p.line,
                        UNUSED_PRAGMA,
                        f"pragma suppresses nothing (allow({', '.join(p.rules)})) "
                        "— the finding it excused is gone; delete it",
                    )
                )

    return sorted(set(kept))
