"""The dfl-lint rule catalog — DESIGN.md invariants as executable checks.

Every rule is deny-by-default: it fails the build unless the finding is
fixed or excused with a justified pragma.  Rules receive the whole
:class:`~dfllint.engine.Project` so cross-file rules (feature gates,
wire tags, CLI parity, layering) see everything at once, but findings
are always anchored to one ``path:line``.

Scoping conventions:

* *hot-path* and *module* scopes match on the path below ``src/``
  (``SourceFile.module_rel``), so the catalog works unchanged on the
  real tree and on test fixtures.
* ``#[cfg(test)]`` regions are exempt from the path-scoped determinism
  rules (tests deliberately measure wall time and panic on assertion
  failure); the RNG rule applies even there — a test drawing from the
  OS entropy pool is a flaky test.
"""

from __future__ import annotations

import bisect
import re
from dataclasses import dataclass
from typing import Callable, Iterable, Iterator

from .engine import Finding, Project, SourceFile


@dataclass(frozen=True)
class Rule:
    id: str
    severity: str
    summary: str
    check: Callable[[Project], Iterable[Finding]]


# --------------------------------------------------------------------------
# Shared helpers
# --------------------------------------------------------------------------


def _scan_lines(
    sf: SourceFile,
    pattern: re.Pattern,
    *,
    mask: str = "code",
    skip_tests: bool = True,
) -> Iterator[tuple[int, str]]:
    """Yield (line, matched-text) for every pattern hit on the given mask."""
    rows = getattr(sf.lexed, mask)
    for ln, row in enumerate(rows, start=1):
        if skip_tests and sf.lexed.in_test(ln):
            continue
        for m in pattern.finditer(row):
            yield ln, m.group(0) if not m.groups() else m.group(1)


def _line_of(offset: int, newlines: list[int]) -> int:
    return bisect.bisect_right(newlines, offset) + 1


_CRATE_REF = re.compile(r"(?<![\w$])crate\s*::\s*")
_IDENT = re.compile(r"[A-Za-z_][A-Za-z0-9_]*")


def iter_crate_refs(code_text: str) -> Iterator[tuple[int, str]]:
    """Yield (line, top_module) for every ``crate::<module>`` reference.

    Handles plain paths (``crate::util::rng``), grouped imports
    (``use crate::{net::ClientId, util::Rng}`` — yields each top-level
    segment), and multiline groups; ``$crate`` in macros is skipped.
    """
    newlines = [i for i, c in enumerate(code_text) if c == "\n"]
    for m in _CRATE_REF.finditer(code_text):
        start = m.end()
        if start < len(code_text) and code_text[start] == "{":
            depth, j, seg_start = 0, start, start + 1
            segments = []
            while j < len(code_text):
                c = code_text[j]
                if c == "{":
                    depth += 1
                elif c == "}":
                    depth -= 1
                    if depth == 0:
                        segments.append((seg_start, code_text[seg_start:j]))
                        break
                elif c == "," and depth == 1:
                    segments.append((seg_start, code_text[seg_start:j]))
                    seg_start = j + 1
                j += 1
            for seg_off, seg in segments:
                im = _IDENT.search(seg)
                if im:
                    yield _line_of(seg_off + im.start(), newlines), im.group(0)
        else:
            im = _IDENT.match(code_text, start)
            if im:
                yield _line_of(m.start(), newlines), im.group(0)


# --------------------------------------------------------------------------
# wall-clock
# --------------------------------------------------------------------------

_WALL = re.compile(r"\bInstant\s*::\s*now\b|\bSystemTime\b|\bthread\s*::\s*sleep\b")
_WALL_EXEMPT = "util/time.rs"


def check_wall_clock(project: Project) -> Iterator[Finding]:
    for sf in project.files:
        if sf.module_rel == _WALL_EXEMPT:
            continue
        for ln, text in _scan_lines(sf, _WALL):
            yield Finding(
                sf.rel,
                ln,
                "wall-clock",
                f"`{text.strip()}` outside {_WALL_EXEMPT} — wall-clock reads "
                "break virtual-time determinism; route through `Clock` "
                "(DESIGN.md §2) or justify with a pragma",
            )


# --------------------------------------------------------------------------
# unseeded-rng
# --------------------------------------------------------------------------

_RNG = re.compile(
    r"\bthread_rng\b|\bfrom_entropy\b|\brand\s*::\s*random\b|\bOsRng\b|\bgetrandom\b"
)


def check_unseeded_rng(project: Project) -> Iterator[Finding]:
    for sf in project.files:
        for ln, text in _scan_lines(sf, _RNG, skip_tests=False):
            yield Finding(
                sf.rel,
                ln,
                "unseeded-rng",
                f"`{text.strip()}` draws OS entropy — every stream must come "
                "from the seeded `util::rng` hierarchy (same seed ⇒ "
                "byte-identical run)",
            )


# --------------------------------------------------------------------------
# hash-iter-order
# --------------------------------------------------------------------------

_HASH = re.compile(r"\bHashMap\b|\bHashSet\b")
_HASH_MODULES = {"coordinator", "sim", "net"}


def check_hash_iter_order(project: Project) -> Iterator[Finding]:
    for sf in project.files:
        if sf.top_module not in _HASH_MODULES:
            continue
        for ln, text in _scan_lines(sf, _HASH):
            yield Finding(
                sf.rel,
                ln,
                "hash-iter-order",
                f"`{text.strip()}` in `{sf.top_module}/` — randomized iteration "
                "order can leak into event order or RNG draws; use "
                "BTreeMap/BTreeSet, or add a pragma justifying why order "
                "never escapes",
            )


# --------------------------------------------------------------------------
# no-panic-hot-path
# --------------------------------------------------------------------------

_PANIC = re.compile(
    r"\.\s*unwrap\s*\(|\.\s*expect\s*\(|\bpanic!|\btodo!|\bunimplemented!"
)
_HOT_FILES = {
    "coordinator/machine.rs",
    "sim/exec.rs",
    "net/delta.rs",
    "net/overlay.rs",
}


def check_no_panic_hot_path(project: Project) -> Iterator[Finding]:
    for sf in project.files:
        if sf.module_rel not in _HOT_FILES:
            continue
        for ln, text in _scan_lines(sf, _PANIC):
            yield Finding(
                sf.rel,
                ln,
                "no-panic-hot-path",
                f"`{text.strip()}` in hot path {sf.module_rel} — a panic here "
                "kills a client/shard mid-protocol; return an error, or "
                "justify the invariant with a pragma",
            )


# --------------------------------------------------------------------------
# feature-gate-consistency
# --------------------------------------------------------------------------

_FEATURE = re.compile(r"\bfeature\s*=\s*\"([^\"]+)\"")


def check_feature_gate(project: Project) -> Iterator[Finding]:
    if project.manifest_path is None:
        return
    declared = set(project.manifest_features)
    for sf in project.files:
        for ln, name in _scan_lines(sf, _FEATURE, mask="sig", skip_tests=False):
            if name not in declared:
                yield Finding(
                    sf.rel,
                    ln,
                    "feature-gate",
                    f'`feature = "{name}"` names a feature not declared in '
                    f"{project.manifest_path} [features] "
                    f"({', '.join(sorted(declared)) or 'none declared'}) — "
                    "an uncompiled typo here silently disables the gated code",
                )


# --------------------------------------------------------------------------
# wire-tag-uniqueness
# --------------------------------------------------------------------------

_WIRE_TAG = re.compile(r"\bconst\s+(TAG_[A-Z0-9_]+)\s*:\s*u8\s*=\s*(\d+)")
_WIRE_FILE = "net/message.rs"


def check_wire_tags(project: Project) -> Iterator[Finding]:
    for sf in project.files:
        if sf.module_rel != _WIRE_FILE:
            continue
        seen: dict[str, tuple[str, int]] = {}
        for ln, row in enumerate(sf.lexed.code, start=1):
            for m in _WIRE_TAG.finditer(row):
                name, value = m.group(1), m.group(2)
                if value in seen:
                    first_name, first_ln = seen[value]
                    yield Finding(
                        sf.rel,
                        ln,
                        "wire-tag",
                        f"wire tag {name} = {value} collides with {first_name} "
                        f"(line {first_ln}) — decode would route one message "
                        "kind into the other",
                    )
                else:
                    seen[value] = (name, ln)


# --------------------------------------------------------------------------
# cli-doc-parity
# --------------------------------------------------------------------------

_CLI_REG = re.compile(r"\.\s*(?:opt|switch)\s*\(\s*\"([^\"]+)\"")


def check_cli_doc_parity(project: Project) -> Iterator[Finding]:
    if project.readme_path is None:
        return
    for sf in project.files:
        for ln, name in _scan_lines(sf, _CLI_REG, mask="sig"):
            if f"--{name}" not in project.readme_text:
                yield Finding(
                    sf.rel,
                    ln,
                    "cli-doc-parity",
                    f"flag `--{name}` is registered here but never mentioned "
                    f"in {project.readme_path} — undocumented knobs rot; add "
                    "it to the README flag reference",
                )


# --------------------------------------------------------------------------
# module-layering
# --------------------------------------------------------------------------

# The architecture DAG (DESIGN.md §15): higher layers may use lower (or
# same-layer) modules, never the reverse.
LAYERS = {
    "util": 0,
    "net": 1,
    "metrics": 1,
    "model": 1,
    "data": 1,
    "runtime": 1,
    "coordinator": 2,
    "sim": 3,
    "exp": 4,
}


def check_module_layering(project: Project) -> Iterator[Finding]:
    for sf in project.files:
        src_mod = sf.top_module
        if src_mod not in LAYERS:
            continue  # src-root files (main.rs, lib.rs) sit above the DAG
        src_layer = LAYERS[src_mod]
        # Strip cfg(test) lines from the joined code before extracting
        # refs: integration-style test modules may reach across layers.
        rows = [
            row if not sf.lexed.in_test(ln) else ""
            for ln, row in enumerate(sf.lexed.code, start=1)
        ]
        for ln, target in iter_crate_refs("\n".join(rows)):
            if target not in LAYERS:
                continue  # crate-root re-exports (crate::ProtocolConfig, …)
            if LAYERS[target] > src_layer:
                yield Finding(
                    sf.rel,
                    ln,
                    "module-layering",
                    f"upward edge {src_mod} → {target} (layer {src_layer} → "
                    f"{LAYERS[target]}) violates the DAG util ← {{net, "
                    "metrics, model, data, runtime} ← coordinator ← sim ← "
                    "exp — move the shared type down or the dependent code up",
                )


# --------------------------------------------------------------------------
# Catalog
# --------------------------------------------------------------------------

CATALOG: list[Rule] = [
    Rule(
        "wall-clock",
        "deny",
        "Instant::now / SystemTime / thread::sleep outside util/time.rs",
        check_wall_clock,
    ),
    Rule(
        "unseeded-rng",
        "deny",
        "thread_rng / from_entropy / rand::random / OsRng anywhere",
        check_unseeded_rng,
    ),
    Rule(
        "hash-iter-order",
        "deny",
        "HashMap/HashSet in coordinator/, sim/, net/ (iteration-order leak)",
        check_hash_iter_order,
    ),
    Rule(
        "no-panic-hot-path",
        "deny",
        "unwrap/expect/panic!/todo! in machine.rs, sim/exec.rs, net/delta.rs, "
        "net/overlay.rs (outside #[cfg(test)])",
        check_no_panic_hot_path,
    ),
    Rule(
        "feature-gate",
        "deny",
        'every cfg(feature = "…") names a feature declared in Cargo.toml',
        check_feature_gate,
    ),
    Rule(
        "wire-tag",
        "deny",
        "message wire tags in net/message.rs pairwise distinct",
        check_wire_tags,
    ),
    Rule(
        "cli-doc-parity",
        "deny",
        "every registered --flag appears in README.md",
        check_cli_doc_parity,
    ),
    Rule(
        "module-layering",
        "deny",
        "use-crate graph respects util ← {net,metrics,model,data,runtime} ← "
        "coordinator ← sim ← exp",
        check_module_layering,
    ),
]

META_RULES: list[tuple[str, str]] = [
    ("bad-pragma", "pragma is malformed, names unknown rules, or lacks a justification"),
    ("unused-pragma", "pragma suppresses nothing — it has expired; delete it"),
]
