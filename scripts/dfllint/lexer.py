"""Rust *surface* lexer: classify every byte of a source file.

This is not a Rust parser.  It is the minimal single-pass scanner that
lets lint rules regex over source text without ever firing inside a
string literal, a char literal, a comment/doc, or (separately
classified) an attribute.  It understands:

* line comments (``//``, ``///``, ``//!``) and **nested** block comments
  (``/* /* */ */``),
* string literals with escapes (``"a\\"b"``), byte strings (``b"..."``),
* raw strings with any guard arity (``r"..."``, ``r#"..."#``,
  ``br##"..."##``) — no escapes, closed only by ``"`` + matching ``#``s,
* char literals vs. lifetimes/labels (``'a'`` and ``'\\u{1F600}'`` are
  literals; ``'static`` and ``'outer:`` are code),
* attributes ``#[...]`` / ``#![...]`` with bracket matching that is
  itself string-aware (a ``]`` inside ``#[doc = "]"]`` does not close
  the attribute).

Output is a :class:`Lexed` carrying parallel per-line *masks* (the line
with all bytes outside the wanted classes replaced by spaces, so column
numbers survive), the per-line comment text (for pragma parsing), and
the set of lines under ``#[cfg(test)]`` items.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

# Byte classes.
CODE = 0  # executable surface: idents, punctuation, keywords
STR = 1  # string/char literal in code position (delimiters included)
COM = 2  # comment or doc text (delimiters included)
ATTR = 3  # attribute surface: `#[cfg(test)]` minus its string literals
ASTR = 4  # string literal inside an attribute

_CHAR_LIT = re.compile(
    r"'(?:\\(?:x[0-9a-fA-F]{2}|u\{[0-9a-fA-F_]{1,6}\}|.)|[^\\'\n])'"
)
_RAW_START = re.compile(r'(?:b?r)(#*)"')
_CFG_TEST = re.compile(r"\bcfg\s*\(\s*test\s*\)")


@dataclass
class Lexed:
    """A classified source file (all line numbers are 1-based)."""

    path: str
    lines: list[str]  # original text, split on newlines
    code: list[str]  # CODE bytes only, everything else blanked
    sig: list[str]  # everything except comments (CODE|STR|ATTR|ASTR)
    attrs: list[str]  # attribute bytes only (ATTR|ASTR)
    comments: list[str]  # comment bytes only (COM, delimiters stripped of //)
    test_lines: set[int] = field(default_factory=set)

    def n_lines(self) -> int:
        return len(self.lines)

    def in_test(self, line: int) -> bool:
        return line in self.test_lines

    def code_text(self) -> str:
        """The CODE mask joined back into one string (for multiline regexes)."""
        return "\n".join(self.code)


def _mask(lines: list[str], kinds: list[list[int]], keep: set[int]) -> list[str]:
    out = []
    for text, kind_row in zip(lines, kinds):
        out.append(
            "".join(ch if k in keep else " " for ch, k in zip(text, kind_row))
        )
    return out


def lex(path: str, src: str) -> Lexed:
    """Classify ``src`` byte-by-byte; never raises on malformed input.

    Unterminated constructs (string/comment running off the end of the
    file) keep their class to EOF — a lint pass must degrade gracefully
    on code the compiler would reject anyway.
    """
    n = len(src)
    kinds = [CODE] * n
    i = 0
    in_attr = False
    attr_depth = 0

    def classify(start: int, end: int, k: int) -> None:
        for j in range(start, min(end, n)):
            kinds[j] = k

    while i < n:
        c = src[i]
        nxt = src[i + 1] if i + 1 < n else ""

        # Comments win over everything else (valid in and out of attrs).
        if c == "/" and nxt == "/":
            end = src.find("\n", i)
            end = n if end == -1 else end
            classify(i, end, COM)
            i = end
            continue
        if c == "/" and nxt == "*":
            depth, j = 1, i + 2
            while j < n and depth:
                if src.startswith("/*", j):
                    depth += 1
                    j += 2
                elif src.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    j += 1
            classify(i, j, COM)
            i = j
            continue

        # Attribute entry / exit bookkeeping.
        if not in_attr and c == "#" and (nxt == "[" or src.startswith("![", i + 1)):
            in_attr = True
            attr_depth = 0
            kinds[i] = ATTR
            i += 1
            continue
        if in_attr:
            if c == "[":
                attr_depth += 1
                kinds[i] = ATTR
                i += 1
                continue
            if c == "]":
                attr_depth -= 1
                kinds[i] = ATTR
                i += 1
                if attr_depth == 0:
                    in_attr = False
                continue

        str_kind = ASTR if in_attr else STR

        # Raw / byte-raw strings: r"..", r#".."#, br##"..."## — check the
        # prefix is not the tail of an identifier (`for"` is not `r"`).
        if c in ("r", "b"):
            m = _RAW_START.match(src, i)
            if m and (i == 0 or not (src[i - 1].isalnum() or src[i - 1] == "_")):
                guard = '"' + "#" * len(m.group(1))
                end = src.find(guard, m.end())
                end = n if end == -1 else end + len(guard)
                classify(i, end, str_kind)
                i = end
                continue
            if c == "b" and nxt == '"':
                i0, j = i, i + 2
                while j < n and src[j] != '"':
                    j += 2 if src[j] == "\\" else 1
                classify(i0, j + 1, str_kind)
                i = j + 1
                continue
            if c == "b" and nxt == "'":
                m2 = _CHAR_LIT.match(src, i + 1)
                if m2 and (i == 0 or not (src[i - 1].isalnum() or src[i - 1] == "_")):
                    classify(i, m2.end(), str_kind)
                    i = m2.end()
                    continue

        if c == '"':
            j = i + 1
            while j < n and src[j] != '"':
                j += 2 if src[j] == "\\" else 1
            classify(i, j + 1, str_kind)
            i = j + 1
            continue

        if c == "'":
            m = _CHAR_LIT.match(src, i)
            if m:
                classify(i, m.end(), str_kind)
                i = m.end()
                continue
            # Lifetime or loop label: the quote itself is code.
            kinds[i] = ATTR if in_attr else CODE
            i += 1
            continue

        kinds[i] = ATTR if in_attr else CODE
        i += 1

    # Split the flat classification back into per-line rows.
    lines = src.split("\n")
    kind_rows: list[list[int]] = []
    pos = 0
    for text in lines:
        kind_rows.append(kinds[pos : pos + len(text)])
        pos += len(text) + 1  # the split-away newline

    lexed = Lexed(
        path=path,
        lines=lines,
        code=_mask(lines, kind_rows, {CODE}),
        sig=_mask(lines, kind_rows, {CODE, STR, ATTR, ASTR}),
        attrs=_mask(lines, kind_rows, {ATTR, ASTR}),
        comments=_mask(lines, kind_rows, {COM}),
    )
    lexed.test_lines = _find_test_lines(lexed)
    return lexed


def _find_test_lines(lx: Lexed) -> set[int]:
    """Lines covered by ``#[cfg(test)]``-gated items.

    For each outer ``#[cfg(test)]`` attribute, the gated item runs from
    the attribute to either the first top-level ``;`` (a gated ``use`` or
    tuple struct) or the close of the first top-level ``{...}`` (a gated
    ``mod``/``fn``/``impl``) — brace matching on the CODE mask only, so
    braces in strings, comments, and attribute args never miscount.
    An inner ``#![cfg(test)]`` gates the rest of the file.
    """
    out: set[int] = set()
    n = lx.n_lines()
    for ln0 in range(n):
        attr_text = lx.attrs[ln0]
        if not _CFG_TEST.search(attr_text):
            continue
        # cfg_attr(test, ...) conditions on test but the item itself is
        # not test-only; cfg(not(test)) is the opposite gate. Skip both.
        if "cfg_attr" in attr_text or re.search(r"not\s*\(\s*test", attr_text):
            continue
        if "#!" in attr_text:  # inner attribute: gates the enclosing scope
            out.update(range(ln0 + 1, n + 1))
            continue
        depth = 0
        opened = False
        end_line = n  # unterminated item degrades to end-of-file
        start_col = lx.attrs[ln0].rindex("]") + 1 if "]" in lx.attrs[ln0] else 0
        for ln in range(ln0, n):
            row = lx.code[ln]
            for col, ch in enumerate(row):
                if ln == ln0 and col < start_col:
                    continue
                if ch == "{":
                    depth += 1
                    opened = True
                elif ch == "}":
                    depth -= 1
                    if opened and depth == 0:
                        end_line = ln + 1
                        break
                elif ch == ";" and not opened and depth == 0:
                    end_line = ln + 1
                    break
            else:
                continue
            break
        out.update(range(ln0 + 1, end_line + 1))
    return out
