"""dfl-lint command line.

Usage::

    dfllint.py [PATH ...] [--json] [--list-rules] [--allow RULE[,RULE…]]
               [--manifest CARGO_TOML] [--readme README_MD] [--quiet]

Exit codes (CI contract):

* ``0`` — no unsuppressed deny findings,
* ``1`` — at least one deny finding,
* ``2`` — usage or I/O error.

Output is stable and machine-diffable: one ``path:line rule message``
per finding, sorted by (path, line, rule, message).  ``--json`` switches
to a single JSON document for automation.
"""

from __future__ import annotations

import json
import sys

from . import __version__
from .engine import load_project, run
from .rules import CATALOG, META_RULES

USAGE = (
    "usage: dfllint.py [PATH ...] [--json] [--list-rules] "
    "[--allow RULE[,RULE...]] [--manifest PATH] [--readme PATH] [--quiet]"
)


def list_rules() -> str:
    lines = [f"dfl-lint {__version__} — rule catalog (all deny-by-default)", ""]
    width = max(len(r.id) for r in CATALOG)
    for r in CATALOG:
        lines.append(f"  {r.id:<{width}}  [{r.severity}]  {r.summary}")
    lines.append("")
    lines.append("  engine meta-rules (not suppressible):")
    for rid, summary in META_RULES:
        lines.append(f"  {rid:<{width}}  [deny]  {summary}")
    lines.append("")
    lines.append(
        "  suppress: `// dfl-lint: allow(<rule>) — <justification>` on or "
        "above the line;\n  `allow-file(<rule>)` for the whole file.  "
        "See DESIGN.md §15."
    )
    return "\n".join(lines)


def main(argv: list[str]) -> int:
    paths: list[str] = []
    as_json = False
    quiet = False
    manifest: str | None = None
    readme: str | None = None
    disabled: set[str] = set()

    it = iter(argv)
    for arg in it:
        if arg in ("-h", "--help"):
            print(USAGE)
            print()
            print(list_rules())
            return 0
        if arg == "--version":
            print(f"dfl-lint {__version__}")
            return 0
        if arg == "--list-rules":
            print(list_rules())
            return 0
        if arg == "--json":
            as_json = True
        elif arg in ("-q", "--quiet"):
            quiet = True
        elif arg == "--allow":
            value = next(it, None)
            if value is None:
                print(f"{USAGE}\n--allow requires a rule list", file=sys.stderr)
                return 2
            disabled.update(r.strip() for r in value.split(",") if r.strip())
        elif arg == "--manifest":
            manifest = next(it, None)
            if manifest is None:
                print(f"{USAGE}\n--manifest requires a path", file=sys.stderr)
                return 2
        elif arg == "--readme":
            readme = next(it, None)
            if readme is None:
                print(f"{USAGE}\n--readme requires a path", file=sys.stderr)
                return 2
        elif arg.startswith("-"):
            print(f"{USAGE}\nunknown flag {arg}", file=sys.stderr)
            return 2
        else:
            paths.append(arg)

    if not paths:
        print(f"{USAGE}\nno paths given", file=sys.stderr)
        return 2

    known = {r.id for r in CATALOG}
    bogus = disabled - known
    if bogus:
        print(f"--allow names unknown rule(s): {', '.join(sorted(bogus))}", file=sys.stderr)
        return 2

    try:
        project = load_project(paths, manifest=manifest, readme=readme)
    except OSError as e:
        print(f"dfl-lint: {e}", file=sys.stderr)
        return 2

    findings = run(project, CATALOG, disabled=disabled)
    denies = [f for f in findings if f.severity == "deny"]

    if as_json:
        print(
            json.dumps(
                {
                    "version": __version__,
                    "files_scanned": len(project.files),
                    "rules_disabled": sorted(disabled),
                    "findings": [f.as_dict() for f in findings],
                    "deny_count": len(denies),
                },
                indent=2,
            )
        )
    else:
        for note in project.notes:
            print(f"dfl-lint: note: {note}", file=sys.stderr)
        for f in findings:
            print(f.render())
        if not quiet:
            status = "clean" if not denies else f"{len(denies)} finding(s)"
            print(
                f"dfl-lint: {len(project.files)} file(s), {status}",
                file=sys.stderr,
            )

    return 1 if denies else 0
