"""One positive and one negative fixture per catalog rule."""

import tempfile
import unittest
from pathlib import Path

from .helpers import POSITIVE, lint, make_crate, rules_of


class RuleFixtureCase(unittest.TestCase):
    def setUp(self):
        self._tmp = tempfile.TemporaryDirectory()
        self.tmp = Path(self._tmp.name)
        self.addCleanup(self._tmp.cleanup)

    def lint_files(self, files, readme=None):
        kwargs = {"readme": readme} if readme is not None else {}
        return lint(make_crate(self.tmp, files, **kwargs))


class PositiveFixtures(RuleFixtureCase):
    """Each POSITIVE tree reports exactly its own rule."""

    def test_every_rule_fires_on_its_positive_fixture(self):
        for rule, files in POSITIVE.items():
            with self.subTest(rule=rule):
                tmp = tempfile.TemporaryDirectory()
                self.addCleanup(tmp.cleanup)
                findings = lint(make_crate(Path(tmp.name), files))
                self.assertEqual(
                    rules_of(findings), [rule],
                    f"fixture for {rule} produced {findings}",
                )

    def test_unseeded_rng_fires_even_inside_cfg_test(self):
        findings = self.lint_files({
            "model/tests_mod.rs": (
                "#[cfg(test)]\n"
                "mod tests {\n"
                "    #[test]\n"
                "    fn flaky() { let _ = rand::thread_rng(); }\n"
                "}\n"
            ),
        })
        self.assertEqual(rules_of(findings), ["unseeded-rng"])

    def test_module_layering_sees_grouped_multiline_use(self):
        findings = self.lint_files({
            "net/overlay2.rs": (
                "use crate::{\n"
                "    util::Rng,\n"
                "    sim::SimConfig,\n"
                "};\n"
            ),
        })
        self.assertEqual(rules_of(findings), ["module-layering"])
        # The finding anchors on the sim segment, not the use keyword.
        self.assertEqual([f.line for f in findings], [3])


class NegativeFixtures(RuleFixtureCase):
    """The negative twins: same shapes, no findings."""

    def test_clean_crate_is_clean(self):
        findings = self.lint_files({
            # wall-clock: allowed inside util/time.rs, and in test regions.
            "util/time.rs": (
                "pub fn now() -> std::time::Instant { std::time::Instant::now() }\n"
            ),
            "sim/mod.rs": (
                "pub struct SimConfig { pub rounds: usize }\n"
                "#[cfg(test)]\n"
                "mod tests {\n"
                "    #[test]\n"
                "    fn timed() { let _ = std::time::Instant::now(); }\n"
                "}\n"
            ),
            # unseeded-rng: seeded hierarchy is fine.
            "model/init.rs": (
                "pub fn noise(rng: &mut crate::util::Rng) -> f64 { rng.next_f64() }\n"
            ),
            # hash-iter-order: BTree in net/, Hash outside the scoped modules.
            "net/routing.rs": (
                "use std::collections::BTreeMap;\n"
                "pub struct Routes { pub next_hop: BTreeMap<u32, u32> }\n"
            ),
            "util/cache.rs": (
                "use std::collections::HashMap;\n"
                "pub struct Cache { pub m: HashMap<u32, u32> }\n"
            ),
            # no-panic: unwrap is fine outside the hot files and in tests.
            "coordinator/machine.rs": (
                "pub fn step(x: Option<u32>) -> Option<u32> { x }\n"
                "#[cfg(test)]\n"
                "mod tests {\n"
                "    #[test]\n"
                "    fn t() { super::step(Some(1)).unwrap(); }\n"
                "}\n"
            ),
            "coordinator/termination.rs": (
                "pub fn get(x: Option<u32>) -> u32 { x.unwrap() }\n"
            ),
            # feature-gate: declared feature names pass.
            "runtime/backend.rs": (
                '#[cfg(feature = "pjrt")]\npub fn accel() {}\n'
                '#[cfg(feature = "alloc-audit")]\npub fn audit() {}\n'
            ),
            # wire-tag: distinct values pass.
            "net/message.rs": (
                "pub const TAG_MODEL: u8 = 1;\n"
                "pub const TAG_FLAG: u8 = 2;\n"
            ),
            # cli-doc-parity: --seed and --clients are in the fixture README.
            "exp/cli.rs": (
                "pub fn build(args: Args) -> Args {\n"
                '    args.opt("seed", "s", "rng seed")\n'
                '        .opt("clients", "c", "client count")\n'
                "}\n"
            ),
            # module-layering: downward edges only.
            "sim/exec.rs": (
                "use crate::util::Rng;\n"
                "use crate::coordinator::Machine;\n"
                "pub fn run(_r: Rng, _m: Machine) {}\n"
            ),
        })
        self.assertEqual(findings, [], [f.render() for f in findings])

    def test_matches_inside_strings_and_comments_do_not_fire(self):
        findings = self.lint_files({
            "net/doc.rs": (
                "// A comment naming HashMap and Instant::now() is fine.\n"
                'pub const NOTE: &str = "HashMap thread_rng Instant::now()";\n'
                'pub const RAW: &str = r#"SystemTime .unwrap()"#;\n'
            ),
        })
        self.assertEqual(findings, [], [f.render() for f in findings])

    def test_src_root_files_are_exempt_from_layering(self):
        findings = self.lint_files({
            "main.rs": (
                "use crate::exp::Runner;\n"
                "use crate::util::Rng;\n"
                "fn main() {}\n"
            ),
        })
        self.assertEqual(findings, [], [f.render() for f in findings])

    def test_feature_gate_skipped_without_manifest(self):
        # A bare tree with no Cargo.toml anywhere above it: the rule must
        # skip rather than flag every gate.  TemporaryDirectory lives under
        # /tmp, which has no Cargo.toml on the upward walk.
        src = self.tmp / "src"
        (src / "runtime").mkdir(parents=True)
        (src / "runtime" / "backend.rs").write_text(
            '#[cfg(feature = "whatever")]\npub fn f() {}\n'
        )
        findings = lint(src)
        self.assertEqual(
            [f for f in findings if f.rule == "feature-gate"], [],
        )


if __name__ == "__main__":
    unittest.main()
