"""Shared fixture plumbing for the dfl-lint test suite.

Builds throwaway crate-shaped trees (``<tmp>/src/...`` + ``Cargo.toml``
+ ``README.md``) and runs the engine over them in-process, so each rule
test is a few lines: write a positive fixture, assert the finding;
write the negative twin, assert silence.
"""

from __future__ import annotations

import os
import pathlib
import sys

# Make `import dfllint` work under both pytest (any rootdir) and
# `python3 -m unittest` from anywhere: the package root is scripts/.
SCRIPTS_DIR = pathlib.Path(__file__).resolve().parents[2]
if str(SCRIPTS_DIR) not in sys.path:
    sys.path.insert(0, str(SCRIPTS_DIR))

from dfllint.engine import load_project, run  # noqa: E402
from dfllint.rules import CATALOG  # noqa: E402

REPO_ROOT = SCRIPTS_DIR.parent

CARGO_TOML = """\
[package]
name = "fixture"
version = "0.0.0"

[features]
default = []
pjrt = []
alloc-audit = []
"""

README = """\
# fixture
Documented flags: --seed and --clients.
"""


def make_crate(tmp: pathlib.Path, files: dict[str, str], readme: str = README) -> pathlib.Path:
    """Write ``files`` (paths relative to ``src/``) plus manifest+README."""
    (tmp / "Cargo.toml").write_text(CARGO_TOML)
    (tmp / "README.md").write_text(readme)
    for rel, text in files.items():
        path = tmp / "src" / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(text)
    return tmp / "src"


def lint(src_dir: pathlib.Path, disabled: set[str] | None = None):
    """Run the full catalog; returns the post-pragma finding list."""
    cwd = os.getcwd()
    try:
        os.chdir(src_dir.parent)  # findings get stable relative paths
        project = load_project(["src"])
        return run(project, CATALOG, disabled=disabled)
    finally:
        os.chdir(cwd)


def rules_of(findings) -> list[str]:
    return sorted({f.rule for f in findings})


# One minimal positive fixture per catalog rule: each tree, scanned on its
# own, must make dfl-lint report exactly that rule (engine-level in
# test_rules.py, exit-code-level in test_selfcheck.py).
POSITIVE: dict[str, dict[str, str]] = {
    "wall-clock": {
        "sim/clock_use.rs": (
            "pub fn tick() -> std::time::Instant {\n"
            "    std::time::Instant::now()\n"
            "}\n"
        ),
    },
    "unseeded-rng": {
        "model/init.rs": (
            "pub fn noise() -> f64 {\n"
            "    let mut rng = rand::thread_rng();\n"
            "    rng.gen()\n"
            "}\n"
        ),
    },
    "hash-iter-order": {
        "net/routing.rs": (
            "use std::collections::HashMap;\n"
            "pub struct Routes {\n"
            "    pub next_hop: HashMap<u32, u32>,\n"
            "}\n"
        ),
    },
    "no-panic-hot-path": {
        "coordinator/machine.rs": (
            "pub fn step(x: Option<u32>) -> u32 {\n"
            "    x.unwrap()\n"
            "}\n"
        ),
    },
    "feature-gate": {
        "runtime/backend.rs": (
            '#[cfg(feature = "definitely-not-declared")]\n'
            "pub fn accel() {}\n"
        ),
    },
    "wire-tag": {
        "net/message.rs": (
            "pub const TAG_MODEL: u8 = 1;\n"
            "pub const TAG_FLAG: u8 = 2;\n"
            "pub const TAG_ACK: u8 = 1;\n"
        ),
    },
    "cli-doc-parity": {
        "exp/cli.rs": (
            "pub fn build(args: Args) -> Args {\n"
            '    args.opt("undocumented-knob", "u", "mystery flag")\n'
            "}\n"
        ),
    },
    "module-layering": {
        "util/helper.rs": (
            "use crate::sim::SimConfig;\n"
            "pub fn peek(c: &SimConfig) -> usize {\n"
            "    c.rounds\n"
            "}\n"
        ),
    },
}
