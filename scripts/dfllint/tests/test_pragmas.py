"""Pragma suppression, justification hygiene, and expiry behavior."""

import tempfile
import unittest
from pathlib import Path

from .helpers import lint, make_crate, rules_of

WALL = "std::time::Instant::now()"


class PragmaCase(unittest.TestCase):
    def setUp(self):
        self._tmp = tempfile.TemporaryDirectory()
        self.tmp = Path(self._tmp.name)
        self.addCleanup(self._tmp.cleanup)

    def lint_files(self, files):
        return lint(make_crate(self.tmp, files))


class Suppression(PragmaCase):
    def test_trailing_pragma_suppresses_same_line(self):
        findings = self.lint_files({
            "sim/mod.rs": (
                f"pub fn t() {{ let _ = {WALL}; }} "
                "// dfl-lint: allow(wall-clock) — harness stopwatch\n"
            ),
        })
        self.assertEqual(findings, [], [f.render() for f in findings])

    def test_standalone_pragma_covers_next_code_line(self):
        findings = self.lint_files({
            "sim/mod.rs": (
                "// dfl-lint: allow(wall-clock) — harness stopwatch\n"
                f"pub fn t() {{ let _ = {WALL}; }}\n"
            ),
        })
        self.assertEqual(findings, [], [f.render() for f in findings])

    def test_pragma_does_not_leak_to_other_lines(self):
        findings = self.lint_files({
            "sim/mod.rs": (
                "// dfl-lint: allow(wall-clock) — covers only the next line\n"
                f"pub fn a() {{ let _ = {WALL}; }}\n"
                f"pub fn b() {{ let _ = {WALL}; }}\n"
            ),
        })
        self.assertEqual(rules_of(findings), ["wall-clock"])
        self.assertEqual([f.line for f in findings], [3])

    def test_allow_file_suppresses_whole_file(self):
        findings = self.lint_files({
            "net/tcpish.rs": (
                "// dfl-lint: allow-file(wall-clock) — real-socket transport\n"
                f"pub fn a() {{ let _ = {WALL}; }}\n"
                f"pub fn b() {{ let _ = {WALL}; }}\n"
            ),
        })
        self.assertEqual(findings, [], [f.render() for f in findings])

    def test_pragma_only_suppresses_named_rules(self):
        findings = self.lint_files({
            "net/mix.rs": (
                "use std::collections::HashMap; "
                f"pub fn t() -> std::time::Instant {{ {WALL} }} "
                "// dfl-lint: allow(wall-clock) — stopwatch only\n"
            ),
        })
        self.assertEqual(rules_of(findings), ["hash-iter-order"])

    def test_one_pragma_may_name_several_rules(self):
        findings = self.lint_files({
            "net/mix.rs": (
                "use std::collections::HashMap; "
                f"pub fn t() -> std::time::Instant {{ {WALL} }} "
                "// dfl-lint: allow(wall-clock, hash-iter-order) — bench shim\n"
            ),
        })
        self.assertEqual(findings, [], [f.render() for f in findings])


class Hygiene(PragmaCase):
    def test_missing_justification_is_bad_pragma(self):
        findings = self.lint_files({
            "sim/mod.rs": (
                f"pub fn t() {{ let _ = {WALL}; }} // dfl-lint: allow(wall-clock)\n"
            ),
        })
        self.assertEqual(rules_of(findings), ["bad-pragma"])

    def test_unknown_rule_is_bad_pragma(self):
        findings = self.lint_files({
            "sim/mod.rs": (
                "pub fn t() {}\n"
                "// dfl-lint: allow(no-such-rule) — typo\n"
            ),
        })
        self.assertEqual(rules_of(findings), ["bad-pragma"])
        self.assertIn("no-such-rule", findings[0].message)

    def test_empty_rule_list_is_bad_pragma(self):
        findings = self.lint_files({
            "sim/mod.rs": "pub fn t() {}\n// dfl-lint: allow() — nothing\n",
        })
        self.assertEqual(rules_of(findings), ["bad-pragma"])

    def test_meta_rules_cannot_be_suppressed(self):
        # allow(bad-pragma) names a rule outside the catalog, which is
        # itself a bad pragma — exemptions cannot excuse themselves.
        findings = self.lint_files({
            "sim/mod.rs": (
                "pub fn t() {}\n"
                "// dfl-lint: allow(bad-pragma) — trying to self-excuse\n"
            ),
        })
        self.assertEqual(rules_of(findings), ["bad-pragma"])


class Expiry(PragmaCase):
    def test_stale_pragma_is_reported_unused(self):
        # The offending call was fixed but the pragma stayed behind.
        findings = self.lint_files({
            "sim/mod.rs": (
                "// dfl-lint: allow(wall-clock) — excuse for code long gone\n"
                "pub fn t() {}\n"
            ),
        })
        self.assertEqual(rules_of(findings), ["unused-pragma"])

    def test_stale_allow_file_is_reported_unused(self):
        findings = self.lint_files({
            "net/quiet.rs": (
                "// dfl-lint: allow-file(wall-clock) — excuse for code long gone\n"
                "pub fn t() {}\n"
            ),
        })
        self.assertEqual(rules_of(findings), ["unused-pragma"])

    def test_used_pragma_is_not_reported(self):
        findings = self.lint_files({
            "sim/mod.rs": (
                "// dfl-lint: allow(wall-clock) — harness stopwatch\n"
                f"pub fn t() {{ let _ = {WALL}; }}\n"
            ),
        })
        self.assertEqual(findings, [], [f.render() for f in findings])

    def test_pragma_for_disabled_rule_is_not_expired(self):
        # `--allow wall-clock` turns the rule off globally; pragmas for it
        # must not suddenly read as stale.
        findings = lint(
            make_crate(self.tmp, {
                "sim/mod.rs": (
                    "// dfl-lint: allow(wall-clock) — harness stopwatch\n"
                    f"pub fn t() {{ let _ = {WALL}; }}\n"
                ),
            }),
            disabled={"wall-clock"},
        )
        self.assertEqual(findings, [], [f.render() for f in findings])


if __name__ == "__main__":
    unittest.main()
