"""CLI exit-code contract and the repo-wide self-check.

These tests shell out to ``scripts/dfllint.py`` exactly the way
``scripts/tier1.sh`` does, pinning the acceptance criteria: exit 0 on
the real tree (zero unsuppressed findings), exit 1 on every rule's
positive fixture, exit 2 on usage errors, machine-readable ``--json``.
"""

import json
import subprocess
import sys
import tempfile
import unittest
from pathlib import Path

from .helpers import POSITIVE, REPO_ROOT, make_crate

DFLLINT = REPO_ROOT / "scripts" / "dfllint.py"


def run_cli(args, cwd):
    return subprocess.run(
        [sys.executable, str(DFLLINT), *args],
        cwd=cwd,
        capture_output=True,
        text=True,
        timeout=120,
    )


class RepoSelfCheck(unittest.TestCase):
    """The tree this linter ships in must itself be clean."""

    def test_rust_src_is_clean(self):
        proc = run_cli(["rust/src"], cwd=REPO_ROOT)
        self.assertEqual(
            proc.returncode, 0,
            f"dfl-lint found unsuppressed findings:\n{proc.stdout}{proc.stderr}",
        )

    def test_rust_src_json_reports_zero_denies(self):
        proc = run_cli(["--json", "rust/src"], cwd=REPO_ROOT)
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        doc = json.loads(proc.stdout)
        self.assertEqual(doc["deny_count"], 0)
        self.assertEqual(doc["findings"], [])
        self.assertGreater(doc["files_scanned"], 0)


class ExitCodes(unittest.TestCase):
    def test_each_positive_fixture_exits_nonzero(self):
        for rule, files in POSITIVE.items():
            with self.subTest(rule=rule):
                with tempfile.TemporaryDirectory() as tmp:
                    make_crate(Path(tmp), files)
                    proc = run_cli(["src"], cwd=tmp)
                    self.assertEqual(
                        proc.returncode, 1,
                        f"{rule}: expected exit 1, got {proc.returncode}\n"
                        f"{proc.stdout}{proc.stderr}",
                    )
                    self.assertIn(rule, proc.stdout)

    def test_usage_error_exits_2(self):
        with tempfile.TemporaryDirectory() as tmp:
            proc = run_cli(["--no-such-flag"], cwd=tmp)
            self.assertEqual(proc.returncode, 2, proc.stdout + proc.stderr)

    def test_allow_downgrades_exit_to_zero(self):
        with tempfile.TemporaryDirectory() as tmp:
            make_crate(Path(tmp), POSITIVE["wall-clock"])
            proc = run_cli(["--allow", "wall-clock", "src"], cwd=tmp)
            self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)


class OutputModes(unittest.TestCase):
    def test_list_rules_names_whole_catalog(self):
        proc = run_cli(["--list-rules"], cwd=REPO_ROOT)
        self.assertEqual(proc.returncode, 0, proc.stdout + proc.stderr)
        for rule in list(POSITIVE) + ["bad-pragma", "unused-pragma"]:
            self.assertIn(rule, proc.stdout)
        self.assertIn("allow-file", proc.stdout)  # pragma syntax footer

    def test_json_findings_are_structured(self):
        with tempfile.TemporaryDirectory() as tmp:
            make_crate(Path(tmp), POSITIVE["wire-tag"])
            proc = run_cli(["--json", "src"], cwd=tmp)
            self.assertEqual(proc.returncode, 1)
            doc = json.loads(proc.stdout)
            self.assertEqual(doc["deny_count"], len(doc["findings"]))
            f = doc["findings"][0]
            for key in ("path", "line", "rule", "severity", "message"):
                self.assertIn(key, f)
            self.assertEqual(f["rule"], "wire-tag")

    def test_findings_output_is_sorted_and_stable(self):
        files = {}
        files.update(POSITIVE["wire-tag"])
        files.update(POSITIVE["hash-iter-order"])
        with tempfile.TemporaryDirectory() as tmp:
            make_crate(Path(tmp), files)
            first = run_cli(["src"], cwd=tmp)
            second = run_cli(["src"], cwd=tmp)
            self.assertEqual(first.stdout, second.stdout)
            lines = [
                l for l in first.stdout.splitlines() if l and not l.startswith("dfl-lint")
            ]
            self.assertEqual(lines, sorted(lines))


if __name__ == "__main__":
    unittest.main()
