"""Lexer fixtures: the tricky Rust surface forms the rules depend on."""

import unittest

from .helpers import SCRIPTS_DIR  # noqa: F401  (sys.path side effect)
from dfllint.lexer import lex


class NestedBlockComments(unittest.TestCase):
    def test_nested_block_comment_masks_inner_code(self):
        src = "let a = 1; /* outer /* Instant::now() */ still comment */ let b = 2;\n"
        lx = lex("f.rs", src)
        self.assertIn("let a = 1;", lx.code[0])
        self.assertIn("let b = 2;", lx.code[0])
        self.assertNotIn("Instant", lx.code[0])
        self.assertIn("Instant::now()", lx.comments[0])

    def test_multiline_block_comment_spans_lines(self):
        src = "fn f() {}\n/* line one\n   thread_rng()\n*/\nfn g() {}\n"
        lx = lex("f.rs", src)
        self.assertNotIn("thread_rng", lx.code[2])
        self.assertIn("thread_rng", lx.comments[2])
        self.assertIn("fn g", lx.code[4])

    def test_unterminated_block_comment_degrades_to_eof(self):
        lx = lex("f.rs", "fn f() {}\n/* never closed\nSystemTime\n")
        self.assertNotIn("SystemTime", lx.code[2])


class RawStrings(unittest.TestCase):
    def test_raw_string_contents_are_not_code(self):
        src = 'let s = r#"Instant::now() and "quotes" inside"#; let t = 1;\n'
        lx = lex("f.rs", src)
        self.assertNotIn("Instant", lx.code[0])
        self.assertIn("let t = 1;", lx.code[0])

    def test_raw_string_guard_arity_must_match(self):
        # The `"#` inside a `r##"..."##` string does not terminate it.
        src = 'let s = r##"a "# b"##; let after = 9;\n'
        lx = lex("f.rs", src)
        self.assertIn("let after = 9;", lx.code[0])
        self.assertNotIn('a "# b', lx.code[0])

    def test_byte_and_byte_raw_strings(self):
        src = 'let a = b"HashMap"; let b = br#"HashSet"#; let k = 0;\n'
        lx = lex("f.rs", src)
        self.assertNotIn("HashMap", lx.code[0])
        self.assertNotIn("HashSet", lx.code[0])
        self.assertIn("let k = 0;", lx.code[0])

    def test_identifier_ending_in_r_is_not_raw_string(self):
        src = 'let wider = wider_var; for_ = "x"; let z = 3;\n'
        lx = lex("f.rs", src)
        self.assertIn("let wider = wider_var;", lx.code[0])
        self.assertIn("let z = 3;", lx.code[0])


class CharLiteralsVsLifetimes(unittest.TestCase):
    def test_char_literal_is_masked(self):
        lx = lex("f.rs", "let c = 'x'; let esc = '\\n'; let u = '\\u{1F600}';\n")
        self.assertNotIn("'x'", lx.code[0])
        self.assertNotIn("\\n", lx.code[0])
        self.assertNotIn("1F600", lx.code[0])

    def test_lifetimes_and_labels_stay_code(self):
        src = "fn f<'a>(x: &'a str) -> &'a str { 'outer: loop { break 'outer; } }\n"
        lx = lex("f.rs", src)
        self.assertIn("'a", lx.code[0])
        self.assertIn("'outer:", lx.code[0])

    def test_static_lifetime_not_swallowed(self):
        # A naive quote-pairing lexer would treat 'static ... ' as a char
        # literal and eat the code between two lifetimes.
        src = "fn f(x: &'static str, y: &'static str) { x.unwrap_marker(); }\n"
        lx = lex("f.rs", src)
        self.assertIn("unwrap_marker", lx.code[0])


class StringsAndAttributes(unittest.TestCase):
    def test_string_with_escaped_quote(self):
        lx = lex("f.rs", 'let s = "a\\"b Instant::now()"; let ok = 1;\n')
        self.assertNotIn("Instant", lx.code[0])
        self.assertIn("let ok = 1;", lx.code[0])

    def test_bracket_inside_attr_string_does_not_close_attr(self):
        src = '#[doc = "has ] bracket"]\nfn f() {}\n'
        lx = lex("f.rs", src)
        self.assertIn("doc", lx.attrs[0])
        self.assertIn("fn f() {}", lx.code[1])
        # Attribute surface is excluded from the code mask entirely.
        self.assertNotIn("doc", lx.code[0])

    def test_attr_string_visible_in_sig_mask_not_code(self):
        src = '#[cfg(feature = "pjrt")]\nfn f() {}\n'
        lx = lex("f.rs", src)
        self.assertIn('feature = "pjrt"', lx.sig[0])
        self.assertNotIn("feature", lx.code[0])


class CfgTestRegions(unittest.TestCase):
    def test_mod_tests_region(self):
        src = (
            "pub fn real() {}\n"
            "#[cfg(test)]\n"
            "mod tests {\n"
            "    #[test]\n"
            "    fn t() { assert!(true); }\n"
            "}\n"
            "pub fn after() {}\n"
        )
        lx = lex("f.rs", src)
        self.assertFalse(lx.in_test(1))
        self.assertTrue(lx.in_test(2))
        self.assertTrue(lx.in_test(5))
        self.assertTrue(lx.in_test(6))
        self.assertFalse(lx.in_test(7))

    def test_gated_use_statement_ends_at_semicolon(self):
        src = "#[cfg(test)]\nuse std::collections::HashMap;\nfn live() {}\n"
        lx = lex("f.rs", src)
        self.assertTrue(lx.in_test(2))
        self.assertFalse(lx.in_test(3))

    def test_inner_cfg_test_gates_rest_of_file(self):
        src = "#![cfg(test)]\nfn helper() {}\nfn more() {}\n"
        lx = lex("f.rs", src)
        self.assertTrue(lx.in_test(2))
        self.assertTrue(lx.in_test(3))

    def test_cfg_attr_and_not_test_are_not_gated(self):
        src = (
            "#[cfg_attr(test, derive(Debug))]\n"
            "pub struct S;\n"
            "#[cfg(not(test))]\n"
            "pub fn prod_only() {}\n"
        )
        lx = lex("f.rs", src)
        for ln in range(1, 5):
            self.assertFalse(lx.in_test(ln), f"line {ln} wrongly gated")

    def test_braces_in_strings_do_not_break_region_tracking(self):
        src = (
            "#[cfg(test)]\n"
            "mod tests {\n"
            '    const S: &str = "unbalanced } brace {";\n'
            "}\n"
            "pub fn live() {}\n"
        )
        lx = lex("f.rs", src)
        self.assertTrue(lx.in_test(4))
        self.assertFalse(lx.in_test(5))


if __name__ == "__main__":
    unittest.main()
