#!/usr/bin/env bash
# Tier-1 verify sequence — the whole CI story in one entrypoint.
# Referenced by README.md ("Build, test, docs") and ROADMAP.md.
#
#   scripts/tier1.sh            # dfl-lint + build + tests + doc check
#                               # + bench build + executor conformance matrix
#   scripts/tier1.sh --fast     # dfl-lint + build + unit tests (inner loop)
#   scripts/tier1.sh --scale    # additionally run the opt-in scale tests
#                               # (200/1000/10000 clients; minutes)
set -euo pipefail
cd "$(dirname "$0")/.."

FAST=0
SCALE=0
for arg in "$@"; do
  case "$arg" in
    --fast) FAST=1 ;;
    --scale) SCALE=1 ;;
    *) echo "usage: scripts/tier1.sh [--fast|--scale]" >&2; exit 2 ;;
  esac
done

# Static-analysis gate (DESIGN.md §15): dfl-lint runs before any cargo
# leg because it needs no toolchain — on images without rustc it is the
# one tier-1 gate that can still fail the build.  Deny-by-default: any
# unsuppressed finding exits 1 and stops the sequence here.
if command -v python3 >/dev/null 2>&1; then
  echo "==> dfl-lint rust/src     (static determinism & invariant gate, DESIGN.md §15)"
  python3 scripts/dfllint.py rust/src
else
  echo "==> dfl-lint: python3 not found, SKIPPING the static invariant gate" >&2
fi

echo "==> cargo build --release"
cargo build --release

# Lint gate: deny-warnings clippy over every target.  Degrades to a skip
# (not a failure) where the toolchain ships without the clippy component —
# the build/test gates above still ran, so tier-1 stays meaningful there.
if cargo clippy --version >/dev/null 2>&1; then
  echo "==> cargo clippy --all-targets -- -D warnings"
  cargo clippy --all-targets -- -D warnings
else
  echo "==> cargo clippy: not installed, skipping lint gate"
fi

echo "==> cargo test -q"
cargo test -q

if [[ "$FAST" == "1" ]]; then
  echo "tier-1 (fast): OK"
  exit 0
fi

# RUSTDOCFLAGS applies only to rustdoc invocations, and --no-deps means
# rustdoc runs only on this crate — so -D warnings enforces "our docs are
# warning-clean" exactly, without tripping on dependency-compilation
# noise the way grepping combined cargo output would.
echo "==> cargo doc --no-deps   (must be warning-clean; broken intra-doc links are denied)"
RUSTDOCFLAGS="${RUSTDOCFLAGS:-} -D warnings" cargo doc --no-deps

echo "==> cargo bench --no-run  (benches must keep compiling)"
cargo bench --no-run

# Executor-matrix leg: the full cross-executor conformance product
# (events | threads | parallel over every seed × overlay × net ×
# scenario cell) plus the delta-codec diagonal (per-link codec state and
# flag relays under delta:32, alternating q16, across all three
# executors).  Release mode keeps the ~600 small deployments quick.
echo "==> cargo test -q --release --test conformance -- --ignored   (executor matrix + delta-codec diagonal)"
cargo test -q --release --test conformance -- --ignored

# Allocation-budget leg (DESIGN.md §14): rebuilds with the counting global
# allocator — a separate feature set, so it cannot share the cache of the
# runs above — and pins the steady-state allocations per client-round of
# the events and parallel executors.  Release mode keeps the two
# 200-client deployments per executor quick.
echo "==> cargo test -q --release --features alloc-audit --test alloc_budget   (steady-state allocation budget)"
cargo test -q --release --features alloc-audit --test alloc_budget

if [[ "$SCALE" == "1" ]]; then
  echo "==> cargo test -q -- --ignored --test-threads=1   (scale tests)"
  cargo test -q -- --ignored --test-threads=1
fi

echo "tier-1: OK"
