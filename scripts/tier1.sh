#!/usr/bin/env bash
# Tier-1 verify sequence — the whole CI story in one entrypoint.
# Referenced by README.md ("Build, test, docs") and ROADMAP.md.
#
#   scripts/tier1.sh            # build + tests + doc check + bench build
#   scripts/tier1.sh --scale    # additionally run the opt-in scale tests
#                               # (200/1000/10000 clients; minutes)
set -euo pipefail
cd "$(dirname "$0")/.."

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo doc --no-deps   (broken intra-doc links are denied)"
cargo doc --no-deps

echo "==> cargo bench --no-run  (benches must keep compiling)"
cargo bench --no-run

if [[ "${1:-}" == "--scale" ]]; then
  echo "==> cargo test -q -- --ignored --test-threads=1   (scale tests)"
  cargo test -q -- --ignored --test-threads=1
fi

echo "tier-1: OK"
