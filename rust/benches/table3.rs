//! Bench: regenerate Table 3 / Fig 2 (Phase 1 sync, non-IID, 2–10 clients).
//! Paper shape: accuracy 59.78→67.47 rising with client count.

mod common;

fn main() {
    let engine = common::engine();
    let table = dfl::exp::table3(&engine, common::scale());
    table.print("Table 3 — Non-IID results (paper: acc rises 59.78→67.47 with clients)");
}
