//! Bench: the beyond-paper network-scenario matrix (DESIGN.md §3.4) —
//! the async protocol swept across ideal/lan/wan/asym/lossy-burst presets
//! under the deterministic virtual clock.

mod common;

fn main() {
    let engine = common::engine();
    let table = dfl::exp::scenarios(&engine, common::scale());
    table.print("Scenario matrix — network presets (beyond paper)");
}
