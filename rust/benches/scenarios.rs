//! Bench: the beyond-paper sweeps — the network-scenario matrix
//! (DESIGN.md §3.4), the sparse-overlay topology sweep (DESIGN.md §9),
//! the graph-fault sweep (DESIGN.md §10), and the Byzantine sweep
//! (DESIGN.md §11), all under the deterministic virtual clock.

mod common;

fn main() {
    let engine = common::engine();
    let table = dfl::exp::scenarios(&engine, common::scale());
    table.print("Scenario matrix — network presets (beyond paper)");
    let table = dfl::exp::topologies(&engine, common::scale());
    table.print("Topology sweep — sparse overlays (beyond paper)");
    let table = dfl::exp::faults(&engine, common::scale());
    table.print("Fault sweep — graph faults + quorum auto-tuning (beyond paper)");
    let table = dfl::exp::byzantine(&engine, common::scale());
    table.print("Byzantine sweep — adversaries vs robust aggregation (beyond paper)");
}
