//! Bench: regenerate Figures 5 & 6 (Experiment 2 — n/3 proportional faults
//! vs fault-free ⌊2n/3⌋ baseline).
//! Paper shape: faulty accuracy ≈ baseline; multi-machine faulty runs can
//! beat the baseline's time.

mod common;

fn main() {
    let engine = common::engine();
    let table = dfl::exp::fig5_6(&engine, common::scale());
    table.print("Fig 5+6 — N/3 faults vs ⌊2N/3⌋ fault-free baseline");
}
