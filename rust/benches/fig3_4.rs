//! Bench: regenerate Figures 3 & 4 (Experiment 1 — variable crash, 12
//! clients, 0..11 faults, 1/2/3-machine deployments).
//! Paper shape: graceful accuracy decline with faults; 1-machine slowest at
//! zero faults (contention).

mod common;

fn main() {
    let engine = common::engine();
    let table = dfl::exp::fig3_4(&engine, common::scale());
    table.print("Fig 3+4 — 12 clients under variable fault conditions");
}
