//! Bench: regenerate Table 2 (single-client baselines).
//! Paper reference rows: Non-IID 26.23% < IID 37.48% < Full 70.82%.

mod common;

fn main() {
    let engine = common::engine();
    let table = dfl::exp::table2(&engine, common::scale());
    table.print("Table 2 — Baseline Performance Results (paper: 26.23 / 37.48 / 70.82)");
}
