//! Bench: termination-detection reliability under crashes and message loss
//! (the §3 protocol claims: all survivors terminate adaptively via CCC/CRT,
//! none prematurely, none stuck at the round cap).

mod common;

fn main() {
    let engine = common::engine();
    let table = dfl::exp::termination_reliability(&engine, common::scale());
    table.print("Termination reliability under faults");
}
