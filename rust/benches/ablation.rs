//! Ablation benches for the design choices DESIGN.md §5 calls out:
//!
//! 1. **CRT on/off** — without Client-Responsive Termination every client
//!    must reach CCC alone: measures the wasted training rounds CRT saves.
//! 2. **early_window_exit on/off** — the fixed-TIMEOUT pseudocode vs the
//!    all-peers-heard early exit: wallclock per run.
//! 3. **COUNT_THRESHOLD sweep** — stability window vs rounds-to-terminate.
//!
//! Runs on the MockTrainer (protocol behaviour, not ML quality).

mod common;

use std::time::Duration;

use dfl::coordinator::termination::TerminationCause;
use dfl::coordinator::ProtocolConfig;
use dfl::net::NetworkModel;
use dfl::runtime::{MockTrainer, Trainer};
use dfl::sim::{self, Partition, SimConfig};
use dfl::util::benchkit::Table;

fn cfg(n: usize, seed: u64) -> SimConfig {
    let trainer = MockTrainer::tiny();
    let mut cfg = SimConfig::for_meta(n, trainer.meta());
    cfg.protocol = ProtocolConfig {
        timeout: Duration::from_millis(120),
        min_rounds: 4,
        count_threshold: 2,
        conv_threshold_rel: 0.3,
        max_rounds: 40,
        lr: 0.08,
        ..ProtocolConfig::default()
    };
    cfg.partition = Partition::Dirichlet(0.6);
    cfg.train_n = 60 * n;
    cfg.net = NetworkModel::lan(seed);
    cfg.seed = seed;
    cfg
}

fn total_rounds(res: &dfl::sim::SimResult) -> u32 {
    res.reports.iter().map(|r| r.rounds_completed).sum()
}

fn main() {
    let trainer = MockTrainer::tiny();
    let mut table = Table::new(&["Ablation", "Setting", "Total client-rounds", "Wall (s)", "Adaptive (%)"]);

    // 1. CRT on/off — heterogeneous data means clients' own CCC fire at
    //    very different rounds; CRT lets the first trigger stop everyone.
    for (name, crt) in [("CRT on (paper)", true), ("CRT off", false)] {
        let mut c = cfg(8, 11);
        c.protocol.crt_enabled = crt;
        let res = sim::run(&trainer, &c).expect("run");
        let adaptive = res
            .reports
            .iter()
            .filter(|r| matches!(r.cause, TerminationCause::Converged | TerminationCause::Signaled))
            .count();
        table.row(&[
            "1 termination".into(),
            name.into(),
            total_rounds(&res).to_string(),
            format!("{:.2}", res.wall.as_secs_f64()),
            format!("{:.0}", 100.0 * adaptive as f32 / 8.0),
        ]);
    }

    // 2. early window exit on/off
    for (name, early) in [("early-exit (impl)", true), ("fixed TIMEOUT (pseudocode)", false)] {
        let mut c = cfg(6, 13);
        c.protocol.early_window_exit = early;
        let res = sim::run(&trainer, &c).expect("run");
        table.row(&[
            "2 wait window".into(),
            name.into(),
            total_rounds(&res).to_string(),
            format!("{:.2}", res.wall.as_secs_f64()),
            "-".into(),
        ]);
    }

    // 3. COUNT_THRESHOLD sweep
    for ct in [1u32, 2, 4, 8] {
        let mut c = cfg(6, 17);
        c.protocol.count_threshold = ct;
        let res = sim::run(&trainer, &c).expect("run");
        table.row(&[
            "3 COUNT_THRESHOLD".into(),
            format!("x = {ct}"),
            total_rounds(&res).to_string(),
            format!("{:.2}", res.wall.as_secs_f64()),
            "-".into(),
        ]);
    }

    table.print("Ablations (mock trainer, protocol-level)");
}
