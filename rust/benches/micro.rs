//! Micro-benchmarks of the hot path (DESIGN.md §6):
//! PJRT call latencies (train/eval/aggregate), codec encode/decode at model
//! size, in-proc broadcast fan-out, and one full protocol round.

mod common;

use std::sync::Arc;
use std::time::Duration;

use dfl::model::ParamVector;
use dfl::net::{InProcHub, Msg, ModelUpdate, NetworkModel, Transport};
use dfl::runtime::Trainer;
use dfl::util::benchkit::{bench_for, black_box};
use dfl::util::Rng;

fn main() {
    let engine = common::engine();
    let meta = engine.meta().clone();
    let mut rng = Rng::new(1);
    let budget = Duration::from_secs(2);

    // --- PJRT request-path calls -------------------------------------------
    let params = engine.init(42).expect("init");
    let xs: Vec<f32> = (0..meta.train_x_len()).map(|_| rng.normal()).collect();
    let ys: Vec<i32> = (0..meta.train_y_len()).map(|_| rng.below(10) as i32).collect();
    bench_for("pjrt/train_round", budget, || {
        black_box(engine.train_round(&params, &xs, &ys, 0.05).unwrap());
    });

    let exs: Vec<f32> = (0..meta.eval_x_len(false)).map(|_| rng.normal()).collect();
    let eys: Vec<i32> = (0..meta.eval_y_len(false)).map(|_| rng.below(10) as i32).collect();
    bench_for("pjrt/eval_round", budget, || {
        black_box(engine.eval(&params, &exs, &eys, false).unwrap());
    });

    let rows: Vec<(&[f32], f32)> = (0..8).map(|_| (params.as_slice(), 1.0)).collect();
    bench_for("pjrt/aggregate_8", budget, || {
        black_box(engine.aggregate(&rows).unwrap());
    });

    // --- codec at model size -------------------------------------------------
    let update = Msg::Update(ModelUpdate {
        sender: 1,
        round: 7,
        terminate: false,
        weight: 1.0,
        params: ParamVector(params.clone()),
    });
    bench_for("codec/encode_model", budget, || {
        black_box(update.encode());
    });
    let bytes = update.encode();
    bench_for("codec/decode_model", budget, || {
        black_box(Msg::decode(&bytes).unwrap());
    });

    // --- broadcast fan-out (12 peers, ideal network) ------------------------
    let hub = InProcHub::new(12, NetworkModel::ideal());
    let eps: Vec<_> = (0..12).map(|i| hub.endpoint(i)).collect();
    bench_for("net/broadcast_12", budget, || {
        eps[0].broadcast(&update).unwrap();
        // drain receivers so queues don't grow unboundedly
        for ep in &eps[1..] {
            while ep.try_recv().is_some() {}
        }
    });

    // --- one full protocol round (4 clients, mock-speed network) ------------
    let mut cfg = dfl::sim::SimConfig::for_meta(4, &meta);
    cfg.protocol.max_rounds = 1;
    cfg.protocol.min_rounds = 5;
    cfg.train_n = 400;
    let engine_ref = &engine;
    bench_for("e2e/one_round_4_clients", Duration::from_secs(4), || {
        black_box(dfl::sim::run(engine_ref, &cfg).unwrap());
    });

    let _ = Arc::new(());
}
