//! Micro-benchmarks of the hot path (DESIGN.md §6):
//! PJRT call latencies (train/eval/aggregate), codec encode/decode at model
//! size, in-proc broadcast fan-out, virtual-scheduler context-switch
//! throughput (thread-backed vs event-driven vs sharded-parallel at
//! 100 / 1 000 / 10 000 tokens), and one full protocol round under each
//! executor.

mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};

use dfl::metrics::AllocStats;
use dfl::model::ParamVector;
use dfl::net::delta::{DeltaMsg, DeltaRx, DeltaTx};
use dfl::net::{InProcHub, Msg, ModelUpdate, NetworkModel, Transport};
use dfl::runtime::{AggScratch, AggregationRule, TrainScratch, Trainer};
use dfl::util::benchkit::{bench_for, black_box};
use dfl::util::pool;
use dfl::util::time::VirtualClock;
use dfl::util::Rng;

/// Staggered sleep per token so the timer heap sees a realistic spread of
/// dues instead of one degenerate instant.
fn stagger(token: usize) -> Duration {
    Duration::from_micros(50 + (token % 7) as u64 * 13)
}

/// Event-driven mode: one thread pumps every token through the driver API.
/// Returns context switches per second of wall time.
fn sched_events(n: usize, wakes_per_token: usize) -> f64 {
    let clock = VirtualClock::new(n);
    let mut remaining = vec![wakes_per_token; n];
    let mut switches = 0u64;
    let t0 = Instant::now();
    while let Some(t) = clock.driver_next() {
        switches += 1;
        if remaining[t] == 0 {
            clock.detach(t);
        } else {
            remaining[t] -= 1;
            clock.driver_sleep(t, stagger(t));
        }
    }
    switches as f64 / t0.elapsed().as_secs_f64()
}

/// Sharded parallel mode: S worker threads each pump a shard-local clock
/// through bounded lookahead windows while a coordinator advances the
/// horizon — the same barrier protocol as `ExecMode::Parallel`, minus the
/// network.  The lookahead sits just below the smallest stagger so every
/// window carries work.
fn sched_parallel(n: usize, wakes_per_token: usize, shards: usize) -> f64 {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::Barrier;

    let s = shards.clamp(1, n);
    let members: Vec<Vec<usize>> = (0..s)
        .map(|sh| (0..n).filter(|t| t % s == sh).collect())
        .collect();
    let clocks: Vec<_> = members.iter().map(|m| VirtualClock::with_members(n, m)).collect();
    let lookahead = Duration::from_micros(40);
    let barrier = Barrier::new(s + 1);
    let horizon_nanos = AtomicU64::new(0);
    let done = AtomicBool::new(false);
    let t0 = Instant::now();
    let switches: u64 = std::thread::scope(|scope| {
        let handles: Vec<_> = clocks
            .iter()
            .map(|clock| {
                let (barrier, horizon_nanos, done) = (&barrier, &horizon_nanos, &done);
                scope.spawn(move || {
                    let mut remaining = vec![wakes_per_token; n];
                    let mut switches = 0u64;
                    loop {
                        barrier.wait();
                        if done.load(Ordering::Acquire) {
                            break switches;
                        }
                        let h = Duration::from_nanos(horizon_nanos.load(Ordering::Acquire));
                        while let Some(t) = clock.driver_next_before(h) {
                            switches += 1;
                            if remaining[t] == 0 {
                                clock.detach(t);
                            } else {
                                remaining[t] -= 1;
                                clock.driver_sleep(t, stagger(t));
                            }
                        }
                        barrier.wait();
                    }
                })
            })
            .collect();
        loop {
            match clocks.iter().filter_map(|c| c.pending_lower_bound()).min() {
                None => {
                    done.store(true, Ordering::Release);
                    barrier.wait();
                    break;
                }
                Some(t) => {
                    let h = t + lookahead;
                    horizon_nanos
                        .store(u64::try_from(h.as_nanos()).unwrap_or(u64::MAX), Ordering::Release);
                    barrier.wait(); // release the window
                    barrier.wait(); // wait for every shard to drain it
                }
            }
        }
        handles.into_iter().map(|h| h.join().expect("join bench shard")).sum()
    });
    switches as f64 / t0.elapsed().as_secs_f64()
}

/// Thread-backed mode: one small-stack OS thread per token, each sleeping
/// `wakes_per_token` times on the shared clock.
fn sched_threads(n: usize, wakes_per_token: usize) -> f64 {
    let clock = VirtualClock::new(n);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for t in 0..n {
            let clock = Arc::clone(&clock);
            std::thread::Builder::new()
                .name(format!("sched-{t}"))
                .stack_size(128 * 1024)
                .spawn_scoped(scope, move || {
                    clock.attach(t);
                    for _ in 0..wakes_per_token {
                        clock.sleep(t, stagger(t));
                    }
                    clock.detach(t);
                })
                .expect("spawn bench thread");
        }
    });
    (n * (wakes_per_token + 1)) as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let engine = common::engine();
    let meta = engine.meta().clone();
    let mut rng = Rng::new(1);
    let budget = Duration::from_secs(2);

    // --- PJRT request-path calls -------------------------------------------
    let params = engine.init(42).expect("init");
    let xs: Vec<f32> = (0..meta.train_x_len()).map(|_| rng.normal()).collect();
    let ys: Vec<i32> = (0..meta.train_y_len()).map(|_| rng.below(10) as i32).collect();
    bench_for("pjrt/train_round", budget, || {
        black_box(engine.train_round(&params, &xs, &ys, 0.05).unwrap());
    });

    let exs: Vec<f32> = (0..meta.eval_x_len(false)).map(|_| rng.normal()).collect();
    let eys: Vec<i32> = (0..meta.eval_y_len(false)).map(|_| rng.below(10) as i32).collect();
    bench_for("pjrt/eval_round", budget, || {
        black_box(engine.eval(&params, &exs, &eys, false).unwrap());
    });

    let rows: Vec<(&[f32], f32)> = (0..8).map(|_| (params.as_slice(), 1.0)).collect();
    bench_for("pjrt/aggregate_8", budget, || {
        black_box(engine.aggregate(&rows).unwrap());
    });

    // --- pooled buffers & scratch kernels (DESIGN.md §14) -------------------
    // alloc/* rows are the malloc baseline; pool/* rows are the pooled or
    // scratch-based counterpart of a row above (same inputs, reused buffers).
    bench_for("alloc/vec_f32_4k", budget, || {
        black_box(vec![0.0f32; 4096]);
    });
    bench_for("alloc/stats_snapshot", budget, || {
        black_box(AllocStats::snapshot());
    });
    bench_for("pool/take_recycle_4k", budget, || {
        let mut v = pool::take_f32(4096);
        v.resize(4096, 0.0);
        pool::recycle_f32(black_box(v));
    });
    let src: Vec<f32> = (0..4096).map(|_| rng.normal()).collect();
    bench_for("pool/copy_of_4k", budget, || {
        pool::recycle_f32(black_box(pool::copy_of(&src)));
    });

    // Same start point every iteration (like pjrt/train_round), refreshed by
    // copy instead of allocation, so the row isolates the kernel cost.
    let mut scratch = TrainScratch::default();
    let mut sp = params.clone();
    bench_for("pool/train_round_scratch", budget, || {
        sp.clear();
        sp.extend_from_slice(&params);
        black_box(engine.train_round_scratch(&mut sp, &xs, &ys, 0.05, &mut scratch).unwrap());
    });
    let mut agg = AggScratch::default();
    bench_for("pool/aggregate_scratch_8", budget, || {
        engine.aggregate_with_scratch(&rows, &AggregationRule::FedAvg, &mut agg).unwrap();
        black_box(agg.out.as_slice());
    });

    // --- codec at model size -------------------------------------------------
    let update = Msg::Update(ModelUpdate {
        sender: 1,
        round: 7,
        terminate: false,
        weight: 1.0,
        params: ParamVector(params.clone()),
    });
    bench_for("codec/encode_model", budget, || {
        black_box(update.encode());
    });
    let bytes = update.encode();
    bench_for("codec/decode_model", budget, || {
        black_box(Msg::decode(&bytes).unwrap());
    });

    // --- delta codec at synthetic model sizes (DESIGN.md §13) ---------------
    // Steady-state link: the base round is acked, so every iteration pays
    // the real per-round cost — top-K selection, sparse body build, wire
    // encode/decode, and receiver reconstruction.  The dense rows run the
    // same round trip through `Msg::Update` for comparison.
    for &(dim, label) in &[(10_000usize, "10k"), (100_000usize, "100k")] {
        let base: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
        // Every coordinate drifts, with varied magnitude, so top-K has a
        // full candidate set to rank instead of a degenerate prefix.
        let cur: Vec<f32> =
            base.iter().enumerate().map(|(i, v)| v + 0.001 * (i % 97) as f32).collect();

        let dense = Msg::Update(ModelUpdate {
            sender: 1,
            round: 2,
            terminate: false,
            weight: 1.0,
            params: ParamVector(cur.clone()),
        });
        bench_for(&format!("codec/dense_{label}"), budget, || {
            let bytes = dense.encode();
            black_box(Msg::decode(&bytes).unwrap());
        });

        for (q16, name) in [(false, "delta64"), (true, "delta64_q16")] {
            let mut tx = DeltaTx::new();
            let mut rx = DeltaRx::new();
            // Round 1 full snapshot + ack establishes the shared base.
            let b1 = tx.encode(64, q16, 1, &base);
            rx.decode(1, &b1).expect("full snapshot decodes");
            tx.on_ack(&rx.ack());
            bench_for(&format!("codec/{name}_{label}"), budget, || {
                let body = tx.encode(64, q16, 2, &cur);
                let msg = Msg::Delta(DeltaMsg {
                    sender: 1,
                    round: 2,
                    terminate: false,
                    weight: 1.0,
                    ack: rx.ack(),
                    body,
                });
                let bytes = msg.encode();
                let Msg::Delta(dm) = Msg::decode(&bytes).unwrap() else {
                    unreachable!("delta frames decode as deltas")
                };
                black_box(rx.decode(2, &dm.body).expect("acked base is held"));
            });
        }
    }

    // --- broadcast fan-out (12 peers, ideal network) ------------------------
    let hub = InProcHub::new(12, NetworkModel::ideal());
    let eps: Vec<_> = (0..12).map(|i| hub.endpoint(i)).collect();
    bench_for("net/broadcast_12", budget, || {
        eps[0].broadcast(&update).unwrap();
        // drain receivers so queues don't grow unboundedly
        for ep in &eps[1..] {
            while ep.try_recv().is_some() {}
        }
    });

    // --- virtual scheduler: context switches/sec, threads vs events ---------
    // ~200k total switches per row, split across the token count, so every
    // row runs in comparable wall time regardless of n.
    for &n in &[100usize, 1_000, 10_000] {
        let wakes = (200_000 / n).max(4);
        println!("sched/events_{n}: {:>12.0} switches/s", sched_events(n, wakes));
        println!("sched/parallel4_{n}: {:>12.0} switches/s", sched_parallel(n, wakes, 4));
        println!("sched/threads_{n}: {:>12.0} switches/s", sched_threads(n, wakes));
    }

    // --- one full protocol round (4 clients, mock-speed network) ------------
    let mut cfg = dfl::sim::SimConfig::for_meta(4, &meta);
    cfg.protocol.max_rounds = 1;
    cfg.protocol.min_rounds = 5;
    cfg.train_n = 400;
    let engine_ref = &engine;
    bench_for("e2e/one_round_4_clients", Duration::from_secs(4), || {
        black_box(dfl::sim::run(engine_ref, &cfg).unwrap());
    });

    // --- the same round under each virtual-time executor ---------------------
    cfg.virtual_time = true;
    cfg.exec = dfl::sim::ExecMode::Events;
    bench_for("e2e/one_round_4_clients_events", Duration::from_secs(4), || {
        black_box(dfl::sim::run(engine_ref, &cfg).unwrap());
    });
    cfg.exec = dfl::sim::ExecMode::Parallel { shards: 2 };
    bench_for("e2e/one_round_4_clients_parallel2", Duration::from_secs(4), || {
        black_box(dfl::sim::run(engine_ref, &cfg).unwrap());
    });
    cfg.exec = dfl::sim::ExecMode::Threads;
    bench_for("e2e/one_round_4_clients_vthreads", Duration::from_secs(4), || {
        black_box(dfl::sim::run(engine_ref, &cfg).unwrap());
    });

    let _ = Arc::new(());
}
