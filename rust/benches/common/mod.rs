//! Shared bench bootstrap: locate artifacts, load the engine, pick scale.
//!
//! Benches run the real PJRT engine on the `tiny` artifact config by
//! default; env knobs (documented in README "Reproducing the paper"):
//!
//! * `DFL_BENCH_CONFIG=fast|paper` — bigger artifact configs.
//! * `DFL_BENCH_FULL=1`           — full experiment grids instead of quick.
//! * `DFL_BENCH_REALTIME=1`       — wall-clock deployments instead of the
//!   default deterministic virtual clock (the seed's original behaviour;
//!   expect minutes instead of seconds).
//! * `DFL_ARTIFACTS=<dir>`        — artifact root for non-repo-root runs.

use std::path::PathBuf;

use dfl::exp::ExpScale;
use dfl::runtime::SharedEngine;

pub fn artifacts_root() -> PathBuf {
    // benches run from the crate root; honor the same env override as main
    std::env::var("DFL_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
}

pub fn engine() -> SharedEngine {
    let config = std::env::var("DFL_BENCH_CONFIG").unwrap_or_else(|_| "tiny".into());
    let dir = artifacts_root().join(&config);
    SharedEngine::load(&dir)
        .unwrap_or_else(|e| panic!("loading artifacts {}: {e}\nrun `make artifacts`", dir.display()))
}

pub fn scale() -> ExpScale {
    let mut scale = if std::env::var("DFL_BENCH_FULL").is_ok_and(|v| v == "1") {
        ExpScale::full()
    } else {
        ExpScale::default()
    };
    scale.virtual_time = !std::env::var("DFL_BENCH_REALTIME").is_ok_and(|v| v == "1");
    scale
}
