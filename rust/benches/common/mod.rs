//! Shared bench bootstrap: locate artifacts, load the engine, pick scale.
//!
//! Benches run the real PJRT engine on the `tiny` artifact config by
//! default; set `DFL_BENCH_CONFIG=fast` (or `paper`) and `DFL_BENCH_FULL=1`
//! for the bigger grids.

use std::path::PathBuf;

use dfl::exp::ExpScale;
use dfl::runtime::SharedEngine;

pub fn artifacts_root() -> PathBuf {
    // benches run from the crate root; honor the same env override as main
    std::env::var("DFL_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
}

pub fn engine() -> SharedEngine {
    let config = std::env::var("DFL_BENCH_CONFIG").unwrap_or_else(|_| "tiny".into());
    let dir = artifacts_root().join(&config);
    SharedEngine::load(&dir)
        .unwrap_or_else(|e| panic!("loading artifacts {}: {e}\nrun `make artifacts`", dir.display()))
}

pub fn scale() -> ExpScale {
    if std::env::var("DFL_BENCH_FULL").is_ok_and(|v| v == "1") {
        ExpScale::full()
    } else {
        ExpScale::default()
    }
}
