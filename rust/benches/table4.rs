//! Bench: regenerate Table 4 / Fig 2 (Phase 1 sync, IID, 2–10 clients).
//! Paper shape: accuracy 61.10→70.50, above the non-IID curve everywhere.

mod common;

fn main() {
    let engine = common::engine();
    let table = dfl::exp::table4(&engine, common::scale());
    table.print("Table 4 — IID results (paper: acc rises 61.10→70.50 with clients)");
}
