//! Bench: regenerate Figures 7 & 8 (Experiment 3 — maximum faults, n−1
//! crash, single survivor).
//! Paper shape: survivor accuracy below fault-free federation but above the
//! isolated non-IID single-client baseline (Table 2); time shrinks.

mod common;

fn main() {
    let engine = common::engine();
    let table = dfl::exp::fig7_8(&engine, common::scale());
    table.print("Fig 7+8 — N-1 faults (single survivor)");
}
