//! Protocol-level integration tests: Algorithm 1/2 + CCC/CRT over the
//! in-process network with the deterministic MockTrainer (no PJRT cost).
//! These assert the paper's §3 claims as invariants.
//!
//! All protocol tests run on the virtual clock (`SimConfig::virtual_time`),
//! so wait windows and outages advance logical time instead of sleeping —
//! whole-suite wall time is compute-bound, and seed loops are wide because
//! runs are cheap.  One wall-clock smoke test per algorithm guards the
//! `RealClock` path end to end.

use std::time::Duration;

use dfl::coordinator::fault::{variable_crash_schedule, FaultPlan};
use dfl::coordinator::termination::TerminationCause;
use dfl::coordinator::{ProtocolConfig, QuorumSpec};
use dfl::net::{CodecSpec, NetworkModel};
use dfl::runtime::{AggregationRule, MockTrainer, Trainer};
use dfl::sim::{self, Partition, SimConfig};
use dfl::util::Rng;

fn base_cfg(n: usize, seed: u64) -> SimConfig {
    let trainer = MockTrainer::tiny();
    let meta = trainer.meta();
    let mut cfg = SimConfig::for_meta(n, meta);
    cfg.protocol = ProtocolConfig {
        timeout: Duration::from_millis(80),
        min_rounds: 4,
        count_threshold: 2,
        // generous: the mock's gradient noise floor is higher than the CNN's;
        // these tests exercise protocol logic, not convergence quality
        conv_threshold_rel: 0.12,
        max_rounds: 60,
        lr: 0.08,
        model_seed: 42,
        weight_by_samples: false,
        early_window_exit: true,
        crt_enabled: true,
        quorum: QuorumSpec::STRICT,
        agg: AggregationRule::FedAvg,
        codec: CodecSpec::Dense,
    };
    cfg.train_n = 60 * n;
    cfg.net = NetworkModel::lan(seed);
    cfg.seed = seed;
    cfg.virtual_time = true;
    cfg.train_cost = Duration::from_millis(5);
    cfg
}

#[test]
fn async_fault_free_all_terminate_adaptively() {
    let trainer = MockTrainer::tiny();
    let cfg = base_cfg(5, 11);
    let res = sim::run(&trainer, &cfg).unwrap();
    assert_eq!(res.reports.len(), 5);
    assert_eq!(res.crashed(), 0);
    for r in &res.reports {
        assert!(
            matches!(r.cause, TerminationCause::Converged | TerminationCause::Signaled),
            "client {} ended with {:?}",
            r.id,
            r.cause
        );
        assert!(r.final_accuracy.is_some());
        assert!(r.rounds_completed >= cfg.protocol.min_rounds);
    }
}

#[test]
fn no_premature_termination_before_min_rounds() {
    // Property over seeds: nobody terminates before MINIMUM_ROUNDS.
    // (Wide loop: virtual-time runs cost no wall-clock waits.)
    for seed in 0..32u64 {
        let trainer = MockTrainer::tiny();
        let cfg = base_cfg(4, 100 + seed);
        let res = sim::run(&trainer, &cfg).unwrap();
        for r in &res.reports {
            if r.cause != TerminationCause::Crashed {
                assert!(
                    r.rounds_completed >= cfg.protocol.min_rounds,
                    "seed {seed}: client {} stopped at round {} < min {}",
                    r.id,
                    r.rounds_completed,
                    cfg.protocol.min_rounds
                );
            }
        }
    }
}

#[test]
fn crashes_are_detected_and_survivors_finish() {
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(6, 21);
    cfg.faults = vec![FaultPlan::none(); 6];
    cfg.faults[2] = FaultPlan::at_round(3);
    cfg.faults[4] = FaultPlan::at_round(5);
    let res = sim::run(&trainer, &cfg).unwrap();
    assert_eq!(res.crashed(), 2);
    // every survivor must have detected both crashed peers at some round
    for r in &res.reports {
        if r.cause == TerminationCause::Crashed {
            continue;
        }
        let detected: Vec<u32> = r
            .history
            .iter()
            .flat_map(|h| h.crashes_detected.iter().copied())
            .collect();
        assert!(detected.contains(&2), "client {} never detected crash of 2", r.id);
        assert!(detected.contains(&4), "client {} never detected crash of 4", r.id);
        assert!(r.final_accuracy.is_some());
    }
}

#[test]
fn termination_signal_floods_to_all_survivors() {
    // Over many seeds with random crashes: all survivors end via CCC or
    // CRT — never stuck, never capped (max_rounds is generous).
    for seed in 0..32u64 {
        let trainer = MockTrainer::tiny();
        let n = 7;
        let mut cfg = base_cfg(n, 300 + seed);
        let mut rng = Rng::new(seed);
        cfg.faults = variable_crash_schedule(n, 2, 2, 10, &mut rng);
        let res = sim::run(&trainer, &cfg).unwrap();
        assert!(
            res.all_terminated_adaptively(),
            "seed {seed}: causes {:?}",
            res.reports.iter().map(|r| r.cause).collect::<Vec<_>>()
        );
    }
}

#[test]
fn crt_provenance_chain_is_consistent() {
    let trainer = MockTrainer::tiny();
    let cfg = base_cfg(6, 41);
    let res = sim::run(&trainer, &cfg).unwrap();
    let initiators: Vec<u32> = res
        .reports
        .iter()
        .filter(|r| r.cause == TerminationCause::Converged)
        .map(|r| r.id)
        .collect();
    assert!(!initiators.is_empty(), "someone must initiate (CCC)");
    for r in &res.reports {
        if r.cause == TerminationCause::Signaled {
            let src = r.signal_source.expect("signaled client must have a source");
            assert_ne!(src, r.id);
            assert!(src < 6);
        }
    }
}

#[test]
fn max_fault_single_survivor_still_finishes() {
    let trainer = MockTrainer::tiny();
    let n = 5;
    let mut cfg = base_cfg(n, 51);
    // crash everyone early (before the survivor can converge) so the
    // survivor must observe every failure: rounds 1..=4
    cfg.protocol.min_rounds = 8;
    cfg.faults = (0..n)
        .map(|i| if i == 2 { FaultPlan::none() } else { FaultPlan::at_round(1 + i as u32 % 4) })
        .collect();
    let res = sim::run(&trainer, &cfg).unwrap();
    assert_eq!(res.crashed(), n - 1);
    let survivor = res
        .reports
        .iter()
        .find(|r| r.cause != TerminationCause::Crashed)
        .expect("survivor");
    assert_eq!(survivor.id, 2);
    assert!(survivor.final_accuracy.is_some());
    // survivor must have detected every peer's crash eventually
    let detected: std::collections::BTreeSet<u32> = survivor
        .history
        .iter()
        .flat_map(|h| h.crashes_detected.iter().copied())
        .collect();
    assert_eq!(detected.len(), n - 1, "detected: {detected:?}");
}

#[test]
fn message_loss_does_not_break_termination() {
    // 10% drop probability: CRT piggybacking must still flood the flag.
    for seed in 0..24u64 {
        let trainer = MockTrainer::tiny();
        let mut cfg = base_cfg(5, 500 + seed);
        cfg.net = NetworkModel::lossy(0.10, seed);
        let res = sim::run(&trainer, &cfg).unwrap();
        assert!(
            res.all_terminated_adaptively(),
            "seed {seed}: causes {:?}",
            res.reports.iter().map(|r| r.cause).collect::<Vec<_>>()
        );
    }
}

#[test]
fn sync_phase1_all_clients_agree_on_rounds() {
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(4, 61);
    cfg.sync = true;
    let res = sim::run(&trainer, &cfg).unwrap();
    let rounds: Vec<u32> = res.reports.iter().map(|r| r.rounds_completed).collect();
    assert!(
        rounds.windows(2).all(|w| w[0] == w[1]),
        "sync clients disagree on round count: {rounds:?}"
    );
    // mutual agreement: everyone stops for the same reason class
    for r in &res.reports {
        assert_ne!(r.cause, TerminationCause::Crashed);
        assert!(r.final_accuracy.is_some());
    }
}

#[test]
fn sync_and_async_both_learn() {
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(4, 71);
    cfg.protocol.max_rounds = 12;
    cfg.protocol.conv_threshold_rel = 0.0; // never converge: fixed rounds
    let res_async = sim::run(&trainer, &cfg).unwrap();
    cfg.sync = true;
    let res_sync = sim::run(&trainer, &cfg).unwrap();
    for res in [&res_async, &res_sync] {
        let acc = res.mean_accuracy().unwrap();
        assert!(acc > 0.2, "federation failed to learn: {acc}");
    }
}

#[test]
fn slow_client_is_not_marked_crashed_forever() {
    // A heavily slowed client should be revived by its late messages:
    // the run must finish with everyone terminating adaptively.
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(4, 81);
    cfg.machines = 2; // slowdown via machine profile affects some clients
    let res = sim::run(&trainer, &cfg).unwrap();
    assert!(res.all_terminated_adaptively());
    // and at least one revival OR zero crash-markings happened overall;
    // either way no survivor may end with a permanently-wrong view that
    // prevented aggregation (aggregated >= 2 in final rounds).
    for r in &res.reports {
        if let Some(last) = r.history.last() {
            assert!(last.aggregated >= 1);
        }
    }
}

#[test]
fn transient_failure_rejoins_and_finishes() {
    // §3.1: "temporary and intermittent failures, allowing clients to
    // rejoin after transient faults". Client 1 goes silent for several
    // wait-windows at round 2; peers must mark it crashed, then revive it
    // on its first post-outage broadcast, and it must still terminate.
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(4, 301);
    cfg.protocol.min_rounds = 8; // keep the run alive through the outage
    cfg.faults = vec![FaultPlan::none(); 4];
    cfg.faults[1] = FaultPlan::transient(2, Duration::from_millis(400));
    let res = sim::run(&trainer, &cfg).unwrap();
    // nobody permanently crashed
    assert_eq!(res.crashed(), 0, "transient fault must not be a permanent crash");
    let rejoiner = &res.reports[1];
    assert!(
        matches!(rejoiner.cause, TerminationCause::Converged | TerminationCause::Signaled),
        "rejoiner ended with {:?}",
        rejoiner.cause
    );
    // at least one peer must have first marked client 1 crashed...
    let marked: Vec<u32> = res
        .reports
        .iter()
        .filter(|r| r.id != 1)
        .flat_map(|r| r.history.iter().flat_map(|h| h.crashes_detected.iter().copied()))
        .collect();
    assert!(marked.contains(&1), "outage went undetected: {marked:?}");
    // ...and everyone still finished adaptively (revival worked)
    assert!(res.all_terminated_adaptively());
}

#[test]
fn crt_disabled_forces_self_convergence() {
    // Ablation guard: with CRT off, no client may end as `Signaled`.
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(5, 401);
    cfg.protocol.crt_enabled = false;
    let res = sim::run(&trainer, &cfg).unwrap();
    for r in &res.reports {
        assert_ne!(
            r.cause,
            TerminationCause::Signaled,
            "client {} terminated by signal despite CRT off",
            r.id
        );
    }
}

#[test]
fn async_real_clock_smoke() {
    // Guards the wall-clock path (RealClock + InProcHub timer thread):
    // small n and a short timeout keep the real waiting cheap.
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(3, 901);
    cfg.virtual_time = false;
    cfg.protocol.timeout = Duration::from_millis(40);
    let res = sim::run(&trainer, &cfg).unwrap();
    assert_eq!(res.crashed(), 0);
    assert!(
        res.all_terminated_adaptively(),
        "causes {:?}",
        res.reports.iter().map(|r| r.cause).collect::<Vec<_>>()
    );
}

#[test]
fn sync_real_clock_smoke() {
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(3, 911);
    cfg.virtual_time = false;
    cfg.sync = true;
    let res = sim::run(&trainer, &cfg).unwrap();
    let rounds: Vec<u32> = res.reports.iter().map(|r| r.rounds_completed).collect();
    assert!(rounds.windows(2).all(|w| w[0] == w[1]), "{rounds:?}");
    for r in &res.reports {
        assert_ne!(r.cause, TerminationCause::Crashed);
    }
}

#[test]
fn weight_by_samples_changes_aggregation() {
    let trainer = MockTrainer::tiny();
    let mut a = base_cfg(3, 91);
    a.partition = Partition::Dirichlet(0.3);
    a.protocol.max_rounds = 6;
    a.protocol.conv_threshold_rel = 0.0;
    let res_plain = sim::run(&trainer, &a).unwrap();
    let mut b = a.clone();
    b.protocol.weight_by_samples = true;
    let res_weighted = sim::run(&trainer, &b).unwrap();
    // Different aggregation weights must produce different final models.
    let pa = res_plain.reports[0].final_params.as_ref().unwrap();
    let pb = res_weighted.reports[0].final_params.as_ref().unwrap();
    assert_ne!(pa, pb);
}
