//! Steady-state allocation budget (DESIGN.md §14, `alloc-audit` feature).
//!
//! The hot round loop — train, broadcast, decode, stash, aggregate,
//! evaluate, CCC — is supposed to run out of pooled buffers and reusable
//! scratch, touching the global allocator only a constant number of times
//! per client-round (the encoded broadcast's `Arc<[u8]>`, the aggregation
//! row list, and amortized history growth).  This suite pins that: it runs
//! the same 200-client deployment twice, identical except for the round
//! count, and asserts the *marginal* allocations of the extra rounds stay
//! under a small per-client-round budget.  Differencing two runs cancels
//! everything that is not steady state — dataset synthesis, topology
//! construction, executor spin-up, and the first-round pool warm-up, which
//! both runs pay equally.
//!
//! Run with:
//!
//! ```sh
//! cargo test -q --release --features alloc-audit --test alloc_budget
//! ```
#![cfg(feature = "alloc-audit")]

use std::time::Duration;

use dfl::coordinator::{ProtocolConfig, QuorumSpec};
use dfl::metrics::AllocStats;
use dfl::net::{CodecSpec, NetworkModel, TopologySpec};
use dfl::runtime::{AggregationRule, MockTrainer, Trainer};
use dfl::sim::{self, ExecMode, SimConfig};

const CLIENTS: usize = 200;

/// Steady-state allocator acquisitions allowed per client-round.
const BUDGET: f64 = 4.0;

/// A fixed-length deployment: `min_rounds == max_rounds` keeps adaptive
/// termination from firing, so every client completes exactly `rounds`
/// rounds and the two measurement runs differ in nothing else.
fn fixed_length_cfg(rounds: u32, exec: ExecMode) -> SimConfig {
    let trainer = MockTrainer::tiny();
    let mut cfg = SimConfig::for_meta(CLIENTS, trainer.meta());
    cfg.protocol = ProtocolConfig {
        timeout: Duration::from_millis(80),
        min_rounds: rounds,
        count_threshold: 2,
        conv_threshold_rel: 0.12,
        max_rounds: rounds,
        lr: 0.08,
        model_seed: 42,
        weight_by_samples: false,
        early_window_exit: true,
        crt_enabled: true,
        quorum: QuorumSpec::STRICT,
        agg: AggregationRule::FedAvg,
        codec: CodecSpec::Dense,
    };
    cfg.train_n = 20 * CLIENTS;
    cfg.seed = 7;
    cfg.virtual_time = true;
    cfg.train_cost = Duration::from_millis(5);
    cfg.topology = TopologySpec::parse("k-regular:6").expect("k-regular overlay");
    cfg.net = NetworkModel::preset("ideal", 7).expect("ideal net preset");
    cfg.exec = exec;
    cfg
}

/// Allocator acquisitions across one full deployment.
fn allocs_for(rounds: u32, exec: ExecMode) -> u64 {
    let trainer = MockTrainer::tiny();
    let cfg = fixed_length_cfg(rounds, exec);
    let before = AllocStats::snapshot();
    let out = sim::run(&trainer, &cfg).expect("budget deployment must complete");
    let after = AllocStats::snapshot();
    assert_eq!(out.reports.len(), CLIENTS);
    for r in &out.reports {
        assert_eq!(
            r.rounds_completed, rounds,
            "client {} exited early — the two runs are no longer comparable",
            r.id
        );
    }
    before.allocs_since(&after)
}

/// One test (not one per executor) so the process-global counters are
/// never read by two measurements at once.
#[test]
fn steady_state_allocations_per_client_round_stay_under_budget() {
    assert!(AllocStats::enabled(), "suite requires --features alloc-audit");
    const R_SHORT: u32 = 6;
    const R_LONG: u32 = 12;
    for exec in [ExecMode::Events, ExecMode::Parallel { shards: 2 }] {
        let short = allocs_for(R_SHORT, exec);
        let long = allocs_for(R_LONG, exec);
        let extra_rounds = (R_LONG - R_SHORT) as f64 * CLIENTS as f64;
        let per_client_round = long.saturating_sub(short) as f64 / extra_rounds;
        assert!(
            per_client_round <= BUDGET,
            "{exec:?}: {per_client_round:.2} allocations per client-round \
             (runs: {short} vs {long}) exceeds the steady-state budget of {BUDGET}"
        );
    }
}
