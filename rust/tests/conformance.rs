//! Cross-executor conformance: the sharded parallel executor must be a
//! byte-exact drop-in for the single-threaded events reference (DESIGN.md
//! §12) — same `ClientReport` fingerprints, same `NetStats` totals, same
//! virtual wall — across seeds, overlays, network presets, and fault /
//! adversary scenarios.
//!
//! The quick (non-ignored) tests cover the full clean matrix plus a
//! diagonal of each fault scenario; the `#[ignore]` test is the full
//! three-executor product that `scripts/tier1.sh` runs as its
//! executor-matrix leg (skipped under `--fast`).

mod common;

use std::time::Duration;

use common::fingerprint;
use dfl::coordinator::fault::{AdversarySpec, GraphFault};
use dfl::coordinator::{ProtocolConfig, QuorumSpec};
use dfl::net::{CodecSpec, NetworkModel, TopologySpec};
use dfl::runtime::{AggregationRule, MockTrainer, Trainer};
use dfl::sim::{self, ExecMode, SimConfig};

/// Every overlay shape the simulator supports, as the CLI spells them.
const TOPOLOGIES: [&str; 4] = ["full", "ring:2", "k-regular:6", "small-world:4:0.1"];

/// The zero-lookahead preset (parallel must collapse to one shard) and the
/// nastiest lossy one (correlated bursts over LAN latency).
const NETS: [&str; 2] = ["ideal", "lossy-burst"];

const SEEDS: [u64; 8] = [11, 22, 33, 44, 55, 66, 77, 88];

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Scenario {
    /// No faults, no adversaries: the pure protocol.
    Clean,
    /// A min-cut edge outage window plus one churning client.
    CutChurn,
    /// A −10× poisoner held off by trimmed-mean aggregation.
    Poison,
}

const SCENARIOS: [Scenario; 3] = [Scenario::Clean, Scenario::CutChurn, Scenario::Poison];

/// One deployment cell of the conformance matrix: 8 clients, adaptive
/// termination capped low enough that every cell stays cheap.
fn cell_cfg(seed: u64, topo: &str, net: &str, scenario: Scenario) -> SimConfig {
    let trainer = MockTrainer::tiny();
    let mut cfg = SimConfig::for_meta(8, trainer.meta());
    cfg.protocol = ProtocolConfig {
        timeout: Duration::from_millis(80),
        min_rounds: 4,
        count_threshold: 2,
        conv_threshold_rel: 0.12,
        max_rounds: 12,
        lr: 0.08,
        model_seed: 42,
        weight_by_samples: false,
        early_window_exit: true,
        crt_enabled: true,
        quorum: QuorumSpec::STRICT,
        agg: AggregationRule::FedAvg,
        codec: CodecSpec::Dense,
    };
    cfg.train_n = 60 * 8;
    cfg.seed = seed;
    cfg.virtual_time = true;
    cfg.train_cost = Duration::from_millis(5);
    cfg.topology = TopologySpec::parse(topo).expect("matrix topology");
    cfg.net = NetworkModel::preset(net, seed).expect("matrix net preset");
    match scenario {
        Scenario::Clean => {}
        Scenario::CutChurn => {
            cfg.graph_faults = vec![
                GraphFault::parse("graph-cut:0.15-0.45:mincut").unwrap(),
                GraphFault::parse("churn:4:0.12-0.4").unwrap(),
            ];
        }
        Scenario::Poison => {
            cfg.adversaries = vec![AdversarySpec::parse("poison:-10:C2").unwrap()];
            cfg.protocol.agg = AggregationRule::parse("trimmed-mean:1").unwrap();
        }
    }
    cfg
}

/// Run one cell under `exec`, digesting everything the acceptance
/// criterion covers: per-client fingerprints, traffic totals, the wall.
fn run_cell(cfg: &SimConfig, exec: ExecMode) -> (Vec<u64>, dfl::metrics::NetStats, Duration) {
    let trainer = MockTrainer::tiny();
    let mut cfg = cfg.clone();
    cfg.exec = exec;
    let out = sim::run(&trainer, &cfg).expect("conformance cell must complete");
    let prints: Vec<u64> = out.reports.iter().map(fingerprint).collect();
    (prints, out.net, out.wall)
}

/// Assert two executors agree on a cell, naming the cell on failure.
fn assert_identical(cfg: &SimConfig, reference: ExecMode, candidate: ExecMode, cell: &str) {
    let (fe, ne, we) = run_cell(cfg, reference);
    let (fc, nc, wc) = run_cell(cfg, candidate);
    assert_eq!(fe, fc, "fingerprints diverged [{cell}] {candidate:?} vs {reference:?}");
    assert_eq!(ne, nc, "NetStats diverged [{cell}] {candidate:?} vs {reference:?}");
    assert_eq!(we, wc, "virtual wall diverged [{cell}] {candidate:?} vs {reference:?}");
}

/// The full clean matrix: every seed × overlay × net, `parallel:3` against
/// the events reference.  The `ideal` column exercises the zero-lookahead
/// collapse to one shard; `lossy-burst` exercises real cross-shard windows.
#[test]
fn parallel_matches_events_on_the_clean_matrix() {
    for &seed in &SEEDS {
        for topo in TOPOLOGIES {
            for net in NETS {
                let cfg = cell_cfg(seed, topo, net, Scenario::Clean);
                let cell = format!("seed {seed}, {topo}, {net}, clean");
                assert_identical(&cfg, ExecMode::Events, ExecMode::Parallel { shards: 3 }, &cell);
            }
        }
    }
}

/// Graph cuts + churn across a diagonal of the matrix (every seed, cycling
/// overlay and net so each appears at least twice).  The dynamic-overlay
/// snapshot path, severed-edge drops, and rejoin regeneration must all
/// stay byte-identical when queried from shard threads.
#[test]
fn parallel_matches_events_under_graph_cut_and_churn() {
    for (i, &seed) in SEEDS.iter().enumerate() {
        let topo = TOPOLOGIES[i % TOPOLOGIES.len()];
        let net = NETS[i % NETS.len()];
        let cfg = cell_cfg(seed, topo, net, Scenario::CutChurn);
        let cell = format!("seed {seed}, {topo}, {net}, cut+churn");
        assert_identical(&cfg, ExecMode::Events, ExecMode::Parallel { shards: 3 }, &cell);
    }
}

/// Poison + trimmed-mean across the same diagonal: the adversary branch
/// perturbs payload bytes and the robust rule reorders aggregation — both
/// must be invariant to which shard hosts the poisoner.
#[test]
fn parallel_matches_events_under_poison_and_trimmed_mean() {
    for (i, &seed) in SEEDS.iter().enumerate() {
        let topo = TOPOLOGIES[i % TOPOLOGIES.len()];
        let net = NETS[i % NETS.len()];
        let cfg = cell_cfg(seed, topo, net, Scenario::Poison);
        let cell = format!("seed {seed}, {topo}, {net}, poison");
        assert_identical(&cfg, ExecMode::Events, ExecMode::Parallel { shards: 3 }, &cell);
    }
}

/// Delta-codec cells (DESIGN.md §13) across the same diagonal: per-link
/// Tx/Rx shadow state, ack piggybacking, and the compact flag relay must
/// be invariant to which shard hosts each endpoint of a link — drops and
/// the resulting `need_full` resyncs replay identically from shard threads.
#[test]
fn parallel_matches_events_under_the_delta_codec() {
    for (i, &seed) in SEEDS.iter().enumerate() {
        let topo = TOPOLOGIES[i % TOPOLOGIES.len()];
        let net = NETS[i % NETS.len()];
        let mut cfg = cell_cfg(seed, topo, net, Scenario::Clean);
        cfg.protocol.codec = CodecSpec::Delta { k: 32, q16: false };
        let cell = format!("seed {seed}, {topo}, {net}, clean, delta:32");
        assert_identical(&cfg, ExecMode::Events, ExecMode::Parallel { shards: 3 }, &cell);
    }
}

/// Shard count must never matter: 1 (degenerate fast path), 2, 5, and 16
/// (more shards than clients — clamped to singletons) all reproduce the
/// reference on the hardest cell we have.
#[test]
fn every_shard_count_reproduces_the_reference() {
    let cfg = cell_cfg(77, "small-world:4:0.1", "lossy-burst", Scenario::CutChurn);
    for shards in [1usize, 2, 5, 16] {
        let cell = format!("seed 77, small-world:4:0.1, lossy-burst, cut+churn, shards {shards}");
        assert_identical(&cfg, ExecMode::Events, ExecMode::Parallel { shards }, &cell);
    }
}

/// `parallel` is itself deterministic run-to-run (not merely equal to the
/// reference once): repeated runs of the same cell fingerprint identically.
#[test]
fn parallel_is_reproducible_run_to_run() {
    let cfg = cell_cfg(44, "k-regular:6", "lossy-burst", Scenario::Poison);
    let a = run_cell(&cfg, ExecMode::Parallel { shards: 4 });
    let b = run_cell(&cfg, ExecMode::Parallel { shards: 4 });
    assert_eq!(a, b, "parallel executor must be bit-reproducible");
}

/// The full three-executor product — every seed × overlay × net ×
/// scenario under `events`, `threads`, and `parallel:3` — is the
/// executor-matrix leg of `scripts/tier1.sh` (skipped by `--fast`):
///
/// ```sh
/// cargo test -q --release --test conformance -- --ignored
/// ```
#[test]
#[ignore = "full executor matrix (minutes); run by scripts/tier1.sh"]
fn full_three_executor_matrix_is_byte_identical() {
    for scenario in SCENARIOS {
        for &seed in &SEEDS {
            for topo in TOPOLOGIES {
                for net in NETS {
                    let cfg = cell_cfg(seed, topo, net, scenario);
                    let cell = format!("seed {seed}, {topo}, {net}, {scenario:?}");
                    assert_identical(&cfg, ExecMode::Events, ExecMode::Threads, &cell);
                    assert_identical(
                        &cfg,
                        ExecMode::Events,
                        ExecMode::Parallel { shards: 3 },
                        &cell,
                    );
                }
            }
        }
    }
}

/// Delta-codec diagonal across all three executors — every seed, cycling
/// overlay × net × scenario and alternating u16 quantization — the
/// delta-codec leg of `scripts/tier1.sh` (skipped by `--fast`):
///
/// ```sh
/// cargo test -q --release --test conformance -- --ignored
/// ```
#[test]
#[ignore = "delta-codec executor diagonal (minutes); run by scripts/tier1.sh"]
fn delta_codec_diagonal_is_byte_identical_across_executors() {
    for (i, &seed) in SEEDS.iter().enumerate() {
        let topo = TOPOLOGIES[i % TOPOLOGIES.len()];
        let net = NETS[i % NETS.len()];
        let scenario = SCENARIOS[i % SCENARIOS.len()];
        let mut cfg = cell_cfg(seed, topo, net, scenario);
        cfg.protocol.codec = CodecSpec::Delta { k: 32, q16: i % 2 == 1 };
        let cell = format!(
            "seed {seed}, {topo}, {net}, {scenario:?}, delta:32 q16={}",
            i % 2 == 1
        );
        assert_identical(&cfg, ExecMode::Events, ExecMode::Threads, &cell);
        assert_identical(&cfg, ExecMode::Events, ExecMode::Parallel { shards: 3 }, &cell);
    }
}
