//! Delta-codec acceptance (ISSUE 8 / DESIGN.md §13): the wire-efficiency
//! claim, measured.  `delta:64` on the k-regular:6 LAN deployment must cut
//! `NetStats` bytes/round by ≥5× against `dense` while producing the same
//! final-accuracy table, and the codec's savings counters must agree with
//! the story the byte totals tell.
//!
//! The wide mock trainer (32 classes → 1056 params) makes the dense
//! payload dominate framing overhead, so the ratio measures the codec and
//! not the message headers.  A high `min_rounds` floor keeps both runs in
//! the regime where sparse deltas ride an acked base nearly every round —
//! a quick CCC exit after one Full snapshot would measure boot, not
//! steady state.

use std::time::Duration;

use dfl::coordinator::{ProtocolConfig, QuorumSpec};
use dfl::net::{CodecSpec, NetworkModel, TopologySpec};
use dfl::runtime::{AggregationRule, MockTrainer, Trainer};
use dfl::sim::{self, SimConfig};

fn codec_cfg(trainer: &MockTrainer, codec: CodecSpec) -> SimConfig {
    let n = 8;
    let seed = 4242u64;
    let mut cfg = SimConfig::for_meta(n, trainer.meta());
    cfg.protocol = ProtocolConfig {
        timeout: Duration::from_millis(80),
        // Hold both runs to ≥14 rounds: the steady-state regime where
        // every delta-mode send after the first Full rides a sparse body.
        min_rounds: 14,
        count_threshold: 2,
        conv_threshold_rel: 0.12,
        max_rounds: 16,
        lr: 0.08,
        model_seed: 42,
        weight_by_samples: false,
        early_window_exit: true,
        crt_enabled: true,
        quorum: QuorumSpec::STRICT,
        agg: AggregationRule::FedAvg,
        codec,
    };
    cfg.train_n = 60 * n;
    cfg.net = NetworkModel::lan(seed);
    cfg.topology = TopologySpec::KRegular { d: 6 };
    cfg.seed = seed;
    cfg.virtual_time = true;
    cfg.train_cost = Duration::from_millis(5);
    cfg
}

/// Final-accuracy table at the precision every experiment table prints
/// (2 decimal places of percent), per client in id order.
fn accuracy_table(res: &dfl::sim::SimResult) -> Vec<String> {
    res.reports
        .iter()
        .map(|r| match r.final_accuracy {
            Some(a) => format!("{:.2}", a * 100.0),
            None => "-".into(),
        })
        .collect()
}

#[test]
fn delta64_cuts_bytes_per_round_5x_on_k_regular_lan() {
    let trainer = MockTrainer::wide_with_k_max(16);

    let dense = sim::run(&trainer, &codec_cfg(&trainer, CodecSpec::Dense))
        .expect("dense run");
    let delta = sim::run(
        &trainer,
        &codec_cfg(&trainer, CodecSpec::Delta { k: 64, q16: false }),
    )
    .expect("delta run");

    // Learning quality survives the sparse exchange: same accuracy table.
    assert_eq!(
        accuracy_table(&dense),
        accuracy_table(&delta),
        "delta:64 changed the final-accuracy table"
    );

    // The headline claim: ≥5× fewer bytes per round on the wire.
    let dense_bpr = dense.net.bytes_per_round(dense.rounds());
    let delta_bpr = delta.net.bytes_per_round(delta.rounds());
    assert!(
        dense_bpr >= 5.0 * delta_bpr,
        "delta:64 saved only {:.1}x (dense {dense_bpr:.0} B/round, \
         delta {delta_bpr:.0} B/round)",
        dense_bpr / delta_bpr
    );

    // The savings counters must corroborate the byte totals: dense runs
    // never touch them, delta runs mostly ride sparse bodies.
    assert_eq!(dense.net.bytes_saved, 0, "dense run booked codec savings");
    assert_eq!(dense.net.delta_hit_rate(), 0.0, "dense run booked codec hits");
    assert!(delta.net.bytes_saved > 0, "delta run saved no bytes");
    assert!(
        delta.net.delta_hit_rate() > 0.5,
        "full-snapshot fallback dominated a lossless LAN run: hit rate {:.2}",
        delta.net.delta_hit_rate()
    );
    assert!(
        delta.net.bytes_sent + delta.net.bytes_saved >= dense.net.bytes_sent,
        "savings accounting lost bytes: {} sent + {} saved < {} dense",
        delta.net.bytes_sent,
        delta.net.bytes_saved,
        dense.net.bytes_sent
    );
}

/// Same deployment, same seed, run twice under delta:64 — the per-link
/// Tx/Rx shadow state is part of the determinism contract.
#[test]
fn delta_runs_are_seed_deterministic() {
    let trainer = MockTrainer::wide_with_k_max(16);
    let cfg = codec_cfg(&trainer, CodecSpec::Delta { k: 64, q16: false });
    let a = sim::run(&trainer, &cfg).expect("first run");
    let b = sim::run(&trainer, &cfg).expect("second run");
    assert_eq!(accuracy_table(&a), accuracy_table(&b));
    assert_eq!(a.net, b.net, "NetStats must reproduce under one seed");
    assert_eq!(a.wall, b.wall, "virtual wall must reproduce under one seed");
}
