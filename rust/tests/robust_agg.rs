//! Aggregation-path acceptance tests for the Byzantine repertoire
//! (DESIGN.md §11): every robust rule must agree with FedAvg on honest
//! inputs, the `fedavg` default must stay byte-identical to the plain
//! trainer path, and adversarial deployments must stay deterministic
//! across both virtual-time executors.

mod common;

use std::time::Duration;

use common::fingerprint;
use dfl::coordinator::fault::{AdversaryKind, AdversarySpec};
use dfl::coordinator::{ProtocolConfig, QuorumSpec};
use dfl::net::{CodecSpec, NetworkModel, TopologySpec};
use dfl::runtime::{AggregationRule, MockTrainer, Trainer};
use dfl::sim::{self, ExecMode, SimConfig};
use dfl::util::quickcheck::forall;
use dfl::util::Rng;

fn base_cfg(n: usize, seed: u64) -> SimConfig {
    let trainer = MockTrainer::tiny();
    let mut cfg = SimConfig::for_meta(n, trainer.meta());
    cfg.protocol = ProtocolConfig {
        timeout: Duration::from_millis(80),
        min_rounds: 4,
        count_threshold: 2,
        conv_threshold_rel: 0.12,
        max_rounds: 30,
        lr: 0.08,
        model_seed: 42,
        weight_by_samples: false,
        early_window_exit: true,
        crt_enabled: true,
        quorum: QuorumSpec::STRICT,
        agg: AggregationRule::FedAvg,
        codec: CodecSpec::Dense,
    };
    cfg.train_n = 60 * n;
    cfg.net = NetworkModel::lan(seed);
    cfg.seed = seed;
    cfg.virtual_time = true;
    cfg.train_cost = Duration::from_millis(5);
    cfg
}

fn poison(clients: Vec<u32>) -> Vec<AdversarySpec> {
    vec![AdversarySpec { kind: AdversaryKind::Poison { scale: -10.0 }, clients }]
}

/// Satellite 4a, exact half: when every honest row is the *same* vector,
/// order statistics have nothing to trim, the median is that vector, and
/// Krum returns it — all four rules must equal FedAvg to the bit.
#[test]
fn every_rule_equals_fedavg_on_identical_honest_rows() {
    let trainer = MockTrainer::tiny();
    let n_params = trainer.meta().n_params;
    let mut rng = Rng::new(0xA66);
    let row: Vec<f32> = (0..n_params).map(|_| rng.normal()).collect();
    let rows: Vec<(&[f32], f32)> = (0..5).map(|_| (row.as_slice(), 1.0)).collect();
    let want = trainer.aggregate_with(&rows, &AggregationRule::FedAvg).unwrap();
    for rule in [
        AggregationRule::TrimmedMean { f: 1 },
        AggregationRule::CoordMedian,
        AggregationRule::Krum { f: 1 },
    ] {
        let got = trainer.aggregate_with(&rows, &rule).unwrap();
        assert_eq!(
            got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "{rule:?} must equal FedAvg bit-for-bit on identical rows"
        );
    }
}

/// Satellite 4a, tolerance half: on all-honest equal-weight inputs every
/// robust rule stays inside the per-coordinate [min, max] envelope of the
/// rows, hence within one coordinate-spread of the FedAvg mean.  (This is
/// the strongest rule-agnostic bound: trimmed mean and median are order
/// statistics, Krum returns one of the rows.)
#[test]
fn robust_rules_track_fedavg_within_the_honest_envelope() {
    let trainer = MockTrainer::tiny();
    let n_params = trainer.meta().n_params;
    forall(
        0xB1Fu64,
        20,
        |r| {
            let k = 3 + r.below(6); // 3..=8 rows
            let base: Vec<f32> = (0..n_params).map(|_| r.normal()).collect();
            (0..k)
                .map(|_| base.iter().map(|v| v + r.normal() * 0.05).collect::<Vec<f32>>())
                .collect::<Vec<_>>()
        },
        |rows| {
            let borrowed: Vec<(&[f32], f32)> =
                rows.iter().map(|p| (p.as_slice(), 1.0)).collect();
            let trainer = MockTrainer::tiny();
            let mean = trainer
                .aggregate_with(&borrowed, &AggregationRule::FedAvg)
                .map_err(|e| e.to_string())?;
            for rule in [
                AggregationRule::TrimmedMean { f: 1 },
                AggregationRule::CoordMedian,
                AggregationRule::Krum { f: 1 },
            ] {
                let out = trainer
                    .aggregate_with(&borrowed, &rule)
                    .map_err(|e| e.to_string())?;
                for c in 0..n_params {
                    let lo = rows.iter().map(|p| p[c]).fold(f32::INFINITY, f32::min);
                    let hi = rows.iter().map(|p| p[c]).fold(f32::NEG_INFINITY, f32::max);
                    let spread = hi - lo;
                    if out[c] < lo - 1e-5 || out[c] > hi + 1e-5 {
                        return Err(format!(
                            "{rule:?} coord {c}: {} outside honest envelope [{lo}, {hi}]",
                            out[c]
                        ));
                    }
                    if (out[c] - mean[c]).abs() > spread + 1e-5 {
                        return Err(format!(
                            "{rule:?} coord {c}: {} drifts more than the spread {spread} \
                             from FedAvg {}",
                            out[c], mean[c]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Satellite 4b: the `fedavg` rule is a pure delegation — same bits as
/// calling the trainer's own weighted average, weights included.  This is
/// the in-process pin that rule plumbing left the default path untouched.
#[test]
fn fedavg_rule_delegates_byte_identically_to_the_trainer() {
    let trainer = MockTrainer::tiny();
    let n_params = trainer.meta().n_params;
    let mut rng = Rng::new(0xFEDA);
    for _ in 0..10 {
        let k = 1 + rng.below(8);
        let rows: Vec<(Vec<f32>, f32)> = (0..k)
            .map(|_| {
                let p: Vec<f32> = (0..n_params).map(|_| rng.normal()).collect();
                (p, 0.5 + rng.f32() * 10.0)
            })
            .collect();
        let borrowed: Vec<(&[f32], f32)> =
            rows.iter().map(|(p, w)| (p.as_slice(), *w)).collect();
        let direct = trainer.aggregate(&borrowed).unwrap();
        let via_rule = trainer.aggregate_with(&borrowed, &AggregationRule::FedAvg).unwrap();
        assert_eq!(
            direct.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            via_rule.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        );
    }
}

/// Satellite 4b at deployment scope: a clean fedavg run is byte-identical
/// across both executors — the executor-identity acceptance criterion
/// survives the aggregation-rule threading.
#[test]
fn clean_fedavg_run_is_byte_identical_across_executors() {
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(5, 4242);
    cfg.exec = ExecMode::Events;
    let ev = sim::run(&trainer, &cfg).unwrap();
    cfg.exec = ExecMode::Threads;
    let th = sim::run(&trainer, &cfg).unwrap();
    let fe: Vec<u64> = ev.reports.iter().map(fingerprint).collect();
    let ft: Vec<u64> = th.reports.iter().map(fingerprint).collect();
    assert_eq!(fe, ft, "fedavg default must stay executor-byte-identical");
}

/// A poisoning adversary must actually perturb the deployment: same seed,
/// same config, one client flipped to `poison:-10` ⇒ different report
/// fingerprints.  (Guards against the adversary branch silently sending
/// the honest model.)
#[test]
fn poison_adversary_changes_the_run_fingerprint() {
    let trainer = MockTrainer::tiny();
    let clean_cfg = base_cfg(6, 777);
    let clean = sim::run(&trainer, &clean_cfg).unwrap();
    let mut attacked_cfg = base_cfg(6, 777);
    attacked_cfg.adversaries = poison(vec![2]);
    let attacked = sim::run(&trainer, &attacked_cfg).unwrap();
    let fc: Vec<u64> = clean.reports.iter().map(fingerprint).collect();
    let fa: Vec<u64> = attacked.reports.iter().map(fingerprint).collect();
    assert_ne!(fc, fa, "a -10x poisoner must not leave the run untouched");
}

/// Adversarial deployments stay deterministic: poison + trimmed-mean on a
/// sparse overlay produces byte-identical reports under both executors.
#[test]
fn adversary_and_robust_rule_are_byte_identical_across_executors() {
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(8, 909);
    cfg.topology = TopologySpec::KRegular { d: 4 };
    cfg.protocol.agg = AggregationRule::parse("trimmed-mean:1").unwrap();
    cfg.adversaries = poison(vec![2, 5]);
    cfg.exec = ExecMode::Events;
    let ev = sim::run(&trainer, &cfg).unwrap();
    cfg.exec = ExecMode::Threads;
    let th = sim::run(&trainer, &cfg).unwrap();
    let fe: Vec<u64> = ev.reports.iter().map(fingerprint).collect();
    let ft: Vec<u64> = th.reports.iter().map(fingerprint).collect();
    assert_eq!(fe, ft, "adversary paths must be executor-byte-identical");
}

/// Adversaries are a Phase-2 construct: the sync barrier assumes a
/// fault-free system, so `--sync` + `--adversary` must be rejected at
/// validation, not silently ignored.
#[test]
fn sync_phase_rejects_adversaries() {
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(4, 11);
    cfg.sync = true;
    cfg.adversaries = poison(vec![1]);
    let err = sim::run(&trainer, &cfg).err().expect("sync + adversaries must fail");
    assert!(err.to_string().contains("Phase"), "{err}");
}

/// Role compilation is part of `sim::run` validation: an adversary id
/// outside the client range fails loudly at setup.
#[test]
fn out_of_range_adversary_is_rejected() {
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(4, 12);
    cfg.adversaries = poison(vec![9]);
    let err = sim::run(&trainer, &cfg).err().expect("id 9 of 4 clients must fail");
    assert!(err.to_string().contains('9'), "{err}");
}
