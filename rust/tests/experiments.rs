//! Experiment-harness tests: structural integrity of every table driver
//! (right columns/rows, parseable cells) on the fast MockTrainer, plus the
//! cheap paper-shape assertions that are stable at mock scale.
//!
//! All drivers run under the deterministic virtual clock
//! (`ExpScale::for_mock` defaults), so this file also pins the harness's
//! two virtual-time contracts: same seed ⇒ byte-identical tables, and no
//! driver ever sleeps a wall-clock timeout window.

use std::time::{Duration, Instant};

use dfl::exp::{self, ExpScale};
use dfl::runtime::{MockTrainer, Trainer};

fn scale() -> ExpScale {
    ExpScale::for_mock(9)
}

fn parse_pct(cell: &str) -> f32 {
    cell.parse::<f32>().unwrap_or_else(|_| panic!("bad pct cell {cell:?}"))
}

#[test]
fn table2_structure_and_ordering() {
    let t = MockTrainer::tiny();
    let table = exp::table2(&t, scale());
    let md = table.markdown();
    let rows: Vec<&str> = md.lines().skip(2).collect();
    assert_eq!(rows.len(), 3, "table2 must have 3 scenarios:\n{md}");
    // every accuracy parses and is a valid percentage
    for row in &rows {
        let cells: Vec<&str> = row.trim_matches('|').split('|').map(str::trim).collect();
        let acc = parse_pct(cells[1]);
        assert!((0.0..=100.0).contains(&acc), "{row}");
    }
}

#[test]
fn phase1_tables_structure() {
    let t = MockTrainer::tiny();
    for table in [exp::table3(&t, scale()), exp::table4(&t, scale())] {
        let md = table.markdown();
        let rows: Vec<&str> = md.lines().skip(2).collect();
        assert_eq!(rows.len(), 3, "quick phase1 tables have 3 client counts:\n{md}");
        for row in rows {
            let cells: Vec<&str> = row.trim_matches('|').split('|').map(str::trim).collect();
            assert_eq!(cells.len(), 5);
            let acc = parse_pct(cells[2]);
            assert!((0.0..=100.0).contains(&acc));
            assert!(cells[3].parse::<f32>().unwrap() >= 0.0); // M1 time
        }
    }
}

#[test]
fn fig3_4_has_machine_sweeps_and_survivor_accounting() {
    let t = MockTrainer::tiny();
    let table = exp::fig3_4(&t, scale());
    let md = table.markdown();
    let rows: Vec<&str> = md.lines().skip(2).collect();
    assert_eq!(rows.len(), 4 * 2, "quick: 4 fault levels x 2 machine setups:\n{md}");
    for row in rows {
        let cells: Vec<&str> = row.trim_matches('|').split('|').map(str::trim).collect();
        let faults: usize = cells[0].parse().unwrap();
        let survivors: usize = cells[5].parse().unwrap();
        assert!(
            survivors >= 12 - faults,
            "more crashes than scheduled: faults={faults} survivors={survivors}"
        );
        assert!(survivors >= 1);
    }
}

#[test]
fn fig5_6_has_baseline_rows() {
    let t = MockTrainer::tiny();
    let table = exp::fig5_6(&t, scale());
    let md = table.markdown();
    assert!(md.contains("baseline(2n/3)"), "missing baseline rows:\n{md}");
    // every client count contributes 1 baseline + machine-setup rows
    let baselines = md.matches("baseline(2n/3)").count();
    assert_eq!(baselines, 2, "quick mode sweeps 2 client counts");
}

#[test]
fn fig7_8_survivor_beats_chance() {
    let t = MockTrainer::tiny();
    let table = exp::fig7_8(&t, scale());
    let md = table.markdown();
    let rows: Vec<&str> = md.lines().skip(2).collect();
    assert_eq!(rows.len(), 2);
    for row in rows {
        let cells: Vec<&str> = row.trim_matches('|').split('|').map(str::trim).collect();
        let n: usize = cells[0].parse().unwrap();
        let faults: usize = cells[1].parse().unwrap();
        assert_eq!(faults, n - 1, "exp3 must crash n-1");
        let acc = parse_pct(cells[2]);
        // the mock learns fast; the survivor must at least beat chance
        assert!(acc > 10.0, "survivor at/below chance: {acc}");
    }
}

#[test]
fn termination_reliability_is_total_under_quick_faults() {
    let t = MockTrainer::tiny();
    let table = exp::termination_reliability(&t, scale());
    let md = table.markdown();
    let rows: Vec<&str> = md.lines().skip(2).collect();
    assert_eq!(rows.len(), 2);
    for row in rows {
        let cells: Vec<&str> = row.trim_matches('|').split('|').map(str::trim).collect();
        let adaptive = parse_pct(cells[1]);
        let premature: usize = cells[5].parse().unwrap();
        assert_eq!(premature, 0, "premature termination detected: {row}");
        assert!(adaptive >= 99.0, "adaptive termination below 100%: {row}");
    }
}

#[test]
fn scenario_matrix_sweeps_every_preset_and_is_deterministic() {
    let t = MockTrainer::tiny();
    let table = exp::scenarios(&t, scale());
    let md = table.markdown();
    let rows: Vec<&str> = md.lines().skip(2).collect();
    assert!(rows.len() >= 6, "presets + codec-comparison rows:\n{md}");
    for name in ["ideal", "lan", "wan", "asym", "lossy-burst"] {
        assert!(md.contains(name), "missing preset {name}:\n{md}");
    }
    let cells_of = |row: &str| -> Vec<String> {
        row.trim_matches('|').split('|').map(|c| c.trim().to_string()).collect()
    };
    for row in &rows {
        let cells = cells_of(row);
        assert_eq!(cells.len(), 8, "{row}");
        let acc = parse_pct(&cells[1]);
        assert!((0.0..=100.0).contains(&acc), "{row}");
        assert!(cells[3].parse::<f32>().unwrap() >= 0.0, "virtual time: {row}");
        cells[5].parse::<usize>().expect("false-suspicion count");
        assert!(cells[7].parse::<f64>().unwrap() > 0.0, "kB/round: {row}");
    }
    // the ideal row is fault- and latency-free: nothing can look crashed,
    // and every client must end adaptively
    let ideal = rows.iter().find(|r| cells_of(r)[0] == "ideal").unwrap();
    let cells = cells_of(ideal);
    assert_eq!(cells[5], "0", "false suspicions on an ideal network: {ideal}");
    assert_eq!(parse_pct(&cells[4]), 100.0, "non-adaptive ending on ideal: {ideal}");
    // codec-comparison rows (DESIGN.md §13): the delta:64 re-runs of the
    // two heaviest presets must put measurably fewer bytes on the wire
    // than their dense counterparts
    for preset in ["wan", "lossy-burst"] {
        let dense = rows.iter().find(|r| cells_of(r)[0] == preset).unwrap();
        let delta = rows
            .iter()
            .find(|r| cells_of(r)[0] == format!("{preset}+delta:64"))
            .unwrap_or_else(|| panic!("missing {preset}+delta:64 row:\n{md}"));
        assert_eq!(cells_of(delta)[6], "delta:64", "codec column: {delta}");
        let dense_kb: f64 = cells_of(dense)[7].parse().unwrap();
        let delta_kb: f64 = cells_of(delta)[7].parse().unwrap();
        assert!(
            delta_kb < dense_kb,
            "{preset}: delta:64 {delta_kb} kB/round not below dense {dense_kb}"
        );
    }
    // network-only variation: same seed ⇒ the whole table reproduces
    assert_eq!(md, exp::scenarios(&t, scale()).markdown());
}

#[test]
fn topology_sweep_measures_the_message_volume_gap() {
    let t = MockTrainer::tiny();
    let table = exp::topologies(&t, scale());
    let md = table.markdown();
    let rows: Vec<&str> = md.lines().skip(2).collect();
    assert_eq!(rows.len(), 4, "full + 3 sparse overlays:\n{md}");
    for name in ["full", "ring:2", "k-regular:6", "small-world:6:0.1"] {
        assert!(md.contains(name), "missing overlay {name}:\n{md}");
    }
    let cells_of = |row: &str| -> Vec<String> {
        row.trim_matches('|').split('|').map(|c| c.trim().to_string()).collect()
    };
    let mut full_volume = None;
    for row in &rows {
        let cells = cells_of(row);
        assert_eq!(cells.len(), 9, "{row}");
        let degree: usize = cells[1].parse().unwrap();
        let volume: f64 = cells[2].parse().unwrap();
        assert!(volume > 0.0, "empty counter: {row}");
        // default sweep runs the dense codec: the savings columns must
        // read zero (they only move under a `--codec delta:K` override)
        assert_eq!(cells[7].parse::<f64>().unwrap(), 0.0, "dense saved kB: {row}");
        assert_eq!(cells[8], "0", "dense Δ-hit rate: {row}");
        // fault-free LAN: every overlay must still terminate adaptively
        // (on the sparse rows that exercises the CRT relay)
        assert_eq!(parse_pct(&cells[5]), 100.0, "non-adaptive ending: {row}");
        if cells[0] == "full" {
            assert_eq!(degree, 23, "24 clients, mesh degree");
            full_volume = Some(volume);
        } else {
            assert!(degree < 23, "sparse row with mesh degree: {row}");
        }
    }
    // O(n·d) vs O(n²), measured: ring:2 (degree 4) must offer a fraction
    // of the mesh volume per round.
    let full_volume = full_volume.expect("full row present");
    let ring = rows.iter().find(|r| r.contains("ring:2")).unwrap();
    let ring_volume: f64 = cells_of(ring)[2].parse().unwrap();
    assert!(
        ring_volume * 2.0 < full_volume,
        "ring:2 volume {ring_volume} not well under mesh volume {full_volume}"
    );
    // one seed, one sweep: byte-identical regeneration
    assert_eq!(md, exp::topologies(&t, scale()).markdown());
}

#[test]
fn fault_sweep_measures_graph_attacks_and_is_deterministic() {
    let t = MockTrainer::tiny();
    let table = exp::faults(&t, scale());
    let md = table.markdown();
    let rows: Vec<&str> = md.lines().skip(2).collect();
    assert_eq!(rows.len(), 4, "control + 3 graph-fault rows:\n{md}");
    for name in ["none", "edge-cut", "churn", "cut+churn"] {
        assert!(md.contains(name), "missing fault row {name}:\n{md}");
    }
    let cells_of = |row: &str| -> Vec<String> {
        row.trim_matches('|').split('|').map(|c| c.trim().to_string()).collect()
    };
    for row in &rows {
        let cells = cells_of(row);
        assert_eq!(cells.len(), 6, "{row}");
        let severed: u64 = cells[1].parse().unwrap();
        cells[4].parse::<usize>().expect("suspicion count");
        let acc = parse_pct(&cells[5]);
        assert!((0.0..=100.0).contains(&acc), "{row}");
        if cells[0] == "none" {
            assert_eq!(severed, 0, "control row must sever nothing: {row}");
            // fault-free on the auto quorum: nothing can prevent adaptive
            // termination (this is the topologies-sweep situation)
            assert_eq!(parse_pct(&cells[3]), 100.0, "non-adaptive control: {row}");
        } else {
            assert!(severed > 0, "fault row severed no edges: {row}");
        }
    }
    // graph-fault application is part of the determinism contract: same
    // seed ⇒ the whole sweep reproduces byte-for-byte
    assert_eq!(md, exp::faults(&t, scale()).markdown());
}

#[test]
fn byzantine_sweep_pits_rules_against_adversaries_and_is_deterministic() {
    let t = MockTrainer::tiny();
    let table = exp::byzantine(&t, scale());
    let md = table.markdown();
    let rows: Vec<&str> = md.lines().skip(2).collect();
    assert_eq!(rows.len(), 6, "control + 4 attacked + 1 termination row:\n{md}");
    for name in ["fedavg", "trimmed-mean:2", "coord-median", "krum:2"] {
        assert!(md.contains(name), "missing rule row {name}:\n{md}");
    }
    for name in ["none", "poison:-10", "forge-suspicion"] {
        assert!(md.contains(name), "missing adversary column value {name}:\n{md}");
    }
    let cells_of = |row: &str| -> Vec<String> {
        row.trim_matches('|').split('|').map(|c| c.trim().to_string()).collect()
    };
    for row in &rows {
        let cells = cells_of(row);
        assert_eq!(cells.len(), 6, "{row}");
        let advs: usize = cells[2].parse().unwrap();
        cells[4].parse::<u32>().expect("rounds");
        let acc = parse_pct(&cells[5]);
        assert!((0.0..=100.0).contains(&acc), "{row}");
        if cells[1] == "none" {
            assert_eq!(advs, 0, "control row must run all-honest: {row}");
            // all-honest fedavg on the auto quorum: adaptive termination
            // is the topologies-sweep situation and must be total
            assert_eq!(parse_pct(&cells[3]), 100.0, "non-adaptive control: {row}");
        } else {
            // 24 quick-mode clients, every 4th adversarial
            assert_eq!(advs, 6, "attacked rows run a 25% roster: {row}");
        }
    }
    // adversary branches draw only from the adversary's own RNG stream:
    // the whole sweep must regenerate byte-for-byte under one seed
    assert_eq!(md, exp::byzantine(&t, scale()).markdown());
}

#[test]
fn run_all_produces_every_experiment() {
    let t = MockTrainer::tiny();
    let all = exp::run_all(&t, scale());
    assert_eq!(all.len(), 11);
    let titles: Vec<&str> = all.iter().map(|(t, _)| t.as_str()).collect();
    let needles = [
        "Table 2",
        "Table 3",
        "Table 4",
        "Fig 3+4",
        "Fig 5+6",
        "Fig 7+8",
        "Termination",
        "Scenario matrix",
        "Topology sweep",
        "Fault sweep",
        "Byzantine sweep",
    ];
    for needle in needles {
        assert!(titles.iter().any(|t| t.contains(needle)), "missing {needle}");
    }
}

#[test]
fn tables_are_seed_deterministic_and_never_sleep_real_time() {
    // Two full regenerations with 5-second wait windows: under virtual time
    // the windows are logical, so both passes finish in wall-clock seconds
    // and produce byte-identical markdown.  Any real sleep re-introduced
    // into a driver (one crashed-peer detection costs a full window) blows
    // the time budget immediately.
    let t = MockTrainer::tiny();
    let mut s = scale();
    s.timeout_ms = Some(5_000);
    let t0 = Instant::now();
    let a = exp::run_all(&t, s);
    let b = exp::run_all(&t, s);
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_secs(120),
        "virtual-time harness burned {elapsed:?} of wall time — a driver is \
         sleeping through its windows for real"
    );
    assert_eq!(a.len(), b.len());
    for ((title_a, table_a), (title_b, table_b)) in a.iter().zip(&b) {
        assert_eq!(title_a, title_b);
        assert_eq!(
            table_a.markdown(),
            table_b.markdown(),
            "{title_a} is not reproducible under a fixed seed"
        );
    }
}
