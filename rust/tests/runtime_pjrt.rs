//! Runtime integration tests against the real AOT artifacts via PJRT.
//! Require `make artifacts` (skipped gracefully when absent).

use std::path::PathBuf;
use std::sync::OnceLock;

use dfl::model::ParamVector;
use dfl::runtime::{SharedEngine, Trainer};
use dfl::util::Rng;

fn tiny_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
}

/// One engine per test binary (compiles artifacts once).
fn engine() -> Option<&'static SharedEngine> {
    static ENGINE: OnceLock<Option<SharedEngine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            if !tiny_dir().join("meta.txt").exists() {
                eprintln!("artifacts/tiny missing — run `make artifacts`; skipping");
                return None;
            }
            Some(SharedEngine::load(&tiny_dir()).expect("engine load"))
        })
        .as_ref()
}

fn rand_batch(e: &SharedEngine, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
    let m = e.meta();
    let xs = (0..m.train_x_len()).map(|_| rng.normal()).collect();
    let ys = (0..m.train_y_len()).map(|_| rng.below(m.classes) as i32).collect();
    (xs, ys)
}

#[test]
fn init_is_deterministic_and_finite() {
    let Some(e) = engine() else { return };
    let a = e.init(7).unwrap();
    let b = e.init(7).unwrap();
    let c = e.init(8).unwrap();
    assert_eq!(a.len(), e.meta().n_params);
    assert_eq!(a, b);
    assert_ne!(a, c);
    assert!(a.iter().all(|x| x.is_finite()));
}

#[test]
fn train_round_reduces_loss_and_changes_params() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(3);
    let params = e.init(42).unwrap();
    let (xs, ys) = rand_batch(e, &mut rng);
    let (p1, l1) = e.train_round(&params, &xs, &ys, 0.1).unwrap();
    assert_ne!(p1, params);
    assert!(l1.is_finite() && l1 > 0.0);
    // training repeatedly on the same tensors must reduce loss
    let mut p = p1;
    let mut last = l1;
    for _ in 0..5 {
        let (p2, l2) = e.train_round(&p, &xs, &ys, 0.1).unwrap();
        p = p2;
        last = l2;
    }
    assert!(last < l1, "loss did not fall: {l1} -> {last}");
}

#[test]
fn train_is_deterministic() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(5);
    let params = e.init(1).unwrap();
    let (xs, ys) = rand_batch(e, &mut rng);
    let (pa, la) = e.train_round(&params, &xs, &ys, 0.05).unwrap();
    let (pb, lb) = e.train_round(&params, &xs, &ys, 0.05).unwrap();
    assert_eq!(pa, pb);
    assert_eq!(la, lb);
}

#[test]
fn aggregate_matches_cpu_mean() {
    let Some(e) = engine() else { return };
    let a = e.init(1).unwrap();
    let b = e.init(2).unwrap();
    let c = e.init(3).unwrap();
    let out = e.aggregate(&[(&a, 1.0), (&b, 1.0), (&c, 1.0)]).unwrap();
    let cpu = ParamVector::mean_of(&[
        &ParamVector(a.clone()),
        &ParamVector(b.clone()),
        &ParamVector(c.clone()),
    ]);
    let d = ParamVector(out).l2_distance(&cpu) / cpu.l2_norm().max(1.0);
    assert!(d < 1e-5, "pjrt aggregate deviates from cpu mean: rel {d}");
}

#[test]
fn aggregate_weighted_and_single_row() {
    let Some(e) = engine() else { return };
    let a = e.init(4).unwrap();
    let b = e.init(5).unwrap();
    // single row = identity
    let out = e.aggregate(&[(&a, 2.5)]).unwrap();
    let d = ParamVector(out).l2_distance(&ParamVector(a.clone()));
    assert!(d < 1e-4, "single-row aggregate not identity: {d}");
    // 3:1 weighting
    let out = e.aggregate(&[(&a, 3.0), (&b, 1.0)]).unwrap();
    let expect: Vec<f32> =
        a.iter().zip(&b).map(|(x, y)| 0.75 * x + 0.25 * y).collect();
    let d = ParamVector(out).l2_distance(&ParamVector(expect));
    assert!(d < 1e-3, "weighted aggregate wrong: {d}");
}

#[test]
fn eval_counts_are_bounded_and_deterministic() {
    let Some(e) = engine() else { return };
    let m = e.meta().clone();
    let mut rng = Rng::new(9);
    let params = e.init(6).unwrap();
    let xs: Vec<f32> = (0..m.eval_x_len(false)).map(|_| rng.normal()).collect();
    let ys: Vec<i32> = (0..m.eval_y_len(false)).map(|_| rng.below(m.classes) as i32).collect();
    let (c1, l1) = e.eval(&params, &xs, &ys, false).unwrap();
    let (c2, l2) = e.eval(&params, &xs, &ys, false).unwrap();
    assert_eq!((c1, l1.to_bits()), (c2, l2.to_bits()));
    assert!(c1 as usize <= ys.len());
    assert!(l1.is_finite());
}

#[test]
fn shape_validation_errors_cleanly() {
    let Some(e) = engine() else { return };
    let params = e.init(0).unwrap();
    assert!(e.train_round(&params, &[0.0; 3], &[0; 3], 0.1).is_err());
    assert!(e.eval(&params, &[0.0; 7], &[0; 7], false).is_err());
    assert!(e.aggregate(&[]).is_err());
    let short = vec![0.0f32; 3];
    assert!(e.aggregate(&[(&short, 1.0)]).is_err());
}

#[test]
fn engine_learns_synthetic_task_better_than_chance() {
    let Some(e) = engine() else { return };
    let m = e.meta().clone();
    let (train, test) = dfl::data::Dataset::synthetic_pair(&m, 800, m.nb_eval_full * m.batch, 31);
    let (exs, eys) = test.take_flat(m.nb_eval_full * m.batch);
    let mut rng = Rng::new(32);
    let mut params = e.init(42).unwrap();
    let all: Vec<usize> = (0..train.len()).collect();
    for _ in 0..25 {
        let (xs, ys) = train.gather_round(&all, m.nb_train * m.batch, &mut rng);
        let (p, _) = e.train_round(&params, &xs, &ys, 0.12).unwrap();
        params = p;
    }
    let (correct, _) = e.eval(&params, &exs, &eys, true).unwrap();
    let acc = correct as f32 / eys.len() as f32;
    assert!(acc > 0.25, "PJRT training failed to beat chance x2.5: {acc}");
}
