//! Scale tests (`#[ignore]`-gated — run with `cargo test -q -- --ignored`):
//! the paper's §3 termination claims at client counts the paper's 12-client
//! testbed never reached.  Only feasible under the virtual clock: hundreds
//! of cooperatively-scheduled clients share one event loop instead of
//! fighting for OS timeslices through real 80 ms windows.

use std::time::Duration;

use dfl::coordinator::fault::variable_crash_schedule;
use dfl::coordinator::ProtocolConfig;
use dfl::net::NetworkModel;
use dfl::runtime::{MockTrainer, Trainer};
use dfl::sim::{self, SimConfig};
use dfl::util::Rng;

fn scale_cfg(trainer: &MockTrainer, n: usize, seed: u64) -> SimConfig {
    let mut cfg = SimConfig::for_meta(n, trainer.meta());
    cfg.protocol = ProtocolConfig {
        timeout: Duration::from_millis(80),
        min_rounds: 4,
        count_threshold: 2,
        conv_threshold_rel: 0.12,
        max_rounds: 60,
        lr: 0.08,
        model_seed: 42,
        weight_by_samples: false,
        early_window_exit: true,
        crt_enabled: true,
    };
    cfg.train_n = 20 * n;
    cfg.net = NetworkModel::lan(seed);
    cfg.seed = seed;
    cfg.virtual_time = true;
    cfg.train_cost = Duration::from_millis(5);
    cfg
}

/// The acceptance scenario: 200 clients, 30 staggered crashes, 10% message
/// loss — every survivor must still terminate via CCC or CRT.
#[test]
#[ignore = "scale test: ~200 clients, run explicitly with -- --ignored"]
fn two_hundred_clients_with_crashes_and_drops_terminate_adaptively() {
    let n = 200;
    let trainer = MockTrainer::tiny_with_k_max(n + 8);
    let mut cfg = scale_cfg(&trainer, n, 42);
    cfg.net = NetworkModel::lossy(0.10, 42);
    let mut rng = Rng::new(42);
    cfg.faults = variable_crash_schedule(n, 30, 2, 12, &mut rng);
    let res = sim::run(&trainer, &cfg).unwrap();
    assert_eq!(res.reports.len(), n);
    assert_eq!(res.crashed(), 30, "exactly the scheduled crashes");
    assert!(
        res.all_terminated_adaptively(),
        "some survivor hit the round cap or stalled"
    );
    // Every survivor observed a consistent network: it aggregated at least
    // itself each round and finished with a final model.
    for r in &res.reports {
        if r.cause != dfl::coordinator::termination::TerminationCause::Crashed {
            assert!(r.final_accuracy.is_some());
        }
    }
}

/// Stretch: four-digit client count on the lean (66-param) model so the
/// in-flight message volume stays modest.  Fault-free; asserts the
/// protocol's adaptive-termination claim holds at 1000 clients.
#[test]
#[ignore = "scale test: 1000 clients, several minutes of compute"]
fn thousand_clients_terminate_adaptively() {
    let n = 1000;
    let trainer = MockTrainer::lean_with_k_max(n + 8);
    let mut cfg = scale_cfg(&trainer, n, 7);
    cfg.protocol.min_rounds = 3;
    cfg.protocol.max_rounds = 30;
    cfg.train_n = 4 * n;
    let res = sim::run(&trainer, &cfg).unwrap();
    assert_eq!(res.reports.len(), n);
    assert_eq!(res.crashed(), 0);
    assert!(res.all_terminated_adaptively());
}
