//! Scale tests (`#[ignore]`-gated — run with `cargo test -q -- --ignored`):
//! the paper's §3 termination claims at client counts the paper's 12-client
//! testbed never reached.  Only feasible under the virtual clock, and at
//! four-digit counts only on the machine-per-struct executors: the event
//! executor (`ExecMode::Events`) pumps every client as a state machine on
//! one thread, and the sharded executor (`ExecMode::Parallel`) spreads the
//! same machines over S worker threads — either way a 10 000-client
//! deployment costs ten thousand small structs instead of ten thousand OS
//! threads.

mod common;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use common::fingerprint;
use dfl::coordinator::fault::{variable_crash_schedule, GraphFault};
use dfl::coordinator::termination::TerminationCause;
use dfl::coordinator::{ProtocolConfig, QuorumSpec};
use dfl::net::{CodecSpec, NetworkModel, TopologySpec};
use dfl::runtime::{AggregationRule, MockTrainer, Trainer};
use dfl::sim::{self, ExecMode, Partition, SimConfig};
use dfl::util::Rng;

fn scale_cfg(trainer: &MockTrainer, n: usize, seed: u64) -> SimConfig {
    let mut cfg = SimConfig::for_meta(n, trainer.meta());
    cfg.protocol = ProtocolConfig {
        timeout: Duration::from_millis(80),
        min_rounds: 4,
        count_threshold: 2,
        conv_threshold_rel: 0.12,
        max_rounds: 60,
        lr: 0.08,
        model_seed: 42,
        weight_by_samples: false,
        early_window_exit: true,
        crt_enabled: true,
        quorum: QuorumSpec::STRICT,
        agg: AggregationRule::FedAvg,
        codec: CodecSpec::Dense,
    };
    cfg.train_n = 20 * n;
    cfg.net = NetworkModel::lan(seed);
    cfg.seed = seed;
    cfg.virtual_time = true;
    cfg.train_cost = Duration::from_millis(5);
    cfg
}

/// The acceptance scenario: 200 clients, 30 staggered crashes, 10% message
/// loss — the deployment must complete with exactly the scheduled crashes,
/// a final model on every survivor, and (since quorum-CCC) *adaptive*
/// termination.
///
/// Why quorum-CCC is load-bearing here: with 10% *uniform* loss at 200
/// clients, every round drops messages from ~17 of the ~170 alive peers
/// per observer, so the end-of-window sweep detects (false) crashes
/// essentially every round and the paper-strict condition (a) (q = 1.0,
/// zero fresh suspicions) never holds for `count_threshold` consecutive
/// rounds — survivors ran to the round cap, and this test could not
/// assert adaptive termination before quorum-CCC existed.
///
/// This deployment used to pin a hand-derived `q = 0.85` (⌊0.15·199⌋ =
/// 29 tolerated ≈ Binomial(170, 0.1) mean + 3σ).  `--quorum auto` now
/// performs that derivation per client at run time — an EWMA of the
/// measured fresh-suspicion rate plus the same 3σ binomial margin — so
/// the test asserts the controller *finds* the tolerance the deployment
/// needs instead of being handed it, while still tripping on any
/// mass-crash event; one client reaching CCC floods everyone via CRT.
#[test]
#[ignore = "scale test: ~200 clients, run explicitly with -- --ignored"]
fn two_hundred_clients_with_crashes_and_drops_terminate() {
    let n = 200;
    let trainer = MockTrainer::tiny_with_k_max(n + 8);
    let mut cfg = scale_cfg(&trainer, n, 42);
    cfg.net = NetworkModel::lossy(0.10, 42);
    cfg.protocol.quorum = QuorumSpec::parse("auto").unwrap();
    let mut rng = Rng::new(42);
    cfg.faults = variable_crash_schedule(n, 30, 2, 12, &mut rng);
    let res = sim::run(&trainer, &cfg).unwrap();
    assert_eq!(res.reports.len(), n);
    assert_eq!(res.crashed(), 30, "exactly the scheduled crashes");
    assert!(res.rounds() <= cfg.protocol.max_rounds);
    // Every survivor observed a consistent network: it aggregated at least
    // itself each round and finished with a final model.
    for r in &res.reports {
        if r.cause != TerminationCause::Crashed {
            assert!(r.final_accuracy.is_some());
        }
    }
    // The restored adaptive-termination claim: under auto-tuned
    // quorum-CCC no survivor needs the round cap even with crashes +
    // uniform loss, and nobody hand-picked the tolerance.
    assert!(
        res.all_terminated_adaptively(),
        "quorum auto-tuning must restore adaptive termination under 10% loss; causes: {:?}",
        res.reports.iter().map(|r| r.cause).collect::<Vec<_>>()
    );
}

/// The cross-executor acceptance criterion: at 200 clients with crashes
/// and loss, the event executor and the thread executor must produce
/// byte-identical `SimResult`s for the same seed.
#[test]
#[ignore = "scale test: runs the 200-client scenario twice, run with -- --ignored"]
fn event_and_thread_executors_byte_identical_at_200_clients() {
    let n = 200;
    let trainer = MockTrainer::tiny_with_k_max(n + 8);
    let mut cfg = scale_cfg(&trainer, n, 42);
    cfg.net = NetworkModel::lossy(0.10, 42);
    let mut rng = Rng::new(42);
    cfg.faults = variable_crash_schedule(n, 30, 2, 12, &mut rng);

    cfg.exec = ExecMode::Events;
    let ev = sim::run(&trainer, &cfg).unwrap();
    cfg.exec = ExecMode::Threads;
    let th = sim::run(&trainer, &cfg).unwrap();

    let fe: Vec<u64> = ev.reports.iter().map(fingerprint).collect();
    let ft: Vec<u64> = th.reports.iter().map(fingerprint).collect();
    assert_eq!(fe, ft, "executors diverged at 200 clients");
    assert_eq!(ev.wall, th.wall);
}

/// The sparse-overlay acceptance criterion: 1000 clients on `k-regular:8`
/// must (a) show O(n·d) per-round message volume on the new hub counters
/// — not the full mesh's O(n²) — and (b) still deliver global
/// termination: every client reaches `Finished` adaptively even though
/// each one only ever hears 8 peers, because the CRT flag relays across
/// the overlay (flood with per-client dedup) once any client's CCC fires.
#[test]
#[ignore = "scale test: 1000 clients on a sparse overlay, run with -- --ignored"]
fn thousand_clients_k_regular_volume_is_linear_and_crt_relays() {
    let n = 1000;
    let d = 8usize;
    let trainer = MockTrainer::lean_with_k_max(64);
    let mut cfg = scale_cfg(&trainer, n, 7);
    cfg.topology = TopologySpec::KRegular { d };
    cfg.protocol.min_rounds = 3;
    cfg.protocol.max_rounds = 40;
    cfg.train_n = 4 * n;
    cfg.exec = ExecMode::Events;
    let res = sim::run(&trainer, &cfg).unwrap();
    assert_eq!(res.reports.len(), n);
    assert_eq!(res.crashed(), 0);
    assert!(
        res.all_terminated_adaptively(),
        "every client must reach Finished adaptively on the sparse graph; causes: {:?}",
        res.reports
            .iter()
            .filter(|r| !matches!(
                r.cause,
                TerminationCause::Converged | TerminationCause::Signaled
            ))
            .map(|r| (r.id, r.cause))
            .take(10)
            .collect::<Vec<_>>()
    );
    // CRT actually crossed the overlay: with 1000 clients and degree 8,
    // termination cannot be all-local — peers beyond the origin's
    // neighborhood must have been signaled.
    let signaled = res
        .reports
        .iter()
        .filter(|r| r.cause == TerminationCause::Signaled)
        .count();
    assert!(signaled > d, "flag never left a neighborhood: {signaled} signaled");
    // O(n·d), measured: every client offers ≤ d updates per completed
    // round, plus three bounded one-offs of ≤ d sends each (the final
    // flagged broadcast, the one-shot CRT relay, the Bye) — so total
    // volume is ≤ n·d·(rounds + 3), ~100x below the full mesh's
    // n·(n−1)·rounds ≈ 10⁶/round at this size.
    let rounds = res.rounds() as usize;
    let budget = (n * d * (rounds + 3)) as u64;
    assert!(
        res.net.msgs_sent <= budget,
        "message volume {} over {rounds} rounds exceeds the O(n·d) budget {budget}",
        res.net.msgs_sent
    );
    assert!(
        res.net.msgs_sent >= (n * d) as u64,
        "volume implausibly low ({} total) — counter broken?",
        res.net.msgs_sent
    );
}

/// The PR-5 acceptance scenario (DESIGN.md §10): 200 clients on
/// `k-regular:8` with a mid-run min-cut edge-cut window plus 5% churn
/// (10 clients leave the overlay and rejoin with regenerated edges),
/// all under `--quorum auto` — no hand-picked q anywhere.  The
/// deployment must reach all-Finished adaptively, deterministically per
/// seed, byte-identical across both executors.
///
/// Timing: rounds cost ≥ 5 ms (train) and ≤ ~85 ms (a window riding out
/// silent peers), so the fault window (cut 80–200 ms, churn 60–260 ms)
/// lands squarely inside the MINIMUM_ROUNDS=25 warmup — every churned
/// client is back, and every cut healed, well before convergence can
/// trigger, which is what makes all-Finished-adaptively assertable.
#[test]
#[ignore = "scale test: 200 clients × 2 executors under graph faults, run with -- --ignored"]
fn two_hundred_clients_graph_faults_auto_quorum_all_finish() {
    let n = 200;
    let d = 8usize;
    let trainer = MockTrainer::tiny_with_k_max(n + 8);
    let mut cfg = scale_cfg(&trainer, n, 42);
    cfg.topology = TopologySpec::KRegular { d };
    cfg.protocol.quorum = QuorumSpec::parse("auto").unwrap();
    cfg.protocol.min_rounds = 25;
    cfg.protocol.max_rounds = 100;
    let ms = |v: u64| Duration::from_millis(v);
    let mut faults = vec![GraphFault::parse("graph-cut:0.08-0.2:mincut").unwrap()];
    for i in 0..10u64 {
        faults.push(GraphFault::Churn {
            client: (i * 19 + 3) as u32, // spread across the id space
            leave: ms(60 + 10 * i),
            rejoin: Some(ms(160 + 10 * i)),
        });
    }
    cfg.graph_faults = faults;

    cfg.exec = ExecMode::Events;
    let ev = sim::run(&trainer, &cfg).unwrap();
    cfg.exec = ExecMode::Threads;
    let th = sim::run(&trainer, &cfg).unwrap();
    let fe: Vec<u64> = ev.reports.iter().map(fingerprint).collect();
    let ft: Vec<u64> = th.reports.iter().map(fingerprint).collect();
    assert_eq!(fe, ft, "executors diverged under graph faults at 200 clients");
    assert_eq!(ev.net, th.net, "overlay histories diverged");

    assert_eq!(ev.reports.len(), n);
    assert_eq!(ev.crashed(), 0, "churn is a graph fault, not a client crash");
    // the schedule really attacked the graph: the min-cut severed ≥ 1
    // edge and each of the 10 departures tore down ~d edges
    assert!(
        ev.net.edges_severed >= 1 + 10,
        "implausibly low fault pressure: {:?}",
        ev.net
    );
    // all-Finished, adaptively, with a final model everywhere — the
    // auto-quorum absorbed the fault-induced suspicion noise without a
    // hand-picked q
    for r in &ev.reports {
        assert!(r.final_accuracy.is_some(), "client {} never finalized", r.id);
    }
    assert!(
        ev.all_terminated_adaptively(),
        "graph faults + auto quorum must still reach adaptive termination; causes: {:?}",
        ev.reports
            .iter()
            .filter(|r| !matches!(
                r.cause,
                TerminationCause::Converged | TerminationCause::Signaled
            ))
            .map(|r| (r.id, r.cause))
            .take(10)
            .collect::<Vec<_>>()
    );
    assert!(ev.rounds() <= cfg.protocol.max_rounds);
}

/// Stretch: four-digit client count on the lean (66-param) model so the
/// in-flight message volume stays modest.  Fault-free; asserts the
/// protocol's adaptive-termination claim holds at 1000 clients.
#[test]
#[ignore = "scale test: 1000 clients, several minutes of compute"]
fn thousand_clients_terminate_adaptively() {
    let n = 1000;
    let trainer = MockTrainer::lean_with_k_max(n + 8);
    let mut cfg = scale_cfg(&trainer, n, 7);
    cfg.protocol.min_rounds = 3;
    cfg.protocol.max_rounds = 30;
    cfg.train_n = 4 * n;
    let res = sim::run(&trainer, &cfg).unwrap();
    assert_eq!(res.reports.len(), n);
    assert_eq!(res.crashed(), 0);
    assert!(res.all_terminated_adaptively());
}

/// Current OS thread count of this process (Linux /proc).
fn current_thread_count() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
}

/// The 10 000-client unlock: an async run with 1000 staggered crashes and
/// 10% message loss on the event executor, under a real-time budget
/// (`DFL_SCALE_BUDGET_SECS`, default 1800 s) and — the point of the
/// refactor — without spawning per-client OS threads, which a watcher
/// thread asserts by sampling `/proc/self/status` during the run.
///
/// The lean mock (66 params) and a fan-in cap of 64 keep memory inside the
/// O(n²) message volume's budget: a full broadcast round is ~10⁸ events,
/// each a 48-byte heap entry sharing one refcounted payload per sender.
#[test]
#[ignore = "scale test: 10000 clients, minutes of compute and ~tens of GB RSS"]
fn ten_thousand_clients_event_executor_with_crashes_and_drops() {
    let n = 10_000;
    let budget = Duration::from_secs(
        std::env::var("DFL_SCALE_BUDGET_SECS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1800),
    );
    let trainer = MockTrainer::lean_with_k_max(64);
    let mut cfg = SimConfig::for_meta(n, trainer.meta());
    cfg.protocol = ProtocolConfig {
        timeout: Duration::from_millis(50),
        min_rounds: 2,
        count_threshold: 2,
        conv_threshold_rel: 0.12,
        max_rounds: 4,
        lr: 0.08,
        model_seed: 42,
        weight_by_samples: false,
        early_window_exit: true,
        crt_enabled: true,
        quorum: QuorumSpec::STRICT,
        agg: AggregationRule::FedAvg,
        codec: CodecSpec::Dense,
    };
    // Tiny independent chunks: partitioning 10k clients must not dominate
    // the benchmark, and every client needs a non-empty slice.
    cfg.partition = Partition::FixedChunk(64);
    cfg.train_n = 2 * n;
    cfg.net = NetworkModel::lossy(0.10, 99);
    cfg.seed = 99;
    cfg.virtual_time = true;
    cfg.exec = ExecMode::Events;
    cfg.train_cost = Duration::from_millis(5);
    let mut rng = Rng::new(99);
    cfg.faults = variable_crash_schedule(n, 1000, 1, 3, &mut rng);

    // The thread-count check is a *delta* against a baseline taken just
    // before the run, so libtest's own worker threads don't count.  It
    // still assumes this test is not run concurrently with the
    // thread-executor scale tests in this binary (whose 200 client
    // threads would be attributed to us) — at this size the run wants the
    // whole machine anyway: `cargo test -q -- --ignored --test-threads=1`.
    let baseline = current_thread_count().expect("reading /proc/self/status");
    static STOP: AtomicBool = AtomicBool::new(false);
    static MAX_THREADS: AtomicUsize = AtomicUsize::new(0);
    let watcher = std::thread::spawn(|| {
        while !STOP.load(Ordering::Relaxed) {
            if let Some(t) = current_thread_count() {
                MAX_THREADS.fetch_max(t, Ordering::Relaxed);
            }
            std::thread::sleep(Duration::from_millis(200));
        }
    });

    let t0 = Instant::now();
    let res = sim::run(&trainer, &cfg).unwrap();
    let elapsed = t0.elapsed();
    STOP.store(true, Ordering::Relaxed);
    let _ = watcher.join();

    assert_eq!(res.reports.len(), n);
    assert_eq!(res.crashed(), 1000, "exactly the scheduled crashes");
    assert!(res.rounds() <= cfg.protocol.max_rounds);
    for r in &res.reports {
        if r.cause != TerminationCause::Crashed {
            assert!(r.final_accuracy.is_some());
        }
    }
    assert!(
        elapsed < budget,
        "10k-client run took {elapsed:?}, budget {budget:?}"
    );
    // The event executor owns every client on one thread: the run may add
    // the watcher and nothing per-client.  Allow a generous fixed margin
    // for allocator/runtime helpers — anything near 10 000 means the
    // thread-per-client path ran instead.
    let peak = MAX_THREADS.load(Ordering::Relaxed);
    assert!(
        peak > 0 && peak.saturating_sub(baseline) < 32,
        "expected a threadless deployment: baseline {baseline}, peak {peak}"
    );
}

/// The parallel-executor scale acceptance (DESIGN.md §12): 10 000 clients
/// on `k-regular:8` with 10% uniform loss under `--exec parallel:4` must
/// (a) fingerprint byte-identically to the events reference, (b) reach
/// all-Finished *adaptive* termination under `--quorum auto` (the sparse
/// overlay + loss regime where paper-strict q never holds), and (c) cost
/// S + O(1) OS threads — four shard workers plus fixed scaffolding, never
/// anything per-client — asserted by sampling `/proc/self/status` while
/// the sharded run is live.  Fault-free: crashes would make all-Finished
/// unassertable, and the loss + churn-free overlay already exercises every
/// cross-shard path (the conformance suite owns the fault matrix).
#[test]
#[ignore = "scale test: 10000 clients × 2 executors, minutes of compute"]
fn ten_thousand_clients_parallel_executor_matches_events() {
    let n = 10_000;
    let shards = 4usize;
    let budget = Duration::from_secs(
        std::env::var("DFL_SCALE_BUDGET_SECS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1800),
    );
    let trainer = MockTrainer::lean_with_k_max(64);
    let mut cfg = scale_cfg(&trainer, n, 99);
    cfg.topology = TopologySpec::KRegular { d: 8 };
    cfg.net = NetworkModel::lossy(0.10, 99);
    cfg.protocol.quorum = QuorumSpec::parse("auto").unwrap();
    cfg.protocol.min_rounds = 3;
    cfg.protocol.max_rounds = 40;
    cfg.partition = Partition::FixedChunk(64);
    cfg.train_n = 2 * n;

    let t0 = Instant::now();
    cfg.exec = ExecMode::Events;
    let ev = sim::run(&trainer, &cfg).unwrap();

    // Watch the thread count only while the sharded run is live; the
    // events baseline above keeps libtest's own workers out of the delta.
    // Serialize with the other scale tests: `-- --ignored --test-threads=1`.
    let baseline = current_thread_count().expect("reading /proc/self/status");
    static STOP: AtomicBool = AtomicBool::new(false);
    static MAX_THREADS: AtomicUsize = AtomicUsize::new(0);
    let watcher = std::thread::spawn(|| {
        while !STOP.load(Ordering::Relaxed) {
            if let Some(t) = current_thread_count() {
                MAX_THREADS.fetch_max(t, Ordering::Relaxed);
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    });
    cfg.exec = ExecMode::Parallel { shards };
    let pa = sim::run(&trainer, &cfg).unwrap();
    let elapsed = t0.elapsed();
    STOP.store(true, Ordering::Relaxed);
    let _ = watcher.join();

    // (a) byte identity, the whole acceptance criterion in one line each
    let fe: Vec<u64> = ev.reports.iter().map(fingerprint).collect();
    let fp: Vec<u64> = pa.reports.iter().map(fingerprint).collect();
    assert_eq!(fe, fp, "parallel diverged from events at 10k clients");
    assert_eq!(ev.wall, pa.wall);
    assert_eq!(ev.net, pa.net, "traffic counters diverged");

    // (b) all-Finished adaptive termination on the reference result
    assert_eq!(ev.reports.len(), n);
    assert_eq!(ev.crashed(), 0);
    for r in &ev.reports {
        assert!(r.final_accuracy.is_some(), "client {} never finalized", r.id);
    }
    assert!(
        ev.all_terminated_adaptively(),
        "10k sparse + loss must still finish adaptively; causes: {:?}",
        ev.reports
            .iter()
            .filter(|r| !matches!(
                r.cause,
                TerminationCause::Converged | TerminationCause::Signaled
            ))
            .map(|r| (r.id, r.cause))
            .take(10)
            .collect::<Vec<_>>()
    );
    assert!(
        elapsed < budget,
        "10k-client double run took {elapsed:?}, budget {budget:?}"
    );

    // (c) S + O(1) threads: the four shard workers, the watcher, and a
    // small fixed margin for allocator/runtime helpers.  Anything near n
    // means the thread-per-client path ran instead.
    let peak = MAX_THREADS.load(Ordering::Relaxed);
    assert!(
        peak > 0 && peak.saturating_sub(baseline) <= shards + 8,
        "expected S + O(1) threads: baseline {baseline}, peak {peak}, shards {shards}"
    );
}
