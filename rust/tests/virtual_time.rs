//! Virtual-clock properties: same-seed determinism down to the bit, cause
//! agreement with wall-clock runs, fault downtime charged to logical time,
//! partition-heal CRT flooding, and WAN-scale latency — all in wall-clock
//! milliseconds because nothing actually sleeps.

mod common;

use std::time::{Duration, Instant};

use common::fingerprint;
use dfl::coordinator::fault::{FaultPlan, GraphFault};
use dfl::coordinator::termination::TerminationCause;
use dfl::coordinator::{ProtocolConfig, QuorumSpec};
use dfl::net::{CodecSpec, NetSplit, NetworkModel, TopologySpec};
use dfl::runtime::{AggregationRule, MockTrainer, Trainer};
use dfl::sim::{self, ExecMode, SimConfig};

fn base_cfg(n: usize, seed: u64) -> SimConfig {
    let trainer = MockTrainer::tiny();
    let mut cfg = SimConfig::for_meta(n, trainer.meta());
    cfg.protocol = ProtocolConfig {
        timeout: Duration::from_millis(80),
        min_rounds: 4,
        count_threshold: 2,
        conv_threshold_rel: 0.12,
        max_rounds: 60,
        lr: 0.08,
        model_seed: 42,
        weight_by_samples: false,
        early_window_exit: true,
        crt_enabled: true,
        quorum: QuorumSpec::STRICT,
        agg: AggregationRule::FedAvg,
        codec: CodecSpec::Dense,
    };
    cfg.train_n = 60 * n;
    cfg.net = NetworkModel::lan(seed);
    cfg.seed = seed;
    cfg.virtual_time = true;
    cfg.train_cost = Duration::from_millis(5);
    cfg
}

#[test]
fn identical_config_and_seed_reproduce_byte_identical_histories() {
    // The hardest setting we support: message loss, a permanent crash, and
    // a transient outage.  Same config + seed twice ⇒ every client's full
    // report (floats by bits, times by nanos) is identical.
    let make = || {
        let trainer = MockTrainer::tiny();
        let mut cfg = base_cfg(5, 1234);
        cfg.net = NetworkModel::lossy(0.10, 1234);
        cfg.protocol.min_rounds = 8;
        cfg.faults = vec![FaultPlan::none(); 5];
        cfg.faults[2] = FaultPlan::at_round(4);
        cfg.faults[4] = FaultPlan::transient(3, Duration::from_millis(300));
        sim::run(&trainer, &cfg).unwrap()
    };
    let a = make();
    let b = make();
    let fa: Vec<u64> = a.reports.iter().map(fingerprint).collect();
    let fb: Vec<u64> = b.reports.iter().map(fingerprint).collect();
    assert_eq!(fa, fb, "virtual-time runs must be bit-reproducible");
    assert_eq!(a.wall, b.wall);
}

#[test]
fn event_and_thread_executors_are_byte_identical() {
    // The two virtual-time executors — single-threaded state machines vs
    // one cooperative thread per client — must make the identical sequence
    // of scheduler transitions.  Hardest small setting we have: message
    // loss, a permanent crash, a transient outage.
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(5, 1234);
    cfg.net = NetworkModel::lossy(0.10, 1234);
    cfg.protocol.min_rounds = 8;
    cfg.faults = vec![FaultPlan::none(); 5];
    cfg.faults[2] = FaultPlan::at_round(4);
    cfg.faults[4] = FaultPlan::transient(3, Duration::from_millis(300));
    cfg.exec = ExecMode::Events;
    let ev = sim::run(&trainer, &cfg).unwrap();
    cfg.exec = ExecMode::Threads;
    let th = sim::run(&trainer, &cfg).unwrap();
    let fe: Vec<u64> = ev.reports.iter().map(fingerprint).collect();
    let ft: Vec<u64> = th.reports.iter().map(fingerprint).collect();
    assert_eq!(fe, ft, "executors must be byte-identical");
    assert_eq!(ev.wall, th.wall);
}

#[test]
fn sync_phase_executors_are_byte_identical() {
    // Phase 1's barrier (SyncMachine::Collect) under both executors.
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(4, 888);
    cfg.sync = true;
    cfg.exec = ExecMode::Events;
    let ev = sim::run(&trainer, &cfg).unwrap();
    cfg.exec = ExecMode::Threads;
    let th = sim::run(&trainer, &cfg).unwrap();
    let fe: Vec<u64> = ev.reports.iter().map(fingerprint).collect();
    let ft: Vec<u64> = th.reports.iter().map(fingerprint).collect();
    assert_eq!(fe, ft, "sync executors must be byte-identical");
    // Phase 1's mutual agreement: every client stops at the same round.
    let rounds: Vec<u32> = ev.reports.iter().map(|r| r.rounds_completed).collect();
    assert!(rounds.windows(2).all(|w| w[0] == w[1]), "rounds {rounds:?}");
}

#[test]
fn explicit_full_topology_and_strict_quorum_match_the_defaults() {
    // `--topology full --quorum 1.0` is the byte-identity contract: a
    // config that spells out the defaults must fingerprint identically to
    // one that never mentions them (guards any future drift of either
    // default away from the paper-exact path).
    let trainer = MockTrainer::tiny();
    let mut defaults = base_cfg(5, 1234);
    defaults.net = NetworkModel::lossy(0.10, 1234);
    defaults.faults = vec![FaultPlan::none(); 5];
    defaults.faults[2] = FaultPlan::at_round(4);
    let a = sim::run(&trainer, &defaults).unwrap();
    let mut explicit = defaults.clone();
    explicit.topology = TopologySpec::Full;
    explicit.protocol.quorum = QuorumSpec::Fixed(1.0);
    let b = sim::run(&trainer, &explicit).unwrap();
    let fa: Vec<u64> = a.reports.iter().map(fingerprint).collect();
    let fb: Vec<u64> = b.reports.iter().map(fingerprint).collect();
    assert_eq!(fa, fb, "explicit full/1.0 must be byte-identical to the defaults");
    assert_eq!(a.net, b.net, "traffic counters must agree too");
}

#[test]
fn sparse_topology_executors_are_byte_identical() {
    // The cross-executor contract extended to the sparse overlay: message
    // loss, a permanent crash, a transient outage, quorum-CCC, and the
    // CRT relay path all active — events vs threads must still agree on
    // every byte, including the traffic counters.
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(8, 4321);
    cfg.net = NetworkModel::lossy(0.10, 4321);
    cfg.topology = TopologySpec::SmallWorld { d: 4, p: 0.2 };
    cfg.protocol.quorum = QuorumSpec::Fixed(0.75);
    cfg.protocol.min_rounds = 6;
    cfg.faults = vec![FaultPlan::none(); 8];
    cfg.faults[3] = FaultPlan::at_round(4);
    cfg.faults[6] = FaultPlan::transient(3, Duration::from_millis(300));
    cfg.exec = ExecMode::Events;
    let ev = sim::run(&trainer, &cfg).unwrap();
    cfg.exec = ExecMode::Threads;
    let th = sim::run(&trainer, &cfg).unwrap();
    let fe: Vec<u64> = ev.reports.iter().map(fingerprint).collect();
    let ft: Vec<u64> = th.reports.iter().map(fingerprint).collect();
    assert_eq!(fe, ft, "executors diverged on a sparse overlay");
    assert_eq!(ev.wall, th.wall);
    assert_eq!(ev.net, th.net, "executors offered different traffic");
}

#[test]
fn graph_fault_schedules_are_byte_identical_across_executors() {
    // The tentpole's cross-executor contract (DESIGN.md §10): a churn
    // schedule plus an edge-cut window on a sparse overlay — the mutable
    // overlay, peer-table retracking, and repair/regeneration paths all
    // active — must leave events vs threads in byte agreement, traffic
    // counters and severed-edge accounting included.
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(10, 4242);
    cfg.net = NetworkModel::lossy(0.05, 4242);
    cfg.topology = TopologySpec::Ring { k: 2 };
    cfg.protocol.min_rounds = 30;
    cfg.protocol.max_rounds = 80;
    cfg.graph_faults = vec![
        GraphFault::parse("graph-cut:0.15-0.45:mincut").unwrap(),
        GraphFault::parse("churn:4:0.12-0.4").unwrap(),
    ];
    cfg.exec = ExecMode::Events;
    let ev = sim::run(&trainer, &cfg).unwrap();
    cfg.exec = ExecMode::Threads;
    let th = sim::run(&trainer, &cfg).unwrap();
    let fe: Vec<u64> = ev.reports.iter().map(fingerprint).collect();
    let ft: Vec<u64> = th.reports.iter().map(fingerprint).collect();
    assert_eq!(fe, ft, "executors diverged under graph faults");
    assert_eq!(ev.wall, th.wall);
    assert_eq!(ev.net, th.net, "executors applied different overlay histories");
    assert!(
        ev.net.edges_severed > 0,
        "the schedule must have actually cut edges: {:?}",
        ev.net
    );
    // churn is a graph fault, not a client crash
    assert_eq!(ev.crashed(), 0);
    assert_eq!(ev.reports.len(), 10);
    // and the whole history is reproducible per seed
    cfg.exec = ExecMode::Events;
    let again = sim::run(&trainer, &cfg).unwrap();
    let fa: Vec<u64> = again.reports.iter().map(fingerprint).collect();
    assert_eq!(fe, fa, "same seed, same graph-fault history");
    assert_eq!(ev.net, again.net);
}

#[test]
fn zero_edge_net_split_is_rejected_and_crossings_are_recorded() {
    // Satellite bugfix: a NetSplit that severs zero overlay edges (an
    // all-clients side, or a side of unknown ids) used to be silently
    // accepted and the run then mis-read as "survived a partition" —
    // it must be rejected at setup.
    let trainer = MockTrainer::tiny();
    let split = |side: Vec<u32>| {
        NetSplit {
            start: Duration::from_millis(40),
            end: Duration::from_millis(200),
            side_a: side,
        }
    };
    let mut cfg = base_cfg(6, 510);
    cfg.net = NetworkModel::lan(510).with_splits(vec![split((0..6).collect())]);
    let err = sim::run(&trainer, &cfg).unwrap_err();
    assert!(
        format!("{err:#}").contains("severs zero edges"),
        "wrong error: {err:#}"
    );
    cfg.net = NetworkModel::lan(510).with_splits(vec![split(vec![77, 99])]);
    assert!(sim::run(&trainer, &cfg).is_err(), "unknown-id side is a no-op split");
    // a real bisection is accepted, and its crossing count is recorded
    cfg.net = NetworkModel::lan(510).with_splits(vec![split(vec![0, 1, 2])]);
    let res = sim::run(&trainer, &cfg).unwrap();
    assert_eq!(res.net.edges_severed, 9, "3×3 bisection of the 6-client mesh");
    assert_eq!(res.reports.len(), 6);
}

#[test]
fn quorum_auto_matches_strict_byte_for_byte_on_a_clean_network() {
    // `--quorum auto` starts strict and stays strict while no suspicion
    // is ever observed (LAN, loss-free, fault-free), and the controller
    // is a pure fold that never touches the RNG streams — so the run
    // must fingerprint identically to the paper-strict fixed quorum.
    let trainer = MockTrainer::tiny();
    let strict = base_cfg(5, 1234);
    let a = sim::run(&trainer, &strict).unwrap();
    let mut auto = strict.clone();
    auto.protocol.quorum = QuorumSpec::parse("auto").unwrap();
    let b = sim::run(&trainer, &auto).unwrap();
    let fa: Vec<u64> = a.reports.iter().map(fingerprint).collect();
    let fb: Vec<u64> = b.reports.iter().map(fingerprint).collect();
    assert_eq!(fa, fb, "suspicion-free auto must equal the strict quorum");
    assert_eq!(a.net, b.net);
}

#[test]
fn quorum_auto_is_deterministic_under_loss() {
    // Under loss the controller actually moves (suspicions happen);
    // determinism per seed must survive the moving quorum.
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(8, 777);
    cfg.net = NetworkModel::lossy(0.10, 777);
    cfg.protocol.quorum = QuorumSpec::parse("auto").unwrap();
    cfg.protocol.min_rounds = 8;
    let a = sim::run(&trainer, &cfg).unwrap();
    let b = sim::run(&trainer, &cfg).unwrap();
    let fa: Vec<u64> = a.reports.iter().map(fingerprint).collect();
    let fb: Vec<u64> = b.reports.iter().map(fingerprint).collect();
    assert_eq!(fa, fb, "auto-quorum runs must be bit-reproducible");
    // and both executors still agree
    cfg.exec = ExecMode::Threads;
    let th = sim::run(&trainer, &cfg).unwrap();
    let ft: Vec<u64> = th.reports.iter().map(fingerprint).collect();
    assert_eq!(fa, ft, "auto-quorum diverged across executors");
}

#[test]
fn crt_relay_rearms_toward_a_rejoined_middle_hop() {
    // Satellite bugfix regression (ring:1, crash+rejoin the middle hop):
    // a client that crashes with rejoin_after set drains its mailbox on
    // resume, losing any in-flight terminate flags, and the relay dedup
    // means no neighbor ever repeats the flood toward it.  The re-arm
    // path re-sends the stored flagged update when a suspected neighbor
    // revives, so the flood still reaches the rejoined hop and every
    // client concludes adaptively.
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(6, 2121);
    cfg.topology = TopologySpec::Ring { k: 1 };
    // MINIMUM_ROUNDS holds convergence open well past the outage, so the
    // hop is back (and must be re-integrated into the flood's reach)
    // before any flag exists — the schedule the dedup bug used to strand.
    cfg.protocol.min_rounds = 12;
    cfg.protocol.max_rounds = 80;
    cfg.faults = vec![FaultPlan::none(); 6];
    cfg.faults[3] = FaultPlan::transient(3, Duration::from_millis(100));
    let res = sim::run(&trainer, &cfg).unwrap();
    assert_eq!(res.crashed(), 0, "the outage is transient");
    assert!(
        res.all_terminated_adaptively(),
        "the rejoined middle hop must still learn of termination; causes {:?}",
        res.reports.iter().map(|r| (r.id, r.cause)).collect::<Vec<_>>()
    );
    assert!(res.reports[3].final_accuracy.is_some());
}

#[test]
fn crt_flag_relays_across_a_sparse_overlay() {
    // ring:1 on 10 clients: degree 2, diameter 5 — most pairs are NOT
    // neighbors, so adaptive termination everywhere requires the CRT flag
    // to cross the overlay (in-window relay flood + round-to-round
    // piggybacking).  Fault-free LAN keeps the only hard part the graph.
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(10, 909);
    cfg.topology = TopologySpec::Ring { k: 1 };
    let res = sim::run(&trainer, &cfg).unwrap();
    assert_eq!(res.crashed(), 0);
    assert!(
        res.all_terminated_adaptively(),
        "causes {:?}",
        res.reports.iter().map(|r| r.cause).collect::<Vec<_>>()
    );
    // At least someone ended on a received flag (10 independent CCC
    // triggers in the same instant would be a broken relay).
    assert!(
        res.reports.iter().any(|r| r.cause == TerminationCause::Signaled),
        "nobody was signaled — did the relay run?"
    );
}

#[test]
fn sparse_overlay_cuts_message_volume() {
    // Same 16-client deployment, full mesh vs k-regular:4: the sparse run
    // must offer far fewer messages per round (degree 4 vs 15) while
    // still finishing adaptively — the O(n·d) claim at unit-test scale.
    let trainer = MockTrainer::tiny();
    let full = sim::run(&trainer, &base_cfg(16, 246)).unwrap();
    let mut cfg = base_cfg(16, 246);
    cfg.topology = TopologySpec::KRegular { d: 4 };
    let sparse = sim::run(&trainer, &cfg).unwrap();
    assert!(
        sparse.all_terminated_adaptively(),
        "causes {:?}",
        sparse.reports.iter().map(|r| r.cause).collect::<Vec<_>>()
    );
    let (f, s) = (full.msgs_per_round(), sparse.msgs_per_round());
    assert!(
        s * 2.0 < f,
        "degree-4 overlay should offer well under half the mesh volume: {s:.0} vs {f:.0}"
    );
    assert!(s > 0.0 && full.net.bytes_sent > sparse.net.bytes_sent);
}

#[test]
fn determinism_holds_across_many_seeds() {
    for seed in 0..16u64 {
        let trainer = MockTrainer::tiny();
        let mut cfg = base_cfg(4, 4000 + seed);
        cfg.net = NetworkModel::lossy(0.05, seed);
        let a = sim::run(&trainer, &cfg).unwrap();
        let b = sim::run(&trainer, &cfg).unwrap();
        let fa: Vec<u64> = a.reports.iter().map(fingerprint).collect();
        let fb: Vec<u64> = b.reports.iter().map(fingerprint).collect();
        assert_eq!(fa, fb, "seed {seed} diverged");
    }
}

#[test]
fn virtual_and_real_clock_agree_on_termination_causes() {
    // With CRT off every client must reach CCC on its own, so the cause
    // vector is schedule-independent: the virtual run and the wall-clock
    // run of the same small config must agree exactly.  The window is
    // generous (300 ms — free under virtual time, and wall runs exit it
    // early) so OS descheduling on a loaded host cannot fake a crash and
    // skew the real-clock causes.
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(4, 77);
    cfg.protocol.crt_enabled = false;
    cfg.protocol.max_rounds = 80;
    cfg.protocol.timeout = Duration::from_millis(300);
    let virt = sim::run(&trainer, &cfg).unwrap();
    cfg.virtual_time = false;
    let real = sim::run(&trainer, &cfg).unwrap();
    let causes = |r: &sim::SimResult| -> Vec<TerminationCause> {
        r.reports.iter().map(|c| c.cause).collect()
    };
    assert_eq!(causes(&virt), causes(&real));
    for c in causes(&virt) {
        assert_eq!(c, TerminationCause::Converged);
    }
}

#[test]
fn ten_second_outage_completes_in_under_a_second_of_real_time() {
    // Regression for the fault-injection sleep: FaultPlan::transient used
    // to block the OS thread for the whole downtime; it now charges the
    // clock, so a 10 s outage is instant under virtual time.
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(4, 301);
    cfg.protocol.min_rounds = 6;
    cfg.faults = vec![FaultPlan::none(); 4];
    cfg.faults[1] = FaultPlan::transient(2, Duration::from_secs(10));
    let t0 = Instant::now();
    let res = sim::run(&trainer, &cfg).unwrap();
    let real_elapsed = t0.elapsed();
    assert!(
        real_elapsed < Duration::from_secs(1),
        "10 s virtual outage took {real_elapsed:?} of real time"
    );
    // ...while logically the run did span the outage:
    assert!(res.wall >= Duration::from_secs(10), "virtual wall {:?}", res.wall);
    assert_eq!(res.crashed(), 0, "transient fault must not be a permanent crash");
    assert!(res.all_terminated_adaptively());
}

#[test]
fn partition_heals_and_crt_floods_across_it() {
    // Split 6 clients 3|3 for a stretch of logical time: each side must
    // detect the other as crashed, keep running, then revive peers and
    // finish adaptively once the partition heals (CRT flags flow again).
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(6, 505);
    cfg.protocol.min_rounds = 12;
    cfg.protocol.max_rounds = 120;
    cfg.net = NetworkModel::lan(505).with_splits(vec![NetSplit {
        start: Duration::from_millis(40),
        end: Duration::from_millis(500),
        side_a: vec![0, 1, 2],
    }]);
    let res = sim::run(&trainer, &cfg).unwrap();
    assert_eq!(res.crashed(), 0);
    let cross_group_detection = res.reports.iter().any(|r| {
        r.history.iter().any(|h| {
            h.crashes_detected.iter().any(|&c| (c >= 3) != (r.id >= 3))
        })
    });
    assert!(cross_group_detection, "the split never bit — widen the window");
    assert!(
        res.all_terminated_adaptively(),
        "causes {:?}",
        res.reports.iter().map(|r| r.cause).collect::<Vec<_>>()
    );
}

#[test]
fn wan_latency_distribution_is_testable_in_milliseconds() {
    // WAN model: 40 ms base delay + up to 120 ms jitter per message.  On
    // the wall clock this run would spend minutes waiting; virtually it is
    // compute-bound.  The protocol must still terminate adaptively given a
    // timeout above the latency ceiling.
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(5, 606);
    cfg.net = NetworkModel::wan(606);
    cfg.protocol.timeout = Duration::from_millis(400);
    let t0 = Instant::now();
    let res = sim::run(&trainer, &cfg).unwrap();
    assert!(t0.elapsed() < Duration::from_secs(5), "WAN run not virtualized?");
    assert!(res.wall >= Duration::from_millis(400), "virtual wall {:?}", res.wall);
    assert!(
        res.all_terminated_adaptively(),
        "causes {:?}",
        res.reports.iter().map(|r| r.cause).collect::<Vec<_>>()
    );
}

#[test]
fn virtual_wall_time_reflects_modeled_schedule() {
    // Sanity on SimResult::wall under virtual time: at least min_rounds of
    // modeled training must have elapsed for the slowest client, and
    // machine_times() stays consistent with per-report walls.
    let trainer = MockTrainer::tiny();
    let mut cfg = base_cfg(4, 808);
    cfg.machines = 2;
    let res = sim::run(&trainer, &cfg).unwrap();
    let floor = cfg.train_cost.mul_f32(cfg.protocol.min_rounds as f32);
    assert!(res.wall >= floor, "wall {:?} < training floor {floor:?}", res.wall);
    let mt = res.machine_times();
    assert_eq!(mt.len(), 2);
    assert_eq!(mt.iter().max().copied().unwrap(), res.wall);
}
