//! End-to-end test of the real-socket deployment: Algorithm 2 clients in
//! threads over TcpTransport on localhost (the paper's actual transport),
//! with one injected crash.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener};
use std::sync::Arc;
use std::time::Duration;

use dfl::coordinator::async_client::{AsyncClient, ClientData};
use dfl::coordinator::fault::FaultPlan;
use dfl::coordinator::termination::TerminationCause;
use dfl::coordinator::{ProtocolConfig, QuorumSpec};
use dfl::data::{dirichlet_partition, Dataset};
use dfl::net::{CodecSpec, TcpTransport};
use dfl::runtime::{AggregationRule, MockTrainer, Trainer};
use dfl::util::Rng;

fn free_addr() -> SocketAddr {
    TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap()
}

#[test]
fn four_tcp_clients_with_one_crash_terminate() {
    let n = 4usize;
    let trainer = Arc::new(MockTrainer::tiny());
    let meta = trainer.meta().clone();
    let addrs: Vec<SocketAddr> = (0..n).map(|_| free_addr()).collect();

    let seed = 77u64;
    let (train, test) = Dataset::synthetic_pair(&meta, 400, meta.nb_eval_full * meta.batch, seed);
    let train = Arc::new(train);
    let mut rng = Rng::new(seed);
    let parts = dirichlet_partition(&train, n, 0.6, &mut rng);

    let cfg = ProtocolConfig {
        timeout: Duration::from_millis(400),
        min_rounds: 3,
        count_threshold: 2,
        conv_threshold_rel: 0.12, // mock's noise floor (see protocol.rs)
        max_rounds: 40,
        lr: 0.08,
        model_seed: 42,
        weight_by_samples: false,
        early_window_exit: true,
        crt_enabled: true,
        quorum: QuorumSpec::STRICT,
        agg: AggregationRule::FedAvg,
        codec: CodecSpec::Dense,
    };

    let reports: Vec<_> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for i in 0..n {
            let peers: BTreeMap<u32, SocketAddr> = (0..n)
                .filter(|&j| j != i)
                .map(|j| (j as u32, addrs[j]))
                .collect();
            let transport = TcpTransport::bind(i as u32, addrs[i], peers).unwrap();
            let data = ClientData::new(Arc::clone(&train), parts[i].clone(), &test, &meta);
            let trainer = Arc::clone(&trainer);
            let cfg = cfg.clone();
            handles.push(scope.spawn(move || {
                AsyncClient {
                    id: i as u32,
                    trainer: trainer.as_ref(),
                    transport: Box::new(transport),
                    cfg,
                    data,
                    fault: if i == 3 { FaultPlan::at_round(2) } else { FaultPlan::none() },
                    adversary: None,
                    rng: Rng::new(seed + i as u64),
                    slowdown: 0.0,
                    train_cost: None,
                }
                .run()
                .unwrap()
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    assert_eq!(reports.len(), n);
    let crashed: Vec<u32> = reports
        .iter()
        .filter(|r| r.cause == TerminationCause::Crashed)
        .map(|r| r.id)
        .collect();
    assert_eq!(crashed, vec![3]);
    for r in &reports {
        if r.id == 3 {
            continue;
        }
        assert!(
            matches!(r.cause, TerminationCause::Converged | TerminationCause::Signaled),
            "client {} over TCP ended with {:?}",
            r.id,
            r.cause
        );
        // the crash of client 3 must have been detected by timeout
        let detected: Vec<u32> =
            r.history.iter().flat_map(|h| h.crashes_detected.iter().copied()).collect();
        assert!(detected.contains(&3), "client {} missed the crash", r.id);
    }
}
