//! Shared integration-test helpers (included via `mod common;` — the
//! directory form keeps this out of the test-binary list).

use dfl::metrics::ClientReport;

/// 64-bit FNV-1a over a byte stream (tiny, dependency-free digest).
fn fnv(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x100_0000_01b3);
    }
}

/// Bit-exact fingerprint of everything a client reports: round history,
/// floats by raw bits, virtual wall time to the nanosecond, provenance,
/// and the final model.  This digest *is* the byte-identical-executors
/// acceptance criterion — extend it whenever [`ClientReport`] grows.
pub fn fingerprint(r: &ClientReport) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    fnv(&mut h, &r.id.to_le_bytes());
    fnv(&mut h, format!("{:?}", r.cause).as_bytes());
    fnv(&mut h, &r.rounds_completed.to_le_bytes());
    fnv(&mut h, &r.final_accuracy.map_or(u32::MAX, f32::to_bits).to_le_bytes());
    fnv(&mut h, &r.final_loss.map_or(u32::MAX, f32::to_bits).to_le_bytes());
    fnv(&mut h, &(r.wall.as_nanos() as u64).to_le_bytes());
    fnv(&mut h, &r.signal_source.map_or(u32::MAX, |s| s).to_le_bytes());
    for rec in &r.history {
        fnv(&mut h, &rec.round.to_le_bytes());
        fnv(&mut h, &rec.train_loss.to_bits().to_le_bytes());
        fnv(&mut h, &rec.probe_acc.to_bits().to_le_bytes());
        fnv(&mut h, &(rec.alive_peers as u64).to_le_bytes());
        fnv(&mut h, &(rec.aggregated as u64).to_le_bytes());
        fnv(&mut h, &rec.delta_rel.to_bits().to_le_bytes());
        fnv(&mut h, &rec.conv_counter.to_le_bytes());
        for c in &rec.crashes_detected {
            fnv(&mut h, &c.to_le_bytes());
        }
    }
    if let Some(p) = &r.final_params {
        for v in p {
            fnv(&mut h, &v.to_bits().to_le_bytes());
        }
    }
    h
}
