//! L3 coordinator — the paper's contribution.
//!
//! * [`sync`] — Phase 1: round-based synchronization over an asynchronous
//!   network (Algorithm 1).
//! * [`async_client`] — Phase 2: fully asynchronous client with
//!   timeout-based crash detection (Algorithm 2).
//! * [`machine`] — both protocol loops as poll-style state machines
//!   ([`machine::ClientStateMachine`]): blocking points are yielded to an
//!   executor, so a client needs a thread only if its executor chooses to
//!   spend one.
//! * [`failure`] — peer status table: Alive/Crashed/Terminated with
//!   late-message revival ("slow ≠ crashed"), scoped to the overlay
//!   neighborhood on sparse topologies (DESIGN.md §9).
//! * [`termination`] — Client-Confident Convergence (CCC) monitor with
//!   the quorum generalization of condition (a)
//!   ([`termination::quorum_crash_free`]) and the Client-Responsive
//!   Termination (CRT) flag state; on sparse overlays the flag also
//!   relays hop-by-hop ([`machine`]).
//! * [`fault`] — crash schedules and fault injection used by the
//!   experiments (Experiments 1–3), plus the topology-aware
//!   [`fault::GraphFault`] family (edge cuts, churn — DESIGN.md §10) and
//!   the Byzantine [`fault::AdversarySpec`] roster (equivocation,
//!   poisoning, stale replay, forged suspicion — DESIGN.md §11).
//! * [`config`] — protocol constants (TIMEOUT, MINIMUM_ROUNDS,
//!   COUNT_THRESHOLD, convergence threshold, R_PRIME, learning rate).

pub mod async_client;
pub mod config;
pub mod failure;
pub mod fault;
pub mod machine;
pub mod sync;
pub mod termination;

pub use async_client::{AsyncClient, ClientData, EvalTensors};
pub use config::{ProtocolConfig, QuorumSpec};
pub use crate::net::CodecSpec;
pub use failure::{IdSet, PeerStatus, PeerTable};
pub use fault::{
    compile_adversaries, AdversaryKind, AdversarySpec, CrashPoint, CutSpec, FaultPlan, GraphFault,
};
pub use machine::{ClientStateMachine, Input, Step};
pub use sync::SyncClient;
pub use termination::{
    quorum_crash_free, quorum_tolerated, ConvergenceMonitor, QuorumController,
    TerminationCause, TerminationState,
};
