//! L3 coordinator — the paper's contribution.
//!
//! * [`sync`] — Phase 1: round-based synchronization over an asynchronous
//!   network (Algorithm 1).
//! * [`async_client`] — Phase 2: fully asynchronous client with
//!   timeout-based crash detection (Algorithm 2).
//! * [`failure`] — peer status table: Alive/Crashed/Terminated with
//!   late-message revival ("slow ≠ crashed").
//! * [`termination`] — Client-Confident Convergence (CCC) monitor and the
//!   Client-Responsive Termination (CRT) flag state.
//! * [`fault`] — crash schedules and fault injection used by the
//!   experiments (Experiments 1–3).
//! * [`config`] — protocol constants (TIMEOUT, MINIMUM_ROUNDS,
//!   COUNT_THRESHOLD, convergence threshold, R_PRIME, learning rate).

pub mod async_client;
pub mod config;
pub mod failure;
pub mod fault;
pub mod sync;
pub mod termination;

pub use async_client::{AsyncClient, ClientData};
pub use config::ProtocolConfig;
pub use failure::{PeerStatus, PeerTable};
pub use fault::{CrashPoint, FaultPlan};
pub use sync::SyncClient;
pub use termination::{ConvergenceMonitor, TerminationCause, TerminationState};
