//! Phase 2 — the fully asynchronous, fault-tolerant client (Algorithm 2).
//!
//! Per round: local training → (CRT check) → broadcast → bounded wait
//! window → timeout crash detection → aggregate whatever arrived →
//! evaluate → CCC check → next round.  No barriers: a slow peer delays
//! nobody beyond the window, a late message revives a "crashed" peer, and
//! the terminate flag floods via piggybacking (CRT).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::config::ProtocolConfig;
use super::failure::PeerTable;
use super::fault::FaultPlan;
use super::termination::{ConvergenceMonitor, TerminationCause, TerminationState};
use crate::data::Dataset;
use crate::metrics::{ClientReport, RoundRecord};
use crate::model::ParamVector;
use crate::net::{ClientId, ModelUpdate, Msg, Transport};
use crate::runtime::Trainer;
use crate::util::time::Clock;
use crate::util::Rng;

/// A client's local data: its training partition plus the shared eval
/// tensors (pre-materialized to the artifact's static shapes).
pub struct ClientData {
    pub train: Arc<Dataset>,
    pub indices: Vec<usize>,
    /// Probe eval tensors (eval_round artifact shapes).
    pub eval_xs: Vec<f32>,
    pub eval_ys: Vec<i32>,
    /// Full eval tensors (eval_full artifact shapes).
    pub full_xs: Vec<f32>,
    pub full_ys: Vec<i32>,
}

impl ClientData {
    /// Build from a dataset + partition + shared test set.
    pub fn new(
        train: Arc<Dataset>,
        indices: Vec<usize>,
        test: &Dataset,
        meta: &crate::runtime::Meta,
    ) -> Self {
        let (eval_xs, eval_ys) = test.take_flat(meta.nb_eval_round * meta.batch);
        let (full_xs, full_ys) = test.take_flat(meta.nb_eval_full * meta.batch);
        ClientData { train, indices, eval_xs, eval_ys, full_xs, full_ys }
    }
}

/// One asynchronous FL participant (owns its transport; shares the trainer).
pub struct AsyncClient<'a> {
    pub id: ClientId,
    pub trainer: &'a dyn Trainer,
    pub transport: Box<dyn Transport + 'a>,
    pub cfg: ProtocolConfig,
    pub data: ClientData,
    pub fault: FaultPlan,
    pub rng: Rng,
    /// Artificial per-round slowdown factor ≥ 0 (heterogeneous-machine
    /// contention model; 0 = full speed). Sleeps `factor × train_time`.
    pub slowdown: f32,
    /// Modeled per-round training cost.  `None` (wall-clock deployments)
    /// measures the real training time and sleeps `slowdown × elapsed`;
    /// `Some(c)` (virtual time) charges the clock a deterministic
    /// `c × (1 + slowdown)` instead — measured compute time would leak OS
    /// nondeterminism into the simulated schedule.
    pub train_cost: Option<Duration>,
}

struct WindowOutcome {
    /// Latest update per sender seen this window.
    latest: BTreeMap<ClientId, ModelUpdate>,
    /// Senders heard this window (Update/Hello; a Bye is a leave, not a
    /// liveness signal).
    heard: BTreeSet<ClientId>,
}

impl<'a> AsyncClient<'a> {
    /// Collect messages for up to `cfg.timeout`, processing CRT flags and
    /// liveness as they arrive. Ends early once every currently-alive peer
    /// has reported (if configured).
    fn wait_window(
        &mut self,
        clock: &Clock,
        round: u32,
        peer_table: &mut PeerTable,
        term: &mut TerminationState,
    ) -> WindowOutcome {
        let mut latest: BTreeMap<ClientId, ModelUpdate> = BTreeMap::new();
        let mut heard: BTreeSet<ClientId> = BTreeSet::new();
        // Degenerate single-client deployment: nothing to wait for.
        if self.transport.peers().is_empty() {
            return WindowOutcome { latest, heard };
        }
        // Alive-but-silent peers, maintained incrementally so the early-exit
        // check is O(log n) per message rather than an O(n²) rescan — at
        // hundreds of clients the window loop is the protocol's hot path.
        // Invariant: any peer that *becomes* alive mid-window did so by
        // sending (record_message), so it is heard and never unheard.
        let mut alive_unheard: BTreeSet<ClientId> = peer_table.alive().into_iter().collect();
        let deadline = clock.now() + self.cfg.timeout;
        loop {
            let now = clock.now();
            if now >= deadline {
                break;
            }
            // Every currently-alive peer reported (or none are left at
            // all): nothing further can arrive this window but latecomers.
            if self.cfg.early_window_exit && alive_unheard.is_empty() && !heard.is_empty() {
                break;
            }
            let Some(msg) = self.transport.recv_timeout(deadline - now) else {
                continue; // timeout inside window -> loop re-checks deadline
            };
            let sender = msg.sender();
            match msg {
                Msg::Update(u) => {
                    peer_table.record_message(sender, round, u.terminate);
                    if u.terminate && self.cfg.crt_enabled {
                        term.signal_from(sender, round);
                    }
                    heard.insert(sender);
                    alive_unheard.remove(&sender);
                    latest.insert(sender, u);
                }
                Msg::Hello { .. } => {
                    peer_table.record_message(sender, round, false);
                    heard.insert(sender);
                    alive_unheard.remove(&sender);
                }
                Msg::Bye { .. } => {
                    peer_table.record_message(sender, round, true);
                    // Now Terminated, no longer alive: its silence must not
                    // hold the window open.
                    alive_unheard.remove(&sender);
                }
            }
        }
        WindowOutcome { latest, heard }
    }

    fn broadcast_model(&self, round: u32, params: &[f32], terminate: bool, weight: f32) {
        let msg = Msg::Update(ModelUpdate {
            sender: self.id,
            round,
            terminate,
            weight,
            params: ParamVector(params.to_vec()),
        });
        // best-effort: unreachable peers are handled by the crash model
        let _ = self.transport.broadcast(&msg);
    }

    /// Run Algorithm 2 to completion. Never panics on peer behaviour; Err
    /// only for local/engine failures.
    pub fn run(mut self) -> Result<ClientReport> {
        let meta = self.trainer.meta().clone();
        let clock = self.transport.clock();
        let started = clock.now();
        let mut params = self.trainer.init(self.cfg.model_seed)?;
        let mut peer_table = PeerTable::new(&self.transport.peers());
        let mut term = TerminationState::new();
        let mut monitor =
            ConvergenceMonitor::new(self.cfg.count_threshold, self.cfg.conv_threshold_rel);
        let mut history: Vec<RoundRecord> = Vec::new();
        let my_weight = if self.cfg.weight_by_samples {
            self.data.indices.len() as f32
        } else {
            1.0
        };

        let mut round: u32 = 0;
        let mut cause = TerminationCause::MaxRounds;
        let mut outage_done = false;
        // Messages can arrive between rounds (buffer carries across).
        while round < self.cfg.max_rounds {
            // -- fault injection: benign crash = immediate silence ---------
            if !outage_done
                && self.fault.should_crash(round, clock.now().saturating_sub(started))
            {
                match self.fault.rejoin_after {
                    None => {
                        cause = TerminationCause::Crashed;
                        break;
                    }
                    Some(downtime) => {
                        // Transient failure (§3.1): full silence for the
                        // outage, traffic sent to us meanwhile is lost, then
                        // resume the loop — peers revive us on our next
                        // broadcast (PeerTable late-message rule).  The
                        // downtime charges the clock, so a 10 s outage under
                        // virtual time costs no real waiting.
                        clock.sleep(downtime);
                        while self.transport.try_recv().is_some() {}
                        outage_done = true;
                    }
                }
            }

            // -- local training (EPOCHS_PER_ROUND is baked into the
            //    train_epoch artifact's nb_train scan) ---------------------
            let t_train = clock.now();
            let (xs, ys) = self.data.train.gather_round(
                &self.data.indices,
                meta.nb_train * meta.batch,
                &mut self.rng,
            );
            let (new_params, train_loss) =
                self.trainer.train_round(&params, &xs, &ys, self.cfg.lr)?;
            params = new_params;
            match self.train_cost {
                Some(cost) => clock.sleep(cost.mul_f32(1.0 + self.slowdown.max(0.0))),
                None if self.slowdown > 0.0 => {
                    clock.sleep(clock.now().saturating_sub(t_train).mul_f32(self.slowdown))
                }
                None => {}
            }

            // -- CRT fast path: flag already known -> final broadcast ------
            if term.is_set() {
                self.broadcast_model(round, &params, true, my_weight);
                cause = TerminationCause::Signaled;
                break;
            }

            // -- broadcast + bounded wait ----------------------------------
            self.broadcast_model(round, &params, false, my_weight);
            let window = self.wait_window(&clock, round, &mut peer_table, &mut term);

            // -- crash detection (Alg. 2 lines 14-19) ----------------------
            let newly_crashed = peer_table.mark_missing(round, &window.heard);

            // -- aggregate own + received (Alg. 2 lines 20-21) -------------
            let mut rows: Vec<(&[f32], f32)> = vec![(&params, my_weight)];
            for u in window.latest.values().take(meta.k_max - 1) {
                rows.push((u.params.as_slice(), u.weight.max(0.0)));
            }
            let aggregated = rows.len();
            params = self.trainer.aggregate(&rows)?;

            // -- evaluate (Alg. 2 line 22) ---------------------------------
            let (correct, _eval_loss) =
                self.trainer
                    .eval(&params, &self.data.eval_xs, &self.data.eval_ys, false)?;
            let probe_acc = correct as f32 / self.data.eval_ys.len() as f32;

            // -- CCC check (Alg. 2 lines 23-34) ----------------------------
            let crash_free = newly_crashed.is_empty();
            let avg = ParamVector(params.clone());
            let ccc = monitor.observe(&avg, crash_free, aggregated);
            history.push(RoundRecord {
                round,
                train_loss,
                probe_acc,
                alive_peers: peer_table.alive().len(),
                aggregated,
                delta_rel: monitor.last_delta_rel,
                conv_counter: monitor.counter(),
                crashes_detected: newly_crashed,
            });
            if round >= self.cfg.min_rounds && ccc {
                term.self_trigger(round);
                self.broadcast_model(round, &params, true, my_weight);
                cause = TerminationCause::Converged;
                round += 1;
                break;
            }
            // CRT: flag may have arrived during this window — finish the
            // round (aggregation above already used the data), then exit at
            // the top of the next iteration after one more local update
            // (Alg. 2 lines 8-10).
            round += 1;
        }

        // -- termination finalization (Alg. 2 lines 39-42) ------------------
        let (final_accuracy, final_loss, final_params) =
            if cause == TerminationCause::Crashed {
                (None, None, None)
            } else {
                if cause == TerminationCause::MaxRounds {
                    // max rounds reached: log and broadcast final weights
                    self.broadcast_model(round, &params, true, my_weight);
                }
                let _ = self.transport.broadcast(&Msg::Bye { sender: self.id });
                let (correct, loss) = self.trainer.eval(
                    &params,
                    &self.data.full_xs,
                    &self.data.full_ys,
                    true,
                )?;
                (
                    Some(correct as f32 / self.data.full_ys.len() as f32),
                    Some(loss),
                    Some(params),
                )
            };

        Ok(ClientReport {
            id: self.id,
            cause,
            rounds_completed: round,
            final_accuracy,
            final_loss,
            wall: clock.now().saturating_sub(started),
            history,
            signal_source: term.source,
            final_params,
        })
    }
}
