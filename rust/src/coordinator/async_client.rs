//! Phase 2 — the fully asynchronous, fault-tolerant client (Algorithm 2).
//!
//! The protocol loop itself lives in [`super::machine::AsyncMachine`] as a
//! poll-style state machine (Training / AwaitUpdates / Outage, see the
//! [`super::machine`] docs); [`AsyncClient`] is the construction surface —
//! the same public fields as always — plus the blocking driver that runs
//! the machine on the current thread.  `sim::exec` drives the identical
//! machine without a thread per client.
//!
//! Per round: local training → (CRT check) → broadcast → bounded wait
//! window → timeout crash detection → aggregate whatever arrived →
//! evaluate → CCC check → next round.  No barriers: a slow peer delays
//! nobody beyond the window, a late message revives a "crashed" peer, and
//! the terminate flag floods via piggybacking (CRT).

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::config::ProtocolConfig;
use super::fault::{AdversaryKind, FaultPlan};
use super::machine::{AsyncMachine, ClientStateMachine};
use crate::data::Dataset;
use crate::metrics::ClientReport;
use crate::net::{ClientId, Transport};
use crate::runtime::Trainer;
use crate::util::Rng;

/// The shared evaluation tensors (pre-materialized to the artifact's
/// static shapes).  Every client of a deployment evaluates on the same
/// test set, so these are reference-counted: at 10 000 clients one copy
/// exists instead of 10 000.
#[derive(Clone)]
pub struct EvalTensors {
    /// Probe eval tensors (eval_round artifact shapes).
    pub eval_xs: Arc<Vec<f32>>,
    pub eval_ys: Arc<Vec<i32>>,
    /// Full eval tensors (eval_full artifact shapes).
    pub full_xs: Arc<Vec<f32>>,
    pub full_ys: Arc<Vec<i32>>,
}

impl EvalTensors {
    /// Materialize both eval tensor sets from the shared test dataset.
    pub fn new(test: &Dataset, meta: &crate::runtime::Meta) -> Self {
        let (eval_xs, eval_ys) = test.take_flat(meta.nb_eval_round * meta.batch);
        let (full_xs, full_ys) = test.take_flat(meta.nb_eval_full * meta.batch);
        EvalTensors {
            eval_xs: Arc::new(eval_xs),
            eval_ys: Arc::new(eval_ys),
            full_xs: Arc::new(full_xs),
            full_ys: Arc::new(full_ys),
        }
    }
}

/// A client's local data: its training partition plus the shared eval
/// tensors.
pub struct ClientData {
    pub train: Arc<Dataset>,
    pub indices: Vec<usize>,
    pub eval: EvalTensors,
}

impl ClientData {
    /// Build from a dataset + partition + shared test set (materializes a
    /// private copy of the eval tensors; deployments with many clients
    /// should build one [`EvalTensors`] and use [`ClientData::with_eval`]).
    pub fn new(
        train: Arc<Dataset>,
        indices: Vec<usize>,
        test: &Dataset,
        meta: &crate::runtime::Meta,
    ) -> Self {
        ClientData::with_eval(train, indices, EvalTensors::new(test, meta))
    }

    /// Build from a partition plus already-shared eval tensors.
    pub fn with_eval(train: Arc<Dataset>, indices: Vec<usize>, eval: EvalTensors) -> Self {
        ClientData { train, indices, eval }
    }
}

/// One asynchronous FL participant (owns its transport; shares the
/// trainer).  Fill the fields, then either [`run`](AsyncClient::run) on
/// this thread or [`into_machine`](AsyncClient::into_machine) for an
/// event-driven executor.
pub struct AsyncClient<'a> {
    pub id: ClientId,
    pub trainer: &'a dyn Trainer,
    pub transport: Box<dyn Transport + 'a>,
    pub cfg: ProtocolConfig,
    pub data: ClientData,
    pub fault: FaultPlan,
    /// Byzantine role (`None` = honest): the client runs the full
    /// protocol but its broadcasts lie per [`AdversaryKind`]
    /// (DESIGN.md §11).  Assigned by `sim::run` from `--adversary`.
    pub adversary: Option<AdversaryKind>,
    pub rng: Rng,
    /// Artificial per-round slowdown factor ≥ 0 (heterogeneous-machine
    /// contention model; 0 = full speed). Sleeps `factor × train_time`.
    pub slowdown: f32,
    /// Modeled per-round training cost.  `None` (wall-clock deployments)
    /// measures the real training time and sleeps `slowdown × elapsed`;
    /// `Some(c)` (virtual time) charges the clock a deterministic
    /// `c × (1 + slowdown)` instead — measured compute time would leak OS
    /// nondeterminism into the simulated schedule.
    pub train_cost: Option<Duration>,
}

impl<'a> AsyncClient<'a> {
    /// Lift this client into its poll-style state machine (no thread
    /// needed; see [`super::machine`]).
    pub fn into_machine(self) -> ClientStateMachine<'a> {
        ClientStateMachine::Async(AsyncMachine::new(self))
    }

    /// Run Algorithm 2 to completion on the current thread.  Never panics
    /// on peer behaviour; Err only for local/engine failures.
    pub fn run(self) -> Result<ClientReport> {
        self.into_machine().run_blocking()
    }
}
