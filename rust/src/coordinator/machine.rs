//! Poll-style client state machines — the protocol loops of Algorithms 1–2
//! with every blocking point made explicit.
//!
//! [`AsyncClient`](super::async_client::AsyncClient) and
//! [`SyncClient`](super::sync::SyncClient) used to *be* their run loops:
//! straight-line code that slept and received inline, so every client
//! needed a thread to block on.  This module turns each loop inside out
//! into a [`ClientStateMachine`]: a `step(input) -> Step` automaton that
//! never blocks.  Compute (training, aggregation, evaluation) and sends
//! happen inside `step`; the only things a client ever *waits* for are
//! yielded to the caller as [`Step::Sleep`] or [`Step::Recv`], and the
//! caller answers with the matching [`Input`].
//!
//! Two executors drive the same machine:
//!
//! * **Blocking** ([`ClientStateMachine::run_blocking`]) — one thread per
//!   client, `Sleep` ⇒ `Clock::sleep`, `Recv` ⇒ `Transport::recv_timeout`.
//!   This is the wall-clock path (TCP, `InProcHub`) and the thread-backed
//!   virtual compatibility mode.
//! * **Event-driven** (`sim::exec`) — a single thread owns every machine
//!   and maps the yields onto the virtual clock's driver API; no
//!   per-client OS threads exist.  Both executors make the identical
//!   sequence of scheduler transitions, so same-seed runs are
//!   byte-identical across them.
//!
//! # Async (Phase 2) state lifecycle
//!
//! ```text
//! Boot ──▶ [fault check] ──▶ Training ──▶ AwaitUpdates ──▶ (window close:
//!            │    │ transient              ▲    │ msg        suspect sweep,
//!            │    ▼                        │    ▼            aggregate, CCC)
//!            │  Outage ────────────────────┘  (loop)            │
//!            │ crash                                   next round│  CCC/CRT/cap
//!            ▼                                           ◀──────┴──▶ terminate
//!         Finished ◀───────────── final broadcast + Bye + full eval ──┘
//! ```
//!
//! The end-of-window crash-suspicion sweep ([`PeerTable::mark_missing`])
//! and the terminating tail (final broadcast, `Bye`, full evaluation) run
//! synchronously inside `step` — they never wait, so they are phases of a
//! transition rather than resting states.
//!
//! # Window memory at four-digit client counts
//!
//! A wait window only ever aggregates the `k_max − 1` lowest-id senders
//! (the fan-in cap of the aggregation artifact), so the window keeps full
//! model payloads for exactly that prefix and tracks everyone else in
//! [`IdSet`] bitsets.  At 10 000 clients this is the difference between
//! ~64 buffered updates per client and ~10 000 — without changing a single
//! aggregation result, because an id evicted from the lowest-`k` prefix
//! can never re-enter it.
//!
//! # Sparse overlays (DESIGN.md §9)
//!
//! Everything round-scoped ranges over the transport's overlay
//! *neighborhood* ([`Transport::neighbors`]): the [`PeerTable`] tracks
//! neighbors, wait windows await and aggregate in-neighborhood updates,
//! broadcasts reach neighbors only, and CCC's condition (a) is the
//! quorum test [`quorum_crash_free`] over the neighborhood.  Global
//! information still reaches the whole graph two ways: model content
//! mixes hop-by-hop through successive neighborhood aggregations (gossip
//! averaging), and the CRT terminate flag *relays* — the first flagged
//! update a client receives is forwarded verbatim (origin's sender and
//! round tag preserved) to its own neighborhood, each client forwarding
//! at most once, so one CCC trigger floods the connected overlay in
//! ≤ diameter hops and ≤ n·d total relay messages.  Receivers dedup
//! flagged updates per origin: duplicate copies (direct + relayed) set
//! the flag but are never liveness evidence or aggregation input twice.
//! On the full mesh the relay is disabled: every peer hears the origin
//! directly, and the extra sends would perturb the seeded per-link RNG
//! streams that make full-overlay runs byte-identical to the
//! pre-topology protocol.
//!
//! # Graph faults (DESIGN.md §10)
//!
//! Under a graph-fault schedule the overlay *changes mid-run*: the
//! machine polls [`Transport::topology_generation`] once per round and,
//! on a change, re-scopes its cached neighborhood structure — the
//! [`PeerTable`] tracked set (and with it the quorum denominator) via
//! `retrack`, and the relay gate.  Two churn-safety rules ride along:
//! the CRT relay *re-arms* toward a revived neighbor (the one-shot
//! flood dedup would otherwise strand a peer that was away while the
//! flood passed), and an overlay-isolated client (zero tracked
//! neighbors on a dynamic overlay) paces its rounds through the window
//! and never counts them toward the CCC streak — no solo convergence
//! while disconnected from the graph.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{bail, Result};

use super::async_client::{AsyncClient, ClientData};
use super::config::{ProtocolConfig, QuorumSpec};
use super::failure::{IdSet, PeerTable};
use super::fault::{AdversaryKind, FaultPlan};
use super::sync::{SyncClient, SYNC_GRACE};
use super::termination::{
    quorum_crash_free, ConvergenceMonitor, QuorumController, TerminationCause, TerminationState,
};
use crate::metrics::{ClientReport, RoundRecord};
use crate::model::ParamVector;
use crate::net::delta::{DeltaBody, DeltaMsg, DeltaRx, DeltaTx, FlagMsg, SparseVals};
use crate::net::{ClientId, CodecSpec, ModelUpdate, Msg, Transport};
use crate::runtime::{AggScratch, Meta, TrainScratch, Trainer};
use crate::util::pool;
use crate::util::time::{Clock, SimTime};
use crate::util::Rng;

/// What a machine needs from its executor next.
pub enum Step {
    /// Charge the clock `d` (training cost, fault downtime), then step
    /// again with [`Input::SleepElapsed`].
    Sleep(Duration),
    /// Wait up to `timeout` for one message, then step again with
    /// [`Input::Msg`] or [`Input::Timeout`].
    Recv(Duration),
    /// The client finished; the machine must not be stepped again.
    Done(Box<ClientReport>),
}

/// The executor's answer to the previous [`Step`].
pub enum Input {
    /// First step of a fresh machine.
    Start,
    /// The requested sleep has elapsed.
    SleepElapsed,
    /// A message arrived within the receive window.
    Msg(Msg),
    /// The receive window elapsed without a message.
    Timeout,
}

/// Internal: either yield a [`Step`] to the executor or fall through to
/// the next round (kept iterative so ten thousand zero-wait rounds cannot
/// grow the stack).
enum Flow {
    Yield(Step),
    NextRound,
}

/// One client as a pollable automaton: Phase 2 (async, Algorithm 2) or
/// Phase 1 (sync, Algorithm 1).
pub enum ClientStateMachine<'a> {
    Async(AsyncMachine<'a>),
    Sync(SyncMachine<'a>),
}

impl<'a> ClientStateMachine<'a> {
    /// Advance until the client next needs to wait (or finishes).  `Err`
    /// means a local failure (engine error, Phase-1 barrier overrun); the
    /// machine is then spent.
    pub fn step(&mut self, input: Input) -> Result<Step> {
        match self {
            ClientStateMachine::Async(m) => m.step(input),
            ClientStateMachine::Sync(m) => m.step(input),
        }
    }

    /// The clock this machine's waits are measured on.
    pub fn clock(&self) -> Clock {
        match self {
            ClientStateMachine::Async(m) => m.clock.clone(),
            ClientStateMachine::Sync(m) => m.clock.clone(),
        }
    }

    /// The transport the blocking executor should receive on.
    pub fn transport(&self) -> &(dyn Transport + 'a) {
        match self {
            ClientStateMachine::Async(m) => m.transport.as_ref(),
            ClientStateMachine::Sync(m) => m.transport.as_ref(),
        }
    }

    /// Blocking executor: drive the machine on the current thread, really
    /// sleeping and receiving.  Exactly the pre-state-machine behaviour of
    /// `AsyncClient::run` / `SyncClient::run` under both time regimes.
    pub fn run_blocking(mut self) -> Result<ClientReport> {
        let clock = self.clock();
        let mut input = Input::Start;
        loop {
            match self.step(input)? {
                Step::Sleep(d) => {
                    clock.sleep(d);
                    input = Input::SleepElapsed;
                }
                Step::Recv(timeout) => {
                    input = match self.transport().recv_timeout(timeout) {
                        Some(m) => Input::Msg(m),
                        None => Input::Timeout,
                    };
                }
                Step::Done(report) => return Ok(*report),
            }
        }
    }
}

// --- Phase 2: the asynchronous, fault-tolerant client ----------------------

/// Resting states of the Phase-2 automaton (yield points only; everything
/// between two waits is a synchronous transition).
enum AsyncState {
    /// Created, not yet stepped.
    Boot,
    /// Transient-outage silence: sleeping through the fault downtime.
    Outage,
    /// Charging the modeled (or contention-scaled) training cost.
    Training,
    /// Inside the bounded wait window, between receives.
    AwaitUpdates(Window),
    /// Report emitted; any further step is an executor bug.
    Finished,
}

/// Per-window bookkeeping (see module docs on the `k_max` prefix bound).
struct Window {
    deadline: SimTime,
    /// Peers heard this window (Update/Hello; a Bye is a leave, not a
    /// liveness signal) — the input to the end-of-window suspect sweep.
    heard: IdSet,
    /// Peers alive at window start, whose silence holds the window open.
    awaited: IdSet,
    /// `awaited` members that no longer hold the window open (heard, or
    /// departed via Bye).  Invariant: any peer that *becomes* alive
    /// mid-window did so by sending, so it is heard and never unheard.
    resolved: IdSet,
    /// `awaited.len() - resolved.len()`, maintained for the O(1)
    /// early-exit check.
    awaiting: usize,
    /// Latest updates of the `k_max − 1` lowest-id senders — the only
    /// payloads aggregation can consume.  Kept sorted ascending by sender
    /// id, so iteration matches the `BTreeMap` this used to be, while the
    /// backing storage survives window reuse (DESIGN.md §14): a cleared
    /// `Vec` keeps its capacity where a cleared `BTreeMap` frees its nodes.
    kept: Vec<(ClientId, ModelUpdate)>,
}

impl Window {
    fn empty() -> Window {
        Window {
            deadline: SimTime::ZERO,
            heard: IdSet::new(),
            awaited: IdSet::new(),
            resolved: IdSet::new(),
            awaiting: 0,
            kept: Vec::new(),
        }
    }

    /// `peer` no longer holds the window open.
    fn resolve(&mut self, peer: ClientId) {
        if self.awaited.contains(peer) && self.resolved.insert(peer) {
            self.awaiting -= 1;
        }
    }

    /// Remember `u` as `sender`'s latest update, bounded to the `cap`
    /// lowest-id senders.  Once the prefix is full, only a lower id can
    /// displace its maximum, and a displaced id can never re-enter (the
    /// lowest-`cap` set of a growing id set only ever moves down) — so the
    /// surviving values are exactly what an unbounded map's ascending
    /// prefix would have produced.  Every payload that leaves the prefix
    /// (overwritten, displaced, or refused) goes back to the buffer pool —
    /// decode checked it out, so the stash is where its ownership ends.
    fn stash(&mut self, sender: ClientId, u: ModelUpdate, cap: usize) {
        if cap == 0 {
            pool::recycle_f32(u.params.0);
            return;
        }
        match self.kept.binary_search_by_key(&sender, |(id, _)| *id) {
            Ok(i) => {
                // latest update per sender wins
                let old = std::mem::replace(&mut self.kept[i].1, u);
                pool::recycle_f32(old.params.0);
            }
            Err(i) => {
                if self.kept.len() < cap {
                    self.kept.insert(i, (sender, u));
                    return;
                }
                let max_id = self.kept.last().map_or(sender, |(id, _)| *id);
                if sender < max_id {
                    // dfl-lint: allow(no-panic-hot-path) — max_id came from kept.last(), so kept is provably non-empty on this branch
                    let (_, old) = self.kept.pop().expect("prefix is full, cap > 0");
                    pool::recycle_f32(old.params.0);
                    self.kept.insert(i, (sender, u));
                } else {
                    pool::recycle_f32(u.params.0);
                }
            }
        }
    }
}

/// Per-link delta-codec state (`--codec delta:K[,q16]`, DESIGN.md §13).
/// Empty shells under `--codec dense`: no `Msg::Delta`/`Msg::Flag` traffic
/// exists there, no map entry is ever created, and no send path consults
/// this struct — which is what keeps dense runs byte-identical per seed to
/// the pre-codec protocol.
struct CodecState {
    /// Sender side: per-neighbor acked-base windows ([`DeltaTx`]).
    tx: BTreeMap<ClientId, DeltaTx>,
    /// Receiver side: per-neighbor reconstruction windows ([`DeltaRx`]).
    rx: BTreeMap<ClientId, DeltaRx>,
    /// Peers whose own sends carried the terminate flag — they already
    /// know, so the compact flag relay suppresses the flood toward them.
    /// (The dense relay cannot do this: its forward doubles as the
    /// origin's model payload, which the peer may still need.)
    peers_with_flag: IdSet,
    /// First flag seen under delta mode, as `(origin, round)` — what
    /// [`AsyncMachine::relay_flag`] floods and the revival re-arm repeats.
    /// The delta-mode twin of `relay_msg`, minus the model payload.
    flag_relay: Option<(ClientId, u32)>,
}

impl CodecState {
    fn new() -> CodecState {
        CodecState {
            tx: BTreeMap::new(),
            rx: BTreeMap::new(),
            peers_with_flag: IdSet::new(),
            flag_relay: None,
        }
    }

    /// The anti-entropy piggyback toward `peer`: what of theirs we hold
    /// ([`DeltaRx::ack`]), carried on every delta-mode message we send
    /// them so the reverse direction can promote its base window.
    fn ack_for(&mut self, peer: ClientId) -> crate::net::delta::Ack {
        self.rx.entry(peer).or_insert_with(DeltaRx::new).ack()
    }
}

/// Phase 2 (Algorithm 2) as a state machine.  Per round: local training →
/// (CRT check) → broadcast → bounded wait window → timeout crash detection
/// → aggregate whatever arrived → evaluate → CCC check → next round.  No
/// barriers: a slow peer delays nobody beyond the window, a late message
/// revives a "crashed" peer, and the terminate flag floods via
/// piggybacking (CRT).
pub struct AsyncMachine<'a> {
    id: ClientId,
    trainer: &'a dyn Trainer,
    transport: Box<dyn Transport + 'a>,
    cfg: ProtocolConfig,
    data: ClientData,
    fault: FaultPlan,
    /// Byzantine role (`--adversary`, DESIGN.md §11): `None` = honest.
    /// An adversary runs the full protocol — it trains, receives, and
    /// terminates like anyone else — but [`AsyncMachine::broadcast_model`]
    /// sends lies on its behalf.
    adversary: Option<AdversaryKind>,
    /// [`AdversaryKind::StaleReplay`]'s frozen payload: the first model
    /// this client ever broadcast, re-sent forever under fresh round tags.
    stale_params: Option<Vec<f32>>,
    rng: Rng,
    slowdown: f32,
    train_cost: Option<Duration>,
    clock: Clock,
    meta: Meta,
    my_weight: f32,
    state: AsyncState,
    started: SimTime,
    params: Vec<f32>,
    /// Reusable training scratch (logits/softmax buffers; DESIGN.md §14).
    scratch: TrainScratch,
    /// Reusable aggregation scratch (accumulator + column buffers).
    agg: AggScratch,
    /// Round train tensors, rebuilt in place each round.
    train_xs: Vec<f32>,
    train_ys: Vec<i32>,
    /// Shuffle order for `Dataset::gather_round_into`.
    gather_order: Vec<usize>,
    /// The previous round's window carcass, reopened instead of rebuilt so
    /// its id-sets and stash storage keep their allocations.
    spare: Option<Window>,
    peer_table: PeerTable,
    /// Overlay change counter last seen ([`Transport::topology_generation`]):
    /// a mismatch at the top of a round means graph faults rewired the
    /// neighborhood, and the peer table / quorum denominator resync.
    overlay_gen: u64,
    /// Does the overlay carry a graph-fault schedule?  Gates the
    /// churn-aware paths so static deployments stay byte-identical.
    overlay_dynamic: bool,
    /// Relay first-seen terminate flags onward?  True only on a sparse
    /// overlay (on the full mesh the relay is disabled; see the module
    /// docs on byte-identity).  Refreshed on overlay resync.
    relay_sparse: bool,
    /// Has this client already forwarded a flagged update? (The sender
    /// side of the relay dedup: at most one forward per client per run.)
    relayed: bool,
    /// The first flagged update seen (what the relay forwarded), kept so
    /// the relay can *re-arm* toward a peer that revives after missing
    /// the flood — a crashed-and-rejoined neighbor lost its in-flight
    /// flags, and every other copy it could have heard is spent (its
    /// neighbors relayed once, flagged clients finished).  Without the
    /// re-send the flood provably never reaches it again.
    relay_msg: Option<ModelUpdate>,
    /// Per-client quorum auto-tuner ([`QuorumSpec::Auto`]); idle under a
    /// fixed quorum.
    quorum_ctl: QuorumController,
    /// Delta-codec link state (empty and untouched under `--codec dense`).
    codec: CodecState,
    /// Origins whose flagged update we already processed (the receiver
    /// side of the relay dedup): the flood can deliver the same flagged
    /// broadcast several times — direct plus relayed copies — and only
    /// the first sighting carries liveness/aggregation semantics.  On the
    /// full mesh an origin's flagged update arrives at most once (one
    /// flagged broadcast per client, no relays, no retransmits), so this
    /// set changes nothing there.
    flagged_seen: IdSet,
    term: TerminationState,
    monitor: ConvergenceMonitor,
    history: Vec<RoundRecord>,
    last_train_loss: f32,
    round: u32,
    cause: TerminationCause,
    outage_done: bool,
}

impl<'a> AsyncMachine<'a> {
    pub(super) fn new(c: AsyncClient<'a>) -> AsyncMachine<'a> {
        let clock = c.transport.clock();
        let meta = c.trainer.meta().clone();
        // The `.max(1)` floor keeps a zero-sample partition from claiming
        // weight 0, which the decode-side weight validation (net/message)
        // rejects as unusable aggregation input; the default unweighted
        // path is 1.0 either way, byte-identical to the pre-floor code.
        let my_weight =
            if c.cfg.weight_by_samples { c.data.indices.len().max(1) as f32 } else { 1.0 };
        // Liveness (and therefore quorum-CCC) is neighborhood-scoped: on
        // the full mesh `neighbors()` is the all-peers list and nothing
        // changes; on a sparse overlay only the d neighbors are tracked.
        let neighbors = c.transport.neighbors();
        let peer_table = PeerTable::new(&neighbors);
        let relay_sparse = neighbors.len() < c.transport.n_peers();
        let overlay_dynamic = c.transport.topology_is_dynamic();
        let quorum_ctl = QuorumController::new(match c.cfg.quorum {
            QuorumSpec::Auto { q_min } => q_min,
            QuorumSpec::Fixed(_) => 0.0, // constructed but never consulted
        });
        let monitor = ConvergenceMonitor::new(c.cfg.count_threshold, c.cfg.conv_threshold_rel);
        AsyncMachine {
            id: c.id,
            trainer: c.trainer,
            transport: c.transport,
            cfg: c.cfg,
            data: c.data,
            fault: c.fault,
            adversary: c.adversary,
            stale_params: None,
            rng: c.rng,
            slowdown: c.slowdown,
            train_cost: c.train_cost,
            clock,
            meta,
            my_weight,
            state: AsyncState::Boot,
            started: SimTime::ZERO,
            params: Vec::new(),
            scratch: TrainScratch::default(),
            agg: AggScratch::default(),
            train_xs: Vec::new(),
            train_ys: Vec::new(),
            gather_order: Vec::new(),
            spare: None,
            peer_table,
            overlay_gen: 0,
            overlay_dynamic,
            relay_sparse,
            relayed: false,
            relay_msg: None,
            quorum_ctl,
            codec: CodecState::new(),
            flagged_seen: IdSet::new(),
            term: TerminationState::new(),
            monitor,
            history: Vec::new(),
            last_train_loss: 0.0,
            round: 0,
            cause: TerminationCause::MaxRounds,
            outage_done: false,
        }
    }

    fn step(&mut self, input: Input) -> Result<Step> {
        let state = std::mem::replace(&mut self.state, AsyncState::Finished);
        let mut flow = match (state, input) {
            (AsyncState::Boot, Input::Start) => {
                self.started = self.clock.now();
                self.params = self.trainer.init(self.cfg.model_seed)?;
                self.round_start()?
            }
            (AsyncState::Outage, Input::SleepElapsed) => {
                // Transient failure (§3.1): traffic sent to us during the
                // outage is lost; peers revive us on our next broadcast
                // (PeerTable late-message rule).
                while self.transport.try_recv().is_some() {}
                self.outage_done = true;
                self.train()?
            }
            (AsyncState::Training, Input::SleepElapsed) => self.after_train()?,
            (AsyncState::AwaitUpdates(mut w), Input::Msg(msg)) => {
                self.on_window_msg(&mut w, msg);
                self.window_poll(w)?
            }
            (AsyncState::AwaitUpdates(w), Input::Timeout) => self.window_poll(w)?,
            (AsyncState::Finished, _) => {
                bail!("client {}: stepped after completion", self.id)
            }
            _ => bail!("client {}: executor sent an input the state cannot take", self.id),
        };
        loop {
            match flow {
                Flow::Yield(step) => return Ok(step),
                Flow::NextRound => flow = self.round_start()?,
            }
        }
    }

    /// Top of the round loop: round cap, then fault injection, then train.
    fn round_start(&mut self) -> Result<Flow> {
        if self.round >= self.cfg.max_rounds {
            return self.finalize();
        }
        // Fault injection: benign crash = immediate silence.
        if !self.outage_done
            && self
                .fault
                .should_crash(self.round, self.clock.now().saturating_sub(self.started))
        {
            match self.fault.rejoin_after {
                None => {
                    self.cause = TerminationCause::Crashed;
                    return self.finalize();
                }
                Some(downtime) => {
                    // Full silence for the outage; the downtime charges the
                    // clock, so a 10 s outage under virtual time costs no
                    // real waiting.
                    self.state = AsyncState::Outage;
                    return Ok(Flow::Yield(Step::Sleep(downtime)));
                }
            }
        }
        self.train()
    }

    /// Local training (EPOCHS_PER_ROUND is baked into the train_epoch
    /// artifact's nb_train scan), then the modeled / contention time
    /// charge.
    fn train(&mut self) -> Result<Flow> {
        let t_train = self.clock.now();
        self.data.train.gather_round_into(
            &self.data.indices,
            self.meta.nb_train * self.meta.batch,
            &mut self.rng,
            &mut self.train_xs,
            &mut self.train_ys,
            &mut self.gather_order,
        );
        let train_loss = self.trainer.train_round_scratch(
            &mut self.params,
            &self.train_xs,
            &self.train_ys,
            self.cfg.lr,
            &mut self.scratch,
        )?;
        self.last_train_loss = train_loss;
        // `Some(cost)` (virtual time) charges a deterministic modeled cost;
        // `None` (wall clock) measures real training time and sleeps
        // `slowdown × elapsed` — measured compute time would leak OS
        // nondeterminism into a simulated schedule.
        let charge = match self.train_cost {
            Some(cost) => Some(cost.mul_f32(1.0 + self.slowdown.max(0.0))),
            None if self.slowdown > 0.0 => {
                Some(self.clock.now().saturating_sub(t_train).mul_f32(self.slowdown))
            }
            None => None,
        };
        match charge {
            Some(d) => {
                self.state = AsyncState::Training;
                Ok(Flow::Yield(Step::Sleep(d)))
            }
            None => self.after_train(),
        }
    }

    /// Graph-fault awareness: once per round, check whether the overlay
    /// changed under us (cuts, churn, repairs) and re-scope every cached
    /// neighborhood structure — the tracked peer set (and with it the
    /// quorum denominator) and the relay gate.  On a static overlay the
    /// generation is pinned at 0 and this is a branch-not-taken.
    ///
    /// An *alive entrant* (a neighbor the rewiring just connected us to —
    /// a rejoined churn client, or a repair edge's new endpoint) gets the
    /// stored terminate flag re-sent immediately: it may have been
    /// outside the flood's reach while the flag circulated, and the
    /// one-shot relay dedup means nobody else will repeat it.  This is
    /// the churn-side twin of the revival re-arm in `rearm_relay`.
    fn resync_overlay(&mut self) {
        let gen = self.transport.topology_generation();
        if gen == self.overlay_gen {
            return;
        }
        self.overlay_gen = gen;
        let neighbors = self.transport.neighbors();
        self.relay_sparse = neighbors.len() < self.transport.n_peers();
        // Delta-codec base invalidation on churn/cut (DESIGN.md §13):
        // drop link state for departed neighbors.  Correctness never
        // depends on this — the acked-base protocol self-heals through
        // the `need_full` NACK — it bounds memory on churn-heavy runs.
        // An *entrant* simply has no entry yet, so its first send is a
        // full snapshot (the "no shared base" rule) via the lazy
        // `or_insert` on the send path.
        if self.cfg.codec.is_delta() {
            self.codec.tx.retain(|p, _| neighbors.contains(p));
            self.codec.rx.retain(|p, _| neighbors.contains(p));
        }
        let entered_alive = self.peer_table.retrack(&neighbors);
        for peer in entered_alive {
            self.rearm_relay(peer);
        }
    }

    /// Post-training: overlay resync, CRT fast path, broadcast, open the
    /// wait window.
    fn after_train(&mut self) -> Result<Flow> {
        self.resync_overlay();
        // CRT fast path: flag already known -> final broadcast.
        if self.term.is_set() {
            self.broadcast_model(true);
            self.cause = TerminationCause::Signaled;
            return self.finalize();
        }
        self.broadcast_model(false);
        // Degenerate neighborless deployment (single client): nothing to
        // wait for.  Under graph faults a zero-neighbor state means the
        // client is churned *out*, not alone in the world: it idles
        // through the window (pacing its rounds, catching the rejoin)
        // instead of spinning straight to the round cap.
        if self.peer_table.tracked() == 0 && !self.overlay_dynamic {
            let w = self.open_window(self.clock.now());
            return self.close_window(w);
        }
        let deadline = self.clock.now() + self.cfg.timeout;
        let w = self.open_window(deadline);
        self.window_poll(w)
    }

    /// A window for the current round: the previous round's carcass with
    /// its id-sets cleared (keeping their bit-vector storage) and the
    /// awaited set rebuilt from the live peer table, or a fresh one on the
    /// first round.  Same observable state as building from scratch.
    fn open_window(&mut self, deadline: SimTime) -> Window {
        let mut w = self.spare.take().unwrap_or_else(Window::empty);
        w.deadline = deadline;
        w.heard.clear();
        w.resolved.clear();
        self.peer_table.alive_ids_into(&mut w.awaited);
        w.awaiting = w.awaited.len();
        debug_assert!(w.kept.is_empty(), "close_window drains the stash");
        w
    }

    /// One turn of the wait-window loop: close on deadline or early exit,
    /// otherwise ask for the next message.
    fn window_poll(&mut self, w: Window) -> Result<Flow> {
        let now = self.clock.now();
        if now >= w.deadline {
            return self.close_window(w);
        }
        // Every currently-alive peer reported (or none are left at all):
        // nothing further can arrive this window but latecomers.
        if self.cfg.early_window_exit && w.awaiting == 0 && !w.heard.is_empty() {
            return self.close_window(w);
        }
        let remaining = w.deadline - now;
        self.state = AsyncState::AwaitUpdates(w);
        Ok(Flow::Yield(Step::Recv(remaining)))
    }

    /// Process one in-window message: CRT flags (with the sparse-overlay
    /// relay) and liveness as they arrive.  Liveness, window bookkeeping,
    /// and aggregation stashing apply only to *tracked* (in-neighborhood)
    /// senders; a relayed update from a distant origin contributes its
    /// terminate flag and nothing else.
    fn on_window_msg(&mut self, w: &mut Window, msg: Msg) {
        let sender = msg.sender();
        let tracked = self.peer_table.status(sender).is_some();
        match msg {
            Msg::Update(u) => self.on_update(w, u),
            Msg::Delta(d) => self.on_delta(w, d),
            Msg::Flag(f) => self.on_flag(w, f),
            Msg::Hello { .. } => {
                if tracked {
                    let revived = self.peer_table.record_message(sender, self.round, false);
                    w.heard.insert(sender);
                    w.resolve(sender);
                    if revived {
                        self.rearm_relay(sender);
                    }
                }
            }
            Msg::Bye { .. } => {
                if tracked {
                    self.peer_table.record_message(sender, self.round, true);
                    // Now Terminated, no longer alive: its silence must not
                    // hold the window open.
                    w.resolve(sender);
                }
            }
        }
    }

    /// Shared handling of a full model update — dense `Msg::Update`
    /// traffic, and the synthesized equivalent of a successfully
    /// reconstructed `Msg::Delta` (one window/liveness/CCC code path for
    /// both codecs, so the protocol semantics cannot drift between them).
    fn on_update(&mut self, w: &mut Window, u: ModelUpdate) {
        let sender = u.sender;
        let tracked = self.peer_table.status(sender).is_some();
        if u.terminate {
            self.codec.peers_with_flag.insert(sender);
        }
        // Receiver-side relay dedup: only the first flagged update
        // per origin carries liveness/aggregation semantics; a
        // later (relayed) copy would otherwise re-stash the
        // origin's stale round-r model into a later window.  The
        // first copy to arrive — direct or relayed, they are
        // byte-identical — wins.
        let fresh = !u.terminate || self.flagged_seen.insert(sender);
        if u.terminate && self.cfg.crt_enabled {
            self.term.signal_from(sender, self.round);
            self.relay_terminate(&u);
        }
        if tracked && fresh {
            let revived = self.peer_table.record_message(sender, self.round, u.terminate);
            let carried_flag = u.terminate;
            w.heard.insert(sender);
            w.resolve(sender);
            w.stash(sender, u, self.meta.k_max.saturating_sub(1));
            // A revival whose own message carried the flag needs no
            // re-arm — that peer terminated knowingly.
            if revived && !carried_flag {
                self.rearm_relay(sender);
            }
        } else {
            // Untracked or duplicate-flagged payload: decode checked this
            // buffer out of the pool; hand it back instead of dropping it.
            pool::recycle_f32(u.params.0);
        }
    }

    /// One delta-codec model broadcast (DESIGN.md §13).  The ack
    /// piggyback advances our sender-side base window for this peer
    /// whether or not the body reconstructs; a successful reconstruction
    /// then flows through [`AsyncMachine::on_update`] exactly like a
    /// dense update.
    fn on_delta(&mut self, w: &mut Window, d: DeltaMsg) {
        let sender = d.sender;
        self.codec.tx.entry(sender).or_insert_with(DeltaTx::new).on_ack(&d.ack);
        // Bound to a local first: a match scrutinee's temporaries (here the
        // map `Entry` and its borrow of `self`) live through the arms.
        let decoded =
            self.codec.rx.entry(sender).or_insert_with(DeltaRx::new).decode(d.round, &d.body);
        match decoded {
            Some(params) => self.on_update(
                w,
                ModelUpdate {
                    sender,
                    round: d.round,
                    terminate: d.terminate,
                    weight: d.weight,
                    params: ParamVector(params),
                },
            ),
            None => {
                // No shared base (boot race, NACK window): the ack we
                // piggyback on our next send carries `need_full`, and the
                // sender falls back to a snapshot — self-healing.  The
                // bytes still prove the sender alive, and its terminate
                // flag still counts: dropping either would turn a codec
                // miss into a false crash suspicion or a lost flood.
                if d.terminate {
                    self.codec.peers_with_flag.insert(sender);
                    if self.cfg.crt_enabled {
                        self.term.signal_from(sender, self.round);
                        self.relay_flag(sender, d.round);
                    }
                }
                if self.peer_table.status(sender).is_some() {
                    let revived =
                        self.peer_table.record_message(sender, self.round, d.terminate);
                    w.heard.insert(sender);
                    w.resolve(sender);
                    if revived && !d.terminate {
                        self.rearm_relay(sender);
                    }
                }
            }
        }
    }

    /// A compact CRT flag relay (delta mode's replacement for the dense
    /// full-model forward): provenance and round tag, no model payload.
    fn on_flag(&mut self, w: &mut Window, f: FlagMsg) {
        self.codec.tx.entry(f.sender).or_insert_with(DeltaTx::new).on_ack(&f.ack);
        // The forwarder evidently knows the flag; so, by construction,
        // does the origin.
        self.codec.peers_with_flag.insert(f.sender);
        self.codec.peers_with_flag.insert(f.origin);
        if self.cfg.crt_enabled {
            self.term.signal_from(f.origin, self.round);
            self.relay_flag(f.origin, f.round);
        }
        // First sighting of this origin's flag: the origin is finishing,
        // so its silence must not hold windows open or read as a crash.
        if self.peer_table.status(f.origin).is_some() && self.flagged_seen.insert(f.origin) {
            self.peer_table.record_message(f.origin, self.round, true);
            w.resolve(f.origin);
        }
    }

    /// CRT flag relay over a sparse overlay: forward the first flagged
    /// update we see to our whole neighborhood, verbatim (the origin's
    /// sender id and round tag ride along, so provenance and round
    /// accounting survive multi-hop).  Each client forwards at most once
    /// per run — with the receiver-side `flagged_seen` dedup that bounds
    /// the flood at one message per directed edge (≤ n·d total) while
    /// still reaching every client of the connected graph within diameter
    /// hops.  Forwarding uses `broadcast` for its encode-once path (one
    /// serialization instead of d); the origin may be among the
    /// recipients, but it has already terminated and sends to finished
    /// clients are swallowed by the crash model.  No-op on the full mesh:
    /// there every peer hears the origin directly, and extra sends would
    /// shift the seeded link streams.
    fn relay_terminate(&mut self, u: &ModelUpdate) {
        if self.cfg.codec.is_delta() {
            // Delta mode relays the flag, not the model: ~20 bytes of
            // provenance instead of a dense forward (anti-entropy — the
            // neighborhood already has our model content via deltas).
            self.relay_flag(u.sender, u.round);
            return;
        }
        if !self.relay_sparse {
            return;
        }
        if self.relay_msg.is_none() {
            // Keep the first flagged update for the re-arm path below,
            // whether or not we are the one who forwards the flood.
            self.relay_msg = Some(u.clone());
        }
        if self.relayed {
            return;
        }
        self.relayed = true;
        // Best-effort, like every send under the crash model.
        let _ = self.transport.broadcast(&Msg::Update(u.clone()));
    }

    /// Delta-mode twin of [`AsyncMachine::relay_terminate`]: flood a
    /// compact [`FlagMsg`] — suppressed toward peers whose own sends
    /// already carried the flag (they know; repeating it buys nothing),
    /// which the dense relay cannot do because its forward is also the
    /// origin's model payload.  Same one-forward-per-client dedup
    /// (`relayed`) and same sparse-only gate as the dense path.
    fn relay_flag(&mut self, origin: ClientId, round: u32) {
        if !self.relay_sparse {
            return;
        }
        if self.codec.flag_relay.is_none() {
            // Kept for the revival/entrant re-arm, whether or not we are
            // the one who forwards the flood (see `relay_msg`).
            self.codec.flag_relay = Some((origin, round));
        }
        if self.relayed {
            return;
        }
        self.relayed = true;
        for peer in self.transport.neighbors() {
            if self.codec.peers_with_flag.contains(peer) {
                continue;
            }
            let ack = self.codec.ack_for(peer);
            let msg = Msg::Flag(FlagMsg { sender: self.id, origin, round, ack });
            let _ = self.transport.send(peer, &msg);
        }
    }

    /// Relay re-arm (bugfix, DESIGN.md §10): the flood's dedup is
    /// one-shot — each client forwards at most once — so a neighbor that
    /// crashed with `rejoin_after` set and drained its mailbox on resume
    /// can have missed every copy of the terminate flag with nobody left
    /// to repeat it (flagged clients finish one round after flagging).
    /// A *revival* of a tracked peer is exactly that situation becoming
    /// visible, so the stored flagged update is re-sent to the revived
    /// peer directly.  Sparse-overlay only (`relay_msg` is never stored
    /// on the full mesh, where the origin's broadcast already reached
    /// every peer and extra sends would break byte-identity); duplicate
    /// deliveries are harmless — the receiver-side per-origin dedup
    /// ignores all but the first copy.
    fn rearm_relay(&mut self, peer: ClientId) {
        if self.cfg.codec.is_delta() {
            if let Some((origin, round)) = self.codec.flag_relay {
                if !self.codec.peers_with_flag.contains(peer) {
                    let ack = self.codec.ack_for(peer);
                    let msg = Msg::Flag(FlagMsg { sender: self.id, origin, round, ack });
                    let _ = self.transport.send(peer, &msg);
                }
            }
            return;
        }
        if let Some(flag) = &self.relay_msg {
            let _ = self.transport.send(peer, &Msg::Update(flag.clone()));
        }
    }

    /// End of window: suspect sweep, aggregate, evaluate, CCC — the
    /// synchronous tail of Algorithm 2's round.
    fn close_window(&mut self, mut w: Window) -> Result<Flow> {
        // Crash detection (Alg. 2 lines 14-19).
        let newly_crashed = self.peer_table.mark_missing(self.round, &w.heard);
        // Aggregate own + received (Alg. 2 lines 20-21), through the
        // configured rule: `fedavg` is the trainer's weighted mean
        // (byte-identical pre-rule path); the robust rules bound what a
        // Byzantine row can do to the result (DESIGN.md §11).  The result
        // lands in the reusable accumulator and is swapped into `params`;
        // the stash's pooled payloads go back to the pool.
        let aggregated = {
            let mut rows: Vec<(&[f32], f32)> = Vec::with_capacity(1 + w.kept.len());
            rows.push((&self.params, self.my_weight));
            for (_, u) in &w.kept {
                rows.push((u.params.as_slice(), u.weight.max(0.0)));
            }
            let trainer = self.trainer;
            trainer.aggregate_with_scratch(&rows, &self.cfg.agg, &mut self.agg)?;
            rows.len()
        };
        std::mem::swap(&mut self.params, &mut self.agg.out);
        for (_, u) in w.kept.drain(..) {
            pool::recycle_f32(u.params.0);
        }
        // Park the carcass: next round's `open_window` reuses its storage.
        self.spare = Some(w);
        // Evaluate (Alg. 2 line 22).
        let (correct, _eval_loss) = self.trainer.eval_scratch(
            &self.params,
            &self.data.eval.eval_xs,
            &self.data.eval.eval_ys,
            false,
            &mut self.scratch,
        )?;
        let probe_acc = correct as f32 / self.data.eval.eval_ys.len() as f32;
        // CCC check (Alg. 2 lines 23-34), condition (a) generalized to the
        // neighborhood quorum: at q = 1.0 this is exactly the paper's
        // `newly_crashed.is_empty()`.  Under `--quorum auto` the q is the
        // controller's, derived from *previous* windows only (this
        // window's sweep is folded in after judging it, so a fresh
        // mass-crash spike is always judged against the pre-spike
        // tolerance).
        let tracked = self.peer_table.tracked();
        let q = match self.cfg.quorum {
            QuorumSpec::Fixed(q) => q,
            QuorumSpec::Auto { .. } => self.quorum_ctl.q(tracked),
        };
        let mut crash_free = quorum_crash_free(newly_crashed.len(), tracked, q);
        if let QuorumSpec::Auto { .. } = self.cfg.quorum {
            self.quorum_ctl.observe(newly_crashed.len(), tracked);
        }
        // A churned-out client (zero tracked neighbors on a dynamic
        // overlay) has no quorum to confirm anything with: its solo
        // rounds never count toward the stability streak, so it cannot
        // self-converge and terminate while disconnected from the graph.
        // The static neighborless case (a genuine single-client
        // deployment) keeps the pre-fault always-crash-free behaviour.
        if self.overlay_dynamic && tracked == 0 {
            crash_free = false;
        }
        let ccc = self.monitor.observe_slice(&self.params, crash_free, aggregated);
        self.history.push(RoundRecord {
            round: self.round,
            train_loss: self.last_train_loss,
            probe_acc,
            alive_peers: self.peer_table.alive_count(),
            aggregated,
            delta_rel: self.monitor.last_delta_rel,
            conv_counter: self.monitor.counter(),
            crashes_detected: newly_crashed,
        });
        if self.round >= self.cfg.min_rounds && ccc {
            self.term.self_trigger(self.round);
            self.broadcast_model(true);
            self.cause = TerminationCause::Converged;
            self.round += 1;
            return self.finalize();
        }
        // CRT: a flag that arrived during this window is honored at the
        // top of the next iteration, after one more local update
        // (Alg. 2 lines 8-10).
        self.round += 1;
        Ok(Flow::NextRound)
    }

    /// Terminating tail (Alg. 2 lines 39-42): final broadcast on a round
    /// cap, Bye, full evaluation, report.
    fn finalize(&mut self) -> Result<Flow> {
        let (final_accuracy, final_loss, final_params) =
            if self.cause == TerminationCause::Crashed {
                (None, None, None)
            } else {
                if self.cause == TerminationCause::MaxRounds {
                    // Max rounds reached: log and broadcast final weights.
                    self.broadcast_model(true);
                }
                let _ = self.transport.broadcast(&Msg::Bye { sender: self.id });
                let (correct, loss) = self.trainer.eval(
                    &self.params,
                    &self.data.eval.full_xs,
                    &self.data.eval.full_ys,
                    true,
                )?;
                (
                    Some(correct as f32 / self.data.eval.full_ys.len() as f32),
                    Some(loss),
                    Some(std::mem::take(&mut self.params)),
                )
            };
        let report = ClientReport {
            id: self.id,
            cause: self.cause,
            rounds_completed: self.round,
            final_accuracy,
            final_loss,
            wall: self.clock.now().saturating_sub(self.started),
            history: std::mem::take(&mut self.history),
            signal_source: self.term.source,
            final_params,
        };
        self.state = AsyncState::Finished;
        Ok(Flow::Yield(Step::Done(Box::new(report))))
    }

    /// Broadcast this round's model — or, for a Byzantine client, this
    /// round's lie (DESIGN.md §11).  The adversary branches touch only
    /// *this* client's RNG stream and sends: honest clients' seeded
    /// streams are untouched, so an all-honest run stays byte-identical
    /// per seed whether or not the adversary machinery exists.
    fn broadcast_model(&mut self, terminate: bool) {
        let update = |params: Vec<f32>, sender: ClientId, round: u32, weight: f32| {
            Msg::Update(ModelUpdate {
                sender,
                round,
                terminate,
                weight,
                params: ParamVector(params),
            })
        };
        match self.adversary {
            // Honest path: the true model to the whole neighborhood.
            // Best-effort: unreachable peers are handled by the crash model.
            None => {
                if let CodecSpec::Delta { k, q16 } = self.cfg.codec {
                    // Per-link bodies: each neighbor's delta is encoded
                    // against the base *that neighbor* acked, so one
                    // broadcast becomes d tailored sends (DESIGN.md §13).
                    for peer in self.transport.neighbors() {
                        let ack = self.codec.ack_for(peer);
                        let body = self
                            .codec
                            .tx
                            .entry(peer)
                            .or_insert_with(DeltaTx::new)
                            .encode(k, q16, self.round, &self.params);
                        let msg = Msg::Delta(DeltaMsg {
                            sender: self.id,
                            round: self.round,
                            terminate,
                            weight: self.my_weight,
                            ack,
                            body,
                        });
                        let _ = self.transport.send(peer, &msg);
                        // `send` serialized the body; its pooled payload
                        // (full snapshot or raw sparse values) goes back.
                        let Msg::Delta(dm) = msg else { unreachable!("built as a delta") };
                        match dm.body {
                            DeltaBody::Full(v) => pool::recycle_f32(v),
                            DeltaBody::Sparse { vals: SparseVals::F32(v), .. } => {
                                pool::recycle_f32(v)
                            }
                            DeltaBody::Sparse { .. } => {}
                        }
                    }
                } else {
                    // Shuttle `params` through the message instead of
                    // cloning it: `broadcast` only borrows, so the buffer
                    // comes straight back.
                    let msg =
                        update(std::mem::take(&mut self.params), self.id, self.round, self.my_weight);
                    let _ = self.transport.broadcast(&msg);
                    let Msg::Update(u) = msg else { unreachable!("built as an update") };
                    self.params = u.params.0;
                }
            }
            // Every coordinate scaled (negative = inverted direction):
            // dominates a mean, gets trimmed/out-voted by robust rules.
            Some(AdversaryKind::Poison { scale }) => {
                let mut lie = pool::take_f32(self.params.len());
                lie.extend(self.params.iter().map(|v| v * scale));
                let msg = update(lie, self.id, self.round, self.my_weight);
                let _ = self.transport.broadcast(&msg);
                let Msg::Update(u) = msg else { unreachable!("built as an update") };
                pool::recycle_f32(u.params.0);
            }
            // The first model ever broadcast, frozen, re-sent under this
            // round's fresh tag — freshness checks pass, content is stale.
            // One clone ever (freezing round): afterwards the frozen buffer
            // shuttles through the message and back.
            Some(AdversaryKind::StaleReplay) => {
                let stale = self.stale_params.take().unwrap_or_else(|| self.params.clone());
                let msg = update(stale, self.id, self.round, self.my_weight);
                let _ = self.transport.broadcast(&msg);
                let Msg::Update(u) = msg else { unreachable!("built as an update") };
                self.stale_params = Some(u.params.0);
            }
            // A different lie to every neighbor: each gets the true model
            // scaled by an independent draw from this client's own seeded
            // stream, so no two neighbors can agree on what we said.
            Some(AdversaryKind::Equivocate) => {
                for peer in self.transport.neighbors() {
                    let factor = self.rng.range_f32(-2.0, 2.0);
                    let mut lie = pool::take_f32(self.params.len());
                    lie.extend(self.params.iter().map(|v| v * factor));
                    let msg = update(lie, self.id, self.round, self.my_weight);
                    let _ = self.transport.send(peer, &msg);
                    let Msg::Update(u) = msg else { unreachable!("built as an update") };
                    pool::recycle_f32(u.params.0);
                }
            }
            // Manufactured suspicion churn: the true model, but only to
            // alternating halves of the neighborhood each round — every
            // neighbor perpetually timeout-suspects us, then revives us on
            // the next round's message.  Under strict quorum (q = 1.0)
            // each fresh suspicion resets the CCC streak; `--quorum auto`
            // learns the flap rate instead (DESIGN.md §11).
            Some(AdversaryKind::ForgeSuspicion) => {
                let msg =
                    update(std::mem::take(&mut self.params), self.id, self.round, self.my_weight);
                for (idx, peer) in self.transport.neighbors().into_iter().enumerate() {
                    if (idx as u32 + self.round) % 2 == 0 {
                        let _ = self.transport.send(peer, &msg);
                    }
                }
                let Msg::Update(u) = msg else { unreachable!("built as an update") };
                self.params = u.params.0;
            }
        }
    }
}

// --- Phase 1: round-synchronized client ------------------------------------

/// Resting states of the Phase-1 automaton.
enum SyncState {
    Boot,
    /// Charging the modeled / contention training cost.
    Training,
    /// Blocked on the round barrier: waiting for every peer's round-tagged
    /// model.
    Collect {
        deadline: SimTime,
        got: BTreeMap<ClientId, ModelUpdate>,
        terminate_seen: bool,
    },
    Finished,
}

/// Phase 1 (Algorithm 1) as a state machine.  Each round every client
/// trains locally, broadcasts ⟨M_i, round⟩, then *waits* until models from
/// all other clients for the same round have arrived, aggregates the
/// average, and advances.  No crash tolerance: a peer that never reports
/// is a deployment error, surfaced after a liberal grace period rather
/// than masked.  Termination mirrors the paper's "mutual agreement": any
/// client whose convergence monitor fires broadcasts its round-tagged
/// model with the terminate flag; every client finishes that same round —
/// all clients therefore complete an identical number of rounds.
pub struct SyncMachine<'a> {
    id: ClientId,
    trainer: &'a dyn Trainer,
    transport: Box<dyn Transport + 'a>,
    cfg: ProtocolConfig,
    data: ClientData,
    rng: Rng,
    slowdown: f32,
    train_cost: Option<Duration>,
    clock: Clock,
    meta: Meta,
    my_weight: f32,
    n_peers: usize,
    state: SyncState,
    started: SimTime,
    params: Vec<f32>,
    /// Reusable training / aggregation scratch and round train tensors —
    /// same hot-loop discipline as the async machine (DESIGN.md §14).
    scratch: TrainScratch,
    agg: AggScratch,
    train_xs: Vec<f32>,
    train_ys: Vec<i32>,
    gather_order: Vec<usize>,
    monitor: ConvergenceMonitor,
    history: Vec<RoundRecord>,
    last_train_loss: f32,
    /// Early/late updates buffered across rounds — the paper's round tag
    /// exists precisely to tolerate out-of-order arrival.
    pending: Vec<ModelUpdate>,
    round: u32,
    cause: TerminationCause,
    want_terminate: bool,
}

impl<'a> SyncMachine<'a> {
    pub(super) fn new(c: SyncClient<'a>) -> SyncMachine<'a> {
        let clock = c.transport.clock();
        let meta = c.trainer.meta().clone();
        // Same zero-sample floor as the async machine (see there).
        let my_weight =
            if c.cfg.weight_by_samples { c.data.indices.len().max(1) as f32 } else { 1.0 };
        let n_peers = c.transport.n_peers();
        let monitor = ConvergenceMonitor::new(c.cfg.count_threshold, c.cfg.conv_threshold_rel);
        SyncMachine {
            id: c.id,
            trainer: c.trainer,
            transport: c.transport,
            cfg: c.cfg,
            data: c.data,
            rng: c.rng,
            slowdown: c.slowdown,
            train_cost: c.train_cost,
            clock,
            meta,
            my_weight,
            n_peers,
            state: SyncState::Boot,
            started: SimTime::ZERO,
            params: Vec::new(),
            scratch: TrainScratch::default(),
            agg: AggScratch::default(),
            train_xs: Vec::new(),
            train_ys: Vec::new(),
            gather_order: Vec::new(),
            monitor,
            history: Vec::new(),
            last_train_loss: 0.0,
            pending: Vec::new(),
            round: 0,
            cause: TerminationCause::MaxRounds,
            want_terminate: false,
        }
    }

    fn step(&mut self, input: Input) -> Result<Step> {
        let state = std::mem::replace(&mut self.state, SyncState::Finished);
        let mut flow = match (state, input) {
            (SyncState::Boot, Input::Start) => {
                self.started = self.clock.now();
                self.params = self.trainer.init(self.cfg.model_seed)?;
                self.round_start()?
            }
            (SyncState::Training, Input::SleepElapsed) => self.after_train()?,
            (
                SyncState::Collect { deadline, mut got, mut terminate_seen },
                Input::Msg(msg),
            ) => {
                if let Msg::Update(u) = msg {
                    match u.round.cmp(&self.round) {
                        std::cmp::Ordering::Equal => {
                            // The terminate flag only counts for the round
                            // it is tagged with: honoring a *future*
                            // round's flag here would stop this client one
                            // round before its peers and deadlock their
                            // barrier (they wait on us).
                            if u.terminate {
                                terminate_seen = true;
                            }
                            got.insert(u.sender, u);
                        }
                        std::cmp::Ordering::Greater => self.pending.push(u),
                        std::cmp::Ordering::Less => {} // stale duplicate
                    }
                }
                self.collect_poll(deadline, got, terminate_seen)?
            }
            (SyncState::Collect { deadline, got, terminate_seen }, Input::Timeout) => {
                self.collect_poll(deadline, got, terminate_seen)?
            }
            (SyncState::Finished, _) => {
                bail!("client {}: stepped after completion", self.id)
            }
            _ => bail!("client {}: executor sent an input the state cannot take", self.id),
        };
        loop {
            match flow {
                Flow::Yield(step) => return Ok(step),
                Flow::NextRound => flow = self.round_start()?,
            }
        }
    }

    fn round_start(&mut self) -> Result<Flow> {
        if self.round >= self.cfg.max_rounds {
            return self.finalize();
        }
        // Local update.
        let t_train = self.clock.now();
        self.data.train.gather_round_into(
            &self.data.indices,
            self.meta.nb_train * self.meta.batch,
            &mut self.rng,
            &mut self.train_xs,
            &mut self.train_ys,
            &mut self.gather_order,
        );
        let train_loss = self.trainer.train_round_scratch(
            &mut self.params,
            &self.train_xs,
            &self.train_ys,
            self.cfg.lr,
            &mut self.scratch,
        )?;
        self.last_train_loss = train_loss;
        let charge = match self.train_cost {
            Some(cost) => Some(cost.mul_f32(1.0 + self.slowdown.max(0.0))),
            None if self.slowdown > 0.0 => {
                Some(self.clock.now().saturating_sub(t_train).mul_f32(self.slowdown))
            }
            None => None,
        };
        match charge {
            Some(d) => {
                self.state = SyncState::Training;
                Ok(Flow::Yield(Step::Sleep(d)))
            }
            None => self.after_train(),
        }
    }

    /// Broadcast ⟨M_i, round⟩ (terminate flag set if our CCC fired last
    /// round — the "mutual agreement" carrier), then open the barrier.
    fn after_train(&mut self) -> Result<Flow> {
        // Shuttle `params` through the message instead of cloning it —
        // `broadcast` only borrows (see the async machine's honest path).
        let msg = Msg::Update(ModelUpdate {
            sender: self.id,
            round: self.round,
            terminate: self.want_terminate,
            weight: self.my_weight,
            params: ParamVector(std::mem::take(&mut self.params)),
        });
        let _ = self.transport.broadcast(&msg);
        let Msg::Update(own) = msg else { unreachable!("built as an update") };
        self.params = own.params.0;
        let mut terminate_seen = self.want_terminate;
        let mut got: BTreeMap<ClientId, ModelUpdate> = BTreeMap::new();
        let round = self.round;
        // Pull matching updates already buffered.
        self.pending.retain(|u| {
            if u.round == round {
                if u.terminate {
                    terminate_seen = true;
                }
                got.insert(u.sender, u.clone());
                false
            } else {
                u.round > round // drop stale rounds, keep future ones
            }
        });
        let deadline = self.clock.now() + SYNC_GRACE;
        self.collect_poll(deadline, got, terminate_seen)
    }

    /// One turn of the barrier: complete, overrun, or wait for the next
    /// update.
    fn collect_poll(
        &mut self,
        deadline: SimTime,
        got: BTreeMap<ClientId, ModelUpdate>,
        terminate_seen: bool,
    ) -> Result<Flow> {
        if got.len() >= self.n_peers {
            return self.close_round(got, terminate_seen);
        }
        let now = self.clock.now();
        if now >= deadline {
            bail!(
                "sync client {}: round {} incomplete after {:?} \
                 ({}/{} peers) — Phase 1 assumes a fault-free system",
                self.id,
                self.round,
                SYNC_GRACE,
                got.len(),
                self.n_peers
            );
        }
        let remaining = deadline - now;
        self.state = SyncState::Collect { deadline, got, terminate_seen };
        Ok(Flow::Yield(Step::Recv(remaining)))
    }

    fn close_round(
        &mut self,
        got: BTreeMap<ClientId, ModelUpdate>,
        terminate_seen: bool,
    ) -> Result<Flow> {
        // Aggregate own + all peers (Algorithm 1 line 12), through the
        // configured rule (fedavg default = the pre-rule weighted mean),
        // into the reusable accumulator.
        let aggregated = {
            let mut rows: Vec<(&[f32], f32)> = Vec::with_capacity(self.meta.k_max);
            rows.push((&self.params, self.my_weight));
            for u in got.values().take(self.meta.k_max - 1) {
                rows.push((u.params.as_slice(), u.weight.max(0.0)));
            }
            let trainer = self.trainer;
            trainer.aggregate_with_scratch(&rows, &self.cfg.agg, &mut self.agg)?;
            rows.len()
        };
        std::mem::swap(&mut self.params, &mut self.agg.out);
        let (correct, _) = self.trainer.eval_scratch(
            &self.params,
            &self.data.eval.eval_xs,
            &self.data.eval.eval_ys,
            false,
            &mut self.scratch,
        )?;
        let probe_acc = correct as f32 / self.data.eval.eval_ys.len() as f32;
        let ccc = self.monitor.observe_slice(&self.params, true, aggregated);
        self.history.push(RoundRecord {
            round: self.round,
            train_loss: self.last_train_loss,
            probe_acc,
            alive_peers: self.n_peers,
            aggregated,
            delta_rel: self.monitor.last_delta_rel,
            conv_counter: self.monitor.counter(),
            crashes_detected: Vec::new(),
        });
        self.round += 1;
        // Mutual-agreement termination: if anyone (us included) carried the
        // flag this round, every client stops at this same boundary.
        if terminate_seen {
            self.cause = if self.want_terminate {
                TerminationCause::Converged
            } else {
                TerminationCause::Signaled
            };
            return self.finalize();
        }
        if self.round >= self.cfg.min_rounds && ccc {
            // Fire our flag next round so all peers see the same tag.
            self.want_terminate = true;
        }
        Ok(Flow::NextRound)
    }

    fn finalize(&mut self) -> Result<Flow> {
        let (correct, loss) = self.trainer.eval(
            &self.params,
            &self.data.eval.full_xs,
            &self.data.eval.full_ys,
            true,
        )?;
        let report = ClientReport {
            id: self.id,
            cause: self.cause,
            rounds_completed: self.round,
            final_accuracy: Some(correct as f32 / self.data.eval.full_ys.len() as f32),
            final_loss: Some(loss),
            wall: self.clock.now().saturating_sub(self.started),
            history: std::mem::take(&mut self.history),
            signal_source: None,
            final_params: Some(std::mem::take(&mut self.params)),
        };
        self.state = SyncState::Finished;
        Ok(Flow::Yield(Step::Done(Box::new(report))))
    }
}
