//! Phase 1 — round-based synchronization over an asynchronous network
//! (Algorithm 1).
//!
//! Each round every client trains locally, broadcasts ⟨M_i, round⟩, then
//! *blocks* until models from all other clients for the same round have
//! arrived, aggregates the average, and advances.  No crash tolerance:
//! Phase 1 assumes a fault-free system (the paper's baseline), so a peer
//! that never reports is a deployment error, surfaced after a liberal
//! grace period rather than masked.
//!
//! Termination mirrors the paper's "mutual agreement": any client whose
//! convergence monitor fires broadcasts its round-tagged model with the
//! terminate flag; every client finishes that same round and stops — all
//! clients therefore complete an identical number of rounds.
//!
//! The loop itself lives in [`super::machine::SyncMachine`] as a
//! poll-style state machine; [`SyncClient`] is the construction surface
//! plus the blocking driver.

use std::time::Duration;

use anyhow::Result;

use super::async_client::ClientData;
use super::config::ProtocolConfig;
use super::machine::{ClientStateMachine, SyncMachine};
use crate::metrics::ClientReport;
use crate::net::{ClientId, Transport};
use crate::runtime::Trainer;
use crate::util::Rng;

/// Hard cap on how long a Phase-1 client waits for one round's peers.
pub(crate) const SYNC_GRACE: Duration = Duration::from_secs(120);

/// One Phase-1 participant.  Fill the fields, then either
/// [`run`](SyncClient::run) on this thread or
/// [`into_machine`](SyncClient::into_machine) for an event-driven
/// executor.
pub struct SyncClient<'a> {
    pub id: ClientId,
    pub trainer: &'a dyn Trainer,
    pub transport: Box<dyn Transport + 'a>,
    pub cfg: ProtocolConfig,
    pub data: ClientData,
    pub rng: Rng,
    pub slowdown: f32,
    /// Modeled per-round training cost (see
    /// [`AsyncClient::train_cost`](super::async_client::AsyncClient)).
    pub train_cost: Option<Duration>,
}

impl<'a> SyncClient<'a> {
    /// Lift this client into its poll-style state machine (no thread
    /// needed; see [`super::machine`]).
    pub fn into_machine(self) -> ClientStateMachine<'a> {
        ClientStateMachine::Sync(SyncMachine::new(self))
    }

    /// Run Algorithm 1 to completion on the current thread.
    pub fn run(self) -> Result<ClientReport> {
        self.into_machine().run_blocking()
    }
}
