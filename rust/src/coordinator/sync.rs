//! Phase 1 — round-based synchronization over an asynchronous network
//! (Algorithm 1).
//!
//! Each round every client trains locally, broadcasts ⟨M_i, round⟩, then
//! *blocks* until models from all other clients for the same round have
//! arrived, aggregates the average, and advances.  No crash tolerance:
//! Phase 1 assumes a fault-free system (the paper's baseline), so a peer
//! that never reports is a deployment error, surfaced after a liberal
//! grace period rather than masked.
//!
//! Termination mirrors the paper's "mutual agreement": any client whose
//! convergence monitor fires broadcasts its round-tagged model with the
//! terminate flag; every client finishes that same round and stops — all
//! clients therefore complete an identical number of rounds.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{bail, Result};

use super::async_client::ClientData;
use super::config::ProtocolConfig;
use super::termination::{ConvergenceMonitor, TerminationCause};
use crate::metrics::{ClientReport, RoundRecord};
use crate::model::ParamVector;
use crate::net::{ClientId, ModelUpdate, Msg, Transport};
use crate::runtime::Trainer;
use crate::util::time::Clock;
use crate::util::Rng;

/// Hard cap on how long a Phase-1 client waits for one round's peers.
const SYNC_GRACE: Duration = Duration::from_secs(120);

/// One Phase-1 participant.
pub struct SyncClient<'a> {
    pub id: ClientId,
    pub trainer: &'a dyn Trainer,
    pub transport: Box<dyn Transport + 'a>,
    pub cfg: ProtocolConfig,
    pub data: ClientData,
    pub rng: Rng,
    pub slowdown: f32,
    /// Modeled per-round training cost (see
    /// [`AsyncClient::train_cost`](super::async_client::AsyncClient)).
    pub train_cost: Option<Duration>,
}

impl<'a> SyncClient<'a> {
    /// Block until an update from every peer tagged with `round` arrived.
    /// Early/late messages are buffered (`pending`) — the paper's round tag
    /// exists precisely to tolerate out-of-order arrival.
    fn collect_round(
        &self,
        clock: &Clock,
        round: u32,
        pending: &mut Vec<ModelUpdate>,
        terminate_seen: &mut bool,
    ) -> Result<BTreeMap<ClientId, ModelUpdate>> {
        let peers = self.transport.peers();
        let mut got: BTreeMap<ClientId, ModelUpdate> = BTreeMap::new();
        // pull matching updates already buffered
        pending.retain(|u| {
            if u.round == round {
                if u.terminate {
                    *terminate_seen = true;
                }
                got.insert(u.sender, u.clone());
                false
            } else {
                u.round > round // drop stale rounds, keep future ones
            }
        });
        let deadline = clock.now() + SYNC_GRACE;
        while got.len() < peers.len() {
            let now = clock.now();
            if now >= deadline {
                bail!(
                    "sync client {}: round {round} incomplete after {:?} \
                     ({}/{} peers) — Phase 1 assumes a fault-free system",
                    self.id,
                    SYNC_GRACE,
                    got.len(),
                    peers.len()
                );
            }
            let Some(msg) = self.transport.recv_timeout(deadline - now) else {
                continue;
            };
            if let Msg::Update(u) = msg {
                match u.round.cmp(&round) {
                    std::cmp::Ordering::Equal => {
                        // The terminate flag only counts for the round it is
                        // tagged with: honoring a *future* round's flag here
                        // would stop this client one round before its peers
                        // and deadlock their barrier (they wait on us).
                        if u.terminate {
                            *terminate_seen = true;
                        }
                        got.insert(u.sender, u);
                    }
                    std::cmp::Ordering::Greater => pending.push(u),
                    std::cmp::Ordering::Less => {} // stale duplicate
                }
            }
        }
        Ok(got)
    }

    /// Run Algorithm 1 to completion.
    pub fn run(mut self) -> Result<ClientReport> {
        let meta = self.trainer.meta().clone();
        let clock = self.transport.clock();
        let started = clock.now();
        let mut params = self.trainer.init(self.cfg.model_seed)?;
        let mut monitor =
            ConvergenceMonitor::new(self.cfg.count_threshold, self.cfg.conv_threshold_rel);
        let mut history = Vec::new();
        let mut pending: Vec<ModelUpdate> = Vec::new();
        let n_peers = self.transport.peers().len();
        let my_weight = if self.cfg.weight_by_samples {
            self.data.indices.len() as f32
        } else {
            1.0
        };

        let mut cause = TerminationCause::MaxRounds;
        let mut round: u32 = 0;
        let mut want_terminate = false; // set when our CCC fires
        while round < self.cfg.max_rounds {
            // local update
            let t_train = clock.now();
            let (xs, ys) = self.data.train.gather_round(
                &self.data.indices,
                meta.nb_train * meta.batch,
                &mut self.rng,
            );
            let (new_params, train_loss) =
                self.trainer.train_round(&params, &xs, &ys, self.cfg.lr)?;
            params = new_params;
            match self.train_cost {
                Some(cost) => clock.sleep(cost.mul_f32(1.0 + self.slowdown.max(0.0))),
                None if self.slowdown > 0.0 => {
                    clock.sleep(clock.now().saturating_sub(t_train).mul_f32(self.slowdown))
                }
                None => {}
            }

            // broadcast ⟨M_i, round⟩ (terminate flag set if our CCC fired
            // last round — the "mutual agreement" carrier)
            let msg = Msg::Update(ModelUpdate {
                sender: self.id,
                round,
                terminate: want_terminate,
                weight: my_weight,
                params: ParamVector(params.clone()),
            });
            let _ = self.transport.broadcast(&msg);

            // barrier: wait for all peers' round-tagged models
            let mut terminate_seen = want_terminate;
            let got = self.collect_round(&clock, round, &mut pending, &mut terminate_seen)?;

            // aggregate own + all peers (Algorithm 1 line 12)
            let mut rows: Vec<(&[f32], f32)> = vec![(&params, my_weight)];
            for u in got.values().take(meta.k_max - 1) {
                rows.push((u.params.as_slice(), u.weight.max(0.0)));
            }
            let aggregated = rows.len();
            params = self.trainer.aggregate(&rows)?;

            let (correct, _) =
                self.trainer
                    .eval(&params, &self.data.eval_xs, &self.data.eval_ys, false)?;
            let probe_acc = correct as f32 / self.data.eval_ys.len() as f32;

            let ccc = monitor.observe(&ParamVector(params.clone()), true, aggregated);
            history.push(RoundRecord {
                round,
                train_loss,
                probe_acc,
                alive_peers: n_peers,
                aggregated,
                delta_rel: monitor.last_delta_rel,
                conv_counter: monitor.counter(),
                crashes_detected: Vec::new(),
            });
            round += 1;

            // mutual-agreement termination: if anyone (us included) carried
            // the flag this round, every client stops at this same boundary.
            if terminate_seen {
                cause = if want_terminate {
                    TerminationCause::Converged
                } else {
                    TerminationCause::Signaled
                };
                break;
            }
            if round >= self.cfg.min_rounds && ccc {
                // fire our flag next round so all peers see the same tag
                want_terminate = true;
            }
        }

        let (correct, loss) =
            self.trainer
                .eval(&params, &self.data.full_xs, &self.data.full_ys, true)?;
        Ok(ClientReport {
            id: self.id,
            cause,
            rounds_completed: round,
            final_accuracy: Some(correct as f32 / self.data.full_ys.len() as f32),
            final_loss: Some(loss),
            wall: clock.now().saturating_sub(started),
            history,
            signal_source: None,
            final_params: Some(params),
        })
    }
}
