//! Protocol constants of Algorithms 1–2 (one struct shared by both phases).

use std::time::Duration;

/// Tunable protocol parameters.  Field names follow the paper's pseudocode
/// (`TIMEOUT`, `MINIMUM_ROUNDS`, `COUNT_THRESHOLD`, `R_PRIME`).
#[derive(Clone, Debug)]
pub struct ProtocolConfig {
    /// Phase 2 wait window per round: how long a client waits for peer
    /// updates before marking silent peers as crashed (paper `TIMEOUT`).
    pub timeout: Duration,
    /// Rounds before the CCC check activates (paper `MINIMUM_ROUNDS`).
    pub min_rounds: u32,
    /// Consecutive stable rounds required to trigger CCC
    /// (paper `COUNT_THRESHOLD`, the "x" of §3.2).
    pub count_threshold: u32,
    /// Convergence threshold on ‖avg_t − avg_{t−1}‖ relative to ‖avg_t‖
    /// (dimension-free; the paper uses an absolute weight-delta threshold).
    pub conv_threshold_rel: f32,
    /// Hard round cap (paper `R_PRIME`).
    pub max_rounds: u32,
    /// Local SGD learning rate.
    pub lr: f32,
    /// Common model-init seed (all clients must agree).
    pub model_seed: u32,
    /// Weight aggregation by local sample count (true) or plain mean.
    pub weight_by_samples: bool,
    /// In Phase 2, end the wait window early once every currently-alive
    /// peer has reported this round (keeps wallclock off the TIMEOUT floor
    /// while preserving the detection semantics; disable to match the
    /// paper's fixed-window pseudocode exactly).
    pub early_window_exit: bool,
    /// Client-Responsive Termination on/off (ablation knob: with CRT off a
    /// received terminate flag is ignored, so every client must reach CCC
    /// on its own — `benches/ablation.rs` quantifies the wasted rounds).
    pub crt_enabled: bool,
    /// Quorum-CCC fraction `q` for condition (a): a round counts as
    /// crash-free when at least a `q`-fraction of the overlay neighborhood
    /// went unsuspected this round, i.e. at most
    /// `⌊(1 − q) · |neighborhood|⌋` peers were *newly* marked crashed
    /// (see [`crate::coordinator::termination::quorum_crash_free`]).
    /// `q = 1.0` (default) tolerates zero fresh suspicions — exactly the
    /// paper's strict condition, byte-identical per seed; `q < 1.0` keeps
    /// adaptive termination reachable under uniform message loss, where
    /// false suspicion never stops at scale (DESIGN.md §9).
    pub quorum: f32,
}

impl Default for ProtocolConfig {
    fn default() -> Self {
        // Tuned for the synthetic CIFAR-10 stand-in + the shipped CNN
        // artifacts: the CCC threshold sits just above the converged
        // gradient-noise floor of the aggregated model (≈0.015 rel/round),
        // and MINIMUM_ROUNDS covers the steep part of the loss curve.
        ProtocolConfig {
            timeout: Duration::from_millis(500),
            min_rounds: 15,
            count_threshold: 4,
            conv_threshold_rel: 0.028,
            max_rounds: 60,
            lr: 0.12,
            model_seed: 42,
            weight_by_samples: false,
            early_window_exit: true,
            crt_enabled: true,
            quorum: 1.0,
        }
    }
}

impl ProtocolConfig {
    /// Small/fast settings for unit tests (mock trainer scale).
    pub fn for_tests() -> Self {
        ProtocolConfig {
            timeout: Duration::from_millis(60),
            min_rounds: 3,
            count_threshold: 2,
            conv_threshold_rel: 0.028,
            max_rounds: 30,
            lr: 0.1,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = ProtocolConfig::default();
        assert!(c.min_rounds < c.max_rounds);
        assert!(c.count_threshold >= 1);
        assert!(c.conv_threshold_rel > 0.0);
        assert!(!c.timeout.is_zero());
        assert_eq!(c.quorum, 1.0, "default must be the paper-strict condition");
    }
}
