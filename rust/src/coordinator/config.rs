//! Protocol constants of Algorithms 1–2 (one struct shared by both phases).

use std::time::Duration;

use anyhow::Result;

use crate::net::CodecSpec;
use crate::runtime::AggregationRule;

/// How quorum-CCC's condition (a) picks its `q` (the `--quorum` flag).
///
/// * [`QuorumSpec::Fixed`] — a hand-picked fraction; `1.0` (the default)
///   is the paper-strict zero-tolerance condition, byte-identical per
///   seed to the pre-quorum protocol.
/// * [`QuorumSpec::Auto`] — suspicion-driven auto-tuning
///   ([`crate::coordinator::termination::QuorumController`]): each client
///   derives `q` from an EWMA of its own per-window fresh-suspicion rate,
///   clamped to `[q_min, 1.0]`, so no per-deployment constant has to be
///   guessed.  Deterministic per seed (the controller is a pure fold).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QuorumSpec {
    /// Judge every window with this fraction.
    Fixed(f32),
    /// Derive `q` per client from the measured suspicion rate, never
    /// dropping below `q_min`.
    Auto { q_min: f32 },
}

/// Default lower clamp for `--quorum auto` (a majority quorum: condition
/// (a) never tolerates half the neighborhood going silent at once).
pub const QUORUM_AUTO_MIN: f32 = 0.5;

impl QuorumSpec {
    /// The paper-strict condition (a).
    pub const STRICT: QuorumSpec = QuorumSpec::Fixed(1.0);

    /// Parse a CLI spelling: a fraction in `[0, 1]`, `auto`, or
    /// `auto:Q_MIN`.
    ///
    /// ```
    /// use dfl::coordinator::config::{QuorumSpec, QUORUM_AUTO_MIN};
    ///
    /// assert_eq!(QuorumSpec::parse("0.85").unwrap(), QuorumSpec::Fixed(0.85));
    /// assert_eq!(QuorumSpec::parse("auto").unwrap(), QuorumSpec::Auto { q_min: QUORUM_AUTO_MIN });
    /// assert_eq!(QuorumSpec::parse("auto:0.7").unwrap(), QuorumSpec::Auto { q_min: 0.7 });
    /// assert!(QuorumSpec::parse("1.5").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<QuorumSpec> {
        let in_range = |q: f32, what: &str| -> Result<f32> {
            anyhow::ensure!((0.0..=1.0).contains(&q), "--quorum {what} must be in [0, 1], got {q}");
            Ok(q)
        };
        if s == "auto" {
            return Ok(QuorumSpec::Auto { q_min: QUORUM_AUTO_MIN });
        }
        if let Some(min) = s.strip_prefix("auto:") {
            let q_min: f32 = min
                .parse()
                .map_err(|_| anyhow::anyhow!("--quorum auto:{min:?}: bad q_min"))?;
            return Ok(QuorumSpec::Auto { q_min: in_range(q_min, "auto q_min")? });
        }
        let q: f32 = s
            .parse()
            .map_err(|_| anyhow::anyhow!("--quorum {s:?}: want a fraction, auto, or auto:Q_MIN"))?;
        Ok(QuorumSpec::Fixed(in_range(q, "fraction")?))
    }

    /// The CLI spelling (round-trips through [`QuorumSpec::parse`]).
    pub fn name(self) -> String {
        match self {
            QuorumSpec::Fixed(q) => format!("{q}"),
            QuorumSpec::Auto { q_min } if q_min == QUORUM_AUTO_MIN => "auto".into(),
            QuorumSpec::Auto { q_min } => format!("auto:{q_min}"),
        }
    }
}

/// Tunable protocol parameters.  Field names follow the paper's pseudocode
/// (`TIMEOUT`, `MINIMUM_ROUNDS`, `COUNT_THRESHOLD`, `R_PRIME`).
#[derive(Clone, Debug)]
pub struct ProtocolConfig {
    /// Phase 2 wait window per round: how long a client waits for peer
    /// updates before marking silent peers as crashed (paper `TIMEOUT`).
    pub timeout: Duration,
    /// Rounds before the CCC check activates (paper `MINIMUM_ROUNDS`).
    pub min_rounds: u32,
    /// Consecutive stable rounds required to trigger CCC
    /// (paper `COUNT_THRESHOLD`, the "x" of §3.2).
    pub count_threshold: u32,
    /// Convergence threshold on ‖avg_t − avg_{t−1}‖ relative to ‖avg_t‖
    /// (dimension-free; the paper uses an absolute weight-delta threshold).
    pub conv_threshold_rel: f32,
    /// Hard round cap (paper `R_PRIME`).
    pub max_rounds: u32,
    /// Local SGD learning rate.
    pub lr: f32,
    /// Common model-init seed (all clients must agree).
    pub model_seed: u32,
    /// Weight aggregation by local sample count (true) or plain mean.
    pub weight_by_samples: bool,
    /// In Phase 2, end the wait window early once every currently-alive
    /// peer has reported this round (keeps wallclock off the TIMEOUT floor
    /// while preserving the detection semantics; disable to match the
    /// paper's fixed-window pseudocode exactly).
    pub early_window_exit: bool,
    /// Client-Responsive Termination on/off (ablation knob: with CRT off a
    /// received terminate flag is ignored, so every client must reach CCC
    /// on its own — `benches/ablation.rs` quantifies the wasted rounds).
    pub crt_enabled: bool,
    /// Quorum-CCC `q` for condition (a): a round counts as crash-free
    /// when at least a `q`-fraction of the overlay neighborhood went
    /// unsuspected this round, i.e. at most
    /// `⌊(1 − q) · |neighborhood|⌋` peers were *newly* marked crashed
    /// (see [`crate::coordinator::termination::quorum_crash_free`]).
    /// [`QuorumSpec::Fixed`]`(1.0)` (default) tolerates zero fresh
    /// suspicions — exactly the paper's strict condition, byte-identical
    /// per seed; `q < 1.0` keeps adaptive termination reachable under
    /// uniform message loss, where false suspicion never stops at scale
    /// (DESIGN.md §9); [`QuorumSpec::Auto`] derives `q` per client from
    /// the measured suspicion rate (DESIGN.md §10).
    pub quorum: QuorumSpec,
    /// How wait-window rows are combined (`--agg`, DESIGN.md §11):
    /// [`AggregationRule::FedAvg`] (default) is the trainer's weighted
    /// mean — byte-identical per seed to the pre-rule protocol — while
    /// `trimmed-mean:F` / `coord-median` / `krum:F` are Byzantine-robust
    /// order statistics that bound what any `--adversary` client can do
    /// to the aggregate.
    pub agg: AggregationRule,
    /// Model-exchange encoding (`--codec`, DESIGN.md §13):
    /// [`CodecSpec::Dense`] (default) sends every update as the classic
    /// dense `Msg::Update` — byte-identical per seed to the pre-codec
    /// protocol — while `delta:K[,q16]` sends sparse top-K deltas against
    /// per-link acked bases plus compact CRT flag relays, cutting
    /// bytes/round by roughly `dim / K` once links are warmed up.
    pub codec: CodecSpec,
}

impl Default for ProtocolConfig {
    fn default() -> Self {
        // Tuned for the synthetic CIFAR-10 stand-in + the shipped CNN
        // artifacts: the CCC threshold sits just above the converged
        // gradient-noise floor of the aggregated model (≈0.015 rel/round),
        // and MINIMUM_ROUNDS covers the steep part of the loss curve.
        ProtocolConfig {
            timeout: Duration::from_millis(500),
            min_rounds: 15,
            count_threshold: 4,
            conv_threshold_rel: 0.028,
            max_rounds: 60,
            lr: 0.12,
            model_seed: 42,
            weight_by_samples: false,
            early_window_exit: true,
            crt_enabled: true,
            quorum: QuorumSpec::STRICT,
            agg: AggregationRule::FedAvg,
            codec: CodecSpec::Dense,
        }
    }
}

impl ProtocolConfig {
    /// Small/fast settings for unit tests (mock trainer scale).
    pub fn for_tests() -> Self {
        ProtocolConfig {
            timeout: Duration::from_millis(60),
            min_rounds: 3,
            count_threshold: 2,
            conv_threshold_rel: 0.028,
            max_rounds: 30,
            lr: 0.1,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = ProtocolConfig::default();
        assert!(c.min_rounds < c.max_rounds);
        assert!(c.count_threshold >= 1);
        assert!(c.conv_threshold_rel > 0.0);
        assert!(!c.timeout.is_zero());
        assert_eq!(
            c.quorum,
            QuorumSpec::Fixed(1.0),
            "default must be the paper-strict condition"
        );
        assert_eq!(
            c.agg,
            AggregationRule::FedAvg,
            "default must be the byte-identical pre-rule path"
        );
        assert_eq!(
            c.codec,
            CodecSpec::Dense,
            "default must be the byte-identical pre-codec path"
        );
    }

    #[test]
    fn quorum_spec_parses_and_round_trips() {
        for s in ["0.85", "1.0", "0", "auto", "auto:0.7"] {
            let spec = QuorumSpec::parse(s).unwrap();
            assert_eq!(QuorumSpec::parse(&spec.name()).unwrap(), spec, "{s}");
        }
        assert_eq!(QuorumSpec::STRICT, QuorumSpec::Fixed(1.0));
        for bad in ["1.5", "-0.1", "auto:1.5", "auto:", "full", ""] {
            assert!(QuorumSpec::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }
}
