//! Adaptive termination detection: the paper's two central mechanisms.
//!
//! **Client-Confident Convergence (CCC)** — every client independently
//! monitors (a) crash-free stability and (b) diminishing model change
//! ‖avg_t − avg_{t−1}‖; after `COUNT_THRESHOLD` consecutive stable rounds
//! it broadcasts a terminate flag.  (Algorithm 2 line 24 compares
//! `curr_weight − prev_weight > threshold` to *increment* the counter —
//! read in context of §3.2 "falls below a predefined threshold", that is a
//! pseudocode typo; we implement the §3.2 semantics: increment when the
//! delta is *below* threshold.)
//!
//! **Client-Responsive Termination (CRT)** — receiving a terminate flag
//! sets the local flag; every subsequent broadcast carries it, flooding the
//! signal through delays and intermittent disconnects.  On a sparse
//! overlay the flag additionally relays hop-by-hop within the round
//! (`coordinator::machine`, DESIGN.md §9), so the whole graph — not just
//! the origin's neighborhood — learns of termination.
//!
//! **Quorum-CCC** — the paper's condition (a) ("no crash detected for x
//! consecutive rounds") ranges over every peer, which makes it
//! structurally unreachable under uniform message loss at scale: with
//! hundreds of peers, *some* update misses the window essentially every
//! round, so the crash-free streak never starts.  [`quorum_crash_free`]
//! generalizes (a) to tolerate a bounded minority of fresh suspicions per
//! round; `q = 1.0` reproduces the paper exactly.

use crate::model::ParamVector;
use crate::net::ClientId;

/// Why a client's main loop ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TerminationCause {
    /// CCC triggered locally: this client initiated termination.
    Converged,
    /// CRT: terminate flag received from a peer.
    Signaled,
    /// Hit `R_PRIME` (the hard round cap).
    MaxRounds,
    /// Injected crash (the client fell silent mid-run).
    Crashed,
}

/// Local termination flag + bookkeeping (who/when), per client.
#[derive(Clone, Debug, Default)]
pub struct TerminationState {
    flag: bool,
    /// Peer that first delivered the flag to us (None if self-triggered).
    pub source: Option<ClientId>,
    /// Our local round when the flag was set.
    pub at_round: Option<u32>,
}

impl TerminationState {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn is_set(&self) -> bool {
        self.flag
    }

    /// CRT receive path: adopt the flag (first writer wins for provenance).
    pub fn signal_from(&mut self, peer: ClientId, round: u32) {
        if !self.flag {
            self.flag = true;
            self.source = Some(peer);
            self.at_round = Some(round);
        }
    }

    /// CCC local-trigger path.
    pub fn self_trigger(&mut self, round: u32) {
        if !self.flag {
            self.flag = true;
            self.source = None;
            self.at_round = Some(round);
        }
    }
}

/// Quorum-CCC condition (a) for one round: did at least a `quorum`
/// fraction of the (`neighborhood`-sized) tracked peer set go unsuspected
/// this round?  Equivalently: were at most `⌊(1 − q) · neighborhood⌋`
/// peers *newly* marked crashed by this round's sweep?
///
/// * `q = 1.0` tolerates zero fresh suspicions — exactly the paper's
///   strict "no crash detected this round", so full-overlay runs with the
///   default quorum are byte-identical to the pre-quorum protocol.
/// * `q < 1.0` keeps the streak alive through the bounded false suspicion
///   that uniform loss inflicts every round (a peer whose message was
///   dropped looks crashed until its next update revives it).
///
/// Safety is preserved for the same reason as in the strict protocol:
/// tolerating a suspicion never *adds* a model to the aggregate, and a
/// genuinely unconverged neighbor that is still heard keeps moving the
/// aggregated average, so condition (b) — the stability test — holds the
/// counter at zero regardless of (a).  A q-quorum can only terminate a
/// client whose *heard* neighborhood is stable; see DESIGN.md §9 for the
/// full argument.
///
/// ```
/// use dfl::coordinator::termination::quorum_crash_free;
///
/// assert!(quorum_crash_free(0, 199, 1.0));
/// assert!(!quorum_crash_free(1, 199, 1.0));   // paper-strict
/// assert!(quorum_crash_free(29, 199, 0.85));  // ⌊0.15·199⌋ = 29 tolerated
/// assert!(!quorum_crash_free(30, 199, 0.85));
/// ```
pub fn quorum_crash_free(newly_suspected: usize, neighborhood: usize, quorum: f32) -> bool {
    let q = quorum.clamp(0.0, 1.0) as f64;
    if q >= 1.0 {
        // Exact zero-tolerance at any neighborhood size (the epsilon
        // below would otherwise tolerate 1 at n >= 1e6).
        return newly_suspected == 0;
    }
    // The epsilon absorbs the f32→f64 widening error of q (≈1.2e-7·n)
    // so e.g. q = 0.8 over 10 peers tolerates the intended 2, not 1.
    let tolerated = ((1.0 - q) * neighborhood as f64 + 1e-6 * neighborhood as f64).floor();
    (newly_suspected as f64) <= tolerated
}

/// The CCC stability monitor over successive aggregated (global-average)
/// models.
#[derive(Clone, Debug)]
pub struct ConvergenceMonitor {
    prev: Option<ParamVector>,
    counter: u32,
    count_threshold: u32,
    conv_threshold_rel: f32,
    /// Most recent relative delta (diagnostics / logging).
    pub last_delta_rel: f32,
}

impl ConvergenceMonitor {
    pub fn new(count_threshold: u32, conv_threshold_rel: f32) -> Self {
        ConvergenceMonitor {
            prev: None,
            counter: 0,
            count_threshold,
            conv_threshold_rel,
            last_delta_rel: f32::INFINITY,
        }
    }

    pub fn counter(&self) -> u32 {
        self.counter
    }

    /// Feed the round's aggregated model. `crash_free` is CCC condition (a)
    /// for this round; `participants` is how many models entered this
    /// round's average (self included).  Returns true when the monitor has
    /// seen `count_threshold` consecutive stable, crash-free rounds.
    ///
    /// The stability test normalizes the threshold by `participants`:
    /// averaging n locally-trained models dilutes each round's movement
    /// (empirically ≈1/√n once gradient noise partially cancels), so a
    /// fixed threshold fires prematurely at large n and never at small n.
    /// `conv_threshold_rel` is calibrated at 2 participants.
    pub fn observe(&mut self, avg: &ParamVector, crash_free: bool, participants: usize) -> bool {
        let eff_threshold =
            self.conv_threshold_rel * (2.0 / participants.max(1) as f32).sqrt();
        let stable = match &self.prev {
            None => false,
            Some(prev) => {
                let delta = avg.l2_distance(prev);
                let scale = avg.l2_norm().max(1.0);
                self.last_delta_rel = delta / scale;
                self.last_delta_rel < eff_threshold
            }
        };
        if stable && crash_free {
            self.counter += 1;
        } else {
            self.counter = 0; // any instability or crash resets (Alg. 2 l.27)
        }
        self.prev = Some(avg.clone());
        self.counter >= self.count_threshold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pv(v: &[f32]) -> ParamVector {
        ParamVector(v.to_vec())
    }

    #[test]
    fn triggers_after_consecutive_stable_rounds() {
        let mut m = ConvergenceMonitor::new(3, 0.01);
        let base = pv(&[10.0, 10.0, 10.0]);
        assert!(!m.observe(&base, true, 2)); // first round: no prev
        assert!(!m.observe(&base, true, 2)); // counter 1
        assert!(!m.observe(&base, true, 2)); // counter 2
        assert!(m.observe(&base, true, 2)); // counter 3 -> trigger
    }

    #[test]
    fn movement_resets_counter() {
        let mut m = ConvergenceMonitor::new(2, 0.01);
        let a = pv(&[10.0, 0.0]);
        let b = pv(&[0.0, 10.0]); // big jump
        assert!(!m.observe(&a, true, 2));
        assert!(!m.observe(&a, true, 2)); // counter 1
        assert!(!m.observe(&b, true, 2)); // reset
        assert!(!m.observe(&b, true, 2)); // counter 1
        assert!(m.observe(&b, true, 2)); // counter 2 -> trigger
    }

    #[test]
    fn crash_resets_counter() {
        let mut m = ConvergenceMonitor::new(2, 0.01);
        let a = pv(&[5.0; 10]);
        assert!(!m.observe(&a, true, 2));
        assert!(!m.observe(&a, true, 2)); // counter 1
        assert!(!m.observe(&a, false, 2)); // crash round: reset
        assert!(!m.observe(&a, true, 2)); // counter 1
        assert!(m.observe(&a, true, 2)); // trigger
    }

    #[test]
    fn threshold_is_relative() {
        let mut m = ConvergenceMonitor::new(1, 0.01);
        // ~0.1% movement on a large-norm model: stable
        let a = pv(&[1000.0, 0.0]);
        let b = pv(&[1001.0, 0.0]);
        assert!(!m.observe(&a, true, 2));
        assert!(m.observe(&b, true, 2));
        // same absolute movement on a tiny model: not stable
        let mut m2 = ConvergenceMonitor::new(1, 0.01);
        let c = pv(&[1.0, 0.0]);
        let d = pv(&[2.0, 0.0]);
        assert!(!m2.observe(&c, true, 2));
        assert!(!m2.observe(&d, true, 2));
    }

    #[test]
    fn termination_state_provenance() {
        let mut t = TerminationState::new();
        assert!(!t.is_set());
        t.signal_from(7, 12);
        assert!(t.is_set());
        assert_eq!(t.source, Some(7));
        assert_eq!(t.at_round, Some(12));
        // later signals do not overwrite provenance
        t.signal_from(9, 15);
        assert_eq!(t.source, Some(7));
        // nor does a self trigger
        t.self_trigger(20);
        assert_eq!(t.at_round, Some(12));
    }

    #[test]
    fn quorum_one_is_the_strict_paper_condition() {
        for neighborhood in [0usize, 1, 5, 199, 9_999, 10_000_000] {
            assert!(quorum_crash_free(0, neighborhood, 1.0));
            assert!(
                !quorum_crash_free(1, neighborhood, 1.0),
                "q=1.0 must tolerate zero fresh suspicions (n={neighborhood})"
            );
        }
    }

    #[test]
    fn quorum_tolerates_the_complement_fraction() {
        // ⌊(1-q)·d⌋ boundary on both sides, including f32 boundary values
        assert!(quorum_crash_free(2, 10, 0.8));
        assert!(!quorum_crash_free(3, 10, 0.8));
        assert!(quorum_crash_free(29, 199, 0.85));
        assert!(!quorum_crash_free(30, 199, 0.85));
        assert!(quorum_crash_free(1, 10, 0.9));
        assert!(!quorum_crash_free(2, 10, 0.9));
        // q = 0 disables condition (a) entirely
        assert!(quorum_crash_free(10, 10, 0.0));
        // out-of-range inputs clamp instead of exploding
        assert!(quorum_crash_free(0, 10, 1.5));
        assert!(!quorum_crash_free(1, 10, 1.5));
        assert!(quorum_crash_free(10, 10, -0.2));
    }

    #[test]
    fn self_trigger_provenance() {
        let mut t = TerminationState::new();
        t.self_trigger(4);
        assert!(t.is_set());
        assert_eq!(t.source, None);
        assert_eq!(t.at_round, Some(4));
    }
}
