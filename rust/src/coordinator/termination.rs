//! Adaptive termination detection: the paper's two central mechanisms.
//!
//! **Client-Confident Convergence (CCC)** — every client independently
//! monitors (a) crash-free stability and (b) diminishing model change
//! ‖avg_t − avg_{t−1}‖; after `COUNT_THRESHOLD` consecutive stable rounds
//! it broadcasts a terminate flag.  (Algorithm 2 line 24 compares
//! `curr_weight − prev_weight > threshold` to *increment* the counter —
//! read in context of §3.2 "falls below a predefined threshold", that is a
//! pseudocode typo; we implement the §3.2 semantics: increment when the
//! delta is *below* threshold.)
//!
//! **Client-Responsive Termination (CRT)** — receiving a terminate flag
//! sets the local flag; every subsequent broadcast carries it, flooding the
//! signal through delays and intermittent disconnects.  On a sparse
//! overlay the flag additionally relays hop-by-hop within the round
//! (`coordinator::machine`, DESIGN.md §9), so the whole graph — not just
//! the origin's neighborhood — learns of termination.
//!
//! **Quorum-CCC** — the paper's condition (a) ("no crash detected for x
//! consecutive rounds") ranges over every peer, which makes it
//! structurally unreachable under uniform message loss at scale: with
//! hundreds of peers, *some* update misses the window essentially every
//! round, so the crash-free streak never starts.  [`quorum_crash_free`]
//! generalizes (a) to tolerate a bounded minority of fresh suspicions per
//! round; `q = 1.0` reproduces the paper exactly.

use crate::model::ParamVector;
use crate::net::ClientId;

// Defined beside `metrics::ClientReport` (its long-term home in every
// report row) so the metrics layer never has to look upward at the
// coordinator — module-layering DAG, DESIGN.md §15.  Protocol code keeps
// addressing it by this path.
pub use crate::metrics::TerminationCause;

/// Local termination flag + bookkeeping (who/when), per client.
#[derive(Clone, Debug, Default)]
pub struct TerminationState {
    flag: bool,
    /// Peer that first delivered the flag to us (None if self-triggered).
    pub source: Option<ClientId>,
    /// Our local round when the flag was set.
    pub at_round: Option<u32>,
}

impl TerminationState {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn is_set(&self) -> bool {
        self.flag
    }

    /// CRT receive path: adopt the flag (first writer wins for provenance).
    pub fn signal_from(&mut self, peer: ClientId, round: u32) {
        if !self.flag {
            self.flag = true;
            self.source = Some(peer);
            self.at_round = Some(round);
        }
    }

    /// CCC local-trigger path.
    pub fn self_trigger(&mut self, round: u32) {
        if !self.flag {
            self.flag = true;
            self.source = None;
            self.at_round = Some(round);
        }
    }
}

/// The exact number of fresh suspicions a `quorum`-fraction condition (a)
/// tolerates over a `neighborhood`-sized tracked set: `⌊(1 − q) · n⌋`,
/// computed without floating-point rounding anywhere near the boundary.
///
/// `q` is fixed-pointed to parts-per-million first (recovering the
/// decimal the caller wrote — f32 carries ~7 significant digits, so every
/// CLI-expressible quorum survives the round exactly) and the floor is
/// then pure integer arithmetic.  The previous formulation compared
/// `newly as f64 <= (1 − q) · n + ε·n`: the epsilon that absorbed the
/// f32→f64 widening error could also push a product sitting just *below*
/// an integer over it (and at n ≥ 1e6 tolerated 1 even for q = 1.0),
/// admitting one extra suspicion vs the documented `⌊(1 − q) · n⌋`.
///
/// ```
/// use dfl::coordinator::termination::quorum_tolerated;
///
/// assert_eq!(quorum_tolerated(199, 0.85), 29);   // ⌊0.15·199⌋
/// assert_eq!(quorum_tolerated(20, 0.85), 3);     // ⌊0.15·20⌋, exactly
/// assert_eq!(quorum_tolerated(10_000_000, 1.0), 0);
/// ```
pub fn quorum_tolerated(neighborhood: usize, quorum: f32) -> usize {
    let q = quorum.clamp(0.0, 1.0);
    // f32 → ppm is exact for any quorum written with ≤ 6 decimals; the
    // rest is integer floor division, so no boundary can drift.
    let keep_ppm = (q as f64 * 1_000_000.0).round() as u128;
    let cut_ppm = 1_000_000u128 - keep_ppm.min(1_000_000);
    (cut_ppm * neighborhood as u128 / 1_000_000) as usize
}

/// Quorum-CCC condition (a) for one round: did at least a `quorum`
/// fraction of the (`neighborhood`-sized) tracked peer set go unsuspected
/// this round?  Equivalently: were at most [`quorum_tolerated`]
/// (`⌊(1 − q) · neighborhood⌋`) peers *newly* marked crashed by this
/// round's sweep?
///
/// * `q = 1.0` tolerates zero fresh suspicions — exactly the paper's
///   strict "no crash detected this round", so full-overlay runs with the
///   default quorum are byte-identical to the pre-quorum protocol.
/// * `q < 1.0` keeps the streak alive through the bounded false suspicion
///   that uniform loss inflicts every round (a peer whose message was
///   dropped looks crashed until its next update revives it).
///
/// Safety is preserved for the same reason as in the strict protocol:
/// tolerating a suspicion never *adds* a model to the aggregate, and a
/// genuinely unconverged neighbor that is still heard keeps moving the
/// aggregated average, so condition (b) — the stability test — holds the
/// counter at zero regardless of (a).  A q-quorum can only terminate a
/// client whose *heard* neighborhood is stable; see DESIGN.md §9 for the
/// full argument.
///
/// ```
/// use dfl::coordinator::termination::quorum_crash_free;
///
/// assert!(quorum_crash_free(0, 199, 1.0));
/// assert!(!quorum_crash_free(1, 199, 1.0));   // paper-strict
/// assert!(quorum_crash_free(29, 199, 0.85));  // ⌊0.15·199⌋ = 29 tolerated
/// assert!(!quorum_crash_free(30, 199, 0.85));
/// ```
pub fn quorum_crash_free(newly_suspected: usize, neighborhood: usize, quorum: f32) -> bool {
    newly_suspected <= quorum_tolerated(neighborhood, quorum)
}

/// Suspicion-driven quorum auto-tuning (`--quorum auto`, DESIGN.md §10):
/// derives condition (a)'s `q` per client from the *measured* per-window
/// fresh-suspicion rate instead of a hand-picked deployment constant.
///
/// The controller keeps an EWMA of `newly_suspected / neighborhood` per
/// closed window and tolerates the smoothed rate plus a 3σ binomial
/// margin — precisely the derivation that hand-picked q = 0.85 for the
/// 200-client 10%-loss deployment (mean ≈ 0.085 of 199 tracked peers,
/// σ = √(r(1−r)/n) ≈ 0.02, tolerance ≈ mean + 3σ).  The derived `q` is
/// clamped to `[q_min, 1.0]`:
///
/// * while no suspicion has ever been observed the controller returns
///   exactly `1.0`, so a loss-free `auto` run makes the identical
///   decisions (and sends the identical bytes) as the paper-strict fixed
///   quorum;
/// * a sudden mass-crash still trips condition (a): the tolerance tracks
///   the *historical* rate, and the controller only folds a round in
///   *after* that round was judged, so a fresh spike is always judged
///   against the pre-spike quorum.
///
/// Everything is a pure fold over the observation sequence — no RNG, no
/// clock — so auto-quorum runs stay byte-identical per seed.
#[derive(Clone, Debug)]
pub struct QuorumController {
    q_min: f32,
    /// Smoothed fresh-suspicion fraction per window.
    ewma: f64,
    /// Has any window been folded in yet?
    primed: bool,
}

/// EWMA smoothing factor: ~5-round memory, fast enough to adapt inside
/// one `COUNT_THRESHOLD` streak, slow enough to ride out single spikes.
const QUORUM_EWMA_ALPHA: f64 = 0.2;
/// Binomial tolerance margin above the smoothed rate (mean + 3σ).
const QUORUM_SIGMA_MARGIN: f64 = 3.0;

impl QuorumController {
    pub fn new(q_min: f32) -> Self {
        QuorumController { q_min: q_min.clamp(0.0, 1.0), ewma: 0.0, primed: false }
    }

    /// The quorum to judge the *next* window with, from every window
    /// observed so far.  Strict (`1.0`) until the first suspicion.
    pub fn q(&self, neighborhood: usize) -> f32 {
        if !self.primed || neighborhood == 0 || self.ewma <= 0.0 {
            return 1.0;
        }
        let sigma = (self.ewma * (1.0 - self.ewma) / neighborhood as f64).sqrt();
        let tolerance = self.ewma + QUORUM_SIGMA_MARGIN * sigma;
        ((1.0 - tolerance) as f32).clamp(self.q_min, 1.0)
    }

    /// Fold one closed window's sweep result into the EWMA.  Call *after*
    /// judging the window with [`QuorumController::q`] so a spike never
    /// raises its own tolerance.
    pub fn observe(&mut self, newly_suspected: usize, neighborhood: usize) {
        if neighborhood == 0 {
            return;
        }
        let rate = (newly_suspected.min(neighborhood) as f64) / neighborhood as f64;
        if self.primed {
            self.ewma = (1.0 - QUORUM_EWMA_ALPHA) * self.ewma + QUORUM_EWMA_ALPHA * rate;
        } else {
            self.ewma = rate;
            self.primed = true;
        }
    }

    /// The smoothed suspicion rate (diagnostics).
    pub fn rate(&self) -> f64 {
        self.ewma
    }
}

/// The CCC stability monitor over successive aggregated (global-average)
/// models.
#[derive(Clone, Debug)]
pub struct ConvergenceMonitor {
    prev: Option<ParamVector>,
    counter: u32,
    count_threshold: u32,
    conv_threshold_rel: f32,
    /// Most recent relative delta (diagnostics / logging).
    pub last_delta_rel: f32,
}

impl ConvergenceMonitor {
    pub fn new(count_threshold: u32, conv_threshold_rel: f32) -> Self {
        ConvergenceMonitor {
            prev: None,
            counter: 0,
            count_threshold,
            conv_threshold_rel,
            last_delta_rel: f32::INFINITY,
        }
    }

    pub fn counter(&self) -> u32 {
        self.counter
    }

    /// Feed the round's aggregated model. `crash_free` is CCC condition (a)
    /// for this round; `participants` is how many models entered this
    /// round's average (self included).  Returns true when the monitor has
    /// seen `count_threshold` consecutive stable, crash-free rounds.
    ///
    /// The stability test normalizes the threshold by `participants`:
    /// averaging n locally-trained models dilutes each round's movement
    /// (empirically ≈1/√n once gradient noise partially cancels), so a
    /// fixed threshold fires prematurely at large n and never at small n.
    /// `conv_threshold_rel` is calibrated at 2 participants.
    pub fn observe(&mut self, avg: &ParamVector, crash_free: bool, participants: usize) -> bool {
        self.observe_slice(&avg.0, crash_free, participants)
    }

    /// Slice-based [`ConvergenceMonitor::observe`]: identical arithmetic and
    /// state transitions, but the retained previous model is overwritten in
    /// place instead of replaced by a clone — the round loop can feed its
    /// live parameter buffer without allocating (DESIGN.md §14).
    pub fn observe_slice(&mut self, avg: &[f32], crash_free: bool, participants: usize) -> bool {
        let eff_threshold =
            self.conv_threshold_rel * (2.0 / participants.max(1) as f32).sqrt();
        // Same float ops as ParamVector::l2_distance / l2_norm: per-coordinate
        // f32 difference/square widened to f64 for the sum, sqrt back to f32.
        let stable = match &self.prev {
            None => false,
            Some(prev) => {
                let delta = avg
                    .iter()
                    .zip(&prev.0)
                    .map(|(a, b)| {
                        let d = a - b;
                        (d * d) as f64
                    })
                    .sum::<f64>()
                    .sqrt() as f32;
                let scale =
                    (avg.iter().map(|a| (a * a) as f64).sum::<f64>().sqrt() as f32).max(1.0);
                self.last_delta_rel = delta / scale;
                self.last_delta_rel < eff_threshold
            }
        };
        if stable && crash_free {
            self.counter += 1;
        } else {
            self.counter = 0; // any instability or crash resets (Alg. 2 l.27)
        }
        match &mut self.prev {
            Some(p) => {
                p.0.clear();
                p.0.extend_from_slice(avg);
            }
            None => self.prev = Some(ParamVector(avg.to_vec())),
        }
        self.counter >= self.count_threshold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pv(v: &[f32]) -> ParamVector {
        ParamVector(v.to_vec())
    }

    #[test]
    fn triggers_after_consecutive_stable_rounds() {
        let mut m = ConvergenceMonitor::new(3, 0.01);
        let base = pv(&[10.0, 10.0, 10.0]);
        assert!(!m.observe(&base, true, 2)); // first round: no prev
        assert!(!m.observe(&base, true, 2)); // counter 1
        assert!(!m.observe(&base, true, 2)); // counter 2
        assert!(m.observe(&base, true, 2)); // counter 3 -> trigger
    }

    #[test]
    fn movement_resets_counter() {
        let mut m = ConvergenceMonitor::new(2, 0.01);
        let a = pv(&[10.0, 0.0]);
        let b = pv(&[0.0, 10.0]); // big jump
        assert!(!m.observe(&a, true, 2));
        assert!(!m.observe(&a, true, 2)); // counter 1
        assert!(!m.observe(&b, true, 2)); // reset
        assert!(!m.observe(&b, true, 2)); // counter 1
        assert!(m.observe(&b, true, 2)); // counter 2 -> trigger
    }

    #[test]
    fn crash_resets_counter() {
        let mut m = ConvergenceMonitor::new(2, 0.01);
        let a = pv(&[5.0; 10]);
        assert!(!m.observe(&a, true, 2));
        assert!(!m.observe(&a, true, 2)); // counter 1
        assert!(!m.observe(&a, false, 2)); // crash round: reset
        assert!(!m.observe(&a, true, 2)); // counter 1
        assert!(m.observe(&a, true, 2)); // trigger
    }

    #[test]
    fn threshold_is_relative() {
        let mut m = ConvergenceMonitor::new(1, 0.01);
        // ~0.1% movement on a large-norm model: stable
        let a = pv(&[1000.0, 0.0]);
        let b = pv(&[1001.0, 0.0]);
        assert!(!m.observe(&a, true, 2));
        assert!(m.observe(&b, true, 2));
        // same absolute movement on a tiny model: not stable
        let mut m2 = ConvergenceMonitor::new(1, 0.01);
        let c = pv(&[1.0, 0.0]);
        let d = pv(&[2.0, 0.0]);
        assert!(!m2.observe(&c, true, 2));
        assert!(!m2.observe(&d, true, 2));
    }

    #[test]
    fn termination_state_provenance() {
        let mut t = TerminationState::new();
        assert!(!t.is_set());
        t.signal_from(7, 12);
        assert!(t.is_set());
        assert_eq!(t.source, Some(7));
        assert_eq!(t.at_round, Some(12));
        // later signals do not overwrite provenance
        t.signal_from(9, 15);
        assert_eq!(t.source, Some(7));
        // nor does a self trigger
        t.self_trigger(20);
        assert_eq!(t.at_round, Some(12));
    }

    #[test]
    fn quorum_one_is_the_strict_paper_condition() {
        for neighborhood in [0usize, 1, 5, 199, 9_999, 10_000_000] {
            assert!(quorum_crash_free(0, neighborhood, 1.0));
            assert!(
                !quorum_crash_free(1, neighborhood, 1.0),
                "q=1.0 must tolerate zero fresh suspicions (n={neighborhood})"
            );
        }
    }

    #[test]
    fn quorum_tolerates_the_complement_fraction() {
        // ⌊(1-q)·d⌋ boundary on both sides, including f32 boundary values
        assert!(quorum_crash_free(2, 10, 0.8));
        assert!(!quorum_crash_free(3, 10, 0.8));
        assert!(quorum_crash_free(29, 199, 0.85));
        assert!(!quorum_crash_free(30, 199, 0.85));
        assert!(quorum_crash_free(1, 10, 0.9));
        assert!(!quorum_crash_free(2, 10, 0.9));
        // q = 0 disables condition (a) entirely
        assert!(quorum_crash_free(10, 10, 0.0));
        // out-of-range inputs clamp instead of exploding
        assert!(quorum_crash_free(0, 10, 1.5));
        assert!(!quorum_crash_free(1, 10, 1.5));
        assert!(quorum_crash_free(10, 10, -0.2));
    }

    /// The satellite bugfix contract: across q ∈ {0.50, 0.51, …, 1.00}
    /// and n ∈ {1..1000}, the tolerated count is *exactly* the integer
    /// `((100 − j) · n) / 100` for q = j/100 — an independent rational
    /// derivation, no floats — and `quorum_crash_free` flips precisely at
    /// that boundary.  The old epsilon formulation admitted one extra
    /// suspicion whenever `(1−q)·n` sat within ε·n below an integer.
    #[test]
    fn quorum_boundary_is_the_exact_integer_floor() {
        for j in 50..=100u32 {
            let q = j as f32 / 100.0;
            for n in 1..=1000usize {
                let expect = ((100 - j) as usize * n) / 100;
                assert_eq!(
                    quorum_tolerated(n, q),
                    expect,
                    "q={q} n={n}: tolerated must be ⌊(1−q)·n⌋ exactly"
                );
                assert!(quorum_crash_free(expect, n, q), "q={q} n={n} at boundary");
                assert!(!quorum_crash_free(expect + 1, n, q), "q={q} n={n} above boundary");
            }
        }
    }

    #[test]
    fn quorum_tolerated_is_monotone_and_bounded() {
        for j in (50..=100u32).step_by(5) {
            let q = j as f32 / 100.0;
            let mut prev = 0usize;
            for n in 1..=1000usize {
                let t = quorum_tolerated(n, q);
                assert!(t <= n, "tolerated can never exceed the neighborhood");
                assert!(t >= prev, "tolerated must grow with the neighborhood");
                prev = t;
            }
        }
        // q = 1.0 tolerates zero at any size (the old epsilon admitted 1
        // at n >= 1e6 — the regression the strict special case guarded).
        assert_eq!(quorum_tolerated(10_000_000, 1.0), 0);
        assert_eq!(quorum_tolerated(usize::MAX / 2, 1.0), 0);
    }

    #[test]
    fn quorum_controller_is_strict_until_suspicion_appears() {
        let mut c = QuorumController::new(0.5);
        assert_eq!(c.q(199), 1.0, "no evidence yet: paper-strict");
        c.observe(0, 199);
        c.observe(0, 199);
        assert_eq!(c.q(199), 1.0, "zero observed rate stays strict");
        assert_eq!(c.rate(), 0.0);
    }

    #[test]
    fn quorum_controller_derives_the_hand_picked_loss_quorum() {
        // Feed the 200-client 10%-loss regime (≈17 of 199 tracked peers
        // falsely suspected per window): the derived q must land in the
        // neighborhood of the hand-picked 0.85 — mean + 3σ ≈ 0.85/0.84 —
        // and the tolerance it implies must absorb the per-round noise.
        let mut c = QuorumController::new(0.5);
        for _ in 0..30 {
            c.observe(17, 199);
        }
        let q = c.q(199);
        assert!((0.80..0.90).contains(&q), "derived q = {q}, want ≈0.85");
        let tol = quorum_tolerated(199, q);
        assert!((25..40).contains(&tol), "tolerance {tol} must absorb ≈17 ± 3σ");
        assert!(
            !quorum_crash_free(60, 199, q),
            "a mass-crash event must still trip condition (a)"
        );
    }

    #[test]
    fn quorum_controller_clamps_to_q_min_and_adapts_back() {
        let mut c = QuorumController::new(0.8);
        for _ in 0..50 {
            c.observe(100, 200); // 50% suspicion rate: wants q ≈ 0.4
        }
        assert_eq!(c.q(200), 0.8, "q must clamp at q_min");
        for _ in 0..100 {
            c.observe(0, 200); // quiet again: EWMA decays, q recovers
        }
        assert!(c.q(200) > 0.9, "q must recover toward strict, got {}", c.q(200));
    }

    #[test]
    fn quorum_controller_ignores_an_empty_neighborhood() {
        // Regression: a client whose neighborhood empties mid-churn calls
        // observe(_, 0).  An unguarded division would compute 0/0 = NaN,
        // and the NaN would stick in the EWMA for the rest of the run —
        // every later q() comparison silently false.  The guard makes the
        // empty window a no-op instead.
        let mut c = QuorumController::new(0.5);
        c.observe(0, 0);
        c.observe(3, 0);
        assert_eq!(c.rate(), 0.0, "empty windows must not touch the EWMA");
        assert_eq!(c.q(64), 1.0, "controller must stay unprimed (strict)");
        assert!(c.rate().is_finite());
        // a later real observation still primes and adapts normally
        for _ in 0..30 {
            c.observe(16, 64);
        }
        assert!(c.rate().is_finite());
        assert!((0.2..0.3).contains(&c.rate()), "rate {} must track 16/64", c.rate());
        assert!(c.q(64) < 1.0, "controller must adapt after real evidence");
    }

    #[test]
    fn quorum_controller_is_a_pure_fold() {
        // Same observation sequence ⇒ same derived q, bit for bit (the
        // byte-identity contract of `--quorum auto` per seed).
        let run = || {
            let mut c = QuorumController::new(0.5);
            let mut qs = Vec::new();
            for i in 0..40usize {
                qs.push(c.q(64).to_bits());
                c.observe(i % 7, 64);
            }
            qs
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn self_trigger_provenance() {
        let mut t = TerminationState::new();
        t.self_trigger(4);
        assert!(t.is_set());
        assert_eq!(t.source, None);
        assert_eq!(t.at_round, Some(4));
    }
}
