//! Peer status tracking + timeout-based crash detection.
//!
//! The paper's Phase-2 rule (§3.2): a client waits `TIMEOUT` for a message
//! from each peer; silence ⇒ mark crashed and proceed.  A later message
//! from a "crashed" peer flips it back to alive ("if the message m is
//! delayed then C_i will consider m in whatever round it receives and
//! change the status of C_j as alive") — this is what distinguishes *slow*
//! from *failed* clients.  Peers that announced termination are *not*
//! treated as crashed when they fall silent; that disambiguation is the
//! point of the Client-Responsive Termination protocol.

use std::collections::{BTreeMap, BTreeSet};

use crate::net::ClientId;

/// Liveness knowledge about one peer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeerStatus {
    Alive,
    /// Missed a full wait window and has not been heard since.
    Crashed,
    /// Sent (or relayed) the termination flag; silence is expected.
    Terminated,
}

/// One crash/revival event (for logs and the figures' crash accounting).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PeerEvent {
    Crashed { round: u32, peer: ClientId },
    Revived { round: u32, peer: ClientId },
}

/// Per-client view of every peer's liveness.
#[derive(Clone, Debug)]
pub struct PeerTable {
    status: BTreeMap<ClientId, PeerStatus>,
    /// Round at which we last heard each peer (our local round counter).
    last_heard: BTreeMap<ClientId, Option<u32>>,
    events: Vec<PeerEvent>,
}

impl PeerTable {
    pub fn new(peers: &[ClientId]) -> Self {
        PeerTable {
            status: peers.iter().map(|&p| (p, PeerStatus::Alive)).collect(),
            last_heard: peers.iter().map(|&p| (p, None)).collect(),
            events: Vec::new(),
        }
    }

    pub fn status(&self, peer: ClientId) -> Option<PeerStatus> {
        self.status.get(&peer).copied()
    }

    /// Record receipt of any message from `peer` during our `round`.
    /// Returns true if this revived a previously-crashed peer.
    pub fn record_message(&mut self, peer: ClientId, round: u32, terminated: bool) -> bool {
        let mut revived = false;
        if let Some(s) = self.status.get_mut(&peer) {
            if *s == PeerStatus::Crashed {
                revived = true;
                self.events.push(PeerEvent::Revived { round, peer });
            }
            // A terminate flag pins the peer to Terminated; otherwise alive.
            *s = if terminated { PeerStatus::Terminated } else { PeerStatus::Alive };
            self.last_heard.insert(peer, Some(round));
        }
        revived
    }

    /// End-of-window sweep: every peer still `Alive` that was *not* heard
    /// during `round` is marked crashed.  Returns the newly-crashed ids.
    pub fn mark_missing(&mut self, round: u32, heard: &BTreeSet<ClientId>) -> Vec<ClientId> {
        let mut newly = Vec::new();
        for (&peer, s) in self.status.iter_mut() {
            if *s == PeerStatus::Alive && !heard.contains(&peer) {
                *s = PeerStatus::Crashed;
                self.events.push(PeerEvent::Crashed { round, peer });
                newly.push(peer);
            }
        }
        newly
    }

    /// Peers currently believed alive (participating in aggregation).
    pub fn alive(&self) -> Vec<ClientId> {
        self.status
            .iter()
            .filter(|(_, &s)| s == PeerStatus::Alive)
            .map(|(&p, _)| p)
            .collect()
    }

    pub fn crashed(&self) -> Vec<ClientId> {
        self.status
            .iter()
            .filter(|(_, &s)| s == PeerStatus::Crashed)
            .map(|(&p, _)| p)
            .collect()
    }

    pub fn terminated(&self) -> Vec<ClientId> {
        self.status
            .iter()
            .filter(|(_, &s)| s == PeerStatus::Terminated)
            .map(|(&p, _)| p)
            .collect()
    }

    pub fn events(&self) -> &[PeerEvent] {
        &self.events
    }

    /// Did any crash event land within the last `window` rounds
    /// (relative to `current_round`)?  This is CCC condition (a):
    /// "x consecutive rounds without any detected crashes".
    pub fn recent_crash(&self, current_round: u32, window: u32) -> bool {
        self.events.iter().any(|e| match e {
            PeerEvent::Crashed { round, .. } => {
                current_round.saturating_sub(*round) < window
            }
            _ => false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn silence_marks_crash() {
        let mut t = PeerTable::new(&[1, 2, 3]);
        t.record_message(1, 0, false);
        let newly = t.mark_missing(0, &BTreeSet::from([1]));
        assert_eq!(newly, vec![2, 3]);
        assert_eq!(t.status(1), Some(PeerStatus::Alive));
        assert_eq!(t.status(2), Some(PeerStatus::Crashed));
        assert_eq!(t.alive(), vec![1]);
    }

    #[test]
    fn late_message_revives() {
        let mut t = PeerTable::new(&[1]);
        t.mark_missing(0, &BTreeSet::new());
        assert_eq!(t.status(1), Some(PeerStatus::Crashed));
        let revived = t.record_message(1, 3, false);
        assert!(revived);
        assert_eq!(t.status(1), Some(PeerStatus::Alive));
        assert!(t
            .events()
            .contains(&PeerEvent::Revived { round: 3, peer: 1 }));
    }

    #[test]
    fn terminated_peers_not_marked_crashed() {
        let mut t = PeerTable::new(&[1, 2]);
        t.record_message(1, 0, true); // peer 1 announced termination
        let newly = t.mark_missing(1, &BTreeSet::new()); // silence from both
        assert_eq!(newly, vec![2]); // only 2 is a crash
        assert_eq!(t.status(1), Some(PeerStatus::Terminated));
        assert_eq!(t.terminated(), vec![1]);
    }

    #[test]
    fn recent_crash_window() {
        let mut t = PeerTable::new(&[1, 2]);
        t.mark_missing(5, &BTreeSet::from([2])); // 1 crashes at round 5
        assert!(t.recent_crash(5, 3));
        assert!(t.recent_crash(7, 3));
        assert!(!t.recent_crash(8, 3));
        assert!(!t.recent_crash(20, 3));
    }

    #[test]
    fn unknown_peer_ignored() {
        let mut t = PeerTable::new(&[1]);
        assert!(!t.record_message(99, 0, false));
        assert_eq!(t.status(99), None);
    }

    #[test]
    fn crash_then_terminate_flag_pins_terminated() {
        let mut t = PeerTable::new(&[1]);
        t.mark_missing(0, &BTreeSet::new());
        // peer was slow, not dead, and meanwhile learned of termination
        t.record_message(1, 4, true);
        assert_eq!(t.status(1), Some(PeerStatus::Terminated));
        assert_eq!(t.mark_missing(5, &BTreeSet::new()), Vec::<ClientId>::new());
    }
}
