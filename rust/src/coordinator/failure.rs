//! Peer status tracking + timeout-based crash detection.
//!
//! The paper's Phase-2 rule (§3.2): a client waits `TIMEOUT` for a message
//! from each peer; silence ⇒ mark crashed and proceed.  A later message
//! from a "crashed" peer flips it back to alive ("if the message m is
//! delayed then C_i will consider m in whatever round it receives and
//! change the status of C_j as alive") — this is what distinguishes *slow*
//! from *failed* clients.  Peers that announced termination are *not*
//! treated as crashed when they fall silent; that disambiguation is the
//! point of the Client-Responsive Termination protocol.
//!
//! Scope: a client tracks its *overlay neighborhood*
//! ([`crate::net::Transport::neighbors`]) — the full peer set on the
//! default all-to-all topology, a degree-d subset on a sparse overlay
//! (DESIGN.md §9), which is also the population quorum-CCC's condition
//! (a) ranges over.
//!
//! Storage is dense: status is a vector indexed by client id (1 byte of
//! state per slot) rather than a pair of BTreeMaps, and per-window
//! membership checks run on [`IdSet`] bitsets — the difference between
//! megabytes and gigabytes for the full 10 000-client deployment.

use crate::net::ClientId;

/// Liveness knowledge about one peer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeerStatus {
    Alive,
    /// Missed a full wait window and has not been heard since.
    Crashed,
    /// Sent (or relayed) the termination flag; silence is expected.
    Terminated,
}

/// One crash/revival event (for logs and the figures' crash accounting).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PeerEvent {
    Crashed { round: u32, peer: ClientId },
    Revived { round: u32, peer: ClientId },
}

/// Dense bitset of client ids: O(1) insert/contains with 1 bit per id,
/// cheap enough to rebuild every wait window even at 10 000 clients.
#[derive(Clone, Debug, Default)]
pub struct IdSet {
    bits: Vec<u64>,
    len: usize,
}

impl IdSet {
    pub fn new() -> IdSet {
        IdSet::default()
    }

    /// Insert `id`; returns true if it was not already present.
    pub fn insert(&mut self, id: ClientId) -> bool {
        let (word, bit) = (id as usize / 64, id as usize % 64);
        if word >= self.bits.len() {
            self.bits.resize(word + 1, 0);
        }
        let mask = 1u64 << bit;
        if self.bits[word] & mask != 0 {
            return false;
        }
        self.bits[word] |= mask;
        self.len += 1;
        true
    }

    pub fn contains(&self, id: ClientId) -> bool {
        let (word, bit) = (id as usize / 64, id as usize % 64);
        self.bits.get(word).is_some_and(|w| w & (1u64 << bit) != 0)
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Remove every id, keeping the bitset's allocation for reuse.
    pub fn clear(&mut self) {
        self.bits.iter_mut().for_each(|w| *w = 0);
        self.len = 0;
    }
}

impl FromIterator<ClientId> for IdSet {
    fn from_iter<I: IntoIterator<Item = ClientId>>(iter: I) -> IdSet {
        let mut set = IdSet::new();
        for id in iter {
            set.insert(id);
        }
        set
    }
}

/// Per-client view of every peer's liveness (dense by client id).
#[derive(Clone, Debug)]
pub struct PeerTable {
    /// `status[id]`: `None` = not a tracked peer (self / outside the
    /// neighborhood / unknown id).
    status: Vec<Option<PeerStatus>>,
    /// Last-known status of peers [`PeerTable::retrack`] dropped from the
    /// neighborhood: when an overlay change brings such a peer back (a
    /// cut heals, a churned client rejoins nearby), its suspicion or
    /// termination knowledge is restored instead of resurrecting it as
    /// `Alive` — a healed edge is not evidence that a dead peer lives.
    parked: Vec<Option<PeerStatus>>,
    /// Count of peers currently `Alive` (maintained incrementally so the
    /// per-round metrics never rescan the table).
    alive: usize,
    /// How many peers this table tracks (static: the neighborhood size,
    /// the denominator of quorum-CCC's condition (a)).
    tracked: usize,
    events: Vec<PeerEvent>,
}

impl PeerTable {
    pub fn new(peers: &[ClientId]) -> Self {
        let size = peers.iter().max().map_or(0, |&m| m as usize + 1);
        let mut status = vec![None; size];
        for &p in peers {
            status[p as usize] = Some(PeerStatus::Alive);
        }
        PeerTable {
            status,
            parked: Vec::new(),
            alive: peers.len(),
            tracked: peers.len(),
            events: Vec::new(),
        }
    }

    /// How many peers this table tracks (the neighborhood size — the
    /// quorum denominator).  Static on a static overlay; under graph
    /// faults [`PeerTable::retrack`] applies neighborhood deltas, so the
    /// denominator follows the *current* overlay instead of the one the
    /// client booted with.
    pub fn tracked(&self) -> usize {
        self.tracked
    }

    /// Re-scope the table to a new neighborhood (graph faults: cuts,
    /// churn, edge repair — DESIGN.md §10).  Applied as a delta:
    ///
    /// * peers no longer in the neighborhood are dropped from the tracked
    ///   set (keeping them would hold the quorum denominator stale
    ///   against a graph that moved on), but their last-known status is
    ///   *parked* for a possible return;
    /// * peers that persist keep their status — a crash suspicion is not
    ///   forgotten just because an unrelated edge moved;
    /// * entering neighbors restore their parked status if the table has
    ///   ever tracked them (a healed cut must not resurrect a dead or
    ///   terminated peer as `Alive` — that would stall the wait window on
    ///   a corpse and then re-suspect it as a *fresh* crash, resetting
    ///   the CCC streak exactly when the graph healed), and otherwise
    ///   enter as [`PeerStatus::Alive`], the optimistic default every
    ///   tracked peer starts with.
    ///
    /// Returns the peers that entered the tracked set as `Alive` (new, or
    /// parked-alive) — the set the CRT relay re-arms toward: an alive
    /// newcomer may have been out of the flood's reach while a terminate
    /// flag circulated ([`crate::coordinator::machine`], DESIGN.md §10).
    pub fn retrack(&mut self, neighbors: &[ClientId]) -> Vec<ClientId> {
        let keep: IdSet = neighbors.iter().copied().collect();
        for id in 0..self.status.len() {
            if self.status[id].is_some() && !keep.contains(id as ClientId) {
                if self.status[id] == Some(PeerStatus::Alive) {
                    self.alive -= 1;
                }
                if id >= self.parked.len() {
                    self.parked.resize(id + 1, None);
                }
                self.parked[id] = self.status[id].take();
                self.tracked -= 1;
            }
        }
        let mut entered_alive = Vec::new();
        for &p in neighbors {
            if p as usize >= self.status.len() {
                self.status.resize(p as usize + 1, None);
            }
            if self.status[p as usize].is_none() {
                let restored = self
                    .parked
                    .get_mut(p as usize)
                    .and_then(Option::take)
                    .unwrap_or(PeerStatus::Alive);
                self.status[p as usize] = Some(restored);
                if restored == PeerStatus::Alive {
                    self.alive += 1;
                    entered_alive.push(p);
                }
                self.tracked += 1;
            }
        }
        entered_alive
    }

    pub fn status(&self, peer: ClientId) -> Option<PeerStatus> {
        self.status.get(peer as usize).copied().flatten()
    }

    /// Record receipt of any message from `peer` during our `round`.
    /// Returns true if this revived a previously-crashed peer.
    pub fn record_message(&mut self, peer: ClientId, round: u32, terminated: bool) -> bool {
        let prev = match self.status.get(peer as usize) {
            Some(Some(s)) => *s,
            _ => return false,
        };
        let revived = prev == PeerStatus::Crashed;
        if revived {
            self.events.push(PeerEvent::Revived { round, peer });
        }
        // A terminate flag pins the peer to Terminated; otherwise alive.
        let next = if terminated { PeerStatus::Terminated } else { PeerStatus::Alive };
        match (prev == PeerStatus::Alive, next == PeerStatus::Alive) {
            (true, false) => self.alive -= 1,
            (false, true) => self.alive += 1,
            _ => {}
        }
        self.status[peer as usize] = Some(next);
        revived
    }

    /// End-of-window sweep: every peer still `Alive` that was *not* heard
    /// during `round` is marked crashed.  Returns the newly-crashed ids
    /// (ascending).
    pub fn mark_missing(&mut self, round: u32, heard: &IdSet) -> Vec<ClientId> {
        let mut newly = Vec::new();
        for id in 0..self.status.len() {
            let peer = id as ClientId;
            if self.status[id] == Some(PeerStatus::Alive) && !heard.contains(peer) {
                self.status[id] = Some(PeerStatus::Crashed);
                self.alive -= 1;
                self.events.push(PeerEvent::Crashed { round, peer });
                newly.push(peer);
            }
        }
        newly
    }

    fn with_status(&self, want: PeerStatus) -> Vec<ClientId> {
        self.status
            .iter()
            .enumerate()
            .filter(|(_, s)| **s == Some(want))
            .map(|(id, _)| id as ClientId)
            .collect()
    }

    /// Peers currently believed alive (participating in aggregation),
    /// ascending by id.
    pub fn alive(&self) -> Vec<ClientId> {
        self.with_status(PeerStatus::Alive)
    }

    /// The alive peers as a bitset (the per-window working form: no
    /// intermediate `Vec` on the once-per-round path).
    pub fn alive_ids(&self) -> IdSet {
        let mut set = IdSet::new();
        self.alive_ids_into(&mut set);
        set
    }

    /// [`PeerTable::alive_ids`] into a caller-owned set: clears `set` and
    /// refills it, reusing its bitset allocation (the window-reopen path
    /// calls this every round).
    pub fn alive_ids_into(&self, set: &mut IdSet) {
        set.clear();
        for (id, s) in self.status.iter().enumerate() {
            if *s == Some(PeerStatus::Alive) {
                set.insert(id as ClientId);
            }
        }
    }

    /// How many peers are currently believed alive (O(1); the per-round
    /// metrics path at four-digit client counts).
    pub fn alive_count(&self) -> usize {
        self.alive
    }

    pub fn crashed(&self) -> Vec<ClientId> {
        self.with_status(PeerStatus::Crashed)
    }

    pub fn terminated(&self) -> Vec<ClientId> {
        self.with_status(PeerStatus::Terminated)
    }

    pub fn events(&self) -> &[PeerEvent] {
        &self.events
    }

    /// Did any crash event land within the last `window` rounds
    /// (relative to `current_round`)?  This is CCC condition (a):
    /// "x consecutive rounds without any detected crashes".
    pub fn recent_crash(&self, current_round: u32, window: u32) -> bool {
        self.events.iter().any(|e| match e {
            PeerEvent::Crashed { round, .. } => {
                current_round.saturating_sub(*round) < window
            }
            _ => false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids<I: IntoIterator<Item = ClientId>>(iter: I) -> IdSet {
        iter.into_iter().collect()
    }

    #[test]
    fn tracked_denominator_ignores_suspicion_and_termination() {
        // Only `retrack` (an overlay change) may move the denominator —
        // liveness transitions never do.
        let mut t = PeerTable::new(&[1, 5, 9]);
        assert_eq!(t.tracked(), 3);
        t.mark_missing(0, &ids([]));
        assert_eq!(t.tracked(), 3, "suspicion must not shrink the denominator");
        t.record_message(5, 1, true);
        assert_eq!(t.tracked(), 3, "termination must not shrink the denominator");
    }

    #[test]
    fn silence_marks_crash() {
        let mut t = PeerTable::new(&[1, 2, 3]);
        t.record_message(1, 0, false);
        let newly = t.mark_missing(0, &ids([1]));
        assert_eq!(newly, vec![2, 3]);
        assert_eq!(t.status(1), Some(PeerStatus::Alive));
        assert_eq!(t.status(2), Some(PeerStatus::Crashed));
        assert_eq!(t.alive(), vec![1]);
        assert_eq!(t.alive_count(), 1);
        let ids = t.alive_ids();
        assert!(ids.contains(1) && !ids.contains(2) && ids.len() == 1);
    }

    #[test]
    fn late_message_revives() {
        let mut t = PeerTable::new(&[1]);
        t.mark_missing(0, &ids([]));
        assert_eq!(t.status(1), Some(PeerStatus::Crashed));
        assert_eq!(t.alive_count(), 0);
        let revived = t.record_message(1, 3, false);
        assert!(revived);
        assert_eq!(t.status(1), Some(PeerStatus::Alive));
        assert_eq!(t.alive_count(), 1);
        assert!(t
            .events()
            .contains(&PeerEvent::Revived { round: 3, peer: 1 }));
    }

    #[test]
    fn terminated_peers_not_marked_crashed() {
        let mut t = PeerTable::new(&[1, 2]);
        t.record_message(1, 0, true); // peer 1 announced termination
        let newly = t.mark_missing(1, &ids([])); // silence from both
        assert_eq!(newly, vec![2]); // only 2 is a crash
        assert_eq!(t.status(1), Some(PeerStatus::Terminated));
        assert_eq!(t.terminated(), vec![1]);
        assert_eq!(t.alive_count(), 0);
    }

    #[test]
    fn recent_crash_window() {
        let mut t = PeerTable::new(&[1, 2]);
        t.mark_missing(5, &ids([2])); // 1 crashes at round 5
        assert!(t.recent_crash(5, 3));
        assert!(t.recent_crash(7, 3));
        assert!(!t.recent_crash(8, 3));
        assert!(!t.recent_crash(20, 3));
    }

    #[test]
    fn unknown_peer_ignored() {
        let mut t = PeerTable::new(&[1]);
        assert!(!t.record_message(99, 0, false));
        assert_eq!(t.status(99), None);
    }

    #[test]
    fn crash_then_terminate_flag_pins_terminated() {
        let mut t = PeerTable::new(&[1]);
        t.mark_missing(0, &ids([]));
        // peer was slow, not dead, and meanwhile learned of termination
        t.record_message(1, 4, true);
        assert_eq!(t.status(1), Some(PeerStatus::Terminated));
        assert_eq!(t.mark_missing(5, &ids([])), Vec::<ClientId>::new());
    }

    #[test]
    fn retrack_applies_neighborhood_deltas() {
        let mut t = PeerTable::new(&[1, 2, 3]);
        t.record_message(2, 0, true); // 2 terminated
        t.mark_missing(0, &ids([1])); // 3 crashed
        assert_eq!(t.tracked(), 3);
        // overlay rewires: lose 3, keep 1 (alive) and 2 (terminated), gain 5
        let entered = t.retrack(&[1, 2, 5]);
        assert_eq!(entered, vec![5], "only the alive newcomer is reported");
        assert_eq!(t.tracked(), 3, "denominator follows the new neighborhood");
        assert_eq!(t.status(3), None, "dropped peer is gone");
        assert_eq!(t.status(1), Some(PeerStatus::Alive), "kept peer keeps state");
        assert_eq!(t.status(2), Some(PeerStatus::Terminated), "kept state survives");
        assert_eq!(t.status(5), Some(PeerStatus::Alive), "new neighbor starts alive");
        assert_eq!(t.alive_count(), 2);
        // shrink to nothing (a churned-out client)
        assert!(t.retrack(&[]).is_empty());
        assert_eq!(t.tracked(), 0);
        assert_eq!(t.alive_count(), 0);
        // and regrow past the original id range
        assert_eq!(t.retrack(&[9]), vec![9]);
        assert_eq!(t.tracked(), 1);
        assert_eq!(t.status(9), Some(PeerStatus::Alive));
    }

    #[test]
    fn retrack_with_same_neighborhood_is_a_noop() {
        let mut t = PeerTable::new(&[1, 4]);
        t.mark_missing(0, &ids([4]));
        let (alive, tracked) = (t.alive_count(), t.tracked());
        assert!(t.retrack(&[1, 4]).is_empty(), "nothing entered");
        assert_eq!(t.alive_count(), alive);
        assert_eq!(t.tracked(), tracked);
        assert_eq!(t.status(1), Some(PeerStatus::Crashed), "suspicion not forgotten");
    }

    #[test]
    fn retrack_restores_parked_status_instead_of_resurrecting() {
        // A healed cut is not evidence of life: a peer dropped while
        // Crashed/Terminated must come back in that same state.
        let mut t = PeerTable::new(&[1, 2, 3]);
        t.record_message(3, 0, true); // 3 terminated
        t.mark_missing(0, &ids([2])); // 1 crashed
        // cut severs edges to 1 and 3
        t.retrack(&[2]);
        assert_eq!(t.tracked(), 1);
        // cut heals: both return with their remembered states
        let entered = t.retrack(&[1, 2, 3]);
        assert!(entered.is_empty(), "no resurrected peer counts as an alive entry");
        assert_eq!(t.status(1), Some(PeerStatus::Crashed), "suspicion restored");
        assert_eq!(t.status(3), Some(PeerStatus::Terminated), "termination restored");
        assert_eq!(t.alive_count(), 1, "only 2 is alive");
        assert_eq!(t.tracked(), 3);
        // a restored-crashed peer can still revive by speaking
        assert!(t.record_message(1, 5, false));
        assert_eq!(t.status(1), Some(PeerStatus::Alive));
    }

    #[test]
    fn idset_clear_keeps_capacity_and_alive_ids_into_matches() {
        let mut s = IdSet::new();
        s.insert(3);
        s.insert(200);
        s.clear();
        assert!(s.is_empty());
        assert!(!s.contains(3) && !s.contains(200));
        let mut t = PeerTable::new(&[1, 2, 3]);
        t.mark_missing(0, &ids([2]));
        t.alive_ids_into(&mut s);
        assert_eq!(
            (s.contains(1), s.contains(2), s.contains(3), s.len()),
            (false, true, true, 2),
            "refill must match a fresh alive_ids()"
        );
    }

    #[test]
    fn idset_insert_contains_len() {
        let mut s = IdSet::new();
        assert!(s.is_empty());
        assert!(s.insert(3));
        assert!(!s.insert(3), "double insert must report existing");
        assert!(s.insert(200)); // forces bitset growth
        assert!(s.contains(3));
        assert!(s.contains(200));
        assert!(!s.contains(64));
        assert_eq!(s.len(), 2);
    }
}
