//! Crash schedules + fault injection for the three fault experiments,
//! plus the topology-aware graph-fault family (DESIGN.md §10).
//!
//! A crashed client goes *silent* (benign crash model §3.1): its thread
//! stops broadcasting and receiving; it never sends wrong data.  Schedules
//! reproduce the paper's setups:
//!
//! * **Experiment 1** (variable crash): `k` of `n` clients crash, staggered
//!   through the run.
//! * **Experiment 2** (proportional): ⌊n/3⌋ clients "fail during the system
//!   execution at regular intervals" around the middle of the run.
//! * **Experiment 3** (maximum fault): n−1 crash, one survivor.
//!
//! [`GraphFault`]s attack the *communication graph* instead of the client
//! set: [`GraphFault::EdgeCut`] severs a named overlay cut for a time
//! window (a partition that is real on the built graph, unlike a
//! client-ID bisection that may cross zero edges of a sparse overlay),
//! and [`GraphFault::Churn`] removes a client from the overlay mid-run
//! (edges torn down, orphaned neighbors repaired) and optionally rejoins
//! it later with deterministically regenerated edges.  They are compiled
//! against the built [`crate::net::Topology`] at sim setup and applied by
//! the shared [`crate::net::Overlay`] as the deployment clock reaches
//! them.
//!
//! [`AdversarySpec`]s break the benign-failure assumption entirely
//! (DESIGN.md §11): an adversarial client stays *live* but sends wrong
//! data — scaled/inverted models ([`AdversaryKind::Poison`]), different
//! models to different neighbors ([`AdversaryKind::Equivocate`]), an old
//! model under fresh round tags ([`AdversaryKind::StaleReplay`]), or
//! manufactured suspicion churn aimed at stalling CCC/CRT
//! ([`AdversaryKind::ForgeSuspicion`]).  Specs are parsed from
//! `dfl sim --adversary` and compiled/validated in [`crate::sim::run`]
//! like graph faults; the counter-measure is the robust
//! [`crate::runtime::AggregationRule`] family.

use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::net::ClientId;
use crate::util::Rng;

/// When (if ever) a client is scheduled to crash.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CrashPoint {
    Never,
    /// Crash at the start of the given local round.
    AtRound(u32),
    /// Crash once this much clock time (wall or virtual) has elapsed since
    /// client start.
    AtElapsed(Duration),
}

/// Per-client fault plan: a benign crash point, optionally *transient*
/// (§3.1: "supports temporary and intermittent failures, allowing clients
/// to rejoin after transient faults") — with `rejoin_after` set, the
/// client goes silent for that long and then resumes; peers mark it
/// crashed by timeout and revive it on its first post-outage message.
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultPlan {
    pub crash: Option<CrashPoint>,
    /// None = permanent crash; Some(d) = transient outage of length d.
    pub rejoin_after: Option<Duration>,
}

impl FaultPlan {
    pub fn none() -> Self {
        FaultPlan::default()
    }

    pub fn at_round(round: u32) -> Self {
        FaultPlan { crash: Some(CrashPoint::AtRound(round)), rejoin_after: None }
    }

    pub fn at_elapsed(d: Duration) -> Self {
        FaultPlan { crash: Some(CrashPoint::AtElapsed(d)), rejoin_after: None }
    }

    /// Transient outage: silent from `round` for `downtime`, then rejoin.
    pub fn transient(round: u32, downtime: Duration) -> Self {
        FaultPlan { crash: Some(CrashPoint::AtRound(round)), rejoin_after: Some(downtime) }
    }

    /// Checked at the top of every client round.  `elapsed` is time since
    /// client start on the client's [`crate::util::time::Clock`] — wall or
    /// virtual, so elapsed-triggered crashes stay meaningful (and
    /// deterministic) in simulated time.
    pub fn should_crash(&self, round: u32, elapsed: Duration) -> bool {
        match self.crash {
            None => false,
            Some(CrashPoint::Never) => false,
            Some(CrashPoint::AtRound(r)) => round >= r,
            Some(CrashPoint::AtElapsed(d)) => elapsed >= d,
        }
    }
}

/// Which overlay edges an [`GraphFault::EdgeCut`] severs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CutSpec {
    /// An explicit edge list (each pair an existing overlay edge —
    /// validated against the built graph at sim setup).
    Edges(Vec<(ClientId, ClientId)>),
    /// A seeded approximate min-cut of the built topology
    /// ([`crate::net::Topology::min_cut`]): sever the overlay where it is
    /// thinnest.
    MinCut,
}

/// A topology-aware fault: a scheduled change to the communication graph
/// itself (`dfl sim --fault`, DESIGN.md §10).  Times are measured on the
/// deployment clock (virtual or wall), like [`crate::net::NetSplit`]
/// windows.
#[derive(Clone, Debug, PartialEq)]
pub enum GraphFault {
    /// Sever a named overlay cut for `[start, end)`; the severed edges
    /// heal at `end` (unless an endpoint has meanwhile churned out).
    EdgeCut { start: Duration, end: Duration, cut: CutSpec },
    /// `client` leaves the overlay at `leave` (edges torn down, orphaned
    /// neighbors re-attached to maintain their quorum denominators) and
    /// rejoins at `rejoin` with deterministically regenerated edges
    /// (`None` = permanent departure).
    Churn { client: ClientId, leave: Duration, rejoin: Option<Duration> },
}

impl GraphFault {
    /// Parse one CLI spelling:
    ///
    /// * `graph-cut:START-END:mincut` — seeded min-cut window
    /// * `graph-cut:START-END:A-B,C-D,…` — explicit edge-list window
    /// * `churn:CLIENT:LEAVE-REJOIN` / `churn:CLIENT:LEAVE` — churn
    ///
    /// Times are seconds (fractions allowed).
    ///
    /// ```
    /// use dfl::coordinator::fault::{CutSpec, GraphFault};
    /// use std::time::Duration;
    ///
    /// assert_eq!(
    ///     GraphFault::parse("churn:3:0.5-1.5").unwrap(),
    ///     GraphFault::Churn {
    ///         client: 3,
    ///         leave: Duration::from_secs_f64(0.5),
    ///         rejoin: Some(Duration::from_secs_f64(1.5)),
    ///     }
    /// );
    /// assert!(matches!(
    ///     GraphFault::parse("graph-cut:0.2-0.8:mincut").unwrap(),
    ///     GraphFault::EdgeCut { cut: CutSpec::MinCut, .. }
    /// ));
    /// assert!(GraphFault::parse("graph-cut:0.8-0.2:mincut").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<GraphFault> {
        let secs = |v: &str, what: &str| -> Result<Duration> {
            let x: f64 = v
                .parse()
                .map_err(|_| anyhow::anyhow!("graph fault {s:?}: bad {what} {v:?}"))?;
            // The upper bound (~31 years) keeps Duration::from_secs_f64
            // from panicking on absurd inputs — the parser's whole job is
            // to return errors, not crash on them.
            anyhow::ensure!(
                x.is_finite() && (0.0..=1.0e9).contains(&x),
                "graph fault {s:?}: {what} must be a time in [0, 1e9] seconds"
            );
            Ok(Duration::from_secs_f64(x))
        };
        let mut parts = s.splitn(3, ':');
        let kind = parts.next().unwrap_or("");
        match kind {
            "graph-cut" | "cut" => {
                let window = parts.next().context("graph-cut: missing START-END window")?;
                let (a, b) = window
                    .split_once('-')
                    .with_context(|| format!("graph fault {s:?}: window wants START-END"))?;
                let (start, end) = (secs(a, "window start")?, secs(b, "window end")?);
                anyhow::ensure!(end > start, "graph fault {s:?}: window must end after it starts");
                let spec = parts.next().context("graph-cut: missing mincut|edge list")?;
                let cut = if spec == "mincut" {
                    CutSpec::MinCut
                } else {
                    let edges = spec
                        .split(',')
                        .filter(|e| !e.is_empty())
                        .map(|e| {
                            let (x, y) = e
                                .split_once('-')
                                .with_context(|| format!("graph fault {s:?}: edge {e:?} wants A-B"))?;
                            let a: ClientId = x.parse().with_context(|| format!("edge {e:?}"))?;
                            let b: ClientId = y.parse().with_context(|| format!("edge {e:?}"))?;
                            anyhow::ensure!(a != b, "graph fault {s:?}: self-loop edge {e:?}");
                            Ok((a.min(b), a.max(b)))
                        })
                        .collect::<Result<Vec<_>>>()?;
                    anyhow::ensure!(!edges.is_empty(), "graph fault {s:?}: empty edge list");
                    CutSpec::Edges(edges)
                };
                Ok(GraphFault::EdgeCut { start, end, cut })
            }
            "churn" => {
                let client: ClientId = parts
                    .next()
                    .and_then(|v| v.parse().ok())
                    .with_context(|| format!("graph fault {s:?}: missing/bad client id"))?;
                let times = parts.next().context("churn: missing LEAVE[-REJOIN] times")?;
                let (leave, rejoin) = match times.split_once('-') {
                    Some((l, r)) => (secs(l, "leave time")?, Some(secs(r, "rejoin time")?)),
                    None => (secs(times, "leave time")?, None),
                };
                if let Some(r) = rejoin {
                    anyhow::ensure!(r > leave, "graph fault {s:?}: rejoin must follow leave");
                }
                Ok(GraphFault::Churn { client, leave, rejoin })
            }
            _ => bail!(
                "unknown graph fault {s:?} (want graph-cut:START-END:mincut|A-B,… or churn:CLIENT:LEAVE[-REJOIN])"
            ),
        }
    }

    /// Parse a `;`-separated schedule (the `--fault` flag's value).
    pub fn parse_list(s: &str) -> Result<Vec<GraphFault>> {
        s.split(';').filter(|p| !p.trim().is_empty()).map(|p| GraphFault::parse(p.trim())).collect()
    }

    /// The CLI spelling (round-trips through [`GraphFault::parse`]).
    pub fn name(&self) -> String {
        match self {
            GraphFault::EdgeCut { start, end, cut } => {
                let spec = match cut {
                    CutSpec::MinCut => "mincut".to_string(),
                    CutSpec::Edges(edges) => edges
                        .iter()
                        .map(|(a, b)| format!("{a}-{b}"))
                        .collect::<Vec<_>>()
                        .join(","),
                };
                format!("graph-cut:{}-{}:{spec}", start.as_secs_f64(), end.as_secs_f64())
            }
            GraphFault::Churn { client, leave, rejoin } => match rejoin {
                Some(r) => format!("churn:{client}:{}-{}", leave.as_secs_f64(), r.as_secs_f64()),
                None => format!("churn:{client}:{}", leave.as_secs_f64()),
            },
        }
    }

    /// Does this fault reference only clients below `n`?  (The shrinker
    /// drops faults that would dangle when the client count shrinks.)
    pub fn fits(&self, n: usize) -> bool {
        match self {
            GraphFault::EdgeCut { cut: CutSpec::Edges(edges), .. } => {
                edges.iter().all(|&(a, b)| (a as usize) < n && (b as usize) < n)
            }
            GraphFault::EdgeCut { cut: CutSpec::MinCut, .. } => true,
            GraphFault::Churn { client, .. } => (*client as usize) < n,
        }
    }
}

/// What a Byzantine client *does* (DESIGN.md §11).  Unlike the benign
/// [`FaultPlan`] crash model, an adversary stays live — it trains,
/// receives, and participates in termination — but its outgoing updates
/// lie.  Honest clients cannot tell an adversary from a peer with odd
/// data, which is exactly why the counter-measure lives in the
/// aggregation rule rather than in detection.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AdversaryKind {
    /// Send *different* models to different neighbors each round (a
    /// split-brain attack on model agreement: neighbors can never
    /// converge to one another because each sees a distinct lie).
    Equivocate,
    /// Send the true local model with every coordinate multiplied by
    /// `scale` (negative values invert the gradient direction; large
    /// magnitudes dominate a mean-based aggregate).
    Poison { scale: f32 },
    /// Snapshot the first model ever broadcast and re-send it forever
    /// under fresh round tags — freshness checks pass, content is stale.
    StaleReplay,
    /// Manufacture suspicion churn: stay live but go selectively silent
    /// toward alternating halves of the neighborhood each round, so every
    /// neighbor perpetually re-suspects and revives this client.  (The
    /// protocol has no explicit suspicion frames to forge — suspicion is
    /// local and timeout-derived — so fabricated *silence* is the attack
    /// surface; see DESIGN.md §11 for what this can and cannot stall.)
    ForgeSuspicion,
}

/// One adversary assignment: a behavior and the clients playing it
/// (`dfl sim --adversary 'poison:-10:C1,C2;equivocate:C3'`).  Compiled
/// and validated in [`crate::sim::run`] like graph faults: ids must be in
/// range and no client may play two roles.
#[derive(Clone, Debug, PartialEq)]
pub struct AdversarySpec {
    pub kind: AdversaryKind,
    pub clients: Vec<ClientId>,
}

impl AdversarySpec {
    /// Parse one CLI spelling:
    ///
    /// * `poison:SCALE:IDS` — scaled/inverted model updates
    /// * `equivocate:IDS` — per-neighbor divergent updates
    /// * `stale-replay:IDS` — first model re-sent under fresh round tags
    /// * `forge-suspicion:IDS` — manufactured suspicion flapping
    ///
    /// `IDS` is a comma-separated client list; a leading `C`/`c` per id is
    /// accepted (`C1,C2` and `1,2` both work).
    ///
    /// ```
    /// use dfl::coordinator::fault::{AdversaryKind, AdversarySpec};
    ///
    /// assert_eq!(
    ///     AdversarySpec::parse("poison:-10:C1,C2").unwrap(),
    ///     AdversarySpec { kind: AdversaryKind::Poison { scale: -10.0 }, clients: vec![1, 2] }
    /// );
    /// assert!(AdversarySpec::parse("poison:inf:1").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<AdversarySpec> {
        let ids = |v: &str| -> Result<Vec<ClientId>> {
            let clients = v
                .split(',')
                .filter(|p| !p.trim().is_empty())
                .map(|p| {
                    let p = p.trim();
                    let digits = p.strip_prefix(['C', 'c']).unwrap_or(p);
                    digits
                        .parse::<ClientId>()
                        .map_err(|_| anyhow::anyhow!("adversary {s:?}: bad client id {p:?}"))
                })
                .collect::<Result<Vec<_>>>()?;
            anyhow::ensure!(!clients.is_empty(), "adversary {s:?}: empty client list");
            Ok(clients)
        };
        let mut parts = s.splitn(3, ':');
        let kind = parts.next().unwrap_or("");
        match kind {
            "poison" => {
                let scale_str = parts.next().context("poison: missing SCALE")?;
                let scale: f32 = scale_str
                    .parse()
                    .map_err(|_| anyhow::anyhow!("adversary {s:?}: bad scale {scale_str:?}"))?;
                anyhow::ensure!(scale.is_finite(), "adversary {s:?}: scale must be finite");
                let clients = ids(parts.next().context("poison: missing client list")?)?;
                Ok(AdversarySpec { kind: AdversaryKind::Poison { scale }, clients })
            }
            "equivocate" | "stale-replay" | "forge-suspicion" => {
                let list = parts.next().with_context(|| format!("{kind}: missing client list"))?;
                anyhow::ensure!(
                    parts.next().is_none(),
                    "adversary {s:?}: {kind} takes exactly one :IDS field"
                );
                let clients = ids(list)?;
                let kind = match kind {
                    "equivocate" => AdversaryKind::Equivocate,
                    "stale-replay" => AdversaryKind::StaleReplay,
                    _ => AdversaryKind::ForgeSuspicion,
                };
                Ok(AdversarySpec { kind, clients })
            }
            _ => bail!(
                "unknown adversary {s:?} (want poison:SCALE:IDS, equivocate:IDS, stale-replay:IDS, or forge-suspicion:IDS)"
            ),
        }
    }

    /// Parse a `;`-separated roster (the `--adversary` flag's value).
    pub fn parse_list(s: &str) -> Result<Vec<AdversarySpec>> {
        s.split(';')
            .filter(|p| !p.trim().is_empty())
            .map(|p| AdversarySpec::parse(p.trim()))
            .collect()
    }

    /// The CLI spelling (round-trips through [`AdversarySpec::parse`]).
    pub fn name(&self) -> String {
        let ids =
            self.clients.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(",");
        match self.kind {
            AdversaryKind::Poison { scale } => format!("poison:{scale}:{ids}"),
            AdversaryKind::Equivocate => format!("equivocate:{ids}"),
            AdversaryKind::StaleReplay => format!("stale-replay:{ids}"),
            AdversaryKind::ForgeSuspicion => format!("forge-suspicion:{ids}"),
        }
    }

    /// Does this spec reference only clients below `n`?  (Same contract
    /// as [`GraphFault::fits`] — the shrinker drops dangling specs.)
    pub fn fits(&self, n: usize) -> bool {
        self.clients.iter().all(|&c| (c as usize) < n)
    }
}

/// Compile a roster of specs into a per-client role table, validating id
/// range and rejecting double role assignment.  `roles[i]` is what client
/// `i` does; `None` = honest.
pub fn compile_adversaries(
    specs: &[AdversarySpec],
    n: usize,
) -> Result<Vec<Option<AdversaryKind>>> {
    let mut roles: Vec<Option<AdversaryKind>> = vec![None; n];
    for spec in specs {
        for &c in &spec.clients {
            anyhow::ensure!(
                (c as usize) < n,
                "adversary {:?} references client {c} but the sim has only {n} clients",
                spec.name()
            );
            anyhow::ensure!(
                roles[c as usize].is_none(),
                "client {c} is assigned two adversary roles"
            );
            roles[c as usize] = Some(spec.kind);
        }
    }
    Ok(roles)
}

/// Experiment 1 — crash `k` of `n` clients, staggered uniformly across
/// rounds `[min_round, max_round)`.  Which clients crash is seeded.
pub fn variable_crash_schedule(
    n: usize,
    k: usize,
    min_round: u32,
    max_round: u32,
    rng: &mut Rng,
) -> Vec<FaultPlan> {
    assert!(k <= n, "cannot crash {k} of {n}");
    let mut ids: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut ids);
    let mut plans = vec![FaultPlan::none(); n];
    let span = max_round.saturating_sub(min_round).max(1);
    for (j, &id) in ids.iter().take(k).enumerate() {
        // evenly staggered crash rounds so faults arrive throughout the run
        let round = min_round + (j as u32 * span) / k.max(1) as u32;
        plans[id] = FaultPlan::at_round(round.max(1)); // never before round 1
    }
    plans
}

/// Experiment 2 — ⌊n/3⌋ crashes at regular intervals mid-run.
pub fn proportional_schedule(n: usize, max_rounds: u32, rng: &mut Rng) -> Vec<FaultPlan> {
    let k = n / 3;
    // "sometime in the middle of the system execution"
    let lo = max_rounds / 3;
    let hi = (2 * max_rounds) / 3;
    variable_crash_schedule(n, k, lo.max(1), hi.max(2), rng)
}

/// Experiment 3 — n−1 crash staggered through the run; `survivor` stays.
pub fn max_fault_schedule(n: usize, survivor: usize, max_rounds: u32) -> Vec<FaultPlan> {
    assert!(survivor < n);
    let mut plans = Vec::with_capacity(n);
    let k = (n - 1).max(1) as u32;
    let span = max_rounds.max(2) / 2;
    let mut j = 0u32;
    for id in 0..n {
        if id == survivor {
            plans.push(FaultPlan::none());
        } else {
            // staggered in the first half so the survivor runs alone after
            let round = 1 + (j * span) / k;
            plans.push(FaultPlan::at_round(round));
            j += 1;
        }
    }
    plans
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_round_trigger() {
        let p = FaultPlan::at_round(5);
        assert!(!p.should_crash(4, Duration::ZERO));
        assert!(p.should_crash(5, Duration::ZERO));
        assert!(p.should_crash(9, Duration::ZERO));
        assert!(!FaultPlan::none().should_crash(100, Duration::ZERO));
    }

    #[test]
    fn plan_elapsed_trigger() {
        let p = FaultPlan::at_elapsed(Duration::from_millis(1));
        assert!(p.should_crash(0, Duration::from_millis(5)));
        let fresh = FaultPlan::at_elapsed(Duration::from_secs(3600));
        assert!(!fresh.should_crash(0, Duration::ZERO));
    }

    #[test]
    fn variable_schedule_counts() {
        let mut rng = Rng::new(1);
        for k in 0..=12 {
            let plans = variable_crash_schedule(12, k, 2, 20, &mut rng);
            let crashing = plans.iter().filter(|p| p.crash.is_some()).count();
            assert_eq!(crashing, k);
        }
    }

    #[test]
    fn variable_schedule_rounds_in_range() {
        let mut rng = Rng::new(2);
        let plans = variable_crash_schedule(12, 6, 5, 25, &mut rng);
        for p in plans.iter().filter(|p| p.crash.is_some()) {
            match p.crash.unwrap() {
                CrashPoint::AtRound(r) => assert!((5..25).contains(&r), "round {r}"),
                _ => panic!("wrong crash kind"),
            }
        }
    }

    #[test]
    fn proportional_schedule_is_third() {
        let mut rng = Rng::new(3);
        for n in [4, 6, 9, 12] {
            let plans = proportional_schedule(n, 30, &mut rng);
            assert_eq!(plans.iter().filter(|p| p.crash.is_some()).count(), n / 3);
        }
    }

    #[test]
    fn max_fault_spares_survivor() {
        let plans = max_fault_schedule(8, 3, 30);
        assert!(plans[3].crash.is_none());
        assert_eq!(plans.iter().filter(|p| p.crash.is_some()).count(), 7);
    }

    #[test]
    fn graph_fault_parse_round_trips() {
        for s in [
            "graph-cut:0.2-0.8:mincut",
            "graph-cut:0.5-1:3-7,0-9",
            "churn:4:0.3",
            "churn:4:0.3-0.9",
        ] {
            let f = GraphFault::parse(s).unwrap();
            assert_eq!(GraphFault::parse(&f.name()).unwrap(), f, "{s}");
        }
        let list = GraphFault::parse_list("graph-cut:0.2-0.8:mincut; churn:1:0.5").unwrap();
        assert_eq!(list.len(), 2);
        assert!(GraphFault::parse_list("").unwrap().is_empty());
    }

    #[test]
    fn graph_fault_parse_normalizes_and_rejects() {
        // edge endpoints normalized ascending
        match GraphFault::parse("graph-cut:0-1:9-3").unwrap() {
            GraphFault::EdgeCut { cut: CutSpec::Edges(e), .. } => assert_eq!(e, vec![(3, 9)]),
            other => panic!("wrong parse: {other:?}"),
        }
        for bad in [
            "",
            "graph-cut",
            "graph-cut:0.8-0.2:mincut", // inverted window
            "graph-cut:0.2-0.8:",       // empty edge list
            "graph-cut:0.2-0.8:3-3",    // self loop
            "graph-cut:x-1:mincut",
            "churn:4",
            "churn:4:0.9-0.3", // rejoin before leave
            "churn:x:0.3",
            "churn:3:1e20",            // would overflow Duration
            "graph-cut:0-1e300:mincut", // likewise
            "meteor:1:2",
        ] {
            assert!(GraphFault::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn graph_fault_fits_tracks_referenced_clients() {
        assert!(GraphFault::parse("churn:4:0.3").unwrap().fits(5));
        assert!(!GraphFault::parse("churn:4:0.3").unwrap().fits(4));
        let cut = GraphFault::parse("graph-cut:0-1:3-7").unwrap();
        assert!(cut.fits(8));
        assert!(!cut.fits(7));
        assert!(GraphFault::parse("graph-cut:0-1:mincut").unwrap().fits(1));
    }

    #[test]
    fn adversary_parse_round_trips() {
        for s in [
            "poison:-10:1,2",
            "poison:0.5:7",
            "equivocate:3",
            "stale-replay:0,4",
            "forge-suspicion:2,5,8",
        ] {
            let a = AdversarySpec::parse(s).unwrap();
            assert_eq!(AdversarySpec::parse(&a.name()).unwrap(), a, "{s}");
        }
        // issue spelling: C-prefixed ids, ;-separated roster
        let list = AdversarySpec::parse_list("poison:-10:C1,C2; equivocate:C3").unwrap();
        assert_eq!(list.len(), 2);
        assert_eq!(list[0].clients, vec![1, 2]);
        assert_eq!(list[0].kind, AdversaryKind::Poison { scale: -10.0 });
        assert_eq!(list[1].clients, vec![3]);
        assert!(AdversarySpec::parse_list("").unwrap().is_empty());
    }

    #[test]
    fn adversary_parse_rejects() {
        for bad in [
            "",
            "poison",
            "poison:-10",          // missing ids
            "poison:inf:1",        // non-finite scale
            "poison:nan:1",
            "poison:x:1",
            "poison:2:",           // empty id list
            "equivocate",
            "equivocate:",
            "equivocate:1:2",      // extra field
            "stale-replay:Cx",     // bad id
            "forge-suspicion:1-2", // not comma-separated
            "meteor:1",
        ] {
            assert!(AdversarySpec::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn adversary_fits_and_compile() {
        let spec = AdversarySpec::parse("poison:-1:2,5").unwrap();
        assert!(spec.fits(6));
        assert!(!spec.fits(5));

        let roles =
            compile_adversaries(&AdversarySpec::parse_list("poison:-10:1;equivocate:3").unwrap(), 5)
                .unwrap();
        assert_eq!(roles.len(), 5);
        assert_eq!(roles[1], Some(AdversaryKind::Poison { scale: -10.0 }));
        assert_eq!(roles[3], Some(AdversaryKind::Equivocate));
        assert!(roles[0].is_none() && roles[2].is_none() && roles[4].is_none());

        // out-of-range id
        assert!(compile_adversaries(&AdversarySpec::parse_list("equivocate:9").unwrap(), 5).is_err());
        // double role assignment
        assert!(compile_adversaries(
            &AdversarySpec::parse_list("poison:2:1;stale-replay:1").unwrap(),
            5
        )
        .is_err());
    }
}
