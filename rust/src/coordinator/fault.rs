//! Crash schedules + fault injection for the three fault experiments.
//!
//! A crashed client goes *silent* (benign crash model §3.1): its thread
//! stops broadcasting and receiving; it never sends wrong data.  Schedules
//! reproduce the paper's setups:
//!
//! * **Experiment 1** (variable crash): `k` of `n` clients crash, staggered
//!   through the run.
//! * **Experiment 2** (proportional): ⌊n/3⌋ clients "fail during the system
//!   execution at regular intervals" around the middle of the run.
//! * **Experiment 3** (maximum fault): n−1 crash, one survivor.

use std::time::Duration;

use crate::util::Rng;

/// When (if ever) a client is scheduled to crash.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CrashPoint {
    Never,
    /// Crash at the start of the given local round.
    AtRound(u32),
    /// Crash once this much clock time (wall or virtual) has elapsed since
    /// client start.
    AtElapsed(Duration),
}

/// Per-client fault plan: a benign crash point, optionally *transient*
/// (§3.1: "supports temporary and intermittent failures, allowing clients
/// to rejoin after transient faults") — with `rejoin_after` set, the
/// client goes silent for that long and then resumes; peers mark it
/// crashed by timeout and revive it on its first post-outage message.
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultPlan {
    pub crash: Option<CrashPoint>,
    /// None = permanent crash; Some(d) = transient outage of length d.
    pub rejoin_after: Option<Duration>,
}

impl FaultPlan {
    pub fn none() -> Self {
        FaultPlan::default()
    }

    pub fn at_round(round: u32) -> Self {
        FaultPlan { crash: Some(CrashPoint::AtRound(round)), rejoin_after: None }
    }

    pub fn at_elapsed(d: Duration) -> Self {
        FaultPlan { crash: Some(CrashPoint::AtElapsed(d)), rejoin_after: None }
    }

    /// Transient outage: silent from `round` for `downtime`, then rejoin.
    pub fn transient(round: u32, downtime: Duration) -> Self {
        FaultPlan { crash: Some(CrashPoint::AtRound(round)), rejoin_after: Some(downtime) }
    }

    /// Checked at the top of every client round.  `elapsed` is time since
    /// client start on the client's [`crate::util::time::Clock`] — wall or
    /// virtual, so elapsed-triggered crashes stay meaningful (and
    /// deterministic) in simulated time.
    pub fn should_crash(&self, round: u32, elapsed: Duration) -> bool {
        match self.crash {
            None => false,
            Some(CrashPoint::Never) => false,
            Some(CrashPoint::AtRound(r)) => round >= r,
            Some(CrashPoint::AtElapsed(d)) => elapsed >= d,
        }
    }
}

/// Experiment 1 — crash `k` of `n` clients, staggered uniformly across
/// rounds `[min_round, max_round)`.  Which clients crash is seeded.
pub fn variable_crash_schedule(
    n: usize,
    k: usize,
    min_round: u32,
    max_round: u32,
    rng: &mut Rng,
) -> Vec<FaultPlan> {
    assert!(k <= n, "cannot crash {k} of {n}");
    let mut ids: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut ids);
    let mut plans = vec![FaultPlan::none(); n];
    let span = max_round.saturating_sub(min_round).max(1);
    for (j, &id) in ids.iter().take(k).enumerate() {
        // evenly staggered crash rounds so faults arrive throughout the run
        let round = min_round + (j as u32 * span) / k.max(1) as u32;
        plans[id] = FaultPlan::at_round(round.max(1)); // never before round 1
    }
    plans
}

/// Experiment 2 — ⌊n/3⌋ crashes at regular intervals mid-run.
pub fn proportional_schedule(n: usize, max_rounds: u32, rng: &mut Rng) -> Vec<FaultPlan> {
    let k = n / 3;
    // "sometime in the middle of the system execution"
    let lo = max_rounds / 3;
    let hi = (2 * max_rounds) / 3;
    variable_crash_schedule(n, k, lo.max(1), hi.max(2), rng)
}

/// Experiment 3 — n−1 crash staggered through the run; `survivor` stays.
pub fn max_fault_schedule(n: usize, survivor: usize, max_rounds: u32) -> Vec<FaultPlan> {
    assert!(survivor < n);
    let mut plans = Vec::with_capacity(n);
    let k = (n - 1).max(1) as u32;
    let span = max_rounds.max(2) / 2;
    let mut j = 0u32;
    for id in 0..n {
        if id == survivor {
            plans.push(FaultPlan::none());
        } else {
            // staggered in the first half so the survivor runs alone after
            let round = 1 + (j * span) / k;
            plans.push(FaultPlan::at_round(round));
            j += 1;
        }
    }
    plans
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_round_trigger() {
        let p = FaultPlan::at_round(5);
        assert!(!p.should_crash(4, Duration::ZERO));
        assert!(p.should_crash(5, Duration::ZERO));
        assert!(p.should_crash(9, Duration::ZERO));
        assert!(!FaultPlan::none().should_crash(100, Duration::ZERO));
    }

    #[test]
    fn plan_elapsed_trigger() {
        let p = FaultPlan::at_elapsed(Duration::from_millis(1));
        assert!(p.should_crash(0, Duration::from_millis(5)));
        let fresh = FaultPlan::at_elapsed(Duration::from_secs(3600));
        assert!(!fresh.should_crash(0, Duration::ZERO));
    }

    #[test]
    fn variable_schedule_counts() {
        let mut rng = Rng::new(1);
        for k in 0..=12 {
            let plans = variable_crash_schedule(12, k, 2, 20, &mut rng);
            let crashing = plans.iter().filter(|p| p.crash.is_some()).count();
            assert_eq!(crashing, k);
        }
    }

    #[test]
    fn variable_schedule_rounds_in_range() {
        let mut rng = Rng::new(2);
        let plans = variable_crash_schedule(12, 6, 5, 25, &mut rng);
        for p in plans.iter().filter(|p| p.crash.is_some()) {
            match p.crash.unwrap() {
                CrashPoint::AtRound(r) => assert!((5..25).contains(&r), "round {r}"),
                _ => panic!("wrong crash kind"),
            }
        }
    }

    #[test]
    fn proportional_schedule_is_third() {
        let mut rng = Rng::new(3);
        for n in [4, 6, 9, 12] {
            let plans = proportional_schedule(n, 30, &mut rng);
            assert_eq!(plans.iter().filter(|p| p.crash.is_some()).count(), n / 3);
        }
    }

    #[test]
    fn max_fault_spares_survivor() {
        let plans = max_fault_schedule(8, 3, 30);
        assert!(plans[3].crash.is_none());
        assert_eq!(plans.iter().filter(|p| p.crash.is_some()).count(), 7);
    }
}
