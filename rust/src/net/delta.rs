//! Sparse delta codec + anti-entropy link state for model exchange
//! (DESIGN.md §13).
//!
//! Under `--codec delta:K[,q16]` a model broadcast no longer ships the
//! full parameter vector to every neighbor every round.  Each *directed
//! link* keeps codec state on both ends:
//!
//! * the **sender** ([`DeltaTx`]) remembers, per neighbor, the receiver's
//!   reconstruction at the last round that neighbor *acknowledged* (the
//!   shadow), plus a short window of reconstructions it has sent but not
//!   yet seen acked;
//! * the **receiver** ([`DeltaRx`]) keeps a short window of reconstructed
//!   rounds, pins whichever round the sender is currently using as its
//!   delta base, and piggybacks an [`Ack`] — its per-link model version
//!   vector — on every message it sends back (the scuttlebutt-style
//!   anti-entropy exchange: each side always tells the other how much of
//!   its state it already holds, so nothing already-known is resent).
//!
//! A sparse body carries the top-K coordinates of `|params − shadow|`,
//! but the wire carries the **new parameter values** at those indices,
//! not differences: reconstruction is `shadow` with those coordinates
//! overwritten, which is bit-exact and makes residual accumulation
//! implicit — a coordinate not selected this round keeps its full
//! outstanding drift `|params[i] − shadow[i]|` and stays in contention
//! until it is transmitted, so dropped or deferred mass is never lost.
//! The sender records the exact reconstruction the receiver will compute
//! (for q16, the *dequantized* values), so shadow and reconstruction
//! agree bit-for-bit on both ends without any second channel.
//!
//! When no shared base exists — boot, a rejoin after churn, a cut heal,
//! or a receiver NACK (`need_full`) — the sender falls back to a full
//! snapshot, which always decodes.  All state advances in sender/receiver
//! program order per link, so the executor conformance matrix
//! (`tests/conformance.rs`) holds byte-for-byte under `delta` exactly as
//! it does under `dense`.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::util::codec::{Reader, SliceWriter};
use crate::util::pool;

use super::message::{ClientId, Msg};

/// How many recent reconstructions each link end retains beyond the
/// pinned delta base.  Acks normally lag one round, so a handful is
/// plenty; a deeper loss streak falls back to a full snapshot via
/// `need_full` instead of growing memory.
const HISTORY: usize = 8;

// ---------------------------------------------------------------------------
// CodecSpec — the `--codec` knob
// ---------------------------------------------------------------------------

/// Wire codec for model broadcasts (the `--codec` knob).
///
/// ```
/// use dfl::net::CodecSpec;
/// assert_eq!(CodecSpec::parse("dense").unwrap(), CodecSpec::Dense);
/// assert_eq!(
///     CodecSpec::parse("delta:64").unwrap(),
///     CodecSpec::Delta { k: 64, q16: false }
/// );
/// assert_eq!(
///     CodecSpec::parse("delta:32,q16").unwrap(),
///     CodecSpec::Delta { k: 32, q16: true }
/// );
/// assert!(CodecSpec::parse("delta:0").is_err());
/// assert_eq!(CodecSpec::parse("delta:64,q16").unwrap().name(), "delta:64,q16");
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum CodecSpec {
    /// Full model every message — the paper's wire format, byte-identical
    /// per seed to every release before the codec existed.
    #[default]
    Dense,
    /// Sparse top-`k` delta against the per-link acknowledged base, with
    /// optional u16 quantization of the transmitted values.
    Delta {
        /// Coordinates transmitted per sparse message.
        k: usize,
        /// Quantize transmitted values to u16 against a per-message
        /// affine range (lossy; halves the payload of the value block).
        q16: bool,
    },
}

impl CodecSpec {
    pub fn parse(s: &str) -> Result<CodecSpec> {
        let s = s.trim();
        if s.is_empty() || s == "dense" {
            return Ok(CodecSpec::Dense);
        }
        if let Some(rest) = s.strip_prefix("delta:") {
            let (k_str, q16) = match rest.strip_suffix(",q16") {
                Some(k_str) => (k_str, true),
                None => (rest, false),
            };
            let k: usize = k_str
                .parse()
                .map_err(|_| anyhow::anyhow!("bad codec k in {s:?}; want delta:K[,q16]"))?;
            if k == 0 {
                bail!("codec {s:?}: k must be >= 1");
            }
            return Ok(CodecSpec::Delta { k, q16 });
        }
        bail!("unknown codec {s:?}; want dense | delta:K[,q16]")
    }

    pub fn name(&self) -> String {
        match self {
            CodecSpec::Dense => "dense".into(),
            CodecSpec::Delta { k, q16: false } => format!("delta:{k}"),
            CodecSpec::Delta { k, q16: true } => format!("delta:{k},q16"),
        }
    }

    pub fn is_delta(&self) -> bool {
        matches!(self, CodecSpec::Delta { .. })
    }
}

// ---------------------------------------------------------------------------
// Wire types
// ---------------------------------------------------------------------------

/// Per-link anti-entropy acknowledgment, piggybacked on every delta-mode
/// message: "of *your* model, the highest round I have reconstructed is
/// `round`" — a one-entry version vector for the reverse direction of the
/// link.  `need_full` is the NACK: the receiver lost the sender's delta
/// base and wants a full snapshot.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Ack {
    pub round: u32,
    /// False until the first successful reconstruction (and again after a
    /// churn/cut reset) — tells the peer "assume no shared base".
    pub have: bool,
    pub need_full: bool,
}

impl Ack {
    pub const NONE: Ack = Ack { round: 0, have: false, need_full: false };

    const WIRE: usize = 4 + 1 + 1;

    fn encode_into(&self, w: &mut SliceWriter) {
        w.u32(self.round);
        w.bool(self.have);
        w.bool(self.need_full);
    }

    fn decode(r: &mut Reader) -> Result<Ack> {
        Ok(Ack { round: r.u32()?, have: r.bool()?, need_full: r.bool()? })
    }
}

/// Transmitted values of a sparse body.
#[derive(Clone, Debug, PartialEq)]
pub enum SparseVals {
    /// Raw f32 bits — reconstruction is exact at the selected indices.
    F32(Vec<f32>),
    /// u16 quantization against a per-message affine range: value `i`
    /// decodes as `lo + scale * (q[i] / 65535)`.  The sender applies the
    /// *dequantized* values to its own shadow, so both ends still agree
    /// bit-for-bit.
    Q16 { lo: f32, scale: f32, q: Vec<u16> },
}

/// Body of a delta-mode model message.
#[derive(Clone, Debug, PartialEq)]
pub enum DeltaBody {
    /// Complete parameter vector — the no-shared-base fallback (boot,
    /// rejoin, cut heal, NACK) and the k ≥ dim degenerate case.
    Full(Vec<f32>),
    /// Top-K coordinates against the reconstruction the receiver holds
    /// for `base_round`; `idx` is strictly ascending, values parallel.
    Sparse { base_round: u32, dim: u32, idx: Vec<u32>, vals: SparseVals },
}

const BODY_FULL: u8 = 0;
const BODY_SPARSE: u8 = 1;
const VALS_F32: u8 = 0;
const VALS_Q16: u8 = 1;

/// Indices ride as u16 when the model dimension allows it.
fn narrow_idx(dim: u32) -> bool {
    dim <= u16::MAX as u32
}

impl DeltaBody {
    /// Model dimension this body reconstructs to.
    pub fn dim(&self) -> usize {
        match self {
            DeltaBody::Full(p) => p.len(),
            DeltaBody::Sparse { dim, .. } => *dim as usize,
        }
    }

    fn wire_len(&self) -> usize {
        match self {
            DeltaBody::Full(p) => 1 + 4 + p.len() * 4,
            DeltaBody::Sparse { dim, idx, vals, .. } => {
                let idx_w = if narrow_idx(*dim) { 2 } else { 4 };
                let vals_w = match vals {
                    SparseVals::F32(v) => v.len() * 4,
                    SparseVals::Q16 { q, .. } => 4 + 4 + q.len() * 2,
                };
                1 + 4 + 4 + 4 + idx.len() * idx_w + 1 + vals_w
            }
        }
    }

    fn encode_into(&self, w: &mut SliceWriter) {
        match self {
            DeltaBody::Full(p) => {
                w.u8(BODY_FULL);
                w.f32_slice(p);
            }
            DeltaBody::Sparse { base_round, dim, idx, vals } => {
                w.u8(BODY_SPARSE);
                w.u32(*base_round);
                w.u32(*dim);
                w.u32(idx.len() as u32);
                if narrow_idx(*dim) {
                    for &i in idx {
                        w.u16(i as u16);
                    }
                } else {
                    for &i in idx {
                        w.u32(i);
                    }
                }
                match vals {
                    SparseVals::F32(v) => {
                        w.u8(VALS_F32);
                        for &x in v {
                            w.f32(x);
                        }
                    }
                    SparseVals::Q16 { lo, scale, q } => {
                        w.u8(VALS_Q16);
                        w.f32(*lo);
                        w.f32(*scale);
                        for &x in q {
                            w.u16(x);
                        }
                    }
                }
            }
        }
    }

    fn decode(r: &mut Reader) -> Result<DeltaBody> {
        match r.u8()? {
            BODY_FULL => Ok(DeltaBody::Full(r.f32_vec()?)),
            BODY_SPARSE => {
                let base_round = r.u32()?;
                let dim = r.u32()?;
                let count = r.u32()? as usize;
                // count is attacker-controlled: bound it by what the body
                // can legitimately carry before sizing any allocation.
                if count > dim as usize {
                    bail!("sparse delta claims {count} coords over dim {dim}");
                }
                let idx_bytes = count * if narrow_idx(dim) { 2 } else { 4 };
                if idx_bytes > r.remaining() {
                    bail!("sparse delta index block truncated");
                }
                let mut idx = Vec::with_capacity(count);
                if narrow_idx(dim) {
                    for _ in 0..count {
                        idx.push(r.u16()? as u32);
                    }
                } else {
                    for _ in 0..count {
                        idx.push(r.u32()?);
                    }
                }
                // Strictly ascending in-range indices: rejects duplicates,
                // out-of-bounds writes, and non-canonical encodings.
                for w in idx.windows(2) {
                    if w[1] <= w[0] {
                        bail!("sparse delta indices not strictly ascending");
                    }
                }
                if let Some(&last) = idx.last() {
                    if last >= dim {
                        bail!("sparse delta index {last} out of range (dim {dim})");
                    }
                }
                let vals = match r.u8()? {
                    VALS_F32 => {
                        let mut v = Vec::with_capacity(count);
                        for _ in 0..count {
                            v.push(r.f32()?);
                        }
                        SparseVals::F32(v)
                    }
                    VALS_Q16 => {
                        let lo = r.f32()?;
                        let scale = r.f32()?;
                        let mut q = Vec::with_capacity(count);
                        for _ in 0..count {
                            q.push(r.u16()?);
                        }
                        SparseVals::Q16 { lo, scale, q }
                    }
                    t => bail!("unknown sparse value kind {t}"),
                };
                Ok(DeltaBody::Sparse { base_round, dim, idx, vals })
            }
            t => bail!("unknown delta body kind {t}"),
        }
    }
}

/// A delta-mode model broadcast: the `Msg::Update` fields plus the
/// anti-entropy piggyback and the (full or sparse) body.
#[derive(Clone, Debug, PartialEq)]
pub struct DeltaMsg {
    pub sender: ClientId,
    pub round: u32,
    pub terminate: bool,
    pub weight: f32,
    /// Reverse-direction version vector for this link (receiver→sender
    /// model state), advanced in program order.
    pub ack: Ack,
    pub body: DeltaBody,
}

impl DeltaMsg {
    pub(crate) fn wire_len(&self) -> usize {
        4 + 4 + 1 + 4 + Ack::WIRE + self.body.wire_len()
    }

    pub(crate) fn encode_into(&self, w: &mut SliceWriter) {
        w.u32(self.sender);
        w.u32(self.round);
        w.bool(self.terminate);
        w.f32(self.weight);
        self.ack.encode_into(w);
        self.body.encode_into(w);
    }

    pub(crate) fn decode(r: &mut Reader) -> Result<DeltaMsg> {
        let sender = r.u32()?;
        let round = r.u32()?;
        let terminate = r.bool()?;
        let weight = r.f32()?;
        // Same trust boundary as Msg::Update: an unusable aggregation
        // weight is rejected before any payload work.
        if !weight.is_finite() || weight <= 0.0 {
            bail!("delta update from client {sender} carries invalid aggregation weight {weight}");
        }
        let ack = Ack::decode(r)?;
        let body = DeltaBody::decode(r)?;
        Ok(DeltaMsg { sender, round, terminate, weight, ack, body })
    }
}

/// Compact Client-Responsive Termination flag relay (delta mode only):
/// replaces the dense path's verbatim full-model forward with ~20 bytes.
/// Carries whose CCC trigger the flag descends from, the origin's round,
/// and the link's anti-entropy piggyback.
#[derive(Clone, Debug, PartialEq)]
pub struct FlagMsg {
    /// The relaying peer (the message's author).
    pub sender: ClientId,
    /// Whose Client-Confident Convergence trigger this flag descends from.
    pub origin: ClientId,
    /// The origin's round when it flagged.
    pub round: u32,
    pub ack: Ack,
}

impl FlagMsg {
    pub(crate) fn wire_len(&self) -> usize {
        4 + 4 + 4 + Ack::WIRE
    }

    pub(crate) fn encode_into(&self, w: &mut SliceWriter) {
        w.u32(self.sender);
        w.u32(self.origin);
        w.u32(self.round);
        self.ack.encode_into(w);
    }

    pub(crate) fn decode(r: &mut Reader) -> Result<FlagMsg> {
        Ok(FlagMsg {
            sender: r.u32()?,
            origin: r.u32()?,
            round: r.u32()?,
            ack: Ack::decode(r)?,
        })
    }
}

/// Wire size of a dense `Msg::Update` for a model of `dim` parameters —
/// the baseline the hub's `bytes_saved` counter measures codec wins
/// against.  Kept in lockstep with the `Msg::Update` layout by a test.
pub fn dense_wire_size(dim: usize) -> usize {
    // tag + sender + round + terminate + weight + len prefix + payload
    1 + 4 + 4 + 1 + 4 + 4 + dim * 4
}

/// Codec accounting for one encoded message, used by the hub traffic
/// counters: `Some((bytes_saved, was_full_snapshot))` for delta-mode
/// messages, `None` for dense traffic.  Flag relays save the cost of the
/// full-model forward they replace, but the model dimension is not on
/// their wire, so they count conservatively as a hit with zero savings.
pub fn codec_accounting(msg: &Msg, wire_len: usize) -> Option<(u64, bool)> {
    match msg {
        Msg::Delta(dm) => {
            let dense = dense_wire_size(dm.body.dim()) as u64;
            let full = matches!(dm.body, DeltaBody::Full(_));
            Some((dense.saturating_sub(wire_len as u64), full))
        }
        Msg::Flag(_) => Some((0, false)),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Quantization
// ---------------------------------------------------------------------------

fn dequant(lo: f32, scale: f32, q: u16) -> f32 {
    lo + scale * (q as f32 / u16::MAX as f32)
}

fn quant(lo: f32, scale: f32, v: f32) -> u16 {
    if scale <= 0.0 {
        return 0;
    }
    let t = (v - lo) / scale * u16::MAX as f32;
    if t.is_nan() || t < 0.0 {
        return 0;
    }
    t.round().min(u16::MAX as f32) as u16
}

// ---------------------------------------------------------------------------
// Per-link sender state
// ---------------------------------------------------------------------------

/// Sender-side codec state for one directed link (`me → peer`).
///
/// The invariant everything rests on (DESIGN.md §13): `acked` is always a
/// `(round, reconstruction)` pair the *receiver provably holds* — it is
/// only installed when the receiver's piggybacked [`Ack`] names a round
/// this sender recorded when it encoded that round.  Sparse bodies are
/// deltas against `acked` exclusively, never against unacked sends, so an
/// arbitrary run of message drops can never desynchronize the pair: a
/// drop merely keeps the base (and the untransmitted residual) where it
/// was.
#[derive(Clone, Debug, Default)]
pub struct DeltaTx {
    /// The receiver's reconstruction at the last acked round.
    acked: Option<(u32, Vec<f32>)>,
    /// Reconstructions sent but not yet acked, oldest first (bounded).
    sent: VecDeque<(u32, Vec<f32>)>,
    /// Receiver NACKed (or we have proof it lost state): next message is
    /// a full snapshot.
    need_full: bool,
}

impl DeltaTx {
    pub fn new() -> Self {
        DeltaTx::default()
    }

    /// Encode this round's model for the peer: a sparse top-`k` delta
    /// when a shared base exists, a full snapshot otherwise.  Records the
    /// receiver's exact reconstruction so a future ack can promote it to
    /// the new base.
    pub fn encode(&mut self, k: usize, q16: bool, round: u32, params: &[f32]) -> DeltaBody {
        let body = self.encode_inner(k, q16, params);
        // Shadows live in pooled buffers: encode checks them out, eviction
        // and ack promotion hand them back (DESIGN.md §14).
        let recon = match &body {
            DeltaBody::Full(p) => pool::copy_of(p),
            DeltaBody::Sparse { idx, vals, .. } => {
                // dfl-lint: allow(no-panic-hot-path) — encode_inner only returns Sparse when self.acked is Some; the branch cannot be reached base-less
                let (_, base) = self.acked.as_ref().expect("sparse requires a base");
                let mut recon = pool::copy_of(base);
                apply_sparse(&mut recon, idx, vals);
                recon
            }
        };
        if matches!(body, DeltaBody::Full(_)) {
            self.need_full = false;
        }
        self.sent.push_back((round, recon));
        while self.sent.len() > HISTORY {
            if let Some((_, v)) = self.sent.pop_front() {
                pool::recycle_f32(v);
            }
        }
        body
    }

    fn encode_inner(&self, k: usize, q16: bool, params: &[f32]) -> DeltaBody {
        // Full snapshots ride in pooled buffers; the broadcast path recycles
        // them after serialization.
        let (base_round, base) = match &self.acked {
            Some(b) if !self.need_full && b.1.len() == params.len() => (b.0, &b.1),
            _ => return DeltaBody::Full(pool::copy_of(params)),
        };
        if k >= params.len() {
            // A "sparse" body covering every coordinate is strictly larger
            // than the full snapshot.
            return DeltaBody::Full(pool::copy_of(params));
        }
        let idx = top_k_indices(params, base, k);
        if q16 {
            match quantize(params, &idx) {
                Some(vals) => DeltaBody::Sparse {
                    base_round,
                    dim: params.len() as u32,
                    idx,
                    vals,
                },
                // Non-finite values don't survive affine quantization;
                // the full snapshot carries their exact bits instead.
                None => DeltaBody::Full(pool::copy_of(params)),
            }
        } else {
            let mut v = pool::take_f32(idx.len());
            v.extend(idx.iter().map(|&i| params[i as usize]));
            DeltaBody::Sparse { base_round, dim: params.len() as u32, idx, vals: SparseVals::F32(v) }
        }
    }

    /// Apply the peer's piggybacked ack: promote the acked base and/or
    /// schedule a full snapshot.
    pub fn on_ack(&mut self, ack: &Ack) {
        if ack.need_full {
            self.need_full = true;
        }
        if !ack.have {
            // The receiver reports no reconstructed state at all — it was
            // reset (churn rejoin, cut heal).  Any base we hold is for a
            // link incarnation that no longer exists.
            if let Some((_, v)) = self.acked.take() {
                pool::recycle_f32(v);
            }
            return;
        }
        if let Some((r, _)) = &self.acked {
            if *r >= ack.round {
                return;
            }
        }
        while let Some((r, _)) = self.sent.front() {
            if *r < ack.round {
                if let Some((_, v)) = self.sent.pop_front() {
                    pool::recycle_f32(v);
                }
            } else if *r == ack.round {
                let old = std::mem::replace(&mut self.acked, self.sent.pop_front());
                if let Some((_, v)) = old {
                    pool::recycle_f32(v);
                }
                break;
            } else {
                // The acked round predates our retained window (it was
                // pruned); keep the old base — still valid, just stale.
                break;
            }
        }
    }

    /// Drop all link state (the churn/cut invalidation rule): the next
    /// message will be a full snapshot.
    pub fn reset(&mut self) {
        *self = DeltaTx::default();
    }

    #[cfg(test)]
    fn last_sent(&self) -> Option<&(u32, Vec<f32>)> {
        self.sent.back()
    }
}

fn apply_sparse(recon: &mut [f32], idx: &[u32], vals: &SparseVals) {
    match vals {
        SparseVals::F32(v) => {
            for (&i, &x) in idx.iter().zip(v) {
                recon[i as usize] = x;
            }
        }
        SparseVals::Q16 { lo, scale, q } => {
            for (&i, &x) in idx.iter().zip(q) {
                recon[i as usize] = dequant(*lo, *scale, x);
            }
        }
    }
}

/// Indices of the `k` largest `|params − base|`, ascending.  The ordering
/// key maps NaN drift to +∞ so poisoned coordinates are transmitted (and
/// thereby resolved) rather than silently pinned at the base value; ties
/// break on the lower index, making the selected *set* a deterministic
/// function of the inputs.
fn top_k_indices(params: &[f32], base: &[f32], k: usize) -> Vec<u32> {
    debug_assert!(k < params.len());
    let key = |i: u32| {
        let d = (params[i as usize] - base[i as usize]).abs();
        if d.is_nan() {
            f32::INFINITY
        } else {
            d
        }
    };
    let mut idx: Vec<u32> = (0..params.len() as u32).collect();
    idx.select_nth_unstable_by(k, |&a, &b| key(b).total_cmp(&key(a)).then(a.cmp(&b)));
    idx.truncate(k);
    idx.sort_unstable();
    idx
}

/// Quantize the selected values; `None` if any is non-finite (the caller
/// falls back to a full snapshot, which preserves exact bits).
fn quantize(params: &[f32], idx: &[u32]) -> Option<SparseVals> {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &i in idx {
        let v = params[i as usize];
        if !v.is_finite() {
            return None;
        }
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let scale = hi - lo;
    if !scale.is_finite() {
        return None;
    }
    let q = idx.iter().map(|&i| quant(lo, scale, params[i as usize])).collect();
    Some(SparseVals::Q16 { lo, scale, q })
}

// ---------------------------------------------------------------------------
// Per-link receiver state
// ---------------------------------------------------------------------------

/// Receiver-side codec state for one directed link (`peer → me`).
#[derive(Clone, Debug, Default)]
pub struct DeltaRx {
    /// Recent reconstructions by round, oldest first (bounded, except the
    /// pinned base below never evicts).
    entries: VecDeque<(u32, Vec<f32>)>,
    /// The base round the sender most recently delta'd against — pinned
    /// against eviction for as long as the sender keeps using it.
    pinned: Option<u32>,
    /// Highest round reconstructed — the ack we piggyback.
    highest: Option<u32>,
    /// Set when a sparse body referenced a base we no longer hold; the
    /// piggybacked NACK stands until a full snapshot arrives.
    need_full: bool,
}

impl DeltaRx {
    pub fn new() -> Self {
        DeltaRx::default()
    }

    /// Reconstruct a delta-mode body.  `None` means the body was sparse
    /// against a base this end does not hold (deep loss streak or a reset
    /// link) — the caller drops the update and the piggybacked NACK
    /// requests a full snapshot.
    pub fn decode(&mut self, round: u32, body: &DeltaBody) -> Option<Vec<f32>> {
        // Reconstructions live in pooled buffers: the returned one is the
        // caller's to recycle (the update stash does), the retained copy is
        // recycled on eviction or retransmit replacement.
        let recon = match body {
            DeltaBody::Full(p) => {
                self.need_full = false;
                pool::copy_of(p)
            }
            DeltaBody::Sparse { base_round, dim, idx, vals } => {
                let base = self
                    .entries
                    .iter()
                    .find(|(r, p)| r == base_round && p.len() == *dim as usize);
                let Some((_, base)) = base else {
                    self.need_full = true;
                    return None;
                };
                let mut recon = pool::copy_of(base);
                apply_sparse(&mut recon, idx, vals);
                self.pinned = Some(*base_round);
                recon
            }
        };
        if let Some(pos) = self.entries.iter().position(|(r, _)| *r == round) {
            if let Some((_, old)) = self.entries.remove(pos) {
                pool::recycle_f32(old);
            }
        }
        self.entries.push_back((round, pool::copy_of(&recon)));
        self.highest = Some(self.highest.map_or(round, |h| h.max(round)));
        // Evict oldest unpinned entries beyond the retention window.
        while self.entries.len() > HISTORY {
            let Some(pos) = self.entries.iter().position(|(r, _)| Some(*r) != self.pinned)
            else {
                break;
            };
            if pos + 1 == self.entries.len() {
                break; // only the newest is unpinned; keep it
            }
            if let Some((_, v)) = self.entries.remove(pos) {
                pool::recycle_f32(v);
            }
        }
        Some(recon)
    }

    /// The anti-entropy piggyback for the reverse direction of this link.
    pub fn ack(&self) -> Ack {
        Ack {
            round: self.highest.unwrap_or(0),
            have: self.highest.is_some(),
            need_full: self.need_full,
        }
    }

    /// Drop all link state (the churn/cut invalidation rule): the next
    /// ack reports `have = false`, forcing the peer back to a snapshot.
    pub fn reset(&mut self) {
        *self = DeltaRx::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::forall;
    use crate::util::Rng;

    fn spec_k(k: usize) -> CodecSpec {
        CodecSpec::Delta { k, q16: false }
    }

    #[test]
    fn codec_spec_parses_and_round_trips() {
        for s in ["dense", "delta:1", "delta:64", "delta:64,q16", "delta:4096,q16"] {
            let spec = CodecSpec::parse(s).unwrap();
            assert_eq!(spec.name(), s);
            assert_eq!(CodecSpec::parse(&spec.name()).unwrap(), spec);
        }
        assert_eq!(CodecSpec::parse("").unwrap(), CodecSpec::Dense);
        for bad in ["delta", "delta:", "delta:0", "delta:x", "delta:8,q8", "sparse:4"] {
            assert!(CodecSpec::parse(bad).is_err(), "{bad} must not parse");
        }
        assert!(spec_k(4).is_delta());
        assert!(!CodecSpec::Dense.is_delta());
        assert_eq!(CodecSpec::default(), CodecSpec::Dense);
    }

    /// One directed link, lossless transport: after every exchange the
    /// receiver's reconstruction matches the sender's recorded shadow
    /// bit-for-bit, and acks promote the base.
    #[test]
    fn tx_rx_agree_over_a_clean_link() {
        let mut tx = DeltaTx::new();
        let mut rx = DeltaRx::new();
        let mut rng = Rng::new(7);
        let dim = 40;
        let mut params: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
        for round in 1..=20u32 {
            for p in params.iter_mut() {
                *p += 0.1 * rng.normal();
            }
            let body = tx.encode(5, false, round, &params);
            if round == 1 {
                assert!(matches!(body, DeltaBody::Full(_)), "boot round must snapshot");
            } else {
                assert!(matches!(body, DeltaBody::Sparse { .. }), "round {round}");
            }
            let recon = rx.decode(round, &body).expect("clean link must decode");
            assert_eq!(&recon, &tx.last_sent().unwrap().1, "round {round}");
            tx.on_ack(&rx.ack());
        }
        // With k=5 over dim=40 and a moving target the reconstruction is
        // an approximation, but the acked base tracks the latest round.
        assert_eq!(tx.acked.as_ref().unwrap().0, 20);
    }

    /// k >= dim collapses to full snapshots (a sparse body would be
    /// strictly bigger), and those decode exactly.
    #[test]
    fn oversized_k_degenerates_to_full() {
        let mut tx = DeltaTx::new();
        let mut rx = DeltaRx::new();
        let params = vec![1.0f32, -2.0, 3.0];
        for round in 1..=3 {
            let body = tx.encode(10, false, round, &params);
            assert!(matches!(body, DeltaBody::Full(_)));
            assert_eq!(rx.decode(round, &body).unwrap(), params);
            tx.on_ack(&rx.ack());
        }
    }

    /// Random sparse masks: whatever subset of coordinates moves, the
    /// receiver's reconstruction equals the sender's shadow bit-for-bit,
    /// and every moved coordinate eventually lands once traffic pauses
    /// (residual accumulation: nothing is ever lost, only deferred).
    #[test]
    fn sparse_mask_property() {
        forall(
            0xDE17A,
            40,
            |r| {
                let dim = 8 + r.below(64);
                let k = 1 + r.below(8);
                let rounds = 4 + r.below(10);
                let seed = r.next_u32() as u64;
                (dim, k, rounds, seed)
            },
            |&(dim, k, rounds, seed)| {
                let mut rng = Rng::new(seed);
                let mut tx = DeltaTx::new();
                let mut rx = DeltaRx::new();
                let mut params: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
                for round in 1..=rounds as u32 {
                    // a random sparse subset of coordinates moves
                    for p in params.iter_mut() {
                        if rng.below(4) == 0 {
                            *p += rng.normal();
                        }
                    }
                    let body = tx.encode(k, false, round, &params);
                    let recon = rx
                        .decode(round, &body)
                        .ok_or("clean link must always decode")?;
                    if recon != tx.last_sent().unwrap().1 {
                        return Err(format!("shadow diverged at round {round}"));
                    }
                    tx.on_ack(&rx.ack());
                }
                // Freeze the model; within ceil(dim/k)+1 more rounds every
                // outstanding residual must drain to exactness.
                let settle = dim.div_ceil(k) as u32 + 1;
                let mut last = Vec::new();
                for round in 0..settle {
                    let body = tx.encode(k, false, rounds as u32 + 1 + round, &params);
                    last = rx.decode(rounds as u32 + 1 + round, &body).unwrap();
                    tx.on_ack(&rx.ack());
                }
                if last != params {
                    return Err("residuals failed to drain to exactness".into());
                }
                Ok(())
            },
        );
    }

    /// NaN and ±inf payloads survive the codec: full snapshots carry the
    /// exact bits, and a NaN drift sorts as infinite so the poisoned
    /// coordinate is transmitted (raw f32) rather than pinned.
    #[test]
    fn non_finite_payloads_roundtrip() {
        let mut tx = DeltaTx::new();
        let mut rx = DeltaRx::new();
        let mut params = vec![1.0f32; 16];
        let body = tx.encode(4, false, 1, &params);
        rx.decode(1, &body).unwrap();
        tx.on_ack(&rx.ack());

        params[3] = f32::NAN;
        params[7] = f32::INFINITY;
        params[11] = f32::NEG_INFINITY;
        let body = tx.encode(4, false, 2, &params);
        assert!(matches!(body, DeltaBody::Sparse { .. }));
        let recon = rx.decode(2, &body).unwrap();
        assert!(recon[3].is_nan());
        assert_eq!(recon[7], f32::INFINITY);
        assert_eq!(recon[11], f32::NEG_INFINITY);
        // bit-exact agreement with the sender's shadow, NaN included
        let shadow = &tx.last_sent().unwrap().1;
        assert_eq!(
            recon.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            shadow.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );

        // Under q16 the same payload cannot quantize: full-snapshot
        // fallback, exact bits preserved.
        let mut txq = DeltaTx::new();
        let mut rxq = DeltaRx::new();
        let clean = vec![0.5f32; 16];
        let body = txq.encode(4, true, 1, &clean);
        rxq.decode(1, &body).unwrap();
        txq.on_ack(&rxq.ack());
        let body = txq.encode(4, true, 2, &params);
        assert!(matches!(body, DeltaBody::Full(_)), "non-finite q16 must snapshot");
        let recon = rxq.decode(2, &body).unwrap();
        assert!(recon[3].is_nan());
        assert_eq!(recon[7], f32::INFINITY);
    }

    /// q16 error bound: each transmitted coordinate lands within one
    /// quantization step of the true value, and the sender's shadow holds
    /// the same dequantized value the receiver computed.
    #[test]
    fn q16_error_bound() {
        forall(
            0x9160,
            40,
            |r| {
                let dim = 8 + r.below(64);
                let seed = r.next_u32() as u64;
                (dim, seed)
            },
            |&(dim, seed)| {
                let mut rng = Rng::new(seed);
                let mut tx = DeltaTx::new();
                let mut rx = DeltaRx::new();
                let params: Vec<f32> = (0..dim).map(|_| rng.normal() * 3.0).collect();
                let body = tx.encode(4, true, 1, &params);
                rx.decode(1, &body).unwrap();
                tx.on_ack(&rx.ack());
                let moved: Vec<f32> =
                    params.iter().map(|p| p + rng.normal() * 0.5).collect();
                let body = tx.encode(4, true, 2, &moved);
                let DeltaBody::Sparse { ref idx, vals: SparseVals::Q16 { lo, scale, .. }, .. } =
                    body
                else {
                    return Err("expected a q16 sparse body".into());
                };
                let step = (scale as f64 / u16::MAX as f64).abs();
                let recon = rx.decode(2, &body).ok_or("decode failed")?;
                for &i in idx {
                    let err = (recon[i as usize] as f64 - moved[i as usize] as f64).abs();
                    if err > step + 1e-6 + (lo.abs() as f64 + scale.abs() as f64) * 1e-6 {
                        return Err(format!(
                            "coord {i}: err {err} exceeds quantization step {step}"
                        ));
                    }
                }
                if recon != tx.last_sent().unwrap().1 {
                    return Err("q16 shadow diverged from receiver".into());
                }
                Ok(())
            },
        );
    }

    /// Delta-chain reconstruction across a simulated drop: the dropped
    /// round's mass is not lost — because deltas are always taken against
    /// the *acked* base with fresh residual magnitudes, the next delivered
    /// message recovers it (or a NACK forces a snapshot).
    #[test]
    fn drop_chain_recovers_lost_mass() {
        let mut rng = Rng::new(99);
        let mut tx = DeltaTx::new();
        let mut rx = DeltaRx::new();
        let dim = 32;
        let mut params: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();

        // Round 1 delivered (snapshot), acked.
        let body = tx.encode(8, false, 1, &params);
        rx.decode(1, &body).unwrap();
        tx.on_ack(&rx.ack());

        // Round 2: a large spike on coordinate 5 — encoded, but DROPPED.
        params[5] += 100.0;
        let _lost = tx.encode(8, false, 2, &params);

        // Round 3: tiny drift elsewhere; delivered.  The spike's residual
        // against the acked base is still outstanding, so coordinate 5
        // must be selected again and the delivered message recovers it.
        for p in params.iter_mut() {
            *p += 0.001 * rng.normal();
        }
        let body = tx.encode(8, false, 3, &params);
        let DeltaBody::Sparse { ref idx, .. } = body else {
            panic!("expected sparse after an acked base");
        };
        assert!(idx.contains(&5), "dropped spike must stay in contention: {idx:?}");
        let recon = rx.decode(3, &body).unwrap();
        assert_eq!(recon[5], params[5], "lost mass recovered exactly");
        assert_eq!(&recon, &tx.last_sent().unwrap().1);
    }

    /// A receiver that lost the sender's base NACKs via the piggyback and
    /// the sender answers with a full snapshot (self-healing under deep
    /// loss or link reset).
    #[test]
    fn need_full_nack_heals_the_link() {
        let mut tx = DeltaTx::new();
        let mut rx = DeltaRx::new();
        let params = vec![1.0f32; 8];
        let body = tx.encode(2, false, 1, &params);
        rx.decode(1, &body).unwrap();
        tx.on_ack(&rx.ack());

        // The receiver is reset mid-stream (churn rejoin).
        rx.reset();
        let body = tx.encode(2, false, 2, &params);
        assert!(matches!(body, DeltaBody::Sparse { .. }));
        assert!(rx.decode(2, &body).is_none(), "no base -> undecodable");
        let ack = rx.ack();
        assert!(ack.need_full && !ack.have);
        tx.on_ack(&ack);
        assert!(tx.acked.is_none(), "have=false must drop the stale base");

        let body = tx.encode(2, false, 3, &params);
        assert!(matches!(body, DeltaBody::Full(_)), "NACK must force a snapshot");
        assert_eq!(rx.decode(3, &body).unwrap(), params);
        assert!(!rx.ack().need_full, "snapshot clears the NACK");
    }

    /// The receiver pins the sender's in-use base: even when acks stall
    /// for longer than the retention window, sparse bodies keep decoding.
    #[test]
    fn stalled_acks_keep_the_base_pinned() {
        let mut rng = Rng::new(3);
        let mut tx = DeltaTx::new();
        let mut rx = DeltaRx::new();
        let mut params: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let body = tx.encode(3, false, 1, &params);
        rx.decode(1, &body).unwrap();
        tx.on_ack(&rx.ack());
        // No acks delivered for 3x the history window: every message
        // deltas against round 1, which the receiver must keep pinned.
        for round in 2..=(3 * HISTORY as u32 + 2) {
            for p in params.iter_mut() {
                *p += 0.01 * rng.normal();
            }
            let body = tx.encode(3, false, round, &params);
            assert!(matches!(body, DeltaBody::Sparse { base_round: 1, .. }));
            let recon = rx.decode(round, &body).expect("pinned base must decode");
            assert_eq!(&recon, &tx.last_sent().unwrap().1, "round {round}");
        }
    }

    #[test]
    fn wire_roundtrip_property() {
        forall(
            0xD317A,
            60,
            |r| {
                let dim = 1 + r.below(300);
                let sparse = r.below(3) > 0;
                let body = if !sparse {
                    DeltaBody::Full((0..dim).map(|_| r.normal()).collect())
                } else {
                    let k = 1 + r.below(dim.min(16));
                    let mut idx: Vec<u32> = (0..dim as u32).collect();
                    // deterministic subset: keep every index with prob k/dim
                    idx.retain(|_| r.below(dim) < k);
                    if r.below(2) == 0 {
                        DeltaBody::Sparse {
                            base_round: r.next_u32() % 1000,
                            dim: dim as u32,
                            vals: SparseVals::F32(idx.iter().map(|_| r.normal()).collect()),
                            idx,
                        }
                    } else {
                        DeltaBody::Sparse {
                            base_round: r.next_u32() % 1000,
                            dim: dim as u32,
                            vals: SparseVals::Q16 {
                                lo: -1.0,
                                scale: 2.0,
                                q: idx.iter().map(|_| r.next_u32() as u16).collect(),
                            },
                            idx,
                        }
                    }
                };
                DeltaMsg {
                    sender: r.next_u32() % 64,
                    round: r.next_u32() % 10_000,
                    terminate: r.below(2) == 1,
                    weight: 0.1 + r.f32() * 10.0,
                    ack: Ack {
                        round: r.next_u32() % 10_000,
                        have: r.below(2) == 1,
                        need_full: r.below(4) == 0,
                    },
                    body,
                }
            },
            |dm| {
                let mut buf = vec![0u8; dm.wire_len()];
                let mut w = SliceWriter::new(&mut buf);
                dm.encode_into(&mut w);
                if w.written() != buf.len() {
                    return Err(format!(
                        "wire_len {} != written {}",
                        buf.len(),
                        w.written()
                    ));
                }
                let got = DeltaMsg::decode(&mut Reader::new(&buf)).map_err(|e| e.to_string())?;
                if &got == dm {
                    Ok(())
                } else {
                    Err("roundtrip mismatch".into())
                }
            },
        );
    }

    #[test]
    fn decode_rejects_malformed_sparse() {
        let good = DeltaMsg {
            sender: 1,
            round: 5,
            terminate: false,
            weight: 1.0,
            ack: Ack::NONE,
            body: DeltaBody::Sparse {
                base_round: 4,
                dim: 10,
                idx: vec![1, 3, 7],
                vals: SparseVals::F32(vec![0.5, -0.5, 2.0]),
            },
        };
        let encode = |dm: &DeltaMsg| {
            let mut buf = vec![0u8; dm.wire_len()];
            dm.encode_into(&mut SliceWriter::new(&mut buf));
            buf
        };
        assert!(DeltaMsg::decode(&mut Reader::new(&encode(&good))).is_ok());

        // out-of-range index
        let mut bad = good.clone();
        if let DeltaBody::Sparse { idx, .. } = &mut bad.body {
            idx[2] = 10;
        }
        assert!(DeltaMsg::decode(&mut Reader::new(&encode(&bad))).is_err());

        // non-ascending (duplicate) indices
        let mut bad = good.clone();
        if let DeltaBody::Sparse { idx, .. } = &mut bad.body {
            idx[1] = 1;
        }
        assert!(DeltaMsg::decode(&mut Reader::new(&encode(&bad))).is_err());

        // invalid aggregation weight — same trust boundary as Msg::Update
        for w in [f32::NAN, 0.0, -2.0] {
            let mut bad = good.clone();
            bad.weight = w;
            assert!(DeltaMsg::decode(&mut Reader::new(&encode(&bad))).is_err());
        }

        // count > dim must be rejected before any allocation
        let mut buf = Vec::new();
        {
            let mut tmp = vec![0u8; 64];
            let mut w = SliceWriter::new(&mut tmp);
            w.u8(BODY_SPARSE);
            w.u32(0); // base_round
            w.u32(4); // dim
            w.u32(u32::MAX); // claimed count
            let n = w.written();
            buf.extend_from_slice(&tmp[..n]);
        }
        assert!(DeltaBody::decode(&mut Reader::new(&buf)).is_err());
    }

    #[test]
    fn dense_wire_size_matches_update_encoding() {
        use crate::model::ParamVector;
        use crate::net::message::ModelUpdate;
        for dim in [0usize, 1, 330, 1056] {
            let msg = Msg::Update(ModelUpdate {
                sender: 3,
                round: 9,
                terminate: false,
                weight: 1.0,
                params: ParamVector(vec![0.5; dim]),
            });
            assert_eq!(msg.encode().len(), dense_wire_size(dim), "dim {dim}");
        }
    }

    #[test]
    fn codec_accounting_classifies_messages() {
        let full = Msg::Delta(DeltaMsg {
            sender: 0,
            round: 1,
            terminate: false,
            weight: 1.0,
            ack: Ack::NONE,
            body: DeltaBody::Full(vec![0.0; 100]),
        });
        let wire = full.encode();
        let (saved, was_full) = codec_accounting(&full, wire.len()).unwrap();
        assert!(was_full);
        assert_eq!(saved, 0, "a snapshot saves nothing over dense");

        let sparse = Msg::Delta(DeltaMsg {
            sender: 0,
            round: 2,
            terminate: false,
            weight: 1.0,
            ack: Ack::NONE,
            body: DeltaBody::Sparse {
                base_round: 1,
                dim: 100,
                idx: vec![4, 10],
                vals: SparseVals::F32(vec![1.0, 2.0]),
            },
        });
        let wire = sparse.encode();
        let (saved, was_full) = codec_accounting(&sparse, wire.len()).unwrap();
        assert!(!was_full);
        assert_eq!(saved as usize, dense_wire_size(100) - wire.len());
        assert!(saved > 300, "2 of 100 coords must save most of the payload");

        assert!(codec_accounting(&Msg::Hello { sender: 1 }, 5).is_none());
    }
}
