//! Sparse peer-overlay graphs — the topology layer (DESIGN.md §9).
//!
//! The paper's Phase-2 protocol broadcasts every update to every peer:
//! O(n²) messages per round, which is what bounds 10 000-client rounds in
//! both time and memory.  Production decentralized-FL systems replace the
//! full mesh with a sparse overlay: each client exchanges models only with
//! a small neighbor set, and global information (convergence, termination)
//! reaches the rest of the graph over multiple hops.  This module provides
//! that overlay as a seeded, deterministic graph shared by both in-proc
//! hubs:
//!
//! * [`TopologySpec`] — the CLI-facing description (`full`, `ring:K`,
//!   `k-regular:D`, `small-world:D:P`), a pure value carried by
//!   `SimConfig`.
//! * [`Topology`] — the built graph: one sorted neighbor list per client,
//!   undirected (neighborhoods are mutual, so liveness tracking and relays
//!   work in both directions) and connected by construction (every
//!   non-full preset keeps the offset-1 ring intact).
//!
//! Determinism contract: the adjacency is a pure function of
//! `(spec, n, seed)` — same inputs, same graph, independent of build
//! order or thread interleaving — and on `full` the neighbor list of
//! client `i` is exactly the ascending all-peers list the pre-topology
//! transports produced, so a full-overlay run is byte-identical to the
//! pre-refactor behaviour.
//!
//! Since the graph-fault subsystem (DESIGN.md §10) the built graph is no
//! longer necessarily immutable: the mutable-overlay API
//! ([`Topology::add_edge`] / [`Topology::remove_edge`] /
//! [`Topology::depart`] / [`Topology::regenerate`] / [`Topology::min_cut`])
//! lets [`super::overlay::Overlay`] apply a deterministic schedule of edge
//! cuts and churn.  Deployments without graph faults never touch it, so
//! the determinism contract above is unchanged for them.

use std::cmp::Reverse;
use std::collections::BTreeSet;

use anyhow::{bail, Result};

use super::message::ClientId;
use crate::util::Rng;

/// Salt separating the graph-construction RNG stream from every other
/// consumer of the deployment seed.
const TOPO_SALT: u64 = 0x7090_1060_0000;

/// Salt of the churn edge-regeneration streams ([`Topology::regenerate`]).
const REGEN_SALT: u64 = 0x4E6E_2070_0000;

/// Salt of the seeded min-cut search ([`Topology::min_cut`]).
const MINCUT_SALT: u64 = 0x3C07_C070_0000;

/// Salt of the shard-partition search ([`Topology::partition_shards`]).
const SHARD_SALT: u64 = 0x5D42_D070_0000;

/// Which overlay to build (the `--topology` flag).  `Full` reproduces the
/// paper's all-to-all dissemination exactly; the sparse presets trade
/// per-round message volume O(n²) → O(n·d) for multi-hop dissemination
/// latency.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TopologySpec {
    /// All-to-all (the paper's assumption; the default).
    Full,
    /// Circulant ring: client `i` connects to `i ± 1..=k` (mod n),
    /// degree `min(2k, n−1)`.
    Ring { k: usize },
    /// Random circulant: `⌊d/2⌋` seeded distinct ring offsets (offset 1
    /// forced for connectivity), degree ≈ `d` (exact for even `d`; odd
    /// `d` adds the antipodal chord when `n` is even, else rounds down).
    KRegular { d: usize },
    /// Watts–Strogatz small world: a `ring(d/2)` base whose long-range
    /// chords (offset ≥ 2) are each rewired to a random peer with
    /// probability `p`; offset-1 edges are never rewired, keeping the
    /// graph connected.
    SmallWorld { d: usize, p: f64 },
}

impl TopologySpec {
    /// The CLI spelling (`full`, `ring:2`, `k-regular:8`,
    /// `small-world:8:0.1`).
    pub fn name(self) -> String {
        match self {
            TopologySpec::Full => "full".into(),
            TopologySpec::Ring { k } => format!("ring:{k}"),
            TopologySpec::KRegular { d } => format!("k-regular:{d}"),
            TopologySpec::SmallWorld { d, p } => format!("small-world:{d}:{p}"),
        }
    }

    /// Parse a CLI spelling.
    ///
    /// ```
    /// use dfl::net::TopologySpec;
    ///
    /// assert_eq!(TopologySpec::parse("full").unwrap(), TopologySpec::Full);
    /// assert_eq!(TopologySpec::parse("ring:2").unwrap(), TopologySpec::Ring { k: 2 });
    /// assert_eq!(TopologySpec::parse("k-regular:8").unwrap(), TopologySpec::KRegular { d: 8 });
    /// assert!(TopologySpec::parse("torus:3").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<TopologySpec> {
        let mut parts = s.split(':');
        let kind = parts.next().unwrap_or("");
        let arg = |p: Option<&str>, what: &str| -> Result<usize> {
            p.and_then(|v| v.parse().ok())
                .ok_or_else(|| anyhow::anyhow!("topology {s:?}: missing/bad {what}"))
        };
        let spec = match kind {
            "full" => TopologySpec::Full,
            "ring" => TopologySpec::Ring { k: arg(parts.next(), "ring width k")? },
            "k-regular" | "kreg" => TopologySpec::KRegular { d: arg(parts.next(), "degree d")? },
            "small-world" | "sw" => {
                let d = arg(parts.next(), "degree d")?;
                let p: f64 = parts
                    .next()
                    .and_then(|v| v.parse().ok())
                    .ok_or_else(|| anyhow::anyhow!("topology {s:?}: missing/bad rewire p"))?;
                TopologySpec::SmallWorld { d, p }
            }
            _ => bail!(
                "unknown topology {s:?} (want full | ring:K | k-regular:D | small-world:D:P)"
            ),
        };
        if parts.next().is_some() {
            bail!("topology {s:?}: trailing arguments");
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Degree/probability sanity (n-independent; n-dependent clamping
    /// happens in [`TopologySpec::build`]).
    pub fn validate(self) -> Result<()> {
        match self {
            TopologySpec::Full => {}
            TopologySpec::Ring { k } => {
                if k == 0 {
                    bail!("ring topology needs k >= 1");
                }
            }
            TopologySpec::KRegular { d } => {
                if d < 2 {
                    bail!("k-regular topology needs degree d >= 2");
                }
            }
            TopologySpec::SmallWorld { d, p } => {
                if d < 2 {
                    bail!("small-world topology needs degree d >= 2");
                }
                if !(0.0..=1.0).contains(&p) {
                    bail!("small-world rewire probability must be in [0, 1], got {p}");
                }
            }
        }
        Ok(())
    }

    /// The simplest strictly-smaller spec along the degree axis, if any
    /// (the shrink dimension `sim::shrink::shrink_sim_config` walks
    /// before falling back to `Full`).
    pub fn shrink_degree(self) -> Option<TopologySpec> {
        match self {
            TopologySpec::Full => None,
            TopologySpec::Ring { k } if k > 1 => Some(TopologySpec::Ring { k: k / 2 }),
            TopologySpec::Ring { .. } => None,
            TopologySpec::KRegular { d } if d > 2 => {
                Some(TopologySpec::KRegular { d: (d / 2).max(2) })
            }
            TopologySpec::KRegular { .. } => None,
            TopologySpec::SmallWorld { d, p } if d > 2 => {
                Some(TopologySpec::SmallWorld { d: (d / 2).max(2), p })
            }
            TopologySpec::SmallWorld { .. } => None,
        }
    }

    /// Build the overlay for an `n`-client deployment.  Deterministic in
    /// `(self, n, seed)`.  Any spec whose requested degree reaches `n − 1`
    /// degenerates to the full mesh.
    pub fn build(self, n: usize, seed: u64) -> Result<Topology> {
        self.validate()?;
        let full = Topology { spec: self, n, adj: None };
        if n <= 2 {
            return Ok(full); // 0/1/2 clients: every overlay is the mesh
        }
        let adj = match self {
            TopologySpec::Full => return Ok(full),
            TopologySpec::Ring { k } => {
                if 2 * k >= n - 1 {
                    return Ok(full);
                }
                circulant(n, &(1..=k).collect::<Vec<_>>(), false)
            }
            TopologySpec::KRegular { d } => {
                let d = d.min(n - 1);
                if d >= n - 1 {
                    return Ok(full);
                }
                // Offsets 1..=(n−1)/2 each contribute degree 2; the forced
                // offset 1 keeps the ring (and therefore the graph)
                // connected, the rest are a seeded sample without
                // replacement.  An odd degree on even n adds the antipodal
                // chord n/2 (degree +1).
                let mut rng = Rng::new(seed ^ TOPO_SALT);
                let mut pool: Vec<usize> = (2..=(n - 1) / 2).collect();
                rng.shuffle(&mut pool);
                let mut offsets = vec![1usize];
                offsets.extend(pool.into_iter().take((d / 2).saturating_sub(1)));
                circulant(n, &offsets, d % 2 == 1 && n % 2 == 0)
            }
            TopologySpec::SmallWorld { d, p } => {
                let h = (d / 2).max(1);
                if 2 * h >= n - 1 {
                    return Ok(full);
                }
                let mut sets = circulant_sets(n, &(1..=h).collect::<Vec<_>>(), false);
                // Watts–Strogatz rewiring over the long-range chords only
                // (offset >= 2); the offset-1 ring is left intact so the
                // graph stays connected.  Deterministic iteration order:
                // ascending (i, offset).
                let mut rng = Rng::new(seed ^ TOPO_SALT);
                for i in 0..n {
                    for o in 2..=h {
                        let j = (i + o) % n;
                        if rng.f64() >= p {
                            continue;
                        }
                        // Pick a fresh endpoint; bounded retries keep the
                        // build total even in dense corners, and giving up
                        // just keeps the original chord.
                        for _ in 0..8 {
                            let t = rng.below(n);
                            if t != i && t != j && !sets[i].contains(&(t as ClientId)) {
                                sets[i].remove(&(j as ClientId));
                                sets[j].remove(&(i as ClientId));
                                sets[i].insert(t as ClientId);
                                sets[t].insert(i as ClientId);
                                break;
                            }
                        }
                    }
                }
                finalize(sets)
            }
        };
        Ok(Topology { spec: self, n, adj: Some(adj) })
    }
}

/// Circulant adjacency as sorted neighbor lists.
fn circulant(n: usize, offsets: &[usize], antipode: bool) -> Vec<Vec<ClientId>> {
    finalize(circulant_sets(n, offsets, antipode))
}

/// Circulant adjacency as sets (the small-world rewiring substrate).
fn circulant_sets(n: usize, offsets: &[usize], antipode: bool) -> Vec<BTreeSet<ClientId>> {
    let mut sets = vec![BTreeSet::new(); n];
    for i in 0..n {
        for &o in offsets {
            let j = (i + o) % n;
            sets[i].insert(j as ClientId);
            sets[j].insert(i as ClientId);
        }
        if antipode {
            let j = (i + n / 2) % n;
            sets[i].insert(j as ClientId);
            sets[j].insert(i as ClientId);
        }
    }
    sets
}

fn finalize(sets: Vec<BTreeSet<ClientId>>) -> Vec<Vec<ClientId>> {
    sets.into_iter().map(|s| s.into_iter().collect()).collect()
}

/// A built overlay: one sorted neighbor list per client.  The full mesh
/// is represented implicitly (no adjacency is materialized), so a
/// 10 000-client full-topology deployment costs no O(n²) memory here.
#[derive(Clone, Debug)]
pub struct Topology {
    spec: TopologySpec,
    n: usize,
    /// `None` = full mesh (implicit); `Some` = sparse adjacency, each
    /// list sorted ascending.
    adj: Option<Vec<Vec<ClientId>>>,
}

impl Topology {
    /// The all-to-all overlay for `n` clients (what every deployment used
    /// before the topology layer existed).
    pub fn full(n: usize) -> Topology {
        Topology { spec: TopologySpec::Full, n, adj: None }
    }

    pub fn spec(&self) -> TopologySpec {
        self.spec
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Is this the all-to-all mesh? (Multi-hop relays are pointless —
    /// and disabled — on a full overlay.)
    pub fn is_full(&self) -> bool {
        self.adj.is_none()
    }

    /// The neighbor set of `id`, ascending.  On the full mesh this is
    /// exactly the ascending all-peers list (the pre-topology `peers()`
    /// order — the byte-identity contract).
    pub fn neighbors(&self, id: ClientId) -> Vec<ClientId> {
        match &self.adj {
            None => (0..self.n as ClientId).filter(|&p| p != id).collect(),
            Some(adj) => adj[id as usize].clone(),
        }
    }

    /// Visit `id`'s neighbors in ascending order without allocating.
    pub fn for_each_neighbor(&self, id: ClientId, mut f: impl FnMut(ClientId)) {
        match &self.adj {
            None => (0..self.n as ClientId).filter(|&p| p != id).for_each(&mut f),
            Some(adj) => adj[id as usize].iter().copied().for_each(&mut f),
        }
    }

    pub fn degree(&self, id: ClientId) -> usize {
        match &self.adj {
            None => self.n.saturating_sub(1),
            Some(adj) => adj[id as usize].len(),
        }
    }

    pub fn max_degree(&self) -> usize {
        match &self.adj {
            None => self.n.saturating_sub(1),
            Some(adj) => adj.iter().map(Vec::len).max().unwrap_or(0),
        }
    }

    /// Total undirected edges.
    pub fn edges(&self) -> usize {
        match &self.adj {
            None => self.n * self.n.saturating_sub(1) / 2,
            Some(adj) => adj.iter().map(Vec::len).sum::<usize>() / 2,
        }
    }

    /// Is every client reachable from client 0?  All presets guarantee
    /// this by construction (the offset-1 ring is never broken); the
    /// check exists for tests and debug assertions.
    pub fn is_connected(&self) -> bool {
        if self.n <= 1 {
            return true;
        }
        let Some(adj) = &self.adj else { return true };
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(i) = stack.pop() {
            for &j in &adj[i] {
                if !seen[j as usize] {
                    seen[j as usize] = true;
                    count += 1;
                    stack.push(j as usize);
                }
            }
        }
        count == self.n
    }

    // --- mutable-overlay API (graph faults, DESIGN.md §10) -----------------
    //
    // The methods below are the substrate of [`super::overlay::Overlay`]:
    // edge cuts, churn departures with repair, and deterministic edge
    // regeneration for rejoining clients.  Static deployments never call
    // any of them, which is what keeps fault-free runs byte-identical.

    /// How many overlay edges the [`super::NetSplit`]-style bisection
    /// `side_a` (vs the complement) would sever — the setup-time
    /// validation of partition faults: a "cut" crossing zero overlay
    /// edges is a no-op on this graph (ids outside `0..n` are ignored, so
    /// a side made only of unknown ids counts as empty).
    pub fn split_crossing_edges(&self, side_a: &[ClientId]) -> usize {
        let mut in_a = vec![false; self.n];
        for &c in side_a {
            if let Some(slot) = in_a.get_mut(c as usize) {
                *slot = true;
            }
        }
        let a_count = in_a.iter().filter(|x| **x).count();
        match &self.adj {
            None => a_count * (self.n - a_count),
            Some(adj) => (0..self.n)
                .filter(|&i| in_a[i])
                .map(|i| adj[i].iter().filter(|&&j| !in_a[j as usize]).count())
                .sum(),
        }
    }

    /// Turn the implicit full mesh into an explicit adjacency so edges
    /// can be mutated (no-op on an already-sparse graph).  After this the
    /// graph is no longer [`Topology::is_full`] even before any cut.
    pub fn materialize(&mut self) {
        if self.adj.is_some() {
            return;
        }
        self.adj = Some(
            (0..self.n as ClientId)
                .map(|i| (0..self.n as ClientId).filter(|&p| p != i).collect())
                .collect(),
        );
    }

    /// Is `a — b` currently an overlay edge?
    pub fn has_edge(&self, a: ClientId, b: ClientId) -> bool {
        if a == b || a as usize >= self.n || b as usize >= self.n {
            return false;
        }
        match &self.adj {
            None => true,
            Some(adj) => adj[a as usize].binary_search(&b).is_ok(),
        }
    }

    /// Add the undirected edge `a — b` (materializing first if needed).
    /// Returns true if the edge was actually new.
    pub fn add_edge(&mut self, a: ClientId, b: ClientId) -> bool {
        if a == b || a as usize >= self.n || b as usize >= self.n {
            return false;
        }
        self.materialize();
        let adj = self.adj.as_mut().expect("just materialized");
        match adj[a as usize].binary_search(&b) {
            Ok(_) => false,
            Err(pos) => {
                adj[a as usize].insert(pos, b);
                let pos = adj[b as usize].binary_search(&a).unwrap_err();
                adj[b as usize].insert(pos, a);
                true
            }
        }
    }

    /// Remove the undirected edge `a — b` (materializing first if
    /// needed).  Returns true if the edge existed.
    pub fn remove_edge(&mut self, a: ClientId, b: ClientId) -> bool {
        if a == b || a as usize >= self.n || b as usize >= self.n {
            return false;
        }
        self.materialize();
        let adj = self.adj.as_mut().expect("just materialized");
        match adj[a as usize].binary_search(&b) {
            Err(_) => false,
            Ok(pos) => {
                adj[a as usize].remove(pos);
                let pos = adj[b as usize].binary_search(&a).expect("symmetric adjacency");
                adj[b as usize].remove(pos);
                true
            }
        }
    }

    /// Churn departure with repair: tear down every edge of `client` and
    /// re-attach its orphaned neighbors in a cycle, so (a) any path that
    /// used to route through the departed client can route around it —
    /// the graph cannot disconnect at the departure — and (b) each
    /// orphan's degree (the quorum denominator of its tracked set) drops
    /// by at most one net.  Returns the removed edges.
    pub fn depart(&mut self, client: ClientId) -> Vec<(ClientId, ClientId)> {
        if client as usize >= self.n {
            return Vec::new();
        }
        let nbrs = self.neighbors(client);
        let mut removed = Vec::with_capacity(nbrs.len());
        for &p in &nbrs {
            if self.remove_edge(client, p) {
                removed.push((client.min(p), client.max(p)));
            }
        }
        if nbrs.len() >= 2 {
            for w in nbrs.windows(2) {
                self.add_edge(w[0], w[1]);
            }
            if nbrs.len() > 2 {
                self.add_edge(nbrs[nbrs.len() - 1], nbrs[0]);
            }
        }
        removed
    }

    /// Deterministic edge regeneration for a (re)joining client: connect
    /// it to its nearest present neighbor on each side of the id ring
    /// (connectivity: the rest of the graph is connected, so one edge to
    /// any present client reconnects the joiner) and then to a seeded
    /// sample of present clients until it reaches the graph's mean degree
    /// (degree bound: the joiner never exceeds ⌈mean⌉, and each chosen
    /// peer gains exactly one edge).  "Present" = currently has at least
    /// one edge — a departed client has none by construction.  Pure
    /// function of `(self, seed, client)`; callers vary `seed` per rejoin
    /// event to decorrelate successive regenerations.  Returns the edges
    /// added.
    pub fn regenerate(&mut self, seed: u64, client: ClientId) -> Vec<(ClientId, ClientId)> {
        if client as usize >= self.n {
            return Vec::new();
        }
        self.materialize();
        let present: Vec<ClientId> = (0..self.n as ClientId)
            .filter(|&i| i != client && self.degree(i) > 0)
            .collect();
        if present.is_empty() {
            return Vec::new();
        }
        let deg_sum: usize = present.iter().map(|&i| self.degree(i)).sum();
        let mean_deg = (deg_sum + present.len() - 1) / present.len(); // ⌈mean⌉
        // max-then-min rather than clamp: with a single present client the
        // bounds cross (2 > 1) and Ord::clamp would panic; the degenerate
        // target is simply "the one edge there is to make".
        let target = mean_deg.max(2).min(present.len());
        let mut added = Vec::new();
        let mut add = |topo: &mut Topology, p: ClientId, added: &mut Vec<_>| {
            if topo.add_edge(client, p) {
                added.push((client.min(p), client.max(p)));
            }
        };
        // Ring anchors: the nearest present id above and below (cyclic),
        // mirroring the construction-time offset-1 ring.
        let n64 = self.n as u64;
        let above = present
            .iter()
            .copied()
            .min_by_key(|&p| (p as u64 + n64 - client as u64) % n64);
        let below = present
            .iter()
            .copied()
            .min_by_key(|&p| (client as u64 + n64 - p as u64) % n64);
        for anchor in [above, below].into_iter().flatten() {
            add(self, anchor, &mut added);
        }
        // Seeded fill to the mean degree.
        let mut rng = Rng::new(seed ^ REGEN_SALT ^ (client as u64).wrapping_mul(0x9E37_79B9));
        let mut pool = present;
        while self.degree(client) < target && !pool.is_empty() {
            let p = pool.swap_remove(rng.below(pool.len()));
            add(self, p, &mut added);
        }
        added
    }

    /// Seeded approximate min-cut (Karger's randomized contraction, a
    /// fixed number of trials, best cut kept): the `--fault
    /// graph-cut:…:mincut` resolver, severing the overlay where it is
    /// thinnest.  Deterministic in `(self, seed)`.  Returns the cut's
    /// edges (each `(lo, hi)`, ascending); empty only when the graph has
    /// fewer than two non-isolated vertices.
    pub fn min_cut(&self, seed: u64) -> Vec<(ClientId, ClientId)> {
        let mut edges: Vec<(ClientId, ClientId)> = Vec::new();
        for i in 0..self.n as ClientId {
            self.for_each_neighbor(i, |j| {
                if i < j {
                    edges.push((i, j));
                }
            });
        }
        let vertices = {
            let mut seen = vec![false; self.n];
            for &(a, b) in &edges {
                seen[a as usize] = true;
                seen[b as usize] = true;
            }
            seen.iter().filter(|x| **x).count()
        };
        if vertices < 2 {
            return Vec::new();
        }
        let mut rng = Rng::new(seed ^ MINCUT_SALT);
        let mut best: Option<Vec<(ClientId, ClientId)>> = None;
        for trial in 0..MINCUT_TRIALS {
            let mut order = edges.clone();
            let mut trial_rng = rng.fork(trial);
            trial_rng.shuffle(&mut order);
            // Contract shuffled edges until two super-nodes remain.
            let mut dsu = Dsu::new(self.n);
            let mut components = vertices;
            for &(a, b) in &order {
                if components == 2 {
                    break;
                }
                if dsu.union(a as usize, b as usize) {
                    components -= 1;
                }
            }
            let cut: Vec<(ClientId, ClientId)> = edges
                .iter()
                .copied()
                .filter(|&(a, b)| dsu.find(a as usize) != dsu.find(b as usize))
                .collect();
            if best.as_ref().map_or(true, |b| cut.len() < b.len()) {
                best = Some(cut);
            }
        }
        best.unwrap_or_default()
    }

    /// Partition the clients into at most `s` shards for the parallel
    /// executor (`--exec parallel:S`, DESIGN.md §12), minimizing the
    /// cross-shard (cut) edge count over a deterministic candidate set.
    /// Returns `shard_of`: one shard index in `0..s_eff` per client,
    /// where `s_eff = min(max(s, 1), n)`; every shard in `0..s_eff` is
    /// non-empty.  Pure function of `(self, s, seed)` — same inputs,
    /// same partition, the determinism contract the cross-executor
    /// conformance suite relies on.
    ///
    /// Candidates (best cut wins, earliest candidate breaks ties):
    ///
    /// 1. Balanced contiguous id chunks — near-optimal for the circulant
    ///    presets, whose edges are short ring offsets.
    /// 2. Size-capped randomized edge contraction — the Karger/[`Dsu`]
    ///    machinery of [`Topology::min_cut`] re-targeted at partitioning:
    ///    contract seeded shuffled edges while components stay ≤ ⌊n/s⌋,
    ///    then bin-pack the components onto shards largest-first into
    ///    the lightest shard.  Contraction merges along edges, so
    ///    tightly-coupled clients land on one worker.
    /// 3. Seeded balanced shuffles — the random-partition baseline, kept
    ///    in the candidate set so the result can never lose to it.
    pub fn partition_shards(&self, s: usize, seed: u64) -> Vec<usize> {
        let n = self.n;
        if n == 0 {
            return Vec::new();
        }
        let s = s.max(1).min(n);
        if s == 1 {
            return vec![0; n];
        }
        let crossing = |assign: &[usize]| -> usize {
            let mut cut = 0;
            for i in 0..n as ClientId {
                self.for_each_neighbor(i, |j| {
                    if i < j && assign[i as usize] != assign[j as usize] {
                        cut += 1;
                    }
                });
            }
            cut
        };
        // candidate 1: balanced contiguous chunks (sizes differ by ≤ 1)
        let mut best: Vec<usize> = (0..n).map(|i| i * s / n).collect();
        let mut best_cut = crossing(&best);
        let mut edges: Vec<(ClientId, ClientId)> = Vec::new();
        for i in 0..n as ClientId {
            self.for_each_neighbor(i, |j| {
                if i < j {
                    edges.push((i, j));
                }
            });
        }
        // ⌊n/s⌋ ≥ 1 caps every component, so ≥ s components always come
        // out of a contraction and no shard packs empty.
        let cap = n / s;
        let mut rng = Rng::new(seed ^ SHARD_SALT);
        for trial in 0..SHARD_CONTRACTION_TRIALS + SHARD_SHUFFLE_TRIALS {
            let mut trial_rng = rng.fork(trial);
            let cand: Vec<usize> = if trial < SHARD_CONTRACTION_TRIALS {
                // candidate 2: capped contraction + largest-first packing
                let mut order = edges.clone();
                trial_rng.shuffle(&mut order);
                let mut dsu = Dsu::new(n);
                for &(a, b) in &order {
                    dsu.union_capped(a as usize, b as usize, cap);
                }
                let roots: Vec<usize> = (0..n).filter(|&v| dsu.find(v) == v).collect();
                let mut comps: Vec<(usize, usize)> =
                    roots.iter().map(|&r| (dsu.size[r], r)).collect();
                comps.sort_by_key(|&(size, root)| (Reverse(size), root));
                let mut weight = vec![0usize; s];
                let mut shard_of_root = vec![0usize; n];
                for (size, root) in comps {
                    let lightest =
                        (0..s).min_by_key(|&sh| (weight[sh], sh)).expect("s >= 2");
                    weight[lightest] += size;
                    shard_of_root[root] = lightest;
                }
                (0..n).map(|v| shard_of_root[dsu.find(v)]).collect()
            } else {
                // candidate 3: a balanced chunking of a seeded shuffle
                let mut ids: Vec<usize> = (0..n).collect();
                trial_rng.shuffle(&mut ids);
                let mut cand = vec![0usize; n];
                for (pos, &id) in ids.iter().enumerate() {
                    cand[id] = pos * s / n;
                }
                cand
            };
            let cut = crossing(&cand);
            if cut < best_cut {
                best_cut = cut;
                best = cand;
            }
        }
        best
    }
}

/// Karger trial count: enough repetitions that the best of them sits at
/// or near the true min-cut on the deployment sizes we sweep, while the
/// whole search stays O(trials · m · α).
const MINCUT_TRIALS: u64 = 24;

/// [`Topology::partition_shards`] candidate counts: capped-contraction
/// trials and balanced-shuffle (random baseline) trials.
const SHARD_CONTRACTION_TRIALS: u64 = 8;
const SHARD_SHUFFLE_TRIALS: u64 = 4;

/// Union-find for the contraction trials.
struct Dsu {
    parent: Vec<usize>,
    /// Component size, valid at roots only.
    size: Vec<usize>,
}

impl Dsu {
    fn new(n: usize) -> Dsu {
        Dsu { parent: (0..n).collect(), size: vec![1; n] }
    }

    fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] != x {
            self.parent[x] = self.parent[self.parent[x]]; // path halving
            x = self.parent[x];
        }
        x
    }

    /// Merge two distinct roots; the smaller index survives as root (so
    /// root choice is deterministic regardless of merge order).
    fn link(&mut self, ra: usize, rb: usize) {
        let (keep, absorb) = (ra.min(rb), ra.max(rb));
        self.parent[absorb] = keep;
        self.size[keep] += self.size[absorb];
    }

    fn union(&mut self, a: usize, b: usize) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        self.link(ra, rb);
        true
    }

    /// Union, refused when the merged component would exceed `cap` —
    /// the contraction step of [`Topology::partition_shards`], which
    /// needs every component to still fit inside one shard.
    fn union_capped(&mut self, a: usize, b: usize, cap: usize) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb || self.size[ra] + self.size[rb] > cap {
            return false;
        }
        self.link(ra, rb);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_undirected(t: &Topology) {
        for i in 0..t.n() as ClientId {
            for j in t.neighbors(i) {
                assert_ne!(i, j, "self loop at {i}");
                assert!(
                    t.neighbors(j).contains(&i),
                    "edge {i}->{j} has no reverse"
                );
            }
        }
    }

    #[test]
    fn full_matches_pretopology_peer_order() {
        let t = Topology::full(5);
        assert!(t.is_full());
        assert_eq!(t.neighbors(2), vec![0, 1, 3, 4]);
        assert_eq!(t.degree(2), 4);
        let mut visited = Vec::new();
        t.for_each_neighbor(2, |p| visited.push(p));
        assert_eq!(visited, vec![0, 1, 3, 4], "iteration must match allocation");
    }

    #[test]
    fn ring_degree_and_symmetry() {
        let t = TopologySpec::Ring { k: 2 }.build(10, 7).unwrap();
        assert!(!t.is_full());
        for i in 0..10 {
            assert_eq!(t.degree(i), 4, "ring:2 degree at {i}");
        }
        assert_eq!(t.neighbors(0), vec![1, 2, 8, 9]);
        assert_undirected(&t);
        assert!(t.is_connected());
    }

    #[test]
    fn wide_ring_degenerates_to_full() {
        let t = TopologySpec::Ring { k: 5 }.build(8, 7).unwrap();
        assert!(t.is_full(), "2k >= n-1 must be the mesh");
        assert_eq!(t.neighbors(3), vec![0, 1, 2, 4, 5, 6, 7]);
    }

    #[test]
    fn k_regular_is_regular_connected_and_seeded() {
        for seed in [1u64, 2, 99] {
            let t = TopologySpec::KRegular { d: 6 }.build(50, seed).unwrap();
            for i in 0..50 {
                assert_eq!(t.degree(i), 6, "seed {seed} client {i}");
            }
            assert_undirected(&t);
            assert!(t.is_connected(), "seed {seed}");
        }
        // deterministic per seed, different across seeds (50 choose 2
        // offsets — a collision would be a broken RNG stream)
        let a = TopologySpec::KRegular { d: 6 }.build(50, 1).unwrap();
        let b = TopologySpec::KRegular { d: 6 }.build(50, 1).unwrap();
        let c = TopologySpec::KRegular { d: 6 }.build(50, 2).unwrap();
        assert_eq!(a.neighbors(0), b.neighbors(0), "same seed, same graph");
        assert_ne!(
            (0..50).map(|i| a.neighbors(i)).collect::<Vec<_>>(),
            (0..50).map(|i| c.neighbors(i)).collect::<Vec<_>>(),
            "different seeds must differ somewhere"
        );
    }

    #[test]
    fn k_regular_odd_degree_even_n_uses_antipode() {
        let t = TopologySpec::KRegular { d: 5 }.build(12, 3).unwrap();
        for i in 0..12 {
            assert_eq!(t.degree(i), 5, "antipodal chord must top up odd degree");
        }
        assert!(t.neighbors(0).contains(&6), "antipode of 0 in a 12-ring");
    }

    #[test]
    fn small_world_stays_connected_and_near_degree() {
        let t = TopologySpec::SmallWorld { d: 6, p: 0.3 }.build(60, 11).unwrap();
        assert!(t.is_connected());
        assert_undirected(&t);
        let total: usize = (0..60).map(|i| t.degree(i)).sum();
        assert_eq!(total, 2 * t.edges());
        // rewiring moves edges, it does not add or remove them
        assert_eq!(t.edges(), 60 * 3, "edge count preserved by rewiring");
        // p = 0.3 over 2 long chords/client: some rewiring must happen
        let base = TopologySpec::SmallWorld { d: 6, p: 0.0 }.build(60, 11).unwrap();
        assert_ne!(
            (0..60).map(|i| t.neighbors(i)).collect::<Vec<_>>(),
            (0..60).map(|i| base.neighbors(i)).collect::<Vec<_>>(),
            "p=0.3 never rewired anything"
        );
        // deterministic per seed
        let again = TopologySpec::SmallWorld { d: 6, p: 0.3 }.build(60, 11).unwrap();
        assert_eq!(
            (0..60).map(|i| t.neighbors(i)).collect::<Vec<_>>(),
            (0..60).map(|i| again.neighbors(i)).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn parse_name_roundtrip_and_rejections() {
        for s in ["full", "ring:2", "k-regular:8", "small-world:8:0.1"] {
            let spec = TopologySpec::parse(s).unwrap();
            assert_eq!(TopologySpec::parse(&spec.name()).unwrap(), spec);
        }
        assert_eq!(
            TopologySpec::parse("kreg:4").unwrap(),
            TopologySpec::KRegular { d: 4 },
            "short alias"
        );
        assert_eq!(
            TopologySpec::parse("sw:4:0.2").unwrap(),
            TopologySpec::SmallWorld { d: 4, p: 0.2 },
        );
        for bad in [
            "",
            "mesh",
            "ring",
            "ring:0",
            "ring:x",
            "k-regular:1",
            "small-world:4",
            "small-world:4:1.5",
            "full:1",
        ] {
            assert!(TopologySpec::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn shrink_degree_walks_down_then_stops() {
        let mut spec = TopologySpec::KRegular { d: 16 };
        let mut seen = vec![spec];
        while let Some(s) = spec.shrink_degree() {
            spec = s;
            seen.push(s);
        }
        assert_eq!(
            seen,
            vec![
                TopologySpec::KRegular { d: 16 },
                TopologySpec::KRegular { d: 8 },
                TopologySpec::KRegular { d: 4 },
                TopologySpec::KRegular { d: 2 },
            ]
        );
        assert_eq!(TopologySpec::Full.shrink_degree(), None);
        assert_eq!(TopologySpec::Ring { k: 1 }.shrink_degree(), None);
    }

    #[test]
    fn tiny_deployments_are_always_the_mesh() {
        for n in 0..=2 {
            let t = TopologySpec::KRegular { d: 4 }.build(n, 9).unwrap();
            assert!(t.is_full(), "n={n}");
        }
    }

    // --- mutable-overlay API ------------------------------------------------

    #[test]
    fn materialized_full_mesh_matches_the_implicit_one() {
        let mut t = Topology::full(6);
        t.materialize();
        assert!(!t.is_full(), "materialized mesh is mutable, not implicit");
        for i in 0..6 {
            assert_eq!(t.neighbors(i), Topology::full(6).neighbors(i));
        }
        assert_eq!(t.edges(), 15);
        assert_undirected(&t);
    }

    #[test]
    fn add_remove_edge_round_trip() {
        let mut t = TopologySpec::Ring { k: 1 }.build(6, 1).unwrap();
        assert!(t.has_edge(0, 1));
        assert!(t.remove_edge(0, 1));
        assert!(!t.has_edge(0, 1) && !t.has_edge(1, 0));
        assert!(!t.remove_edge(0, 1), "double remove must be a no-op");
        assert!(t.add_edge(0, 1));
        assert!(!t.add_edge(0, 1), "double add must be a no-op");
        assert!(t.has_edge(1, 0), "edges are undirected");
        assert!(!t.add_edge(3, 3), "self loops rejected");
        assert!(!t.add_edge(0, 99), "out-of-range rejected");
        assert_undirected(&t);
        // neighbor lists stay sorted through mutation
        for i in 0..6 {
            let nbrs = t.neighbors(i);
            let mut sorted = nbrs.clone();
            sorted.sort_unstable();
            assert_eq!(nbrs, sorted, "adjacency of {i} lost its order");
        }
    }

    #[test]
    fn depart_repairs_connectivity_and_bounds_degree_loss() {
        let t0 = TopologySpec::KRegular { d: 4 }.build(20, 5).unwrap();
        let mut t = t0.clone();
        let victim = 7;
        let nbrs = t.neighbors(victim);
        let removed = t.depart(victim);
        assert_eq!(removed.len(), nbrs.len(), "every edge of the victim removed");
        assert_eq!(t.degree(victim), 0, "departed client is isolated");
        // connectivity survives among the remaining n−1 clients: reachability
        // from client 0 must cover everyone except the victim.
        let mut seen = vec![false; 20];
        let mut stack = vec![0u32];
        seen[0] = true;
        while let Some(i) = stack.pop() {
            for j in t.neighbors(i) {
                if !seen[j as usize] {
                    seen[j as usize] = true;
                    stack.push(j);
                }
            }
        }
        let reached = seen.iter().filter(|x| **x).count();
        assert_eq!(reached, 19, "repair must keep the survivors connected");
        // repair bound: each orphan loses 1 edge and regains up to 2,
        // so its degree moves by at most 1 net in either direction... the
        // cycle re-attachment guarantees no orphan drops by more than 1.
        for &p in &nbrs {
            assert!(
                t.degree(p) + 1 >= t0.degree(p),
                "orphan {p}: degree {} fell more than 1 below {}",
                t.degree(p),
                t0.degree(p)
            );
        }
        assert_undirected(&t);
    }

    #[test]
    fn regenerate_is_deterministic_connected_and_degree_bounded() {
        let mut a = TopologySpec::KRegular { d: 4 }.build(20, 5).unwrap();
        let mut b = a.clone();
        a.depart(7);
        b.depart(7);
        let ea = a.regenerate(99, 7);
        let eb = b.regenerate(99, 7);
        assert_eq!(ea, eb, "same seed must regenerate the same edges");
        assert!(!ea.is_empty());
        assert!(a.degree(7) >= 2, "rejoined client must get ring anchors");
        assert!(
            a.degree(7) <= a.max_degree(),
            "regeneration must respect the graph's degree regime"
        );
        assert!(a.is_connected(), "rejoin must reconnect the graph");
        assert_undirected(&a);
        // a different seed may pick different chords
        let mut c = TopologySpec::KRegular { d: 4 }.build(20, 5).unwrap();
        c.depart(7);
        let ec = c.regenerate(100, 7);
        assert_eq!(ec.len(), ea.len(), "target degree is seed-independent");
    }

    #[test]
    fn regenerate_into_empty_graph_is_a_noop() {
        let mut t = Topology::full(1);
        assert!(t.regenerate(3, 0).is_empty());
        // single present peer: the crossed bounds (target 2 vs 1 available)
        // must degrade gracefully, not panic in clamp
        let mut pair = Topology::full(2);
        pair.materialize();
        assert!(pair.regenerate(3, 0).is_empty(), "edge 0-1 already exists");
        pair.remove_edge(0, 1);
        assert!(pair.regenerate(3, 0).is_empty(), "peer 1 is isolated: nobody present");
        let mut lonely = TopologySpec::Ring { k: 1 }.build(4, 1).unwrap();
        for c in 0..4 {
            lonely.depart(c);
        }
        assert!(lonely.regenerate(3, 2).is_empty(), "nobody present to join");
    }

    #[test]
    fn min_cut_of_a_cycle_is_exactly_two_edges() {
        // Every contraction of a cycle keeps its components contiguous
        // arcs, and two arcs of a cycle always share exactly two boundary
        // edges — so on ring:1 *every* trial yields a true min-cut and
        // the assertion is exact, not probabilistic.
        let t = TopologySpec::Ring { k: 1 }.build(8, 3).unwrap();
        let cut = t.min_cut(42);
        assert_eq!(cut.len(), 2, "a cycle's min-cut is two edges: {cut:?}");
        for &(a, b) in &cut {
            assert!(a < b, "cut edges normalized ascending");
            assert!(t.has_edge(a, b), "cut edge {a}-{b} not in the graph");
        }
        let mut severed = t.clone();
        for &(a, b) in &cut {
            severed.remove_edge(a, b);
        }
        assert!(!severed.is_connected(), "a min-cut must disconnect when removed");
        assert_eq!(t.min_cut(42), cut, "seeded min-cut must be deterministic");
    }

    #[test]
    fn min_cut_is_a_valid_cut_on_any_graph() {
        // Guaranteed-by-construction properties on a denser overlay: the
        // returned edges exist, removing them disconnects the graph, and
        // the search is a pure function of (graph, seed).
        let t = TopologySpec::KRegular { d: 4 }.build(20, 5).unwrap();
        let cut = t.min_cut(7);
        assert!(!cut.is_empty());
        for &(a, b) in &cut {
            assert!(t.has_edge(a, b));
        }
        let mut severed = t.clone();
        for &(a, b) in &cut {
            severed.remove_edge(a, b);
        }
        assert!(!severed.is_connected());
        assert_eq!(t.min_cut(7), cut);
        // degenerate graphs yield no cut instead of panicking
        assert!(Topology::full(1).min_cut(1).is_empty());
        assert!(Topology::full(0).min_cut(1).is_empty());
    }

    #[test]
    fn split_crossing_edges_counts_the_overlay_not_the_id_space() {
        let full = Topology::full(6);
        assert_eq!(full.split_crossing_edges(&[0, 1, 2]), 9, "3×3 on the mesh");
        assert_eq!(full.split_crossing_edges(&[]), 0);
        assert_eq!(full.split_crossing_edges(&[0, 1, 2, 3, 4, 5]), 0);
        assert_eq!(full.split_crossing_edges(&[77, 99]), 0, "unknown ids are no side");
        let ring = TopologySpec::Ring { k: 1 }.build(6, 1).unwrap();
        assert_eq!(
            ring.split_crossing_edges(&[0, 1, 2]),
            2,
            "a contiguous arc cuts exactly its two boundary edges"
        );
        assert_eq!(ring.split_crossing_edges(&[0, 2, 4]), 6, "alternating cut");
    }

    // --- shard partitioner (parallel executor) ------------------------------

    fn crossing(t: &Topology, assign: &[usize]) -> usize {
        let mut cut = 0;
        for i in 0..t.n() as ClientId {
            t.for_each_neighbor(i, |j| {
                if i < j && assign[i as usize] != assign[j as usize] {
                    cut += 1;
                }
            });
        }
        cut
    }

    #[test]
    fn partition_covers_every_client_once_respects_s_and_is_deterministic() {
        use crate::util::quickcheck::forall;
        let specs = [
            TopologySpec::Full,
            TopologySpec::Ring { k: 2 },
            TopologySpec::KRegular { d: 6 },
            TopologySpec::SmallWorld { d: 4, p: 0.1 },
        ];
        forall(
            0x5A4D,
            24,
            |r| {
                let n = 8 + r.below(57);
                let s = 2 + r.below(7);
                let spec = specs[r.below(specs.len())];
                let seed = r.next_u64();
                (n, s, spec, seed)
            },
            |&(n, s, spec, seed)| {
                let t = spec.build(n, seed).map_err(|e| e.to_string())?;
                let assign = t.partition_shards(s, seed);
                if assign.len() != n {
                    return Err(format!("{} assignments for {n} clients", assign.len()));
                }
                let s_eff = s.min(n);
                let mut sizes = vec![0usize; s_eff];
                for (i, &sh) in assign.iter().enumerate() {
                    if sh >= s_eff {
                        return Err(format!("client {i} on shard {sh} >= {s_eff}"));
                    }
                    sizes[sh] += 1;
                }
                if let Some(empty) = sizes.iter().position(|&c| c == 0) {
                    return Err(format!("shard {empty} is empty: {sizes:?}"));
                }
                if t.partition_shards(s, seed) != assign {
                    return Err("same (graph, s, seed) gave a different partition".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn partition_cut_beats_a_random_balanced_baseline() {
        // Local topologies only: on the full mesh an *unbalanced* random
        // partition can legitimately cut fewer edges than any balanced
        // one (Σ|Sᵢ|² grows as balance improves), so "beats random" is
        // only a meaningful yardstick where locality exists to exploit.
        let specs = [
            TopologySpec::Ring { k: 2 },
            TopologySpec::Ring { k: 3 },
            TopologySpec::SmallWorld { d: 4, p: 0.1 },
        ];
        use crate::util::quickcheck::forall;
        forall(
            0x5A4E,
            16,
            |r| {
                let n = 24 + r.below(41);
                let s = 2 + r.below(5);
                let spec = specs[r.below(specs.len())];
                let seed = r.next_u64();
                (n, s, spec, seed)
            },
            |&(n, s, spec, seed)| {
                let t = spec.build(n, seed).map_err(|e| e.to_string())?;
                let assign = t.partition_shards(s, seed);
                let cut = crossing(&t, &assign);
                // random balanced baseline: seeded shuffle, chunked
                let mut ids: Vec<usize> = (0..n).collect();
                Rng::new(seed ^ 0xBA5E).shuffle(&mut ids);
                let mut baseline = vec![0usize; n];
                for (pos, &id) in ids.iter().enumerate() {
                    baseline[id] = pos * s.min(n) / n;
                }
                let base_cut = crossing(&t, &baseline);
                if cut > base_cut {
                    return Err(format!(
                        "partitioner cut {cut} worse than random baseline {base_cut}"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn partition_clamps_and_degenerates_sanely() {
        let t = TopologySpec::Ring { k: 1 }.build(6, 3).unwrap();
        assert_eq!(t.partition_shards(1, 9), vec![0; 6], "s=1 is the whole graph");
        assert_eq!(t.partition_shards(0, 9), vec![0; 6], "s=0 clamps to 1");
        let singletons = t.partition_shards(64, 9);
        let mut sorted = singletons.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..6).collect::<Vec<_>>(), "s>n degenerates to singletons");
        assert!(Topology::full(0).partition_shards(4, 1).is_empty());
        // a 24-cycle into 4 shards: contiguous arcs cut exactly 4 edges,
        // and the candidate set contains the contiguous chunking — so the
        // best cut can never exceed it.
        let ring = TopologySpec::Ring { k: 1 }.build(24, 3).unwrap();
        let assign = ring.partition_shards(4, 7);
        assert!(
            crossing(&ring, &assign) <= 4,
            "cycle into 4 arcs cuts at most 4 edges, got {}",
            crossing(&ring, &assign)
        );
    }
}
