//! Wire messages exchanged between clients.
//!
//! The protocol needs exactly what Algorithm 2 carries: model parameters
//! tagged with sender, round number, aggregation weight, and the
//! Client-Responsive Termination flag that piggybacks on every broadcast
//! after a client learns of termination.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::model::ParamVector;
use crate::util::codec::{Reader, SliceWriter};

use super::delta::{DeltaMsg, FlagMsg};

pub type ClientId = u32;

/// A model broadcast (the paper's ⟨M_i, round, terminate⟩ message).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelUpdate {
    pub sender: ClientId,
    pub round: u32,
    /// Client-Responsive Termination flag: set once the sender has either
    /// triggered Client-Confident Convergence itself or heard the flag from
    /// any peer; propagated on every subsequent broadcast.
    pub terminate: bool,
    /// Aggregation weight (local sample count; 1.0 = plain FedAvg).
    pub weight: f32,
    pub params: ParamVector,
}

/// All message kinds on the wire.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    Update(ModelUpdate),
    /// Join/identify (TCP connection handshake).
    Hello { sender: ClientId },
    /// Graceful leave (distinct from a crash, which is silence).
    Bye { sender: ClientId },
    /// Delta-codec model broadcast (`--codec delta:K[,q16]`, DESIGN.md
    /// §13): sparse top-K against a per-link acked base, or a full
    /// snapshot, plus the anti-entropy piggyback.
    Delta(DeltaMsg),
    /// Compact Client-Responsive Termination flag relay (delta mode):
    /// replaces the dense path's full-model forward.
    Flag(FlagMsg),
}

const TAG_UPDATE: u8 = 1;
const TAG_HELLO: u8 = 2;
const TAG_BYE: u8 = 3;
const TAG_DELTA: u8 = 4;
const TAG_FLAG: u8 = 5;

impl Msg {
    pub fn sender(&self) -> ClientId {
        match self {
            Msg::Update(u) => u.sender,
            Msg::Hello { sender } | Msg::Bye { sender } => *sender,
            Msg::Delta(d) => d.sender,
            Msg::Flag(f) => f.sender,
        }
    }

    /// Exact encoded size, computed from the same layout [`encode_into`]
    /// walks — what lets both [`encode`] and [`encode_arc`] write into a
    /// buffer allocated once at its final size.
    ///
    /// [`encode_into`]: Msg::encode_into
    /// [`encode`]: Msg::encode
    /// [`encode_arc`]: Msg::encode_arc
    fn encoded_len(&self) -> usize {
        1 + match self {
            Msg::Update(u) => 4 + 4 + 1 + 4 + (4 + u.params.len() * 4),
            Msg::Hello { .. } | Msg::Bye { .. } => 4,
            Msg::Delta(d) => d.wire_len(),
            Msg::Flag(f) => f.wire_len(),
        }
    }

    /// The one encoder: writes the message into `buf` (which must be
    /// exactly [`encoded_len`](Msg::encoded_len) bytes).
    fn encode_into(&self, buf: &mut [u8]) {
        let mut w = SliceWriter::new(buf);
        match self {
            Msg::Update(u) => {
                w.u8(TAG_UPDATE);
                w.u32(u.sender);
                w.u32(u.round);
                w.bool(u.terminate);
                w.f32(u.weight);
                w.f32_slice(&u.params.0);
            }
            Msg::Hello { sender } => {
                w.u8(TAG_HELLO);
                w.u32(*sender);
            }
            Msg::Bye { sender } => {
                w.u8(TAG_BYE);
                w.u32(*sender);
            }
            Msg::Delta(d) => {
                w.u8(TAG_DELTA);
                d.encode_into(&mut w);
            }
            Msg::Flag(f) => {
                w.u8(TAG_FLAG);
                f.encode_into(&mut w);
            }
        }
        debug_assert_eq!(w.written(), buf.len(), "encoded_len out of sync with encode_into");
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut buf = vec![0u8; self.encoded_len()];
        self.encode_into(&mut buf);
        buf
    }

    /// Encode straight into a single `Arc<[u8]>` allocation — the
    /// broadcast hubs share one encoded buffer across all receivers, and
    /// the old `encode().into()` path paid a second allocation plus a
    /// copy to re-home the `Vec` behind the `Arc` header.
    pub fn encode_arc(&self) -> Arc<[u8]> {
        let mut arc: Arc<[u8]> = std::iter::repeat(0u8).take(self.encoded_len()).collect();
        let buf = Arc::get_mut(&mut arc).expect("freshly collected Arc is unique");
        self.encode_into(buf);
        arc
    }

    pub fn decode(bytes: &[u8]) -> Result<Msg> {
        let mut r = Reader::new(bytes);
        let tag = r.u8()?;
        let msg = match tag {
            TAG_UPDATE => {
                let sender = r.u32()?;
                let round = r.u32()?;
                let terminate = r.bool()?;
                let weight = r.f32()?;
                // A NaN/zero/negative weight from one peer would poison or
                // zero the neighborhood weighted average — unusable
                // aggregation input, rejected at the trust boundary.
                if !weight.is_finite() || weight <= 0.0 {
                    bail!("update from client {sender} carries invalid aggregation weight {weight}");
                }
                Msg::Update(ModelUpdate {
                    sender,
                    round,
                    terminate,
                    weight,
                    params: ParamVector::decode(&mut r)?,
                })
            }
            TAG_HELLO => Msg::Hello { sender: r.u32()? },
            TAG_BYE => Msg::Bye { sender: r.u32()? },
            TAG_DELTA => Msg::Delta(DeltaMsg::decode(&mut r)?),
            TAG_FLAG => Msg::Flag(FlagMsg::decode(&mut r)?),
            t => bail!("unknown message tag {t}"),
        };
        if r.remaining() != 0 {
            bail!("trailing bytes after message ({} left)", r.remaining());
        }
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::forall;

    #[test]
    fn update_roundtrip() {
        let msg = Msg::Update(ModelUpdate {
            sender: 3,
            round: 17,
            terminate: true,
            weight: 2.5,
            params: ParamVector(vec![1.0, -2.0, 0.5]),
        });
        assert_eq!(Msg::decode(&msg.encode()).unwrap(), msg);
    }

    #[test]
    fn hello_bye_roundtrip() {
        for msg in [Msg::Hello { sender: 9 }, Msg::Bye { sender: 0 }] {
            assert_eq!(Msg::decode(&msg.encode()).unwrap(), msg);
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Msg::decode(&[]).is_err());
        assert!(Msg::decode(&[99, 0, 0]).is_err());
        // trailing bytes
        let mut bytes = Msg::Hello { sender: 1 }.encode();
        bytes.push(0);
        assert!(Msg::decode(&bytes).is_err());
    }

    #[test]
    fn decode_rejects_invalid_weights() {
        // encode() doesn't judge (a Byzantine sender controls its own
        // bytes anyway); decode is the trust boundary that must.
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 0.0, -1.0] {
            let msg = Msg::Update(ModelUpdate {
                sender: 5,
                round: 2,
                terminate: false,
                weight: bad,
                params: ParamVector(vec![1.0]),
            });
            assert!(Msg::decode(&msg.encode()).is_err(), "weight {bad} must be rejected");
        }
        // the boundary itself: tiny positive weights are legitimate
        let msg = Msg::Update(ModelUpdate {
            sender: 5,
            round: 2,
            terminate: false,
            weight: f32::MIN_POSITIVE,
            params: ParamVector(vec![1.0]),
        });
        assert!(Msg::decode(&msg.encode()).is_ok());
    }

    #[test]
    fn encode_arc_matches_encode() {
        use crate::net::delta::{Ack, DeltaBody, SparseVals};
        let msgs = [
            Msg::Hello { sender: 9 },
            Msg::Bye { sender: 0 },
            Msg::Update(ModelUpdate {
                sender: 3,
                round: 17,
                terminate: true,
                weight: 2.5,
                params: ParamVector(vec![1.0, -2.0, 0.5]),
            }),
            Msg::Delta(DeltaMsg {
                sender: 4,
                round: 6,
                terminate: false,
                weight: 1.0,
                ack: Ack { round: 5, have: true, need_full: false },
                body: DeltaBody::Sparse {
                    base_round: 5,
                    dim: 8,
                    idx: vec![0, 3],
                    vals: SparseVals::F32(vec![0.25, -4.0]),
                },
            }),
            Msg::Flag(FlagMsg { sender: 2, origin: 7, round: 11, ack: Ack::NONE }),
        ];
        for msg in msgs {
            assert_eq!(&*msg.encode_arc(), &msg.encode()[..], "{msg:?}");
        }
    }

    #[test]
    fn delta_and_flag_roundtrip() {
        use crate::net::delta::{Ack, DeltaBody, SparseVals};
        let msg = Msg::Delta(DeltaMsg {
            sender: 12,
            round: 40,
            terminate: true,
            weight: 3.0,
            ack: Ack { round: 39, have: true, need_full: true },
            body: DeltaBody::Full(vec![1.0, f32::MIN_POSITIVE, -0.0]),
        });
        assert_eq!(Msg::decode(&msg.encode()).unwrap(), msg);
        let msg = Msg::Flag(FlagMsg {
            sender: 1,
            origin: 30,
            round: 9,
            ack: Ack { round: 8, have: true, need_full: false },
        });
        assert_eq!(Msg::decode(&msg.encode()).unwrap(), msg);
    }

    #[test]
    fn roundtrip_property() {
        forall(
            0x4E55,
            40,
            |r| {
                let n = r.below(1000);
                Msg::Update(ModelUpdate {
                    sender: r.next_u32() % 64,
                    round: r.next_u32() % 10_000,
                    terminate: r.below(2) == 1,
                    // strictly positive: decode rejects weight <= 0
                    weight: 0.1 + r.f32() * 100.0,
                    params: ParamVector((0..n).map(|_| r.normal()).collect()),
                })
            },
            |msg| {
                let got = Msg::decode(&msg.encode()).map_err(|e| e.to_string())?;
                if &got == msg {
                    Ok(())
                } else {
                    Err("roundtrip mismatch".into())
                }
            },
        );
    }
}
