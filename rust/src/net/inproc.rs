//! In-process transport: per-client channels behind a seeded network model
//! (per-message latency, jitter, probabilistic drops, time-windowed
//! partitions, and per-link blocks for failure injection).  Every message
//! round-trips through the binary codec so tests exercise the real wire
//! format.
//!
//! Two hubs share the [`NetworkModel`]:
//!
//! * [`InProcHub`] — wall-clock: a single timer thread owns delayed
//!   deliveries, keeping the network deterministic under a fixed seed
//!   (modulo OS scheduling of the client threads themselves).
//! * [`VirtualHub`] — logical-clock: deliveries become events on a shared
//!   [`VirtualClock`], delays sampled from *per-link* RNG streams and tie
//!   broken by `(due, from, to, seq)`, so the entire network schedule is a
//!   pure function of the seed — byte-identical across runs.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::message::{ClientId, Msg};
use super::Transport;
use crate::util::time::{Clock, SimTime, VirtualClock};
use crate::util::Rng;

/// A time-windowed network partition: while `start <= t < end`, messages
/// between `side_a` and everyone else are silently lost in both directions.
/// Times are measured on the hub's clock (virtual time under [`VirtualHub`],
/// time since hub creation under [`InProcHub`]), so partition-and-heal
/// scenarios are reproducible without mid-run intervention.
#[derive(Clone, Debug)]
pub struct NetSplit {
    pub start: Duration,
    pub end: Duration,
    /// One side of the split; the complement forms the other side.
    pub side_a: Vec<ClientId>,
}

impl NetSplit {
    /// Does this split sever the directed link `from → to` at time `at`?
    pub fn severs(&self, at: SimTime, from: ClientId, to: ClientId) -> bool {
        at >= self.start
            && at < self.end
            && (self.side_a.contains(&from) != self.side_a.contains(&to))
    }
}

/// Link behaviour of the simulated network.
#[derive(Clone, Debug)]
pub struct NetworkModel {
    /// Minimum one-way latency applied to every message.
    pub base_delay: Duration,
    /// Extra uniform jitter in [0, jitter].
    pub jitter: Duration,
    /// Per-message drop probability (0 = reliable, the paper's default
    /// assumption; raised for the message-loss robustness experiments).
    pub drop_prob: f64,
    /// RNG seed for delays/drops (reproducible network schedules).
    pub seed: u64,
    /// Scheduled partitions (empty = never partitioned).
    pub splits: Vec<NetSplit>,
}

impl NetworkModel {
    /// No delay, no loss (unit tests).
    pub fn ideal() -> Self {
        NetworkModel {
            base_delay: Duration::ZERO,
            jitter: Duration::ZERO,
            drop_prob: 0.0,
            seed: 0,
            splits: Vec::new(),
        }
    }

    /// LAN-like: small base latency with jitter (the paper's testbed).
    pub fn lan(seed: u64) -> Self {
        NetworkModel {
            base_delay: Duration::from_micros(200),
            jitter: Duration::from_millis(2),
            drop_prob: 0.0,
            seed,
            splits: Vec::new(),
        }
    }

    /// WAN-like: high base latency, heavy jitter, mild loss.  Pair with a
    /// protocol `timeout` comfortably above `base_delay + jitter` or every
    /// peer looks crashed.  Wall-clock runs at this scale are painful;
    /// under the virtual clock they cost milliseconds.
    pub fn wan(seed: u64) -> Self {
        NetworkModel {
            base_delay: Duration::from_millis(40),
            jitter: Duration::from_millis(120),
            drop_prob: 0.01,
            seed,
            splits: Vec::new(),
        }
    }

    /// Lossy variant for fault-injection tests.
    pub fn lossy(drop_prob: f64, seed: u64) -> Self {
        NetworkModel { drop_prob, ..NetworkModel::lan(seed) }
    }

    /// Attach a partition schedule.
    pub fn with_splits(mut self, splits: Vec<NetSplit>) -> Self {
        self.splits = splits;
        self
    }
}

struct Scheduled {
    due: Instant,
    seq: u64,
    to: usize,
    msg: Msg,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.due, self.seq).cmp(&(other.due, other.seq))
    }
}

struct HubShared {
    inboxes: Vec<Sender<Msg>>,
    queue: Mutex<BinaryHeap<Reverse<Scheduled>>>,
    cv: Condvar,
    shutdown: AtomicBool,
    model: NetworkModel,
    rng: Mutex<Rng>,
    seq: Mutex<u64>,
    blocked: Mutex<HashSet<(ClientId, ClientId)>>,
    /// Hub creation time: the reference point for `NetSplit` windows.
    epoch: Instant,
}

impl HubShared {
    fn deliver(&self, to: usize, msg: Msg) {
        // Receiver may be gone (crashed client dropped its endpoint) — the
        // crash model says sends to dead peers vanish silently.
        let _ = self.inboxes[to].send(msg);
    }
}

/// The simulated network; create once, then [`InProcHub::endpoint`] per
/// client. Dropping the hub stops the timer thread.
pub struct InProcHub {
    shared: Arc<HubShared>,
    timer: Option<JoinHandle<()>>,
    receivers: Mutex<Vec<Option<Receiver<Msg>>>>,
    n: usize,
}

impl InProcHub {
    pub fn new(n: usize, model: NetworkModel) -> Self {
        let mut inboxes = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = mpsc::channel();
            inboxes.push(tx);
            receivers.push(Some(rx));
        }
        let seed = model.seed;
        let shared = Arc::new(HubShared {
            inboxes,
            queue: Mutex::new(BinaryHeap::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            model,
            rng: Mutex::new(Rng::new(seed ^ 0x1E7_0000)),
            seq: Mutex::new(0),
            blocked: Mutex::new(HashSet::new()),
            epoch: Instant::now(),
        });
        let timer = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("inproc-timer".into())
                .spawn(move || timer_loop(&shared))
                .expect("spawn timer")
        };
        InProcHub { shared, timer: Some(timer), receivers: Mutex::new(receivers), n }
    }

    /// Claim the endpoint for client `id` (each id claimable once).
    pub fn endpoint(&self, id: ClientId) -> Endpoint {
        let rx = self.receivers.lock().unwrap()[id as usize]
            .take()
            .expect("endpoint already claimed");
        Endpoint { id, n: self.n, shared: Arc::clone(&self.shared), rx }
    }

    /// Block/unblock a directed link (failure injection: lost messages
    /// between a specific pair, e.g. to test CRT flag re-propagation).
    pub fn set_link_blocked(&self, from: ClientId, to: ClientId, blocked: bool) {
        let mut set = self.shared.blocked.lock().unwrap();
        if blocked {
            set.insert((from, to));
        } else {
            set.remove(&(from, to));
        }
    }
}

impl Drop for InProcHub {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        if let Some(t) = self.timer.take() {
            let _ = t.join();
        }
    }
}

fn timer_loop(shared: &HubShared) {
    let mut queue = shared.queue.lock().unwrap();
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let now = Instant::now();
        if let Some(Reverse(front)) = queue.peek() {
            if front.due <= now {
                let Reverse(item) = queue.pop().unwrap();
                // deliver outside the lock to avoid holding it during send
                drop(queue);
                shared.deliver(item.to, item.msg);
                queue = shared.queue.lock().unwrap();
            } else {
                let wait = front.due - now;
                let (q, _) = shared.cv.wait_timeout(queue, wait).unwrap();
                queue = q;
            }
        } else {
            queue = shared.cv.wait(queue).unwrap();
        }
    }
}

/// One client's handle onto the in-process network.
pub struct Endpoint {
    id: ClientId,
    n: usize,
    shared: Arc<HubShared>,
    rx: Receiver<Msg>,
}

impl Transport for Endpoint {
    fn id(&self) -> ClientId {
        self.id
    }

    fn peers(&self) -> Vec<ClientId> {
        (0..self.n as ClientId).filter(|&p| p != self.id).collect()
    }

    fn send(&self, to: ClientId, msg: &Msg) -> Result<()> {
        if self.shared.blocked.lock().unwrap().contains(&(self.id, to)) {
            return Ok(()); // injected link failure: message lost
        }
        let at = self.shared.epoch.elapsed();
        if self.shared.model.splits.iter().any(|sp| sp.severs(at, self.id, to)) {
            return Ok(()); // partitioned: message lost
        }
        // Exercise the wire format on every in-proc message.
        let decoded = Msg::decode(&msg.encode())?;
        let (delay, dropped) = {
            let mut rng = self.shared.rng.lock().unwrap();
            let m = &self.shared.model;
            let dropped = m.drop_prob > 0.0 && rng.f64() < m.drop_prob;
            let jitter = m.jitter.mul_f64(rng.f64());
            (m.base_delay + jitter, dropped)
        };
        if dropped {
            return Ok(());
        }
        if delay.is_zero() {
            self.shared.deliver(to as usize, decoded);
        } else {
            let seq = {
                let mut s = self.shared.seq.lock().unwrap();
                *s += 1;
                *s
            };
            self.shared.queue.lock().unwrap().push(Reverse(Scheduled {
                due: Instant::now() + delay,
                seq,
                to: to as usize,
                msg: decoded,
            }));
            self.shared.cv.notify_all();
        }
        Ok(())
    }

    fn recv_timeout(&self, timeout: Duration) -> Option<Msg> {
        match self.rx.recv_timeout(timeout) {
            Ok(m) => Some(m),
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => None,
        }
    }

    fn try_recv(&self) -> Option<Msg> {
        self.rx.try_recv().ok()
    }
}

/// Deterministic per-link state of the virtual network: an independent RNG
/// stream (seeded purely by `(model.seed, from, to)`) plus a message
/// counter.  Because no draw on one link depends on traffic of any other
/// link, delays and drops are identical across runs regardless of how the
/// client threads happened to interleave before the scheduler serialized
/// them.
struct LinkState {
    rng: Rng,
    seq: u64,
}

struct VirtualHubShared {
    n: usize,
    model: NetworkModel,
    clock: Arc<VirtualClock>,
    links: Mutex<BTreeMap<(ClientId, ClientId), LinkState>>,
    blocked: Mutex<HashSet<(ClientId, ClientId)>>,
}

impl VirtualHubShared {
    fn link_rng(&self, from: ClientId, to: ClientId) -> Rng {
        Rng::new(
            self.model.seed
                ^ 0x11AB_0000_0000
                ^ ((from as u64) << 32)
                ^ (to as u64).wrapping_add(1),
        )
    }
}

/// The virtual-time simulated network: deliveries are events on a shared
/// [`VirtualClock`] (token = client id), so a run never sleeps through its
/// own latency model.  Create once per deployment, then claim one
/// [`VirtualHub::endpoint`] per client.
pub struct VirtualHub {
    shared: Arc<VirtualHubShared>,
    claimed: Mutex<Vec<bool>>,
}

impl VirtualHub {
    /// `clock` must have been created with (at least) `n` tokens.
    pub fn new(n: usize, model: NetworkModel, clock: Arc<VirtualClock>) -> Self {
        VirtualHub {
            shared: Arc::new(VirtualHubShared {
                n,
                model,
                clock,
                links: Mutex::new(BTreeMap::new()),
                blocked: Mutex::new(HashSet::new()),
            }),
            claimed: Mutex::new(vec![false; n]),
        }
    }

    /// Claim the endpoint for client `id` (each id claimable once).
    pub fn endpoint(&self, id: ClientId) -> VirtualEndpoint {
        let mut claimed = self.claimed.lock().unwrap();
        assert!(
            !std::mem::replace(&mut claimed[id as usize], true),
            "endpoint {id} already claimed"
        );
        VirtualEndpoint { id, shared: Arc::clone(&self.shared) }
    }

    /// Block/unblock a directed link (failure injection), as on [`InProcHub`].
    pub fn set_link_blocked(&self, from: ClientId, to: ClientId, blocked: bool) {
        let mut set = self.shared.blocked.lock().unwrap();
        if blocked {
            set.insert((from, to));
        } else {
            set.remove(&(from, to));
        }
    }

    /// The clock this network schedules on.
    pub fn clock(&self) -> Arc<VirtualClock> {
        Arc::clone(&self.shared.clock)
    }
}

/// One client's handle onto the virtual network.  Its `recv` waits advance
/// logical time instead of blocking the OS thread past the next event.
pub struct VirtualEndpoint {
    id: ClientId,
    shared: Arc<VirtualHubShared>,
}

impl Transport for VirtualEndpoint {
    fn id(&self) -> ClientId {
        self.id
    }

    fn clock(&self) -> Clock {
        Clock::virtual_for(Arc::clone(&self.shared.clock), self.id as usize)
    }

    fn peers(&self) -> Vec<ClientId> {
        (0..self.shared.n as ClientId).filter(|&p| p != self.id).collect()
    }

    fn send(&self, to: ClientId, msg: &Msg) -> Result<()> {
        let sh = &self.shared;
        if sh.blocked.lock().unwrap().contains(&(self.id, to)) {
            return Ok(()); // injected link failure: message lost
        }
        let at = sh.clock.now();
        if sh.model.splits.iter().any(|sp| sp.severs(at, self.id, to)) {
            return Ok(()); // partitioned: message lost
        }
        let (delay, dropped, seq) = {
            let mut links = sh.links.lock().unwrap();
            let link = links
                .entry((self.id, to))
                .or_insert_with(|| LinkState { rng: sh.link_rng(self.id, to), seq: 0 });
            link.seq += 1;
            let m = &sh.model;
            let dropped = m.drop_prob > 0.0 && link.rng.f64() < m.drop_prob;
            let jitter = m.jitter.mul_f64(link.rng.f64());
            (m.base_delay + jitter, dropped, link.seq)
        };
        if dropped {
            return Ok(());
        }
        // The codec round-trip happens decode-side (recv_timeout), keeping
        // parity with the wall-clock hub's coverage of the wire format.
        sh.clock.post(to as usize, delay, (self.id, to, seq), msg.encode());
        Ok(())
    }

    fn recv_timeout(&self, timeout: Duration) -> Option<Msg> {
        let bytes = self.shared.clock.recv_deadline(self.id as usize, timeout)?;
        // The hub encoded these bytes itself; failure here is a codec bug
        // and must be loud, not a fake window timeout.
        Some(Msg::decode(&bytes).expect("virtual hub delivered an undecodable message"))
    }

    fn try_recv(&self) -> Option<Msg> {
        let bytes = self.shared.clock.try_recv(self.id as usize)?;
        Some(Msg::decode(&bytes).expect("virtual hub delivered an undecodable message"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::message::ModelUpdate;
    use crate::model::ParamVector;

    fn update(sender: ClientId, round: u32) -> Msg {
        Msg::Update(ModelUpdate {
            sender,
            round,
            terminate: false,
            weight: 1.0,
            params: ParamVector(vec![sender as f32, round as f32]),
        })
    }

    #[test]
    fn direct_delivery_no_delay() {
        let hub = InProcHub::new(3, NetworkModel::ideal());
        let a = hub.endpoint(0);
        let b = hub.endpoint(1);
        a.send(1, &update(0, 5)).unwrap();
        let got = b.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(got, update(0, 5));
    }

    #[test]
    fn broadcast_reaches_all_peers() {
        let hub = InProcHub::new(4, NetworkModel::ideal());
        let eps: Vec<Endpoint> = (0..4).map(|i| hub.endpoint(i)).collect();
        eps[2].broadcast(&update(2, 1)).unwrap();
        for (i, ep) in eps.iter().enumerate() {
            if i == 2 {
                assert!(ep.try_recv().is_none());
            } else {
                assert_eq!(ep.recv_timeout(Duration::from_secs(1)), Some(update(2, 1)));
            }
        }
    }

    #[test]
    fn delayed_delivery_respects_latency() {
        let model = NetworkModel {
            base_delay: Duration::from_millis(30),
            jitter: Duration::ZERO,
            drop_prob: 0.0,
            seed: 1,
            splits: Vec::new(),
        };
        let hub = InProcHub::new(2, model);
        let a = hub.endpoint(0);
        let b = hub.endpoint(1);
        let t0 = Instant::now();
        a.send(1, &update(0, 1)).unwrap();
        assert!(b.try_recv().is_none(), "arrived too early");
        let got = b.recv_timeout(Duration::from_secs(1));
        assert!(got.is_some());
        assert!(t0.elapsed() >= Duration::from_millis(25), "{:?}", t0.elapsed());
    }

    #[test]
    fn drops_lose_messages() {
        let hub = InProcHub::new(2, NetworkModel::lossy(1.0, 2)); // drop all
        let a = hub.endpoint(0);
        let b = hub.endpoint(1);
        for r in 0..10 {
            a.send(1, &update(0, r)).unwrap();
        }
        assert!(b.recv_timeout(Duration::from_millis(50)).is_none());
    }

    #[test]
    fn blocked_link_is_one_directional() {
        let hub = InProcHub::new(2, NetworkModel::ideal());
        let a = hub.endpoint(0);
        let b = hub.endpoint(1);
        hub.set_link_blocked(0, 1, true);
        a.send(1, &update(0, 1)).unwrap();
        assert!(b.recv_timeout(Duration::from_millis(50)).is_none());
        b.send(0, &update(1, 2)).unwrap();
        assert_eq!(a.recv_timeout(Duration::from_secs(1)), Some(update(1, 2)));
        hub.set_link_blocked(0, 1, false);
        a.send(1, &update(0, 3)).unwrap();
        assert_eq!(b.recv_timeout(Duration::from_secs(1)), Some(update(0, 3)));
    }

    #[test]
    fn send_to_dropped_endpoint_is_silent() {
        let hub = InProcHub::new(2, NetworkModel::ideal());
        let a = hub.endpoint(0);
        {
            let _b = hub.endpoint(1);
        } // b crashes
        assert!(a.send(1, &update(0, 1)).is_ok());
    }

    #[test]
    fn ordering_preserved_per_link_without_jitter() {
        let hub = InProcHub::new(2, NetworkModel::ideal());
        let a = hub.endpoint(0);
        let b = hub.endpoint(1);
        for r in 0..20 {
            a.send(1, &update(0, r)).unwrap();
        }
        for r in 0..20 {
            let got = b.recv_timeout(Duration::from_secs(1)).unwrap();
            match got {
                Msg::Update(u) => assert_eq!(u.round, r),
                _ => panic!("wrong kind"),
            }
        }
    }

    #[test]
    fn split_severs_only_cross_group_during_window() {
        let sp = NetSplit {
            start: Duration::from_millis(10),
            end: Duration::from_millis(20),
            side_a: vec![0, 1],
        };
        let in_window = Duration::from_millis(15);
        assert!(sp.severs(in_window, 0, 2));
        assert!(sp.severs(in_window, 2, 1), "severed in both directions");
        assert!(!sp.severs(in_window, 0, 1), "same side unaffected");
        assert!(!sp.severs(in_window, 2, 3), "same side unaffected");
        assert!(!sp.severs(Duration::from_millis(5), 0, 2), "before window");
        assert!(!sp.severs(Duration::from_millis(20), 0, 2), "end is exclusive");
    }

    #[test]
    fn wan_preset_is_heavier_than_lan() {
        let lan = NetworkModel::lan(1);
        let wan = NetworkModel::wan(1);
        assert!(wan.base_delay > lan.base_delay);
        assert!(wan.jitter > lan.jitter);
        assert!(wan.drop_prob > 0.0 && wan.drop_prob < 0.1);
    }

    #[test]
    fn virtual_hub_delivers_at_modeled_latency() {
        let model = NetworkModel {
            base_delay: Duration::from_millis(30),
            jitter: Duration::ZERO,
            drop_prob: 0.0,
            seed: 1,
            splits: Vec::new(),
        };
        let clock = VirtualClock::new(2);
        let hub = VirtualHub::new(2, model, Arc::clone(&clock));
        let a = hub.endpoint(0);
        let b = hub.endpoint(1);
        std::thread::scope(|scope| {
            let ca = a.clock();
            scope.spawn(move || {
                if let Clock::Virtual { clock, token } = &ca {
                    clock.attach(*token);
                    a.send(1, &update(0, 1)).unwrap();
                    clock.detach(*token);
                }
            });
            let cb = b.clock();
            scope.spawn(move || {
                if let Clock::Virtual { clock, token } = &cb {
                    clock.attach(*token);
                    let got = b.recv_timeout(Duration::from_secs(5));
                    assert_eq!(got, Some(update(0, 1)));
                    assert_eq!(cb.now(), Duration::from_millis(30), "exact logical latency");
                    clock.detach(*token);
                }
            });
        });
    }

    #[test]
    fn virtual_hub_recv_times_out_without_real_waiting() {
        let clock = VirtualClock::new(1);
        let hub = VirtualHub::new(1, NetworkModel::ideal(), Arc::clone(&clock));
        let a = hub.endpoint(0);
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            scope.spawn(|| {
                clock.attach(0);
                assert!(a.recv_timeout(Duration::from_secs(30)).is_none());
                clock.detach(0);
            });
        });
        assert_eq!(clock.now(), Duration::from_secs(30));
        assert!(t0.elapsed() < Duration::from_secs(2), "virtual wait burned wall time");
    }
}
