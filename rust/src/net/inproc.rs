//! In-process transport: per-client channels behind a seeded network model
//! (per-link latency with optional asymmetry, jitter, bandwidth caps,
//! independent and Gilbert–Elliott burst drops, time-windowed partitions,
//! and per-link blocks for failure injection).  Every message round-trips
//! through the binary codec so tests exercise the real wire format.
//!
//! Two hubs share the [`NetworkModel`] (the scenario matrix of DESIGN.md
//! §3.4, exposed as named presets via [`NetPreset`]):
//!
//! * [`InProcHub`] — wall-clock: a single timer thread owns delayed
//!   deliveries, keeping the network deterministic under a fixed seed
//!   (modulo OS scheduling of the client threads themselves).
//! * [`VirtualHub`] — logical-clock: deliveries become events on a shared
//!   [`VirtualClock`], delays sampled from *per-link* RNG streams and tie
//!   broken by `(due, from, to, seq)`, so the entire network schedule is a
//!   pure function of the seed — byte-identical across runs.
//!
//! # VirtualHub delivery guarantees
//!
//! * **Latency is exact.**  A message sampled with one-way delay `d` at
//!   logical time `t` is visible to the receiver at exactly `t + d` — no
//!   OS jitter is added and none can be observed, because logical time only
//!   advances between thread turns (`util::time` DESIGN note).
//! * **Per-link FIFO under equal delays.**  Deliveries due at the same
//!   instant fire in `(from, to, seq)` key order, so two messages on one
//!   link with equal sampled delays arrive in send order.  With jitter the
//!   model can reorder across *different* sends — exactly the asynchronous
//!   network the paper assumes.
//! * **Schedule is a pure function of `(model, seed)`.**  Every draw (drop,
//!   burst-state step, jitter) comes from an RNG stream owned by the
//!   directed link and seeded only by `(model.seed, from, to)`; no draw
//!   depends on cross-link traffic or thread interleaving.
//! * **Crash semantics.**  Sends to a detached (finished/crashed) client
//!   are swallowed silently, matching the paper's benign crash model.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::message::{ClientId, Msg};
use super::overlay::Overlay;
use super::topology::Topology;
use super::Transport;
use crate::metrics::NetStats;
use crate::util::time::{Clock, SimTime, VirtualClock};
use crate::util::Rng;

/// Shared traffic counters (one set per hub, lock-free): every endpoint
/// send bumps these, [`InProcHub::net_stats`] / [`VirtualHub::net_stats`]
/// snapshot them into the [`NetStats`] the simulator reports.  Counting
/// never touches the RNG streams or the event schedule, so it cannot
/// perturb determinism.
#[derive(Default)]
struct NetCounters {
    sent: AtomicU64,
    delivered: AtomicU64,
    bytes: AtomicU64,
    bytes_saved: AtomicU64,
    delta_hits: AtomicU64,
    delta_full: AtomicU64,
}

impl NetCounters {
    fn count_send(&self, msg: &Msg, bytes: usize) {
        self.sent.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        // Delta-codec accounting (DESIGN.md §13): what the sparse/flag
        // encodings kept off the wire versus the dense `Msg::Update` they
        // replace.  Dense traffic returns `None` — all three counters stay
        // untouched, so `--codec dense` reports exact zeros.
        if let Some((saved, was_full)) = super::delta::codec_accounting(msg, bytes) {
            self.bytes_saved.fetch_add(saved, Ordering::Relaxed);
            if was_full {
                self.delta_full.fetch_add(1, Ordering::Relaxed);
            } else {
                self.delta_hits.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    fn count_delivered(&self) {
        self.delivered.fetch_add(1, Ordering::Relaxed);
    }

    /// Drops are derived (`sent − delivered`) rather than counted, so the
    /// invariant cannot drift as loss paths are added.
    fn snapshot(&self) -> NetStats {
        let sent = self.sent.load(Ordering::Relaxed);
        let delivered = self.delivered.load(Ordering::Relaxed);
        NetStats {
            msgs_sent: sent,
            msgs_delivered: delivered,
            msgs_dropped: sent.saturating_sub(delivered),
            bytes_sent: self.bytes.load(Ordering::Relaxed),
            // Severed-edge accounting is schedule-side, not hub-side:
            // `sim::run` fills it from the validated splits + overlay.
            edges_severed: 0,
            bytes_saved: self.bytes_saved.load(Ordering::Relaxed),
            delta_hits: self.delta_hits.load(Ordering::Relaxed),
            delta_full: self.delta_full.load(Ordering::Relaxed),
        }
    }
}

/// A time-windowed network partition: while `start <= t < end`, messages
/// between `side_a` and everyone else are silently lost in both directions.
/// Times are measured on the hub's clock (virtual time under [`VirtualHub`],
/// time since hub creation under [`InProcHub`]), so partition-and-heal
/// scenarios are reproducible without mid-run intervention.
#[derive(Clone, Debug)]
pub struct NetSplit {
    pub start: Duration,
    pub end: Duration,
    /// One side of the split; the complement forms the other side.
    pub side_a: Vec<ClientId>,
}

impl NetSplit {
    /// Does this split sever the directed link `from → to` at time `at`?
    pub fn severs(&self, at: SimTime, from: ClientId, to: ClientId) -> bool {
        at >= self.start
            && at < self.end
            && (self.side_a.contains(&from) != self.side_a.contains(&to))
    }
}

/// Correlated loss bursts: a two-state Gilbert–Elliott chain per directed
/// link, stepped once per message.  In the *good* state the model's base
/// `drop_prob` applies; in the *bad* state `drop_bad` does.  Expected burst
/// length is `1 / p_exit` messages, so e.g. `p_exit = 0.25` loses messages
/// in runs of ~4 — the failure mode that defeats naive "one retry"
/// reasoning and that independent drops cannot reproduce.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GilbertElliott {
    /// P(good → bad) per message.
    pub p_enter: f64,
    /// P(bad → good) per message.
    pub p_exit: f64,
    /// Drop probability while the link is in the bad state.
    pub drop_bad: f64,
}

/// Link behaviour of the simulated network (the scenario matrix —
/// DESIGN.md §3.4).  One-way delay of a message of `b` encoded bytes on
/// the directed link `from → to`:
///
/// ```text
/// delay = base_delay × asym(from, to) + U[0, jitter] + b / bandwidth
/// ```
///
/// where `asym` is a static per-link multiplier in
/// `[1 − asymmetry, 1 + asymmetry]` derived purely from
/// `(seed, from, to)`, and the bandwidth term is zero when `bandwidth`
/// is `None`.  Drops come from the base `drop_prob` or, when `burst` is
/// set, from a per-link [`GilbertElliott`] chain.
///
/// ```
/// use std::time::Duration;
/// use dfl::net::NetworkModel;
///
/// // A 1 MB/s link serializes a 500 kB model update for 500 ms.
/// let m = NetworkModel::ideal().with_bandwidth(1_000_000);
/// assert_eq!(m.transfer_time(500_000), Duration::from_millis(500));
/// assert_eq!(m.max_one_way(500_000), Duration::from_millis(500));
/// ```
#[derive(Clone, Debug)]
pub struct NetworkModel {
    /// Minimum one-way latency applied to every message.
    pub base_delay: Duration,
    /// Extra uniform jitter in [0, jitter].
    pub jitter: Duration,
    /// Per-message drop probability (0 = reliable, the paper's default
    /// assumption; raised for the message-loss robustness experiments).
    pub drop_prob: f64,
    /// RNG seed for delays/drops (reproducible network schedules).
    pub seed: u64,
    /// Scheduled partitions (empty = never partitioned).
    pub splits: Vec<NetSplit>,
    /// Static per-direction latency spread in [0, 1): each directed link
    /// gets a persistent `base_delay` multiplier in
    /// `[1 − asymmetry, 1 + asymmetry]`, so `A → B` can be reliably fast
    /// while `B → A` is reliably slow (0 = symmetric).
    pub asymmetry: f64,
    /// Link bandwidth in bytes/second (`None` = infinite): adds
    /// `encoded size / bandwidth` of serialization delay per message, so
    /// large model updates cost more than tiny control messages.
    pub bandwidth: Option<u64>,
    /// Correlated loss bursts (`None` = independent drops only).
    pub burst: Option<GilbertElliott>,
}

impl NetworkModel {
    /// No delay, no loss (unit tests).
    pub fn ideal() -> Self {
        NetworkModel {
            base_delay: Duration::ZERO,
            jitter: Duration::ZERO,
            drop_prob: 0.0,
            seed: 0,
            splits: Vec::new(),
            asymmetry: 0.0,
            bandwidth: None,
            burst: None,
        }
    }

    /// LAN-like: small base latency with jitter (the paper's testbed).
    pub fn lan(seed: u64) -> Self {
        NetworkModel {
            base_delay: Duration::from_micros(200),
            jitter: Duration::from_millis(2),
            seed,
            ..NetworkModel::ideal()
        }
    }

    /// WAN-like: high base latency, heavy jitter, mild loss.  Pair with a
    /// protocol `timeout` comfortably above [`Self::max_one_way`] or every
    /// peer looks crashed.  Wall-clock runs at this scale are painful;
    /// under the virtual clock they cost milliseconds.
    pub fn wan(seed: u64) -> Self {
        NetworkModel {
            base_delay: Duration::from_millis(40),
            jitter: Duration::from_millis(120),
            drop_prob: 0.01,
            seed,
            ..NetworkModel::ideal()
        }
    }

    /// Asymmetric-path preset: WAN-grade latency whose per-direction
    /// multipliers spread ±80% (each direction lands in [0.2, 1.8]× — up
    /// to 9× between a link's two directions), over a 2 MiB/s bandwidth
    /// cap.  The regime where "my broadcast arrived, the reply didn't
    /// make the window" happens.
    pub fn asym(seed: u64) -> Self {
        NetworkModel {
            base_delay: Duration::from_millis(25),
            jitter: Duration::from_millis(30),
            asymmetry: 0.8,
            bandwidth: Some(2 << 20),
            seed,
            ..NetworkModel::ideal()
        }
    }

    /// Burst-loss preset: LAN-grade latency with a Gilbert–Elliott chain
    /// (≈5% of messages enter a bad state that drops 60% and lasts ~4
    /// messages) plus a light independent floor.  Stresses CRT flag
    /// re-propagation and timeout crash detection under correlated loss.
    pub fn lossy_burst(seed: u64) -> Self {
        NetworkModel {
            drop_prob: 0.005,
            burst: Some(GilbertElliott { p_enter: 0.05, p_exit: 0.25, drop_bad: 0.6 }),
            ..NetworkModel::lan(seed)
        }
    }

    /// Lossy variant for fault-injection tests.
    pub fn lossy(drop_prob: f64, seed: u64) -> Self {
        NetworkModel { drop_prob, ..NetworkModel::lan(seed) }
    }

    /// Look up a named preset (the CLI's `--net` values).
    ///
    /// ```
    /// use dfl::net::NetworkModel;
    ///
    /// assert!(NetworkModel::preset("lossy-burst", 7).unwrap().burst.is_some());
    /// assert!(NetworkModel::preset("asym", 7).unwrap().asymmetry > 0.0);
    /// assert!(NetworkModel::preset("dial-up", 7).is_err());
    /// ```
    pub fn preset(name: &str, seed: u64) -> Result<Self> {
        Ok(NetPreset::parse(name)?.model(seed))
    }

    /// Attach a partition schedule.
    pub fn with_splits(mut self, splits: Vec<NetSplit>) -> Self {
        self.splits = splits;
        self
    }

    /// Cap link bandwidth (bytes/second).
    pub fn with_bandwidth(mut self, bytes_per_sec: u64) -> Self {
        self.bandwidth = Some(bytes_per_sec);
        self
    }

    /// Attach a correlated-loss chain.
    pub fn with_burst(mut self, burst: GilbertElliott) -> Self {
        self.burst = Some(burst);
        self
    }

    /// Serialization delay of `payload_bytes` under the bandwidth cap
    /// (zero when uncapped).
    pub fn transfer_time(&self, payload_bytes: usize) -> Duration {
        match self.bandwidth {
            Some(rate) if rate > 0 => {
                Duration::from_secs_f64(payload_bytes as f64 / rate as f64)
            }
            _ => Duration::ZERO,
        }
    }

    /// Worst-case one-way delay of a `payload_bytes` message on the
    /// slowest direction of the slowest link: the latency ceiling a
    /// protocol wait window must clear to avoid false crash suspicion.
    pub fn max_one_way(&self, payload_bytes: usize) -> Duration {
        self.base_delay.mul_f64(1.0 + self.asymmetry.clamp(0.0, MAX_ASYMMETRY))
            + self.jitter
            + self.transfer_time(payload_bytes)
    }

    /// The guaranteed minimum one-way delay of *any* message under this
    /// model: the base delay scaled by the fastest possible direction
    /// multiplier.  Jitter, bandwidth serialization, and the asymmetric
    /// spread only ever *add* delay, so every per-link delay draw is ≥
    /// this floor — which makes it the conservative lookahead bound
    /// the parallel executor's null-message windows rely on
    /// (`sim::exec::run_parallel`, DESIGN.md §12).  Zero exactly when
    /// `base_delay` is zero (e.g. the `ideal` preset), in which case
    /// conservative parallel simulation admits no concurrency at all.
    pub fn latency_floor(&self) -> Duration {
        self.base_delay.mul_f64(1.0 - self.asymmetry.clamp(0.0, MAX_ASYMMETRY))
    }

    /// The static delay multiplier of the directed link `from → to`: a
    /// pure function of `(seed, from, to)`, uniform in
    /// `[1 − asymmetry, 1 + asymmetry]`.
    fn asym_mult(&self, from: ClientId, to: ClientId) -> f64 {
        if self.asymmetry <= 0.0 {
            return 1.0;
        }
        let a = self.asymmetry.min(MAX_ASYMMETRY);
        let mut r = Rng::new(link_seed(self.seed, ASYM_SALT, from, to));
        1.0 - a + 2.0 * a * r.f64()
    }
}

/// Asymmetry is clamped below 1 so no direction's multiplier reaches 0.
const MAX_ASYMMETRY: f64 = 0.95;

/// Salt separating the static delay-multiplier stream from the per-message
/// drop/jitter stream of the same link.
const ASYM_SALT: u64 = 0xA5F3_0000_0000;
/// Salt of the per-message drop/jitter/burst stream.
const LINK_SALT: u64 = 0x11AB_0000_0000;

/// Mix a directed link's identity into a stream seed: every per-link RNG
/// stream is a pure function of `(model.seed, salt, from, to)`.
fn link_seed(seed: u64, salt: u64, from: ClientId, to: ClientId) -> u64 {
    seed ^ salt ^ ((from as u64) << 32) ^ (to as u64).wrapping_add(1)
}

/// The named rows of the network-scenario matrix (DESIGN.md §3.4): what
/// `dfl sim --net`, `dfl reproduce --net`, and the `scenarios` experiment
/// driver sweep over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetPreset {
    /// Zero latency, zero loss.
    Ideal,
    /// The paper's testbed: sub-ms base latency, small jitter.
    Lan,
    /// High latency, heavy jitter, 1% independent loss.
    Wan,
    /// Asymmetric per-direction latency plus a bandwidth cap.
    Asym,
    /// Gilbert–Elliott correlated loss bursts on LAN latency.
    LossyBurst,
}

impl NetPreset {
    /// Every preset, in sweep order.
    pub const ALL: [NetPreset; 5] = [
        NetPreset::Ideal,
        NetPreset::Lan,
        NetPreset::Wan,
        NetPreset::Asym,
        NetPreset::LossyBurst,
    ];

    /// The CLI spelling.
    pub fn name(self) -> &'static str {
        match self {
            NetPreset::Ideal => "ideal",
            NetPreset::Lan => "lan",
            NetPreset::Wan => "wan",
            NetPreset::Asym => "asym",
            NetPreset::LossyBurst => "lossy-burst",
        }
    }

    /// Parse a CLI spelling.
    pub fn parse(name: &str) -> Result<NetPreset> {
        NetPreset::ALL
            .into_iter()
            .find(|p| p.name() == name)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown network preset {name:?} (want ideal|lan|wan|asym|lossy-burst)"
                )
            })
    }

    /// Instantiate the preset's [`NetworkModel`] with `seed`.
    pub fn model(self, seed: u64) -> NetworkModel {
        match self {
            NetPreset::Ideal => NetworkModel { seed, ..NetworkModel::ideal() },
            NetPreset::Lan => NetworkModel::lan(seed),
            NetPreset::Wan => NetworkModel::wan(seed),
            NetPreset::Asym => NetworkModel::asym(seed),
            NetPreset::LossyBurst => NetworkModel::lossy_burst(seed),
        }
    }
}

/// Deterministic per-directed-link state shared by both hubs: an
/// independent RNG stream (seeded purely by `(model.seed, from, to)`), a
/// message counter, the static asymmetric delay multiplier, and the
/// Gilbert–Elliott burst-chain state.  Because no draw on one link depends
/// on traffic of any other link, delays and drops are identical across
/// runs regardless of how the client threads happened to interleave before
/// a scheduler (virtual) or the OS (wall-clock) serialized them.
struct LinkState {
    rng: Rng,
    seq: u64,
    /// Static per-direction latency multiplier (1.0 = symmetric).
    delay_mult: f64,
    /// Gilbert–Elliott chain position: currently in the bad (bursty) state?
    bad: bool,
}

impl LinkState {
    fn new(model: &NetworkModel, from: ClientId, to: ClientId) -> LinkState {
        LinkState {
            rng: Rng::new(link_seed(model.seed, LINK_SALT, from, to)),
            seq: 0,
            delay_mult: model.asym_mult(from, to),
            bad: false,
        }
    }

    /// Advance the link one message: step the burst chain, sample drop and
    /// jitter.  Returns `None` if the message is dropped, otherwise the
    /// one-way delay plus the per-link sequence number (unique and
    /// reproducible — dropped messages consume a number too, keeping the
    /// stream independent of delivery outcomes downstream).
    fn sample(&mut self, m: &NetworkModel, payload_bytes: usize) -> Option<(Duration, u64)> {
        self.seq += 1;
        if let Some(ge) = m.burst {
            let u = self.rng.f64();
            self.bad = if self.bad { u >= ge.p_exit } else { u < ge.p_enter };
        }
        let drop_prob = match (self.bad, m.burst) {
            (true, Some(ge)) => ge.drop_bad,
            _ => m.drop_prob,
        };
        let dropped = drop_prob > 0.0 && self.rng.f64() < drop_prob;
        let jitter = m.jitter.mul_f64(self.rng.f64());
        if dropped {
            return None;
        }
        let delay =
            m.base_delay.mul_f64(self.delay_mult) + jitter + m.transfer_time(payload_bytes);
        Some((delay, self.seq))
    }
}

/// Look up (or lazily create) the link `from → to` and sample one message.
fn sample_link(
    links: &Mutex<BTreeMap<(ClientId, ClientId), LinkState>>,
    model: &NetworkModel,
    from: ClientId,
    to: ClientId,
    payload_bytes: usize,
) -> Option<(Duration, u64)> {
    let mut links = links.lock().unwrap();
    links
        .entry((from, to))
        .or_insert_with(|| LinkState::new(model, from, to))
        .sample(model, payload_bytes)
}

struct Scheduled {
    due: Instant,
    seq: u64,
    to: usize,
    msg: Msg,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.due, self.seq).cmp(&(other.due, other.seq))
    }
}

struct HubShared {
    inboxes: Vec<Sender<Msg>>,
    queue: Mutex<BinaryHeap<Reverse<Scheduled>>>,
    cv: Condvar,
    shutdown: AtomicBool,
    model: NetworkModel,
    links: Mutex<BTreeMap<(ClientId, ClientId), LinkState>>,
    /// Global tie-break counter for the timer queue (per-link seqs are not
    /// globally unique).
    seq: Mutex<u64>,
    blocked: Mutex<BTreeSet<(ClientId, ClientId)>>,
    /// Hub creation time: the reference point for `NetSplit` windows and
    /// the overlay's graph-fault schedule.
    epoch: Instant,
    /// Peer overlay: which peers each endpoint's broadcasts reach —
    /// time-aware when a graph-fault schedule is attached.
    overlay: Arc<Overlay>,
    stats: NetCounters,
}

impl HubShared {
    fn deliver(&self, to: usize, msg: Msg) {
        // Receiver may be gone (crashed client dropped its endpoint) — the
        // crash model says sends to dead peers vanish silently.
        let _ = self.inboxes[to].send(msg);
    }
}

/// The simulated network; create once, then [`InProcHub::endpoint`] per
/// client. Dropping the hub stops the timer thread.
pub struct InProcHub {
    shared: Arc<HubShared>,
    timer: Option<JoinHandle<()>>,
    receivers: Mutex<Vec<Option<Receiver<Msg>>>>,
    n: usize,
}

impl InProcHub {
    /// A full-mesh hub (the pre-topology behaviour).
    pub fn new(n: usize, model: NetworkModel) -> Self {
        Self::with_topology(n, model, Arc::new(Topology::full(n)))
    }

    /// A hub whose broadcasts follow `topology` (each endpoint's
    /// [`Transport::neighbors`] is its overlay neighborhood).  Direct
    /// `send` to any peer stays possible — the overlay scopes
    /// *dissemination*, it is not a reachability firewall.
    pub fn with_topology(n: usize, model: NetworkModel, topology: Arc<Topology>) -> Self {
        Self::with_overlay(n, model, Arc::new(Overlay::immutable(topology)))
    }

    /// A hub on a (possibly mutable) [`Overlay`] — the graph-fault path:
    /// neighbors are read at send time, so cuts, churn, and repairs take
    /// effect mid-run.
    pub fn with_overlay(n: usize, model: NetworkModel, overlay: Arc<Overlay>) -> Self {
        assert_eq!(overlay.n(), n, "overlay built for a different deployment size");
        let mut inboxes = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = mpsc::channel();
            inboxes.push(tx);
            receivers.push(Some(rx));
        }
        let shared = Arc::new(HubShared {
            inboxes,
            queue: Mutex::new(BinaryHeap::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            model,
            links: Mutex::new(BTreeMap::new()),
            seq: Mutex::new(0),
            blocked: Mutex::new(BTreeSet::new()),
            // dfl-lint: allow(wall-clock) — real-time InProcHub: this hub IS the wall-clock regime (DESIGN.md §3.3); the virtual path uses VirtualHub below
            epoch: Instant::now(),
            overlay,
            stats: NetCounters::default(),
        });
        let timer = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("inproc-timer".into())
                .spawn(move || timer_loop(&shared))
                .expect("spawn timer")
        };
        InProcHub { shared, timer: Some(timer), receivers: Mutex::new(receivers), n }
    }

    /// Claim the endpoint for client `id` (each id claimable once).
    pub fn endpoint(&self, id: ClientId) -> Endpoint {
        let rx = self.receivers.lock().unwrap()[id as usize]
            .take()
            .expect("endpoint already claimed");
        Endpoint { id, n: self.n, shared: Arc::clone(&self.shared), rx }
    }

    /// Block/unblock a directed link (failure injection: lost messages
    /// between a specific pair, e.g. to test CRT flag re-propagation).
    pub fn set_link_blocked(&self, from: ClientId, to: ClientId, blocked: bool) {
        let mut set = self.shared.blocked.lock().unwrap();
        if blocked {
            set.insert((from, to));
        } else {
            set.remove(&(from, to));
        }
    }

    /// Snapshot the hub's traffic counters.
    pub fn net_stats(&self) -> NetStats {
        self.shared.stats.snapshot()
    }
}

impl Drop for InProcHub {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        if let Some(t) = self.timer.take() {
            let _ = t.join();
        }
    }
}

fn timer_loop(shared: &HubShared) {
    let mut queue = shared.queue.lock().unwrap();
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        // dfl-lint: allow(wall-clock) — real-time delivery timer thread: latencies here are meant to elapse for real
        let now = Instant::now();
        if let Some(Reverse(front)) = queue.peek() {
            if front.due <= now {
                let Reverse(item) = queue.pop().unwrap();
                // deliver outside the lock to avoid holding it during send
                drop(queue);
                shared.deliver(item.to, item.msg);
                queue = shared.queue.lock().unwrap();
            } else {
                let wait = front.due - now;
                let (q, _) = shared.cv.wait_timeout(queue, wait).unwrap();
                queue = q;
            }
        } else {
            queue = shared.cv.wait(queue).unwrap();
        }
    }
}

/// One client's handle onto the in-process network.
pub struct Endpoint {
    id: ClientId,
    n: usize,
    shared: Arc<HubShared>,
    rx: Receiver<Msg>,
}

impl Transport for Endpoint {
    fn id(&self) -> ClientId {
        self.id
    }

    fn peers(&self) -> Vec<ClientId> {
        (0..self.n as ClientId).filter(|&p| p != self.id).collect()
    }

    fn n_peers(&self) -> usize {
        self.n.saturating_sub(1)
    }

    fn neighbors(&self) -> Vec<ClientId> {
        self.shared.overlay.neighbors(self.shared.epoch.elapsed(), self.id)
    }

    fn topology_generation(&self) -> u64 {
        self.shared.overlay.generation(self.shared.epoch.elapsed())
    }

    fn topology_is_dynamic(&self) -> bool {
        self.shared.overlay.is_dynamic()
    }

    fn send(&self, to: ClientId, msg: &Msg) -> Result<()> {
        // Exercise the wire format on every in-proc message (encoding is
        // pure, so doing it before the loss checks only feeds the traffic
        // counters — the schedule is untouched).
        let wire = msg.encode();
        self.shared.stats.count_send(msg, wire.len());
        if self.shared.blocked.lock().unwrap().contains(&(self.id, to)) {
            return Ok(()); // injected link failure: message lost
        }
        let at = self.shared.epoch.elapsed();
        if self.shared.model.splits.iter().any(|sp| sp.severs(at, self.id, to)) {
            return Ok(()); // partitioned: message lost
        }
        let decoded = Msg::decode(&wire)?;
        let Some((delay, _)) =
            sample_link(&self.shared.links, &self.shared.model, self.id, to, wire.len())
        else {
            return Ok(()); // dropped (independent or burst loss)
        };
        self.shared.stats.count_delivered();
        if delay.is_zero() {
            self.shared.deliver(to as usize, decoded);
        } else {
            let seq = {
                let mut s = self.shared.seq.lock().unwrap();
                *s += 1;
                *s
            };
            self.shared.queue.lock().unwrap().push(Reverse(Scheduled {
                // dfl-lint: allow(wall-clock) — real-time hub schedules deliveries on the actual clock by design
                due: Instant::now() + delay,
                seq,
                to: to as usize,
                msg: decoded,
            }));
            self.shared.cv.notify_all();
        }
        Ok(())
    }

    fn recv_timeout(&self, timeout: Duration) -> Option<Msg> {
        match self.rx.recv_timeout(timeout) {
            Ok(m) => Some(m),
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => None,
        }
    }

    fn try_recv(&self) -> Option<Msg> {
        self.rx.try_recv().ok()
    }
}

/// How a virtual hub maps client ids onto virtual clocks.  The classic
/// executors drive every client on one shared clock; the sharded
/// parallel executor (`sim::exec::run_parallel`, DESIGN.md §12) gives
/// each shard its own clock and routes cross-shard deliveries as
/// absolute-time posts on the destination's clock.
enum ClockBinding {
    /// Every client on one shared clock.
    Single(Arc<VirtualClock>),
    /// One clock per shard; `shard_of[id]` is each client's home shard.
    Sharded { clocks: Vec<Arc<VirtualClock>>, shard_of: Vec<usize> },
}

impl ClockBinding {
    /// The clock that owns client `id`'s mailbox and timers.
    fn clock_of(&self, id: ClientId) -> &Arc<VirtualClock> {
        match self {
            ClockBinding::Single(c) => c,
            ClockBinding::Sharded { clocks, shard_of } => &clocks[shard_of[id as usize]],
        }
    }

    /// "Now" as client `id` observes it: its own (shard's) clock.
    fn now_for(&self, id: ClientId) -> SimTime {
        self.clock_of(id).now()
    }

    /// Deliver `wire` to `to` at `at + delay`, where `at` is the sending
    /// client's current instant (frozen while the sender holds its
    /// scheduler turn, so relative and absolute posting agree).
    fn post(
        &self,
        from: ClientId,
        to: ClientId,
        at: SimTime,
        delay: Duration,
        key: (ClientId, ClientId, u64),
        wire: Arc<[u8]>,
    ) {
        match self {
            ClockBinding::Single(c) => c.post(to as usize, delay, key, wire),
            ClockBinding::Sharded { clocks, shard_of } => {
                let (fs, ts) = (shard_of[from as usize], shard_of[to as usize]);
                if fs == ts {
                    clocks[fs].post(to as usize, delay, key, wire);
                } else {
                    // Absolute due on the destination shard's clock.  The
                    // conservative window protocol guarantees the due
                    // instant sits at or beyond the destination's current
                    // horizon, because `delay` is ≥ the model's
                    // [`NetworkModel::latency_floor`] (DESIGN.md §12).
                    clocks[ts].post_at(to as usize, at + delay, key, wire);
                }
            }
        }
    }
}

struct VirtualHubShared {
    n: usize,
    model: NetworkModel,
    clock: ClockBinding,
    links: Mutex<BTreeMap<(ClientId, ClientId), LinkState>>,
    blocked: Mutex<BTreeSet<(ClientId, ClientId)>>,
    /// Peer overlay: which peers each endpoint's broadcasts reach —
    /// time-aware (on the shared virtual clock) when a graph-fault
    /// schedule is attached.
    overlay: Arc<Overlay>,
    stats: NetCounters,
}

/// The virtual-time simulated network: deliveries are events on a shared
/// [`VirtualClock`] (token = client id), so a run never sleeps through its
/// own latency model.  Create once per deployment, then claim one
/// [`VirtualHub::endpoint`] per client.
pub struct VirtualHub {
    shared: Arc<VirtualHubShared>,
    claimed: Mutex<Vec<bool>>,
}

impl VirtualHub {
    /// `clock` must have been created with (at least) `n` tokens.
    /// Full-mesh overlay (the pre-topology behaviour).
    pub fn new(n: usize, model: NetworkModel, clock: Arc<VirtualClock>) -> Self {
        Self::with_topology(n, model, clock, Arc::new(Topology::full(n)))
    }

    /// A virtual hub whose broadcasts follow `topology` (see
    /// [`InProcHub::with_topology`]).
    pub fn with_topology(
        n: usize,
        model: NetworkModel,
        clock: Arc<VirtualClock>,
        topology: Arc<Topology>,
    ) -> Self {
        Self::with_overlay(n, model, clock, Arc::new(Overlay::immutable(topology)))
    }

    /// A virtual hub on a (possibly mutable) [`Overlay`] — the
    /// graph-fault path (see [`InProcHub::with_overlay`]).
    pub fn with_overlay(
        n: usize,
        model: NetworkModel,
        clock: Arc<VirtualClock>,
        overlay: Arc<Overlay>,
    ) -> Self {
        assert_eq!(overlay.n(), n, "overlay built for a different deployment size");
        Self::with_binding(n, model, ClockBinding::Single(clock), overlay)
    }

    /// A virtual hub over per-shard clocks — the parallel executor's
    /// network (`sim::exec::run_parallel`, DESIGN.md §12).  `shard_of`
    /// maps every client id to its home shard; `clocks[s]` must have been
    /// built with [`VirtualClock::with_members`] over exactly the clients
    /// with `shard_of[id] == s`.  Cross-shard sends land as absolute-time
    /// posts on the destination shard's clock.
    pub fn with_sharded(
        n: usize,
        model: NetworkModel,
        clocks: Vec<Arc<VirtualClock>>,
        shard_of: Vec<usize>,
        overlay: Arc<Overlay>,
    ) -> Self {
        assert_eq!(shard_of.len(), n, "shard map built for a different deployment size");
        assert!(
            shard_of.iter().all(|&s| s < clocks.len()),
            "shard map points past the clock list"
        );
        Self::with_binding(n, model, ClockBinding::Sharded { clocks, shard_of }, overlay)
    }

    fn with_binding(
        n: usize,
        model: NetworkModel,
        clock: ClockBinding,
        overlay: Arc<Overlay>,
    ) -> Self {
        assert_eq!(overlay.n(), n, "overlay built for a different deployment size");
        VirtualHub {
            shared: Arc::new(VirtualHubShared {
                n,
                model,
                clock,
                links: Mutex::new(BTreeMap::new()),
                blocked: Mutex::new(BTreeSet::new()),
                overlay,
                stats: NetCounters::default(),
            }),
            claimed: Mutex::new(vec![false; n]),
        }
    }

    /// Claim the endpoint for client `id` (each id claimable once).
    pub fn endpoint(&self, id: ClientId) -> VirtualEndpoint {
        let mut claimed = self.claimed.lock().unwrap();
        assert!(
            !std::mem::replace(&mut claimed[id as usize], true),
            "endpoint {id} already claimed"
        );
        VirtualEndpoint { id, shared: Arc::clone(&self.shared) }
    }

    /// Block/unblock a directed link (failure injection), as on [`InProcHub`].
    pub fn set_link_blocked(&self, from: ClientId, to: ClientId, blocked: bool) {
        let mut set = self.shared.blocked.lock().unwrap();
        if blocked {
            set.insert((from, to));
        } else {
            set.remove(&(from, to));
        }
    }

    /// The clock this network schedules on.  Panics on a sharded hub,
    /// which has no single clock — the parallel executor owns the shard
    /// clocks it passed to [`VirtualHub::with_sharded`].
    pub fn clock(&self) -> Arc<VirtualClock> {
        match &self.shared.clock {
            ClockBinding::Single(c) => Arc::clone(c),
            ClockBinding::Sharded { .. } => {
                panic!("sharded hub has no single clock (see VirtualHub::with_sharded)")
            }
        }
    }

    /// Snapshot the hub's traffic counters.
    pub fn net_stats(&self) -> NetStats {
        self.shared.stats.snapshot()
    }
}

/// One client's handle onto the virtual network.  Its `recv` waits advance
/// logical time instead of blocking the OS thread past the next event.
pub struct VirtualEndpoint {
    id: ClientId,
    shared: Arc<VirtualHubShared>,
}

/// Decode bytes the virtual hub delivered (used by both the blocking
/// receive path below and the event executor, which pops the same
/// mailboxes through the clock's driver API — one decode contract for
/// both executors).  The hub encoded these bytes itself; failure here is
/// a codec bug and must be loud, not a fake window timeout.
pub fn decode_delivery(bytes: &[u8]) -> Msg {
    Msg::decode(bytes).expect("virtual hub delivered an undecodable message")
}

impl VirtualEndpoint {
    /// Route one already-encoded message: link block / partition / drop
    /// sampling, then an event post on the shared clock.  Sharing the
    /// encoded bytes is what keeps a broadcast to 10 000 peers at one
    /// encode + n refcounts instead of n copies of the model.
    fn send_encoded(&self, to: ClientId, msg: &Msg, wire: &Arc<[u8]>) {
        let sh = &self.shared;
        sh.stats.count_send(msg, wire.len());
        if sh.blocked.lock().unwrap().contains(&(self.id, to)) {
            return; // injected link failure: message lost
        }
        let at = sh.clock.now_for(self.id);
        if sh.model.splits.iter().any(|sp| sp.severs(at, self.id, to)) {
            return; // partitioned: message lost
        }
        let Some((delay, seq)) = sample_link(&sh.links, &sh.model, self.id, to, wire.len())
        else {
            return; // dropped (independent or burst loss)
        };
        // The codec round-trip happens decode-side (recv_timeout), keeping
        // parity with the wall-clock hub's coverage of the wire format.
        sh.stats.count_delivered();
        sh.clock.post(self.id, to, at, delay, (self.id, to, seq), Arc::clone(wire));
    }
}

impl Transport for VirtualEndpoint {
    fn id(&self) -> ClientId {
        self.id
    }

    fn clock(&self) -> Clock {
        Clock::virtual_for(Arc::clone(self.shared.clock.clock_of(self.id)), self.id as usize)
    }

    fn peers(&self) -> Vec<ClientId> {
        (0..self.shared.n as ClientId).filter(|&p| p != self.id).collect()
    }

    fn n_peers(&self) -> usize {
        self.shared.n.saturating_sub(1)
    }

    fn neighbors(&self) -> Vec<ClientId> {
        self.shared.overlay.neighbors(self.shared.clock.now_for(self.id), self.id)
    }

    fn topology_generation(&self) -> u64 {
        self.shared.overlay.generation(self.shared.clock.now_for(self.id))
    }

    fn topology_is_dynamic(&self) -> bool {
        self.shared.overlay.is_dynamic()
    }

    fn send(&self, to: ClientId, msg: &Msg) -> Result<()> {
        let wire = msg.encode_arc();
        self.send_encoded(to, msg, &wire);
        Ok(())
    }

    /// Encode once, post per *current* overlay neighbor (same per-link
    /// sampling and ascending order as the default per-peer `send` loop —
    /// on a full mesh the neighbor list *is* the ascending peer list, so
    /// the network schedule is unchanged; only the allocations are).
    /// Under a graph-fault schedule the neighborhood is read at send
    /// time, so a broadcast never reaches across a cut that is open *now*.
    fn broadcast(&self, msg: &Msg) -> Result<()> {
        let wire = msg.encode_arc();
        self.shared.overlay.for_each_neighbor(self.shared.clock.now_for(self.id), self.id, |p| {
            self.send_encoded(p, msg, &wire);
        });
        Ok(())
    }

    fn recv_timeout(&self, timeout: Duration) -> Option<Msg> {
        let bytes =
            self.shared.clock.clock_of(self.id).recv_deadline(self.id as usize, timeout)?;
        Some(decode_delivery(&bytes))
    }

    fn try_recv(&self) -> Option<Msg> {
        let bytes = self.shared.clock.clock_of(self.id).try_recv(self.id as usize)?;
        Some(decode_delivery(&bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::message::ModelUpdate;
    use crate::model::ParamVector;

    fn update(sender: ClientId, round: u32) -> Msg {
        Msg::Update(ModelUpdate {
            sender,
            round,
            terminate: false,
            weight: 1.0,
            params: ParamVector(vec![sender as f32, round as f32]),
        })
    }

    #[test]
    fn direct_delivery_no_delay() {
        let hub = InProcHub::new(3, NetworkModel::ideal());
        let a = hub.endpoint(0);
        let b = hub.endpoint(1);
        a.send(1, &update(0, 5)).unwrap();
        let got = b.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(got, update(0, 5));
    }

    #[test]
    fn broadcast_reaches_all_peers() {
        let hub = InProcHub::new(4, NetworkModel::ideal());
        let eps: Vec<Endpoint> = (0..4).map(|i| hub.endpoint(i)).collect();
        eps[2].broadcast(&update(2, 1)).unwrap();
        for (i, ep) in eps.iter().enumerate() {
            if i == 2 {
                assert!(ep.try_recv().is_none());
            } else {
                assert_eq!(ep.recv_timeout(Duration::from_secs(1)), Some(update(2, 1)));
            }
        }
    }

    #[test]
    fn delayed_delivery_respects_latency() {
        let model = NetworkModel {
            base_delay: Duration::from_millis(30),
            seed: 1,
            ..NetworkModel::ideal()
        };
        let hub = InProcHub::new(2, model);
        let a = hub.endpoint(0);
        let b = hub.endpoint(1);
        let t0 = Instant::now();
        a.send(1, &update(0, 1)).unwrap();
        assert!(b.try_recv().is_none(), "arrived too early");
        let got = b.recv_timeout(Duration::from_secs(1));
        assert!(got.is_some());
        assert!(t0.elapsed() >= Duration::from_millis(25), "{:?}", t0.elapsed());
    }

    #[test]
    fn drops_lose_messages() {
        let hub = InProcHub::new(2, NetworkModel::lossy(1.0, 2)); // drop all
        let a = hub.endpoint(0);
        let b = hub.endpoint(1);
        for r in 0..10 {
            a.send(1, &update(0, r)).unwrap();
        }
        assert!(b.recv_timeout(Duration::from_millis(50)).is_none());
        let stats = hub.net_stats();
        assert_eq!(stats.msgs_sent, 10);
        assert_eq!(stats.msgs_dropped, 10, "a 100% lossy link drops every send");
        assert_eq!(stats.msgs_delivered, 0);
    }

    #[test]
    fn topology_scopes_broadcast_and_counters_measure_it() {
        use crate::net::topology::TopologySpec;
        let topo = Arc::new(TopologySpec::Ring { k: 1 }.build(4, 5).unwrap());
        let hub = InProcHub::with_topology(4, NetworkModel::ideal(), topo);
        let eps: Vec<Endpoint> = (0..4).map(|i| hub.endpoint(i)).collect();
        assert_eq!(eps[0].neighbors(), vec![1, 3], "ring:1 neighborhood of 0");
        assert_eq!(eps[0].peers(), vec![1, 2, 3], "peers stays the full set");
        eps[0].broadcast(&update(0, 1)).unwrap();
        assert_eq!(eps[1].recv_timeout(Duration::from_secs(1)), Some(update(0, 1)));
        assert_eq!(eps[3].recv_timeout(Duration::from_secs(1)), Some(update(0, 1)));
        assert!(eps[2].try_recv().is_none(), "non-neighbor heard a broadcast");
        let stats = hub.net_stats();
        assert_eq!(stats.msgs_sent, 2, "degree-2 broadcast is 2 sends, not n-1");
        assert_eq!(stats.msgs_delivered, 2);
        assert_eq!(stats.msgs_dropped, 0);
        assert_eq!(stats.bytes_sent, 2 * update(0, 1).encode().len() as u64);
    }

    #[test]
    fn delta_codec_sends_feed_savings_counters() {
        use crate::net::delta::{dense_wire_size, Ack, DeltaBody, DeltaMsg, SparseVals};
        let hub = InProcHub::new(2, NetworkModel::ideal());
        let a = hub.endpoint(0);
        let _b = hub.endpoint(1);
        // Dense traffic must leave every codec counter at zero.
        a.send(1, &update(0, 1)).unwrap();
        let stats = hub.net_stats();
        assert_eq!((stats.bytes_saved, stats.delta_hits, stats.delta_full), (0, 0, 0));
        // A sparse delta counts as a hit and books dense − wire bytes.
        let sparse = Msg::Delta(DeltaMsg {
            sender: 0,
            round: 1,
            terminate: false,
            weight: 1.0,
            ack: Ack::NONE,
            body: DeltaBody::Sparse {
                base_round: 0,
                dim: 100,
                idx: vec![1, 7],
                vals: SparseVals::F32(vec![0.5, -0.5]),
            },
        });
        let sparse_wire = sparse.encode().len() as u64;
        a.send(1, &sparse).unwrap();
        let stats = hub.net_stats();
        assert_eq!(stats.delta_hits, 1);
        assert_eq!(stats.delta_full, 0);
        assert_eq!(stats.bytes_saved, dense_wire_size(100) as u64 - sparse_wire);
        // A full snapshot counts as a fallback; its wire is a shade larger
        // than dense (the ack piggyback), so it books zero savings.
        let full = Msg::Delta(DeltaMsg {
            sender: 0,
            round: 2,
            terminate: false,
            weight: 1.0,
            ack: Ack::NONE,
            body: DeltaBody::Full(vec![0.0; 100]),
        });
        a.send(1, &full).unwrap();
        let stats = hub.net_stats();
        assert_eq!(stats.delta_full, 1);
        assert_eq!(stats.bytes_saved, dense_wire_size(100) as u64 - sparse_wire);
        assert!(stats.delta_hit_rate() > 0.49 && stats.delta_hit_rate() < 0.51);
    }

    #[test]
    fn blocked_link_is_one_directional() {
        let hub = InProcHub::new(2, NetworkModel::ideal());
        let a = hub.endpoint(0);
        let b = hub.endpoint(1);
        hub.set_link_blocked(0, 1, true);
        a.send(1, &update(0, 1)).unwrap();
        assert!(b.recv_timeout(Duration::from_millis(50)).is_none());
        b.send(0, &update(1, 2)).unwrap();
        assert_eq!(a.recv_timeout(Duration::from_secs(1)), Some(update(1, 2)));
        hub.set_link_blocked(0, 1, false);
        a.send(1, &update(0, 3)).unwrap();
        assert_eq!(b.recv_timeout(Duration::from_secs(1)), Some(update(0, 3)));
    }

    #[test]
    fn send_to_dropped_endpoint_is_silent() {
        let hub = InProcHub::new(2, NetworkModel::ideal());
        let a = hub.endpoint(0);
        {
            let _b = hub.endpoint(1);
        } // b crashes
        assert!(a.send(1, &update(0, 1)).is_ok());
    }

    #[test]
    fn ordering_preserved_per_link_without_jitter() {
        let hub = InProcHub::new(2, NetworkModel::ideal());
        let a = hub.endpoint(0);
        let b = hub.endpoint(1);
        for r in 0..20 {
            a.send(1, &update(0, r)).unwrap();
        }
        for r in 0..20 {
            let got = b.recv_timeout(Duration::from_secs(1)).unwrap();
            match got {
                Msg::Update(u) => assert_eq!(u.round, r),
                _ => panic!("wrong kind"),
            }
        }
    }

    #[test]
    fn split_severs_only_cross_group_during_window() {
        let sp = NetSplit {
            start: Duration::from_millis(10),
            end: Duration::from_millis(20),
            side_a: vec![0, 1],
        };
        let in_window = Duration::from_millis(15);
        assert!(sp.severs(in_window, 0, 2));
        assert!(sp.severs(in_window, 2, 1), "severed in both directions");
        assert!(!sp.severs(in_window, 0, 1), "same side unaffected");
        assert!(!sp.severs(in_window, 2, 3), "same side unaffected");
        assert!(!sp.severs(Duration::from_millis(5), 0, 2), "before window");
        assert!(!sp.severs(Duration::from_millis(20), 0, 2), "end is exclusive");
    }

    #[test]
    fn wan_preset_is_heavier_than_lan() {
        let lan = NetworkModel::lan(1);
        let wan = NetworkModel::wan(1);
        assert!(wan.base_delay > lan.base_delay);
        assert!(wan.jitter > lan.jitter);
        assert!(wan.drop_prob > 0.0 && wan.drop_prob < 0.1);
    }

    #[test]
    fn presets_parse_round_trip_and_are_distinct() {
        for p in NetPreset::ALL {
            assert_eq!(NetPreset::parse(p.name()).unwrap(), p);
            let m = p.model(9);
            assert_eq!(m.seed, 9, "preset {} must take the caller's seed", p.name());
        }
        assert!(NetPreset::parse("carrier-pigeon").is_err());
        assert!(NetworkModel::preset("asym", 1).unwrap().bandwidth.is_some());
        assert!(NetworkModel::preset("lossy-burst", 1).unwrap().burst.is_some());
    }

    #[test]
    fn asym_multiplier_is_static_per_direction_and_bounded() {
        let m = NetworkModel::asym(42);
        for from in 0..4u32 {
            for to in 0..4u32 {
                if from == to {
                    continue;
                }
                let mult = m.asym_mult(from, to);
                assert_eq!(mult, m.asym_mult(from, to), "multiplier must be static");
                assert!((1.0 - m.asymmetry..=1.0 + m.asymmetry).contains(&mult));
            }
        }
        // the spread must actually produce asymmetric directions somewhere
        let skewed = (0..8u32).any(|a| {
            let b = a + 8;
            (m.asym_mult(a, b) - m.asym_mult(b, a)).abs() > 0.05
        });
        assert!(skewed, "±80% spread never separated a link's directions");
    }

    #[test]
    fn bandwidth_cap_adds_size_proportional_delay_virtually() {
        // 10 kB/s link, zero base latency: a ~1.4 kB model update must take
        // ~0.14 s of *logical* time, measured exactly by the virtual clock.
        let model = NetworkModel {
            bandwidth: Some(10_000),
            seed: 3,
            ..NetworkModel::ideal()
        };
        let wire_len = update(0, 1).encode().len();
        let expect = Duration::from_secs_f64(wire_len as f64 / 10_000.0);
        let clock = VirtualClock::new(2);
        let hub = VirtualHub::new(2, model, Arc::clone(&clock));
        let a = hub.endpoint(0);
        let b = hub.endpoint(1);
        std::thread::scope(|scope| {
            let c = Arc::clone(&clock);
            scope.spawn(move || {
                c.attach(0);
                a.send(1, &update(0, 1)).unwrap();
                c.detach(0);
            });
            let c = Arc::clone(&clock);
            scope.spawn(move || {
                c.attach(1);
                let got = b.recv_timeout(Duration::from_secs(5));
                assert_eq!(got, Some(update(0, 1)));
                assert_eq!(c.now(), expect, "transfer time must be exactly size/rate");
                c.detach(1);
            });
        });
    }

    #[test]
    fn burst_chain_drops_in_runs_not_uniformly() {
        // Deterministic per-link chain: with drop_bad = 1.0 every loss run
        // inside a bad state is contiguous.  Check (a) losses occur, (b)
        // they cluster (at least one run of >= 2 consecutive drops), and
        // (c) the schedule is seed-reproducible.
        let model = NetworkModel {
            burst: Some(GilbertElliott { p_enter: 0.2, p_exit: 0.3, drop_bad: 1.0 }),
            seed: 11,
            ..NetworkModel::ideal()
        };
        let outcomes = |m: &NetworkModel| -> Vec<bool> {
            let mut link = LinkState::new(m, 0, 1);
            (0..400).map(|_| link.sample(m, 100).is_some()).collect()
        };
        let a = outcomes(&model);
        assert_eq!(a, outcomes(&model), "burst schedule must be reproducible");
        let drops = a.iter().filter(|&&ok| !ok).count();
        assert!(drops > 20, "burst chain never bit: {drops} drops of 400");
        assert!(drops < 380, "burst chain never recovered: {drops} drops of 400");
        let clustered = a.windows(2).any(|w| !w[0] && !w[1]);
        assert!(clustered, "losses never clustered — not a burst model");
    }

    #[test]
    fn virtual_hub_delivers_at_modeled_latency() {
        let model = NetworkModel {
            base_delay: Duration::from_millis(30),
            seed: 1,
            ..NetworkModel::ideal()
        };
        let clock = VirtualClock::new(2);
        let hub = VirtualHub::new(2, model, Arc::clone(&clock));
        let a = hub.endpoint(0);
        let b = hub.endpoint(1);
        std::thread::scope(|scope| {
            let ca = a.clock();
            scope.spawn(move || {
                if let Clock::Virtual { clock, token } = &ca {
                    clock.attach(*token);
                    a.send(1, &update(0, 1)).unwrap();
                    clock.detach(*token);
                }
            });
            let cb = b.clock();
            scope.spawn(move || {
                if let Clock::Virtual { clock, token } = &cb {
                    clock.attach(*token);
                    let got = b.recv_timeout(Duration::from_secs(5));
                    assert_eq!(got, Some(update(0, 1)));
                    assert_eq!(cb.now(), Duration::from_millis(30), "exact logical latency");
                    clock.detach(*token);
                }
            });
        });
    }

    #[test]
    fn virtual_hub_recv_times_out_without_real_waiting() {
        let clock = VirtualClock::new(1);
        let hub = VirtualHub::new(1, NetworkModel::ideal(), Arc::clone(&clock));
        let a = hub.endpoint(0);
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            scope.spawn(|| {
                clock.attach(0);
                assert!(a.recv_timeout(Duration::from_secs(30)).is_none());
                clock.detach(0);
            });
        });
        assert_eq!(clock.now(), Duration::from_secs(30));
        assert!(t0.elapsed() < Duration::from_secs(2), "virtual wait burned wall time");
    }
}
