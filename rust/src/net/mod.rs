//! P2P messaging substrate.
//!
//! Three interchangeable transports implement [`Transport`]:
//!
//! * [`inproc::InProcHub`] — in-process channels with a seeded network model
//!   (per-link delay with optional asymmetry, jitter, bandwidth caps,
//!   independent and burst drops — the scenario matrix, see
//!   [`inproc::NetPreset`]) used by the simulator, tests, and the
//!   experiment harness.  Messages still round-trip through the binary wire
//!   codec so the encoding is exercised everywhere.
//! * [`inproc::VirtualHub`] — the same network model on a deterministic
//!   [`crate::util::time::VirtualClock`]: waits advance logical time instead
//!   of sleeping, making 1000-client deployments and WAN-scale latency
//!   distributions testable in milliseconds.
//! * [`tcp::TcpTransport`] — real sockets (std::net) with length-prefixed
//!   frames for multi-process / multi-machine deployments, matching the
//!   paper's thread+socket implementation.
//!
//! Clients obtain their time source from [`Transport::clock`], so protocol
//! code is identical under wall and virtual time.

pub mod delta;
pub mod inproc;
pub mod message;
pub mod overlay;
pub mod tcp;
pub mod topology;

pub use delta::CodecSpec;
pub use inproc::{
    GilbertElliott, InProcHub, NetPreset, NetSplit, NetworkModel, VirtualEndpoint, VirtualHub,
};
pub use message::{ClientId, ModelUpdate, Msg};
pub use overlay::{GraphAction, GraphEvent, Overlay};
pub use tcp::TcpTransport;
pub use topology::{Topology, TopologySpec};

use std::time::Duration;

use anyhow::Result;

use crate::util::time::Clock;

/// Peer-to-peer endpoint owned by one client.
///
/// Send operations are best-effort (the failure model allows peers to be
/// gone); receipt ordering between different senders is not guaranteed
/// (asynchronous network).
pub trait Transport: Send {
    fn id(&self) -> ClientId;

    /// The time source deadline waits on this transport are measured
    /// against.  Wall time unless the transport runs on a virtual clock;
    /// clients should call this once and reuse the handle.
    fn clock(&self) -> Clock {
        Clock::real()
    }

    /// All peers this endpoint can address (excluding itself).
    fn peers(&self) -> Vec<ClientId>;

    /// How many peers this endpoint can address (excluding itself).
    /// Override where the count is known without materializing the list —
    /// at 10 000 clients the default would allocate a 10 000-entry `Vec`
    /// per call.
    fn n_peers(&self) -> usize {
        self.peers().len()
    }

    /// The peers this endpoint *disseminates to* — its overlay
    /// neighborhood ([`topology::Topology`]), ascending.  Defaults to all
    /// peers (the full mesh); transports built over a sparse overlay
    /// return the neighbor set instead, and protocol code that used to
    /// range over `peers()` (liveness tracking, wait windows, broadcasts)
    /// ranges over this.
    ///
    /// Since the graph-fault subsystem (DESIGN.md §10) this is
    /// *time-aware*: on a transport backed by a mutable
    /// [`overlay::Overlay`] the answer reflects the overlay at the
    /// transport's current clock time (cuts, churn, repairs applied) —
    /// callers that cache it should watch
    /// [`Transport::topology_generation`] for staleness.
    fn neighbors(&self) -> Vec<ClientId> {
        self.peers()
    }

    /// Monotonic overlay-change counter: increments every time a graph
    /// fault rewires the overlay, constant `0` on a static one.  Protocol
    /// code polls this once per round and refreshes its cached
    /// neighborhood structure (tracked peer set, quorum denominator) on a
    /// change.
    fn topology_generation(&self) -> u64 {
        0
    }

    /// Does this transport's overlay carry a graph-fault schedule?
    /// Static overlays answer false, letting protocol code keep its
    /// pre-fault degenerate paths byte-identical.
    fn topology_is_dynamic(&self) -> bool {
        false
    }

    /// Send to one peer. Returns Ok even if the peer never receives it
    /// (crash model); hard local errors (e.g. serialization) are Err.
    fn send(&self, to: ClientId, msg: &Msg) -> Result<()>;

    /// Broadcast to every overlay neighbor (best effort, independent per
    /// peer; the whole peer set on a full mesh).
    fn broadcast(&self, msg: &Msg) -> Result<()> {
        for p in self.neighbors() {
            self.send(p, msg)?;
        }
        Ok(())
    }

    /// Blocking receive with timeout; None on timeout or hub shutdown.
    fn recv_timeout(&self, timeout: Duration) -> Option<Msg>;

    /// Non-blocking receive.
    fn try_recv(&self) -> Option<Msg>;

    /// Drain everything currently queued.
    fn drain(&self) -> Vec<Msg> {
        let mut out = Vec::new();
        while let Some(m) = self.try_recv() {
            out.push(m);
        }
        out
    }
}
