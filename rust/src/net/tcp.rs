//! Real-socket transport (std::net TCP) for multi-process / multi-machine
//! deployments — the configuration the paper actually ran (threads +
//! sockets on three LAN machines).
//!
//! Frames use the codec's `[magic][version][len][payload][crc]` layout.
//! Outgoing connections are created lazily and cached; a send to a dead
//! peer fails silently after one reconnect attempt (crash model: silence,
//! not errors). Incoming connections are accepted on a background thread,
//! one reader thread per connection feeding a shared inbox.

// dfl-lint: allow-file(wall-clock) — real-socket transport: reconnect backoff and polling sleep on the actual clock; never on the deterministic executor path
// dfl-lint: allow-file(hash-iter-order) — connection/peer caches are keyed lookups only; nothing here feeds the seeded RNG streams or the virtual event order
use std::collections::{BTreeMap, HashMap};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use super::message::{ClientId, Msg};
use super::Transport;
use crate::util::codec;

const CONNECT_TIMEOUT: Duration = Duration::from_millis(500);
const CONNECT_RETRIES: usize = 20;
const RETRY_BACKOFF: Duration = Duration::from_millis(100);

/// TCP endpoint for one client process.
pub struct TcpTransport {
    id: ClientId,
    peer_addrs: BTreeMap<ClientId, SocketAddr>,
    conns: Mutex<HashMap<ClientId, TcpStream>>,
    /// Peers we have successfully dialed at least once: startup races get
    /// the patient retry loop; once a peer has been up, refusal means crash
    /// and deserves only one quick re-dial (silence, not stalling).
    ever_connected: Mutex<std::collections::HashSet<ClientId>>,
    inbox: Mutex<Receiver<Msg>>,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl TcpTransport {
    /// Bind `listen` and prepare lazy connections to `peers`
    /// (id → address, excluding our own id).
    pub fn bind(
        id: ClientId,
        listen: SocketAddr,
        peers: BTreeMap<ClientId, SocketAddr>,
    ) -> Result<TcpTransport> {
        let listener =
            TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
        listener.set_nonblocking(true)?;
        let (tx, rx) = mpsc::channel::<Msg>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let accept_thread = {
            let shutdown = Arc::clone(&shutdown);
            std::thread::Builder::new()
                .name(format!("tcp-accept-{id}"))
                .spawn(move || accept_loop(&listener, &tx, &shutdown))
                .expect("spawn accept thread")
        };
        Ok(TcpTransport {
            id,
            peer_addrs: peers,
            conns: Mutex::new(HashMap::new()),
            ever_connected: Mutex::new(std::collections::HashSet::new()),
            inbox: Mutex::new(rx),
            shutdown,
            accept_thread: Some(accept_thread),
        })
    }

    fn connect(&self, to: ClientId) -> Option<TcpStream> {
        let addr = self.peer_addrs.get(&to)?;
        let retries = if self.ever_connected.lock().unwrap().contains(&to) {
            1 // previously-live peer refusing = crashed; don't stall the round
        } else {
            CONNECT_RETRIES // startup race: peer may not have bound yet
        };
        for attempt in 0..retries {
            match TcpStream::connect_timeout(addr, CONNECT_TIMEOUT) {
                Ok(s) => {
                    let _ = s.set_nodelay(true);
                    self.ever_connected.lock().unwrap().insert(to);
                    return Some(s);
                }
                Err(_) if attempt + 1 < retries => std::thread::sleep(RETRY_BACKOFF),
                Err(_) => return None,
            }
        }
        None
    }

    fn write_frame(stream: &mut TcpStream, bytes: &[u8]) -> std::io::Result<()> {
        stream.write_all(bytes)?;
        stream.flush()
    }
}

fn accept_loop(listener: &TcpListener, tx: &Sender<Msg>, shutdown: &Arc<AtomicBool>) {
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let tx = tx.clone();
                let shutdown = Arc::clone(shutdown);
                let _ = std::thread::Builder::new()
                    .name("tcp-reader".into())
                    .spawn(move || reader_loop(stream, &tx, &shutdown));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
}

fn reader_loop(mut stream: TcpStream, tx: &Sender<Msg>, shutdown: &Arc<AtomicBool>) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut buf = Vec::with_capacity(64 * 1024);
    let mut chunk = [0u8; 64 * 1024];
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match stream.read(&mut chunk) {
            Ok(0) => return, // peer closed
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                // parse every complete frame in the buffer
                loop {
                    match codec::deframe(&buf) {
                        Ok(Some((payload, used))) => {
                            if let Ok(msg) = Msg::decode(payload) {
                                if tx.send(msg).is_err() {
                                    return; // transport dropped
                                }
                            }
                            buf.drain(..used);
                        }
                        Ok(None) => break,
                        Err(_) => return, // corrupt stream: drop connection
                    }
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => return,
        }
    }
}

impl Transport for TcpTransport {
    fn id(&self) -> ClientId {
        self.id
    }

    fn peers(&self) -> Vec<ClientId> {
        self.peer_addrs.keys().copied().collect()
    }

    fn n_peers(&self) -> usize {
        self.peer_addrs.len()
    }

    fn send(&self, to: ClientId, msg: &Msg) -> Result<()> {
        let bytes = codec::frame(&msg.encode())?;
        let mut conns = self.conns.lock().unwrap();
        // reuse the cached connection, else dial
        if let Some(stream) = conns.get_mut(&to) {
            if Self::write_frame(stream, &bytes).is_ok() {
                return Ok(());
            }
            conns.remove(&to); // stale — reconnect below
        }
        if let Some(mut stream) = self.connect(to) {
            if Self::write_frame(&mut stream, &bytes).is_ok() {
                conns.insert(to, stream);
            }
        }
        // Unreachable peer == crashed peer: silence, not an error.
        Ok(())
    }

    fn recv_timeout(&self, timeout: Duration) -> Option<Msg> {
        match self.inbox.lock().unwrap().recv_timeout(timeout) {
            Ok(m) => Some(m),
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => None,
        }
    }

    fn try_recv(&self) -> Option<Msg> {
        self.inbox.lock().unwrap().try_recv().ok()
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ParamVector;
    use crate::net::message::ModelUpdate;
    use std::net::{IpAddr, Ipv4Addr};

    fn addr(port: u16) -> SocketAddr {
        SocketAddr::new(IpAddr::V4(Ipv4Addr::LOCALHOST), port)
    }

    /// Find a free port by binding port 0.
    fn free_addr() -> SocketAddr {
        TcpListener::bind(addr(0)).unwrap().local_addr().unwrap()
    }

    fn update(sender: ClientId, round: u32, n: usize) -> Msg {
        Msg::Update(ModelUpdate {
            sender,
            round,
            terminate: false,
            weight: 1.0,
            params: ParamVector((0..n).map(|i| i as f32).collect()),
        })
    }

    #[test]
    fn two_endpoints_roundtrip() {
        let a_addr = free_addr();
        let b_addr = free_addr();
        let a = TcpTransport::bind(0, a_addr, BTreeMap::from([(1, b_addr)])).unwrap();
        let b = TcpTransport::bind(1, b_addr, BTreeMap::from([(0, a_addr)])).unwrap();
        a.send(1, &update(0, 3, 100)).unwrap();
        let got = b.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(got, update(0, 3, 100));
        // reply over the reverse direction
        b.send(0, &update(1, 4, 10)).unwrap();
        assert_eq!(a.recv_timeout(Duration::from_secs(5)).unwrap(), update(1, 4, 10));
    }

    #[test]
    fn large_model_crosses_stream_chunks() {
        let a_addr = free_addr();
        let b_addr = free_addr();
        let a = TcpTransport::bind(0, a_addr, BTreeMap::from([(1, b_addr)])).unwrap();
        let b = TcpTransport::bind(1, b_addr, BTreeMap::from([(0, a_addr)])).unwrap();
        // ~880 KB message forces multiple reads on the receiver.
        let msg = update(0, 1, 220_000);
        a.send(1, &msg).unwrap();
        let got = b.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(got, msg);
    }

    #[test]
    fn send_to_dead_peer_is_silent() {
        let a_addr = free_addr();
        let dead = free_addr(); // nothing listens here
        let a = TcpTransport::bind(0, a_addr, BTreeMap::from([(1, dead)])).unwrap();
        // must not error or hang forever
        let t0 = std::time::Instant::now();
        a.send(1, &update(0, 1, 10)).unwrap();
        assert!(t0.elapsed() < Duration::from_secs(30));
    }

    #[test]
    fn reconnects_after_peer_restart() {
        let a_addr = free_addr();
        let b_addr = free_addr();
        let a = TcpTransport::bind(0, a_addr, BTreeMap::from([(1, b_addr)])).unwrap();
        {
            let b1 = TcpTransport::bind(1, b_addr, BTreeMap::from([(0, a_addr)])).unwrap();
            a.send(1, &update(0, 1, 5)).unwrap();
            assert!(b1.recv_timeout(Duration::from_secs(5)).is_some());
        } // b crashes
        std::thread::sleep(Duration::from_millis(100));
        a.send(1, &update(0, 2, 5)).unwrap(); // drops silently
        // b rejoins on the same address (transient-failure model)
        let b2 = TcpTransport::bind(1, b_addr, BTreeMap::from([(0, a_addr)])).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        a.send(1, &update(0, 3, 5)).unwrap();
        let got = b2.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(got, update(0, 3, 5));
    }
}
