//! The mutable peer overlay — a [`Topology`] plus a deterministic
//! schedule of graph faults (DESIGN.md §10).
//!
//! PR 4 made the overlay a pure value built once at deployment setup;
//! the graph-fault subsystem makes it *time-dependent*: edge cuts open
//! and heal, churned clients depart (edges torn down, orphans repaired)
//! and rejoin (edges regenerated).  [`Overlay`] is the single shared
//! source of truth both in-proc hubs read at **send time** — so
//! broadcasts, [`crate::net::Transport::neighbors`], and the CRT relay
//! always see the *current* neighborhood — and its generation counter is
//! how protocol code ([`crate::coordinator::machine`]) notices that its
//! cached neighborhood structure (PeerTable tracked set, quorum
//! denominator) went stale.
//!
//! # Determinism
//!
//! The schedule is compiled before the run (`sim::run`) and **replayed
//! once at construction** into a vector of immutable topology snapshots,
//! one per event.  A query at logical time `t` binary-searches for the
//! last snapshot with `at <= t` — a pure, lock-free read.  This makes
//! every query a function of `t` alone, *independent of the order
//! queries arrive in*: the single-clock executors always query at
//! non-decreasing times, but the sharded parallel executor
//! (`sim::exec::run_parallel`, DESIGN.md §12) has S worker threads
//! querying at interleaved shard-local times within a synchronization
//! window, and a lazily-advanced overlay would hand them whatever state
//! the wall-clock-racy *maximum* queried time had forced.  Snapshots
//! reduce the entire overlay history to a pure function of
//! `(topology, schedule, seed)` — byte-identical across executors,
//! thread interleavings, and re-runs.
//!
//! The one time-cursor that survives is [`Overlay::edges_severed`],
//! which reports the severed count *as of the latest time any query has
//! reached* — kept as a lock-free atomic high-water over queried times.
//! The *set* of query times in a run is deterministic (every send and
//! neighborhood poll happens at a seed-determined logical instant), so
//! its maximum — and therefore the reported count — is too, even though
//! the wall-clock order the high-water is bumped in is not.
//!
//! # The static fast path
//!
//! A deployment without graph faults wraps its topology in
//! [`Overlay::immutable`]: no snapshots, no events, generation pinned at
//! 0, and every query forwards to the shared immutable [`Topology`] —
//! the byte-identity guarantee for fault-free runs is structural, not
//! behavioural.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::message::ClientId;
use super::topology::Topology;
use crate::util::time::SimTime;

/// One scheduled overlay change, applied when the hub clock first reaches
/// `at`.
#[derive(Clone, Debug)]
pub struct GraphEvent {
    pub at: SimTime,
    pub action: GraphAction,
}

/// What a [`GraphEvent`] does to the overlay.
#[derive(Clone, Debug)]
pub enum GraphAction {
    /// Sever the listed edges (cut window opening).  `cut_id` pairs the
    /// cut with its [`GraphAction::Restore`] so healing re-adds exactly
    /// the edges that were actually removed.
    Cut { cut_id: usize, edges: Vec<(ClientId, ClientId)> },
    /// Heal cut `cut_id`: re-add its severed edges (skipping any whose
    /// endpoint has meanwhile departed).
    Restore { cut_id: usize },
    /// Churn departure: tear down the client's edges and repair its
    /// orphaned neighbors ([`Topology::depart`]).
    Depart(ClientId),
    /// Churn arrival: deterministically regenerate the client's edges
    /// ([`Topology::regenerate`]), seeded per rejoin event.
    Rejoin(ClientId),
}

/// Per-edge cut bookkeeping: how many open cut windows currently claim
/// the edge, and whether any of them physically removed it (as opposed
/// to claiming an edge a departure had already torn down — those are
/// the rejoin path's to rebuild, not the heal path's).
#[derive(Clone, Copy, Default)]
struct CutRef {
    refs: u32,
    removed_by_cut: bool,
}

/// The overlay as of one applied event: the instant it applied, the
/// graph after it, and the cumulative severed-edge count.
struct Snapshot {
    /// `GraphEvent::at` in nanos.  Ascending but not strictly — equal
    /// instants keep compile order, and a query takes the *last* one due.
    at_nanos: u64,
    topo: Arc<Topology>,
    /// Cuts + departures applied so far (healing does not re-count) —
    /// surfaced as `edges_severed` on [`crate::metrics::NetStats`].
    severed: u64,
}

/// A compiled fault schedule: the pre-event base graph, one snapshot per
/// event, and the queried-time high-water that anchors
/// [`Overlay::edges_severed`].
struct Compiled {
    base: Arc<Topology>,
    snaps: Vec<Snapshot>,
    /// Latest queried time, stored as `nanos + 1` (0 = never queried),
    /// advanced with a relaxed `fetch_max` by every query.
    hw: AtomicU64,
}

/// Replay state used once, inside [`Overlay::with_events`], to turn the
/// event list into snapshots.
struct Replay {
    topo: Topology,
    /// Edges claimed per cut (filled at apply time, consumed by the
    /// matching restore).
    claims: Vec<Vec<(ClientId, ClientId)>>,
    /// Refcounts over every currently-claimed edge: an edge heals only
    /// when its *last* claiming window closes, so overlapping cuts that
    /// share edges (two `mincut`s of the same graph, say) compose
    /// instead of the first heal silently negating the second window.
    /// Also the "do not bridge an open cut" source of truth for the
    /// churn repair/regeneration paths.
    cut_refs: BTreeMap<(ClientId, ClientId), CutRef>,
    /// Clients currently departed (their edges must not be restored).
    departed: Vec<bool>,
    /// Per-client rejoin counter: varies the regeneration stream across
    /// successive rejoins of the same client.
    rejoins: Vec<u32>,
    /// Cumulative overlay edges severed (cuts + departures).
    severed: u64,
    seed: u64,
}

/// The two shapes an overlay can take.  An enum (rather than optional
/// snapshots next to an always-present base graph) makes the "the static
/// topology is never consulted on the dynamic path" invariant
/// structural: there is no stale base for a future accessor to read by
/// mistake.
enum OverlayState {
    /// Shared immutable topology: no schedule, no snapshots.
    Static(Arc<Topology>),
    /// Pre-replayed fault schedule: lock-free snapshot lookups.
    Dynamic(Compiled),
}

fn nanos(at: SimTime) -> u64 {
    u64::try_from(at.as_nanos()).unwrap_or(u64::MAX)
}

/// The time-aware overlay shared by both hubs.  See the module docs.
pub struct Overlay {
    n: usize,
    state: OverlayState,
}

impl Overlay {
    /// The static fast path: no schedule, no lock, generation forever 0.
    pub fn immutable(topology: Arc<Topology>) -> Overlay {
        Overlay { n: topology.n(), state: OverlayState::Static(topology) }
    }

    /// An overlay that applies `events` as the querying clock reaches
    /// them.  `n_cuts` is the number of distinct `cut_id`s in the
    /// schedule; `seed` feeds the per-rejoin regeneration streams.  The
    /// topology is materialized up front so a full mesh can be cut too,
    /// and the whole schedule is replayed here, once, into per-event
    /// snapshots (see the module docs).
    pub fn with_events(
        mut topology: Topology,
        mut events: Vec<GraphEvent>,
        n_cuts: usize,
        seed: u64,
    ) -> Overlay {
        let n = topology.n();
        topology.materialize();
        events.sort_by_key(|e| e.at); // stable: compile order breaks ties
        let mut replay = Replay {
            topo: topology.clone(),
            claims: vec![Vec::new(); n_cuts],
            cut_refs: BTreeMap::new(),
            departed: vec![false; n],
            rejoins: vec![0; n],
            severed: 0,
            seed,
        };
        let mut snaps = Vec::with_capacity(events.len());
        for event in events {
            replay.apply(event.action);
            snaps.push(Snapshot {
                at_nanos: nanos(event.at),
                topo: Arc::new(replay.topo.clone()),
                severed: replay.severed,
            });
        }
        Overlay {
            n,
            state: OverlayState::Dynamic(Compiled {
                base: Arc::new(topology),
                snaps,
                hw: AtomicU64::new(0),
            }),
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Does this overlay carry a fault schedule?  Protocol code uses this
    /// to keep the static degenerate paths (e.g. the neighborless
    /// single-client round) byte-identical.
    pub fn is_dynamic(&self) -> bool {
        matches!(self.state, OverlayState::Dynamic(_))
    }

    /// The neighbor set of `id` at time `at`, ascending.
    pub fn neighbors(&self, at: SimTime, id: ClientId) -> Vec<ClientId> {
        match &self.state {
            OverlayState::Static(topo) => topo.neighbors(id),
            OverlayState::Dynamic(c) => c.topo_at(at).neighbors(id),
        }
    }

    /// Visit `id`'s neighbors at time `at` in ascending order (the
    /// encode-once broadcast path).  Lock-free on both paths, so `f` may
    /// re-enter the hub — or the overlay — freely.
    pub fn for_each_neighbor(&self, at: SimTime, id: ClientId, f: impl FnMut(ClientId)) {
        match &self.state {
            OverlayState::Static(topo) => topo.for_each_neighbor(id, f),
            OverlayState::Dynamic(c) => c.topo_at(at).for_each_neighbor(id, f),
        }
    }

    /// Monotonic change counter at time `at`: the number of schedule
    /// events due by `at` — 0 until the first event applies (and forever
    /// on a static overlay).  Cheap enough to poll once per protocol
    /// round.
    pub fn generation(&self, at: SimTime) -> u64 {
        match &self.state {
            OverlayState::Static(_) => 0,
            OverlayState::Dynamic(c) => {
                let t = c.touch(at);
                c.snaps.partition_point(|s| s.at_nanos <= t) as u64
            }
        }
    }

    /// Total overlay edges severed by the events due at the latest time
    /// any query has reached (the atomic high-water — see module docs).
    pub fn edges_severed(&self) -> u64 {
        match &self.state {
            OverlayState::Static(_) => 0,
            OverlayState::Dynamic(c) => match c.hw.load(Ordering::Relaxed) {
                0 => 0,
                hw1 => {
                    let idx = c.snaps.partition_point(|s| s.at_nanos <= hw1 - 1);
                    if idx == 0 {
                        0
                    } else {
                        c.snaps[idx - 1].severed
                    }
                }
            },
        }
    }
}

impl Compiled {
    /// Bump the queried-time high-water, returning `at` in nanos.
    fn touch(&self, at: SimTime) -> u64 {
        let t = nanos(at);
        self.hw.fetch_max(t.saturating_add(1), Ordering::Relaxed);
        t
    }

    /// The graph as of time `at`: the last snapshot due, or the base.
    fn topo_at(&self, at: SimTime) -> &Arc<Topology> {
        let t = self.touch(at);
        let idx = self.snaps.partition_point(|s| s.at_nanos <= t);
        if idx == 0 {
            &self.base
        } else {
            &self.snaps[idx - 1].topo
        }
    }
}

impl Replay {
    fn apply(&mut self, action: GraphAction) {
        match action {
            GraphAction::Cut { cut_id, edges } => {
                let mut claims = Vec::with_capacity(edges.len());
                for (a, b) in edges {
                    let e = (a.min(b), a.max(b));
                    let entry = self.cut_refs.entry(e).or_default();
                    entry.refs += 1;
                    if self.topo.remove_edge(e.0, e.1) {
                        entry.removed_by_cut = true;
                        self.severed += 1;
                    }
                    claims.push(e);
                }
                self.claims[cut_id] = claims;
            }
            GraphAction::Restore { cut_id } => {
                for (a, b) in std::mem::take(&mut self.claims[cut_id]) {
                    let entry =
                        // dfl-lint: allow(no-panic-hot-path) — every edge in claims[cut_id] inserted a cut_refs entry when the window opened; Restore replays the same compiled schedule
                        self.cut_refs.get_mut(&(a, b)).expect("claimed edge has a refcount");
                    entry.refs -= 1;
                    if entry.refs > 0 {
                        continue; // another cut window still holds the edge down
                    }
                    let heal = entry.removed_by_cut
                        && !self.departed[a as usize]
                        && !self.departed[b as usize];
                    self.cut_refs.remove(&(a, b));
                    if heal {
                        self.topo.add_edge(a, b);
                    }
                }
            }
            GraphAction::Depart(c) => {
                self.departed[c as usize] = true;
                let removed = self.topo.depart(c);
                self.severed += removed.len() as u64;
                self.enforce_open_cuts();
            }
            GraphAction::Rejoin(c) => {
                self.departed[c as usize] = false;
                let nth = self.rejoins[c as usize] as u64;
                self.rejoins[c as usize] += 1;
                // Vary the regeneration stream per rejoin event so a
                // client that churns twice does not rebuild the same
                // chords both times.
                self.topo.regenerate(self.seed ^ (nth << 48), c);
                self.enforce_open_cuts();
            }
        }
    }

    /// Churn repair and rejoin regeneration pick edges by graph shape,
    /// not by fault schedule — either can innocently re-create an edge an
    /// open cut window deliberately severed, silently bridging the
    /// partition under test.  Strip any currently-claimed edge they
    /// re-added; the eventual restore re-heals it through the normal
    /// refcounted path.  (Stripped re-creations are not counted as
    /// severed: the cut already paid for them when it opened.)
    fn enforce_open_cuts(&mut self) {
        let claimed: Vec<(ClientId, ClientId)> =
            self.cut_refs.iter().filter(|(_, r)| r.refs > 0).map(|(&e, _)| e).collect();
        for (a, b) in claimed {
            if self.topo.remove_edge(a, b) {
                // The strip is a cut-caused removal: mark it so the heal
                // path gives the edge back when the window closes.
                if let Some(r) = self.cut_refs.get_mut(&(a, b)) {
                    r.removed_by_cut = true;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::topology::TopologySpec;
    use std::time::Duration;

    fn ms(v: u64) -> SimTime {
        Duration::from_millis(v)
    }

    #[test]
    fn static_overlay_never_changes() {
        let topo = Arc::new(TopologySpec::Ring { k: 1 }.build(6, 1).unwrap());
        let ov = Overlay::immutable(Arc::clone(&topo));
        assert!(!ov.is_dynamic());
        assert_eq!(ov.generation(ms(10_000)), 0);
        assert_eq!(ov.neighbors(ms(10_000), 0), topo.neighbors(0));
        assert_eq!(ov.edges_severed(), 0);
    }

    #[test]
    fn cut_window_opens_and_heals_lazily() {
        let topo = TopologySpec::Ring { k: 1 }.build(6, 1).unwrap();
        let events = vec![
            GraphEvent {
                at: ms(100),
                action: GraphAction::Cut { cut_id: 0, edges: vec![(0, 1), (3, 4)] },
            },
            GraphEvent { at: ms(200), action: GraphAction::Restore { cut_id: 0 } },
        ];
        let ov = Overlay::with_events(topo, events, 1, 7);
        assert!(ov.is_dynamic());
        assert_eq!(ov.neighbors(ms(0), 0), vec![1, 5], "before the window");
        assert_eq!(ov.generation(ms(99)), 0);
        assert_eq!(ov.neighbors(ms(100), 0), vec![5], "window start is inclusive");
        assert_eq!(ov.generation(ms(100)), 1);
        assert_eq!(ov.edges_severed(), 2);
        assert_eq!(ov.neighbors(ms(200), 0), vec![1, 5], "healed at window end");
        assert_eq!(ov.generation(ms(200)), 2);
        assert_eq!(ov.edges_severed(), 2, "healing does not re-count");
    }

    #[test]
    fn a_skipped_queried_time_still_applies_every_due_event() {
        // Lazy application: a single late query applies the whole prefix.
        let topo = TopologySpec::Ring { k: 1 }.build(6, 1).unwrap();
        let events = vec![
            GraphEvent {
                at: ms(10),
                action: GraphAction::Cut { cut_id: 0, edges: vec![(0, 1)] },
            },
            GraphEvent { at: ms(20), action: GraphAction::Restore { cut_id: 0 } },
            GraphEvent { at: ms(30), action: GraphAction::Depart(3) },
        ];
        let ov = Overlay::with_events(topo, events, 1, 7);
        assert_eq!(ov.neighbors(ms(1_000), 0), vec![1, 5]);
        assert_eq!(ov.neighbors(ms(1_000), 3), Vec::<ClientId>::new());
        assert_eq!(ov.generation(ms(1_000)), 3);
    }

    #[test]
    fn churn_departure_and_rejoin_rewire_deterministically() {
        let make = || {
            let topo = TopologySpec::KRegular { d: 4 }.build(12, 5).unwrap();
            let events = vec![
                GraphEvent { at: ms(50), action: GraphAction::Depart(4) },
                GraphEvent { at: ms(150), action: GraphAction::Rejoin(4) },
            ];
            Overlay::with_events(topo, events, 0, 99)
        };
        let ov = make();
        let before = ov.neighbors(ms(0), 4);
        assert!(!before.is_empty());
        assert_eq!(ov.neighbors(ms(60), 4), Vec::<ClientId>::new(), "departed");
        assert!(ov.edges_severed() >= before.len() as u64);
        let after = ov.neighbors(ms(160), 4);
        assert!(after.len() >= 2, "rejoin must regenerate edges: {after:?}");
        // neighbors see the rejoined client symmetrically
        for &p in &after {
            assert!(ov.neighbors(ms(160), p).contains(&4));
        }
        // the whole history is a pure function of (topology, schedule, seed)
        let again = make();
        again.generation(ms(1_000));
        assert_eq!(again.neighbors(ms(1_000), 4), ov.neighbors(ms(1_000), 4));
    }

    #[test]
    fn overlapping_cuts_sharing_edges_compose_instead_of_cancelling() {
        // Two cut windows claiming the same edge (what two `mincut`
        // faults of one seed always do): the first heal must NOT re-add
        // the edge while the second window is still open — the edge
        // heals only when its last claiming window closes.
        let topo = TopologySpec::Ring { k: 1 }.build(6, 1).unwrap();
        let shared = vec![(0u32, 1u32)];
        let events = vec![
            GraphEvent {
                at: ms(10),
                action: GraphAction::Cut { cut_id: 0, edges: shared.clone() },
            },
            GraphEvent {
                at: ms(30),
                action: GraphAction::Cut { cut_id: 1, edges: shared },
            },
            GraphEvent { at: ms(50), action: GraphAction::Restore { cut_id: 0 } },
            GraphEvent { at: ms(90), action: GraphAction::Restore { cut_id: 1 } },
        ];
        let ov = Overlay::with_events(topo, events, 2, 7);
        assert!(!ov.neighbors(ms(20), 0).contains(&1), "first window open");
        assert!(
            !ov.neighbors(ms(60), 0).contains(&1),
            "first heal must not breach the still-open second window"
        );
        assert!(ov.neighbors(ms(90), 0).contains(&1), "healed at the last window's end");
        assert_eq!(ov.edges_severed(), 1, "one physical removal, however many claims");
    }

    #[test]
    fn churn_repair_cannot_bridge_an_open_cut() {
        // ring:2 on 8: departing client 3 orphans {1, 2, 4, 5}, and the
        // repair cycle over them would re-create (2, 4) — which the open
        // cut window deliberately severed.  The overlay must keep the
        // claimed edge down for the rest of the window, then heal it.
        let topo = TopologySpec::Ring { k: 2 }.build(8, 1).unwrap();
        assert!(topo.has_edge(2, 4), "test premise: (2,4) is an overlay edge");
        let events = vec![
            GraphEvent {
                at: ms(10),
                action: GraphAction::Cut { cut_id: 0, edges: vec![(2, 4)] },
            },
            GraphEvent { at: ms(20), action: GraphAction::Depart(3) },
            GraphEvent { at: ms(100), action: GraphAction::Restore { cut_id: 0 } },
        ];
        let ov = Overlay::with_events(topo, events, 1, 7);
        assert!(
            !ov.neighbors(ms(30), 2).contains(&4),
            "the repair cycle must not breach the open cut window"
        );
        assert!(ov.neighbors(ms(100), 2).contains(&4), "cut heals at window end");
    }

    #[test]
    fn restore_skips_edges_into_a_departed_client() {
        let topo = TopologySpec::Ring { k: 1 }.build(6, 1).unwrap();
        let events = vec![
            GraphEvent {
                at: ms(10),
                action: GraphAction::Cut { cut_id: 0, edges: vec![(0, 1)] },
            },
            GraphEvent { at: ms(20), action: GraphAction::Depart(1) },
            GraphEvent { at: ms(30), action: GraphAction::Restore { cut_id: 0 } },
        ];
        let ov = Overlay::with_events(topo, events, 1, 7);
        assert!(
            !ov.neighbors(ms(40), 0).contains(&1),
            "healing must not resurrect a departed client's edge"
        );
        assert_eq!(ov.neighbors(ms(40), 1), Vec::<ClientId>::new());
    }
}
