//! # dfl — Fault-Tolerant Decentralized Asynchronous Federated Learning
//!
//! Reproduction of *"Fault-Tolerant Decentralized Distributed Asynchronous
//! Federated Learning with Adaptive Termination Detection"* (CS.DC 2025) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: a decentralized
//!   peer-to-peer FL coordinator with round-based ([`coordinator::sync`]) and
//!   fully asynchronous ([`coordinator::async_client`]) protocols,
//!   timeout-based crash detection ([`coordinator::failure`]), and the
//!   *Client-Confident Convergence* / *Client-Responsive Termination*
//!   mechanisms ([`coordinator::termination`]).
//! * **L2/L1 (build-time python)** — the CNN fwd/bwd, FedAvg aggregation and
//!   SGD update, authored in JAX on Pallas kernels and AOT-lowered to HLO
//!   text in `artifacts/` (`make artifacts`).
//! * **Runtime bridge** — [`runtime`] loads the artifacts once per process
//!   via the PJRT CPU client and executes them on the request path; python is
//!   never imported at runtime.
//!
//! Entry points: [`sim::run`] (in-process N-client deployments used by the
//! experiment harness — wall-clock, or the deterministic virtual-time mode
//! built on [`util::time`], driven either by one thread per client or by
//! the zero-thread event executor over [`coordinator::machine`] state
//! machines, [`sim::ExecMode`]), the `dfl` binary (CLI + real TCP
//! clients), and the `examples/` directory.  The testbed model (virtual machines,
//! synthetic data, time regimes, network-scenario matrix) is specified in
//! the repo-root `DESIGN.md`.

// Docs are part of the CI contract: a dangling [`reference`] fails
// `cargo doc --no-deps` (the doc check tier-1 runs alongside the tests).
#![deny(rustdoc::broken_intra_doc_links)]

pub mod coordinator;
pub mod data;
pub mod exp;
pub mod metrics;
pub mod model;
pub mod net;
pub mod runtime;
pub mod sim;
pub mod util;

pub use coordinator::config::ProtocolConfig;
pub use model::ParamVector;
pub use runtime::{Engine, Meta, MockTrainer, SharedEngine, Trainer};
