//! Allocation audit (the `alloc-audit` feature, DESIGN.md §14).
//!
//! When the feature is enabled this module installs a counting
//! [`GlobalAlloc`](std::alloc::GlobalAlloc) that wraps the system
//! allocator with four relaxed atomic counters.  [`AllocStats`] is the
//! read side: diff two snapshots around a workload to measure its
//! allocator traffic (`tests/alloc_budget.rs` pins the steady-state round
//! loop this way).  Without the feature nothing is installed, the type
//! still exists, and every snapshot is zero — callers never need their own
//! `cfg` gates.

/// Snapshot of the process-wide allocation counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AllocStats {
    /// Allocator acquisitions since process start (`alloc`, `alloc_zeroed`,
    /// and the new block of every successful `realloc`).
    pub allocs: u64,
    /// Releases since process start (`dealloc` and the old block of every
    /// successful `realloc`).
    pub frees: u64,
    /// Bytes currently live (allocated minus freed).
    pub live_bytes: u64,
    /// High-water mark of `live_bytes`.
    pub peak_bytes: u64,
}

impl AllocStats {
    /// Is the counting allocator compiled in?
    pub fn enabled() -> bool {
        cfg!(feature = "alloc-audit")
    }

    /// Current counters (all zero without the `alloc-audit` feature).
    pub fn snapshot() -> AllocStats {
        #[cfg(feature = "alloc-audit")]
        {
            audit::snapshot()
        }
        #[cfg(not(feature = "alloc-audit"))]
        {
            AllocStats::default()
        }
    }

    /// Allocator acquisitions between this snapshot and a `later` one.
    pub fn allocs_since(&self, later: &AllocStats) -> u64 {
        later.allocs.saturating_sub(self.allocs)
    }
}

#[cfg(feature = "alloc-audit")]
mod audit {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicU64, Ordering};

    use super::AllocStats;

    static ALLOCS: AtomicU64 = AtomicU64::new(0);
    static FREES: AtomicU64 = AtomicU64::new(0);
    static LIVE: AtomicU64 = AtomicU64::new(0);
    static PEAK: AtomicU64 = AtomicU64::new(0);

    fn on_alloc(size: usize) {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        let live = LIVE.fetch_add(size as u64, Ordering::Relaxed) + size as u64;
        PEAK.fetch_max(live, Ordering::Relaxed);
    }

    fn on_free(size: usize) {
        FREES.fetch_add(1, Ordering::Relaxed);
        LIVE.fetch_sub(size as u64, Ordering::Relaxed);
    }

    /// The counting wrapper.  Counters update *after* the system call so a
    /// failed (null) allocation is never counted.
    struct Counting;

    unsafe impl GlobalAlloc for Counting {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            let p = System.alloc(layout);
            if !p.is_null() {
                on_alloc(layout.size());
            }
            p
        }

        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            let p = System.alloc_zeroed(layout);
            if !p.is_null() {
                on_alloc(layout.size());
            }
            p
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout);
            on_free(layout.size());
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            let p = System.realloc(ptr, layout, new_size);
            if !p.is_null() {
                // One allocator round-trip: the old block is gone, a new
                // (possibly same) block exists — count both sides so a
                // Vec growing in place still shows up as allocator traffic.
                on_free(layout.size());
                on_alloc(new_size);
            }
            p
        }
    }

    #[global_allocator]
    static AUDIT: Counting = Counting;

    pub(super) fn snapshot() -> AllocStats {
        AllocStats {
            allocs: ALLOCS.load(Ordering::Relaxed),
            frees: FREES.load(Ordering::Relaxed),
            live_bytes: LIVE.load(Ordering::Relaxed),
            peak_bytes: PEAK.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_is_coherent() {
        let a = AllocStats::snapshot();
        if AllocStats::enabled() {
            // Force allocator traffic and observe it.
            let v: Vec<u64> = Vec::with_capacity(1024);
            drop(std::hint::black_box(v));
            let b = AllocStats::snapshot();
            assert!(a.allocs_since(&b) >= 1, "an allocation must be counted");
            assert!(b.peak_bytes >= b.live_bytes.min(b.peak_bytes));
        } else {
            assert_eq!(a, AllocStats::default(), "feature off means zeros");
        }
    }
}
