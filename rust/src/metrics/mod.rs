//! Per-round metrics, client reports and CSV emission.

use std::io::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::termination::TerminationCause;
use crate::net::ClientId;

/// One row of a client's training log.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: u32,
    /// Mean local training loss this round.
    pub train_loss: f32,
    /// Probe accuracy after aggregation (eval_round artifact), in [0, 1].
    pub probe_acc: f32,
    /// Peers believed alive after this round's sweep.
    pub alive_peers: usize,
    /// Models aggregated this round (self + received).
    pub aggregated: usize,
    /// Convergence-monitor relative delta after this round.
    pub delta_rel: f32,
    /// CCC stability counter after this round.
    pub conv_counter: u32,
    /// Crashes detected this round.
    pub crashes_detected: Vec<ClientId>,
}

/// Everything a finished (or crashed) client hands back to the harness.
#[derive(Clone, Debug)]
pub struct ClientReport {
    pub id: ClientId,
    pub cause: TerminationCause,
    pub rounds_completed: u32,
    /// Full-test-set accuracy of the final model, in [0, 1]
    /// (None for crashed clients — they never finalize).
    pub final_accuracy: Option<f32>,
    pub final_loss: Option<f32>,
    pub wall: std::time::Duration,
    pub history: Vec<RoundRecord>,
    /// Who signalled us (CRT provenance), if terminated by signal.
    pub signal_source: Option<ClientId>,
    pub final_params: Option<Vec<f32>>,
}

impl ClientReport {
    /// Write the per-round history as CSV.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        writeln!(
            f,
            "round,train_loss,probe_acc,alive_peers,aggregated,delta_rel,conv_counter,crashes"
        )?;
        for r in &self.history {
            writeln!(
                f,
                "{},{},{},{},{},{},{},{}",
                r.round,
                r.train_loss,
                r.probe_acc,
                r.alive_peers,
                r.aggregated,
                r.delta_rel,
                r.conv_counter,
                r.crashes_detected
                    .iter()
                    .map(|c| c.to_string())
                    .collect::<Vec<_>>()
                    .join(";")
            )?;
        }
        Ok(())
    }
}

/// Mean of an f32 iterator (None when empty) — small shared helper.
pub fn mean<I: IntoIterator<Item = f32>>(xs: I) -> Option<f32> {
    let mut sum = 0.0f64;
    let mut n = 0usize;
    for x in xs {
        sum += x as f64;
        n += 1;
    }
    (n > 0).then(|| (sum / n as f64) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_works() {
        assert_eq!(mean([1.0, 2.0, 3.0]), Some(2.0));
        assert_eq!(mean([]), None);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let rep = ClientReport {
            id: 0,
            cause: TerminationCause::Converged,
            rounds_completed: 2,
            final_accuracy: Some(0.5),
            final_loss: Some(1.0),
            wall: std::time::Duration::from_millis(10),
            history: vec![RoundRecord {
                round: 0,
                train_loss: 2.0,
                probe_acc: 0.1,
                alive_peers: 3,
                aggregated: 4,
                delta_rel: 0.5,
                conv_counter: 0,
                crashes_detected: vec![2, 5],
            }],
            signal_source: None,
            final_params: None,
        };
        let path = std::env::temp_dir().join(format!("dfl_csv_{}.csv", std::process::id()));
        rep.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("round,"));
        assert!(text.contains("2;5"));
        std::fs::remove_file(&path).unwrap();
    }
}
