//! Per-round metrics, client reports and CSV emission.

mod alloc;

pub use alloc::AllocStats;

use std::io::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use crate::net::ClientId;

/// Why a client's main loop ended.
///
/// Defined here — where [`ClientReport`] records it — rather than in
/// `coordinator::termination` (which re-exports it), so that the metrics
/// layer has no upward dependency on the protocol layer (module-layering
/// DAG, DESIGN.md §15).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TerminationCause {
    /// CCC triggered locally: this client initiated termination.
    Converged,
    /// CRT: terminate flag received from a peer.
    Signaled,
    /// Hit `R_PRIME` (the hard round cap).
    MaxRounds,
    /// Injected crash (the client fell silent mid-run).
    Crashed,
}

/// One row of a client's training log.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: u32,
    /// Mean local training loss this round.
    pub train_loss: f32,
    /// Probe accuracy after aggregation (eval_round artifact), in [0, 1].
    pub probe_acc: f32,
    /// Peers believed alive after this round's sweep.
    pub alive_peers: usize,
    /// Models aggregated this round (self + received).
    pub aggregated: usize,
    /// Convergence-monitor relative delta after this round.
    pub delta_rel: f32,
    /// CCC stability counter after this round.
    pub conv_counter: u32,
    /// Crashes detected this round.
    pub crashes_detected: Vec<ClientId>,
}

/// Everything a finished (or crashed) client hands back to the harness.
#[derive(Clone, Debug)]
pub struct ClientReport {
    pub id: ClientId,
    pub cause: TerminationCause,
    pub rounds_completed: u32,
    /// Full-test-set accuracy of the final model, in [0, 1]
    /// (None for crashed clients — they never finalize).
    pub final_accuracy: Option<f32>,
    pub final_loss: Option<f32>,
    pub wall: std::time::Duration,
    pub history: Vec<RoundRecord>,
    /// Who signalled us (CRT provenance), if terminated by signal.
    pub signal_source: Option<ClientId>,
    pub final_params: Option<Vec<f32>>,
}

impl ClientReport {
    /// Write the per-round history as CSV.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        writeln!(
            f,
            "round,train_loss,probe_acc,alive_peers,aggregated,delta_rel,conv_counter,crashes"
        )?;
        for r in &self.history {
            writeln!(
                f,
                "{},{},{},{},{},{},{},{}",
                r.round,
                r.train_loss,
                r.probe_acc,
                r.alive_peers,
                r.aggregated,
                r.delta_rel,
                r.conv_counter,
                r.crashes_detected
                    .iter()
                    .map(|c| c.to_string())
                    .collect::<Vec<_>>()
                    .join(";")
            )?;
        }
        Ok(())
    }
}

/// Aggregate network-traffic counters of one simulated deployment (both
/// in-proc hubs count; collected into `sim::SimResult::net`).  This is
/// how the topology layer's O(n·d) claim is *measured* instead of argued:
/// a full mesh sends ~n·(n−1) updates per round, a degree-d overlay ~n·d.
///
/// `msgs_sent`/`bytes_sent` count every send attempt a client made (the
/// offered load); `msgs_delivered` counts what the network actually
/// handed (or scheduled) to a receiver; `msgs_dropped` is the difference
/// — injected link blocks, partitions, and sampled (independent or
/// burst) loss.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NetStats {
    pub msgs_sent: u64,
    pub msgs_delivered: u64,
    pub msgs_dropped: u64,
    pub bytes_sent: u64,
    /// Overlay edges severed by the deployment's fault schedule: the
    /// crossing counts of every validated `NetSplit` window plus every
    /// edge a graph fault (cut or churn departure) actually removed —
    /// the measured "how hard was the graph attacked" axis of the
    /// `exp::faults` sweep.  Zero on a fault-free run.
    pub edges_severed: u64,
    /// Bytes the delta codec (DESIGN.md §13) kept off the wire: the sum
    /// over delta-mode sends of `dense_encoding_size − actual_wire_size`.
    /// Zero under `--codec dense`.
    pub bytes_saved: u64,
    /// Delta-mode sends that rode a sparse delta or a compact flag relay
    /// (the codec doing its job).
    pub delta_hits: u64,
    /// Delta-mode sends that fell back to a full snapshot (boot, rejoin,
    /// cut heal, NACK, non-finite q16 payloads).
    pub delta_full: u64,
}

impl NetStats {
    /// Mean messages offered per protocol round (the O(n·d) vs O(n²)
    /// comparison axis; `rounds` from `sim::SimResult::rounds`).
    pub fn msgs_per_round(&self, rounds: u32) -> f64 {
        self.msgs_sent as f64 / rounds.max(1) as f64
    }

    /// Mean bytes offered per protocol round.
    pub fn bytes_per_round(&self, rounds: u32) -> f64 {
        self.bytes_sent as f64 / rounds.max(1) as f64
    }

    /// Fraction of delta-codec sends that avoided a full snapshot, in
    /// [0, 1] (0 when the codec never ran, i.e. under `--codec dense`).
    pub fn delta_hit_rate(&self) -> f64 {
        let total = self.delta_hits + self.delta_full;
        if total == 0 {
            0.0
        } else {
            self.delta_hits as f64 / total as f64
        }
    }
}

/// Mean of an f32 iterator (None when empty) — small shared helper.
pub fn mean<I: IntoIterator<Item = f32>>(xs: I) -> Option<f32> {
    let mut sum = 0.0f64;
    let mut n = 0usize;
    for x in xs {
        sum += x as f64;
        n += 1;
    }
    (n > 0).then(|| (sum / n as f64) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn net_stats_per_round_guards_zero_rounds() {
        let s = NetStats {
            msgs_sent: 120,
            msgs_delivered: 100,
            msgs_dropped: 20,
            bytes_sent: 1200,
            edges_severed: 0,
            bytes_saved: 0,
            delta_hits: 0,
            delta_full: 0,
        };
        assert_eq!(s.msgs_per_round(10), 12.0);
        assert_eq!(s.bytes_per_round(10), 120.0);
        assert_eq!(s.msgs_per_round(0), 120.0, "0 rounds must not divide by zero");
    }

    #[test]
    fn delta_hit_rate_guards_empty_and_divides() {
        let mut s = NetStats::default();
        assert_eq!(s.delta_hit_rate(), 0.0, "dense runs report 0, not NaN");
        s.delta_hits = 3;
        s.delta_full = 1;
        assert_eq!(s.delta_hit_rate(), 0.75);
    }

    #[test]
    fn mean_works() {
        assert_eq!(mean([1.0, 2.0, 3.0]), Some(2.0));
        assert_eq!(mean([]), None);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let rep = ClientReport {
            id: 0,
            cause: TerminationCause::Converged,
            rounds_completed: 2,
            final_accuracy: Some(0.5),
            final_loss: Some(1.0),
            wall: std::time::Duration::from_millis(10),
            history: vec![RoundRecord {
                round: 0,
                train_loss: 2.0,
                probe_acc: 0.1,
                alive_peers: 3,
                aggregated: 4,
                delta_rel: 0.5,
                conv_counter: 0,
                crashes_detected: vec![2, 5],
            }],
            signal_source: None,
            final_params: None,
        };
        let path = std::env::temp_dir().join(format!("dfl_csv_{}.csv", std::process::id()));
        rep.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("round,"));
        assert!(text.contains("2;5"));
        std::fs::remove_file(&path).unwrap();
    }
}
