//! Byzantine-robust aggregation rules (DESIGN.md §11).
//!
//! [`AggregationRule`] selects how a wait-window's `(model, weight)` rows
//! are combined.  `fedavg` delegates to [`crate::runtime::Trainer::aggregate`]
//! unchanged — the byte-identical default — while the robust rules are
//! order statistics computed here, *unweighted*: an adversary controls
//! its own claimed weight, so any weight-respecting robust rule hands the
//! attacker a second dial.  Dropping weights costs nothing in the
//! all-honest equal-weight case (every rule then agrees with FedAvg on
//! identical inputs) and removes the dial under attack.
//!
//! * **trimmed-mean:F** — per coordinate, drop the `F` lowest and `F`
//!   highest values, average the rest (tolerates `F` outliers per side).
//! * **coord-median** — per-coordinate median (mean of the two middle
//!   values for even row counts, so the result is deterministic and
//!   permutation-invariant).
//! * **krum:F** — pick the single row minimizing the summed squared
//!   distance to its `n − F − 2` nearest peers (Blanchard et al., NeurIPS
//!   2017): a poisoned outlier is far from the honest cluster, so it can
//!   never win the score.

use anyhow::{bail, Result};

use super::AggScratch;

/// How the wait-window rows are combined (`ProtocolConfig::agg`,
/// `dfl sim --agg`).  Parsed/printed via [`AggregationRule::parse`] /
/// [`AggregationRule::name`] like [`crate::coordinator::QuorumSpec`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AggregationRule {
    /// Weighted FedAvg via the trainer's own `aggregate` — the pre-PR
    /// path, byte-identical by construction.
    #[default]
    FedAvg,
    /// Per-coordinate trimmed mean dropping `f` values per side.
    TrimmedMean { f: usize },
    /// Per-coordinate median.
    CoordMedian,
    /// Multi-Krum with `f` presumed adversaries (selects one row).
    Krum { f: usize },
}

impl AggregationRule {
    /// Parse the CLI spelling: `fedavg | trimmed-mean:F | coord-median |
    /// krum:F`.
    ///
    /// ```
    /// use dfl::runtime::AggregationRule;
    /// assert_eq!(AggregationRule::parse("fedavg").unwrap(), AggregationRule::FedAvg);
    /// assert_eq!(
    ///     AggregationRule::parse("trimmed-mean:2").unwrap(),
    ///     AggregationRule::TrimmedMean { f: 2 }
    /// );
    /// assert!(AggregationRule::parse("krum").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<AggregationRule> {
        let f_of = |v: Option<&str>, kind: &str| -> Result<usize> {
            v.and_then(|x| x.parse::<usize>().ok())
                .ok_or_else(|| anyhow::anyhow!("aggregation rule {s:?}: {kind} wants {kind}:F"))
        };
        let (kind, rest) = match s.split_once(':') {
            Some((k, r)) => (k, Some(r)),
            None => (s, None),
        };
        match kind {
            "fedavg" if rest.is_none() => Ok(AggregationRule::FedAvg),
            "coord-median" | "median" if rest.is_none() => Ok(AggregationRule::CoordMedian),
            "trimmed-mean" => Ok(AggregationRule::TrimmedMean { f: f_of(rest, "trimmed-mean")? }),
            "krum" => Ok(AggregationRule::Krum { f: f_of(rest, "krum")? }),
            _ => bail!("unknown aggregation rule {s:?} (want fedavg|trimmed-mean:F|coord-median|krum:F)"),
        }
    }

    /// The CLI spelling (round-trips through [`AggregationRule::parse`]).
    pub fn name(&self) -> String {
        match self {
            AggregationRule::FedAvg => "fedavg".into(),
            AggregationRule::TrimmedMean { f } => format!("trimmed-mean:{f}"),
            AggregationRule::CoordMedian => "coord-median".into(),
            AggregationRule::Krum { f } => format!("krum:{f}"),
        }
    }
}

/// Shape check shared by the robust rules (they bypass the trainer's
/// `aggregate`, so they validate on their own).
fn check_rows(rows: &[(&[f32], f32)]) -> Result<usize> {
    let Some(&(first, _)) = rows.first() else {
        bail!("robust aggregate called with zero rows");
    };
    for (i, (p, _)) in rows.iter().enumerate() {
        if p.len() != first.len() {
            bail!("robust aggregate row {i} has {} params, want {}", p.len(), first.len());
        }
    }
    Ok(first.len())
}

/// Apply a non-FedAvg rule to the rows.  Callers reach this through
/// [`crate::runtime::Trainer::aggregate_with`], which routes FedAvg to
/// the trainer instead.
pub(crate) fn apply(rows: &[(&[f32], f32)], rule: &AggregationRule) -> Result<Vec<f32>> {
    let mut s = AggScratch::default();
    apply_into(rows, rule, &mut s)?;
    Ok(s.out)
}

/// Scratch-reusing [`apply`]: the result lands in `s.out`, and the column /
/// distance working buffers keep their capacity across rounds.  Bit-identical
/// to [`apply`] — every buffer is fully overwritten before it is read.
pub(crate) fn apply_into(
    rows: &[(&[f32], f32)],
    rule: &AggregationRule,
    s: &mut AggScratch,
) -> Result<()> {
    match *rule {
        AggregationRule::FedAvg => bail!("fedavg is handled by the trainer, not the robust path"),
        AggregationRule::TrimmedMean { f } => trimmed_mean_into(rows, f, &mut s.out, &mut s.col),
        AggregationRule::CoordMedian => coord_median_into(rows, &mut s.out, &mut s.col),
        AggregationRule::Krum { f } => krum_into(rows, f, &mut s.out, &mut s.dists),
    }
}

/// Per-coordinate trimmed mean.  `f` is clamped so at least one value
/// survives the trim (`f ≤ (n−1)/2`): a window smaller than the
/// configured tolerance degrades toward the median instead of erroring,
/// which matters because wait-window sizes vary round to round.
pub fn trimmed_mean(rows: &[(&[f32], f32)], f: usize) -> Result<Vec<f32>> {
    let mut out = Vec::new();
    let mut col = Vec::new();
    trimmed_mean_into(rows, f, &mut out, &mut col)?;
    Ok(out)
}

fn trimmed_mean_into(
    rows: &[(&[f32], f32)],
    f: usize,
    out: &mut Vec<f32>,
    col: &mut Vec<f32>,
) -> Result<()> {
    let dim = check_rows(rows)?;
    let n = rows.len();
    let f = f.min((n - 1) / 2);
    let keep = (n - 2 * f) as f32;
    out.clear();
    out.resize(dim, 0.0);
    col.clear();
    col.resize(n, 0.0);
    for (j, o) in out.iter_mut().enumerate() {
        for (i, (p, _)) in rows.iter().enumerate() {
            col[i] = p[j];
        }
        // total_cmp: NaN sorts deterministically instead of panicking, so
        // a poisoned NaN coordinate lands at the top and gets trimmed.
        col.sort_unstable_by(f32::total_cmp);
        *o = col[f..n - f].iter().sum::<f32>() / keep;
    }
    Ok(())
}

/// Per-coordinate median; even row counts average the two middle values.
pub fn coord_median(rows: &[(&[f32], f32)]) -> Result<Vec<f32>> {
    let mut out = Vec::new();
    let mut col = Vec::new();
    coord_median_into(rows, &mut out, &mut col)?;
    Ok(out)
}

fn coord_median_into(rows: &[(&[f32], f32)], out: &mut Vec<f32>, col: &mut Vec<f32>) -> Result<()> {
    let dim = check_rows(rows)?;
    let n = rows.len();
    out.clear();
    out.resize(dim, 0.0);
    col.clear();
    col.resize(n, 0.0);
    for (j, o) in out.iter_mut().enumerate() {
        for (i, (p, _)) in rows.iter().enumerate() {
            col[i] = p[j];
        }
        col.sort_unstable_by(f32::total_cmp);
        *o = if n % 2 == 1 { col[n / 2] } else { (col[n / 2 - 1] + col[n / 2]) / 2.0 };
    }
    Ok(())
}

/// Krum: return the row with the smallest summed squared distance to its
/// `max(1, n − f − 2)` nearest peers (clamped to the `n − 1` available).
/// Ties break toward the lower row index, so the result is deterministic.
pub fn krum(rows: &[(&[f32], f32)], f: usize) -> Result<Vec<f32>> {
    let mut out = Vec::new();
    let mut dists = Vec::new();
    krum_into(rows, f, &mut out, &mut dists)?;
    Ok(out)
}

fn krum_into(
    rows: &[(&[f32], f32)],
    f: usize,
    out: &mut Vec<f32>,
    dists: &mut Vec<f64>,
) -> Result<()> {
    check_rows(rows)?;
    let n = rows.len();
    if n == 1 {
        out.clear();
        out.extend_from_slice(rows[0].0);
        return Ok(());
    }
    let closest = n.saturating_sub(f + 2).max(1).min(n - 1);
    let mut best: Option<(f64, usize)> = None;
    dists.clear();
    dists.resize(n - 1, 0.0);
    for i in 0..n {
        let mut k = 0;
        for j in 0..n {
            if i == j {
                continue;
            }
            let d: f64 = rows[i]
                .0
                .iter()
                .zip(rows[j].0)
                .map(|(a, b)| {
                    let d = (*a as f64) - (*b as f64);
                    d * d
                })
                .sum();
            dists[k] = d;
            k += 1;
        }
        dists.sort_unstable_by(f64::total_cmp);
        let score: f64 = dists[..closest].iter().sum();
        if best.map_or(true, |(s, _)| score < s) {
            best = Some((score, i));
        }
    }
    out.clear();
    out.extend_from_slice(rows[best.expect("n >= 2 rows scored").1].0);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_parse_round_trips() {
        for s in ["fedavg", "trimmed-mean:2", "coord-median", "krum:1"] {
            let r = AggregationRule::parse(s).unwrap();
            assert_eq!(AggregationRule::parse(&r.name()).unwrap(), r, "{s}");
        }
        assert_eq!(AggregationRule::parse("median").unwrap(), AggregationRule::CoordMedian);
        assert_eq!(AggregationRule::default(), AggregationRule::FedAvg);
        for bad in ["", "krum", "trimmed-mean", "trimmed-mean:x", "fedavg:1", "mode"] {
            assert!(AggregationRule::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn identical_rows_pass_through_every_rule() {
        let row = [1.0f32, -2.0, 3.5];
        let rows: Vec<(&[f32], f32)> = (0..5).map(|_| (&row[..], 1.0)).collect();
        for rule in [
            AggregationRule::TrimmedMean { f: 1 },
            AggregationRule::CoordMedian,
            AggregationRule::Krum { f: 1 },
        ] {
            assert_eq!(apply(&rows, &rule).unwrap(), row.to_vec(), "{}", rule.name());
        }
    }

    #[test]
    fn trimmed_mean_drops_extremes() {
        let rows: Vec<Vec<f32>> =
            vec![vec![0.0], vec![1.0], vec![2.0], vec![3.0], vec![100.0]];
        let refs: Vec<(&[f32], f32)> = rows.iter().map(|r| (r.as_slice(), 1.0)).collect();
        assert_eq!(trimmed_mean(&refs, 1).unwrap(), vec![2.0]);
        // f too large for the window: clamps to (n-1)/2 = 2 → median-like
        assert_eq!(trimmed_mean(&refs, 10).unwrap(), vec![2.0]);
        // NaN sorts high under total_cmp and gets trimmed away
        let poisoned: Vec<Vec<f32>> =
            vec![vec![1.0], vec![2.0], vec![3.0], vec![f32::NAN]];
        let refs: Vec<(&[f32], f32)> = poisoned.iter().map(|r| (r.as_slice(), 1.0)).collect();
        assert!(trimmed_mean(&refs, 1).unwrap()[0].is_finite());
    }

    #[test]
    fn median_even_and_odd() {
        let rows: Vec<Vec<f32>> = vec![vec![1.0], vec![5.0], vec![3.0]];
        let refs: Vec<(&[f32], f32)> = rows.iter().map(|r| (r.as_slice(), 1.0)).collect();
        assert_eq!(coord_median(&refs).unwrap(), vec![3.0]);
        let rows: Vec<Vec<f32>> = vec![vec![1.0], vec![5.0], vec![3.0], vec![7.0]];
        let refs: Vec<(&[f32], f32)> = rows.iter().map(|r| (r.as_slice(), 1.0)).collect();
        assert_eq!(coord_median(&refs).unwrap(), vec![4.0]);
    }

    #[test]
    fn krum_picks_the_cluster() {
        let rows: Vec<Vec<f32>> = vec![
            vec![1.0, 1.0],
            vec![1.1, 0.9],
            vec![0.9, 1.1],
            vec![-50.0, 80.0], // the outlier can never win
        ];
        let refs: Vec<(&[f32], f32)> = rows.iter().map(|r| (r.as_slice(), 1.0)).collect();
        let out = krum(&refs, 1).unwrap();
        assert!(rows[..3].iter().any(|r| r.as_slice() == out.as_slice()));
        // single row: trivially itself
        let one: Vec<(&[f32], f32)> = vec![(rows[0].as_slice(), 1.0)];
        assert_eq!(krum(&one, 1).unwrap(), rows[0]);
    }

    #[test]
    fn apply_into_with_dirty_scratch_matches_apply() {
        let rows: Vec<Vec<f32>> = vec![
            vec![1.0, -4.0, 2.5],
            vec![1.2, -3.8, 2.4],
            vec![0.8, -4.1, 2.7],
            vec![50.0, 9.0, -1.0],
        ];
        let refs: Vec<(&[f32], f32)> = rows.iter().map(|r| (r.as_slice(), 1.0)).collect();
        let mut s = AggScratch::default();
        // Poison the scratch so any read-before-write would show up.
        s.out = vec![f32::NAN; 17];
        s.col = vec![f32::NAN; 3];
        s.dists = vec![f64::NAN; 9];
        for rule in [
            AggregationRule::TrimmedMean { f: 1 },
            AggregationRule::CoordMedian,
            AggregationRule::Krum { f: 1 },
        ] {
            let plain = apply(&refs, &rule).unwrap();
            apply_into(&refs, &rule, &mut s).unwrap();
            assert_eq!(plain, s.out, "{}", rule.name());
        }
    }

    #[test]
    fn robust_rules_reject_bad_shapes() {
        assert!(coord_median(&[]).is_err());
        let a = [1.0f32, 2.0];
        let b = [1.0f32];
        let rows: Vec<(&[f32], f32)> = vec![(&a, 1.0), (&b, 1.0)];
        assert!(trimmed_mean(&rows, 0).is_err());
        assert!(krum(&rows, 0).is_err());
    }
}
