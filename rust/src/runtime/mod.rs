//! Runtime bridge: load the AOT artifacts (`artifacts/<cfg>/*.hlo.txt`)
//! once per process via the PJRT CPU client and execute them on the hot
//! path.  Python never runs at request time.
//!
//! The coordinator is written against the [`Trainer`] trait so protocol
//! logic can be unit/property-tested with the deterministic [`MockTrainer`]
//! while deployments use the PJRT-backed [`Engine`] / [`SharedEngine`].

// The PJRT engine needs the external `xla` crate, which the offline build
// image does not ship; without the `pjrt` feature a same-API stub keeps the
// crate (and everything written against `SharedEngine`) compiling, erroring
// only at artifact-load time.
#[cfg(feature = "pjrt")]
mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
mod engine;
mod mock;
mod robust;

pub use engine::{Engine, SharedEngine};
pub use mock::MockTrainer;
pub use robust::AggregationRule;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Artifact metadata emitted by `python -m compile.aot` (`meta.txt`):
/// the exact static shapes every request-path call must feed.
#[derive(Clone, Debug, PartialEq)]
pub struct Meta {
    pub config: String,
    pub n_params: usize,
    pub img: usize,
    pub channels: usize,
    pub classes: usize,
    pub batch: usize,
    pub nb_train: usize,
    pub nb_eval_round: usize,
    pub nb_eval_full: usize,
    pub k_max: usize,
}

impl Meta {
    pub fn parse(text: &str) -> Result<Meta> {
        let mut kv = BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("bad meta line {line:?}"))?;
            kv.insert(k.to_string(), v.to_string());
        }
        let get = |k: &str| -> Result<usize> {
            kv.get(k)
                .with_context(|| format!("meta missing key {k}"))?
                .parse::<usize>()
                .with_context(|| format!("meta key {k} not an integer"))
        };
        Ok(Meta {
            config: kv.get("config").cloned().unwrap_or_default(),
            n_params: get("n_params")?,
            img: get("img")?,
            channels: get("channels")?,
            classes: get("classes")?,
            batch: get("batch")?,
            nb_train: get("nb_train")?,
            nb_eval_round: get("nb_eval_round")?,
            nb_eval_full: get("nb_eval_full")?,
            k_max: get("k_max")?,
        })
    }

    pub fn load(dir: &Path) -> Result<Meta> {
        let path = dir.join("meta.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Meta::parse(&text)
    }

    /// Element count of one training-round image tensor (nb, B, img, img, C).
    pub fn train_x_len(&self) -> usize {
        self.nb_train * self.batch * self.img * self.img * self.channels
    }

    pub fn train_y_len(&self) -> usize {
        self.nb_train * self.batch
    }

    pub fn eval_x_len(&self, full: bool) -> usize {
        let nb = if full { self.nb_eval_full } else { self.nb_eval_round };
        nb * self.batch * self.img * self.img * self.channels
    }

    pub fn eval_y_len(&self, full: bool) -> usize {
        (if full { self.nb_eval_full } else { self.nb_eval_round }) * self.batch
    }
}

/// Reusable per-client training/eval scratch (DESIGN.md §14): the softmax
/// working buffers the mock kernel hoists out of its per-sample loop.
/// Callers hold one per client and pass it to [`Trainer::train_round_scratch`]
/// / [`Trainer::eval_scratch`]; contents are meaningless between calls — the
/// kernels fully rewrite whatever they read.
#[derive(Debug, Default)]
pub struct TrainScratch {
    /// Per-sample feature vector (`featurize` output).
    pub feat: Vec<f32>,
    /// Per-class linear scores (logits).
    pub scores: Vec<f32>,
    /// Per-class shifted exponentials for the softmax.
    pub exps: Vec<f32>,
}

/// Reusable aggregation scratch (DESIGN.md §14): the output accumulator plus
/// the per-coordinate column / per-candidate distance buffers the robust
/// rules need.  [`Trainer::aggregate_with_scratch`] leaves the aggregated
/// model in `out`; the other buffers are internal working space.
#[derive(Debug, Default)]
pub struct AggScratch {
    /// Aggregated model (the call's result).
    pub out: Vec<f32>,
    /// One coordinate across all rows (trimmed-mean / median sort column).
    pub col: Vec<f32>,
    /// Pairwise squared distances (krum score column).
    pub dists: Vec<f64>,
}

/// The compute interface the coordinator drives.  One local round of
/// Algorithm 2 is exactly: `train_round` → broadcast → collect →
/// `aggregate` → `eval_round`.
pub trait Trainer: Send + Sync {
    fn meta(&self) -> &Meta;

    /// Derive the common model-0 from a seed (all clients call this with the
    /// same seed; the paper assumes a shared initialization).
    fn init(&self, seed: u32) -> Result<Vec<f32>>;

    /// One local training round over `nb_train` minibatches.
    /// `xs`: flat (nb, B, img, img, C) f32, `ys`: flat (nb, B) i32 labels.
    /// Returns (updated params, mean loss).
    fn train_round(&self, params: &[f32], xs: &[f32], ys: &[i32], lr: f32)
        -> Result<(Vec<f32>, f32)>;

    /// Evaluate on a probe (`full = false`) or the full test tensor
    /// (`full = true`).  Returns (correct count, mean loss).
    fn eval(&self, params: &[f32], xs: &[f32], ys: &[i32], full: bool) -> Result<(u32, f32)>;

    /// Masked FedAvg: `rows` are (model, weight) pairs; at most
    /// `meta().k_max` rows participate (the caller enforces this).
    fn aggregate(&self, rows: &[(&[f32], f32)]) -> Result<Vec<f32>>;

    /// Rule-dispatched aggregation ([`AggregationRule`]): `fedavg`
    /// delegates to [`Trainer::aggregate`] — the byte-identical pre-PR
    /// path — while the robust rules run the shared order-statistic
    /// implementations behind [`AggregationRule`] (unweighted; an
    /// adversary controls its own claimed weight).  Provided so every
    /// Trainer gets the robust family for free.
    fn aggregate_with(&self, rows: &[(&[f32], f32)], rule: &AggregationRule) -> Result<Vec<f32>> {
        match rule {
            AggregationRule::FedAvg => self.aggregate(rows),
            _ => {
                check_aggregate_rows(self.meta(), rows)?;
                robust::apply(rows, rule)
            }
        }
    }

    /// Scratch-based variant of [`Trainer::train_round`]: updates `params`
    /// in place and returns the mean loss.  The default delegates to the
    /// allocating kernel, so every Trainer keeps working unchanged;
    /// implementations that override it (the mock) must stay bit-identical
    /// to their `train_round` for the same inputs.
    fn train_round_scratch(
        &self,
        params: &mut Vec<f32>,
        xs: &[f32],
        ys: &[i32],
        lr: f32,
        scratch: &mut TrainScratch,
    ) -> Result<f32> {
        let _ = scratch;
        let (new_params, loss) = self.train_round(params, xs, ys, lr)?;
        *params = new_params;
        Ok(loss)
    }

    /// Scratch-based variant of [`Trainer::eval`]; same bit-identity
    /// contract as [`Trainer::train_round_scratch`].
    fn eval_scratch(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        full: bool,
        scratch: &mut TrainScratch,
    ) -> Result<(u32, f32)> {
        let _ = scratch;
        self.eval(params, xs, ys, full)
    }

    /// Accumulator variant of [`Trainer::aggregate`]: leaves the aggregated
    /// model in `out` (fully overwritten), reusing its capacity.
    fn aggregate_into(&self, rows: &[(&[f32], f32)], out: &mut Vec<f32>) -> Result<()> {
        *out = self.aggregate(rows)?;
        Ok(())
    }

    /// Scratch-based variant of [`Trainer::aggregate_with`]: the result
    /// lands in `scratch.out`.  Bit-identical to [`Trainer::aggregate_with`]
    /// for the same rows and rule.
    fn aggregate_with_scratch(
        &self,
        rows: &[(&[f32], f32)],
        rule: &AggregationRule,
        scratch: &mut AggScratch,
    ) -> Result<()> {
        match rule {
            AggregationRule::FedAvg => self.aggregate_into(rows, &mut scratch.out),
            _ => {
                check_aggregate_rows(self.meta(), rows)?;
                robust::apply_into(rows, rule, scratch)
            }
        }
    }
}

/// Validate row shapes shared by both Trainer impls.
pub(crate) fn check_aggregate_rows(meta: &Meta, rows: &[(&[f32], f32)]) -> Result<()> {
    check_rows_shape(meta.n_params, meta.k_max, rows)
}

/// Shape validation against explicit dimensions — the mock's param count
/// differs from its meta's `n_params`, and cloning a patched `Meta` per
/// aggregation would put a `String` allocation in the hot loop.
pub(crate) fn check_rows_shape(n_params: usize, k_max: usize, rows: &[(&[f32], f32)]) -> Result<()> {
    if rows.is_empty() {
        bail!("aggregate called with zero rows");
    }
    if rows.len() > k_max {
        bail!("aggregate rows {} exceed k_max {}", rows.len(), k_max);
    }
    for (i, (p, w)) in rows.iter().enumerate() {
        if p.len() != n_params {
            bail!("aggregate row {i} has {} params, want {}", p.len(), n_params);
        }
        if !w.is_finite() || *w < 0.0 {
            bail!("aggregate row {i} has invalid weight {w}");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const META_TEXT: &str = "config=tiny\nn_params=6202\nimg=8\nchannels=3\nclasses=10\nbatch=16\nnb_train=2\nnb_eval_round=4\nnb_eval_full=8\nk_max=16\n";

    #[test]
    fn meta_parses() {
        let m = Meta::parse(META_TEXT).unwrap();
        assert_eq!(m.config, "tiny");
        assert_eq!(m.n_params, 6202);
        assert_eq!(m.train_x_len(), 2 * 16 * 8 * 8 * 3);
        assert_eq!(m.train_y_len(), 32);
        assert_eq!(m.eval_x_len(false), 4 * 16 * 8 * 8 * 3);
        assert_eq!(m.eval_y_len(true), 8 * 16);
    }

    #[test]
    fn meta_missing_key_errors() {
        assert!(Meta::parse("config=x\nn_params=10\n").is_err());
    }

    #[test]
    fn meta_bad_value_errors() {
        assert!(Meta::parse(&META_TEXT.replace("6202", "abc")).is_err());
    }

    #[test]
    fn aggregate_row_validation() {
        let m = Meta::parse(META_TEXT).unwrap();
        let good = vec![1.0f32; m.n_params];
        assert!(check_aggregate_rows(&m, &[]).is_err());
        assert!(check_aggregate_rows(&m, &[(&good, 1.0)]).is_ok());
        let bad = vec![1.0f32; 3];
        assert!(check_aggregate_rows(&m, &[(&bad, 1.0)]).is_err());
        assert!(check_aggregate_rows(&m, &[(&good, f32::NAN)]).is_err());
        assert!(check_aggregate_rows(&m, &[(&good, -1.0)]).is_err());
        let many: Vec<(&[f32], f32)> = (0..17).map(|_| (good.as_slice(), 1.0)).collect();
        assert!(check_aggregate_rows(&m, &many).is_err());
    }
}
