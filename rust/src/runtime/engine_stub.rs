//! Stand-in for the PJRT-backed engine when built without the `pjrt`
//! feature (the offline image has no `xla` crate).  Presents the same API
//! as `engine.rs` so binaries, benches and examples compile; every load
//! fails with a clear error and the types are uninhabited, so no post-load
//! path can be reached.  Protocol logic tests run on [`super::MockTrainer`]
//! either way.

use std::path::Path;

use anyhow::{bail, Result};

use super::{Meta, Trainer};

/// Uninhabited marker: a stub `Engine` can never actually be constructed.
enum Never {}

/// API twin of the PJRT `Engine`; see `engine.rs` for the real thing.
pub struct Engine {
    never: Never,
}

const NO_PJRT: &str = "dfl was built without the `pjrt` feature: the PJRT engine is \
     unavailable (add the `xla` dependency and build with `--features pjrt`, \
     or use the MockTrainer)";

impl Engine {
    pub fn load(_dir: &Path) -> Result<Engine> {
        bail!(NO_PJRT)
    }

    pub fn dir(&self) -> &Path {
        match self.never {}
    }

    pub fn platform(&self) -> String {
        match self.never {}
    }

    pub fn meta(&self) -> &Meta {
        match self.never {}
    }

    pub fn train_step(
        &self,
        _params: &[f32],
        _xs: &[f32],
        _ys: &[i32],
        _lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        match self.never {}
    }

    pub fn init(&self, _seed: u32) -> Result<Vec<f32>> {
        match self.never {}
    }

    pub fn train_round(
        &self,
        _params: &[f32],
        _xs: &[f32],
        _ys: &[i32],
        _lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        match self.never {}
    }

    pub fn eval(&self, _params: &[f32], _xs: &[f32], _ys: &[i32], _full: bool) -> Result<(u32, f32)> {
        match self.never {}
    }

    pub fn aggregate(&self, _rows: &[(&[f32], f32)]) -> Result<Vec<f32>> {
        match self.never {}
    }
}

/// API twin of the thread-shareable PJRT engine.
pub struct SharedEngine {
    inner: Engine,
}

impl SharedEngine {
    pub fn load(dir: &Path) -> Result<SharedEngine> {
        Engine::load(dir).map(SharedEngine::from_engine)
    }

    pub fn from_engine(engine: Engine) -> SharedEngine {
        SharedEngine { inner: engine }
    }
}

impl Trainer for SharedEngine {
    fn meta(&self) -> &Meta {
        match self.inner.never {}
    }

    fn init(&self, _seed: u32) -> Result<Vec<f32>> {
        match self.inner.never {}
    }

    fn train_round(
        &self,
        _params: &[f32],
        _xs: &[f32],
        _ys: &[i32],
        _lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        match self.inner.never {}
    }

    fn eval(&self, _params: &[f32], _xs: &[f32], _ys: &[i32], _full: bool) -> Result<(u32, f32)> {
        match self.inner.never {}
    }

    fn aggregate(&self, _rows: &[(&[f32], f32)]) -> Result<Vec<f32>> {
        match self.inner.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_load_fails_loudly() {
        let err = SharedEngine::load(Path::new("artifacts/tiny")).err().unwrap();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
