//! PJRT-backed [`Trainer`]: loads HLO-text artifacts, compiles each once on
//! the CPU client, and executes them on the request path.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  All entry computations were lowered with
//! `return_tuple=True`, so every result is a (possibly 1-element) tuple.

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

use super::{check_aggregate_rows, Meta, Trainer};

/// One compiled artifact set (init/train/eval/aggregate) on a PJRT client.
pub struct Engine {
    meta: Meta,
    dir: PathBuf,
    client: PjRtClient,
    init: PjRtLoadedExecutable,
    train_step: PjRtLoadedExecutable,
    train_epoch: PjRtLoadedExecutable,
    eval_round: PjRtLoadedExecutable,
    eval_full: PjRtLoadedExecutable,
    aggregate: PjRtLoadedExecutable,
    /// Reused (k_max × P) staging buffer for aggregate calls — at the paper
    /// config this is 14 MB; re-zeroing only the dirty rows instead of
    /// reallocating each round keeps the hot loop allocation-free
    /// (EXPERIMENTS.md §Perf).
    agg_scratch: Mutex<Vec<f32>>,
}

fn compile(client: &PjRtClient, dir: &Path, name: &str) -> Result<PjRtLoadedExecutable> {
    let path = dir.join(format!("{name}.hlo.txt"));
    let proto = xla::HloModuleProto::from_text_file(&path)
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compiling {}: {e}", path.display()))
}

/// f32 slice → PJRT literal of the given logical dims (zero-copy view of the
/// host bytes at literal-creation time).
fn lit_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("literal shape {:?} wants {n} elements, got {}", dims, data.len());
    }
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::F32, dims, bytes)
        .map_err(|e| anyhow!("creating f32 literal: {e}"))
}

fn lit_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("literal shape {:?} wants {n} elements, got {}", dims, data.len());
    }
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::S32, dims, bytes)
        .map_err(|e| anyhow!("creating i32 literal: {e}"))
}

fn run(exe: &PjRtLoadedExecutable, args: &[Literal]) -> Result<Vec<Literal>> {
    let result = exe
        .execute::<Literal>(args)
        .map_err(|e| anyhow!("pjrt execute: {e}"))?;
    let buf = result
        .first()
        .and_then(|d| d.first())
        .ok_or_else(|| anyhow!("pjrt execute returned no buffers"))?;
    let lit = buf
        .to_literal_sync()
        .map_err(|e| anyhow!("fetching result literal: {e}"))?;
    lit.to_tuple().map_err(|e| anyhow!("untupling result: {e}"))
}

impl Engine {
    /// Load and compile every artifact under `dir` (e.g. `artifacts/fast`).
    /// Compilation happens once here; calls afterwards only execute.
    pub fn load(dir: &Path) -> Result<Engine> {
        let meta = Meta::load(dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        let agg_scratch = Mutex::new(vec![0.0f32; meta.k_max * meta.n_params]);
        Ok(Engine {
            init: compile(&client, dir, "init")?,
            train_step: compile(&client, dir, "train_step")?,
            train_epoch: compile(&client, dir, "train_epoch")?,
            eval_round: compile(&client, dir, "eval_round")?,
            eval_full: compile(&client, dir, "eval_full")?,
            aggregate: compile(&client, dir, "aggregate")?,
            meta,
            dir: dir.to_path_buf(),
            client,
            agg_scratch,
        })
    }

    /// Artifact directory this engine was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn meta(&self) -> &Meta {
        &self.meta
    }

    /// Single-minibatch SGD step (tests/micro-benches; the request path uses
    /// `train_round`). `xs`: (B, img, img, C) flat.
    pub fn train_step(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let m = &self.meta;
        let args = [
            lit_f32(params, &[m.n_params])?,
            lit_f32(xs, &[m.batch, m.img, m.img, m.channels])?,
            lit_i32(ys, &[m.batch])?,
            Literal::scalar(lr),
        ];
        let out = run(&self.train_step, &args)?;
        let [p, loss]: [Literal; 2] = out
            .try_into()
            .map_err(|_| anyhow!("train_step: expected 2 outputs"))?;
        Ok((
            p.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?,
            loss.get_first_element::<f32>().map_err(|e| anyhow!("{e}"))?,
        ))
    }
}

/// Inherent request-path calls (the thread-shareable [`SharedEngine`] is the
/// [`Trainer`] implementor; `Engine` itself holds non-`Send` PJRT handles).
impl Engine {
    pub fn init(&self, seed: u32) -> Result<Vec<f32>> {
        let seed_lit = Literal::scalar(seed);
        let out = run(&self.init, &[seed_lit])?;
        let p = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("init: no output"))?;
        p.to_vec::<f32>().map_err(|e| anyhow!("{e}"))
    }

    pub fn train_round(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let m = &self.meta;
        if xs.len() != m.train_x_len() || ys.len() != m.train_y_len() {
            bail!(
                "train_round shapes: xs {} (want {}), ys {} (want {})",
                xs.len(),
                m.train_x_len(),
                ys.len(),
                m.train_y_len()
            );
        }
        let args = [
            lit_f32(params, &[m.n_params])?,
            lit_f32(xs, &[m.nb_train, m.batch, m.img, m.img, m.channels])?,
            lit_i32(ys, &[m.nb_train, m.batch])?,
            Literal::scalar(lr),
        ];
        let out = run(&self.train_epoch, &args)?;
        let [p, loss]: [Literal; 2] = out
            .try_into()
            .map_err(|_| anyhow!("train_epoch: expected 2 outputs"))?;
        Ok((
            p.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?,
            loss.get_first_element::<f32>().map_err(|e| anyhow!("{e}"))?,
        ))
    }

    pub fn eval(&self, params: &[f32], xs: &[f32], ys: &[i32], full: bool) -> Result<(u32, f32)> {
        let m = &self.meta;
        let nb = if full { m.nb_eval_full } else { m.nb_eval_round };
        if xs.len() != m.eval_x_len(full) || ys.len() != m.eval_y_len(full) {
            bail!(
                "eval shapes: xs {} (want {}), ys {} (want {})",
                xs.len(),
                m.eval_x_len(full),
                ys.len(),
                m.eval_y_len(full)
            );
        }
        let exe = if full { &self.eval_full } else { &self.eval_round };
        let args = [
            lit_f32(params, &[m.n_params])?,
            lit_f32(xs, &[nb, m.batch, m.img, m.img, m.channels])?,
            lit_i32(ys, &[nb, m.batch])?,
        ];
        let out = run(exe, &args)?;
        let [correct, loss]: [Literal; 2] = out
            .try_into()
            .map_err(|_| anyhow!("eval: expected 2 outputs"))?;
        let c = correct.get_first_element::<i32>().map_err(|e| anyhow!("{e}"))?;
        Ok((
            c.max(0) as u32,
            loss.get_first_element::<f32>().map_err(|e| anyhow!("{e}"))?,
        ))
    }

    pub fn aggregate(&self, rows: &[(&[f32], f32)]) -> Result<Vec<f32>> {
        let m = &self.meta;
        check_aggregate_rows(m, rows)?;
        // Pack rows into the fixed (k_max, P) staging buffer; absent rows
        // keep weight 0 so their (stale) contents are masked out by the
        // kernel. The buffer is reused across calls — no per-round 14 MB
        // allocation at paper scale.
        let mut stack = self.agg_scratch.lock().unwrap_or_else(|p| p.into_inner());
        let mut weights = vec![0.0f32; m.k_max];
        for (i, (p, w)) in rows.iter().enumerate() {
            stack[i * m.n_params..(i + 1) * m.n_params].copy_from_slice(p);
            weights[i] = *w;
        }
        let args = [
            lit_f32(&stack, &[m.k_max, m.n_params])?,
            lit_f32(&weights, &[m.k_max])?,
        ];
        let out = run(&self.aggregate, &args)?;
        let p = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("aggregate: no output"))?;
        p.to_vec::<f32>().map_err(|e| anyhow!("{e}"))
    }
}

/// Thread-shareable engine. The `xla` wrapper types hold raw C pointers and
/// are not `Send`/`Sync` by default; the PJRT CPU client itself is
/// thread-safe, and we additionally serialize calls behind a mutex so one
/// process-wide compile cache serves all simulated clients.
pub struct SharedEngine {
    inner: Mutex<Engine>,
    meta: Meta,
}

// SAFETY: all access to the inner Engine (and thus to the PJRT C API) is
// serialized through the Mutex; PJRT CPU objects may be used from any thread
// as long as calls do not race.
unsafe impl Send for SharedEngine {}
unsafe impl Sync for SharedEngine {}

impl SharedEngine {
    pub fn load(dir: &Path) -> Result<SharedEngine> {
        let engine = Engine::load(dir)?;
        let meta = engine.meta.clone();
        Ok(SharedEngine { inner: Mutex::new(engine), meta })
    }

    pub fn from_engine(engine: Engine) -> SharedEngine {
        let meta = engine.meta.clone();
        SharedEngine { inner: Mutex::new(engine), meta }
    }

    fn locked(&self) -> std::sync::MutexGuard<'_, Engine> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }
}

impl Trainer for SharedEngine {
    fn meta(&self) -> &Meta {
        &self.meta
    }

    fn init(&self, seed: u32) -> Result<Vec<f32>> {
        self.locked().init(seed)
    }

    fn train_round(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        self.locked().train_round(params, xs, ys, lr)
    }

    fn eval(&self, params: &[f32], xs: &[f32], ys: &[i32], full: bool) -> Result<(u32, f32)> {
        self.locked().eval(params, xs, ys, full)
    }

    fn aggregate(&self, rows: &[(&[f32], f32)]) -> Result<Vec<f32>> {
        self.locked().aggregate(rows)
    }
}
