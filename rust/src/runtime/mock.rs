//! Deterministic CPU-only [`Trainer`] used by unit/property tests and the
//! protocol-level benches: exercises every coordinator code path (including
//! convergence: repeated rounds genuinely contract toward a data-dependent
//! fixed point) without paying PJRT costs.
//!
//! The "model" is a linear scorer over downsampled pixels trained with a
//! perceptron-style update — real enough that accuracy moves with data
//! quality and rounds, tiny enough to run thousands of simulated rounds.

use anyhow::Result;

use super::{check_rows_shape, Meta, TrainScratch, Trainer};

/// Mock trainer with the same static-shape discipline as the PJRT engine.
pub struct MockTrainer {
    meta: Meta,
    /// Convergence contraction per round (params drift toward batch mean).
    pub lr_scale: f32,
}

impl MockTrainer {
    pub fn new(meta: Meta) -> Self {
        MockTrainer { meta, lr_scale: 1.0 }
    }

    /// A small default meta (decoupled from artifact files on disk).
    pub fn tiny() -> Self {
        MockTrainer::new(Meta {
            config: "mock".into(),
            n_params: 330, // classes * (features=32) + classes*... see below
            img: 8,
            channels: 3,
            classes: 10,
            batch: 16,
            nb_train: 2,
            nb_eval_round: 4,
            nb_eval_full: 8,
            k_max: 16,
        })
    }

    /// `tiny()` with a custom aggregation fan-in cap — scale tests run
    /// hundreds of clients, far past the paper's k_max = 16.
    pub fn tiny_with_k_max(k_max: usize) -> Self {
        let mut t = MockTrainer::tiny();
        t.meta.k_max = k_max;
        t
    }

    /// Lean variant for very large deployments (1000 clients): 2 classes
    /// shrink the model to 66 params, keeping per-message payloads and the
    /// in-flight event queue small.
    pub fn lean_with_k_max(k_max: usize) -> Self {
        let mut t = MockTrainer::tiny();
        t.meta.classes = 2;
        t.meta.n_params = t.check_params();
        t.meta.k_max = k_max;
        t
    }

    /// Wide variant for codec measurements: 32 classes grow the model to
    /// 1056 params, so a dense update dwarfs a top-K sparse delta and the
    /// bytes/round ratio actually shows the codec, not framing overhead.
    pub fn wide_with_k_max(k_max: usize) -> Self {
        let mut t = MockTrainer::tiny();
        t.meta.classes = 32;
        t.meta.n_params = t.check_params();
        t.meta.k_max = k_max;
        t
    }

    /// Feature count: mean-pooled channels (img*img*C -> 32 buckets).
    fn n_features(&self) -> usize {
        32
    }

    /// (weights per class, bias per class) flattened = classes*(feat+1).
    fn check_params(&self) -> usize {
        self.meta.classes * (self.n_features() + 1)
    }

    /// Scratch-filling `featurize`: `out` is fully overwritten
    /// (`clear` + `resize`), so reuse is bit-identical to a fresh `vec!`.
    fn featurize_into(&self, img: &[f32], out: &mut Vec<f32>) {
        let f = self.n_features();
        out.clear();
        out.resize(f, 0.0);
        let chunk = img.len().div_ceil(f);
        for (i, v) in img.iter().enumerate() {
            out[(i / chunk).min(f - 1)] += v;
        }
        let norm = (chunk as f32).max(1.0);
        for o in out.iter_mut() {
            *o /= norm;
        }
    }

    /// Scratch-filling per-class linear scores: same arithmetic and push
    /// order as the old collecting version.
    fn scores_into(&self, params: &[f32], feat: &[f32], out: &mut Vec<f32>) {
        let f = self.n_features();
        out.clear();
        for c in 0..self.meta.classes {
            let base = c * (f + 1);
            let w = &params[base..base + f];
            let b = params[base + f];
            out.push(w.iter().zip(feat).map(|(a, x)| a * x).sum::<f32>() + b);
        }
    }
}

impl Trainer for MockTrainer {
    fn meta(&self) -> &Meta {
        &self.meta
    }

    fn init(&self, seed: u32) -> Result<Vec<f32>> {
        // Deterministic tiny init from the seed (same seed -> same model).
        let n = self.check_params();
        let mut rng = crate::util::Rng::new(seed as u64 ^ 0xC0FF_EE00);
        Ok((0..n).map(|_| rng.normal() * 0.01).collect())
    }

    fn train_round(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let mut p = params.to_vec();
        let loss = self.train_round_scratch(&mut p, xs, ys, lr, &mut TrainScratch::default())?;
        Ok((p, loss))
    }

    fn train_round_scratch(
        &self,
        params: &mut Vec<f32>,
        xs: &[f32],
        ys: &[i32],
        lr: f32,
        scratch: &mut TrainScratch,
    ) -> Result<f32> {
        let m = &self.meta;
        anyhow::ensure!(params.len() == self.check_params(), "mock param len");
        anyhow::ensure!(xs.len() == m.train_x_len(), "mock xs len");
        anyhow::ensure!(ys.len() == m.train_y_len(), "mock ys len");
        let img_len = m.img * m.img * m.channels;
        let f = self.n_features();
        let mut loss_sum = 0.0f64;
        let n = ys.len();
        for (i, &label) in ys.iter().enumerate() {
            self.featurize_into(&xs[i * img_len..(i + 1) * img_len], &mut scratch.feat);
            self.scores_into(params, &scratch.feat, &mut scratch.scores);
            // softmax xent + gradient step on the one example
            let mx = scratch.scores.iter().cloned().fold(f32::MIN, f32::max);
            scratch.exps.clear();
            scratch.exps.extend(scratch.scores.iter().map(|v| (v - mx).exp()));
            let z: f32 = scratch.exps.iter().sum();
            let label = label as usize % m.classes;
            loss_sum += -((scratch.exps[label] / z).max(1e-9).ln()) as f64;
            for c in 0..m.classes {
                let prob = scratch.exps[c] / z;
                let g = prob - if c == label { 1.0 } else { 0.0 };
                let base = c * (f + 1);
                // `lr * lr_scale * g * x` associates left, so hoisting the
                // loop-invariant prefix is bit-exact.
                let step = lr * self.lr_scale * g;
                for (j, x) in scratch.feat.iter().enumerate() {
                    params[base + j] -= step * x;
                }
                params[base + f] -= step;
            }
        }
        Ok((loss_sum / n as f64) as f32)
    }

    fn eval(&self, params: &[f32], xs: &[f32], ys: &[i32], full: bool) -> Result<(u32, f32)> {
        self.eval_scratch(params, xs, ys, full, &mut TrainScratch::default())
    }

    fn eval_scratch(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        full: bool,
        scratch: &mut TrainScratch,
    ) -> Result<(u32, f32)> {
        let m = &self.meta;
        anyhow::ensure!(xs.len() == m.eval_x_len(full), "mock eval xs len");
        anyhow::ensure!(ys.len() == m.eval_y_len(full), "mock eval ys len");
        let img_len = m.img * m.img * m.channels;
        let mut correct = 0u32;
        let mut loss_sum = 0.0f64;
        for (i, &label) in ys.iter().enumerate() {
            self.featurize_into(&xs[i * img_len..(i + 1) * img_len], &mut scratch.feat);
            self.scores_into(params, &scratch.feat, &mut scratch.scores);
            let s = &scratch.scores;
            let pred = s
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
            let label = label as usize % m.classes;
            if pred == label {
                correct += 1;
            }
            let mx = s.iter().cloned().fold(f32::MIN, f32::max);
            let z: f32 = s.iter().map(|v| (v - mx).exp()).sum();
            loss_sum += -((((s[label] - mx).exp()) / z).max(1e-9).ln()) as f64;
        }
        Ok((correct, (loss_sum / ys.len() as f64) as f32))
    }

    fn aggregate(&self, rows: &[(&[f32], f32)]) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.aggregate_into(rows, &mut out)?;
        Ok(out)
    }

    fn aggregate_into(&self, rows: &[(&[f32], f32)], out: &mut Vec<f32>) -> Result<()> {
        // The mock bypasses the n_params check of the real meta (its param
        // count is check_params()), but keeps weight/row-count validation.
        check_rows_shape(self.check_params(), self.meta.k_max, rows)?;
        let n = rows[0].0.len();
        let wsum: f32 = rows.iter().map(|(_, w)| w).sum();
        out.clear();
        out.resize(n, 0.0);
        if wsum <= 0.0 {
            return Ok(());
        }
        for (p, w) in rows {
            for (o, x) in out.iter_mut().zip(*p) {
                *o += w / wsum * x;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn data(m: &Meta, rng: &mut Rng, n: usize) -> (Vec<f32>, Vec<i32>) {
        // class-dependent mean so the linear mock can actually learn
        let img_len = m.img * m.img * m.channels;
        let mut xs = Vec::with_capacity(n * img_len);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.below(m.classes) as i32;
            for j in 0..img_len {
                let base = if (j / 16) % m.classes == c as usize { 1.0 } else { 0.0 };
                xs.push(base + 0.3 * rng.normal());
            }
            ys.push(c);
        }
        (xs, ys)
    }

    #[test]
    fn mock_learns() {
        let t = MockTrainer::tiny();
        let m = t.meta().clone();
        let mut rng = Rng::new(5);
        let mut p = t.init(0).unwrap();
        let (exs, eys) = data(&m, &mut rng, m.nb_eval_round * m.batch);
        let (c0, _) = t.eval(&p, &exs, &eys, false).unwrap();
        for _ in 0..10 {
            let (xs, ys) = data(&m, &mut rng, m.nb_train * m.batch);
            let (p2, _) = t.train_round(&p, &xs, &ys, 0.1).unwrap();
            p = p2;
        }
        let (c1, _) = t.eval(&p, &exs, &eys, false).unwrap();
        assert!(c1 > c0, "no learning: {c0} -> {c1}");
    }

    #[test]
    fn mock_init_deterministic() {
        let t = MockTrainer::tiny();
        assert_eq!(t.init(3).unwrap(), t.init(3).unwrap());
        assert_ne!(t.init(3).unwrap(), t.init(4).unwrap());
    }

    #[test]
    fn mock_aggregate_is_weighted_mean() {
        let t = MockTrainer::tiny();
        let n = t.check_params();
        let a = vec![1.0f32; n];
        let b = vec![3.0f32; n];
        let out = t.aggregate(&[(&a, 1.0), (&b, 1.0)]).unwrap();
        assert!(out.iter().all(|&x| (x - 2.0).abs() < 1e-6));
        let out = t.aggregate(&[(&a, 3.0), (&b, 1.0)]).unwrap();
        assert!(out.iter().all(|&x| (x - 1.5).abs() < 1e-6));
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        let t = MockTrainer::tiny();
        let m = t.meta().clone();
        let mut rng = Rng::new(9);
        let p0 = t.init(1).unwrap();
        let (xs, ys) = data(&m, &mut rng, m.nb_train * m.batch);
        let (xs2, ys2) = data(&m, &mut rng, m.nb_train * m.batch);

        // Fresh scratch per call vs one scratch reused across calls.
        let mut a = p0.clone();
        let la1 =
            t.train_round_scratch(&mut a, &xs, &ys, 0.07, &mut TrainScratch::default()).unwrap();
        let la2 =
            t.train_round_scratch(&mut a, &xs2, &ys2, 0.07, &mut TrainScratch::default()).unwrap();
        let mut b = p0.clone();
        let mut s = TrainScratch::default();
        let lb1 = t.train_round_scratch(&mut b, &xs, &ys, 0.07, &mut s).unwrap();
        let lb2 = t.train_round_scratch(&mut b, &xs2, &ys2, 0.07, &mut s).unwrap();
        assert_eq!(a, b, "reused scratch must not perturb params");
        assert_eq!((la1.to_bits(), la2.to_bits()), (lb1.to_bits(), lb2.to_bits()));

        // The allocating wrappers agree bit-for-bit with the scratch path.
        let (c, lc) = t.train_round(&p0, &xs, &ys, 0.07).unwrap();
        let mut d = p0.clone();
        let ld = t.train_round_scratch(&mut d, &xs, &ys, 0.07, &mut s).unwrap();
        assert_eq!(c, d);
        assert_eq!(lc.to_bits(), ld.to_bits());

        let (exs, eys) = data(&m, &mut rng, m.nb_eval_round * m.batch);
        let plain = t.eval(&c, &exs, &eys, false).unwrap();
        let pooled = t.eval_scratch(&c, &exs, &eys, false, &mut s).unwrap();
        assert_eq!(plain.0, pooled.0);
        assert_eq!(plain.1.to_bits(), pooled.1.to_bits());
    }

    #[test]
    fn aggregate_into_matches_aggregate_and_reuses_capacity() {
        let t = MockTrainer::tiny();
        let n = t.check_params();
        let a = vec![1.0f32; n];
        let b = vec![3.0f32; n];
        let rows: [(&[f32], f32); 2] = [(&a, 3.0), (&b, 1.0)];
        let plain = t.aggregate(&rows).unwrap();
        let mut out = vec![f32::NAN; n + 7]; // stale junk must be overwritten
        t.aggregate_into(&rows, &mut out).unwrap();
        assert_eq!(plain, out);
        let cap = out.capacity();
        t.aggregate_into(&rows, &mut out).unwrap();
        assert_eq!(out.capacity(), cap, "second call must reuse the buffer");
    }

    #[test]
    fn mock_rejects_bad_shapes() {
        let t = MockTrainer::tiny();
        let p = t.init(0).unwrap();
        assert!(t.train_round(&p, &[0.0; 3], &[0; 3], 0.1).is_err());
        assert!(t.eval(&p, &[0.0; 3], &[0; 3], false).is_err());
    }
}
