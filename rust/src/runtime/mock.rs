//! Deterministic CPU-only [`Trainer`] used by unit/property tests and the
//! protocol-level benches: exercises every coordinator code path (including
//! convergence: repeated rounds genuinely contract toward a data-dependent
//! fixed point) without paying PJRT costs.
//!
//! The "model" is a linear scorer over downsampled pixels trained with a
//! perceptron-style update — real enough that accuracy moves with data
//! quality and rounds, tiny enough to run thousands of simulated rounds.

use anyhow::Result;

use super::{check_aggregate_rows, Meta, Trainer};

/// Mock trainer with the same static-shape discipline as the PJRT engine.
pub struct MockTrainer {
    meta: Meta,
    /// Convergence contraction per round (params drift toward batch mean).
    pub lr_scale: f32,
}

impl MockTrainer {
    pub fn new(meta: Meta) -> Self {
        MockTrainer { meta, lr_scale: 1.0 }
    }

    /// A small default meta (decoupled from artifact files on disk).
    pub fn tiny() -> Self {
        MockTrainer::new(Meta {
            config: "mock".into(),
            n_params: 330, // classes * (features=32) + classes*... see below
            img: 8,
            channels: 3,
            classes: 10,
            batch: 16,
            nb_train: 2,
            nb_eval_round: 4,
            nb_eval_full: 8,
            k_max: 16,
        })
    }

    /// `tiny()` with a custom aggregation fan-in cap — scale tests run
    /// hundreds of clients, far past the paper's k_max = 16.
    pub fn tiny_with_k_max(k_max: usize) -> Self {
        let mut t = MockTrainer::tiny();
        t.meta.k_max = k_max;
        t
    }

    /// Lean variant for very large deployments (1000 clients): 2 classes
    /// shrink the model to 66 params, keeping per-message payloads and the
    /// in-flight event queue small.
    pub fn lean_with_k_max(k_max: usize) -> Self {
        let mut t = MockTrainer::tiny();
        t.meta.classes = 2;
        t.meta.n_params = t.check_params();
        t.meta.k_max = k_max;
        t
    }

    /// Wide variant for codec measurements: 32 classes grow the model to
    /// 1056 params, so a dense update dwarfs a top-K sparse delta and the
    /// bytes/round ratio actually shows the codec, not framing overhead.
    pub fn wide_with_k_max(k_max: usize) -> Self {
        let mut t = MockTrainer::tiny();
        t.meta.classes = 32;
        t.meta.n_params = t.check_params();
        t.meta.k_max = k_max;
        t
    }

    /// Feature count: mean-pooled channels (img*img*C -> 32 buckets).
    fn n_features(&self) -> usize {
        32
    }

    /// (weights per class, bias per class) flattened = classes*(feat+1).
    fn check_params(&self) -> usize {
        self.meta.classes * (self.n_features() + 1)
    }

    fn featurize(&self, img: &[f32]) -> Vec<f32> {
        let f = self.n_features();
        let mut out = vec![0.0f32; f];
        let chunk = img.len().div_ceil(f);
        for (i, v) in img.iter().enumerate() {
            out[(i / chunk).min(f - 1)] += v;
        }
        let norm = (chunk as f32).max(1.0);
        for o in &mut out {
            *o /= norm;
        }
        out
    }

    fn scores(&self, params: &[f32], feat: &[f32]) -> Vec<f32> {
        let f = self.n_features();
        (0..self.meta.classes)
            .map(|c| {
                let base = c * (f + 1);
                let w = &params[base..base + f];
                let b = params[base + f];
                w.iter().zip(feat).map(|(a, x)| a * x).sum::<f32>() + b
            })
            .collect()
    }
}

impl Trainer for MockTrainer {
    fn meta(&self) -> &Meta {
        &self.meta
    }

    fn init(&self, seed: u32) -> Result<Vec<f32>> {
        // Deterministic tiny init from the seed (same seed -> same model).
        let n = self.check_params();
        let mut rng = crate::util::Rng::new(seed as u64 ^ 0xC0FF_EE00);
        Ok((0..n).map(|_| rng.normal() * 0.01).collect())
    }

    fn train_round(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let m = &self.meta;
        anyhow::ensure!(params.len() == self.check_params(), "mock param len");
        anyhow::ensure!(xs.len() == m.train_x_len(), "mock xs len");
        anyhow::ensure!(ys.len() == m.train_y_len(), "mock ys len");
        let img_len = m.img * m.img * m.channels;
        let f = self.n_features();
        let mut p = params.to_vec();
        let mut loss_sum = 0.0f64;
        let n = ys.len();
        for (i, &label) in ys.iter().enumerate() {
            let feat = self.featurize(&xs[i * img_len..(i + 1) * img_len]);
            let s = self.scores(&p, &feat);
            // softmax xent + gradient step on the one example
            let mx = s.iter().cloned().fold(f32::MIN, f32::max);
            let exps: Vec<f32> = s.iter().map(|v| (v - mx).exp()).collect();
            let z: f32 = exps.iter().sum();
            let label = label as usize % m.classes;
            loss_sum += -((exps[label] / z).max(1e-9).ln()) as f64;
            for c in 0..m.classes {
                let prob = exps[c] / z;
                let g = prob - if c == label { 1.0 } else { 0.0 };
                let base = c * (f + 1);
                for (j, x) in feat.iter().enumerate() {
                    p[base + j] -= lr * self.lr_scale * g * x;
                }
                p[base + f] -= lr * self.lr_scale * g;
            }
        }
        Ok((p, (loss_sum / n as f64) as f32))
    }

    fn eval(&self, params: &[f32], xs: &[f32], ys: &[i32], full: bool) -> Result<(u32, f32)> {
        let m = &self.meta;
        anyhow::ensure!(xs.len() == m.eval_x_len(full), "mock eval xs len");
        anyhow::ensure!(ys.len() == m.eval_y_len(full), "mock eval ys len");
        let img_len = m.img * m.img * m.channels;
        let mut correct = 0u32;
        let mut loss_sum = 0.0f64;
        for (i, &label) in ys.iter().enumerate() {
            let feat = self.featurize(&xs[i * img_len..(i + 1) * img_len]);
            let s = self.scores(params, &feat);
            let pred = s
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0);
            let label = label as usize % m.classes;
            if pred == label {
                correct += 1;
            }
            let mx = s.iter().cloned().fold(f32::MIN, f32::max);
            let z: f32 = s.iter().map(|v| (v - mx).exp()).sum();
            loss_sum += -((((s[label] - mx).exp()) / z).max(1e-9).ln()) as f64;
        }
        Ok((correct, (loss_sum / ys.len() as f64) as f32))
    }

    fn aggregate(&self, rows: &[(&[f32], f32)]) -> Result<Vec<f32>> {
        // The mock bypasses the n_params check of the real meta (its param
        // count is check_params()), but keeps weight/row-count validation.
        let mut meta = self.meta.clone();
        meta.n_params = self.check_params();
        check_aggregate_rows(&meta, rows)?;
        let n = rows[0].0.len();
        let wsum: f32 = rows.iter().map(|(_, w)| w).sum();
        let mut out = vec![0.0f32; n];
        if wsum <= 0.0 {
            return Ok(out);
        }
        for (p, w) in rows {
            for (o, x) in out.iter_mut().zip(*p) {
                *o += w / wsum * x;
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn data(m: &Meta, rng: &mut Rng, n: usize) -> (Vec<f32>, Vec<i32>) {
        // class-dependent mean so the linear mock can actually learn
        let img_len = m.img * m.img * m.channels;
        let mut xs = Vec::with_capacity(n * img_len);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.below(m.classes) as i32;
            for j in 0..img_len {
                let base = if (j / 16) % m.classes == c as usize { 1.0 } else { 0.0 };
                xs.push(base + 0.3 * rng.normal());
            }
            ys.push(c);
        }
        (xs, ys)
    }

    #[test]
    fn mock_learns() {
        let t = MockTrainer::tiny();
        let m = t.meta().clone();
        let mut rng = Rng::new(5);
        let mut p = t.init(0).unwrap();
        let (exs, eys) = data(&m, &mut rng, m.nb_eval_round * m.batch);
        let (c0, _) = t.eval(&p, &exs, &eys, false).unwrap();
        for _ in 0..10 {
            let (xs, ys) = data(&m, &mut rng, m.nb_train * m.batch);
            let (p2, _) = t.train_round(&p, &xs, &ys, 0.1).unwrap();
            p = p2;
        }
        let (c1, _) = t.eval(&p, &exs, &eys, false).unwrap();
        assert!(c1 > c0, "no learning: {c0} -> {c1}");
    }

    #[test]
    fn mock_init_deterministic() {
        let t = MockTrainer::tiny();
        assert_eq!(t.init(3).unwrap(), t.init(3).unwrap());
        assert_ne!(t.init(3).unwrap(), t.init(4).unwrap());
    }

    #[test]
    fn mock_aggregate_is_weighted_mean() {
        let t = MockTrainer::tiny();
        let n = t.check_params();
        let a = vec![1.0f32; n];
        let b = vec![3.0f32; n];
        let out = t.aggregate(&[(&a, 1.0), (&b, 1.0)]).unwrap();
        assert!(out.iter().all(|&x| (x - 2.0).abs() < 1e-6));
        let out = t.aggregate(&[(&a, 3.0), (&b, 1.0)]).unwrap();
        assert!(out.iter().all(|&x| (x - 1.5).abs() < 1e-6));
    }

    #[test]
    fn mock_rejects_bad_shapes() {
        let t = MockTrainer::tiny();
        let p = t.init(0).unwrap();
        assert!(t.train_round(&p, &[0.0; 3], &[0; 3], 0.1).is_err());
        assert!(t.eval(&p, &[0.0; 3], &[0; 3], false).is_err());
    }
}
