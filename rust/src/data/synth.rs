//! Synthetic class-conditional image generator (the CIFAR-10 stand-in).
//!
//! The environment has no network access to fetch CIFAR-10, so experiments
//! run on a synthetic 10-class 32×32×3 (or scaled) distribution that keeps
//! the paper-relevant properties (DESIGN.md §3.2):
//!
//! * class identity is carried by a *smooth spatial template* per class
//!   (low-frequency sinusoid mixture — learnable by a small CNN, not by a
//!   trivial per-pixel threshold),
//! * per-sample Gaussian noise + random global intensity jitter control the
//!   difficulty so accuracy curves land mid-range like the paper's
//!   (26–70%), leaving headroom for collaboration effects to show, and
//! * non-IID splits of it behave like non-IID CIFAR: single-client accuracy
//!   collapses, federated accuracy recovers.

use super::Dataset;
use crate::runtime::Meta;
use crate::util::Rng;

/// Parameters of the synthetic distribution.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub img: usize,
    pub channels: usize,
    pub classes: usize,
    /// Number of sinusoid components per class template.
    pub components: usize,
    /// Template signal amplitude.
    pub signal: f32,
    /// Per-pixel noise sigma (difficulty knob).
    pub noise: f32,
    /// Global intensity jitter range (multiplicative).
    pub jitter: f32,
}

impl SynthSpec {
    pub fn for_meta(meta: &Meta) -> SynthSpec {
        // Noise/jitter tuned so the paper CNN lands in the paper's accuracy
        // band (single-client chunk ≈ 25-40%, full federation ≈ 55-75%) —
        // hard enough that collaboration visibly helps, see exp tests.
        SynthSpec {
            img: meta.img,
            channels: meta.channels,
            classes: meta.classes,
            components: 4,
            signal: 1.0,
            noise: 3.2,
            jitter: 0.35,
        }
    }

    /// One smooth template per class: a mixture of low-frequency sinusoids
    /// with class-specific frequencies/phases per channel.
    pub fn class_templates(&self, rng: &mut Rng) -> Vec<Vec<f32>> {
        let n = self.img * self.img * self.channels;
        (0..self.classes)
            .map(|_| {
                let mut t = vec![0.0f32; n];
                for _ in 0..self.components {
                    let fx = rng.range_f32(0.5, 2.5);
                    let fy = rng.range_f32(0.5, 2.5);
                    let phase = rng.range_f32(0.0, std::f32::consts::TAU);
                    let ch_amp: Vec<f32> =
                        (0..self.channels).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                    for y in 0..self.img {
                        for x in 0..self.img {
                            let v = (fx * x as f32 / self.img as f32 * std::f32::consts::TAU
                                + fy * y as f32 / self.img as f32 * std::f32::consts::TAU
                                + phase)
                                .sin();
                            for (c, &a) in ch_amp.iter().enumerate() {
                                t[(y * self.img + x) * self.channels + c] += a * v;
                            }
                        }
                    }
                }
                // normalize template to unit RMS then scale by signal
                let rms = (t.iter().map(|v| (v * v) as f64).sum::<f64>() / n as f64)
                    .sqrt()
                    .max(1e-6) as f32;
                for v in &mut t {
                    *v *= self.signal / rms;
                }
                t
            })
            .collect()
    }

    /// Draw `n` labelled samples: (template[label] * jitter + noise),
    /// scaled to ~unit per-pixel variance so He-initialized convs see the
    /// input statistics they assume (un-normalized inputs collapse the net
    /// on some seeds: round-0 logits explode, ReLUs die at chance level).
    pub fn sample(&self, templates: &[Vec<f32>], n: usize, rng: &mut Rng) -> Dataset {
        let img_len = self.img * self.img * self.channels;
        let scale = 1.0 / (self.signal * self.signal + self.noise * self.noise).sqrt();
        let mut xs = Vec::with_capacity(n * img_len);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let label = rng.below(self.classes);
            let jitter = 1.0 + rng.range_f32(-self.jitter, self.jitter);
            let t = &templates[label];
            for &tv in t.iter() {
                xs.push((tv * jitter + self.noise * rng.normal()) * scale);
            }
            ys.push(label as i32);
        }
        Dataset { img: self.img, channels: self.channels, classes: self.classes, xs, ys }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SynthSpec {
        SynthSpec {
            img: 8,
            channels: 3,
            classes: 10,
            components: 4,
            signal: 1.0,
            noise: 0.9,
            jitter: 0.25,
        }
    }

    #[test]
    fn templates_are_distinct_and_normalized() {
        let s = spec();
        let mut rng = Rng::new(1);
        let ts = s.class_templates(&mut rng);
        assert_eq!(ts.len(), 10);
        for t in &ts {
            let rms = (t.iter().map(|v| (v * v) as f64).sum::<f64>() / t.len() as f64).sqrt();
            assert!((rms - 1.0).abs() < 0.05, "rms {rms}");
        }
        // distinct classes must differ substantially
        let d: f32 = ts[0].iter().zip(&ts[1]).map(|(a, b)| (a - b).abs()).sum();
        assert!(d > 1.0);
    }

    #[test]
    fn nearest_template_recovers_labels_above_chance() {
        // Sanity: with the default SNR a nearest-template classifier should
        // beat 10% chance by a lot but stay below 100% (mid-range difficulty).
        let s = spec();
        let mut rng = Rng::new(2);
        let ts = s.class_templates(&mut rng);
        let ds = s.sample(&ts, 500, &mut rng);
        let mut correct = 0;
        for i in 0..ds.len() {
            let img = ds.image(i);
            let best = (0..s.classes)
                .min_by(|&a, &b| {
                    let da: f32 = ts[a].iter().zip(img).map(|(t, x)| (t - x) * (t - x)).sum();
                    let db: f32 = ts[b].iter().zip(img).map(|(t, x)| (t - x) * (t - x)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == ds.ys[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f32 / ds.len() as f32;
        assert!(acc > 0.5, "synthetic data too hard: nearest-template acc {acc}");
    }

    #[test]
    fn labels_roughly_balanced() {
        let s = spec();
        let mut rng = Rng::new(3);
        let ts = s.class_templates(&mut rng);
        let ds = s.sample(&ts, 2000, &mut rng);
        let mut hist = vec![0usize; 10];
        for &y in &ds.ys {
            hist[y as usize] += 1;
        }
        for &h in &hist {
            assert!(h > 120, "unbalanced: {hist:?}");
        }
    }
}
