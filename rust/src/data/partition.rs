//! Client data partitioners.
//!
//! * [`dirichlet_partition`] — the paper's non-IID split: for each class,
//!   proportions over clients are drawn from Dirichlet(α); α = 0.6 in the
//!   paper's experiments.  Low α ⇒ heavy class skew per client.
//! * [`iid_partition`] — shuffled equal split (the paper's IID setting).
//! * [`fixed_chunk`] — fixed-size random chunk per client (Table 2 baseline:
//!   "fixed chunk of 5000 data points").

use super::Dataset;
use crate::util::Rng;

/// Non-IID Dirichlet(α) split: returns per-client index lists covering the
/// dataset exactly once (a partition).  Every client is guaranteed at least
/// one sample (paper's clients all train every round).
pub fn dirichlet_partition(
    ds: &Dataset,
    n_clients: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    assert!(n_clients > 0);
    // indices per class
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); ds.classes];
    for (i, &y) in ds.ys.iter().enumerate() {
        by_class[y as usize].push(i);
    }
    let mut parts: Vec<Vec<usize>> = vec![Vec::new(); n_clients];
    for idxs in by_class.iter_mut() {
        rng.shuffle(idxs);
        let props = rng.dirichlet(alpha, n_clients);
        // convert proportions to integer cut points (largest remainder)
        let n = idxs.len();
        let mut counts: Vec<usize> = props.iter().map(|p| (p * n as f64) as usize).collect();
        let mut assigned: usize = counts.iter().sum();
        // distribute the remainder to the largest fractional parts
        let mut order: Vec<usize> = (0..n_clients).collect();
        order.sort_by(|&a, &b| {
            let fa = props[a] * n as f64 - counts[a] as f64;
            let fb = props[b] * n as f64 - counts[b] as f64;
            fb.partial_cmp(&fa).unwrap()
        });
        let mut k = 0;
        while assigned < n {
            counts[order[k % n_clients]] += 1;
            assigned += 1;
            k += 1;
        }
        let mut pos = 0;
        for (c, &cnt) in counts.iter().enumerate() {
            parts[c].extend_from_slice(&idxs[pos..pos + cnt]);
            pos += cnt;
        }
    }
    // guarantee non-empty partitions: steal one sample from the largest
    for c in 0..n_clients {
        if parts[c].is_empty() {
            let donor = (0..n_clients).max_by_key(|&i| parts[i].len()).unwrap();
            assert!(parts[donor].len() > 1, "dataset too small for {n_clients} clients");
            let moved = parts[donor].pop().unwrap();
            parts[c].push(moved);
        }
    }
    for p in &mut parts {
        rng.shuffle(p);
    }
    parts
}

/// IID split: global shuffle then equal contiguous chunks (remainder spread
/// over the first clients).
pub fn iid_partition(ds: &Dataset, n_clients: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    assert!(n_clients > 0);
    let mut all: Vec<usize> = (0..ds.len()).collect();
    rng.shuffle(&mut all);
    let base = ds.len() / n_clients;
    let rem = ds.len() % n_clients;
    let mut parts = Vec::with_capacity(n_clients);
    let mut pos = 0;
    for c in 0..n_clients {
        let take = base + usize::from(c < rem);
        parts.push(all[pos..pos + take].to_vec());
        pos += take;
    }
    parts
}

/// A fixed-size chunk with Dirichlet(α)-skewed class proportions — the
/// "fixed chunk drawn from a highly Non-IID distribution" of the Table 2
/// baseline. Falls back to whatever is available when a class runs short.
pub fn skewed_chunk(ds: &Dataset, size: usize, alpha: f64, rng: &mut Rng) -> Vec<usize> {
    let props = rng.dirichlet(alpha, ds.classes);
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); ds.classes];
    for (i, &y) in ds.ys.iter().enumerate() {
        by_class[y as usize].push(i);
    }
    for idxs in by_class.iter_mut() {
        rng.shuffle(idxs);
    }
    let size = size.min(ds.len());
    let mut out = Vec::with_capacity(size);
    // first pass: proportional draw
    for (c, idxs) in by_class.iter_mut().enumerate() {
        let want = ((props[c] * size as f64).round() as usize).min(idxs.len());
        out.extend(idxs.drain(..want));
    }
    // top up from remaining pools (largest first) to hit the exact size
    while out.len() < size {
        let donor = (0..ds.classes).max_by_key(|&c| by_class[c].len()).unwrap();
        match by_class[donor].pop() {
            Some(i) => out.push(i),
            None => break,
        }
    }
    out.truncate(size);
    rng.shuffle(&mut out);
    out
}

/// A fixed-size random chunk (Table 2 single-client baselines).
pub fn fixed_chunk(ds: &Dataset, size: usize, rng: &mut Rng) -> Vec<usize> {
    let mut all: Vec<usize> = (0..ds.len()).collect();
    rng.shuffle(&mut all);
    all.truncate(size.min(ds.len()));
    all
}

/// Per-class sample counts of an index list (skew diagnostics / tests).
pub fn label_histogram(ds: &Dataset, indices: &[usize]) -> Vec<usize> {
    let mut hist = vec![0usize; ds.classes];
    for &i in indices {
        hist[ds.ys[i] as usize] += 1;
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Meta;
    use crate::util::quickcheck::forall;

    fn dataset(n: usize, seed: u64) -> Dataset {
        let meta = Meta {
            config: "t".into(),
            n_params: 0,
            img: 4,
            channels: 1,
            classes: 10,
            batch: 4,
            nb_train: 1,
            nb_eval_round: 1,
            nb_eval_full: 1,
            k_max: 16,
        };
        Dataset::synthetic_pair(&meta, n, 1, seed).0
    }

    fn is_exact_partition(n: usize, parts: &[Vec<usize>]) -> bool {
        let mut seen = vec![false; n];
        for p in parts {
            for &i in p {
                if seen[i] {
                    return false;
                }
                seen[i] = true;
            }
        }
        seen.iter().all(|&s| s)
    }

    #[test]
    fn dirichlet_is_exact_partition_property() {
        forall(
            0xA11A,
            25,
            |r| {
                let n = 200 + r.below(400);
                let clients = 2 + r.below(11);
                let alpha = [0.1, 0.3, 0.6, 1.0, 10.0][r.below(5)];
                (n, clients, alpha, r.next_u64())
            },
            |&(n, clients, alpha, seed)| {
                let ds = dataset(n, seed);
                let mut rng = Rng::new(seed ^ 1);
                let parts = dirichlet_partition(&ds, clients, alpha, &mut rng);
                if parts.len() != clients {
                    return Err("wrong client count".into());
                }
                if !is_exact_partition(ds.len(), &parts) {
                    return Err("not an exact partition".into());
                }
                if parts.iter().any(|p| p.is_empty()) {
                    return Err("empty partition".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn low_alpha_skews_more_than_high_alpha() {
        let ds = dataset(4000, 9);
        let skew = |alpha: f64| {
            // average over several seeds: max class share within a client
            let mut total = 0.0;
            for seed in 0..5u64 {
                let mut rng = Rng::new(100 + seed);
                let parts = dirichlet_partition(&ds, 8, alpha, &mut rng);
                let mut m = 0.0f64;
                let mut cnt = 0.0f64;
                for p in &parts {
                    let h = label_histogram(&ds, p);
                    let n: usize = h.iter().sum();
                    if n >= 20 {
                        m += *h.iter().max().unwrap() as f64 / n as f64;
                        cnt += 1.0;
                    }
                }
                total += m / cnt.max(1.0);
            }
            total / 5.0
        };
        let s_low = skew(0.1);
        let s_high = skew(100.0);
        assert!(
            s_low > s_high + 0.1,
            "alpha ordering violated: skew(0.1)={s_low:.3} vs skew(100)={s_high:.3}"
        );
    }

    #[test]
    fn iid_partition_is_balanced_exact() {
        let ds = dataset(1003, 11);
        let mut rng = Rng::new(12);
        let parts = iid_partition(&ds, 7, &mut rng);
        assert!(is_exact_partition(ds.len(), &parts));
        let sizes: Vec<usize> = parts.iter().map(Vec::len).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1, "{sizes:?}");
    }

    #[test]
    fn fixed_chunk_size_and_uniqueness() {
        let ds = dataset(300, 13);
        let mut rng = Rng::new(14);
        let chunk = fixed_chunk(&ds, 100, &mut rng);
        assert_eq!(chunk.len(), 100);
        let mut sorted = chunk.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 100);
    }

    #[test]
    fn skewed_chunk_is_skewed_and_sized() {
        let ds = dataset(3000, 21);
        let mut rng = Rng::new(22);
        // average over seeds: skewed chunks should concentrate mass vs IID
        let mut skew_max = 0.0f64;
        for _ in 0..5 {
            let chunk = skewed_chunk(&ds, 300, 0.1, &mut rng);
            assert_eq!(chunk.len(), 300);
            let h = label_histogram(&ds, &chunk);
            skew_max += *h.iter().max().unwrap() as f64 / 300.0 / 5.0;
        }
        let uniform_share = 1.0 / ds.classes as f64;
        assert!(skew_max > 2.0 * uniform_share, "not skewed: {skew_max}");
        // indices unique
        let chunk = skewed_chunk(&ds, 500, 0.1, &mut rng);
        let mut s = chunk.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), chunk.len());
    }

    #[test]
    fn fixed_chunk_caps_at_dataset_size() {
        let ds = dataset(50, 15);
        let mut rng = Rng::new(16);
        assert_eq!(fixed_chunk(&ds, 100, &mut rng).len(), 50);
    }
}
