//! Dataset substrate: synthetic CIFAR-10-like image generation, the paper's
//! Dirichlet(α = 0.6) non-IID partitioner, IID/fixed-chunk splits (Table 2
//! baselines), round-batch sampling, and an optional real CIFAR-10 binary
//! loader (auto-used when the files are on disk; see DESIGN.md §3.2).

mod cifar;
mod partition;
mod synth;

pub use cifar::load_cifar10;
pub use partition::{
    dirichlet_partition, fixed_chunk, iid_partition, label_histogram, skewed_chunk,
};
pub use synth::SynthSpec;

use crate::runtime::Meta;
use crate::util::Rng;

/// An in-memory labelled image set (row-major `(n, img, img, channels)`).
#[derive(Clone)]
pub struct Dataset {
    pub img: usize,
    pub channels: usize,
    pub classes: usize,
    /// Flat pixels, `n * img * img * channels` f32 in [-1, 1]-ish range.
    pub xs: Vec<f32>,
    pub ys: Vec<i32>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.ys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ys.is_empty()
    }

    pub fn img_len(&self) -> usize {
        self.img * self.img * self.channels
    }

    pub fn image(&self, i: usize) -> &[f32] {
        let l = self.img_len();
        &self.xs[i * l..(i + 1) * l]
    }

    /// Generate the default train+test synthetic sets for an artifact config.
    /// Deterministic in `seed`; train/test are disjoint draws of the same
    /// class-conditional distribution.
    pub fn synthetic_pair(meta: &Meta, train_n: usize, test_n: usize, seed: u64)
        -> (Dataset, Dataset) {
        let spec = SynthSpec::for_meta(meta);
        let mut rng = Rng::new(seed);
        let templates = spec.class_templates(&mut rng);
        let train = spec.sample(&templates, train_n, &mut rng.fork(1));
        let test = spec.sample(&templates, test_n, &mut rng.fork(2));
        (train, test)
    }

    /// Gather `count` samples by index list into flat (xs, ys) buffers,
    /// cycling (with reshuffle) when the index list is shorter than `count`.
    /// This is how a client materializes the fixed-shape train tensor each
    /// round from its (variable-size) local partition.
    pub fn gather_round(
        &self,
        indices: &[usize],
        count: usize,
        rng: &mut Rng,
    ) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let mut order = Vec::new();
        self.gather_round_into(indices, count, rng, &mut xs, &mut ys, &mut order);
        (xs, ys)
    }

    /// [`Dataset::gather_round`] into caller-owned buffers, so the per-round
    /// train tensors reuse their capacity.  `order` is rebuilt from `indices`
    /// every call before shuffling — the RNG consumes exactly the same draws
    /// as the allocating path, so round data stays bit-identical.
    pub fn gather_round_into(
        &self,
        indices: &[usize],
        count: usize,
        rng: &mut Rng,
        xs: &mut Vec<f32>,
        ys: &mut Vec<i32>,
        order: &mut Vec<usize>,
    ) {
        assert!(!indices.is_empty(), "empty partition");
        let l = self.img_len();
        xs.clear();
        xs.reserve(count * l);
        ys.clear();
        ys.reserve(count);
        order.clear();
        order.extend_from_slice(indices);
        rng.shuffle(order);
        let mut pos = 0;
        for _ in 0..count {
            if pos == order.len() {
                rng.shuffle(order);
                pos = 0;
            }
            let idx = order[pos];
            pos += 1;
            xs.extend_from_slice(self.image(idx));
            ys.push(self.ys[idx]);
        }
    }

    /// First `count` examples as flat buffers (deterministic eval tensors).
    pub fn take_flat(&self, count: usize) -> (Vec<f32>, Vec<i32>) {
        assert!(count <= self.len(), "dataset too small: {} < {count}", self.len());
        let l = self.img_len();
        (self.xs[..count * l].to_vec(), self.ys[..count].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> Meta {
        Meta {
            config: "tiny".into(),
            n_params: 6202,
            img: 8,
            channels: 3,
            classes: 10,
            batch: 16,
            nb_train: 2,
            nb_eval_round: 4,
            nb_eval_full: 8,
            k_max: 16,
        }
    }

    #[test]
    fn synthetic_pair_shapes() {
        let m = meta();
        let (train, test) = Dataset::synthetic_pair(&m, 500, 200, 42);
        assert_eq!(train.len(), 500);
        assert_eq!(test.len(), 200);
        assert_eq!(train.xs.len(), 500 * m.img * m.img * m.channels);
        assert!(train.ys.iter().all(|&y| (0..10).contains(&y)));
    }

    #[test]
    fn synthetic_deterministic_in_seed() {
        let m = meta();
        let (a, _) = Dataset::synthetic_pair(&m, 100, 10, 7);
        let (b, _) = Dataset::synthetic_pair(&m, 100, 10, 7);
        let (c, _) = Dataset::synthetic_pair(&m, 100, 10, 8);
        assert_eq!(a.xs, b.xs);
        assert_eq!(a.ys, b.ys);
        assert_ne!(a.xs, c.xs);
    }

    #[test]
    fn gather_round_cycles_small_partitions() {
        let m = meta();
        let (train, _) = Dataset::synthetic_pair(&m, 50, 10, 1);
        let mut rng = Rng::new(2);
        let indices = vec![3, 4, 5]; // only 3 samples, ask for 32
        let (xs, ys) = train.gather_round(&indices, 32, &mut rng);
        assert_eq!(ys.len(), 32);
        assert_eq!(xs.len(), 32 * train.img_len());
        // all labels must come from the partition
        let allowed: Vec<i32> = indices.iter().map(|&i| train.ys[i]).collect();
        assert!(ys.iter().all(|y| allowed.contains(y)));
    }

    #[test]
    fn gather_round_into_matches_gather_round_and_rng_stream() {
        let m = meta();
        let (train, _) = Dataset::synthetic_pair(&m, 50, 10, 1);
        let indices = vec![3, 4, 5, 11, 20];
        let mut rng_a = Rng::new(9);
        let mut rng_b = Rng::new(9);
        let (mut xs, mut ys, mut order) = (Vec::new(), Vec::new(), vec![usize::MAX; 99]);
        for _ in 0..3 {
            let (pxs, pys) = train.gather_round(&indices, 32, &mut rng_a);
            train.gather_round_into(&indices, 32, &mut rng_b, &mut xs, &mut ys, &mut order);
            assert_eq!(pxs, xs, "reused buffers must reproduce the allocating path");
            assert_eq!(pys, ys);
        }
        // identical RNG consumption: both streams land in the same state
        assert_eq!(rng_a.below(1 << 30), rng_b.below(1 << 30));
    }

    #[test]
    fn take_flat_bounds() {
        let m = meta();
        let (_, test) = Dataset::synthetic_pair(&m, 10, 64, 3);
        let (xs, ys) = test.take_flat(64);
        assert_eq!(ys.len(), 64);
        assert_eq!(xs.len(), 64 * test.img_len());
    }
}
