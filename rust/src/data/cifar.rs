//! Optional real CIFAR-10 loader (binary version, `data_batch_*.bin`).
//!
//! The environment cannot download CIFAR-10, so all recorded experiments run
//! on the synthetic distribution ([`super::SynthSpec`]).  If a user drops the
//! standard binary files into a directory, `load_cifar10` gives the paper's
//! exact dataset for the `paper` artifact config (32×32×3, 10 classes).
//!
//! Binary record format: 1 label byte + 3072 pixel bytes (R, G, B planes).

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Dataset;

const REC_LEN: usize = 1 + 3072;

fn load_file(path: &Path, xs: &mut Vec<f32>, ys: &mut Vec<i32>) -> Result<()> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % REC_LEN != 0 {
        bail!("{}: size {} not a multiple of {REC_LEN}", path.display(), bytes.len());
    }
    for rec in bytes.chunks_exact(REC_LEN) {
        let label = rec[0];
        if label > 9 {
            bail!("{}: bad label {label}", path.display());
        }
        ys.push(label as i32);
        // planes (R,G,B) -> interleaved NHWC, normalized to [-1, 1]
        let px = &rec[1..];
        for i in 0..1024 {
            for c in 0..3 {
                xs.push(px[c * 1024 + i] as f32 / 127.5 - 1.0);
            }
        }
    }
    Ok(())
}

/// Load CIFAR-10 train+test sets from a directory holding the standard
/// binary batches. Returns `Ok(None)` when the files are absent (callers
/// then fall back to the synthetic distribution).
pub fn load_cifar10(dir: &Path) -> Result<Option<(Dataset, Dataset)>> {
    let train_files: Vec<_> = (1..=5).map(|i| dir.join(format!("data_batch_{i}.bin"))).collect();
    let test_file = dir.join("test_batch.bin");
    if !test_file.exists() || train_files.iter().any(|f| !f.exists()) {
        return Ok(None);
    }
    let mut train = Dataset { img: 32, channels: 3, classes: 10, xs: Vec::new(), ys: Vec::new() };
    for f in &train_files {
        load_file(f, &mut train.xs, &mut train.ys)?;
    }
    let mut test = Dataset { img: 32, channels: 3, classes: 10, xs: Vec::new(), ys: Vec::new() };
    load_file(&test_file, &mut test.xs, &mut test.ys)?;
    Ok(Some((train, test)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_dir_returns_none() {
        let out = load_cifar10(Path::new("/nonexistent/cifar")).unwrap();
        assert!(out.is_none());
    }

    #[test]
    fn parses_synthetic_binary_batches() {
        // Write a miniature fake CIFAR binary set and load it back.
        let dir = std::env::temp_dir().join(format!("dfl_cifar_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mk = |n: usize, seed: u8| {
            let mut v = Vec::with_capacity(n * REC_LEN);
            for i in 0..n {
                v.push(((i as u8).wrapping_add(seed)) % 10); // label
                v.extend(std::iter::repeat_n((i % 256) as u8, 3072));
            }
            v
        };
        for i in 1..=5 {
            std::fs::write(dir.join(format!("data_batch_{i}.bin")), mk(4, i as u8)).unwrap();
        }
        std::fs::write(dir.join("test_batch.bin"), mk(3, 0)).unwrap();
        let (train, test) = load_cifar10(&dir).unwrap().unwrap();
        assert_eq!(train.len(), 20);
        assert_eq!(test.len(), 3);
        assert_eq!(train.img_len(), 3072);
        assert!(train.xs.iter().all(|&x| (-1.0..=1.0).contains(&x)));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_bad_label() {
        let dir = std::env::temp_dir().join(format!("dfl_cifar_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rec = vec![0u8; REC_LEN];
        rec[0] = 77; // invalid label
        for i in 1..=5 {
            std::fs::write(dir.join(format!("data_batch_{i}.bin")), &rec).unwrap();
        }
        std::fs::write(dir.join("test_batch.bin"), &rec).unwrap();
        assert!(load_cifar10(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
