//! Deterministic in-process N-client deployments.
//!
//! Clients run with a machine-contention model standing in for the paper's
//! 1/2/3-machine LAN testbed (DESIGN.md §3.1): clients are round-robined
//! onto `machines` virtual hosts whose relative clock speeds follow
//! Table 1 (4.0 / 2.0 / 3.5 GHz) and whose per-host contention grows with
//! co-located client count — exactly the effect the paper observes when
//! all 12 clients share one box.
//!
//! Two time regimes ([`SimConfig::virtual_time`], DESIGN.md §3.3):
//!
//! * **Wall clock** (default) over an [`InProcHub`]: timeouts and fault
//!   downtime really elapse, exactly as the seed behaved.  One OS thread
//!   per client, because blocking is real.
//! * **Virtual clock** over a [`VirtualHub`]: the deployment runs as a
//!   discrete-event simulation (`util::time` DESIGN note).  Wait windows,
//!   WAN latencies, and multi-second outages cost no wall time, runs are
//!   byte-identical under a fixed seed, and `SimResult::wall` reports
//!   *virtual* durations, keeping Table-1-style machine-time comparisons
//!   meaningful.
//!
//! Virtual-time deployments additionally pick an executor
//! ([`SimConfig::exec`], DESIGN.md §8):
//!
//! * [`ExecMode::Events`] (default) — every client is a poll-style state
//!   machine driven by the single-threaded [`exec`] executor: zero
//!   per-client OS threads, which is what makes 10 000-client deployments
//!   practical.
//! * [`ExecMode::Threads`] — the original thread-backed compatibility
//!   mode: one small-stack, cooperatively-scheduled OS thread per client.
//!   Same seed ⇒ byte-identical [`SimResult`] across both executors
//!   (asserted in `tests/virtual_time.rs` and `tests/scale.rs`).
//! * [`ExecMode::Parallel`] — the sharded parallel executor (DESIGN.md
//!   §12): min-edge-cut client shards on per-core worker threads with
//!   shard-local clocks, synchronized by conservative lookahead windows.
//!   Same seed ⇒ byte-identical to [`ExecMode::Events`] across the whole
//!   scenario matrix (`tests/conformance.rs`).

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::async_client::{AsyncClient, ClientData, EvalTensors};
use crate::coordinator::config::ProtocolConfig;
use crate::coordinator::fault::{
    compile_adversaries, AdversarySpec, CutSpec, FaultPlan, GraphFault,
};
use crate::coordinator::sync::SyncClient;
use crate::coordinator::termination::TerminationCause;
use crate::data::{dirichlet_partition, fixed_chunk, iid_partition, skewed_chunk, Dataset};
use crate::metrics::{ClientReport, NetStats};
use crate::net::{
    GraphAction, GraphEvent, InProcHub, NetworkModel, Overlay, Topology, TopologySpec,
    Transport, VirtualHub,
};
use crate::runtime::Trainer;
use crate::util::time::VirtualClock;
use crate::util::Rng;

pub mod exec;
pub mod shrink;

pub use shrink::{shrink_sim_config, Shrunk};

/// How client data is split (paper settings).
#[derive(Clone, Copy, Debug)]
pub enum Partition {
    Iid,
    /// Dirichlet(α) non-IID (paper: α = 0.6).
    Dirichlet(f64),
    /// Every client draws an independent fixed-size chunk (Table 2).
    FixedChunk(usize),
    /// Fixed-size chunk with Dirichlet(α)-skewed class mix (Table 2 non-IID
    /// single-client baseline).
    SkewedChunk { size: usize, alpha: f64 },
    /// Everyone trains on the whole dataset (Table 2 "full" baseline).
    Full,
}

/// How a virtual-time deployment executes its clients (ignored on the
/// wall clock, where blocking is real and therefore needs threads).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// One cooperatively-scheduled OS thread per client (compatibility
    /// mode; the only option before the event executor existed).
    Threads,
    /// Single-threaded event executor over client state machines
    /// ([`exec`]): no per-client OS threads at all.  The byte-exact
    /// reference the other executors are measured against.
    Events,
    /// Sharded parallel event executor (DESIGN.md §12): clients
    /// partitioned into `shards` min-edge-cut shards, one worker thread
    /// and one shard-local clock each, synchronized by conservative
    /// lookahead windows.  Byte-identical to [`ExecMode::Events`] per
    /// seed (`tests/conformance.rs`).
    Parallel {
        /// Worker count; clamped to the client count, and collapsed to 1
        /// when the network model has a zero latency floor (conservative
        /// simulation admits no parallelism at zero lookahead).
        shards: usize,
    },
}

impl ExecMode {
    /// The CLI spelling (`dfl sim --exec`).
    pub fn name(self) -> String {
        match self {
            ExecMode::Threads => "threads".into(),
            ExecMode::Events => "events".into(),
            ExecMode::Parallel { shards } => format!("parallel:{shards}"),
        }
    }

    /// Parse a CLI spelling: `threads`, `events`, `parallel` (one shard
    /// per available core), or `parallel:S`.
    pub fn parse(name: &str) -> Result<ExecMode> {
        match name {
            "threads" => Ok(ExecMode::Threads),
            "events" => Ok(ExecMode::Events),
            "parallel" => Ok(ExecMode::Parallel {
                // Resolved at parse time so the config (and its banner /
                // reproduce line) pins the actual shard count.
                shards: std::thread::available_parallelism().map_or(1, |p| p.get()),
            }),
            other => match other.strip_prefix("parallel:") {
                Some(s) => {
                    let shards: usize = s
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad shard count in {other:?}"))?;
                    anyhow::ensure!(shards >= 1, "parallel executor needs at least one shard");
                    Ok(ExecMode::Parallel { shards })
                }
                None => anyhow::bail!(
                    "unknown executor {other:?} (want threads|events|parallel[:S])"
                ),
            },
        }
    }
}

/// Relative clock-speed factors of the paper's machines (Table 1):
/// M1 4.0 GHz, M2 2.0 GHz, M3 3.5 GHz → slowdown = 4.0/GHz − 1.
const MACHINE_SLOWDOWN: [f32; 3] = [0.0, 1.0, 0.143];
/// Extra slowdown per co-located client beyond the first (contention).
const CONTENTION_PER_CLIENT: f32 = 0.06;

/// Full specification of one simulated deployment.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub n_clients: usize,
    pub protocol: ProtocolConfig,
    pub partition: Partition,
    /// Phase 1 (sync, Algorithm 1) instead of Phase 2 (async, Algorithm 2).
    pub sync: bool,
    /// Virtual machine count (1–3): the paper's deployment variable.
    pub machines: usize,
    pub train_n: usize,
    pub test_n: usize,
    pub net: NetworkModel,
    /// Per-client crash schedule (empty = fault-free).
    pub faults: Vec<FaultPlan>,
    /// Topology-aware fault schedule (`--fault`, DESIGN.md §10): edge-cut
    /// windows and churn applied to the built overlay mid-run.  Empty =
    /// the overlay is immutable and fault-free runs stay byte-identical
    /// to the pre-fault protocol.  Requires Phase 2 (`sync` keeps the
    /// barrier's static full mesh).
    pub graph_faults: Vec<GraphFault>,
    /// Byzantine roster (`--adversary`, DESIGN.md §11): which clients lie
    /// and how.  Compiled into per-client roles at setup (ids validated,
    /// double assignment rejected).  Empty = all honest, byte-identical
    /// to the pre-adversary protocol.  Requires Phase 2 — Phase 1 assumes
    /// a fault-free system.
    pub adversaries: Vec<AdversarySpec>,
    pub seed: u64,
    /// Peer overlay (DESIGN.md §9): `Full` (default) is the paper's
    /// all-to-all dissemination; sparse presets cut per-round message
    /// volume from O(n²) to O(n·d).  The graph is built deterministically
    /// from `(topology, n_clients, seed)`.  Phase 1 (`sync`) requires
    /// `Full` — its barrier waits on every peer's round-tagged model.
    pub topology: TopologySpec,
    /// Run on a deterministic [`VirtualClock`] instead of wall time.
    pub virtual_time: bool,
    /// Which executor drives the clients under virtual time (the wall
    /// clock always uses threads).
    pub exec: ExecMode,
    /// Modeled per-round training cost under virtual time (scaled by each
    /// client's machine slowdown); ignored in wall-clock mode, where real
    /// compute time is measured instead.
    pub train_cost: Duration,
}

impl SimConfig {
    pub fn new(n_clients: usize, trainer_meta_test_batches: usize) -> Self {
        // test_n must cover the eval_full tensor
        SimConfig {
            n_clients,
            protocol: ProtocolConfig::default(),
            partition: Partition::Dirichlet(0.6),
            sync: false,
            machines: 1,
            train_n: 2000,
            test_n: trainer_meta_test_batches,
            net: NetworkModel::lan(7),
            faults: Vec::new(),
            graph_faults: Vec::new(),
            adversaries: Vec::new(),
            seed: 7,
            topology: TopologySpec::Full,
            virtual_time: false,
            exec: ExecMode::Events,
            train_cost: Duration::from_millis(20),
        }
    }

    /// Convenience: derive a config with dataset sizes adequate for `meta`.
    pub fn for_meta(n_clients: usize, meta: &crate::runtime::Meta) -> Self {
        let test_n = meta.nb_eval_full * meta.batch;
        let mut cfg = SimConfig::new(n_clients, test_n);
        cfg.train_n = (200 * n_clients).max(1000);
        cfg
    }

    /// Build this deployment's overlay graph — the one derivation of
    /// `(topology, n_clients, seed)`, shared by [`run`] and any reporting
    /// code that wants to describe the graph a config will actually use.
    pub fn build_topology(&self) -> Result<Topology> {
        self.topology.build(self.n_clients, self.seed)
    }

    /// Compile the graph-fault schedule against the built topology into
    /// the shared [`Overlay`] both hubs read (DESIGN.md §10), validating
    /// every fault at setup time:
    ///
    /// * an [`GraphFault::EdgeCut`] with an explicit edge list must name
    ///   only existing overlay edges (a cut of absent edges is a silent
    ///   no-op — the class of bug the `NetSplit` validation below also
    ///   rejects); `mincut` resolves through the seeded
    ///   [`Topology::min_cut`] and is rejected if the graph has no cut;
    /// * a [`GraphFault::Churn`] client must exist.
    ///
    /// With an empty schedule the result is the static
    /// [`Overlay::immutable`] fast path — structurally incapable of
    /// perturbing a fault-free run.
    fn compile_overlay(&self, topology: &Arc<Topology>) -> Result<Overlay> {
        if self.graph_faults.is_empty() {
            return Ok(Overlay::immutable(Arc::clone(topology)));
        }
        let mut events = Vec::new();
        let mut n_cuts = 0usize;
        for fault in &self.graph_faults {
            match fault {
                GraphFault::EdgeCut { start, end, cut } => {
                    anyhow::ensure!(end > start, "graph cut window must end after it starts");
                    let edges = match cut {
                        CutSpec::Edges(edges) => {
                            for &(a, b) in edges {
                                anyhow::ensure!(
                                    topology.has_edge(a, b),
                                    "graph cut names {a}-{b}, which is not an edge of the \
                                     built {} overlay — a cut that severs nothing is a no-op, \
                                     not a fault",
                                    self.topology.name()
                                );
                            }
                            edges.clone()
                        }
                        CutSpec::MinCut => {
                            let cut = topology.min_cut(self.seed);
                            anyhow::ensure!(
                                !cut.is_empty(),
                                "mincut fault: the {} overlay has no cut to sever",
                                self.topology.name()
                            );
                            cut
                        }
                    };
                    events.push(GraphEvent {
                        at: *start,
                        action: GraphAction::Cut { cut_id: n_cuts, edges },
                    });
                    events
                        .push(GraphEvent { at: *end, action: GraphAction::Restore { cut_id: n_cuts } });
                    n_cuts += 1;
                }
                GraphFault::Churn { client, leave, rejoin } => {
                    anyhow::ensure!(
                        (*client as usize) < self.n_clients,
                        "churn fault names client {client}, deployment has {}",
                        self.n_clients
                    );
                    events.push(GraphEvent { at: *leave, action: GraphAction::Depart(*client) });
                    if let Some(rejoin) = rejoin {
                        anyhow::ensure!(rejoin > leave, "churn rejoin must follow the departure");
                        events.push(GraphEvent { at: *rejoin, action: GraphAction::Rejoin(*client) });
                    }
                }
            }
        }
        Ok(Overlay::with_events((**topology).clone(), events, n_cuts, self.seed))
    }

    fn machine_of(&self, client: usize) -> usize {
        client % self.machines.clamp(1, 3)
    }

    /// Slowdown factor for a client given its machine + co-location count.
    fn slowdown_of(&self, client: usize) -> f32 {
        let m = self.machine_of(client);
        let colocated = (0..self.n_clients).filter(|&c| self.machine_of(c) == m).count();
        let contention = CONTENTION_PER_CLIENT * (colocated.saturating_sub(1)) as f32;
        (1.0 + MACHINE_SLOWDOWN[m]) * (1.0 + contention) - 1.0
    }
}

/// Outcome of a deployment: every client's report plus aggregates.
#[derive(Debug)]
pub struct SimResult {
    pub reports: Vec<ClientReport>,
    pub wall: Duration,
    pub machines: usize,
    pub machine_of: Vec<usize>,
    /// Aggregate traffic the deployment offered to the network — the
    /// measured O(n·d) vs O(n²) axis (see [`NetStats::msgs_per_round`]).
    pub net: NetStats,
}

impl SimResult {
    /// Mean full-test accuracy over clients that completed (not crashed).
    pub fn mean_accuracy(&self) -> Option<f32> {
        crate::metrics::mean(self.reports.iter().filter_map(|r| r.final_accuracy))
    }

    /// Max rounds completed by any non-crashed client.
    pub fn rounds(&self) -> u32 {
        self.reports
            .iter()
            .filter(|r| r.cause != TerminationCause::Crashed)
            .map(|r| r.rounds_completed)
            .max()
            .unwrap_or(0)
    }

    /// Per-virtual-machine wallclock = slowest client on that machine
    /// (the paper's M1/M2 time columns).
    pub fn machine_times(&self) -> Vec<Duration> {
        let mut times = vec![Duration::ZERO; self.machines];
        for (i, r) in self.reports.iter().enumerate() {
            let m = self.machine_of[i];
            times[m] = times[m].max(r.wall);
        }
        times
    }

    pub fn crashed(&self) -> usize {
        self.reports.iter().filter(|r| r.cause == TerminationCause::Crashed).count()
    }

    /// Mean messages offered to the network per protocol round (≈ n·d on
    /// a degree-d overlay, ≈ n² on the full mesh).
    pub fn msgs_per_round(&self) -> f64 {
        self.net.msgs_per_round(self.rounds())
    }

    /// Termination-detection health: every non-crashed client ended by CCC
    /// or CRT (not by hitting the hard round cap).
    pub fn all_terminated_adaptively(&self) -> bool {
        self.reports
            .iter()
            .filter(|r| r.cause != TerminationCause::Crashed)
            .all(|r| matches!(r.cause, TerminationCause::Converged | TerminationCause::Signaled))
    }
}

/// Run one deployment to completion.
pub fn run(trainer: &(dyn Trainer + Sync), cfg: &SimConfig) -> Result<SimResult> {
    let meta = trainer.meta().clone();
    anyhow::ensure!(cfg.n_clients >= 1, "need at least one client");
    // n_clients may exceed meta.k_max: each round then aggregates only the
    // k_max − 1 lowest-id reporters plus the local model (the artifact's
    // static fan-in cap), which is how four-digit deployments stay within
    // the aggregation shapes.
    anyhow::ensure!(
        cfg.faults.is_empty() || cfg.faults.len() == cfg.n_clients,
        "faults must be empty or one per client"
    );
    // The overlay is a pure function of (spec, n, seed): both executors —
    // and any re-run of the same config — build the identical graph.
    let topology = Arc::new(cfg.build_topology()?);
    anyhow::ensure!(
        !cfg.sync || topology.is_full(),
        "Phase 1 (sync) waits on every peer each round and requires --topology full, got {}",
        cfg.topology.name()
    );
    anyhow::ensure!(
        cfg.graph_faults.is_empty() || !cfg.sync,
        "Phase 1 (sync) assumes a static full mesh; graph faults need Phase 2"
    );
    anyhow::ensure!(
        cfg.adversaries.is_empty() || !cfg.sync,
        "Phase 1 (sync) assumes a fault-free system; Byzantine adversaries need Phase 2"
    );
    anyhow::ensure!(
        !cfg.protocol.codec.is_delta() || !cfg.sync,
        "Phase 1 (sync) exchanges dense round-tagged models; --codec delta needs Phase 2"
    );
    // Byzantine roster compiled (and validated: ids in range, no double
    // role) once, shared by both executors (DESIGN.md §11).
    let adversary_roles = compile_adversaries(&cfg.adversaries, cfg.n_clients)?;
    // NetSplit validation (DESIGN.md §10): a scheduled partition must
    // actually sever overlay edges.  A client-ID bisection that crosses
    // zero edges of the built graph — an empty/complete/unknown-id side —
    // is a silent no-op the run would then mis-report as "survived a
    // partition"; reject it at setup instead.  The crossing counts feed
    // `NetStats::edges_severed` (but only for windows that actually open
    // before the run ends — fault pressure is measured, not assumed).
    let mut split_crossings: Vec<(Duration, u64)> = Vec::new();
    for (i, split) in cfg.net.splits.iter().enumerate() {
        let crossing = topology.split_crossing_edges(&split.side_a);
        anyhow::ensure!(
            crossing > 0,
            "NetSplit #{i} ({:?} vs the rest) severs zero edges of the {} overlay — \
             a no-op partition; name a side that actually cuts the graph",
            split.side_a,
            cfg.topology.name()
        );
        split_crossings.push((split.start, crossing as u64));
    }
    // Graph faults compile against the built topology into the shared
    // time-aware overlay (the static fast path when the schedule is empty).
    let overlay = Arc::new(cfg.compile_overlay(&topology)?);

    // --- data --------------------------------------------------------------
    let test_n = cfg.test_n.max(meta.nb_eval_full * meta.batch);
    let (train, test) = Dataset::synthetic_pair(&meta, cfg.train_n, test_n, cfg.seed);
    let train = Arc::new(train);
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let parts: Vec<Vec<usize>> = match cfg.partition {
        Partition::Iid => iid_partition(&train, cfg.n_clients, &mut rng),
        Partition::Dirichlet(a) => dirichlet_partition(&train, cfg.n_clients, a, &mut rng),
        Partition::FixedChunk(size) => (0..cfg.n_clients)
            .map(|_| fixed_chunk(&train, size, &mut rng))
            .collect(),
        Partition::SkewedChunk { size, alpha } => (0..cfg.n_clients)
            .map(|_| skewed_chunk(&train, size, alpha, &mut rng))
            .collect(),
        Partition::Full => (0..cfg.n_clients).map(|_| (0..train.len()).collect()).collect(),
    };
    // One shared copy of the eval tensors for the whole deployment.
    let eval = EvalTensors::new(&test, &meta);

    // --- executors ----------------------------------------------------------
    // dfl-lint: allow(wall-clock) — harness-side stopwatch for the real-time regime; virtual runs overwrite SimResult::wall with virtual durations
    let t0 = Instant::now();
    let (reports, mut net) = match (cfg.virtual_time, cfg.exec) {
        (true, ExecMode::Events) => {
            exec::run_events(trainer, cfg, parts, &train, &eval, &overlay, &adversary_roles)?
        }
        (true, ExecMode::Parallel { shards }) => exec::run_parallel(
            trainer,
            cfg,
            parts,
            &train,
            &eval,
            &overlay,
            &adversary_roles,
            &topology,
            shards,
        )?,
        // Threads — and every wall-clock run, where blocking is real.
        _ => run_threads(trainer, cfg, parts, &train, &eval, &overlay, &adversary_roles)?,
    };
    // Virtual runs report logical time: the deployment "took" as long as
    // its slowest client's simulated schedule, not the compute wall time.
    let wall = if cfg.virtual_time {
        reports.iter().map(|r| r.wall).max().unwrap_or_default()
    } else {
        t0.elapsed()
    };
    // Severed-edge accounting: crossings of every NetSplit window that
    // opened within the run, plus whatever the graph-fault schedule
    // actually cut (the overlay reports cuts up to the latest *queried*
    // time, so a window the run never reached counts nothing).
    // Deterministic per seed — every executor queries the identical
    // logical schedule.
    net.edges_severed = overlay.edges_severed()
        + split_crossings
            .iter()
            .filter(|(start, _)| *start <= wall)
            .map(|(_, crossing)| crossing)
            .sum::<u64>();
    Ok(SimResult {
        wall,
        machines: cfg.machines.clamp(1, 3),
        machine_of: (0..cfg.n_clients).map(|c| cfg.machine_of(c)).collect(),
        reports,
        net,
    })
}

/// Thread-backed executor: one OS thread per client (wall clock, or the
/// virtual-time compatibility mode).
fn run_threads(
    trainer: &(dyn Trainer + Sync),
    cfg: &SimConfig,
    parts: Vec<Vec<usize>>,
    train: &Arc<Dataset>,
    eval: &EvalTensors,
    overlay: &Arc<Overlay>,
    adversary_roles: &[Option<crate::coordinator::fault::AdversaryKind>],
) -> Result<(Vec<ClientReport>, NetStats)> {
    enum Net {
        Real(InProcHub),
        Virtual(VirtualHub, Arc<VirtualClock>),
    }
    let net = if cfg.virtual_time {
        let clock = VirtualClock::new(cfg.n_clients);
        Net::Virtual(
            VirtualHub::with_overlay(
                cfg.n_clients,
                cfg.net.clone(),
                Arc::clone(&clock),
                Arc::clone(overlay),
            ),
            clock,
        )
    } else {
        Net::Real(InProcHub::with_overlay(
            cfg.n_clients,
            cfg.net.clone(),
            Arc::clone(overlay),
        ))
    };

    /// Hands the virtual scheduler onward when a client thread finishes —
    /// or panics; a stuck token would deadlock every other client.
    struct DetachGuard {
        clock: Arc<VirtualClock>,
        token: usize,
    }
    impl Drop for DetachGuard {
        fn drop(&mut self) {
            self.clock.detach(self.token);
        }
    }

    let reports = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        let mut spawn_err = None;
        for (i, indices) in parts.into_iter().enumerate() {
            let data = ClientData::with_eval(Arc::clone(train), indices, eval.clone());
            let fault = cfg.faults.get(i).copied().unwrap_or_default();
            let adversary = adversary_roles.get(i).copied().flatten();
            let protocol = cfg.protocol.clone();
            let client_rng = Rng::new(cfg.seed ^ (0xC11E << 8) ^ i as u64);
            let slowdown = cfg.slowdown_of(i);
            let sync = cfg.sync;
            let train_cost = cfg.virtual_time.then_some(cfg.train_cost);

            let run_client = move |transport: Box<dyn Transport>| -> Result<ClientReport> {
                if sync {
                    SyncClient {
                        id: i as u32,
                        trainer,
                        transport,
                        cfg: protocol,
                        data,
                        rng: client_rng,
                        slowdown,
                        train_cost,
                    }
                    .run()
                } else {
                    AsyncClient {
                        id: i as u32,
                        trainer,
                        transport,
                        cfg: protocol,
                        data,
                        fault,
                        adversary,
                        rng: client_rng,
                        slowdown,
                        train_cost,
                    }
                    .run()
                }
            };

            match &net {
                Net::Real(hub) => {
                    let endpoint = hub.endpoint(i as u32);
                    handles.push(scope.spawn(move || run_client(Box::new(endpoint))));
                }
                Net::Virtual(hub, clock) => {
                    let endpoint = hub.endpoint(i as u32);
                    let spawn_clock = Arc::clone(clock);
                    // Cooperatively scheduled (one runnable thread at a
                    // time), so small stacks keep 1000-client deployments
                    // cheap where a thousand default 8 MB threads are not.
                    let spawned = std::thread::Builder::new()
                        .name(format!("client-{i}"))
                        .stack_size(512 * 1024)
                        .spawn_scoped(scope, move || {
                            spawn_clock.attach(i);
                            let _guard =
                                DetachGuard { clock: Arc::clone(&spawn_clock), token: i };
                            run_client(Box::new(endpoint))
                        });
                    match spawned {
                        Ok(handle) => handles.push(handle),
                        Err(e) => {
                            // This token (and the unspawned rest) will never
                            // attach; detaching them hands the scheduler's
                            // turn onward so already-running clients can
                            // finish instead of waiting forever on a turn
                            // nobody owns. The error surfaces after joins.
                            for t in i..cfg.n_clients {
                                clock.detach(t);
                            }
                            spawn_err =
                                Some(anyhow::anyhow!("spawning client thread {i}: {e}"));
                            break;
                        }
                    }
                }
            }
        }
        let joined: Result<Vec<ClientReport>> = handles
            .into_iter()
            .enumerate()
            .map(|(i, h)| {
                h.join()
                    .map_err(|_| anyhow::anyhow!("client {i} panicked"))?
                    .with_context(|| format!("client {i} failed"))
            })
            .collect();
        match spawn_err {
            Some(e) => Err(e),
            None => joined,
        }
    })?;
    let stats = match &net {
        Net::Real(hub) => hub.net_stats(),
        Net::Virtual(hub, _) => hub.net_stats(),
    };
    Ok((reports, stats))
}
