//! [`SimConfig`] shrinker — minimal failing repros, exploiting determinism.
//!
//! Because a virtual-time deployment is a pure function of its
//! [`SimConfig`], a failing configuration can be *minimized* instead of
//! debugged at full size: [`shrink_sim_config`] bisects the client count
//! and prunes the fault list against any reproducible predicate, handing
//! back the smallest deployment that still exhibits the failure.
//!
//! Lives beside the simulator (not in `util`) because it is inherently a
//! consumer of the sim layer: the module-layering DAG (DESIGN.md §15)
//! keeps `util` free of upward dependencies.  The seeded property-test
//! runner it pairs with is [`crate::util::quickcheck::forall`].

use crate::coordinator::fault::FaultPlan;
use crate::net::TopologySpec;

use super::{ExecMode, SimConfig};

/// Outcome of [`shrink_sim_config`]: the smallest failing configuration
/// found, plus how many predicate evaluations (= deterministic re-runs)
/// the search spent.
#[derive(Debug)]
pub struct Shrunk {
    pub config: SimConfig,
    pub tests_run: usize,
}

/// Minimize a failing [`SimConfig`] against `fails` (true = the failure
/// still reproduces).  Six passes, all preserving the `faults` invariant
/// (empty or one plan per client) and never leaving a graph fault
/// dangling off the end of the client range:
///
/// 1. **Client bisection** — binary-search the smallest prefix of clients
///    (faults truncated alongside, graph faults referencing dropped
///    clients removed) that still fails.
/// 2. **Fault pruning** — try clearing the fault list outright, else
///    disable surviving fault plans one at a time.
/// 3. **Graph-fault pruning** — try clearing the graph-fault schedule
///    outright (a failure independent of the overlay dynamics is the
///    cheapest repro), else drop surviving cut/churn entries one at a
///    time.
/// 4. **Adversary pruning** — try clearing the Byzantine roster
///    outright, else drop surviving specs one at a time, then thin each
///    surviving spec's client list client by client (a one-adversary
///    repro beats a six-adversary one).
/// 5. **Topology shrinking** — halve the overlay degree while the failure
///    holds ([`TopologySpec::shrink_degree`]), then try the trivial
///    preset (`full`) outright: a failure that survives on the mesh is
///    independent of the overlay, which is the most useful thing a
///    repro can learn.
/// 6. **Executor shrinking** — for [`ExecMode::Parallel`] configs, first
///    try the single-threaded [`ExecMode::Events`] reference outright (a
///    failure that survives there is a simulator bug, not an executor
///    race, and replays with zero threads), else halve the shard count
///    toward 1 while the failure holds: a two-shard repro of a window
///    race beats a sixteen-shard one.
///
/// Like every shrinker this is greedy: for non-monotone predicates the
/// result is a local minimum (still failing, never larger than the
/// input).  If `cfg` does not fail at all it is returned unchanged.
pub fn shrink_sim_config<F>(cfg: &SimConfig, mut fails: F) -> Shrunk
where
    F: FnMut(&SimConfig) -> bool,
{
    fn truncate_clients(cfg: &SimConfig, n: usize) -> SimConfig {
        let mut cand = cfg.clone();
        cand.n_clients = n;
        if !cand.faults.is_empty() {
            cand.faults.truncate(n);
        }
        // A graph fault naming a client beyond the shrunken range would
        // make the candidate invalid, not smaller.
        cand.graph_faults.retain(|f| f.fits(n));
        // Adversary specs are per-client lists: drop the out-of-range ids
        // (and any spec emptied by that) instead of the whole roster, so
        // a failure needing one low-id adversary survives the bisection.
        for a in &mut cand.adversaries {
            a.clients.retain(|&c| (c as usize) < n);
        }
        cand.adversaries.retain(|a| !a.clients.is_empty());
        cand
    }

    let mut best = cfg.clone();
    let mut tests_run = 1;
    if !fails(&best) {
        return Shrunk { config: best, tests_run };
    }

    // 1. Bisect n_clients: invariant `best` fails and every count below
    // `lo` has been ruled out (under monotonicity).
    let mut lo = 1usize;
    while lo < best.n_clients {
        let mid = (lo + best.n_clients) / 2;
        let cand = truncate_clients(&best, mid);
        tests_run += 1;
        if fails(&cand) {
            best = cand;
        } else {
            lo = mid + 1;
        }
    }

    // 2. Prune the fault list.
    if best.faults.iter().any(|f| f.crash.is_some()) {
        let mut cand = best.clone();
        cand.faults.clear();
        tests_run += 1;
        if fails(&cand) {
            best = cand;
        } else {
            for i in 0..best.faults.len() {
                if best.faults[i].crash.is_none() {
                    continue;
                }
                let mut cand = best.clone();
                cand.faults[i] = FaultPlan::none();
                tests_run += 1;
                if fails(&cand) {
                    best = cand;
                }
            }
        }
    }

    // 3. Prune the graph-fault schedule.
    if !best.graph_faults.is_empty() {
        let mut cand = best.clone();
        cand.graph_faults.clear();
        tests_run += 1;
        if fails(&cand) {
            best = cand;
        } else {
            let mut i = 0;
            while i < best.graph_faults.len() {
                let mut cand = best.clone();
                cand.graph_faults.remove(i);
                tests_run += 1;
                if fails(&cand) {
                    best = cand; // entry was irrelevant; same index now names the next one
                } else {
                    i += 1;
                }
            }
        }
    }

    // 4. Prune the Byzantine roster: schedule, then specs, then clients.
    if !best.adversaries.is_empty() {
        let mut cand = best.clone();
        cand.adversaries.clear();
        tests_run += 1;
        if fails(&cand) {
            best = cand;
        } else {
            let mut i = 0;
            while i < best.adversaries.len() {
                let mut cand = best.clone();
                cand.adversaries.remove(i);
                tests_run += 1;
                if fails(&cand) {
                    best = cand;
                } else {
                    i += 1;
                }
            }
            // thin each surviving spec: every client whose removal keeps
            // the failure is noise (specs never shrink to empty — the
            // spec-removal pass above already ruled that out)
            for s in 0..best.adversaries.len() {
                let mut c = 0;
                while best.adversaries[s].clients.len() > 1
                    && c < best.adversaries[s].clients.len()
                {
                    let mut cand = best.clone();
                    cand.adversaries[s].clients.remove(c);
                    tests_run += 1;
                    if fails(&cand) {
                        best = cand;
                    } else {
                        c += 1;
                    }
                }
            }
        }
    }

    // 5. Shrink the topology: degree first, then the preset toward `full`.
    while let Some(smaller) = best.topology.shrink_degree() {
        let mut cand = best.clone();
        cand.topology = smaller;
        tests_run += 1;
        if fails(&cand) {
            best = cand;
        } else {
            break;
        }
    }
    if best.topology != TopologySpec::Full {
        let mut cand = best.clone();
        cand.topology = TopologySpec::Full;
        tests_run += 1;
        if fails(&cand) {
            best = cand;
        }
    }

    // 6. Shrink the executor: reference first, then halve the shards.
    if let ExecMode::Parallel { shards } = best.exec {
        let mut cand = best.clone();
        cand.exec = ExecMode::Events;
        tests_run += 1;
        if fails(&cand) {
            best = cand; // executor-independent: the zero-thread repro wins
        } else {
            let mut s = shards;
            while s > 1 {
                let mut cand = best.clone();
                cand.exec = ExecMode::Parallel { shards: s / 2 };
                tests_run += 1;
                if fails(&cand) {
                    s /= 2;
                    best = cand;
                } else {
                    break;
                }
            }
        }
    }
    Shrunk { config: best, tests_run }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fault::GraphFault;

    /// A seeded "failure": the bug needs at least `min_clients` clients
    /// and both planted faults to manifest.  The shrinker must walk a
    /// 64-client, fully-faulted config down to exactly that minimum.
    #[test]
    fn shrinks_seeded_sim_config_failure() {
        let mut rng = crate::util::Rng::new(31);
        let idx_a = rng.below(8) as u32;
        let idx_b = 8 + rng.below(8) as u32; // distinct from idx_a by range
        let min_clients = idx_b as usize + 1;

        let mut cfg = SimConfig::new(64, 128);
        cfg.faults = vec![FaultPlan::none(); 64];
        cfg.faults[idx_a as usize] = FaultPlan::at_round(3);
        cfg.faults[idx_b as usize] = FaultPlan::at_round(5);
        let fails = |c: &SimConfig| {
            c.n_clients >= min_clients
                && c.faults.iter().filter(|f| f.crash.is_some()).count() >= 2
        };
        assert!(fails(&cfg), "the seeded failure must reproduce at full size");

        let shrunk = shrink_sim_config(&cfg, fails);
        assert!(fails(&shrunk.config), "shrinking must preserve the failure");
        assert_eq!(shrunk.config.n_clients, min_clients, "client bisection");
        assert_eq!(
            shrunk.config.faults.iter().filter(|f| f.crash.is_some()).count(),
            2,
            "both load-bearing faults kept, all idle plans prunable"
        );
        assert_eq!(
            shrunk.config.faults.len(),
            min_clients,
            "faults invariant: one plan per surviving client"
        );
        assert!(shrunk.tests_run > 5, "the search must actually have run");
    }

    #[test]
    fn shrink_returns_non_failing_config_unchanged() {
        let cfg = SimConfig::new(12, 128);
        let shrunk = shrink_sim_config(&cfg, |_| false);
        assert_eq!(shrunk.config.n_clients, 12);
        assert_eq!(shrunk.tests_run, 1);
    }

    #[test]
    fn shrink_clears_irrelevant_fault_list_outright() {
        let mut cfg = SimConfig::new(16, 128);
        cfg.faults = (0..16).map(|_| FaultPlan::at_round(2)).collect();
        // Failure depends only on the client count.
        let shrunk = shrink_sim_config(&cfg, |c| c.n_clients >= 4);
        assert_eq!(shrunk.config.n_clients, 4);
        assert!(
            shrunk.config.faults.is_empty(),
            "faults play no role and must be cleared"
        );
    }

    #[test]
    fn shrink_prunes_graph_fault_lists() {
        let mut cfg = SimConfig::new(32, 128);
        cfg.topology = TopologySpec::KRegular { d: 4 };
        cfg.graph_faults = vec![
            GraphFault::parse("graph-cut:0.1-0.5:mincut").unwrap(),
            GraphFault::parse("churn:3:0.2-0.6").unwrap(),
            GraphFault::parse("churn:30:0.2").unwrap(), // dangles below 31 clients
        ];
        // The "bug" needs >= 8 clients and at least one churn entry; the
        // cut and the out-of-range churn are noise the shrinker must drop.
        let fails = |c: &SimConfig| {
            c.n_clients >= 8
                && c.graph_faults.iter().any(|f| matches!(f, GraphFault::Churn { .. }))
        };
        let shrunk = shrink_sim_config(&cfg, fails);
        assert!(fails(&shrunk.config), "shrinking must preserve the failure");
        assert_eq!(shrunk.config.n_clients, 8, "client bisection still runs first");
        assert_eq!(
            shrunk.config.graph_faults,
            vec![GraphFault::parse("churn:3:0.2-0.6").unwrap()],
            "only the load-bearing graph fault survives"
        );
        // every surviving graph fault fits the shrunken client range
        assert!(shrunk.config.graph_faults.iter().all(|f| f.fits(8)));
    }

    #[test]
    fn shrink_clears_irrelevant_graph_fault_schedule_outright() {
        let mut cfg = SimConfig::new(16, 128);
        cfg.graph_faults = vec![
            GraphFault::parse("churn:1:0.2").unwrap(),
            GraphFault::parse("churn:2:0.3").unwrap(),
        ];
        let shrunk = shrink_sim_config(&cfg, |c| c.n_clients >= 4);
        assert_eq!(shrunk.config.n_clients, 4);
        assert!(
            shrunk.config.graph_faults.is_empty(),
            "graph faults play no role and must be cleared"
        );
    }

    #[test]
    fn shrink_prunes_adversary_rosters() {
        use crate::coordinator::fault::AdversarySpec;
        let mut cfg = SimConfig::new(32, 128);
        cfg.adversaries = vec![
            AdversarySpec::parse("poison:-10:C2,C6,C10,C30").unwrap(),
            AdversarySpec::parse("equivocate:C5,C13").unwrap(),
        ];
        // The "bug" needs >= 8 clients and at least one poisoner; the
        // equivocators, the out-of-range id 30, and all but one poisoner
        // are noise the shrinker must drop.
        let fails = |c: &SimConfig| {
            use crate::coordinator::fault::AdversaryKind;
            c.n_clients >= 8
                && c.adversaries
                    .iter()
                    .any(|a| matches!(a.kind, AdversaryKind::Poison { .. }))
        };
        let shrunk = shrink_sim_config(&cfg, fails);
        assert!(fails(&shrunk.config), "shrinking must preserve the failure");
        assert_eq!(shrunk.config.n_clients, 8, "client bisection still runs first");
        assert_eq!(shrunk.config.adversaries.len(), 1, "equivocate spec pruned");
        assert_eq!(
            shrunk.config.adversaries[0].clients.len(),
            1,
            "poison roster thinned to a single client"
        );
        assert!(
            shrunk.config.adversaries[0].fits(8),
            "surviving adversary fits the shrunken client range"
        );
    }

    #[test]
    fn shrink_clears_irrelevant_adversaries_outright() {
        use crate::coordinator::fault::AdversarySpec;
        let mut cfg = SimConfig::new(16, 128);
        cfg.adversaries = vec![AdversarySpec::parse("stale-replay:C1,C2").unwrap()];
        let shrunk = shrink_sim_config(&cfg, |c| c.n_clients >= 4);
        assert_eq!(shrunk.config.n_clients, 4);
        assert!(
            shrunk.config.adversaries.is_empty(),
            "adversaries play no role and must be cleared"
        );
    }

    #[test]
    fn shrink_walks_topology_degree_down_to_the_failing_minimum() {
        let mut cfg = SimConfig::new(64, 128);
        cfg.topology = TopologySpec::KRegular { d: 16 };
        // The "bug" needs a sparse overlay of degree >= 4: the shrinker
        // must halve 16 -> 8 -> 4, reject 2, and reject `full`.
        let fails = |c: &SimConfig| {
            c.n_clients >= 8
                && matches!(c.topology, TopologySpec::KRegular { d } if d >= 4)
        };
        let shrunk = shrink_sim_config(&cfg, fails);
        assert!(fails(&shrunk.config), "shrinking must preserve the failure");
        assert_eq!(shrunk.config.n_clients, 8, "client bisection still runs first");
        assert_eq!(
            shrunk.config.topology,
            TopologySpec::KRegular { d: 4 },
            "degree must shrink to the smallest failing value"
        );
    }

    #[test]
    fn shrink_replaces_irrelevant_overlay_with_full() {
        let mut cfg = SimConfig::new(32, 128);
        cfg.topology = TopologySpec::SmallWorld { d: 8, p: 0.1 };
        // Failure depends only on the client count: the overlay must be
        // walked all the way back to the trivial mesh.
        let shrunk = shrink_sim_config(&cfg, |c| c.n_clients >= 6);
        assert_eq!(shrunk.config.n_clients, 6);
        assert_eq!(
            shrunk.config.topology,
            TopologySpec::Full,
            "an overlay the failure does not need must shrink to full"
        );
    }

    #[test]
    fn shrink_halves_parallel_shards_toward_the_failing_minimum() {
        let mut cfg = SimConfig::new(16, 128);
        cfg.exec = ExecMode::Parallel { shards: 16 };
        // The "bug" is a window race needing real parallelism: it must
        // not reproduce on the reference, and needs at least two shards.
        let fails = |c: &SimConfig| {
            c.n_clients >= 4
                && matches!(c.exec, ExecMode::Parallel { shards } if shards >= 2)
        };
        let shrunk = shrink_sim_config(&cfg, fails);
        assert!(fails(&shrunk.config), "shrinking must preserve the failure");
        assert_eq!(shrunk.config.n_clients, 4, "client bisection still runs first");
        assert_eq!(
            shrunk.config.exec,
            ExecMode::Parallel { shards: 2 },
            "shards must halve 16 -> 8 -> 4 -> 2 and stop before 1"
        );
    }

    #[test]
    fn shrink_collapses_irrelevant_executor_to_the_reference() {
        let mut cfg = SimConfig::new(16, 128);
        cfg.exec = ExecMode::Parallel { shards: 8 };
        // Failure depends only on the client count: the executor must be
        // walked all the way back to the zero-thread reference.
        let shrunk = shrink_sim_config(&cfg, |c| c.n_clients >= 4);
        assert_eq!(shrunk.config.n_clients, 4);
        assert_eq!(
            shrunk.config.exec,
            ExecMode::Events,
            "an executor the failure does not need must shrink to events"
        );
    }
}
