//! Event-driven deployment executors: every client is a poll-style state
//! machine ([`ClientStateMachine`]) pumped through a virtual clock's
//! driver API — zero per-client OS threads.
//!
//! Two shapes ([`SimConfig::exec`](super::SimConfig), virtual time only;
//! wall-clock deployments need real threads to really block):
//!
//! * [`ExecMode::Events`] — one thread, one clock, the byte-exact
//!   reference.  The executor makes exactly the scheduler transitions
//!   the thread-backed path makes — [`Step::Sleep`] ⇒
//!   [`VirtualClock::driver_sleep`], [`Step::Recv`] ⇒
//!   [`VirtualClock::driver_recv`] / resume — so a same-seed run is
//!   byte-identical across the two modes (asserted in
//!   `tests/virtual_time.rs` and at 200 clients in `tests/scale.rs`).
//! * [`ExecMode::Parallel`] — S worker threads over S shard-local
//!   clocks, synchronized by conservative lookahead windows
//!   ([`run_parallel`], DESIGN.md §12).  Byte-identical to `Events` per
//!   seed (asserted across the whole matrix in `tests/conformance.rs`);
//!   what changes is wall-clock, which is what turns 10 000-client
//!   sweeps into overnight 100k–1M-client sweeps.
//!
//! [`ExecMode::Events`]: super::ExecMode
//! [`ExecMode::Parallel`]: super::ExecMode

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::async_client::{AsyncClient, ClientData, EvalTensors};
use crate::coordinator::fault::AdversaryKind;
use crate::coordinator::machine::{ClientStateMachine, Input, Step};
use crate::coordinator::sync::SyncClient;
use crate::data::Dataset;
use crate::metrics::{ClientReport, NetStats};
use crate::net::inproc::decode_delivery;
use crate::net::{Overlay, Topology, VirtualHub};
use crate::runtime::Trainer;
use crate::util::time::{DriverRecv, SimTime, VirtualClock};
use crate::util::Rng;

use super::SimConfig;

/// What each parked machine is waiting for (the executor-side mirror of
/// the clock's blocked state).
#[derive(Clone, Copy)]
enum Pending {
    /// Never stepped: owes an [`Input::Start`].
    Fresh,
    /// Parked in [`Step::Sleep`]: owes an [`Input::SleepElapsed`].
    Sleeping,
    /// Parked in [`Step::Recv`] until `deadline`: owes a message or an
    /// [`Input::Timeout`].
    Receiving { deadline: SimTime },
}

/// Build every client's state machine in ascending id order — the one
/// construction both event-driven executors share, and the mirror of the
/// thread-backed path (same per-client RNG streams, same endpoint claim
/// order), so all executors diverge in nothing but how turns are granted.
fn build_machines<'a>(
    trainer: &'a (dyn Trainer + Sync),
    cfg: &SimConfig,
    parts: Vec<Vec<usize>>,
    train: &Arc<Dataset>,
    eval: &EvalTensors,
    hub: &VirtualHub,
    adversary_roles: &[Option<AdversaryKind>],
) -> Vec<ClientStateMachine<'a>> {
    let mut machines: Vec<ClientStateMachine<'a>> = Vec::with_capacity(cfg.n_clients);
    for (i, indices) in parts.into_iter().enumerate() {
        let data = ClientData::with_eval(Arc::clone(train), indices, eval.clone());
        let fault = cfg.faults.get(i).copied().unwrap_or_default();
        let adversary = adversary_roles.get(i).copied().flatten();
        let rng = Rng::new(cfg.seed ^ (0xC11E << 8) ^ i as u64);
        let slowdown = cfg.slowdown_of(i);
        let transport = Box::new(hub.endpoint(i as u32));
        let train_cost = Some(cfg.train_cost);
        machines.push(if cfg.sync {
            SyncClient {
                id: i as u32,
                trainer,
                transport,
                cfg: cfg.protocol.clone(),
                data,
                rng,
                slowdown,
                train_cost,
            }
            .into_machine()
        } else {
            AsyncClient {
                id: i as u32,
                trainer,
                transport,
                cfg: cfg.protocol.clone(),
                data,
                fault,
                adversary,
                rng,
                slowdown,
                train_cost,
            }
            .into_machine()
        });
    }
    machines
}

/// One worker's pump state: the machines it owns (indexed by token; a
/// shard worker owns only its members), their parked reasons, and the
/// per-client outcome slots merged by [`finish`].
struct Pump<'a> {
    machines: Vec<Option<ClientStateMachine<'a>>>,
    pending: Vec<Pending>,
    reports: Vec<Option<ClientReport>>,
    failures: Vec<Option<anyhow::Error>>,
}

impl<'a> Pump<'a> {
    fn new(n: usize) -> Pump<'a> {
        Pump {
            machines: (0..n).map(|_| None).collect(),
            pending: vec![Pending::Fresh; n],
            reports: (0..n).map(|_| None).collect(),
            failures: (0..n).map(|_| None).collect(),
        }
    }

    /// One granted turn: translate the wakeup into the machine's input,
    /// then step the machine until it parks again.
    fn pump(&mut self, clock: &VirtualClock, token: usize) {
        let mut input = match self.pending[token] {
            Pending::Fresh => Input::Start,
            Pending::Sleeping => Input::SleepElapsed,
            Pending::Receiving { deadline } => {
                match clock.driver_recv_resume(token, deadline) {
                    DriverRecv::Delivered(bytes) => Input::Msg(decode_delivery(&bytes)),
                    DriverRecv::TimedOut => Input::Timeout,
                    // Re-parked (defensive; a wakeup always carries mail or
                    // the deadline).
                    DriverRecv::Parked { deadline } => {
                        self.pending[token] = Pending::Receiving { deadline };
                        return;
                    }
                }
            }
        };
        let machine =
            // dfl-lint: allow(no-panic-hot-path) — executor invariant: the clock only grants turns to tokens it registered, and every registered token owns a machine
            self.machines[token].as_mut().expect("turn granted to a token without a machine");
        loop {
            match machine.step(input) {
                Ok(Step::Sleep(d)) => {
                    clock.driver_sleep(token, d);
                    self.pending[token] = Pending::Sleeping;
                    break;
                }
                Ok(Step::Recv(timeout)) => match clock.driver_recv(token, timeout) {
                    DriverRecv::Delivered(bytes) => input = Input::Msg(decode_delivery(&bytes)),
                    DriverRecv::TimedOut => input = Input::Timeout,
                    DriverRecv::Parked { deadline } => {
                        self.pending[token] = Pending::Receiving { deadline };
                        break;
                    }
                },
                Ok(Step::Done(report)) => {
                    self.reports[token] = Some(*report);
                    clock.detach(token);
                    break;
                }
                // A failed client leaves the deployment exactly as a dead
                // thread would: detached, its error surfaced after the
                // survivors finish.
                Err(e) => {
                    self.failures[token] = Some(e);
                    clock.detach(token);
                    break;
                }
            }
        }
    }
}

/// Merge every worker's outcome slots and surface them exactly as the
/// single-threaded executor does: the lowest-id failure first, then any
/// client the scheduler never completed.
fn finish(
    pumps: Vec<Pump<'_>>,
    hub: &VirtualHub,
    n: usize,
) -> Result<(Vec<ClientReport>, NetStats)> {
    let mut reports: Vec<Option<ClientReport>> = (0..n).map(|_| None).collect();
    let mut failures: Vec<Option<anyhow::Error>> = (0..n).map(|_| None).collect();
    for pump in pumps {
        for (i, r) in pump.reports.into_iter().enumerate() {
            if let Some(r) = r {
                reports[i] = Some(r);
            }
        }
        for (i, e) in pump.failures.into_iter().enumerate() {
            if let Some(e) = e {
                failures[i] = Some(e);
            }
        }
    }
    for (i, failure) in failures.iter_mut().enumerate() {
        if let Some(e) = failure.take() {
            return Err(e).with_context(|| format!("client {i} failed"));
        }
    }
    let reports: Result<Vec<ClientReport>> = reports
        .into_iter()
        .enumerate()
        .map(|(i, r)| r.with_context(|| format!("client {i} never completed (scheduler stall)")))
        .collect();
    Ok((reports?, hub.net_stats()))
}

/// Run one virtual-time deployment on the single-threaded event executor
/// (the byte-exact reference both other executors are measured against).
pub(super) fn run_events(
    trainer: &(dyn Trainer + Sync),
    cfg: &SimConfig,
    parts: Vec<Vec<usize>>,
    train: &Arc<Dataset>,
    eval: &EvalTensors,
    overlay: &Arc<Overlay>,
    adversary_roles: &[Option<AdversaryKind>],
) -> Result<(Vec<ClientReport>, NetStats)> {
    let n = cfg.n_clients;
    let clock = VirtualClock::new(n);
    let hub =
        VirtualHub::with_overlay(n, cfg.net.clone(), Arc::clone(&clock), Arc::clone(overlay));
    let machines = build_machines(trainer, cfg, parts, train, eval, &hub, adversary_roles);
    let mut pump = Pump::new(n);
    pump.machines = machines.into_iter().map(Some).collect();
    while let Some(token) = clock.driver_next() {
        pump.pump(&clock, token);
    }
    finish(vec![pump], &hub, n)
}

/// Run one virtual-time deployment on the sharded parallel executor
/// (DESIGN.md §12): clients are partitioned into `shards` per-core
/// shards by minimum overlay edge-cut ([`Topology::partition_shards`]),
/// each shard's ready queue runs on its own worker thread against a
/// shard-local clock ([`VirtualClock::with_members`]), and shards
/// synchronize only at conservative lookahead windows.
///
/// # The window protocol (null messages, batched)
///
/// Let `L` be the network's guaranteed minimum one-way delay
/// ([`NetworkModel::latency_floor`](crate::net::NetworkModel::latency_floor)).
/// Per round, while every worker is parked at the release barrier, the
/// coordinator computes `T_min` = the minimum
/// [`VirtualClock::pending_lower_bound`] over all shards — the earliest
/// instant anything in the whole deployment can happen — and releases
/// the workers to drain everything due strictly before the horizon
/// `H = T_min + L` ([`VirtualClock::driver_next_before`]).  Any message
/// a shard sends during the window is due at or after `now + L ≥
/// T_min + L = H`, so nothing a worker does can create work *inside*
/// another worker's current window: each shard's window is causally
/// closed, and pumping it in shard-local `(due, token)` order makes
/// every client observe exactly the mailbox/timer sequence the global
/// single-clock order would have produced.  This is the classic
/// conservative (Chandy–Misra–Bryant) scheme with the per-link null
/// messages batched into one barrier exchange per window.
///
/// Zero lookahead (e.g. the `ideal` preset) admits no conservative
/// parallelism — every cross-shard message could be due "now" — so the
/// shard count collapses to 1 and the run degenerates to a bounded
/// single-worker pump with no windows at all.
#[allow(clippy::too_many_arguments)]
pub(super) fn run_parallel(
    trainer: &(dyn Trainer + Sync),
    cfg: &SimConfig,
    parts: Vec<Vec<usize>>,
    train: &Arc<Dataset>,
    eval: &EvalTensors,
    overlay: &Arc<Overlay>,
    adversary_roles: &[Option<AdversaryKind>],
    topology: &Topology,
    shards: usize,
) -> Result<(Vec<ClientReport>, NetStats)> {
    let n = cfg.n_clients;
    let lookahead = cfg.net.latency_floor();
    let shards = if lookahead.is_zero() { 1 } else { shards };
    let shard_of = topology.partition_shards(shards, cfg.seed);
    let s = shard_of.iter().copied().max().map_or(1, |top| top + 1);
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); s];
    for (id, &sh) in shard_of.iter().enumerate() {
        members[sh].push(id);
    }
    let clocks: Vec<Arc<VirtualClock>> =
        members.iter().map(|m| VirtualClock::with_members(n, m)).collect();
    let hub = VirtualHub::with_sharded(
        n,
        cfg.net.clone(),
        clocks.clone(),
        shard_of.clone(),
        Arc::clone(overlay),
    );
    let machines = build_machines(trainer, cfg, parts, train, eval, &hub, adversary_roles);
    let mut pumps: Vec<Pump> = (0..s).map(|_| Pump::new(n)).collect();
    for (i, machine) in machines.into_iter().enumerate() {
        pumps[shard_of[i]].machines[i] = Some(machine);
    }

    if s == 1 {
        // Single shard (requested, clamped, or zero-lookahead collapse):
        // no windows, no extra threads — the reference pump on this
        // shard's clock.
        let clock = &clocks[0];
        // dfl-lint: allow(no-panic-hot-path) — s == 1 on this branch, so exactly one pump was just built
        let mut pump = pumps.pop().expect("one shard");
        while let Some(token) = clock.driver_next() {
            pump.pump(clock, token);
        }
        return finish(vec![pump], &hub, n);
    }

    let barrier = Barrier::new(s + 1);
    // Written by the coordinator only while every worker is parked at the
    // release barrier, so relaxed-ordering concerns do not arise — the
    // barrier is the synchronization edge.
    let horizon_nanos = AtomicU64::new(0);
    let done = AtomicBool::new(false);

    let pumps: Vec<Pump> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(s);
        for (sh, mut pump) in pumps.into_iter().enumerate() {
            let clock = Arc::clone(&clocks[sh]);
            let barrier = &barrier;
            let horizon_nanos = &horizon_nanos;
            let done = &done;
            let handle = std::thread::Builder::new()
                .name(format!("shard-{sh}"))
                .spawn_scoped(scope, move || {
                    loop {
                        barrier.wait(); // window release
                        if done.load(Ordering::SeqCst) {
                            break;
                        }
                        let h = Duration::from_nanos(horizon_nanos.load(Ordering::SeqCst));
                        while let Some(token) = clock.driver_next_before(h) {
                            pump.pump(&clock, token);
                        }
                        barrier.wait(); // window drained: rejoin
                    }
                    pump
                })
                // dfl-lint: allow(no-panic-hot-path) — OS refusing to spawn a thread is unrecoverable for the run; aborting the sim is the correct response
                .expect("spawn shard worker");
            handles.push(handle);
        }
        // The coordinator: one lower-bound exchange and one window per
        // iteration, until no shard has anything left to do.
        loop {
            let t_min = clocks.iter().filter_map(|c| c.pending_lower_bound()).min();
            match t_min {
                None => {
                    done.store(true, Ordering::SeqCst);
                    barrier.wait(); // final release: workers exit
                    break;
                }
                Some(t) => {
                    let h = t + lookahead;
                    horizon_nanos.store(
                        u64::try_from(h.as_nanos()).unwrap_or(u64::MAX),
                        Ordering::SeqCst,
                    );
                    barrier.wait(); // release into the window
                    barrier.wait(); // every shard drained below the horizon
                }
            }
        }
        // dfl-lint: allow(no-panic-hot-path) — join() only errs if the worker already panicked; re-raising on the coordinator surfaces that panic instead of deadlocking the barrier
        handles.into_iter().map(|h| h.join().expect("shard worker panicked")).collect()
    });
    finish(pumps, &hub, n)
}
