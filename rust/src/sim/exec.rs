//! Event-driven deployment executor: every client is a poll-style state
//! machine ([`ClientStateMachine`]) and one thread pumps all of them
//! through the virtual clock's driver API — zero per-client OS threads.
//!
//! This is [`SimConfig::exec`](super::SimConfig) = [`ExecMode::Events`]
//! (virtual time only; wall-clock deployments need real threads to really
//! block).  The executor makes exactly the scheduler transitions the
//! thread-backed path makes — [`Step::Sleep`] ⇒
//! [`VirtualClock::driver_sleep`], [`Step::Recv`] ⇒
//! [`VirtualClock::driver_recv`] / resume — so a same-seed run is
//! byte-identical across the two modes (asserted in `tests/virtual_time.rs`
//! and at 200 clients in `tests/scale.rs`).  What changes is the resource
//! envelope: a 10 000-client deployment is one thread, one clock, and ten
//! thousand small state structs.
//!
//! [`ExecMode::Events`]: super::ExecMode

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::async_client::{AsyncClient, ClientData, EvalTensors};
use crate::coordinator::machine::{ClientStateMachine, Input, Step};
use crate::coordinator::sync::SyncClient;
use crate::data::Dataset;
use crate::metrics::{ClientReport, NetStats};
use crate::net::inproc::decode_delivery;
use crate::net::{Overlay, VirtualHub};
use crate::runtime::Trainer;
use crate::util::time::{DriverRecv, SimTime, VirtualClock};
use crate::util::Rng;

use super::SimConfig;

/// What each parked machine is waiting for (the executor-side mirror of
/// the clock's blocked state).
#[derive(Clone, Copy)]
enum Pending {
    /// Never stepped: owes an [`Input::Start`].
    Fresh,
    /// Parked in [`Step::Sleep`]: owes an [`Input::SleepElapsed`].
    Sleeping,
    /// Parked in [`Step::Recv`] until `deadline`: owes a message or an
    /// [`Input::Timeout`].
    Receiving { deadline: SimTime },
}

/// Run one virtual-time deployment on the event executor.  Mirrors the
/// thread-backed path's client construction exactly (same per-client RNG
/// streams, same endpoint claim order) so the two executors diverge in
/// nothing but how turns are granted.
pub(super) fn run_events(
    trainer: &(dyn Trainer + Sync),
    cfg: &SimConfig,
    parts: Vec<Vec<usize>>,
    train: &Arc<Dataset>,
    eval: &EvalTensors,
    overlay: &Arc<Overlay>,
    adversary_roles: &[Option<crate::coordinator::fault::AdversaryKind>],
) -> Result<(Vec<ClientReport>, NetStats)> {
    let n = cfg.n_clients;
    let clock = VirtualClock::new(n);
    let hub =
        VirtualHub::with_overlay(n, cfg.net.clone(), Arc::clone(&clock), Arc::clone(overlay));

    let mut machines: Vec<ClientStateMachine> = Vec::with_capacity(n);
    for (i, indices) in parts.into_iter().enumerate() {
        let data = ClientData::with_eval(Arc::clone(train), indices, eval.clone());
        let fault = cfg.faults.get(i).copied().unwrap_or_default();
        let adversary = adversary_roles.get(i).copied().flatten();
        let rng = Rng::new(cfg.seed ^ (0xC11E << 8) ^ i as u64);
        let slowdown = cfg.slowdown_of(i);
        let transport = Box::new(hub.endpoint(i as u32));
        let train_cost = Some(cfg.train_cost);
        machines.push(if cfg.sync {
            SyncClient {
                id: i as u32,
                trainer,
                transport,
                cfg: cfg.protocol.clone(),
                data,
                rng,
                slowdown,
                train_cost,
            }
            .into_machine()
        } else {
            AsyncClient {
                id: i as u32,
                trainer,
                transport,
                cfg: cfg.protocol.clone(),
                data,
                fault,
                adversary,
                rng,
                slowdown,
                train_cost,
            }
            .into_machine()
        });
    }

    let mut pending: Vec<Pending> = vec![Pending::Fresh; n];
    let mut reports: Vec<Option<ClientReport>> = (0..n).map(|_| None).collect();
    let mut failures: Vec<Option<anyhow::Error>> = (0..n).map(|_| None).collect();

    // The pump: take the next turn, translate the wakeup into the machine's
    // input, then step the machine until it parks again.
    while let Some(token) = clock.driver_next() {
        let mut input = match pending[token] {
            Pending::Fresh => Input::Start,
            Pending::Sleeping => Input::SleepElapsed,
            Pending::Receiving { deadline } => {
                match clock.driver_recv_resume(token, deadline) {
                    DriverRecv::Delivered(bytes) => Input::Msg(decode_delivery(&bytes)),
                    DriverRecv::TimedOut => Input::Timeout,
                    // Re-parked (defensive; a wakeup always carries mail or
                    // the deadline).
                    DriverRecv::Parked { deadline } => {
                        pending[token] = Pending::Receiving { deadline };
                        continue;
                    }
                }
            }
        };
        loop {
            match machines[token].step(input) {
                Ok(Step::Sleep(d)) => {
                    clock.driver_sleep(token, d);
                    pending[token] = Pending::Sleeping;
                    break;
                }
                Ok(Step::Recv(timeout)) => match clock.driver_recv(token, timeout) {
                    DriverRecv::Delivered(bytes) => input = Input::Msg(decode_delivery(&bytes)),
                    DriverRecv::TimedOut => input = Input::Timeout,
                    DriverRecv::Parked { deadline } => {
                        pending[token] = Pending::Receiving { deadline };
                        break;
                    }
                },
                Ok(Step::Done(report)) => {
                    reports[token] = Some(*report);
                    clock.detach(token);
                    break;
                }
                // A failed client leaves the deployment exactly as a dead
                // thread would: detached, its error surfaced after the
                // survivors finish.
                Err(e) => {
                    failures[token] = Some(e);
                    clock.detach(token);
                    break;
                }
            }
        }
    }

    for (i, failure) in failures.iter_mut().enumerate() {
        if let Some(e) = failure.take() {
            return Err(e).with_context(|| format!("client {i} failed"));
        }
    }
    let reports: Result<Vec<ClientReport>> = reports
        .into_iter()
        .enumerate()
        .map(|(i, r)| r.with_context(|| format!("client {i} never completed (scheduler stall)")))
        .collect();
    Ok((reports?, hub.net_stats()))
}
