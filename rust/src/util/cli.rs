//! Minimal declarative CLI flag parser (the `clap` substrate).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, typed getters with defaults, and auto-generated `--help`.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// One registered flag (for help text + boolean detection).
#[derive(Clone)]
struct Spec {
    name: &'static str,
    help: &'static str,
    is_bool: bool,
    default: Option<String>,
}

/// Declarative flag set; call [`Flags::parse`] on `std::env::args`-style
/// input to get an [`Args`] bag.
pub struct Flags {
    command: &'static str,
    about: &'static str,
    specs: Vec<Spec>,
}

impl Flags {
    pub fn new(command: &'static str, about: &'static str) -> Self {
        Flags { command, about, specs: Vec::new() }
    }

    /// Register a value flag with an optional default (None = required).
    pub fn opt(mut self, name: &'static str, default: Option<&str>, help: &'static str) -> Self {
        self.specs.push(Spec {
            name,
            help,
            is_bool: false,
            default: default.map(str::to_string),
        });
        self
    }

    /// Register a boolean switch (defaults to false).
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(Spec { name, help, is_bool: true, default: None });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nflags:\n", self.command, self.about);
        for spec in &self.specs {
            let d = match (&spec.default, spec.is_bool) {
                (_, true) => " (switch)".to_string(),
                (Some(d), _) => format!(" (default {d})"),
                (None, _) => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", spec.name, spec.help, d));
        }
        s
    }

    /// Parse raw args (without the program/subcommand names).
    pub fn parse<I: IntoIterator<Item = String>>(&self, args: I) -> Result<Args> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut positional = Vec::new();
        for spec in &self.specs {
            if let Some(d) = &spec.default {
                values.insert(spec.name.to_string(), d.clone());
            }
        }
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(name) = arg.strip_prefix("--") {
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .with_context(|| format!("unknown flag --{name}\n{}", self.usage()))?;
                let value = if spec.is_bool {
                    inline.unwrap_or_else(|| "true".to_string())
                } else {
                    match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .with_context(|| format!("--{name} requires a value"))?,
                    }
                };
                values.insert(name.to_string(), value);
            } else {
                positional.push(arg);
            }
        }
        for spec in &self.specs {
            if !spec.is_bool && spec.default.is_none() && !values.contains_key(spec.name) {
                bail!("missing required flag --{}\n{}", spec.name, self.usage());
            }
        }
        Ok(Args { values, positional })
    }
}

/// Parsed argument bag with typed getters.
pub struct Args {
    values: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn str(&self, name: &str) -> &str {
        self.values.get(name).map(String::as_str).unwrap_or("")
    }

    pub fn usize(&self, name: &str) -> Result<usize> {
        self.values
            .get(name)
            .with_context(|| format!("missing --{name}"))?
            .parse()
            .with_context(|| format!("--{name} must be an integer"))
    }

    pub fn u64(&self, name: &str) -> Result<u64> {
        Ok(self.usize(name)? as u64)
    }

    pub fn f64(&self, name: &str) -> Result<f64> {
        self.values
            .get(name)
            .with_context(|| format!("missing --{name}"))?
            .parse()
            .with_context(|| format!("--{name} must be a float"))
    }

    pub fn f32(&self, name: &str) -> Result<f32> {
        Ok(self.f64(name)? as f32)
    }

    pub fn bool(&self, name: &str) -> bool {
        matches!(
            self.values.get(name).map(String::as_str),
            Some("true") | Some("1") | Some("yes")
        )
    }

    pub fn csv_usize(&self, name: &str) -> Result<Vec<usize>> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .with_context(|| format!("--{name}: bad integer {s:?}"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags() -> Flags {
        Flags::new("test", "test command")
            .opt("clients", Some("4"), "number of clients")
            .opt("alpha", Some("0.6"), "dirichlet alpha")
            .opt("name", None, "required name")
            .switch("verbose", "noisy output")
    }

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = flags().parse(sv(&["--name", "x"])).unwrap();
        assert_eq!(a.usize("clients").unwrap(), 4);
        assert_eq!(a.f32("alpha").unwrap(), 0.6);
        assert!(!a.bool("verbose"));
    }

    #[test]
    fn values_and_switches() {
        let a = flags()
            .parse(sv(&["--clients", "12", "--verbose", "--name=y", "pos1"]))
            .unwrap();
        assert_eq!(a.usize("clients").unwrap(), 12);
        assert!(a.bool("verbose"));
        assert_eq!(a.str("name"), "y");
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn missing_required_errors() {
        assert!(flags().parse(sv(&[])).is_err());
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(flags().parse(sv(&["--nope", "1", "--name", "x"])).is_err());
    }

    #[test]
    fn csv_parsing() {
        let f = Flags::new("t", "").opt("ns", Some("4,6,8"), "");
        let a = f.parse(sv(&[])).unwrap();
        assert_eq!(a.csv_usize("ns").unwrap(), vec![4, 6, 8]);
    }
}
