//! Clock abstraction: real wall time or a deterministic virtual clock.
//!
//! Every layer that waits — the in-process transport's delivery delays, the
//! Phase-2 wait window, Phase-1's round barrier, fault-plan downtime, and
//! the machine-contention slowdown — goes through a [`Clock`] handle instead
//! of `Instant::now()` / `thread::sleep`.  [`Clock::Real`] preserves the
//! original wall-clock behaviour (TCP deployments, real-clock smoke tests);
//! [`Clock::Virtual`] runs the whole deployment as a discrete-event
//! simulation whose logical time jumps instantly to the next due event, so
//! a protocol round that "waits" 80 ms costs microseconds of wall time and
//! a 1000-client run is limited by compute, not by sleeping.
//!
//! # DESIGN — virtual-clock event ordering and determinism
//!
//! The virtual clock is a cooperative discrete-event scheduler over the
//! deployment's client threads:
//!
//! * **One runnable thread at a time.**  Every participant registers a
//!   `token` (its client id) and gates on [`VirtualClock::attach`] before
//!   doing any work.  A thread runs until it blocks — [`VirtualClock::sleep`]
//!   (training charge, fault downtime) or [`VirtualClock::recv_deadline`]
//!   (transport wait) — and only then does the scheduler hand the CPU to the
//!   next ready thread.  Serial execution means the interleaving of sends,
//!   receives and RNG draws is a pure function of the configuration, which
//!   is what makes same-seed runs byte-identical.
//! * **Events are totally ordered by `(due, seq)`.**  A scheduled message
//!   delivery carries a key `(from, to, per-link seq)`; two deliveries due
//!   at the same instant fire in key order, never in OS-arrival order.
//!   Sleep/deadline wakeups at the same instant are granted in token order.
//! * **Time advances only when no thread is ready.**  When every live
//!   thread is blocked, the scheduler fires all deliveries due at or before
//!   the earliest pending instant, advances `now` to it, and wakes the
//!   lowest ready token.  Logical time is therefore exact: an 80 ms wait
//!   window ends at precisely `start + 80 ms`, with zero OS-jitter.
//! * **Mailboxes are per-token FIFO queues of fired events.**  A delivery
//!   becomes visible the moment its due instant fires, in `(due, key)`
//!   order; [`VirtualClock::recv_deadline`] pops in that arrival order,
//!   [`VirtualClock::try_recv`] never blocks, and mail posted to a `Done`
//!   token is swallowed silently (the crash model).  Mail never expires:
//!   anything delivered during a round boundary is waiting at the next
//!   receive.
//! * **Payloads are opaque bytes.**  The clock carries encoded wire
//!   messages (`Msg::encode`) so `util` stays independent of `net`; the
//!   virtual transport decodes on receive, preserving the seed behaviour of
//!   exercising the codec on every in-process message.
//!
//! Liveness: every blocking call carries a finite due instant (windows and
//! barriers always have deadlines), so the scheduler can always advance; a
//! thread that finishes (or panics) detaches via a drop guard, and sends to
//! detached clients vanish silently — exactly the paper's crash model.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// A timestamp on a [`Clock`]: time elapsed since the clock's epoch.
pub type SimTime = Duration;

/// Per-client handle on either wall time or a shared [`VirtualClock`].
///
/// Cheap to clone; obtain one from `Transport::clock()` so the same client
/// code runs under both time regimes.
///
/// A virtual handle charges sleeps to logical time only — an hour of
/// protocol waiting costs microseconds of wall time:
///
/// ```
/// use std::sync::Arc;
/// use std::time::Duration;
/// use dfl::util::time::{Clock, VirtualClock};
///
/// let vc = VirtualClock::new(1);
/// let clock = Clock::virtual_for(Arc::clone(&vc), 0);
/// assert!(clock.is_virtual());
/// std::thread::scope(|s| {
///     s.spawn(|| {
///         vc.attach(0);
///         clock.sleep(Duration::from_secs(3600)); // logical hour, instant
///         assert_eq!(clock.now(), Duration::from_secs(3600));
///         vc.detach(0);
///     });
/// });
/// ```
#[derive(Clone)]
pub enum Clock {
    /// Wall time, measured from this handle's creation.
    Real { epoch: Instant },
    /// Logical time on a shared discrete-event scheduler.
    Virtual { clock: Arc<VirtualClock>, token: usize },
}

impl Clock {
    /// A fresh wall-clock handle (epoch = now).
    pub fn real() -> Clock {
        Clock::Real { epoch: Instant::now() }
    }

    /// Handle for one registered participant of a virtual clock.
    pub fn virtual_for(clock: Arc<VirtualClock>, token: usize) -> Clock {
        Clock::Virtual { clock, token }
    }

    pub fn is_virtual(&self) -> bool {
        matches!(self, Clock::Virtual { .. })
    }

    /// Time elapsed since this clock's epoch.
    pub fn now(&self) -> SimTime {
        match self {
            Clock::Real { epoch } => epoch.elapsed(),
            Clock::Virtual { clock, .. } => clock.now(),
        }
    }

    /// Block (really or logically) for `d`.
    pub fn sleep(&self, d: Duration) {
        match self {
            Clock::Real { .. } => std::thread::sleep(d),
            Clock::Virtual { clock, token } => clock.sleep(*token, d),
        }
    }
}

/// State of one registered participant.
enum ThreadState {
    /// Scheduled: the thread may run until its next blocking call.
    Running,
    /// Blocked in [`VirtualClock::sleep`] until `due`.
    Asleep { due: u64 },
    /// Blocked in [`VirtualClock::recv_deadline`] until mail or `deadline`.
    Receiving { deadline: u64 },
    /// Finished (or crashed); sends to it are dropped.
    Done,
}

/// One scheduled delivery: fires into `to`'s mailbox at `due`; ties broken
/// by `key` (see module DESIGN note).
struct VcEvent {
    due: u64,
    key: (u32, u32, u64),
    to: usize,
    payload: Vec<u8>,
}

impl PartialEq for VcEvent {
    fn eq(&self, other: &Self) -> bool {
        (self.due, self.key) == (other.due, other.key)
    }
}
impl Eq for VcEvent {}
impl PartialOrd for VcEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for VcEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.due, self.key).cmp(&(other.due, other.key))
    }
}

struct VcState {
    /// Logical nanoseconds since the simulation epoch.
    now: u64,
    threads: Vec<ThreadState>,
    mailboxes: Vec<VecDeque<Vec<u8>>>,
    events: BinaryHeap<Reverse<VcEvent>>,
    /// Tokens currently in `Running` state (0 or 1 after startup).
    running: usize,
    /// Tokens not yet `Done`.
    live: usize,
}

/// The shared discrete-event scheduler (see module docs).
///
/// Deliveries posted with a `(from, to, seq)` key arrive at exactly their
/// due instant of logical time, ties broken by key — never by OS timing:
///
/// ```
/// use std::sync::Arc;
/// use std::time::Duration;
/// use dfl::util::time::VirtualClock;
///
/// let clock = VirtualClock::new(2);
/// std::thread::scope(|s| {
///     let c = Arc::clone(&clock);
///     s.spawn(move || {
///         c.attach(0);
///         c.post(1, Duration::from_millis(5), (0, 1, 1), vec![42]);
///         c.detach(0);
///     });
///     let c = Arc::clone(&clock);
///     s.spawn(move || {
///         c.attach(1);
///         assert_eq!(c.recv_deadline(1, Duration::from_secs(1)), Some(vec![42]));
///         assert_eq!(c.now(), Duration::from_millis(5)); // exact logical latency
///         c.detach(1);
///     });
/// });
/// ```
pub struct VirtualClock {
    state: Mutex<VcState>,
    /// One condvar per token, paired with `state`.
    cvs: Vec<Condvar>,
}

fn to_nanos(d: Duration) -> u64 {
    d.as_nanos().min(u64::MAX as u128) as u64
}

impl VirtualClock {
    /// Create a clock for `n` participants (tokens `0..n`).  All start
    /// blocked at t = 0; the scheduler grants token 0 the first turn, so
    /// threads may be spawned in any order and simply gate on [`attach`].
    ///
    /// [`attach`]: VirtualClock::attach
    pub fn new(n: usize) -> Arc<VirtualClock> {
        let mut state = VcState {
            now: 0,
            threads: (0..n).map(|_| ThreadState::Asleep { due: 0 }).collect(),
            mailboxes: (0..n).map(|_| VecDeque::new()).collect(),
            events: BinaryHeap::new(),
            running: 0,
            live: n,
        };
        let cvs: Vec<Condvar> = (0..n).map(|_| Condvar::new()).collect();
        Self::schedule(&mut state, &cvs);
        Arc::new(VirtualClock { state: Mutex::new(state), cvs })
    }

    /// Current logical time.  Deterministic when called by the running
    /// participant (time cannot advance while any thread runs).
    pub fn now(&self) -> SimTime {
        Duration::from_nanos(self.state.lock().unwrap().now)
    }

    /// Gate until this token is scheduled.  Must be the first clock call a
    /// participant thread makes.
    pub fn attach(&self, token: usize) {
        let guard = self.state.lock().unwrap();
        drop(self.wait_for_turn(guard, token));
    }

    /// Unregister a finished participant and hand the turn onward.  Safe to
    /// call from a drop guard on panic; idempotent.
    pub fn detach(&self, token: usize) {
        let mut s = self.state.lock().unwrap();
        if matches!(s.threads[token], ThreadState::Done) {
            return;
        }
        let was_running = matches!(s.threads[token], ThreadState::Running);
        s.threads[token] = ThreadState::Done;
        s.mailboxes[token].clear();
        s.live -= 1;
        if was_running {
            s.running -= 1;
        }
        if s.running == 0 && s.live > 0 {
            Self::schedule(&mut s, &self.cvs);
        }
    }

    /// Block this token for `d` of logical time.
    pub fn sleep(&self, token: usize, d: Duration) {
        let mut s = self.state.lock().unwrap();
        let due = s.now.saturating_add(to_nanos(d));
        s.threads[token] = ThreadState::Asleep { due };
        s.running -= 1;
        if s.running == 0 {
            Self::schedule(&mut s, &self.cvs);
        }
        drop(self.wait_for_turn(s, token));
    }

    /// Schedule `payload` for delivery into `to`'s mailbox after `delay`.
    /// `key` must be unique and reproducible (e.g. `(from, to, link seq)`);
    /// it breaks ties between deliveries due at the same instant.
    pub fn post(&self, to: usize, delay: Duration, key: (u32, u32, u64), payload: Vec<u8>) {
        let mut s = self.state.lock().unwrap();
        let due = s.now.saturating_add(to_nanos(delay));
        s.events.push(Reverse(VcEvent { due, key, to, payload }));
    }

    /// Pop the next delivered payload, or block until one arrives or
    /// logical `timeout` elapses (then `None`).
    pub fn recv_deadline(&self, token: usize, timeout: Duration) -> Option<Vec<u8>> {
        let mut s = self.state.lock().unwrap();
        let deadline = s.now.saturating_add(to_nanos(timeout));
        loop {
            Self::fire_due(&mut s);
            if let Some(p) = s.mailboxes[token].pop_front() {
                return Some(p);
            }
            if s.now >= deadline {
                return None;
            }
            s.threads[token] = ThreadState::Receiving { deadline };
            s.running -= 1;
            if s.running == 0 {
                Self::schedule(&mut s, &self.cvs);
            }
            s = self.wait_for_turn(s, token);
        }
    }

    /// Non-blocking receive of anything already due.
    pub fn try_recv(&self, token: usize) -> Option<Vec<u8>> {
        let mut s = self.state.lock().unwrap();
        Self::fire_due(&mut s);
        s.mailboxes[token].pop_front()
    }

    /// Park until the scheduler marks `token` running again.
    fn wait_for_turn<'a>(
        &'a self,
        mut guard: MutexGuard<'a, VcState>,
        token: usize,
    ) -> MutexGuard<'a, VcState> {
        while !matches!(guard.threads[token], ThreadState::Running) {
            guard = self.cvs[token].wait(guard).unwrap();
        }
        guard
    }

    /// Deliver every event due at or before `now` (mailboxes of `Done`
    /// tokens swallow their traffic — the crash model).
    fn fire_due(s: &mut VcState) {
        while let Some(Reverse(ev)) = s.events.peek() {
            if ev.due > s.now {
                break;
            }
            let Reverse(ev) = s.events.pop().unwrap();
            if !matches!(s.threads[ev.to], ThreadState::Done) {
                s.mailboxes[ev.to].push_back(ev.payload);
            }
        }
    }

    /// Core scheduling step; requires `running == 0`.  Fires due events,
    /// wakes the lowest ready token, advancing `now` to the earliest
    /// pending instant when nothing is ready yet.
    fn schedule(s: &mut VcState, cvs: &[Condvar]) {
        debug_assert_eq!(s.running, 0);
        if s.live == 0 {
            return;
        }
        loop {
            Self::fire_due(s);
            let mut next_due: Option<u64> = s.events.peek().map(|Reverse(e)| e.due);
            let mut pick: Option<usize> = None;
            for (t, st) in s.threads.iter().enumerate() {
                let ready = match st {
                    ThreadState::Running => {
                        debug_assert!(false, "schedule() with a running thread");
                        false
                    }
                    ThreadState::Done => continue,
                    ThreadState::Asleep { due } => {
                        if *due <= s.now {
                            true
                        } else {
                            next_due = Some(next_due.map_or(*due, |d| d.min(*due)));
                            false
                        }
                    }
                    ThreadState::Receiving { deadline } => {
                        if !s.mailboxes[t].is_empty() || *deadline <= s.now {
                            true
                        } else {
                            next_due = Some(next_due.map_or(*deadline, |d| d.min(*deadline)));
                            false
                        }
                    }
                };
                if ready {
                    pick = Some(t);
                    break;
                }
            }
            if let Some(t) = pick {
                s.threads[t] = ThreadState::Running;
                s.running = 1;
                cvs[t].notify_all();
                return;
            }
            match next_due {
                // Nothing ready: jump to the earliest pending instant.
                Some(d) if d > s.now => s.now = d,
                // No pending work at all — every live thread is Done-racing
                // to detach, or the simulation is over.
                _ => return,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MS: Duration = Duration::from_millis(1);

    #[test]
    fn real_clock_elapses() {
        let c = Clock::real();
        assert!(!c.is_virtual());
        let t0 = c.now();
        std::thread::sleep(Duration::from_millis(2));
        assert!(c.now() > t0);
    }

    #[test]
    fn virtual_sleep_advances_logical_time_instantly() {
        let clock = VirtualClock::new(2);
        let wall = Instant::now();
        let ends: Vec<SimTime> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..2usize)
                .map(|t| {
                    let clock = Arc::clone(&clock);
                    scope.spawn(move || {
                        clock.attach(t);
                        // token 0 sleeps 10 s, token 1 sleeps 20 s — virtual
                        clock.sleep(t, Duration::from_secs(10 * (t as u64 + 1)));
                        let end = clock.now();
                        clock.detach(t);
                        end
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(ends[0], Duration::from_secs(10));
        assert_eq!(ends[1], Duration::from_secs(20));
        assert_eq!(clock.now(), Duration::from_secs(20));
        assert!(wall.elapsed() < Duration::from_secs(2), "virtual sleep slept for real");
    }

    #[test]
    fn same_instant_deliveries_fire_in_key_order() {
        let clock = VirtualClock::new(2);
        std::thread::scope(|scope| {
            let c0 = Arc::clone(&clock);
            scope.spawn(move || {
                c0.attach(0);
                // posted in reverse key order, same due instant
                c0.post(1, 5 * MS, (0, 1, 2), vec![2]);
                c0.post(1, 5 * MS, (0, 1, 1), vec![1]);
                c0.detach(0);
            });
            let c1 = Arc::clone(&clock);
            scope.spawn(move || {
                c1.attach(1);
                let a = c1.recv_deadline(1, Duration::from_secs(1)).unwrap();
                let b = c1.recv_deadline(1, Duration::from_secs(1)).unwrap();
                assert_eq!((a, b), (vec![1], vec![2]), "ties must break by key");
                assert_eq!(c1.now(), 5 * MS, "delivery at exact due instant");
                c1.detach(1);
            });
        });
    }

    #[test]
    fn recv_deadline_times_out_at_exact_instant() {
        let clock = VirtualClock::new(1);
        std::thread::scope(|scope| {
            let c = Arc::clone(&clock);
            scope.spawn(move || {
                c.attach(0);
                assert!(c.recv_deadline(0, 50 * MS).is_none());
                assert_eq!(c.now(), 50 * MS);
                c.detach(0);
            });
        });
    }

    #[test]
    fn detach_unblocks_waiters_and_drops_mail() {
        let clock = VirtualClock::new(2);
        std::thread::scope(|scope| {
            let c0 = Arc::clone(&clock);
            scope.spawn(move || {
                c0.attach(0);
                c0.post(1, Duration::ZERO, (0, 1, 1), vec![7]);
                c0.detach(0); // token 1 must still be scheduled afterwards
            });
            let c1 = Arc::clone(&clock);
            scope.spawn(move || {
                c1.attach(1);
                c1.sleep(1, 10 * MS);
                // mail sent to a detached token is swallowed silently
                c1.post(0, Duration::ZERO, (1, 0, 1), vec![9]);
                assert_eq!(c1.try_recv(1), Some(vec![7]));
                assert_eq!(c1.try_recv(1), None);
                c1.detach(1);
            });
        });
    }

    #[test]
    fn ping_pong_round_trip_accumulates_latency() {
        let clock = VirtualClock::new(2);
        std::thread::scope(|scope| {
            let c0 = Arc::clone(&clock);
            scope.spawn(move || {
                c0.attach(0);
                c0.post(1, 3 * MS, (0, 1, 1), vec![1]);
                let got = c0.recv_deadline(0, Duration::from_secs(1)).unwrap();
                assert_eq!(got, vec![2]);
                assert_eq!(c0.now(), 7 * MS, "3 ms there + 4 ms back");
                c0.detach(0);
            });
            let c1 = Arc::clone(&clock);
            scope.spawn(move || {
                c1.attach(1);
                let got = c1.recv_deadline(1, Duration::from_secs(1)).unwrap();
                assert_eq!(got, vec![1]);
                c1.post(0, 4 * MS, (1, 0, 1), vec![2]);
                c1.detach(1);
            });
        });
    }
}
