//! Clock abstraction: real wall time or a deterministic virtual clock.
//!
//! Every layer that waits — the in-process transport's delivery delays, the
//! Phase-2 wait window, Phase-1's round barrier, fault-plan downtime, and
//! the machine-contention slowdown — goes through a [`Clock`] handle instead
//! of `Instant::now()` / `thread::sleep`.  [`Clock::Real`] preserves the
//! original wall-clock behaviour (TCP deployments, real-clock smoke tests);
//! [`Clock::Virtual`] runs the whole deployment as a discrete-event
//! simulation whose logical time jumps instantly to the next due event, so
//! a protocol round that "waits" 80 ms costs microseconds of wall time and
//! a 1000-client run is limited by compute, not by sleeping.
//!
//! # DESIGN — virtual-clock event ordering and determinism
//!
//! The virtual clock is a serialized discrete-event scheduler over the
//! deployment's participants:
//!
//! * **One runnable participant at a time.**  Every participant registers a
//!   `token` (its client id).  A participant runs until it blocks —
//!   [`VirtualClock::sleep`] (training charge, fault downtime) or
//!   [`VirtualClock::recv_deadline`] (transport wait) — and only then does
//!   the scheduler hand the turn to the next ready token.  Serial execution
//!   means the interleaving of sends, receives and RNG draws is a pure
//!   function of the configuration, which is what makes same-seed runs
//!   byte-identical.
//! * **Events are totally ordered by `(due, seq)`.**  A scheduled message
//!   delivery carries a key `(from, to, per-link seq)`; two deliveries due
//!   at the same instant fire in key order, never in OS-arrival order.
//!   Sleep/deadline wakeups at the same instant are granted in token order.
//! * **Wakeups are incremental, not scanned.**  Ready tokens live in an
//!   explicit ready set (granted lowest-token-first); pending sleep and
//!   receive deadlines live in a `(due, token, gen)` timer heap, and a mail
//!   delivery moves its receiver straight onto the ready set.  A context
//!   switch therefore costs O(log n) instead of rescanning every
//!   participant's state (the pre-refactor O(n) bottleneck at four-digit
//!   client counts).  The `gen` tag makes superseded timer entries — a
//!   receive deadline whose mail arrived first — cheap to discard lazily.
//! * **The `(due, token, gen)` tuple order is a pinned contract,** not an
//!   incidental field layout: timers tied on `due` pop in ascending
//!   *token* order (then arming order via `gen`), which — together with
//!   the ready set's lowest-token grant — is the tie-break every executor
//!   (threads, events, and the sharded parallel merge, DESIGN.md §12)
//!   relies on for byte-identical schedules.  The regression test
//!   `equal_deadline_timers_drain_in_token_order` pins it; reordering the
//!   tuple fields is a determinism break, not a refactor.
//! * **Time advances only when no token is ready.**  The scheduler fires
//!   every delivery and timer due at or before the earliest pending
//!   instant, advances `now` to it, and wakes the lowest ready token.
//!   Logical time is therefore exact: an 80 ms wait window ends at
//!   precisely `start + 80 ms`, with zero OS-jitter.
//! * **Mailboxes are per-token FIFO queues of fired events.**  A delivery
//!   becomes visible the moment its due instant fires, in `(due, key)`
//!   order; [`VirtualClock::recv_deadline`] pops in that arrival order,
//!   [`VirtualClock::try_recv`] never blocks, and mail posted to a `Done`
//!   token is swallowed silently (the crash model).  Mail never expires:
//!   anything delivered during a round boundary is waiting at the next
//!   receive.
//! * **Payloads are opaque shared bytes.**  The clock carries encoded wire
//!   messages (`Msg::encode`) as `Arc<[u8]>` so `util` stays independent of
//!   `net` and a broadcast to 10 000 peers shares one encoded buffer
//!   instead of cloning it 10 000 times; the virtual transport decodes on
//!   receive, preserving the seed behaviour of exercising the codec on
//!   every in-process message.
//!
//! # Three ways to drive the scheduler
//!
//! *Thread-backed* (compatibility mode): each participant is an OS thread
//! that gates on [`VirtualClock::attach`] and parks on a condvar whenever
//! it is not its turn.  *Event-driven* (`sim::exec`): a single thread owns
//! every client as a poll-style state machine and pumps the scheduler
//! through the non-parking driver API ([`VirtualClock::driver_next`],
//! [`VirtualClock::driver_sleep`], [`VirtualClock::driver_recv`]) — same
//! `VcState` transitions, zero per-client threads, byte-identical
//! schedules.  *Sharded parallel* (`sim::exec::run_parallel`): S worker
//! threads each own one clock built by [`VirtualClock::with_members`]
//! over a disjoint client shard and pump it through the *bounded* driver
//! API ([`VirtualClock::driver_next_before`]) up to a conservative
//! horizon the coordinator derives from every shard's
//! [`VirtualClock::pending_lower_bound`] plus the network's latency
//! floor; cross-shard traffic lands via [`VirtualClock::post_at`] at an
//! absolute instant at or beyond that horizon, so no shard ever receives
//! a message from its own past (the null-message bound, DESIGN.md §12).
//!
//! Liveness: every blocking call carries a finite due instant (windows and
//! barriers always have deadlines), so the scheduler can always advance; a
//! participant that finishes (or panics) detaches via a drop guard, and
//! sends to detached clients vanish silently — exactly the paper's crash
//! model.

use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap, VecDeque};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// A timestamp on a [`Clock`]: time elapsed since the clock's epoch.
pub type SimTime = Duration;

/// Per-client handle on either wall time or a shared [`VirtualClock`].
///
/// Cheap to clone; obtain one from `Transport::clock()` so the same client
/// code runs under both time regimes.
///
/// A virtual handle charges sleeps to logical time only — an hour of
/// protocol waiting costs microseconds of wall time:
///
/// ```
/// use std::sync::Arc;
/// use std::time::Duration;
/// use dfl::util::time::{Clock, VirtualClock};
///
/// let vc = VirtualClock::new(1);
/// let clock = Clock::virtual_for(Arc::clone(&vc), 0);
/// assert!(clock.is_virtual());
/// std::thread::scope(|s| {
///     s.spawn(|| {
///         vc.attach(0);
///         clock.sleep(Duration::from_secs(3600)); // logical hour, instant
///         assert_eq!(clock.now(), Duration::from_secs(3600));
///         vc.detach(0);
///     });
/// });
/// ```
#[derive(Clone)]
pub enum Clock {
    /// Wall time, measured from this handle's creation.
    Real { epoch: Instant },
    /// Logical time on a shared discrete-event scheduler.
    Virtual { clock: Arc<VirtualClock>, token: usize },
}

impl Clock {
    /// A fresh wall-clock handle (epoch = now).
    pub fn real() -> Clock {
        Clock::Real { epoch: Instant::now() }
    }

    /// Handle for one registered participant of a virtual clock.
    pub fn virtual_for(clock: Arc<VirtualClock>, token: usize) -> Clock {
        Clock::Virtual { clock, token }
    }

    pub fn is_virtual(&self) -> bool {
        matches!(self, Clock::Virtual { .. })
    }

    /// Time elapsed since this clock's epoch.
    pub fn now(&self) -> SimTime {
        match self {
            Clock::Real { epoch } => epoch.elapsed(),
            Clock::Virtual { clock, .. } => clock.now(),
        }
    }

    /// Block (really or logically) for `d`.
    ///
    /// Only valid from the owning participant's turn; poll-style state
    /// machines must yield a `Sleep` step to their executor instead (the
    /// executor calls [`VirtualClock::driver_sleep`]).
    pub fn sleep(&self, d: Duration) {
        match self {
            Clock::Real { .. } => std::thread::sleep(d),
            Clock::Virtual { clock, token } => clock.sleep(*token, d),
        }
    }
}

/// State of one registered participant.
enum ThreadState {
    /// Scheduled: the participant may run until its next blocking call.
    Running,
    /// Runnable (wakeup fired / mail arrived); in the ready set, waiting
    /// for the turn.
    Ready,
    /// Blocked in [`VirtualClock::sleep`] until `due`.
    Asleep { due: u64 },
    /// Blocked in [`VirtualClock::recv_deadline`] until mail or `deadline`.
    Receiving { deadline: u64 },
    /// Finished (or crashed); sends to it are dropped.
    Done,
}

impl ThreadState {
    fn is_blocked(&self) -> bool {
        matches!(self, ThreadState::Asleep { .. } | ThreadState::Receiving { .. })
    }
}

/// One scheduled delivery: fires into `to`'s mailbox at `due`; ties broken
/// by `key` (see module DESIGN note).
struct VcEvent {
    due: u64,
    key: (u32, u32, u64),
    to: usize,
    payload: Arc<[u8]>,
}

impl PartialEq for VcEvent {
    fn eq(&self, other: &Self) -> bool {
        (self.due, self.key) == (other.due, other.key)
    }
}
impl Eq for VcEvent {}
impl PartialOrd for VcEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for VcEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.due, self.key).cmp(&(other.due, other.key))
    }
}

struct VcState {
    /// Logical nanoseconds since the simulation epoch.
    now: u64,
    threads: Vec<ThreadState>,
    mailboxes: Vec<VecDeque<Arc<[u8]>>>,
    events: BinaryHeap<Reverse<VcEvent>>,
    /// Pending sleep / receive-deadline wakeups as `(due, token, gen)`;
    /// an entry is live iff `gen` still matches `wait_gen[token]` and the
    /// token is still blocked (stale entries are discarded lazily).
    timers: BinaryHeap<Reverse<(u64, usize, u64)>>,
    /// Per-token blocking-operation counter; bumped on every block so
    /// superseded timer entries self-invalidate.
    wait_gen: Vec<u64>,
    /// Runnable tokens, granted in ascending token order.
    ready: BTreeSet<usize>,
    /// The token currently holding the turn (at most one).
    current: Option<usize>,
    /// Tokens not yet `Done`.
    live: usize,
    /// Bounded-window mode (parallel executor): when set, the scheduler
    /// never advances `now` to or past this instant — it returns with no
    /// grant instead, leaving everything due at or beyond the horizon
    /// pending for the next window.  Sticky across the internal
    /// reschedules that [`VirtualClock::detach`] and the blocking calls
    /// perform, so a mid-window detach cannot leak past the horizon.
    horizon: Option<u64>,
}

impl VcState {
    /// Register a wakeup for `token` at `due` (the token must already be in
    /// a blocked state).
    fn arm_timer(&mut self, token: usize, due: u64) {
        self.wait_gen[token] += 1;
        let gen = self.wait_gen[token];
        self.timers.push(Reverse((due, token, gen)));
    }

    /// Move a blocked token onto the ready set.
    fn make_ready(&mut self, token: usize) {
        self.threads[token] = ThreadState::Ready;
        self.ready.insert(token);
    }

    /// Release the turn if `token` holds it.
    fn yield_turn(&mut self, token: usize) {
        if self.current == Some(token) {
            self.current = None;
        }
    }
}

/// Outcome of one non-parking receive attempt
/// ([`VirtualClock::driver_recv`] / [`VirtualClock::driver_recv_resume`]).
pub enum DriverRecv {
    /// A payload was already deliverable; the token keeps its turn.
    Delivered(Arc<[u8]>),
    /// The deadline has passed with nothing deliverable; the token keeps
    /// its turn.
    TimedOut,
    /// Nothing deliverable yet: the token is parked until mail arrives or
    /// `deadline` (an absolute instant — hand it back to
    /// [`VirtualClock::driver_recv_resume`] on wakeup).
    Parked { deadline: SimTime },
}

/// The shared discrete-event scheduler (see module docs).
///
/// Deliveries posted with a `(from, to, seq)` key arrive at exactly their
/// due instant of logical time, ties broken by key — never by OS timing:
///
/// ```
/// use std::sync::Arc;
/// use std::time::Duration;
/// use dfl::util::time::VirtualClock;
///
/// let clock = VirtualClock::new(2);
/// std::thread::scope(|s| {
///     let c = Arc::clone(&clock);
///     s.spawn(move || {
///         c.attach(0);
///         c.post(1, Duration::from_millis(5), (0, 1, 1), vec![42].into());
///         c.detach(0);
///     });
///     let c = Arc::clone(&clock);
///     s.spawn(move || {
///         c.attach(1);
///         let got = c.recv_deadline(1, Duration::from_secs(1));
///         assert_eq!(got.as_deref(), Some(&[42u8][..]));
///         assert_eq!(c.now(), Duration::from_millis(5)); // exact logical latency
///         c.detach(1);
///     });
/// });
/// ```
pub struct VirtualClock {
    state: Mutex<VcState>,
    /// One condvar per token, paired with `state` (thread-backed mode
    /// only; the event-driven executor never parks).
    cvs: Vec<Condvar>,
}

fn to_nanos(d: Duration) -> u64 {
    d.as_nanos().min(u64::MAX as u128) as u64
}

impl VirtualClock {
    /// Create a clock for `n` participants (tokens `0..n`).  All start
    /// runnable at t = 0; the scheduler grants token 0 the first turn, so
    /// threads may be spawned in any order and simply gate on [`attach`]
    /// (an event-driven executor instead pumps [`driver_next`]).
    ///
    /// [`attach`]: VirtualClock::attach
    /// [`driver_next`]: VirtualClock::driver_next
    pub fn new(n: usize) -> Arc<VirtualClock> {
        let mut state = VcState {
            now: 0,
            threads: (0..n).map(|_| ThreadState::Asleep { due: 0 }).collect(),
            mailboxes: (0..n).map(|_| VecDeque::new()).collect(),
            events: BinaryHeap::new(),
            timers: BinaryHeap::new(),
            wait_gen: vec![0; n],
            ready: BTreeSet::new(),
            current: None,
            live: n,
            horizon: None,
        };
        for t in 0..n {
            state.arm_timer(t, 0);
        }
        let cvs: Vec<Condvar> = (0..n).map(|_| Condvar::new()).collect();
        Self::schedule(&mut state, &cvs);
        Arc::new(VirtualClock { state: Mutex::new(state), cvs })
    }

    /// A shard-local clock over the full token space `0..n` in which only
    /// `members` are live participants (the parallel executor's per-shard
    /// clock, DESIGN.md §12).  Non-members are `Done` from birth — never
    /// armed, never granted a turn, and mail addressed to them here is
    /// swallowed (the hub routes every delivery to its owner shard's
    /// clock, so that never happens in practice).  Keeping the full token
    /// space means global client ids index mailboxes and thread states
    /// directly on every shard.
    ///
    /// Unlike [`VirtualClock::new`], no turn is granted eagerly: the first
    /// [`VirtualClock::driver_next_before`] performs the initial bounded
    /// schedule, so time cannot move before the first window's horizon is
    /// known.
    pub fn with_members(n: usize, members: &[usize]) -> Arc<VirtualClock> {
        let mut state = VcState {
            now: 0,
            threads: (0..n).map(|_| ThreadState::Done).collect(),
            mailboxes: (0..n).map(|_| VecDeque::new()).collect(),
            events: BinaryHeap::new(),
            timers: BinaryHeap::new(),
            wait_gen: vec![0; n],
            ready: BTreeSet::new(),
            current: None,
            live: 0,
            horizon: None,
        };
        for &t in members {
            debug_assert!(
                matches!(state.threads[t], ThreadState::Done),
                "duplicate shard member {t}"
            );
            state.threads[t] = ThreadState::Asleep { due: 0 };
            state.live += 1;
            state.arm_timer(t, 0);
        }
        let cvs: Vec<Condvar> = (0..n).map(|_| Condvar::new()).collect();
        Arc::new(VirtualClock { state: Mutex::new(state), cvs })
    }

    /// Current logical time.  Deterministic when called by the running
    /// participant (time cannot advance while any participant runs).
    pub fn now(&self) -> SimTime {
        Duration::from_nanos(self.state.lock().unwrap().now)
    }

    /// Gate until this token is scheduled.  Must be the first clock call a
    /// participant thread makes (thread-backed mode only).
    pub fn attach(&self, token: usize) {
        let guard = self.state.lock().unwrap();
        drop(self.wait_for_turn(guard, token));
    }

    /// Unregister a finished participant and hand the turn onward.  Safe to
    /// call from a drop guard on panic; idempotent.
    pub fn detach(&self, token: usize) {
        let mut s = self.state.lock().unwrap();
        if matches!(s.threads[token], ThreadState::Done) {
            return;
        }
        if matches!(s.threads[token], ThreadState::Ready) {
            s.ready.remove(&token);
        }
        s.threads[token] = ThreadState::Done;
        s.wait_gen[token] += 1; // invalidate any pending wakeup
        s.mailboxes[token].clear();
        s.live -= 1;
        s.yield_turn(token);
        if s.current.is_none() && s.live > 0 {
            Self::schedule(&mut s, &self.cvs);
        }
    }

    /// Block this token for `d` of logical time (thread-backed mode; the
    /// event-driven equivalent is [`VirtualClock::driver_sleep`]).
    pub fn sleep(&self, token: usize, d: Duration) {
        let mut s = self.state.lock().unwrap();
        let due = s.now.saturating_add(to_nanos(d));
        s.threads[token] = ThreadState::Asleep { due };
        s.arm_timer(token, due);
        s.yield_turn(token);
        if s.current.is_none() {
            Self::schedule(&mut s, &self.cvs);
        }
        drop(self.wait_for_turn(s, token));
    }

    /// Schedule `payload` for delivery into `to`'s mailbox after `delay`.
    /// `key` must be unique and reproducible (e.g. `(from, to, link seq)`);
    /// it breaks ties between deliveries due at the same instant.  Mail to
    /// a `Done` token is swallowed immediately (crash model).
    pub fn post(&self, to: usize, delay: Duration, key: (u32, u32, u64), payload: Arc<[u8]>) {
        let mut s = self.state.lock().unwrap();
        if matches!(s.threads[to], ThreadState::Done) {
            return;
        }
        let due = s.now.saturating_add(to_nanos(delay));
        s.events.push(Reverse(VcEvent { due, key, to, payload }));
    }

    /// [`post`](VirtualClock::post) at an *absolute* instant — the
    /// cross-shard delivery path (DESIGN.md §12): a sender on another
    /// shard's clock computes `due = its own now + link delay` and lands
    /// the event here, on the recipient's clock.  The conservative-window
    /// protocol guarantees `due ≥` this shard's current horizon, so the
    /// event can never be in this clock's past (debug-asserted); the
    /// `(due, key)` total order of the event heap then makes the pop
    /// sequence independent of cross-thread push timing.  Mail to a
    /// `Done` token is swallowed, exactly like `post`.
    pub fn post_at(&self, to: usize, due: SimTime, key: (u32, u32, u64), payload: Arc<[u8]>) {
        let mut s = self.state.lock().unwrap();
        if matches!(s.threads[to], ThreadState::Done) {
            return;
        }
        let due = to_nanos(due);
        debug_assert!(due >= s.now, "post_at into the destination shard's past");
        s.events.push(Reverse(VcEvent { due, key, to, payload }));
    }

    /// Pop the next delivered payload, or block until one arrives or
    /// logical `timeout` elapses (then `None`).  Thread-backed mode; the
    /// event-driven equivalent is [`VirtualClock::driver_recv`].
    pub fn recv_deadline(&self, token: usize, timeout: Duration) -> Option<Arc<[u8]>> {
        let mut s = self.state.lock().unwrap();
        let deadline = s.now.saturating_add(to_nanos(timeout));
        loop {
            Self::fire_due(&mut s);
            if let Some(p) = s.mailboxes[token].pop_front() {
                return Some(p);
            }
            if s.now >= deadline {
                return None;
            }
            s.threads[token] = ThreadState::Receiving { deadline };
            s.arm_timer(token, deadline);
            s.yield_turn(token);
            if s.current.is_none() {
                Self::schedule(&mut s, &self.cvs);
            }
            s = self.wait_for_turn(s, token);
        }
    }

    /// Non-blocking receive of anything already due.
    pub fn try_recv(&self, token: usize) -> Option<Arc<[u8]>> {
        let mut s = self.state.lock().unwrap();
        Self::fire_due(&mut s);
        s.mailboxes[token].pop_front()
    }

    // --- event-driven executor API (no per-client threads) -----------------
    //
    // A single driver thread owns every participant as a state machine and
    // pumps these instead of parking on condvars.  The state transitions
    // are the same ones the blocking calls make, so a driver-pumped run is
    // byte-identical to a thread-backed run of the same seed.

    /// Hand out the next turn: the lowest ready token, advancing logical
    /// time when none is ready yet.  Returns `None` when every participant
    /// is `Done` (or nothing can ever become ready — a protocol deadlock,
    /// which finite deadlines rule out).  The returned token holds the turn
    /// until it blocks via [`driver_sleep`](VirtualClock::driver_sleep) /
    /// [`driver_recv`](VirtualClock::driver_recv) or detaches.
    pub fn driver_next(&self) -> Option<usize> {
        let mut s = self.state.lock().unwrap();
        s.horizon = None;
        if s.current.is_none() {
            Self::schedule(&mut s, &self.cvs);
        }
        s.current
    }

    /// Bounded [`driver_next`](VirtualClock::driver_next) — the parallel
    /// executor's per-window pump (DESIGN.md §12).  Grants turns and fires
    /// events exactly like `driver_next`, but never advances `now` to or
    /// past `horizon`: once everything strictly before the horizon has
    /// drained, returns `None` with all remaining work (dues ≥ horizon)
    /// left pending for the next window.  The horizon is sticky until the
    /// next bounded (or unbounded) call, so the internal reschedule a
    /// mid-window [`detach`](VirtualClock::detach) performs cannot leak
    /// past it.
    ///
    /// `None` from this call therefore means "window drained", not "run
    /// over" — the coordinator distinguishes the two with
    /// [`pending_lower_bound`](VirtualClock::pending_lower_bound).
    pub fn driver_next_before(&self, horizon: SimTime) -> Option<usize> {
        let mut s = self.state.lock().unwrap();
        s.horizon = Some(to_nanos(horizon));
        if s.current.is_none() {
            Self::schedule(&mut s, &self.cvs);
        }
        s.current
    }

    /// Earliest instant at which this clock has any pending work — the
    /// minimum over live timers and undelivered events — or `None` when
    /// nothing is pending (every member detached, or the remaining members
    /// are stalled with no wakeup, the error case the executor surfaces).
    /// This is each shard's contribution to the coordinator's lower-bound
    /// timestamp exchange: the next window's horizon is
    /// `min over shards + lookahead` (DESIGN.md §12).
    ///
    /// Only meaningful at a window barrier (no token ready or running —
    /// defensively, `now` is returned if one is).
    pub fn pending_lower_bound(&self) -> Option<SimTime> {
        let mut s = self.state.lock().unwrap();
        if s.live == 0 {
            return None;
        }
        if s.current.is_some() || !s.ready.is_empty() {
            return Some(Duration::from_nanos(s.now));
        }
        let timer = Self::next_timer_due(&mut s);
        let event = s.events.peek().map(|Reverse(e)| e.due);
        match (timer, event) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, None) => a,
            (None, b) => b,
        }
        .map(Duration::from_nanos)
    }

    /// Non-parking [`sleep`](VirtualClock::sleep): block `token` for `d` of
    /// logical time and release the turn.  The token comes back from
    /// [`driver_next`](VirtualClock::driver_next) once `d` has elapsed.
    pub fn driver_sleep(&self, token: usize, d: Duration) {
        let mut s = self.state.lock().unwrap();
        debug_assert_eq!(s.current, Some(token), "driver_sleep off-turn");
        let due = s.now.saturating_add(to_nanos(d));
        s.threads[token] = ThreadState::Asleep { due };
        s.arm_timer(token, due);
        s.yield_turn(token);
    }

    /// Non-parking [`recv_deadline`](VirtualClock::recv_deadline): one
    /// attempt with a deadline of `timeout` from now.  On
    /// [`DriverRecv::Parked`] the turn is released; when
    /// [`driver_next`](VirtualClock::driver_next) returns this token again,
    /// finish the receive with [`driver_recv_resume`] and the parked
    /// deadline.
    ///
    /// [`driver_recv_resume`]: VirtualClock::driver_recv_resume
    pub fn driver_recv(&self, token: usize, timeout: Duration) -> DriverRecv {
        let mut s = self.state.lock().unwrap();
        debug_assert_eq!(s.current, Some(token), "driver_recv off-turn");
        let deadline = s.now.saturating_add(to_nanos(timeout));
        Self::recv_attempt(&mut s, token, deadline)
    }

    /// Resume a parked receive: deliver mail that woke the token, or report
    /// the timeout.  `deadline` is the absolute instant returned by the
    /// [`DriverRecv::Parked`] that parked it.
    pub fn driver_recv_resume(&self, token: usize, deadline: SimTime) -> DriverRecv {
        let mut s = self.state.lock().unwrap();
        debug_assert_eq!(s.current, Some(token), "driver_recv_resume off-turn");
        Self::recv_attempt(&mut s, token, to_nanos(deadline))
    }

    /// Shared body of the two driver receives: mirror one iteration of
    /// [`recv_deadline`](VirtualClock::recv_deadline)'s loop.
    fn recv_attempt(s: &mut VcState, token: usize, deadline: u64) -> DriverRecv {
        Self::fire_due(s);
        if let Some(p) = s.mailboxes[token].pop_front() {
            return DriverRecv::Delivered(p);
        }
        if s.now >= deadline {
            return DriverRecv::TimedOut;
        }
        s.threads[token] = ThreadState::Receiving { deadline };
        s.arm_timer(token, deadline);
        s.yield_turn(token);
        DriverRecv::Parked { deadline: Duration::from_nanos(deadline) }
    }

    // --- scheduler core ------------------------------------------------------

    /// Park until the scheduler marks `token` running again.
    fn wait_for_turn<'a>(
        &'a self,
        mut guard: MutexGuard<'a, VcState>,
        token: usize,
    ) -> MutexGuard<'a, VcState> {
        while !matches!(guard.threads[token], ThreadState::Running) {
            guard = self.cvs[token].wait(guard).unwrap();
        }
        guard
    }

    /// Deliver every event due at or before `now`, in `(due, key)` order.
    /// Mailboxes of `Done` tokens swallow their traffic (the crash model);
    /// a `Receiving` recipient moves straight onto the ready set.
    fn fire_due(s: &mut VcState) {
        while let Some(Reverse(ev)) = s.events.peek() {
            if ev.due > s.now {
                break;
            }
            let Reverse(ev) = s.events.pop().unwrap();
            let to = ev.to;
            if matches!(s.threads[to], ThreadState::Done) {
                continue; // crash model: swallowed
            }
            s.mailboxes[to].push_back(ev.payload);
            if matches!(s.threads[to], ThreadState::Receiving { .. }) {
                s.make_ready(to);
            }
        }
    }

    /// Wake every timer due at or before `now` whose blocking operation is
    /// still outstanding; stale entries (superseded by an earlier wake) are
    /// dropped.
    fn wake_timers(s: &mut VcState) {
        while let Some(&Reverse((due, token, gen))) = s.timers.peek() {
            if due > s.now {
                break;
            }
            s.timers.pop();
            if gen == s.wait_gen[token] && s.threads[token].is_blocked() {
                s.make_ready(token);
            }
        }
    }

    /// Due instant of the earliest still-live timer, discarding stale
    /// entries on the way.
    fn next_timer_due(s: &mut VcState) -> Option<u64> {
        while let Some(&Reverse((due, token, gen))) = s.timers.peek() {
            if gen == s.wait_gen[token] && s.threads[token].is_blocked() {
                return Some(due);
            }
            s.timers.pop();
        }
        None
    }

    /// Core scheduling step; requires no token to hold the turn.  Fires due
    /// deliveries and timers, grants the lowest ready token, and advances
    /// `now` to the earliest pending instant when nothing is ready yet.
    fn schedule(s: &mut VcState, cvs: &[Condvar]) {
        debug_assert!(s.current.is_none(), "schedule() with a running thread");
        if s.live == 0 {
            return;
        }
        loop {
            Self::fire_due(s);
            Self::wake_timers(s);
            let first = s.ready.iter().next().copied();
            if let Some(t) = first {
                s.ready.remove(&t);
                s.threads[t] = ThreadState::Running;
                s.current = Some(t);
                cvs[t].notify_all();
                return;
            }
            let next_due = match (Self::next_timer_due(s), s.events.peek().map(|Reverse(e)| e.due))
            {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, None) => a,
                (None, b) => b,
            };
            match next_due {
                // Nothing ready: jump to the earliest pending instant —
                // unless a bounded window forbids crossing the horizon
                // (the pending instant then waits for the next window).
                Some(d) if d > s.now => match s.horizon {
                    Some(h) if d >= h => return,
                    _ => s.now = d,
                },
                // No pending work at all — every live participant is racing
                // to detach, or the simulation is over.
                _ => return,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MS: Duration = Duration::from_millis(1);

    fn bytes(v: &[u8]) -> Arc<[u8]> {
        v.to_vec().into()
    }

    #[test]
    fn real_clock_elapses() {
        let c = Clock::real();
        assert!(!c.is_virtual());
        let t0 = c.now();
        std::thread::sleep(Duration::from_millis(2));
        assert!(c.now() > t0);
    }

    #[test]
    fn virtual_sleep_advances_logical_time_instantly() {
        let clock = VirtualClock::new(2);
        let wall = Instant::now();
        let ends: Vec<SimTime> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..2usize)
                .map(|t| {
                    let clock = Arc::clone(&clock);
                    scope.spawn(move || {
                        clock.attach(t);
                        // token 0 sleeps 10 s, token 1 sleeps 20 s — virtual
                        clock.sleep(t, Duration::from_secs(10 * (t as u64 + 1)));
                        let end = clock.now();
                        clock.detach(t);
                        end
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(ends[0], Duration::from_secs(10));
        assert_eq!(ends[1], Duration::from_secs(20));
        assert_eq!(clock.now(), Duration::from_secs(20));
        assert!(wall.elapsed() < Duration::from_secs(2), "virtual sleep slept for real");
    }

    #[test]
    fn same_instant_deliveries_fire_in_key_order() {
        let clock = VirtualClock::new(2);
        std::thread::scope(|scope| {
            let c0 = Arc::clone(&clock);
            scope.spawn(move || {
                c0.attach(0);
                // posted in reverse key order, same due instant
                c0.post(1, 5 * MS, (0, 1, 2), bytes(&[2]));
                c0.post(1, 5 * MS, (0, 1, 1), bytes(&[1]));
                c0.detach(0);
            });
            let c1 = Arc::clone(&clock);
            scope.spawn(move || {
                c1.attach(1);
                let a = c1.recv_deadline(1, Duration::from_secs(1)).unwrap();
                let b = c1.recv_deadline(1, Duration::from_secs(1)).unwrap();
                assert_eq!((&a[..], &b[..]), (&[1u8][..], &[2u8][..]), "ties must break by key");
                assert_eq!(c1.now(), 5 * MS, "delivery at exact due instant");
                c1.detach(1);
            });
        });
    }

    #[test]
    fn recv_deadline_times_out_at_exact_instant() {
        let clock = VirtualClock::new(1);
        std::thread::scope(|scope| {
            let c = Arc::clone(&clock);
            scope.spawn(move || {
                c.attach(0);
                assert!(c.recv_deadline(0, 50 * MS).is_none());
                assert_eq!(c.now(), 50 * MS);
                c.detach(0);
            });
        });
    }

    #[test]
    fn detach_unblocks_waiters_and_drops_mail() {
        let clock = VirtualClock::new(2);
        std::thread::scope(|scope| {
            let c0 = Arc::clone(&clock);
            scope.spawn(move || {
                c0.attach(0);
                c0.post(1, Duration::ZERO, (0, 1, 1), bytes(&[7]));
                c0.detach(0); // token 1 must still be scheduled afterwards
            });
            let c1 = Arc::clone(&clock);
            scope.spawn(move || {
                c1.attach(1);
                c1.sleep(1, 10 * MS);
                // mail sent to a detached token is swallowed silently
                c1.post(0, Duration::ZERO, (1, 0, 1), bytes(&[9]));
                assert_eq!(c1.try_recv(1).as_deref(), Some(&[7u8][..]));
                assert_eq!(c1.try_recv(1), None);
                c1.detach(1);
            });
        });
    }

    #[test]
    fn ping_pong_round_trip_accumulates_latency() {
        let clock = VirtualClock::new(2);
        std::thread::scope(|scope| {
            let c0 = Arc::clone(&clock);
            scope.spawn(move || {
                c0.attach(0);
                c0.post(1, 3 * MS, (0, 1, 1), bytes(&[1]));
                let got = c0.recv_deadline(0, Duration::from_secs(1)).unwrap();
                assert_eq!(&got[..], &[2u8][..]);
                assert_eq!(c0.now(), 7 * MS, "3 ms there + 4 ms back");
                c0.detach(0);
            });
            let c1 = Arc::clone(&clock);
            scope.spawn(move || {
                c1.attach(1);
                let got = c1.recv_deadline(1, Duration::from_secs(1)).unwrap();
                assert_eq!(&got[..], &[1u8][..]);
                c1.post(0, 4 * MS, (1, 0, 1), bytes(&[2]));
                c1.detach(1);
            });
        });
    }

    // --- driver (event-executor) API ---------------------------------------

    /// The full sleep/recv/post lifecycle pumped by a single thread: no
    /// participant threads exist at all.
    #[test]
    fn driver_api_ping_pong_without_threads() {
        let clock = VirtualClock::new(2);
        // token 0: sleep 2 ms, post to 1, recv reply; token 1: recv, reply.
        let mut t0_phase = 0;
        let mut t1_phase = 0;
        let mut parked: [Option<SimTime>; 2] = [None, None];
        let mut done = [false, false];
        while let Some(t) = clock.driver_next() {
            if t == 0 {
                match t0_phase {
                    0 => {
                        clock.driver_sleep(0, 2 * MS);
                        t0_phase = 1;
                    }
                    1 => {
                        clock.post(1, 3 * MS, (0, 1, 1), bytes(&[10]));
                        match clock.driver_recv(0, Duration::from_secs(1)) {
                            DriverRecv::Parked { deadline } => parked[0] = Some(deadline),
                            _ => panic!("reply cannot be ready yet"),
                        }
                        t0_phase = 2;
                    }
                    _ => {
                        let d = parked[0].take().unwrap();
                        match clock.driver_recv_resume(0, d) {
                            DriverRecv::Delivered(p) => assert_eq!(&p[..], &[20u8][..]),
                            _ => panic!("expected the reply"),
                        }
                        // 2 ms sleep + 3 ms there + 4 ms back
                        assert_eq!(clock.now(), 9 * MS);
                        done[0] = true;
                        clock.detach(0);
                    }
                }
            } else {
                match t1_phase {
                    0 => {
                        match clock.driver_recv(1, Duration::from_secs(1)) {
                            DriverRecv::Parked { deadline } => parked[1] = Some(deadline),
                            _ => panic!("nothing sent yet"),
                        }
                        t1_phase = 1;
                    }
                    _ => {
                        let d = parked[1].take().unwrap();
                        match clock.driver_recv_resume(1, d) {
                            DriverRecv::Delivered(p) => assert_eq!(&p[..], &[10u8][..]),
                            _ => panic!("expected the ping"),
                        }
                        clock.post(0, 4 * MS, (1, 0, 1), bytes(&[20]));
                        done[1] = true;
                        clock.detach(1);
                    }
                }
            }
        }
        assert_eq!(done, [true, true]);
    }

    #[test]
    fn driver_recv_times_out_at_exact_deadline() {
        let clock = VirtualClock::new(1);
        let t = clock.driver_next().unwrap();
        assert_eq!(t, 0);
        let deadline = match clock.driver_recv(0, 25 * MS) {
            DriverRecv::Parked { deadline } => deadline,
            _ => panic!("mailbox must be empty"),
        };
        assert_eq!(clock.driver_next(), Some(0), "deadline must wake the token");
        assert_eq!(clock.now(), 25 * MS);
        match clock.driver_recv_resume(0, deadline) {
            DriverRecv::TimedOut => {}
            _ => panic!("expected timeout"),
        }
        clock.detach(0);
        assert_eq!(clock.driver_next(), None);
    }

    /// Same-instant wakeups must be granted in token order (the ready set's
    /// invariant) and a receive whose mail arrives before its deadline must
    /// leave no live timer behind (the gen-tag invariant).
    #[test]
    fn ready_queue_grants_lowest_token_and_discards_stale_timers() {
        let clock = VirtualClock::new(3);
        // Park everyone: 2 and 1 sleep to the same instant, 0 receives with
        // a far deadline but gets mail at that same instant.
        assert_eq!(clock.driver_next(), Some(0));
        let d0 = match clock.driver_recv(0, Duration::from_secs(60)) {
            DriverRecv::Parked { deadline } => deadline,
            _ => panic!("no mail yet"),
        };
        assert_eq!(clock.driver_next(), Some(1));
        clock.post(0, 5 * MS, (1, 0, 1), bytes(&[1]));
        clock.driver_sleep(1, 5 * MS);
        assert_eq!(clock.driver_next(), Some(2));
        clock.driver_sleep(2, 5 * MS);
        // All three wake at t = 5 ms: token order, mail before deadline.
        assert_eq!(clock.driver_next(), Some(0), "mail readies the receiver");
        match clock.driver_recv_resume(0, d0) {
            DriverRecv::Delivered(p) => assert_eq!(&p[..], &[1u8][..]),
            _ => panic!("mail was due"),
        }
        clock.detach(0);
        assert_eq!(clock.driver_next(), Some(1));
        clock.detach(1);
        assert_eq!(clock.driver_next(), Some(2));
        assert_eq!(clock.now(), 5 * MS);
        clock.detach(2);
        assert_eq!(clock.driver_next(), None);
        // The receiver's 60 s deadline must not hold the clock hostage.
        assert_eq!(clock.now(), 5 * MS, "stale deadline advanced the clock");
    }

    /// Satellite regression (the invariant the parallel merge relies on):
    /// timers tied on `due` must drain in ascending *token* order no
    /// matter the order they were armed in, and a same-instant delivery
    /// must ready its receiver into the same token-ordered grant sequence.
    /// Pins the `(due, token, gen)` tuple layout of the timer heap — see
    /// the module DESIGN notes; reordering those fields breaks this test.
    #[test]
    fn equal_deadline_timers_drain_in_token_order() {
        let clock = VirtualClock::new(4);
        // Park everyone with a common due of 7 ms, arming token 0's timer
        // *last* (it first sleeps 1 ms, wakes alone, then re-arms to 7 ms)
        // so arm order is 1, 2, 3, 0 — drain order must still be 0..4.
        assert_eq!(clock.driver_next(), Some(0));
        clock.driver_sleep(0, MS);
        assert_eq!(clock.driver_next(), Some(1));
        clock.driver_sleep(1, 7 * MS);
        assert_eq!(clock.driver_next(), Some(2));
        clock.driver_sleep(2, 7 * MS);
        assert_eq!(clock.driver_next(), Some(3));
        let d3 = match clock.driver_recv(3, Duration::from_secs(60)) {
            DriverRecv::Parked { deadline } => deadline,
            _ => panic!("no mail yet"),
        };
        assert_eq!(clock.driver_next(), Some(0));
        assert_eq!(clock.now(), MS);
        // a delivery due at the same 7 ms instant readies token 3 (whose
        // own deadline is an hour out) into the same tie-broken sequence
        clock.post(3, 6 * MS, (0, 3, 1), bytes(&[9]));
        clock.driver_sleep(0, 6 * MS); // due 7 ms, armed after 1 and 2
        for expect in 0..4usize {
            assert_eq!(
                clock.driver_next(),
                Some(expect),
                "equal-deadline drain must be token-ordered"
            );
            assert_eq!(clock.now(), 7 * MS);
            if expect == 3 {
                match clock.driver_recv_resume(3, d3) {
                    DriverRecv::Delivered(p) => assert_eq!(&p[..], &[9u8][..]),
                    _ => panic!("the same-instant delivery was due"),
                }
            }
            clock.detach(expect);
        }
        assert_eq!(clock.driver_next(), None);
        // token 3's superseded 60 s deadline must not have advanced time
        assert_eq!(clock.now(), 7 * MS);
    }

    /// The parallel executor's clock shape: a shard clock over the full
    /// token space with only its members live, pumped through the bounded
    /// driver API — the horizon is never crossed, cross-shard mail lands
    /// at absolute instants, and the lower bound reports pending work.
    #[test]
    fn bounded_driver_never_crosses_the_horizon() {
        let clock = VirtualClock::with_members(4, &[1, 3]);
        // window 1: horizon 5 ms — members drain their t = 0 wakeups
        assert_eq!(clock.driver_next_before(5 * MS), Some(1));
        clock.driver_sleep(1, 2 * MS);
        assert_eq!(clock.driver_next_before(5 * MS), Some(3));
        clock.driver_sleep(3, 10 * MS);
        assert_eq!(clock.driver_next_before(5 * MS), Some(1));
        assert_eq!(clock.now(), 2 * MS);
        clock.driver_sleep(1, 6 * MS); // due 8 ms ≥ horizon
        assert_eq!(clock.driver_next_before(5 * MS), None, "window drained");
        assert_eq!(clock.now(), 2 * MS, "horizon must cap time advance");
        assert_eq!(clock.pending_lower_bound(), Some(8 * MS));
        // a cross-shard delivery lands at an absolute instant ≥ horizon
        clock.post_at(3, 9 * MS, (0, 3, 1), bytes(&[5]));
        assert_eq!(clock.pending_lower_bound(), Some(8 * MS));
        // window 2: horizon 9 ms — token 1 wakes at 8 ms and detaches;
        // the 9 ms event sits exactly on the horizon and must wait
        assert_eq!(clock.driver_next_before(9 * MS), Some(1));
        assert_eq!(clock.now(), 8 * MS);
        clock.detach(1); // the sticky horizon caps the internal reschedule
        assert_eq!(clock.driver_next_before(9 * MS), None);
        assert_eq!(clock.now(), 8 * MS);
        assert_eq!(clock.pending_lower_bound(), Some(9 * MS));
        // window 3: a wide horizon delivers the mail and drains token 3
        assert_eq!(clock.driver_next_before(20 * MS), Some(3));
        assert_eq!(clock.now(), 10 * MS);
        assert_eq!(clock.try_recv(3).as_deref(), Some(&[5u8][..]));
        clock.detach(3);
        assert_eq!(clock.driver_next_before(20 * MS), None);
        assert_eq!(clock.pending_lower_bound(), None, "all members done");
    }

    #[test]
    fn non_members_are_done_from_birth() {
        let clock = VirtualClock::with_members(3, &[2]);
        // posts to non-members are swallowed at post time
        clock.post_at(0, MS, (9, 0, 1), bytes(&[1]));
        assert_eq!(clock.driver_next_before(Duration::from_secs(1)), Some(2));
        assert_eq!(clock.try_recv(2), None);
        clock.detach(2);
        assert_eq!(clock.driver_next_before(Duration::from_secs(1)), None);
        assert_eq!(clock.now(), Duration::ZERO);
    }

    #[test]
    fn post_to_done_token_is_swallowed_at_post_time() {
        let clock = VirtualClock::new(2);
        assert_eq!(clock.driver_next(), Some(0));
        clock.detach(0);
        assert_eq!(clock.driver_next(), Some(1));
        clock.post(0, Duration::ZERO, (1, 0, 1), bytes(&[9]));
        // Nothing pending: detaching 1 ends the run with time unmoved.
        clock.detach(1);
        assert_eq!(clock.driver_next(), None);
        assert_eq!(clock.now(), Duration::ZERO);
    }
}
