//! Micro/bench harness (the `criterion` substrate).
//!
//! Warmup + timed iterations with mean / p50 / p95 / p99 reporting, plus a
//! table printer the per-figure experiment benches use to emit paper-shaped
//! rows. Benches are built with `harness = false` and call these directly.

// dfl-lint: allow-file(wall-clock) — measuring wall time is this module's entire job (bench harness); it never runs inside a deployment
use std::time::{Duration, Instant};

/// Summary statistics over per-iteration wallclock samples.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    fn from_samples(name: &str, mut samples: Vec<Duration>) -> Stats {
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let pct = |p: f64| samples[((n as f64 - 1.0) * p) as usize];
        Stats {
            name: name.to_string(),
            iters: n,
            mean: total / n as u32,
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            min: samples[0],
            max: samples[n - 1],
        }
    }

    pub fn print(&self) {
        println!(
            "{:<38} iters={:<5} mean={:>10.3?} p50={:>10.3?} p95={:>10.3?} p99={:>10.3?}",
            self.name, self.iters, self.mean, self.p50, self.p95, self.p99
        );
    }
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Stats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    let stats = Stats::from_samples(name, samples);
    stats.print();
    stats
}

/// Time `f` until roughly `budget` wallclock is spent (at least 3 iters).
pub fn bench_for<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> Stats {
    f(); // warmup
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 3 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() > 10_000 {
            break;
        }
    }
    let stats = Stats::from_samples(name, samples);
    stats.print();
    stats
}

/// Keep a value from being optimized away (stable `black_box` substitute).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Fixed-width table printer for paper-shaped experiment output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {title} ==");
        let line = |cells: &[String]| {
            let cols: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            println!("| {} |", cols.join(" | "));
        };
        line(&self.headers);
        println!(
            "|{}|",
            widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            line(row);
        }
    }

    /// Render as a markdown table (for EXPERIMENTS.md capture).
    pub fn markdown(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        s.push_str(&format!(
            "|{}|\n",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            s.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_stats() {
        let s = bench("noop", 2, 50, || {
            black_box(1 + 1);
        });
        assert_eq!(s.iters, 50);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["Clients", "Acc"]);
        t.row(&["2".into(), "59.78".into()]);
        t.row(&["10".into(), "67.47".into()]);
        let md = t.markdown();
        assert!(md.contains("| Clients | Acc |"));
        assert!(md.contains("| 10 | 67.47 |"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only one".into()]);
    }
}
