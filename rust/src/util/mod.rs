//! Substrate utilities the offline environment required us to own:
//! deterministic RNG (no `rand`), binary codec (no `serde`), CLI parsing
//! (no `clap`), property-test runner (no `proptest`), bench harness
//! (no `criterion`).

pub mod benchkit;
pub mod cli;
pub mod codec;
pub mod quickcheck;
pub mod rng;

pub use rng::Rng;
