//! Substrate utilities the offline environment required us to own:
//! deterministic RNG (no `rand`), binary codec (no `serde`), CLI parsing
//! (no `clap`), property-test runner (no `proptest`), bench harness
//! (no `criterion`), and the real/virtual clock abstraction (no `tokio`
//! test-time machinery).

pub mod benchkit;
pub mod cli;
pub mod codec;
pub mod pool;
pub mod quickcheck;
pub mod rng;
pub mod time;

pub use rng::Rng;
pub use time::{Clock, SimTime, VirtualClock};
