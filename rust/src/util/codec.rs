//! Binary wire codec (the `serde`/`bincode` substrate).
//!
//! Little-endian, length-prefixed frames with a magic tag, protocol version
//! and CRC-32 trailer.  Used verbatim by both transports: over TCP the frame
//! is the stream record; in-process it round-trips through the same bytes so
//! tests exercise the real encoding.
//!
//! Frame layout:
//! ```text
//! [u32 magic][u8 version][u32 payload_len][payload bytes][u32 crc32(payload)]
//! ```

use anyhow::{bail, Result};

pub const MAGIC: u32 = 0xD1F7_FEED;
pub const VERSION: u8 = 1;

// ---------------------------------------------------------------------------
// CRC-32 (IEEE 802.3, table-driven)
// ---------------------------------------------------------------------------

fn crc_table() -> &'static [u32; 256] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// CRC-32 of a byte slice (IEEE polynomial).
pub fn crc32(data: &[u8]) -> u32 {
    let table = crc_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------------
// Primitive writers/readers
// ---------------------------------------------------------------------------

/// Append-only byte sink with typed little-endian writers.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        Writer { buf: Vec::with_capacity(cap) }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed f32 slice; bulk-copied as raw LE bytes.
    pub fn f32_slice(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        self.buf.reserve(v.len() * 4);
        // f32::to_le_bytes per element optimizes poorly; on LE targets this
        // is a straight memcpy.
        #[cfg(target_endian = "little")]
        {
            let bytes =
                unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) };
            self.buf.extend_from_slice(bytes);
        }
        #[cfg(target_endian = "big")]
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }
}

/// Cursor writing typed little-endian values into a preallocated buffer —
/// the zero-realloc twin of [`Writer`], used where the exact encoded size
/// is known up front (e.g. `Msg::encode_arc` writing straight into a
/// single `Arc<[u8]>` allocation).  Writing past the end panics: callers
/// size the buffer from the same layout the encoder walks, so an overrun
/// is an encoder bug, not an input condition.
pub struct SliceWriter<'a> {
    buf: &'a mut [u8],
    pos: usize,
}

impl<'a> SliceWriter<'a> {
    pub fn new(buf: &'a mut [u8]) -> Self {
        SliceWriter { buf, pos: 0 }
    }

    fn put(&mut self, bytes: &[u8]) {
        self.buf[self.pos..self.pos + bytes.len()].copy_from_slice(bytes);
        self.pos += bytes.len();
    }

    pub fn u8(&mut self, v: u8) {
        self.put(&[v]);
    }

    pub fn bool(&mut self, v: bool) {
        self.put(&[v as u8]);
    }

    pub fn u16(&mut self, v: u16) {
        self.put(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.put(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.put(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.put(&v.to_le_bytes());
    }

    /// Length-prefixed f32 slice; bulk-copied as raw LE bytes (same layout
    /// as [`Writer::f32_slice`]).
    pub fn f32_slice(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        #[cfg(target_endian = "little")]
        {
            let bytes =
                unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) };
            self.put(bytes);
        }
        #[cfg(target_endian = "big")]
        for &x in v {
            self.put(&x.to_le_bytes());
        }
    }

    /// Bytes written so far (the encoder asserts this against the layout's
    /// computed size when it finishes).
    pub fn written(&self) -> usize {
        self.pos
    }
}

/// Cursor over a received payload with typed little-endian readers.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "codec underrun: need {n} bytes at {} of {}",
                self.pos,
                self.buf.len()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool> {
        Ok(self.u8()? != 0)
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Validated length-prefixed f32 block: the element count and its raw
    /// little-endian bytes.  The length prefix is checked against the bytes
    /// actually present BEFORE anything sizes an allocation: a corrupt or
    /// adversarial frame can claim a multi-GiB vector in 4 bytes, and
    /// `n * 4` itself can wrap on 32-bit targets (turning a huge claim into
    /// a tiny take that then mis-frames everything after it).
    fn f32_block(&mut self) -> Result<(usize, &'a [u8])> {
        let n = self.u32()? as usize;
        let need = n
            .checked_mul(4)
            .ok_or_else(|| anyhow::anyhow!("codec: f32 vec length {n} overflows"))?;
        if need > self.remaining() {
            bail!(
                "codec: f32 vec claims {n} elements ({need} bytes) but only {} bytes remain",
                self.remaining()
            );
        }
        let bytes = self.take(need)?;
        Ok((n, bytes))
    }

    /// Overwrite an empty `out` with `n` f32s decoded from `bytes`.
    fn fill_f32(out: &mut Vec<f32>, n: usize, bytes: &[u8]) {
        debug_assert!(out.is_empty());
        out.resize(n, 0.0);
        #[cfg(target_endian = "little")]
        unsafe {
            std::ptr::copy_nonoverlapping(bytes.as_ptr(), out.as_mut_ptr() as *mut u8, n * 4);
        }
        #[cfg(target_endian = "big")]
        for (i, c) in bytes.chunks_exact(4).enumerate() {
            out[i] = f32::from_le_bytes(c.try_into().unwrap());
        }
    }

    pub fn f32_vec(&mut self) -> Result<Vec<f32>> {
        let (n, bytes) = self.f32_block()?;
        let mut out = Vec::with_capacity(n);
        Self::fill_f32(&mut out, n, bytes);
        Ok(out)
    }

    /// [`Reader::f32_vec`] decoded into a buffer from the calling thread's
    /// pool (`util::pool`): same validation, same bytes, same values —
    /// every element is overwritten — but the steady-state decode path
    /// stops touching the global allocator.  Ownership of recycling passes
    /// to the caller.
    pub fn f32_vec_pooled(&mut self) -> Result<Vec<f32>> {
        let (n, bytes) = self.f32_block()?;
        let mut out = crate::util::pool::take_f32(n);
        Self::fill_f32(&mut out, n, bytes);
        Ok(out)
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8(self.take(n)?.to_vec())?)
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

// ---------------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------------

/// Checked conversion of a payload length to the frame header's `u32`.
/// A bare `as u32` cast would silently truncate a > 4 GiB payload, and
/// the receiver would then mis-frame every byte after the lie.
fn frame_len(payload_len: usize) -> Result<u32> {
    u32::try_from(payload_len)
        .map_err(|_| anyhow::anyhow!("payload of {payload_len} bytes exceeds the u32 frame limit"))
}

/// Wrap a payload in the `[magic][version][len][payload][crc]` frame.
/// Errors if the payload exceeds the header's `u32` length field.
pub fn frame(payload: &[u8]) -> Result<Vec<u8>> {
    let len = frame_len(payload.len())?;
    let mut out = Vec::with_capacity(payload.len() + 13);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(VERSION);
    out.extend_from_slice(&len.to_le_bytes());
    out.extend_from_slice(payload);
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    Ok(out)
}

/// Parse one frame from `buf`. Returns `(payload, consumed)` or `None` if
/// the buffer does not yet hold a complete frame. Corrupt frames error.
pub fn deframe(buf: &[u8]) -> Result<Option<(&[u8], usize)>> {
    if buf.len() < 13 {
        return Ok(None);
    }
    let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    if magic != MAGIC {
        bail!("bad frame magic {magic:#x}");
    }
    let version = buf[4];
    if version != VERSION {
        bail!("unsupported frame version {version}");
    }
    let len = u32::from_le_bytes(buf[5..9].try_into().unwrap()) as usize;
    let total = 13 + len;
    if buf.len() < total {
        return Ok(None);
    }
    let payload = &buf[9..9 + len];
    let crc = u32::from_le_bytes(buf[9 + len..total].try_into().unwrap());
    if crc != crc32(payload) {
        bail!("frame crc mismatch");
    }
    Ok(Some((payload, total)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vector() {
        // Standard check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn primitives_roundtrip() {
        let mut w = Writer::new();
        w.u8(7);
        w.bool(true);
        w.u32(0xDEADBEEF);
        w.u64(u64::MAX - 3);
        w.f32(-1.25);
        w.f32_slice(&[1.0, 2.5, -3.75]);
        w.str("hello Δ");
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert!(r.bool().unwrap());
        assert_eq!(r.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f32().unwrap(), -1.25);
        assert_eq!(r.f32_vec().unwrap(), vec![1.0, 2.5, -3.75]);
        assert_eq!(r.str().unwrap(), "hello Δ");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn frame_roundtrip() {
        let payload = b"the payload";
        let framed = frame(payload).unwrap();
        let (got, used) = deframe(&framed).unwrap().unwrap();
        assert_eq!(got, payload);
        assert_eq!(used, framed.len());
    }

    #[test]
    fn frame_len_boundary() {
        // Exercise the length check without allocating 4 GiB: the header
        // cast is what the bug was, so test the cast in isolation.
        assert_eq!(frame_len(0).unwrap(), 0);
        assert_eq!(frame_len(u32::MAX as usize).unwrap(), u32::MAX);
        #[cfg(target_pointer_width = "64")]
        {
            assert!(frame_len(u32::MAX as usize + 1).is_err());
            assert!(frame_len(usize::MAX).is_err());
        }
    }

    #[test]
    fn deframe_partial_returns_none() {
        let framed = frame(b"abcdef").unwrap();
        for cut in 0..framed.len() {
            assert!(deframe(&framed[..cut]).unwrap().is_none(), "cut={cut}");
        }
    }

    #[test]
    fn deframe_detects_corruption() {
        let mut framed = frame(b"abcdef").unwrap();
        let n = framed.len();
        framed[n - 6] ^= 0x40; // flip a payload bit
        assert!(deframe(&framed).is_err());
    }

    #[test]
    fn deframe_rejects_bad_magic() {
        let mut framed = frame(b"x").unwrap();
        framed[0] ^= 0xFF;
        assert!(deframe(&framed).is_err());
    }

    #[test]
    fn reader_underrun_errors() {
        let mut r = Reader::new(&[1, 2]);
        assert!(r.u32().is_err());
    }

    #[test]
    fn slice_writer_matches_writer_bytes() {
        let mut w = Writer::new();
        w.u8(7);
        w.bool(true);
        w.u16(0xBEEF);
        w.u32(0xDEADBEEF);
        w.u64(u64::MAX - 3);
        w.f32(-1.25);
        w.f32_slice(&[1.0, 2.5, -3.75]);
        let reference = w.into_bytes();

        let mut buf = vec![0u8; reference.len()];
        let mut sw = SliceWriter::new(&mut buf);
        sw.u8(7);
        sw.bool(true);
        sw.u16(0xBEEF);
        sw.u32(0xDEADBEEF);
        sw.u64(u64::MAX - 3);
        sw.f32(-1.25);
        sw.f32_slice(&[1.0, 2.5, -3.75]);
        assert_eq!(sw.written(), reference.len());
        assert_eq!(buf, reference);
    }

    #[test]
    fn u16_roundtrip() {
        let mut w = Writer::new();
        w.u16(0);
        w.u16(0xBEEF);
        w.u16(u16::MAX);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u16().unwrap(), 0);
        assert_eq!(r.u16().unwrap(), 0xBEEF);
        assert_eq!(r.u16().unwrap(), u16::MAX);
        assert_eq!(r.remaining(), 0);
    }

    /// A malformed frame whose f32-vec length prefix claims far more
    /// elements than the payload holds must be rejected up front — the
    /// prefix is attacker-controlled and must never size an allocation.
    #[test]
    fn f32_vec_rejects_lying_length_prefix() {
        // Claims u32::MAX elements (a 16 GiB vector) with 4 trailing bytes.
        let mut w = Writer::new();
        w.u32(u32::MAX);
        w.f32(1.0);
        let bytes = w.into_bytes();
        let err = Reader::new(&bytes).f32_vec().unwrap_err().to_string();
        assert!(err.contains("f32 vec"), "wrong error: {err}");

        // Off-by-one: claims 3 elements over 2 elements of payload.
        let mut w = Writer::new();
        w.u32(3);
        w.f32(1.0);
        w.f32(2.0);
        let bytes = w.into_bytes();
        assert!(Reader::new(&bytes).f32_vec().is_err());

        // The boundary itself still parses.
        let mut w = Writer::new();
        w.f32_slice(&[1.0, 2.0]);
        let bytes = w.into_bytes();
        assert_eq!(Reader::new(&bytes).f32_vec().unwrap(), vec![1.0, 2.0]);

        // The pooled variant enforces the same trust boundary.
        let mut w = Writer::new();
        w.u32(3);
        w.f32(1.0);
        let bytes = w.into_bytes();
        assert!(Reader::new(&bytes).f32_vec_pooled().is_err());
    }

    /// The pooled decode is bit-identical to the allocating one, even when
    /// it reuses a buffer with stale contents.
    #[test]
    fn f32_vec_pooled_matches_f32_vec() {
        use crate::util::pool;
        let mut w = Writer::new();
        w.f32_slice(&[1.0, -2.5, f32::NAN, 0.0]);
        let bytes = w.into_bytes();
        let plain = Reader::new(&bytes).f32_vec().unwrap();
        // Poison a recycled buffer so stale contents would be caught.
        let mut poisoned = pool::take_f32(64);
        poisoned.resize(64, f32::MAX);
        pool::recycle_f32(poisoned);
        let pooled = Reader::new(&bytes).f32_vec_pooled().unwrap();
        assert_eq!(plain.len(), pooled.len());
        assert!(plain.iter().zip(&pooled).all(|(a, b)| a.to_bits() == b.to_bits()));
        pool::recycle_f32(pooled);
    }
}
