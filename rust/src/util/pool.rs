//! Deterministic, thread-local buffer pools for the steady-state round loop
//! (DESIGN.md §14).
//!
//! Each OS thread owns an independent pool (`thread_local!`), so the events
//! executor has exactly one, the sharded parallel executor has one per shard
//! worker, and the thread-backed executor has one per client.  Pooling is
//! therefore invisible to scheduling: no locks, no cross-thread hand-off, no
//! effect on event order, and no effect on any RNG stream.  Buffers carry no
//! values across uses — `take_*` returns an *empty* vector (length 0) whose
//! capacity is at least the requested size, and every call-site fully
//! overwrites what it later reads — so a pooled run computes bit-identical
//! results to an unpooled one (`tests/conformance.rs` pins this across all
//! three executors).
//!
//! Size-classed free lists: capacities round up to the next power of two
//! (minimum 64 elements), one LIFO stack per class, at most `PER_CLASS`
//! buffers retained per class; anything beyond that is handed back to the
//! global allocator.

use std::cell::RefCell;

/// Smallest pooled capacity, in elements.  Requests below this round up to it.
const MIN_CLASS: usize = 64;
/// log2 of [`MIN_CLASS`].
const MIN_CLASS_LOG2: u32 = 6;
/// Number of size classes: 64, 128, …, 64·2^(CLASSES−1) elements.
const CLASSES: usize = 26;
/// Retained buffers per size class before recycles fall through to `drop`.
///
/// Sized for the events executor, where one thread hosts the whole fleet
/// and synchronized rounds recycle in bursts of ~clients × degree buffers
/// (window close) that must all be served back on the next round's decode
/// path.  4096 absorbs a four-digit-client deployment; a workload that
/// overflows it degrades to plain allocation, never to an error.
const PER_CLASS: usize = 4096;

/// Cumulative counters for the calling thread's pool.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// `take_*` calls served from a free list.
    pub hits: u64,
    /// `take_*` calls that fell through to a fresh allocation.
    pub misses: u64,
    /// `recycle_*` calls that parked the buffer for reuse.
    pub recycled: u64,
    /// `recycle_*` calls that dropped the buffer (class full or too small).
    pub dropped: u64,
}

/// One element type's size-classed free lists.
struct Shelf<T> {
    classes: [Vec<Vec<T>>; CLASSES],
}

impl<T> Shelf<T> {
    fn new() -> Self {
        Shelf { classes: std::array::from_fn(|_| Vec::new()) }
    }

    /// Pop an empty buffer with capacity ≥ `cap`, or allocate one.
    fn take(&mut self, cap: usize, stats: &mut PoolStats) -> Vec<T> {
        let want = cap.max(MIN_CLASS).next_power_of_two();
        let idx = (want.trailing_zeros() - MIN_CLASS_LOG2) as usize;
        if let Some(list) = self.classes.get_mut(idx) {
            if let Some(buf) = list.pop() {
                debug_assert!(buf.is_empty() && buf.capacity() >= cap);
                stats.hits += 1;
                return buf;
            }
        }
        stats.misses += 1;
        Vec::with_capacity(want.max(cap))
    }

    /// Park `buf` for reuse.  Classification uses the largest power of two
    /// the capacity covers, so a parked buffer always satisfies any request
    /// that rounds up into its class.
    fn recycle(&mut self, mut buf: Vec<T>, stats: &mut PoolStats) {
        let cap = buf.capacity();
        if cap < MIN_CLASS {
            stats.dropped += 1;
            return;
        }
        let idx = ((usize::BITS - 1 - cap.leading_zeros()) - MIN_CLASS_LOG2) as usize;
        if idx >= CLASSES {
            stats.dropped += 1;
            return;
        }
        let list = &mut self.classes[idx];
        if list.len() >= PER_CLASS {
            stats.dropped += 1;
            return;
        }
        buf.clear();
        stats.recycled += 1;
        list.push(buf);
    }

    /// Drop retained buffers beyond `keep` per class.
    fn trim(&mut self, keep: usize, stats: &mut PoolStats) {
        for list in &mut self.classes {
            while list.len() > keep {
                list.pop();
                stats.dropped += 1;
            }
        }
    }
}

struct Pool {
    f32s: Shelf<f32>,
    u8s: Shelf<u8>,
    stats: PoolStats,
}

thread_local! {
    static POOL: RefCell<Pool> = RefCell::new(Pool {
        f32s: Shelf::new(),
        u8s: Shelf::new(),
        stats: PoolStats::default(),
    });
}

/// Check out an **empty** `Vec<f32>` with capacity ≥ `cap`.
pub fn take_f32(cap: usize) -> Vec<f32> {
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        let Pool { f32s, stats, .. } = &mut *p;
        f32s.take(cap, stats)
    })
}

/// Return a `Vec<f32>` to this thread's pool for later reuse.
pub fn recycle_f32(buf: Vec<f32>) {
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        let Pool { f32s, stats, .. } = &mut *p;
        f32s.recycle(buf, stats);
    });
}

/// Check out an **empty** `Vec<u8>` with capacity ≥ `cap`.
pub fn take_u8(cap: usize) -> Vec<u8> {
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        let Pool { u8s, stats, .. } = &mut *p;
        u8s.take(cap, stats)
    })
}

/// Return a `Vec<u8>` to this thread's pool for later reuse.
pub fn recycle_u8(buf: Vec<u8>) {
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        let Pool { u8s, stats, .. } = &mut *p;
        u8s.recycle(buf, stats);
    });
}

/// Pooled clone: an exact element-for-element copy of `src` in a buffer
/// checked out of this thread's pool.
pub fn copy_of(src: &[f32]) -> Vec<f32> {
    let mut buf = take_f32(src.len());
    buf.extend_from_slice(src);
    buf
}

/// Explicit trim hook between runs or epochs: halves the retention cap of
/// every class so long-lived processes shed peak-sized buffers.  Never called
/// from the round loop itself — trimming frees memory, and the steady state
/// is supposed to touch the allocator not at all.
pub fn epoch_tick() {
    POOL.with(|p| {
        let mut p = p.borrow_mut();
        let Pool { f32s, u8s, stats } = &mut *p;
        f32s.trim(PER_CLASS / 2, stats);
        u8s.trim(PER_CLASS / 2, stats);
    });
}

/// The calling thread's cumulative pool counters.
pub fn stats() -> PoolStats {
    POOL.with(|p| p.borrow().stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The test harness runs every #[test] on its own thread, so each test
    // below sees a fresh thread-local pool and clean counters.

    #[test]
    fn take_recycle_take_reuses_the_same_buffer() {
        let mut a = take_f32(100);
        assert!(a.is_empty() && a.capacity() >= 100);
        a.extend_from_slice(&[1.0, 2.0, 3.0]);
        let ptr = a.as_ptr();
        recycle_f32(a);
        let b = take_f32(100);
        assert!(b.is_empty(), "recycled buffers come back cleared");
        assert_eq!(b.as_ptr(), ptr, "LIFO free list hands back the same allocation");
        let s = stats();
        assert_eq!((s.hits, s.misses, s.recycled), (1, 1, 1));
    }

    #[test]
    fn size_classes_round_up_and_classify_by_floor() {
        // A capacity-100 buffer floors into the 64-class, so a 64-element
        // request (which rounds up to exactly 64) can reuse it...
        recycle_f32(Vec::with_capacity(100));
        let b = take_f32(64);
        assert!(b.capacity() >= 100);
        assert_eq!(stats().hits, 1);
        // ...while a 100-element request rounds up to the 128-class and
        // must not see it (class-64 buffers only guarantee ≥ 64).
        recycle_f32(b);
        let c = take_f32(100);
        assert_eq!(stats().misses, 1);
        assert!(c.capacity() >= 100);
    }

    #[test]
    fn undersized_buffers_are_dropped_not_parked() {
        recycle_f32(Vec::with_capacity(8));
        assert_eq!(stats().dropped, 1);
        assert_eq!(stats().recycled, 0);
    }

    #[test]
    fn per_class_retention_is_bounded() {
        for _ in 0..(PER_CLASS + 3) {
            recycle_f32(Vec::with_capacity(64));
        }
        let s = stats();
        assert_eq!(s.recycled, PER_CLASS as u64);
        assert_eq!(s.dropped, 3);
    }

    #[test]
    fn u8_shelf_is_independent_of_f32_shelf() {
        recycle_u8(Vec::with_capacity(64));
        let b = take_f32(64);
        assert_eq!(stats().misses, 1, "f32 take must not raid the u8 shelf");
        recycle_f32(b);
        let c = take_u8(64);
        assert_eq!(stats().hits, 1);
        assert!(c.capacity() >= 64);
    }

    #[test]
    fn copy_of_is_an_exact_copy() {
        let src = [1.5f32, -2.25, 0.0, f32::MIN_POSITIVE];
        let c = copy_of(&src);
        assert_eq!(c.as_slice(), &src);
        // Poison a recycled buffer, then copy again: values must be
        // identical to the first copy — reuse never leaks stale contents.
        let mut poisoned = take_f32(64);
        poisoned.resize(64, f32::NAN);
        recycle_f32(poisoned);
        let d = copy_of(&src);
        assert_eq!(d.as_slice(), &src);
    }

    #[test]
    fn epoch_tick_halves_retention() {
        for _ in 0..PER_CLASS {
            recycle_f32(Vec::with_capacity(64));
        }
        epoch_tick();
        assert_eq!(stats().dropped, (PER_CLASS - PER_CLASS / 2) as u64);
    }
}
