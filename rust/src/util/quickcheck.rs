//! Seeded property-test runner (the `proptest` substrate).
//!
//! `forall(base_seed, cases, |rng| gen, |input| prop)` runs `cases`
//! independently-seeded generations; a failure panics with the exact seed so
//! the case replays deterministically with `replay(seed, gen, prop)`.
//!
//! The [`crate::sim::SimConfig`] *shrinker* that pairs with this runner
//! lives in `sim::shrink` — it is inherently a consumer of the simulator,
//! and `util` sits at the bottom of the module-layering DAG (DESIGN.md
//! §15), so it may not look upward.

use super::rng::Rng;

/// Run `cases` property checks. `generate` builds an input from a seeded RNG;
/// `property` returns `Err(reason)` on violation.
pub fn forall<T, G, P>(base_seed: u64, cases: u64, mut generate: G, mut property: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        let input = generate(&mut rng);
        if let Err(reason) = property(&input) {
            panic!(
                "property failed (seed {seed:#x}, case {case}/{cases}): {reason}\ninput: {input:?}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<T, G, P>(seed: u64, mut generate: G, mut property: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    let input = generate(&mut rng);
    if let Err(reason) = property(&input) {
        panic!("replayed property failure (seed {seed:#x}): {reason}\ninput: {input:?}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(
            1,
            50,
            |r| r.below(100),
            |&x| {
                if x < 100 {
                    Ok(())
                } else {
                    Err(format!("{x} >= 100"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures_with_seed() {
        forall(2, 50, |r| r.below(10), |&x| {
            if x != 7 {
                Ok(())
            } else {
                Err("hit 7".into())
            }
        });
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let mut first = Vec::new();
        forall(3, 10, |r| r.next_u64(), |&x| {
            first.push(x);
            Ok(())
        });
        let mut second = Vec::new();
        forall(3, 10, |r| r.next_u64(), |&x| {
            second.push(x);
            Ok(())
        });
        assert_eq!(first, second);
    }
}
