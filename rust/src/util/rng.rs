//! Deterministic PRNG + distributions (the `rand`/`rand_distr` substrate).
//!
//! PCG32 core (O'Neill 2014) seeded via SplitMix64, with Box-Muller
//! gaussians, Marsaglia-Tsang gamma, and the Dirichlet sampler the non-IID
//! data partitioner uses (paper: Dirichlet α = 0.6).
//!
//! Everything in the repo that needs randomness threads one of these through
//! explicitly — experiments are reproducible from a single u64 seed.

/// Permuted congruential generator (PCG-XSH-RR 64/32).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
}

/// SplitMix64 step — used to expand one u64 seed into PCG state/stream.
fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Deterministic generator from a seed; distinct seeds give independent
    /// streams (state and increment both derived through SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let state = splitmix64(&mut s);
        let inc = splitmix64(&mut s) | 1; // stream selector must be odd
        let mut rng = Rng { state, inc };
        rng.next_u32(); // decorrelate first output from the raw seed
        rng
    }

    /// Derive an independent child stream (e.g. per client, per link).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our needs).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (2000); shape > 0.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            return g * self.f64().max(1e-300).powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal() as f64;
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x * x * x * x {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Dirichlet(α, ..., α) over `k` categories (the paper's non-IID split).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-12)).collect();
        let s: f64 = g.iter().sum();
        for x in &mut g {
            *x /= s;
        }
        g
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }

    /// Sample from a discrete distribution given by (not necessarily
    /// normalized) non-negative weights.
    pub fn discrete(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert!((0..10).any(|_| a.next_u64() != b.next_u64()));
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(13);
        for &shape in &[0.3, 0.6, 1.0, 2.5, 8.0] {
            let n = 20_000;
            let m: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (m - shape).abs() / shape < 0.08,
                "gamma({shape}) mean {m}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one_and_varies() {
        let mut r = Rng::new(17);
        let p = r.dirichlet(0.6, 10);
        let s: f64 = p.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
        assert!(p.iter().all(|&x| x > 0.0));
        // α=0.6 should be visibly skewed most of the time
        let q = r.dirichlet(0.6, 10);
        assert!(p != q);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn discrete_respects_zero_weights() {
        let mut r = Rng::new(23);
        for _ in 0..1000 {
            let i = r.discrete(&[0.0, 3.0, 0.0, 1.0]);
            assert!(i == 1 || i == 3);
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(29);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }
}
