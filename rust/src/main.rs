//! `dfl` — CLI for the decentralized asynchronous FL runtime.
//!
//! Subcommands:
//! * `sim`        — run an in-process N-client deployment (both phases,
//!                  wall or virtual clock, any `--net` scenario preset)
//! * `client`     — run one real TCP client process (multi-machine mode)
//! * `reproduce`  — regenerate a paper table/figure, the beyond-paper
//!                  `scenarios` matrix, or `all` (virtual time by default;
//!                  `--real-time` restores wall-clock runs)
//! * `info`       — print artifact metadata and platform info

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use dfl::coordinator::async_client::{AsyncClient, ClientData};
use dfl::coordinator::fault::variable_crash_schedule;
use dfl::coordinator::ProtocolConfig;
use dfl::data::Dataset;
use dfl::exp::{self, ExpScale};
use dfl::net::TcpTransport;
use dfl::runtime::{SharedEngine, Trainer};
use dfl::sim::{self, Partition, SimConfig};
use dfl::util::cli::Flags;
use dfl::util::Rng;

/// Parse a `--quorum` value — a fraction in [0, 1], `auto`, or
/// `auto:Q_MIN` (shared by `sim` and `reproduce`).
fn parse_quorum(a: &dfl::util::cli::Args) -> Result<dfl::coordinator::QuorumSpec> {
    dfl::coordinator::QuorumSpec::parse(a.str("quorum"))
}

fn artifacts_dir(config: &str) -> PathBuf {
    // honor DFL_ARTIFACTS for non-repo-root invocations
    let root = std::env::var("DFL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    Path::new(&root).join(config)
}

fn load_engine(config: &str) -> Result<SharedEngine> {
    let dir = artifacts_dir(config);
    SharedEngine::load(&dir).with_context(|| {
        format!(
            "loading artifacts from {} — run `make artifacts` first",
            dir.display()
        )
    })
}

fn cmd_info(args: Vec<String>) -> Result<()> {
    let flags = Flags::new("dfl info", "print artifact + platform info")
        .opt("config", Some("tiny"), "artifact config (tiny|fast|paper)");
    let a = flags.parse(args)?;
    let engine = load_engine(a.str("config"))?;
    let m = engine.meta();
    println!("config       : {}", m.config);
    println!("n_params     : {}", m.n_params);
    println!("image        : {}x{}x{}", m.img, m.img, m.channels);
    println!("batch        : {} x {} train minibatches/round", m.batch, m.nb_train);
    println!("eval tensors : probe {} samples, full {} samples", m.eval_y_len(false), m.eval_y_len(true));
    println!("k_max        : {}", m.k_max);
    Ok(())
}

fn cmd_sim(args: Vec<String>) -> Result<()> {
    let flags = Flags::new("dfl sim", "in-process N-client deployment")
        .opt("config", Some("tiny"), "artifact config (tiny|fast|paper)")
        .opt("clients", Some("4"), "number of clients")
        .opt("machines", Some("1"), "virtual machines (1-3)")
        .opt("alpha", Some("0.6"), "dirichlet alpha (non-IID skew)")
        .opt("crashes", Some("0"), "clients to crash mid-run")
        .opt("rounds", Some("20"), "max rounds (R_PRIME)")
        .opt("timeout-ms", Some("500"), "phase-2 wait window")
        .opt("seed", Some("7"), "experiment seed")
        .opt("lr", Some("0.05"), "local SGD learning rate")
        .opt("min-rounds", Some("5"), "MINIMUM_ROUNDS before CCC")
        .opt("threshold", Some("0.015"), "CCC relative convergence threshold")
        .opt("train-n", Some("0"), "global train set size (0 = auto)")
        .opt("net", Some("lan"), "network preset (ideal|lan|wan|asym|lossy-burst)")
        .opt("topology", Some("full"), "peer overlay: full | ring:K | k-regular:D | small-world:D:P")
        .opt("quorum", Some("1.0"), "quorum-CCC condition (a): fraction q (1.0 = paper-strict), auto, or auto:Q_MIN (suspicion-driven)")
        .opt("fault", Some(""), "graph-fault schedule, ';'-separated: graph-cut:T1-T2:mincut|A-B,... and churn:CLIENT:LEAVE[-REJOIN] (seconds)")
        .opt("adversary", Some(""), "Byzantine roster, ';'-separated: poison:SCALE:IDS, equivocate:IDS, stale-replay:IDS, forge-suspicion:IDS (IDS = C1,C2,...)")
        .opt("agg", Some("fedavg"), "aggregation rule: fedavg | trimmed-mean:F | coord-median | krum:F")
        .opt("codec", Some("dense"), "model-exchange codec: dense (byte-identical default) | delta:K[,q16] (sparse top-K deltas + compact flag relays)")
        .opt("train-cost-ms", Some("20"), "modeled per-round train cost under --virtual")
        .opt("exec", Some("events"), "--virtual executor: events (single-threaded reference), parallel[:S] (S shard threads, byte-identical), or threads")
        .switch("virtual", "deterministic virtual clock instead of wall time")
        .switch("iid", "IID split instead of Dirichlet")
        .switch("verbose", "print per-round mean loss/accuracy")
        .switch("sync", "Phase 1 (synchronous rounds) instead of Phase 2");
    let a = flags.parse(args)?;
    let engine = load_engine(a.str("config"))?;
    let n = a.usize("clients")?;
    let mut cfg = SimConfig::for_meta(n, engine.meta());
    cfg.machines = a.usize("machines")?.clamp(1, 3);
    cfg.sync = a.bool("sync");
    cfg.partition =
        if a.bool("iid") { Partition::Iid } else { Partition::Dirichlet(a.f64("alpha")?) };
    cfg.protocol = ProtocolConfig {
        max_rounds: a.usize("rounds")? as u32,
        timeout: std::time::Duration::from_millis(a.u64("timeout-ms")?),
        lr: a.f32("lr")?,
        min_rounds: a.usize("min-rounds")? as u32,
        conv_threshold_rel: a.f32("threshold")?,
        ..ProtocolConfig::default()
    };
    cfg.seed = a.u64("seed")?;
    cfg.net = dfl::net::NetworkModel::preset(a.str("net"), cfg.seed)?;
    cfg.topology = dfl::net::TopologySpec::parse(a.str("topology"))?;
    cfg.protocol.quorum = parse_quorum(&a)?;
    cfg.protocol.agg = dfl::runtime::AggregationRule::parse(a.str("agg"))?;
    cfg.protocol.codec = dfl::net::CodecSpec::parse(a.str("codec"))?;
    cfg.graph_faults = dfl::coordinator::GraphFault::parse_list(a.str("fault"))?;
    cfg.adversaries = dfl::coordinator::AdversarySpec::parse_list(a.str("adversary"))?;
    cfg.virtual_time = a.bool("virtual");
    cfg.exec = dfl::sim::ExecMode::parse(a.str("exec"))?;
    cfg.train_cost = std::time::Duration::from_millis(a.u64("train-cost-ms")?);
    let window_before = cfg.protocol.timeout;
    exp::clear_latency_ceiling(&mut cfg, engine.meta());
    if cfg.protocol.timeout > window_before {
        println!(
            "note: wait window raised {:?} -> {:?} to clear the {} preset's latency ceiling",
            window_before,
            cfg.protocol.timeout,
            a.str("net")
        );
    }
    if a.usize("train-n")? > 0 {
        cfg.train_n = a.usize("train-n")?;
    }
    let crashes = a.usize("crashes")?;
    if crashes > 0 {
        let mut rng = Rng::new(cfg.seed ^ 0xFA17);
        cfg.faults = variable_crash_schedule(
            n,
            crashes,
            2,
            cfg.protocol.max_rounds.saturating_sub(2),
            &mut rng,
        );
    }
    println!(
        "running {} clients ({}), {} machines, {} crashes, {} graph faults, {} adversaries, agg {}, codec {}, net {}, topology {} (q={}), {} clock{}, seed {}",
        n,
        if cfg.sync { "phase 1 sync" } else { "phase 2 async" },
        cfg.machines,
        crashes,
        cfg.graph_faults.len(),
        cfg.adversaries.iter().map(|s| s.clients.len()).sum::<usize>(),
        cfg.protocol.agg.name(),
        cfg.protocol.codec.name(),
        a.str("net"),
        cfg.topology.name(),
        cfg.protocol.quorum.name(),
        if cfg.virtual_time { "virtual" } else { "wall" },
        if cfg.virtual_time {
            format!(" ({} executor)", cfg.exec.name())
        } else {
            String::new()
        },
        cfg.seed
    );
    let res = sim::run(&engine, &cfg)?;
    if a.bool("verbose") {
        let max_r = res.reports.iter().map(|r| r.history.len()).max().unwrap_or(0);
        println!("round | mean loss | mean probe acc | mean delta_rel");
        for round in 0..max_r {
            let rows: Vec<_> =
                res.reports.iter().filter_map(|r| r.history.get(round)).collect();
            let n = rows.len().max(1) as f32;
            println!(
                "{:>5} | {:>9.4} | {:>13.1}% | {:.5}",
                round,
                rows.iter().map(|h| h.train_loss).sum::<f32>() / n,
                rows.iter().map(|h| h.probe_acc).sum::<f32>() / n * 100.0,
                rows.iter().map(|h| h.delta_rel.min(9.9)).sum::<f32>() / n,
            );
        }
    }
    for r in &res.reports {
        println!(
            "  client {:>2}: cause={:?} rounds={} acc={} wall={:.2}s{}",
            r.id,
            r.cause,
            r.rounds_completed,
            r.final_accuracy.map(|a| format!("{:.2}%", a * 100.0)).unwrap_or("-".into()),
            r.wall.as_secs_f64(),
            r.signal_source.map(|s| format!(" (signaled by {s})")).unwrap_or_default()
        );
    }
    println!(
        "mean accuracy {} | rounds {} | wall {:.2}s | msgs/round {:.0} | machine times {:?}",
        res.mean_accuracy().map(|a| format!("{:.2}%", a * 100.0)).unwrap_or("-".into()),
        res.rounds(),
        res.wall.as_secs_f64(),
        res.msgs_per_round(),
        res.machine_times().iter().map(|t| format!("{:.2}s", t.as_secs_f64())).collect::<Vec<_>>(),
    );
    Ok(())
}

/// Parse `id=host:port,id=host:port,...`.
fn parse_peers(spec: &str) -> Result<BTreeMap<u32, std::net::SocketAddr>> {
    let mut out = BTreeMap::new();
    for part in spec.split(',').filter(|s| !s.is_empty()) {
        let (id, addr) = part
            .split_once('=')
            .with_context(|| format!("bad peer spec {part:?} (want id=host:port)"))?;
        out.insert(
            id.trim().parse::<u32>().context("peer id")?,
            addr.trim().parse().context("peer addr")?,
        );
    }
    Ok(out)
}

fn cmd_client(args: Vec<String>) -> Result<()> {
    let flags = Flags::new("dfl client", "one real TCP client (multi-process deployment)")
        .opt("config", Some("tiny"), "artifact config")
        .opt("id", None, "this client's id")
        .opt("listen", None, "listen address host:port")
        .opt("peers", None, "comma list id=host:port for all other clients")
        .opt("clients", Some("0"), "total client count (0 = peers+1)")
        .opt("rounds", Some("20"), "max rounds")
        .opt("timeout-ms", Some("1000"), "phase-2 wait window")
        .opt("alpha", Some("0.6"), "dirichlet alpha")
        .opt("train-n", Some("2000"), "global synthetic train set size")
        .opt("seed", Some("7"), "shared experiment seed (must match peers)")
        .opt("crash-at-round", Some("0"), "inject a crash at this round (0 = never)");
    let a = flags.parse(args)?;
    let engine = load_engine(a.str("config"))?;
    let meta = engine.meta().clone();
    let id = a.usize("id")? as u32;
    let peers = parse_peers(a.str("peers"))?;
    let n_clients = match a.usize("clients")? {
        0 => peers.len() + 1,
        n => n,
    };
    let listen: std::net::SocketAddr = a.str("listen").parse().context("listen addr")?;
    let transport = TcpTransport::bind(id, listen, peers)?;

    // All processes derive the same data + partition from the shared seed.
    let seed = a.u64("seed")?;
    let (train, test) =
        Dataset::synthetic_pair(&meta, a.usize("train-n")?, meta.nb_eval_full * meta.batch, seed);
    let mut rng = Rng::new(seed ^ 0x5EED);
    let parts = dfl::data::dirichlet_partition(&train, n_clients, a.f64("alpha")?, &mut rng);
    let data = ClientData::new(
        Arc::new(train),
        parts.get(id as usize).cloned().unwrap_or_default(),
        &test,
        &meta,
    );

    let crash_round = a.usize("crash-at-round")? as u32;
    let client = AsyncClient {
        id,
        trainer: &engine,
        transport: Box::new(transport),
        cfg: ProtocolConfig {
            max_rounds: a.usize("rounds")? as u32,
            timeout: std::time::Duration::from_millis(a.u64("timeout-ms")?),
            ..ProtocolConfig::default()
        },
        data,
        fault: if crash_round > 0 {
            dfl::coordinator::FaultPlan::at_round(crash_round)
        } else {
            dfl::coordinator::FaultPlan::none()
        },
        adversary: None,
        rng: Rng::new(seed ^ (0xC11E << 8) ^ id as u64),
        slowdown: 0.0,
        train_cost: None,
    };
    let report = client.run()?;
    println!(
        "client {id}: cause={:?} rounds={} acc={} wall={:.2}s",
        report.cause,
        report.rounds_completed,
        report.final_accuracy.map(|x| format!("{:.2}%", x * 100.0)).unwrap_or("-".into()),
        report.wall.as_secs_f64()
    );
    Ok(())
}

fn cmd_reproduce(args: Vec<String>) -> Result<()> {
    let flags = Flags::new("dfl reproduce", "regenerate a paper table/figure")
        .opt("config", Some("tiny"), "artifact config (tiny|fast|paper)")
        .opt("out", Some(""), "append markdown to this file")
        .opt("seed", Some("2025"), "experiment seed (same seed ⇒ identical tables)")
        .opt("net", Some(""), "override every driver's network with a preset (ideal|lan|wan|asym|lossy-burst)")
        .opt("topology", Some(""), "override every async driver's peer overlay (full|ring:K|k-regular:D|small-world:D:P)")
        .opt("quorum", Some(""), "override quorum-CCC condition (a): a fraction, auto, or auto:Q_MIN; empty = 1.0, paper-strict")
        .opt("agg", Some(""), "override the aggregation rule (fedavg|trimmed-mean:F|coord-median|krum:F); empty = fedavg")
        .opt("codec", Some(""), "override the async model-exchange codec (dense|delta:K[,q16]); empty = dense")
        .opt("train-cost-ms", Some("20"), "modeled per-round train cost under virtual time")
        .opt("exec", Some("events"), "virtual-time executor: events, parallel[:S], or threads")
        .switch("full", "full grids (slower) instead of quick mode")
        .switch("real-time", "wall-clock deployments (the paper's regime; minutes instead of seconds)");
    let a = flags.parse(args)?;
    let what = a.positional.first().map(String::as_str).unwrap_or("all");
    let engine = load_engine(a.str("config"))?;
    let mut scale = if a.bool("full") { ExpScale::full() } else { ExpScale::default() };
    scale.seed = a.u64("seed")?;
    scale.virtual_time = !a.bool("real-time");
    scale.exec = dfl::sim::ExecMode::parse(a.str("exec"))?;
    scale.train_cost_ms = a.u64("train-cost-ms")?;
    if !a.str("net").is_empty() {
        scale.net = Some(dfl::net::NetPreset::parse(a.str("net"))?);
    }
    if !a.str("topology").is_empty() {
        scale.topology = Some(dfl::net::TopologySpec::parse(a.str("topology"))?);
    }
    if !a.str("quorum").is_empty() {
        scale.quorum = Some(parse_quorum(&a)?);
    }
    if !a.str("agg").is_empty() {
        scale.agg = Some(dfl::runtime::AggregationRule::parse(a.str("agg"))?);
    }
    if !a.str("codec").is_empty() {
        scale.codec = Some(dfl::net::CodecSpec::parse(a.str("codec"))?);
    }

    let runs: Vec<(String, dfl::util::benchkit::Table)> = match what {
        "all" => exp::run_all(&engine, scale),
        "table2" => vec![("Table 2".into(), exp::table2(&engine, scale))],
        "table3" | "fig2-noniid" => vec![("Table 3".into(), exp::table3(&engine, scale))],
        "table4" | "fig2-iid" => vec![("Table 4".into(), exp::table4(&engine, scale))],
        "fig3" | "fig4" | "fig3_4" | "exp1" => {
            vec![("Fig 3+4".into(), exp::fig3_4(&engine, scale))]
        }
        "fig5" | "fig6" | "fig5_6" | "exp2" => {
            vec![("Fig 5+6".into(), exp::fig5_6(&engine, scale))]
        }
        "fig7" | "fig8" | "fig7_8" | "exp3" => {
            vec![("Fig 7+8".into(), exp::fig7_8(&engine, scale))]
        }
        "termination" => {
            vec![("Termination".into(), exp::termination_reliability(&engine, scale))]
        }
        "scenarios" | "matrix" => {
            vec![("Scenario matrix".into(), exp::scenarios(&engine, scale))]
        }
        "topologies" | "topo" => {
            vec![("Topology sweep".into(), exp::topologies(&engine, scale))]
        }
        "faults" | "graph-faults" => {
            vec![("Fault sweep".into(), exp::faults(&engine, scale))]
        }
        "byzantine" | "adversaries" => {
            vec![("Byzantine sweep".into(), exp::byzantine(&engine, scale))]
        }
        other => bail!(
            "unknown experiment {other:?}; want all|table2|table3|table4|fig3_4|fig5_6|fig7_8|termination|scenarios|topologies|faults|byzantine"
        ),
    };
    let mut md = String::new();
    for (title, table) in &runs {
        table.print(title);
        md.push_str(&format!("\n### {title}\n\n{}\n", table.markdown()));
    }
    let out = a.str("out");
    if !out.is_empty() {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(out)?;
        f.write_all(md.as_bytes())?;
        println!("appended markdown to {out}");
    }
    Ok(())
}

fn main() -> Result<()> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprintln!(
            "usage: dfl <sim|client|reproduce|info> [flags]\n\
             try `dfl sim --help`"
        );
        std::process::exit(2);
    }
    let cmd = args.remove(0);
    match cmd.as_str() {
        "sim" => cmd_sim(args),
        "client" => cmd_client(args),
        "reproduce" => cmd_reproduce(args),
        "info" => cmd_info(args),
        other => bail!("unknown subcommand {other:?} (want sim|client|reproduce|info)"),
    }
}
