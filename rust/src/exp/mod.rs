//! Experiment drivers — one per table/figure of the paper's §4.
//!
//! Each driver runs the relevant deployments through [`crate::sim`] and
//! returns a [`Table`] shaped like the paper's (same rows/series), so
//! `cargo bench` / `dfl reproduce` regenerate every result.  Absolute
//! numbers differ (synthetic data, scaled rounds, virtual machines — see
//! DESIGN.md §3); the *shapes* are the reproduction target and are asserted
//! in `rust/tests/experiments.rs`.

mod baseline;
mod exp1;
mod exp2;
mod exp3;
mod phase1;
mod termination;

pub use baseline::table2;
pub use exp1::fig3_4;
pub use exp2::fig5_6;
pub use exp3::fig7_8;
pub use phase1::{table3, table4};
pub use termination::termination_reliability;

use std::time::Duration;

use crate::coordinator::ProtocolConfig;
use crate::runtime::Trainer;
use crate::util::benchkit::Table;

/// Scaling knobs shared by all drivers.
#[derive(Clone, Copy, Debug)]
pub struct ExpScale {
    /// Fewer grid points + rounds (CI-friendly).
    pub quick: bool,
    pub seed: u64,
    /// Override the CCC threshold (None = CNN-tuned default; the mock
    /// trainer's gradient-noise floor needs a looser value).
    pub conv_threshold_rel: Option<f32>,
    /// Override the round cap (None = scale default).
    pub max_rounds: Option<u32>,
    /// Override MINIMUM_ROUNDS.
    pub min_rounds: Option<u32>,
    /// Override the wait window (ms); None = 60*n+200 for the PJRT engine.
    pub timeout_ms: Option<u64>,
}

impl Default for ExpScale {
    fn default() -> Self {
        ExpScale {
            quick: true,
            seed: 2025,
            conv_threshold_rel: None,
            max_rounds: None,
            min_rounds: None,
            timeout_ms: None,
        }
    }
}

impl ExpScale {
    pub fn full() -> Self {
        ExpScale { quick: false, ..Default::default() }
    }

    /// Mock-trainer scale for fast structural tests: looser convergence
    /// threshold (the mock's noise floor) and a small round cap.
    pub fn for_mock(seed: u64) -> Self {
        ExpScale {
            quick: true,
            seed,
            conv_threshold_rel: Some(0.3),
            max_rounds: Some(20),
            min_rounds: Some(4),
            timeout_ms: Some(120),
        }
    }

    /// Protocol constants scaled for the experiment runs.
    pub(crate) fn protocol(&self, n_clients: usize) -> ProtocolConfig {
        ProtocolConfig {
            // window must cover one serialized train+eval pass of every
            // client on this single-core testbed
            timeout: Duration::from_millis(
                self.timeout_ms.unwrap_or(60 * n_clients as u64 + 200),
            ),
            min_rounds: self.min_rounds.unwrap_or(15),
            count_threshold: 4,
            conv_threshold_rel: self.conv_threshold_rel.unwrap_or(0.028),
            max_rounds: self
                .max_rounds
                .unwrap_or(if self.quick { 60 } else { 100 }),
            lr: 0.12,
            model_seed: 42,
            weight_by_samples: false,
            early_window_exit: true,
            crt_enabled: true,
        }
    }

    pub(crate) fn train_n(&self, n_clients: usize) -> usize {
        (if self.quick { 150 } else { 400 }) * n_clients.max(2)
    }
}

/// Percent formatting helper for table cells.
pub(crate) fn pct(x: Option<f32>) -> String {
    match x {
        Some(v) => format!("{:.2}", v * 100.0),
        None => "-".into(),
    }
}

pub(crate) fn secs(d: Duration) -> String {
    format!("{:.2}", d.as_secs_f64())
}

/// All experiments in paper order (used by `dfl reproduce all`).
pub fn run_all(trainer: &(dyn Trainer + Sync), scale: ExpScale) -> Vec<(String, Table)> {
    vec![
        ("Table 2 — single-client baselines".into(), table2(trainer, scale)),
        ("Table 3 / Fig 2 — Phase 1 sync, non-IID".into(), table3(trainer, scale)),
        ("Table 4 / Fig 2 — Phase 1 sync, IID".into(), table4(trainer, scale)),
        ("Fig 3+4 — Exp 1 variable crash (12 clients)".into(), fig3_4(trainer, scale)),
        ("Fig 5+6 — Exp 2 proportional n/3 faults".into(), fig5_6(trainer, scale)),
        ("Fig 7+8 — Exp 3 maximum (n-1) faults".into(), fig7_8(trainer, scale)),
        (
            "Termination reliability (protocol metric)".into(),
            termination_reliability(trainer, scale),
        ),
    ]
}
