//! Experiment drivers — one per table/figure of the paper's §4, plus the
//! beyond-paper network-scenario matrix ([`scenarios()`]), sparse-
//! overlay topology sweep ([`topologies()`]), graph-fault sweep
//! ([`faults()`]), and Byzantine-adversary sweep ([`byzantine()`]).
//!
//! Each driver runs the relevant deployments through [`crate::sim`] and
//! returns a [`Table`] shaped like the paper's (same rows/series), so
//! `cargo bench` / `dfl reproduce` regenerate every result.  Absolute
//! numbers differ (synthetic data, scaled rounds, virtual machines — see
//! DESIGN.md §3); the *shapes* are the reproduction target and are asserted
//! in `rust/tests/experiments.rs`.
//!
//! Drivers run on the deterministic virtual clock by default
//! ([`ExpScale::virtual_time`], DESIGN.md §3.3): wait windows and modeled
//! training cost charge logical time, so a full `dfl reproduce all` takes
//! seconds of wall time and the same seed regenerates byte-identical
//! tables.  Set `virtual_time: false` (CLI: `--real-time`) for the seed's
//! original wall-clock behaviour.

mod baseline;
mod byzantine;
mod exp1;
mod exp2;
mod exp3;
mod faults;
mod phase1;
mod scenarios;
mod termination;

pub use baseline::table2;
pub use byzantine::byzantine;
pub use exp1::fig3_4;
pub use exp2::fig5_6;
pub use exp3::fig7_8;
pub use faults::faults;
pub use phase1::{table3, table4};
pub use scenarios::{scenarios, topologies};
pub use termination::termination_reliability;

use std::time::Duration;

use crate::coordinator::config::QuorumSpec;
use crate::coordinator::ProtocolConfig;
use crate::net::{CodecSpec, NetPreset, TopologySpec};
use crate::runtime::{AggregationRule, Meta, Trainer};
use crate::sim::{ExecMode, SimConfig};
use crate::util::benchkit::Table;

/// Scaling knobs shared by all drivers.
#[derive(Clone, Copy, Debug)]
pub struct ExpScale {
    /// Fewer grid points + rounds (CI-friendly).
    pub quick: bool,
    pub seed: u64,
    /// Override the CCC threshold (None = CNN-tuned default; the mock
    /// trainer's gradient-noise floor needs a looser value).
    pub conv_threshold_rel: Option<f32>,
    /// Override the round cap (None = scale default).
    pub max_rounds: Option<u32>,
    /// Override MINIMUM_ROUNDS.
    pub min_rounds: Option<u32>,
    /// Override the wait window (ms); None = 60*n+200 for the PJRT engine.
    pub timeout_ms: Option<u64>,
    /// Run deployments on the deterministic virtual clock (default): wait
    /// windows and training cost charge logical time, tables regenerate in
    /// seconds, and a fixed seed reproduces them byte-for-byte.  `false`
    /// restores the seed's wall-clock behaviour.
    pub virtual_time: bool,
    /// Which executor drives virtual-time deployments (CLI: `--exec`).
    /// [`ExecMode::Events`] (default) runs every client as a state machine
    /// on one thread; [`ExecMode::Parallel`] shards those machines across
    /// worker threads behind conservative lookahead windows; and
    /// [`ExecMode::Threads`] is the thread-backed compatibility mode — all
    /// three produce byte-identical tables for the same seed.
    pub exec: ExecMode,
    /// Modeled per-round training cost (ms) under virtual time, scaled by
    /// each client's machine slowdown; ignored on the wall clock, where
    /// real compute time is measured instead.
    pub train_cost_ms: u64,
    /// Override every driver's network with a named preset (None = each
    /// driver's own default, LAN unless the experiment says otherwise).
    pub net: Option<NetPreset>,
    /// Override every async driver's peer overlay (None = each driver's
    /// own default, the paper's full mesh).  Phase-1 drivers ignore it —
    /// their barrier requires the full mesh.
    pub topology: Option<TopologySpec>,
    /// Override quorum-CCC's condition (a) (None = `Fixed(1.0)`, the
    /// paper-strict condition; `Auto` enables suspicion-driven
    /// auto-tuning — the CLI's `--quorum auto`).
    pub quorum: Option<QuorumSpec>,
    /// Override the aggregation rule (None = `FedAvg`, the byte-identical
    /// pre-rule path; the CLI's `--agg`).  The byzantine driver sweeps
    /// rules itself and ignores this override within its rule column.
    pub agg: Option<AggregationRule>,
    /// Override the model-exchange codec (None = `Dense`, the
    /// byte-identical pre-codec path; the CLI's `--codec`).  Phase-1
    /// drivers ignore it — `sim::run` rejects delta under sync, so the
    /// override applies to async deployments only.
    pub codec: Option<CodecSpec>,
}

impl Default for ExpScale {
    fn default() -> Self {
        ExpScale {
            quick: true,
            seed: 2025,
            conv_threshold_rel: None,
            max_rounds: None,
            min_rounds: None,
            timeout_ms: None,
            virtual_time: true,
            exec: ExecMode::Events,
            train_cost_ms: 20,
            net: None,
            topology: None,
            quorum: None,
            agg: None,
            codec: None,
        }
    }
}

impl ExpScale {
    pub fn full() -> Self {
        ExpScale { quick: false, ..Default::default() }
    }

    /// Mock-trainer scale for fast structural tests: looser convergence
    /// threshold (the mock's noise floor), a small round cap, and a small
    /// modeled train cost.
    pub fn for_mock(seed: u64) -> Self {
        ExpScale {
            quick: true,
            seed,
            conv_threshold_rel: Some(0.3),
            max_rounds: Some(20),
            min_rounds: Some(4),
            timeout_ms: Some(120),
            train_cost_ms: 5,
            ..Default::default()
        }
    }

    /// Protocol constants scaled for the experiment runs.
    pub(crate) fn protocol(&self, n_clients: usize) -> ProtocolConfig {
        ProtocolConfig {
            // window must cover one serialized train+eval pass of every
            // client on this single-core testbed (wall clock); virtual
            // windows are free, so the same bound is simply generous there
            timeout: Duration::from_millis(
                self.timeout_ms.unwrap_or(60 * n_clients as u64 + 200),
            ),
            min_rounds: self.min_rounds.unwrap_or(15),
            count_threshold: 4,
            conv_threshold_rel: self.conv_threshold_rel.unwrap_or(0.028),
            max_rounds: self
                .max_rounds
                .unwrap_or(if self.quick { 60 } else { 100 }),
            lr: 0.12,
            model_seed: 42,
            weight_by_samples: false,
            early_window_exit: true,
            crt_enabled: true,
            quorum: self.quorum.unwrap_or(QuorumSpec::STRICT),
            agg: self.agg.unwrap_or(AggregationRule::FedAvg),
            codec: self.codec.unwrap_or(CodecSpec::Dense),
        }
    }

    pub(crate) fn train_n(&self, n_clients: usize) -> usize {
        (if self.quick { 150 } else { 400 }) * n_clients.max(2)
    }

    /// Apply the scale's shared knobs to a driver-built [`SimConfig`]:
    /// protocol constants, dataset size, time regime, modeled train cost,
    /// and the network-preset override.  Drivers call this once per run and
    /// then layer their experiment-specific settings (partition, faults,
    /// per-row seeds) on top.
    pub(crate) fn configure(&self, cfg: &mut SimConfig, meta: &Meta) {
        cfg.protocol = self.protocol(cfg.n_clients);
        // Phase-1 drivers keep the dense codec: their barrier exchanges
        // round-tagged full models (`sim::run` rejects delta under sync),
        // so a global `--codec delta` override applies to async rows only.
        if cfg.sync {
            cfg.protocol.codec = CodecSpec::Dense;
        }
        cfg.train_n = self.train_n(cfg.n_clients);
        cfg.virtual_time = self.virtual_time;
        cfg.exec = self.exec;
        cfg.train_cost = Duration::from_millis(self.train_cost_ms);
        if let Some(topology) = self.topology {
            // Phase-1 drivers keep the full mesh: their barrier waits on
            // every peer, so a sparse override would abort the run.
            if !cfg.sync {
                cfg.topology = topology;
            }
        }
        if let Some(preset) = self.net {
            cfg.net = preset.model(self.seed);
            // A slow preset pushed into a paper table must not shrink below
            // the network's latency ceiling, or a fault-free grid silently
            // measures mass false-crash detection instead of the protocol.
            clear_latency_ceiling(cfg, meta);
        }
    }
}

/// Floor the wait window at 2.5× the network's worst one-way delay for a
/// model-update payload, so runs measure the configured network, not the
/// timeout constant (every peer looks crashed below the ceiling).  Applied
/// wherever a network preset meets a [`SimConfig`]: `ExpScale::configure`
/// (internal) and `dfl sim --net`.
pub fn clear_latency_ceiling(cfg: &mut SimConfig, meta: &Meta) {
    let payload = meta.n_params * 4 + 64; // encoded ModelUpdate upper bound
    cfg.protocol.timeout =
        cfg.protocol.timeout.max(cfg.net.max_one_way(payload).mul_f64(2.5));
}

/// Percent formatting helper for table cells.
pub(crate) fn pct(x: Option<f32>) -> String {
    match x {
        Some(v) => format!("{:.2}", v * 100.0),
        None => "-".into(),
    }
}

pub(crate) fn secs(d: Duration) -> String {
    format!("{:.2}", d.as_secs_f64())
}

/// All experiments in paper order, then the beyond-paper scenario matrix
/// (used by `dfl reproduce all`).
pub fn run_all(trainer: &(dyn Trainer + Sync), scale: ExpScale) -> Vec<(String, Table)> {
    vec![
        ("Table 2 — single-client baselines".into(), table2(trainer, scale)),
        ("Table 3 / Fig 2 — Phase 1 sync, non-IID".into(), table3(trainer, scale)),
        ("Table 4 / Fig 2 — Phase 1 sync, IID".into(), table4(trainer, scale)),
        ("Fig 3+4 — Exp 1 variable crash (12 clients)".into(), fig3_4(trainer, scale)),
        ("Fig 5+6 — Exp 2 proportional n/3 faults".into(), fig5_6(trainer, scale)),
        ("Fig 7+8 — Exp 3 maximum (n-1) faults".into(), fig7_8(trainer, scale)),
        (
            "Termination reliability (protocol metric)".into(),
            termination_reliability(trainer, scale),
        ),
        (
            "Scenario matrix — network presets (beyond paper)".into(),
            scenarios(trainer, scale),
        ),
        (
            "Topology sweep — sparse overlays (beyond paper)".into(),
            topologies(trainer, scale),
        ),
        (
            "Fault sweep — graph faults + quorum auto-tuning (beyond paper)".into(),
            faults(trainer, scale),
        ),
        (
            "Byzantine sweep — adversaries vs robust aggregation (beyond paper)".into(),
            byzantine(trainer, scale),
        ),
    ]
}
