//! Tables 3 & 4 / Figure 2 — Phase 1 (synchronous rounds), fault-free,
//! clients 2..=10, non-IID (Table 3) and IID (Table 4).
//!
//! Paper shape: accuracy rises with client count (59.78→67.47 non-IID,
//! 61.10→70.50 IID); IID beats non-IID at every count; per-machine times
//! (M1/M2) grow with client count.

use super::{pct, secs, ExpScale};
use crate::runtime::Trainer;
use crate::sim::{self, Partition, SimConfig};
use crate::util::benchkit::Table;

fn phase1(trainer: &(dyn Trainer + Sync), scale: ExpScale, iid: bool) -> Table {
    let meta = trainer.meta().clone();
    let counts: Vec<usize> = if scale.quick { vec![2, 6, 10] } else { vec![2, 4, 6, 8, 10] };
    let mut table =
        Table::new(&["Clients", "Rounds", "Accuracy (%)", "M1 Time (s)", "M2 Time (s)"]);
    for &n in &counts {
        let mut cfg = SimConfig::for_meta(n, &meta);
        cfg.sync = true;
        cfg.machines = 2; // the paper reports M1/M2 columns
        cfg.partition = if iid { Partition::Iid } else { Partition::Dirichlet(0.6) };
        scale.configure(&mut cfg, &meta);
        cfg.seed = scale.seed + n as u64;
        let res = sim::run(trainer, &cfg).expect("phase1 run");
        let times = res.machine_times();
        table.row(&[
            n.to_string(),
            res.rounds().to_string(),
            pct(res.mean_accuracy()),
            secs(times[0]),
            secs(*times.get(1).unwrap_or(&times[0])),
        ]);
    }
    table
}

/// Table 3 — non-IID CIFAR-10 (synthetic stand-in).
pub fn table3(trainer: &(dyn Trainer + Sync), scale: ExpScale) -> Table {
    phase1(trainer, scale, false)
}

/// Table 4 — IID CIFAR-10 (synthetic stand-in).
pub fn table4(trainer: &(dyn Trainer + Sync), scale: ExpScale) -> Table {
    phase1(trainer, scale, true)
}
