//! Experiment 2 (Figures 5 & 6) — proportional fault analysis.
//!
//! n ∈ {4..12}: ⌊n/3⌋ clients crash at regular intervals mid-run; compared
//! against the fault-free *baseline* running with ⌊2n/3⌋ clients under
//! Phase 1.  Paper shape: faulty-run accuracy ≈ baseline accuracy (crashed
//! clients still helped before dying), and on 2–3 machines the faulty run
//! can beat the baseline's time.

use super::{pct, secs, ExpScale};
use crate::coordinator::fault::proportional_schedule;
use crate::runtime::Trainer;
use crate::sim::{self, Partition, SimConfig};
use crate::util::benchkit::Table;
use crate::util::Rng;

pub fn fig5_6(trainer: &(dyn Trainer + Sync), scale: ExpScale) -> Table {
    let meta = trainer.meta().clone();
    let counts: Vec<usize> = if scale.quick { vec![6, 12] } else { vec![4, 6, 8, 10, 12] };
    let machine_setups: &[usize] = if scale.quick { &[2] } else { &[1, 2, 3] };
    let mut table = Table::new(&[
        "Clients",
        "Setup",
        "Faults",
        "Accuracy (%)",
        "Time (s)",
        "Rounds",
    ]);
    for &n in &counts {
        // --- baseline: fault-free ⌊2n/3⌋ clients, Phase-1 learning ---------
        let nb = (2 * n) / 3;
        let mut base = SimConfig::for_meta(nb, &meta);
        base.sync = true;
        base.machines = 2;
        base.partition = Partition::Dirichlet(0.6);
        scale.configure(&mut base, &meta);
        base.seed = scale.seed + 31 * n as u64;
        let res = sim::run(trainer, &base).expect("exp2 baseline");
        table.row(&[
            n.to_string(),
            "baseline(2n/3)".into(),
            "0".to_string(),
            pct(res.mean_accuracy()),
            secs(res.wall),
            res.rounds().to_string(),
        ]);

        // --- faulty runs: n clients, n/3 mid-run crashes --------------------
        for &machines in machine_setups {
            let mut cfg = SimConfig::for_meta(n, &meta);
            cfg.machines = machines;
            cfg.partition = Partition::Dirichlet(0.6);
            scale.configure(&mut cfg, &meta);
            cfg.seed = scale.seed + 37 * n as u64 + machines as u64;
            let mut rng = Rng::new(cfg.seed ^ 0xE2);
            cfg.faults = proportional_schedule(n, cfg.protocol.max_rounds, &mut rng);
            let res = sim::run(trainer, &cfg).expect("exp2 faulty");
            table.row(&[
                n.to_string(),
                format!("{machines}-machine"),
                (n / 3).to_string(),
                pct(res.mean_accuracy()),
                secs(res.wall),
                res.rounds().to_string(),
            ]);
        }
    }
    table
}
