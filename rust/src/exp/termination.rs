//! Protocol-level termination metric (not a numbered figure, but the
//! paper's §3 claims): under crashes, delays and message loss, every
//! surviving client must terminate *adaptively* (CCC or CRT) — no client
//! stuck at the round cap, no premature stop before `MINIMUM_ROUNDS`.

use super::ExpScale;
use crate::coordinator::fault::variable_crash_schedule;
use crate::coordinator::termination::TerminationCause;
use crate::net::NetworkModel;
use crate::runtime::Trainer;
use crate::sim::{self, Partition, SimConfig};
use crate::util::benchkit::Table;
use crate::util::Rng;

pub fn termination_reliability(trainer: &(dyn Trainer + Sync), scale: ExpScale) -> Table {
    let meta = trainer.meta().clone();
    let n = 8;
    let scenarios: Vec<(&str, f64, usize)> = if scale.quick {
        vec![("no faults", 0.0, 0), ("2 crashes + 5% loss", 0.05, 2)]
    } else {
        vec![
            ("no faults", 0.0, 0),
            ("2 crashes", 0.0, 2),
            ("5% message loss", 0.05, 0),
            ("2 crashes + 5% loss", 0.05, 2),
            ("4 crashes + 10% loss", 0.10, 4),
        ]
    };
    let mut table = Table::new(&[
        "Scenario",
        "Adaptive Term. (%)",
        "CCC initiators",
        "CRT signaled",
        "Hit round cap",
        "Premature (<min rounds)",
    ]);
    for (name, drop_prob, crashes) in scenarios {
        let mut cfg = SimConfig::for_meta(n, &meta);
        cfg.partition = Partition::Dirichlet(0.6);
        scale.configure(&mut cfg, &meta);
        if scale.max_rounds.is_none() {
            // This experiment specifically measures *termination*: give the
            // CNN a horizon long enough to actually plateau (the table/figure
            // grids cap rounds for wallclock and often end at R_PRIME).
            cfg.protocol.max_rounds = 160;
        }
        if scale.net.is_none() {
            cfg.net = NetworkModel::lossy(drop_prob, scale.seed);
        } else {
            // A scale-level preset supplies latency/bandwidth/burst, but
            // the per-row independent drop rate stays the experiment
            // variable — otherwise rows labeled with different loss rates
            // would be byte-identical runs.
            cfg.net.drop_prob = drop_prob;
        }
        cfg.seed = scale.seed ^ 0x7E21;
        let mut rng = Rng::new(cfg.seed);
        cfg.faults =
            variable_crash_schedule(n, crashes, 3, cfg.protocol.max_rounds / 2, &mut rng);
        let res = sim::run(trainer, &cfg).expect("termination run");
        let finished: Vec<_> = res
            .reports
            .iter()
            .filter(|r| r.cause != TerminationCause::Crashed)
            .collect();
        let adaptive = finished
            .iter()
            .filter(|r| {
                matches!(r.cause, TerminationCause::Converged | TerminationCause::Signaled)
            })
            .count();
        let ccc = finished
            .iter()
            .filter(|r| r.cause == TerminationCause::Converged)
            .count();
        let crt = finished
            .iter()
            .filter(|r| r.cause == TerminationCause::Signaled)
            .count();
        let capped = finished
            .iter()
            .filter(|r| r.cause == TerminationCause::MaxRounds)
            .count();
        let premature = finished
            .iter()
            .filter(|r| r.rounds_completed < cfg.protocol.min_rounds)
            .count();
        table.row(&[
            name.to_string(),
            format!("{:.0}", 100.0 * adaptive as f32 / finished.len().max(1) as f32),
            ccc.to_string(),
            crt.to_string(),
            capped.to_string(),
            premature.to_string(),
        ]);
    }
    table
}
