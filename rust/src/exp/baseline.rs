//! Table 2 — single-client baselines (no communication).
//!
//! Paper: non-IID fixed chunk 26.23%, IID fixed chunk 37.48%, full dataset
//! 70.82%.  Expected shape: non-IID < IID < full.

use super::{pct, ExpScale};
use crate::runtime::Trainer;
use crate::sim::{self, Partition, SimConfig};
use crate::util::benchkit::Table;

pub fn table2(trainer: &(dyn Trainer + Sync), scale: ExpScale) -> Table {
    let meta = trainer.meta().clone();
    let chunk = scale.train_n(10) / 10; // the paper's 5000-of-50000 ratio
    let scenarios: [(&str, Partition); 3] = [
        ("Non-IID Single Client (fixed chunk)", Partition::SkewedChunk { size: chunk, alpha: 0.2 }),
        ("IID Single Client (fixed chunk)", Partition::FixedChunk(chunk)),
        ("Single Client (full dataset)", Partition::Full),
    ];
    let mut table = Table::new(&["Scenario", "Accuracy (%)", "Rounds"]);
    for (name, partition) in scenarios {
        let mut cfg = SimConfig::for_meta(1, &meta);
        cfg.partition = partition;
        scale.configure(&mut cfg, &meta);
        // single-client rows draw chunks from a 10-client-sized pool so the
        // chunk/full ratio matches the paper's 5000-of-50000
        cfg.train_n = scale.train_n(10);
        cfg.seed = scale.seed;
        if matches!(cfg.partition, Partition::Full) {
            // The paper's full-dataset client performs a full pass per epoch
            // (≈10× the SGD steps of a chunk client).  Our train_round does a
            // fixed nb_train minibatches, so scale rounds by the data ratio
            // to keep the per-sample training budget comparable.
            cfg.protocol.max_rounds *= 6;
            cfg.protocol.count_threshold *= 2;
        }
        let res = sim::run(trainer, &cfg).expect("table2 run");
        table.row(&[
            name.to_string(),
            pct(res.mean_accuracy()),
            res.rounds().to_string(),
        ]);
    }
    table
}
