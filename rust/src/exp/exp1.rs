//! Experiment 1 (Figures 3 & 4) — variable crash analysis.
//!
//! 12 clients; crashes swept 0..11; deployments on 1/2/3 virtual machines.
//! Paper shape: accuracy degrades gracefully as faults grow (Fig 4); at 0
//! faults the single-machine setup is much slower than multi-machine
//! (contention), and time broadly decreases as more clients die (Fig 3).

use super::{pct, secs, ExpScale};
use crate::coordinator::fault::variable_crash_schedule;
use crate::runtime::Trainer;
use crate::sim::{self, Partition, SimConfig};
use crate::util::benchkit::Table;
use crate::util::Rng;

pub const N_CLIENTS: usize = 12;

pub fn fig3_4(trainer: &(dyn Trainer + Sync), scale: ExpScale) -> Table {
    let meta = trainer.meta().clone();
    let fault_counts: Vec<usize> =
        if scale.quick { vec![0, 4, 8, 11] } else { vec![0, 2, 4, 6, 8, 10, 11] };
    let machine_setups: &[usize] = if scale.quick { &[1, 3] } else { &[1, 2, 3] };
    let mut table = Table::new(&[
        "Faults",
        "Machines",
        "Accuracy (%)",
        "Time (s)",
        "Rounds",
        "Survivors",
    ]);
    for &machines in machine_setups {
        for &k in &fault_counts {
            let mut cfg = SimConfig::for_meta(N_CLIENTS, &meta);
            cfg.machines = machines;
            cfg.partition = Partition::Dirichlet(0.6);
            scale.configure(&mut cfg, &meta);
            cfg.seed = scale.seed ^ ((machines as u64) << 32) ^ k as u64;
            let mut rng = Rng::new(cfg.seed ^ 0xFA17);
            // crashes land in the first third of the horizon so every
            // configuration has a comparable post-crash convergence window
            // (isolates the paper's data-loss effect from run-length noise)
            cfg.faults = variable_crash_schedule(
                N_CLIENTS,
                k,
                2,
                (cfg.protocol.max_rounds / 3).max(3),
                &mut rng,
            );
            let res = sim::run(trainer, &cfg).expect("exp1 run");
            table.row(&[
                k.to_string(),
                machines.to_string(),
                pct(res.mean_accuracy()),
                secs(res.wall),
                res.rounds().to_string(),
                (N_CLIENTS - res.crashed()).to_string(),
            ]);
        }
    }
    table
}
