//! Graph-fault sweep (DESIGN.md §10) — the topology-aware fault
//! repertoire under quorum auto-tuning, measured.
//!
//! The paper argues fault tolerance against the *client set* (crashes);
//! Asynchronous Byzantine FL (arXiv:2406.01438) argues that
//! termination-relevant guarantees must be stated against the
//! *communication graph*.  This driver attacks the graph directly: one
//! `k-regular:6` deployment per row, everything held fixed (seed, data,
//! partitions, network) except the graph-fault schedule —
//!
//! * `none` — the control row;
//! * `edge-cut` — a seeded min-cut of the overlay severed for a mid-run
//!   window, then healed;
//! * `churn` — two clients depart mid-run (edges torn down, orphans
//!   repaired) and rejoin with regenerated edges;
//! * `cut+churn` — both at once.
//!
//! All rows run `--quorum auto`: the per-client controller derives
//! condition (a)'s tolerance from the suspicion rate the faults actually
//! inflict, so no row needs a hand-picked `q`.  Reported per row:
//! severed overlay edges (the measured fault pressure,
//! `NetStats::edges_severed`), rounds, adaptive-termination health,
//! fault-induced suspicions, and accuracy — does learning survive the
//! graph being attacked?

use super::{clear_latency_ceiling, pct, ExpScale};
use crate::coordinator::config::QuorumSpec;
use crate::coordinator::fault::GraphFault;
use crate::coordinator::termination::TerminationCause;
use crate::net::{NetworkModel, TopologySpec};
use crate::runtime::Trainer;
use crate::sim::{self, Partition, SimConfig};
use crate::util::benchkit::Table;
use std::time::Duration;

pub fn faults(trainer: &(dyn Trainer + Sync), scale: ExpScale) -> Table {
    let meta = trainer.meta().clone();
    let n = if scale.quick { 24 } else { 48 };
    // Fault times scale with the modeled round length so the windows land
    // mid-run at any train-cost setting: a round costs at least
    // `train_cost`, so round ~8 is comfortably past MINIMUM_ROUNDS warmup
    // territory and well before the cap.
    let tick = scale.train_cost_ms.max(1);
    let ms = |t: u64| Duration::from_millis(t);
    let cut = GraphFault::EdgeCut {
        start: ms(8 * tick),
        end: ms(20 * tick),
        cut: crate::coordinator::fault::CutSpec::MinCut,
    };
    let churn = |client: u32| GraphFault::Churn {
        client,
        leave: ms(6 * tick),
        rejoin: Some(ms(18 * tick)),
    };
    let rows: [(&str, Vec<GraphFault>); 4] = [
        ("none", vec![]),
        ("edge-cut", vec![cut.clone()]),
        ("churn", vec![churn(3), churn(11)]),
        ("cut+churn", vec![cut, churn(3), churn(11)]),
    ];
    let mut table = Table::new(&[
        "Fault",
        "Edges severed",
        "Rounds",
        "Adaptive Term. (%)",
        "Suspicions",
        "Accuracy (%)",
    ]);
    for (name, graph_faults) in rows {
        let mut cfg = SimConfig::for_meta(n, &meta);
        cfg.partition = Partition::Dirichlet(0.6);
        scale.configure(&mut cfg, &meta);
        if scale.net.is_none() {
            cfg.net = NetworkModel::lan(scale.seed);
            clear_latency_ceiling(&mut cfg, &meta);
        }
        // The fault schedule is the sweep variable; the overlay and the
        // auto-quorum are the fixed substrate — but like the quorum knob,
        // an explicit CLI override (`--topology` / `--quorum`) still
        // wins, so a fixed-q or different-graph sweep is one flag away
        // (the schedule's mincut and churn ids are valid on any built
        // overlay of this size).
        if scale.topology.is_none() {
            cfg.topology = TopologySpec::KRegular { d: 6 };
        }
        if scale.quorum.is_none() {
            cfg.protocol.quorum = QuorumSpec::parse("auto").expect("auto quorum");
        }
        cfg.graph_faults = graph_faults;
        cfg.seed = scale.seed;
        let res = sim::run(trainer, &cfg).expect("fault-sweep run");
        let adaptive = res
            .reports
            .iter()
            .filter(|r| {
                matches!(r.cause, TerminationCause::Converged | TerminationCause::Signaled)
            })
            .count();
        // No client crashes are scheduled, so every suspicion is the
        // graph fault (or the network) fooling the timeout detector.
        let suspicions: usize = res
            .reports
            .iter()
            .flat_map(|r| &r.history)
            .map(|h| h.crashes_detected.len())
            .sum();
        table.row(&[
            name.to_string(),
            res.net.edges_severed.to_string(),
            res.rounds().to_string(),
            format!("{:.0}", 100.0 * adaptive as f32 / n as f32),
            suspicions.to_string(),
            pct(res.mean_accuracy()),
        ]);
    }
    table
}
