//! Network-scenario matrix and topology sweep — beyond-paper workloads.
//!
//! Two drivers live here: [`scenarios`] sweeps the network presets on the
//! paper's full mesh, and [`topologies`] sweeps the peer overlay
//! (full / ring / k-regular / small-world, DESIGN.md §9) on one network,
//! measuring the O(n·d) vs O(n²) per-round message volume directly from
//! the hub counters.
//!
//! The paper evaluates on one LAN testbed; this driver sweeps the Phase-2
//! asynchronous protocol across every [`NetPreset`] (DESIGN.md §3.4):
//! ideal, LAN, WAN, asymmetric-latency-with-bandwidth-cap, and
//! Gilbert–Elliott burst loss.  All rows share one seed, so data,
//! partitions, and fault-freeness are held fixed and the network is the
//! only variable.  Under the virtual clock the whole sweep is compute
//! bound — WAN latencies and widened wait windows cost no wall time.
//!
//! Reported per preset:
//!
//! * accuracy / rounds — does learning quality survive the network?
//! * virtual time — the modeled schedule length (latency + windows).
//! * adaptive termination — every client must still end by CCC/CRT.
//! * false suspicions — crash detections in a run with *no* faults: pure
//!   network-induced misdiagnosis (late or lost updates past the window).

use super::{clear_latency_ceiling, pct, secs, ExpScale};
use crate::coordinator::termination::TerminationCause;
use crate::net::{CodecSpec, NetPreset, NetworkModel, TopologySpec};
use crate::runtime::Trainer;
use crate::sim::{self, Partition, SimConfig};
use crate::util::benchkit::Table;

pub fn scenarios(trainer: &(dyn Trainer + Sync), scale: ExpScale) -> Table {
    let meta = trainer.meta().clone();
    let n = if scale.quick { 6 } else { 10 };
    let mut table = Table::new(&[
        "Scenario",
        "Accuracy (%)",
        "Rounds",
        "Time (s)",
        "Adaptive Term. (%)",
        "False Suspicions",
        "Codec",
        "kB/round",
    ]);
    let mut run_row = |label: String, preset: NetPreset, codec: Option<CodecSpec>| {
        // The network is the sweep variable: each row configures through a
        // scale whose preset is forced to the row's own, so a scale-level
        // `--net` neither survives into the sweep nor ratchets any other
        // row's wait window; the shared seed keeps data/partitions
        // identical across rows.  `configure` floors each row's window at
        // its own preset's latency ceiling, so rows measure the network,
        // not the timeout constant.
        let row_scale =
            ExpScale { net: Some(preset), codec: codec.or(scale.codec), ..scale };
        let mut cfg = SimConfig::for_meta(n, &meta);
        cfg.partition = Partition::Dirichlet(0.6);
        row_scale.configure(&mut cfg, &meta);
        cfg.seed = scale.seed;
        let res = sim::run(trainer, &cfg).expect("scenario run");

        let adaptive = res
            .reports
            .iter()
            .filter(|r| {
                matches!(r.cause, TerminationCause::Converged | TerminationCause::Signaled)
            })
            .count();
        // No faults are scheduled, so every crash detection is the network
        // fooling the timeout detector.
        let false_suspicions: usize = res
            .reports
            .iter()
            .flat_map(|r| &r.history)
            .map(|h| h.crashes_detected.len())
            .sum();
        table.row(&[
            label,
            pct(res.mean_accuracy()),
            res.rounds().to_string(),
            secs(res.wall),
            format!("{:.0}", 100.0 * adaptive as f32 / n as f32),
            false_suspicions.to_string(),
            cfg.protocol.codec.name(),
            format!("{:.1}", res.net.bytes_per_round(res.rounds()) / 1024.0),
        ]);
    };
    for preset in NetPreset::ALL {
        run_row(preset.name().to_string(), preset, None);
    }
    // Codec comparison rows (DESIGN.md §13): the two heaviest presets
    // re-run under delta:64, so the table shows dense vs delta kB/round on
    // the same seed — the order-of-magnitude claim, measured not argued.
    let delta = CodecSpec::Delta { k: 64, q16: false };
    for preset in [NetPreset::Wan, NetPreset::LossyBurst] {
        run_row(format!("{}+{}", preset.name(), delta.name()), preset, Some(delta));
    }
    table
}

/// Topology sweep (DESIGN.md §9) — the O(n·d) vs O(n²) message-volume
/// comparison, measured: the Phase-2 protocol on one seed across the full
/// mesh and the sparse overlay presets.  Everything but the overlay is
/// held fixed (data, partitions, network, fault-freeness), so per-round
/// message count and bytes isolate the dissemination cost, while rounds /
/// adaptive-termination / accuracy show what multi-hop dissemination does
/// to convergence and the CRT flood.
pub fn topologies(trainer: &(dyn Trainer + Sync), scale: ExpScale) -> Table {
    let meta = trainer.meta().clone();
    let n = if scale.quick { 24 } else { 48 };
    let sweep = [
        TopologySpec::Full,
        TopologySpec::Ring { k: 2 },
        TopologySpec::KRegular { d: 6 },
        TopologySpec::SmallWorld { d: 6, p: 0.1 },
    ];
    let mut table = Table::new(&[
        "Topology",
        "Max degree",
        "Msgs/round",
        "kB/round",
        "Rounds",
        "Adaptive Term. (%)",
        "Accuracy (%)",
        "kB saved/round",
        "Δ-hit (%)",
    ]);
    for spec in sweep {
        // The overlay is the sweep variable; `scale.topology` (the global
        // `--topology` override) must not leak into the sweep, so the row
        // forces its own spec after `configure`.
        let mut cfg = SimConfig::for_meta(n, &meta);
        cfg.partition = Partition::Dirichlet(0.6);
        scale.configure(&mut cfg, &meta);
        if scale.net.is_none() {
            // No global --net override: run the sweep's default (LAN)
            // with the experiment seed, as scenarios() does, so a seed
            // sweep actually varies the network schedule too.
            cfg.net = NetworkModel::lan(scale.seed);
            clear_latency_ceiling(&mut cfg, &meta);
        }
        cfg.topology = spec;
        cfg.seed = scale.seed;
        // Same derivation sim::run uses, so the column describes the
        // graph this row actually ran on.
        let graph = cfg.build_topology().expect("sweep spec");
        let res = sim::run(trainer, &cfg).expect("topology run");
        let adaptive = res
            .reports
            .iter()
            .filter(|r| {
                matches!(r.cause, TerminationCause::Converged | TerminationCause::Signaled)
            })
            .count();
        table.row(&[
            spec.name(),
            graph.max_degree().to_string(),
            format!("{:.0}", res.msgs_per_round()),
            format!("{:.1}", res.net.bytes_per_round(res.rounds()) / 1024.0),
            res.rounds().to_string(),
            format!("{:.0}", 100.0 * adaptive as f32 / n as f32),
            pct(res.mean_accuracy()),
            // Zero under the default dense codec; a `--codec delta:K`
            // override turns these into the per-overlay savings columns.
            format!(
                "{:.1}",
                res.net.bytes_saved as f64 / res.rounds().max(1) as f64 / 1024.0
            ),
            format!("{:.0}", res.net.delta_hit_rate() * 100.0),
        ]);
    }
    table
}
