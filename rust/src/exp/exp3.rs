//! Experiment 3 (Figures 7 & 8) — maximum fault analysis (n−1 crashes).
//!
//! Only one client survives.  Paper shape: accuracy well below the
//! fault-free system but *above* the isolated non-IID single-client
//! baseline of Table 2 (the survivor benefited from early collaboration);
//! time drops with fewer effective participants.

use super::{pct, secs, ExpScale};
use crate::coordinator::fault::max_fault_schedule;
use crate::runtime::Trainer;
use crate::sim::{self, Partition, SimConfig};
use crate::util::benchkit::Table;

pub fn fig7_8(trainer: &(dyn Trainer + Sync), scale: ExpScale) -> Table {
    let meta = trainer.meta().clone();
    let counts: Vec<usize> = if scale.quick { vec![4, 12] } else { vec![4, 6, 8, 10, 12] };
    let mut table = Table::new(&[
        "Clients",
        "Faults",
        "Survivor Acc (%)",
        "Time (s)",
        "Rounds",
    ]);
    for &n in &counts {
        let mut cfg = SimConfig::for_meta(n, &meta);
        cfg.machines = 2;
        cfg.partition = Partition::Dirichlet(0.6);
        scale.configure(&mut cfg, &meta);
        cfg.seed = scale.seed + 41 * n as u64;
        cfg.faults = max_fault_schedule(n, 0, cfg.protocol.max_rounds);
        let res = sim::run(trainer, &cfg).expect("exp3 run");
        table.row(&[
            n.to_string(),
            (n - 1).to_string(),
            pct(res.mean_accuracy()),
            secs(res.wall),
            res.rounds().to_string(),
        ]);
    }
    table
}
