//! Byzantine sweep (DESIGN.md §11) — adversaries vs robust aggregation,
//! measured.
//!
//! The paper's fault model is crash-only: a faulty client falls silent
//! and the timeout detector excludes it.  Asynchronous Byzantine FL
//! (arXiv:2406.01438) studies the complementary adversary — a client
//! that stays live but *lies*.  This driver pits the adversary roster
//! ([`crate::coordinator::AdversarySpec`]) against the aggregation rules
//! ([`crate::runtime::AggregationRule`]) on one fixed substrate: a
//! `k-regular:6` overlay, Dirichlet(0.6) partitions, LAN network,
//! `--quorum auto`, ~25% of clients adversarial at ids spread evenly
//! through the ring so every neighborhood sees some of them.  Rows —
//!
//! * `fedavg / none` — the control: the byte-identical default path;
//! * `fedavg / poison:-10` — the attack succeeding: sign-flipped
//!   amplified updates averaged straight into every honest neighbor;
//! * `trimmed-mean:2`, `coord-median`, `krum:2` vs the same poison —
//!   the defense: order statistics discard the outlier rows;
//! * `fedavg / forge-suspicion` — the termination attack: selective
//!   silence flaps the suspect/revive detector to stall strict-quorum
//!   CCC; `--quorum auto` learns the flap rate instead.
//!
//! Health columns count *honest* clients only (an adversary's own report
//! is not a claim this table defends): adaptive-termination share and
//! mean final accuracy, plus rounds as the termination-cost axis.

use super::{clear_latency_ceiling, pct, ExpScale};
use crate::coordinator::config::QuorumSpec;
use crate::coordinator::fault::{AdversaryKind, AdversarySpec};
use crate::coordinator::termination::TerminationCause;
use crate::net::{ClientId, NetworkModel, TopologySpec};
use crate::runtime::{AggregationRule, Trainer};
use crate::sim::{self, Partition, SimConfig};
use crate::util::benchkit::Table;

pub fn byzantine(trainer: &(dyn Trainer + Sync), scale: ExpScale) -> Table {
    let meta = trainer.meta().clone();
    let n = if scale.quick { 24 } else { 48 };
    // ~25% adversaries, spread every 4th id so each k-regular:6
    // neighborhood (ring + chords) contains some but never a majority.
    let adv_ids: Vec<ClientId> = (0..n as ClientId).filter(|i| i % 4 == 2).collect();
    let roster = |kind: AdversaryKind| vec![AdversarySpec { kind, clients: adv_ids.clone() }];
    let poison = AdversaryKind::Poison { scale: -10.0 };
    let rows: [(&str, &str, Vec<AdversarySpec>); 6] = [
        ("fedavg", "none", vec![]),
        ("fedavg", "poison:-10", roster(poison)),
        ("trimmed-mean:2", "poison:-10", roster(poison)),
        ("coord-median", "poison:-10", roster(poison)),
        ("krum:2", "poison:-10", roster(poison)),
        ("fedavg", "forge-suspicion", roster(AdversaryKind::ForgeSuspicion)),
    ];
    let mut table = Table::new(&[
        "Rule",
        "Adversary",
        "Advs",
        "Honest Adaptive (%)",
        "Rounds",
        "Honest Acc. (%)",
    ]);
    for (rule, adversary, adversaries) in rows {
        let mut cfg = SimConfig::for_meta(n, &meta);
        cfg.partition = Partition::Dirichlet(0.6);
        scale.configure(&mut cfg, &meta);
        if scale.net.is_none() {
            cfg.net = NetworkModel::lan(scale.seed);
            clear_latency_ceiling(&mut cfg, &meta);
        }
        if scale.topology.is_none() {
            cfg.topology = TopologySpec::KRegular { d: 6 };
        }
        if scale.quorum.is_none() {
            cfg.protocol.quorum = QuorumSpec::parse("auto").expect("auto quorum");
        }
        // The rule is this sweep's variable, so it overrides the scale's
        // `--agg` (configure() just applied it); everything else a CLI
        // flag set still wins above.
        cfg.protocol.agg = AggregationRule::parse(rule).expect("sweep rule");
        let n_adv = adversaries.iter().map(|a| a.clients.len()).sum::<usize>();
        cfg.adversaries = adversaries;
        cfg.seed = scale.seed;
        let res = sim::run(trainer, &cfg).expect("byzantine-sweep run");
        let honest: Vec<_> = res
            .reports
            .iter()
            .filter(|r| !adv_ids.contains(&r.id) || adversary == "none")
            .collect();
        let adaptive = honest
            .iter()
            .filter(|r| {
                matches!(r.cause, TerminationCause::Converged | TerminationCause::Signaled)
            })
            .count();
        let acc = crate::metrics::mean(honest.iter().filter_map(|r| r.final_accuracy));
        table.row(&[
            rule.to_string(),
            adversary.to_string(),
            n_adv.to_string(),
            format!("{:.0}", 100.0 * adaptive as f32 / honest.len().max(1) as f32),
            res.rounds().to_string(),
            pct(acc),
        ]);
    }
    table
}
